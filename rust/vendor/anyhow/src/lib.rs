//! Minimal, API-compatible shim of the `anyhow` crate.
//!
//! The build environment has no network access and no vendored registry, so
//! the real crate cannot be fetched. This shim implements exactly the
//! surface the `hgca` crate uses: [`Error`], [`Result`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension trait.
//!
//! Differences from the real crate (none observable by our callers):
//! * `Display` prints the whole context chain (`msg: cause: cause`), which
//!   matches real anyhow's `{:#}` alternate form;
//! * no backtraces, no downcasting.

use std::fmt;

/// Error type: a message plus an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from anything printable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error {
            msg: c.to_string(),
            source: Some(Box::new(self)),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            write!(f, ": {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> Result<()>` prints errors with Debug — keep it readable.
        write!(f, "{self}")
    }
}

// NOTE: deliberately no `impl std::error::Error for Error` — that keeps the
// blanket conversion below coherent (same trick as the real crate, which
// relies on specialization-free overlap rules).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // flatten the std error chain into our linked list, top-down
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Box<Error>> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Box::new(Error { msg: m, source: err }));
        }
        *err.expect("at least one message")
    }
}

/// `anyhow::Result<T>` with our [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(c).context_cause(e))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(f()).context_cause(e))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

impl Error {
    fn context_cause<E: fmt::Display>(mut self, cause: E) -> Error {
        self.source = Some(Box::new(Error::msg(cause)));
        self
    }
}

/// Construct an [`Error`] from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($tt:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($tt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn bail_formats() {
        assert_eq!(fails().unwrap_err().to_string(), "boom 42");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(-1).unwrap_err().to_string().contains("-1"));
    }

    #[test]
    fn context_chains_display() {
        let base: Result<()> = Err(anyhow!("inner"));
        let wrapped = base.context("outer").unwrap_err();
        assert_eq!(wrapped.to_string(), "outer: inner");
    }

    #[test]
    fn with_context_lazy() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| format!("step {}", 7)).unwrap_err();
        assert!(e.to_string().starts_with("step 7"));
    }

    #[test]
    fn from_std_error_via_question_mark() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(!io().unwrap_err().to_string().is_empty());
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u8).context("missing").unwrap(), 3);
    }
}
