//! Conformance + determinism suite for the persistent CPU attention pool
//! and the continuous batcher (the PR's tentpole):
//!
//! * concurrent HTTP requests through the continuous-batching engine loop
//!   produce exactly the tokens sequential execution produces;
//! * requests admitted mid-flight neither perturb running sequences nor
//!   get perturbed by them;
//! * FIFO admission bounds queue wait (no starvation);
//! * end-to-end generation is invariant to the pool parallelism cap.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::rc::Rc;

use hgca::config::HgcaConfig;
use hgca::engine::batcher::{Batcher, Request};
use hgca::engine::{Engine, Policy};
use hgca::runtime::PjrtRuntime;
use hgca::util::json::Json;

fn runtime() -> Rc<PjrtRuntime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Rc::new(PjrtRuntime::new(&dir).expect("runtime"))
}

fn prompts() -> Vec<String> {
    (0..6)
        .map(|i| format!("The expedition number {i} mapped the region around "))
        .collect()
}

/// Sequential ground truth: a fresh engine generates each prompt alone.
fn sequential_texts(max_new: &[usize]) -> Vec<Vec<u8>> {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    prompts()
        .iter()
        .zip(max_new.iter())
        .map(|(p, &m)| {
            let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
            let mut seq = engine.new_sequence(0, p.as_bytes());
            engine.generate(&mut seq, m).unwrap()
        })
        .collect()
}

fn http(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    let status: u16 = out.split_whitespace().nth(1).unwrap().parse().unwrap();
    let body = out.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    (status, body)
}

#[test]
fn concurrent_server_requests_match_sequential() {
    let max_new: Vec<usize> = (0..6).map(|i| 5 + i % 3).collect();
    let expected = sequential_texts(&max_new);

    let (tx, rx) = std::sync::mpsc::channel();
    let (addr, _h) = hgca::server::serve("127.0.0.1:0", tx).unwrap();
    let engine_thread = std::thread::spawn(move || {
        let rt = runtime();
        let mr = rt.load_model("tiny").unwrap();
        let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
        let _ = hgca::server::api::engine_loop(&mut engine, rx, 4);
    });

    // fire all six requests concurrently — more than the batch has rows, so
    // some queue while others decode
    let clients: Vec<_> = prompts()
        .into_iter()
        .zip(max_new.iter().copied())
        .map(|(p, m)| {
            std::thread::spawn(move || {
                let body =
                    format!(r#"{{"prompt": "{p}", "max_new_tokens": {m}}}"#);
                let (st, body) = http(addr, "POST", "/v1/generate", &body);
                assert_eq!(st, 200, "body: {body}");
                let j = Json::parse(&body).unwrap();
                (
                    j.req_str("text").unwrap().to_string(),
                    j.req_usize("completion_tokens").unwrap(),
                )
            })
        })
        .collect();
    let results: Vec<(String, usize)> = clients.into_iter().map(|c| c.join().unwrap()).collect();

    for (i, ((text, count), want)) in results.iter().zip(expected.iter()).enumerate() {
        assert_eq!(*count, max_new[i], "request {i} token count");
        let want_text = String::from_utf8_lossy(want).to_string();
        assert_eq!(
            *text, want_text,
            "request {i}: concurrent execution changed the tokens"
        );
    }

    // serving metrics must show the batcher actually interleaved requests
    let (st, body) = http(addr, "GET", "/v1/metrics", "");
    assert_eq!(st, 200);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.req_f64("batch_completed").unwrap() as u64, 6);
    assert!(j.req_f64("pool_submissions").unwrap() > 0.0);
    assert!(j.req_f64("pool_jobs").unwrap() >= j.req_f64("pool_tasks").unwrap());

    drop(engine_thread);
}

#[test]
fn mid_flight_admission_does_not_perturb_running_sequences() {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();

    // ground truth, one sequence at a time
    let texts = sequential_texts(&[8, 8, 8, 8, 8, 8]);

    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let mut batcher = Batcher::new(4);
    let ps = prompts();
    // first two requests start decoding…
    for i in 0..2 {
        batcher.submit(Request {
            id: i as u64,
            prompt: ps[i].as_bytes().to_vec(),
            max_new_tokens: 8,
        });
    }
    batcher.tick(&mut engine).unwrap();
    batcher.tick(&mut engine).unwrap();
    // …then four more join the running batch mid-flight
    for i in 2..6 {
        batcher.submit(Request {
            id: i as u64,
            prompt: ps[i].as_bytes().to_vec(),
            max_new_tokens: 8,
        });
    }
    let mut done = batcher.run_to_completion(&mut engine).unwrap();
    done.sort_by_key(|c| c.id);
    assert_eq!(done.len(), 6);
    for (c, want) in done.iter().zip(texts.iter()) {
        assert_eq!(
            c.text, *want,
            "request {}: batched tokens diverge from sequential",
            c.id
        );
    }
    // late arrivals were admitted after the loop started ticking
    assert!(done[2..].iter().all(|c| c.admit_tick >= 2));
}

#[test]
fn fifo_admission_bounds_queue_wait() {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let (batch, n_req, max_new) = (4usize, 12usize, 5usize);
    let mut batcher = Batcher::new(batch);
    for i in 0..n_req {
        batcher.submit(Request {
            id: i as u64,
            prompt: format!("request {i} about the garrison ").into_bytes(),
            max_new_tokens: max_new,
        });
    }
    let mut done = batcher.run_to_completion(&mut engine).unwrap();
    assert_eq!(done.len(), n_req);
    done.sort_by_key(|c| c.id);
    // FIFO: admission order follows submission order
    for pair in done.windows(2) {
        assert!(
            pair[0].admit_tick <= pair[1].admit_tick,
            "admission reordered: {} at {} vs {} at {}",
            pair[0].id,
            pair[0].admit_tick,
            pair[1].id,
            pair[1].admit_tick
        );
    }
    // no starvation: a request queued behind Q others waits at most
    // ceil(Q / batch) cohorts × max_new ticks
    let cohorts = n_req.div_ceil(batch) as u64 - 1;
    let bound = cohorts * max_new as u64;
    for c in &done {
        assert!(
            c.queue_ticks <= bound,
            "request {} starved: waited {} ticks (bound {bound})",
            c.id,
            c.queue_ticks
        );
    }
    let s = batcher.stats();
    assert_eq!(s.completed, n_req as u64);
    assert_eq!(s.queued, 0);
    assert_eq!(s.active, 0);
    assert!(s.max_queue_ticks <= bound);
    // equal-length cohorts keep the batch essentially full
    assert!(
        s.mean_occupancy > 0.9,
        "occupancy {:.3} — rows sat idle",
        s.mean_occupancy
    );
}

#[test]
fn generation_invariant_to_pool_parallelism_cap() {
    let rt = runtime();
    let mr = rt.load_model("tiny-small").unwrap();
    let gen = |threads: usize| {
        let cfg = HgcaConfig {
            blk_size: 8,
            blk_num: 4,
            cpu_threads: threads,
            ..Default::default()
        };
        let mut engine = Engine::new(&mr, cfg, Policy::Hgca { beta: 1.0 });
        let mut seq = engine.new_sequence(0, b"The railway company surveyed ");
        engine.generate(&mut seq, 24).unwrap()
    };
    let reference = gen(1);
    for threads in [2usize, 7, 64] {
        assert_eq!(gen(threads), reference, "threads={threads}");
    }
}

#[test]
fn repeated_batched_runs_are_bitwise_stable() {
    // same submissions, fresh engine each time → identical completions,
    // regardless of pool scheduling
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let run = || {
        let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
        let mut batcher = Batcher::new(4);
        for (i, p) in prompts().iter().enumerate() {
            batcher.submit(Request {
                id: i as u64,
                prompt: p.as_bytes().to_vec(),
                max_new_tokens: 6,
            });
        }
        let mut done = batcher.run_to_completion(&mut engine).unwrap();
        done.sort_by_key(|c| c.id);
        done.into_iter().map(|c| c.text).collect::<Vec<_>>()
    };
    assert_eq!(run(), run());
}
