//! Trace-replay conformance: the harness drives the *real* serving stack
//! (batcher + lifecycle + GPU KV pool + NUMA placement), and the same
//! `(scenario, seed)` must replay to bitwise-identical per-request
//! outcomes — across repeated runs and across 1/2/4 synthetic NUMA
//! nodes, extending the bitwise discipline of integration_numa.rs to the
//! open-loop workload path. Also pins the fault-injection and shed knobs
//! with structurally-certain inline scenarios, and cross-checks the
//! report's JSON keys against SCENARIO_baseline.json so the CI gate and
//! the report cannot drift apart.

use std::path::{Path, PathBuf};
use std::rc::Rc;

use hgca::config::HgcaConfig;
use hgca::engine::{Engine, FinishReason, Policy};
use hgca::runtime::PjrtRuntime;
use hgca::simulator::trace::{parse, replay, ReplayOptions, ReplayReport, Scenario};
use hgca::util::json::Json;

fn runtime() -> Rc<PjrtRuntime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Rc::new(PjrtRuntime::new(&dir).expect("runtime"))
}

fn scenario_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("scenarios")
}

fn load(name: &str) -> Scenario {
    let path = scenario_dir().join(name);
    let src = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    parse(&src).unwrap_or_else(|e| panic!("{}: {e}", path.display()))
}

/// One replay on a fresh engine — fresh because the engine RNG seeds at
/// construction, which is what makes two runs comparable at all.
fn run(scn: &Scenario, nodes: usize, seed: Option<u64>) -> ReplayReport {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    replay(&mut engine, scn, &ReplayOptions { nodes, seed, ..Default::default() }).expect("replay")
}

const CHECKED_IN: &[&str] = &[
    "steady_decode.scn",
    "prefill_storm.scn",
    "deadline_edf.scn",
    "client_churn.scn",
    "diurnal_phases.scn",
    "shared_prefix.scn",
    "multi_turn.scn",
];

#[test]
fn same_seed_runs_are_bitwise_identical_for_every_checked_in_scenario() {
    for file in CHECKED_IN {
        let scn = load(file);
        let a = run(&scn, 1, None);
        let b = run(&scn, 1, None);
        assert_eq!(a.outcomes, b.outcomes, "{file}: same-seed runs diverged");
        assert_eq!(a.digest(), b.digest(), "{file}");
        // every trace request is accounted for exactly once, in id order
        assert_eq!(a.outcomes.len(), scn.requests, "{file}");
        for (i, o) in a.outcomes.iter().enumerate() {
            assert_eq!(o.id, i as u64 + 1, "{file}: outcome ids must be dense");
            assert!(o.finish_tick >= o.arrive_tick, "{file}: request {} time-travelled", o.id);
        }
    }
}

#[test]
fn outcomes_are_invariant_across_1_2_4_synthetic_numa_nodes() {
    // the scenarios with the richest admission traffic (shared_prefix
    // runs with the prefix cache auto-enabled); the full set is swept by
    // `hgca replay --verify` in the CI scenario-replay job
    for file in ["steady_decode.scn", "client_churn.scn", "shared_prefix.scn"] {
        let scn = load(file);
        let one = run(&scn, 1, None);
        for nodes in [2usize, 4] {
            let multi = run(&scn, nodes, None);
            assert_eq!(
                one.outcomes, multi.outcomes,
                "{file}: outcomes differ between 1 and {nodes} synthetic NUMA nodes"
            );
            assert_eq!(one.digest(), multi.digest(), "{file}");
            assert_eq!(multi.nodes, nodes);
        }
    }
}

#[test]
fn seed_override_changes_the_trace() {
    let scn = load("steady_decode.scn");
    let a = run(&scn, 1, None);
    let b = run(&scn, 1, Some(scn.seed + 1));
    assert_eq!(a.seed, scn.seed);
    assert_eq!(b.seed, scn.seed + 1);
    assert_ne!(a.digest(), b.digest(), "a different seed must sample a different trace");
}

#[test]
fn churn_scenario_exercises_the_fault_knobs() {
    let scn = load("client_churn.scn");
    let r = run(&scn, 1, None);
    let cancelled = r.count(FinishReason::Cancelled);
    let disconnected = r.count(FinishReason::Disconnected);
    // 24 requests each draw cancel (p=0.3) and disconnect (p=0.3); the
    // chance a fixed seed dodges both everywhere is 0.49^24 ≈ 4e-8
    assert!(cancelled + disconnected >= 1, "churn scenario never tripped a fault");
    for o in &r.outcomes {
        if o.finish_reason == FinishReason::Cancelled
            || o.finish_reason == FinishReason::Disconnected
        {
            assert!(
                o.decode_steps < scn.gen.min() as usize || o.text.len() < scn.gen.min() as usize,
                "request {} was faulted after {}..{} ticks yet ran to its full budget",
                o.id,
                1,
                6
            );
        }
    }
}

/// `cancel 1.0 after fixed(2)` with a 50-token budget: every request is
/// cancelled mid-flight, with certainty — no probability involved.
#[test]
fn cancel_fault_trips_every_request_mid_flight() {
    let scn = parse(
        "scenario cancel_all {\n  requests 4\n  arrival fixed(interval=1)\n  prompt fixed(32)\n  gen fixed(50)\n  cancel 1.0 after fixed(2)\n}",
    )
    .unwrap();
    let r = run(&scn, 1, None);
    assert_eq!(r.count(FinishReason::Cancelled), 4);
    assert!(r.outcomes.iter().all(|o| o.decode_steps < 50));
}

#[test]
fn disconnect_fault_trips_every_request_mid_flight() {
    let scn = parse(
        "scenario disconnect_all {\n  requests 4\n  arrival fixed(interval=1)\n  prompt fixed(32)\n  gen fixed(50)\n  disconnect 1.0 after fixed(2)\n}",
    )
    .unwrap();
    let r = run(&scn, 1, None);
    assert_eq!(r.count(FinishReason::Disconnected), 4);
}

/// `queue_bound 0` on a batch-1 burst: the head of the burst is admitted
/// on the first tick, everything still queued one tick later has waited
/// `1 > 0` ticks and is shed as a queue timeout.
#[test]
fn queue_bound_sheds_surface_as_queue_timeout_outcomes() {
    let scn = parse(
        "scenario shed_all {\n  requests 6\n  batch 1\n  kv_slots 1\n  queue_bound 0\n  arrival bursty(period=100, size=6)\n  prompt fixed(16)\n  gen fixed(5)\n}",
    )
    .unwrap();
    let r = run(&scn, 1, None);
    assert_eq!(r.count(FinishReason::Length), 1);
    assert_eq!(r.count(FinishReason::QueueTimeout), 5);
    assert_eq!(r.watermark_shed, 0, "these sheds are queue timeouts, not watermark rejections");
}

/// `watermark 2` against a size-6 burst at tick 0: requests 1-2 enter
/// (pending 0 then 1), requests 3-6 find pending = 2 and `2 + 1 > 2`, so
/// the door rejects them before they ever reach the queue.
#[test]
fn watermark_sheds_are_rejected_at_the_door() {
    let scn = parse(
        "scenario door_shed {\n  requests 6\n  batch 1\n  kv_slots 1\n  watermark 2\n  arrival bursty(period=100, size=6)\n  prompt fixed(16)\n  gen fixed(3)\n}",
    )
    .unwrap();
    let r = run(&scn, 1, None);
    assert_eq!(r.watermark_shed, 4);
    assert_eq!(r.count(FinishReason::QueueTimeout), 4);
    assert_eq!(r.count(FinishReason::Length), 2);
    for o in &r.outcomes {
        if o.finish_reason == FinishReason::QueueTimeout {
            assert_eq!(o.finish_tick, o.arrive_tick, "door sheds never enter the system");
            assert_eq!(o.queue_ticks, 0);
            assert!(o.text.is_empty());
        }
    }
}

/// Every metric key the checked-in baseline gates (plain, `_max`, or
/// `_min`) must exist in the replay report's JSON — a baseline typo or a
/// renamed report field fails here, not as a silent gate pass.
#[test]
fn baseline_keys_match_the_report_schema() {
    let report = run(&load("steady_decode.scn"), 1, None).to_json();
    let baseline_path =
        Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("SCENARIO_baseline.json");
    let baseline = Json::parse(&std::fs::read_to_string(&baseline_path).unwrap())
        .unwrap_or_else(|e| panic!("{}: {e:?}", baseline_path.display()));
    let scenarios = baseline
        .get("scenarios")
        .and_then(|s| s.as_arr())
        .expect("baseline 'scenarios' array");
    let mut names = Vec::new();
    for entry in scenarios {
        let obj = entry.as_obj().expect("baseline scenario object");
        names.push(obj["name"].as_str().expect("name").to_string());
        for key in obj.keys() {
            if key == "name" || key == "additive" {
                continue;
            }
            let metric = key.strip_suffix("_max").or_else(|| key.strip_suffix("_min")).unwrap_or(key);
            assert!(
                report.get(metric).is_some(),
                "baseline gates '{key}' but the replay report has no '{metric}' field"
            );
        }
    }
    // the baseline covers exactly the checked-in scenario set
    let mut expected: Vec<String> = CHECKED_IN
        .iter()
        .map(|f| f.trim_end_matches(".scn").to_string())
        .collect();
    names.sort();
    expected.sort();
    assert_eq!(names, expected);
    // and the report carries the digest the gate can optionally pin
    assert!(report.get("outcome_digest").and_then(|d| d.as_str()).is_some());
}
