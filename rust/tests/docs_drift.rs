//! Metrics/doc drift gate: the counter set emitted by `GET /v1/metrics`
//! must exactly match the counters documented in docs/API.md. The test
//! parses the doc's metric tables (first-column backticked names, with
//! `{i}` templates for per-node fields) and compares them two-way against
//! a real `handle_metrics` response — an undocumented counter and a
//! documented-but-gone counter both fail, naming the offender.

use std::collections::BTreeSet;
use std::path::Path;
use std::rc::Rc;

use hgca::config::HgcaConfig;
use hgca::engine::{Batcher, Engine, Policy};
use hgca::runtime::PjrtRuntime;
use hgca::server::api::handle_metrics;
use hgca::util::json::Json;

fn runtime() -> Rc<PjrtRuntime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Rc::new(PjrtRuntime::new(&dir).expect("runtime"))
}

/// Metric names documented in API.md between `## GET /v1/metrics` and the
/// next top-level section: every backticked token in the *first* column
/// of the metric tables (one row may document several fields).
fn documented_metrics() -> BTreeSet<String> {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("docs/API.md");
    let doc = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    let start = doc.find("## GET /v1/metrics").expect("API.md documents GET /v1/metrics");
    let section = &doc[start + 2..]; // skip past "##" so the end-scan finds the *next* section
    let end = section.find("\n## ").map(|i| i + 2).unwrap_or(section.len());
    let section = &doc[start..start + end];

    let mut out = BTreeSet::new();
    for line in section.lines() {
        let mut cells = line.split('|');
        let Some(first) = cells.nth(1) else { continue }; // cells[0] is the "" before the leading '|'
        // backticked tokens in the first cell: `a`, `b` — each is a field
        let mut rest = first;
        while let Some(open) = rest.find('`') {
            let tail = &rest[open + 1..];
            let Some(close) = tail.find('`') else { break };
            let token = &tail[..close];
            rest = &tail[close + 1..];
            let valid = !token.is_empty()
                && token
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '{' || c == '}');
            if valid {
                out.insert(token.to_string());
            }
        }
    }
    assert!(
        out.len() > 20,
        "API.md metric tables parsed to only {} names — did the doc format change?",
        out.len()
    );
    out
}

/// Collapse every maximal digit run to `{i}`, so `pool_node3_tasks`
/// matches its documented template `pool_node{i}_tasks`.
fn templated(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    let mut in_digits = false;
    for c in name.chars() {
        if c.is_ascii_digit() {
            if !in_digits {
                out.push_str("{i}");
                in_digits = true;
            }
        } else {
            in_digits = false;
            out.push(c);
        }
    }
    out
}

#[test]
fn metrics_counters_match_api_doc_exactly() {
    let documented = documented_metrics();

    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    // a bounded per-node budget makes the kv_blocks_free_node{i} family
    // appear, so the template rows are actually exercised
    engine.set_kv_node_budgets(vec![engine.blocks_per_sequence()]);
    let batcher = Batcher::new(2);
    let resp = handle_metrics(&engine, Some(&batcher));
    assert_eq!(resp.status, 200);
    let body = Json::parse(&resp.body).expect("metrics body is JSON");
    let emitted: BTreeSet<String> = body.as_obj().expect("flat object").keys().cloned().collect();

    let mut undocumented = Vec::new();
    for name in &emitted {
        if !documented.contains(name) && !documented.contains(&templated(name)) {
            undocumented.push(name.clone());
        }
    }
    assert!(
        undocumented.is_empty(),
        "counters emitted by /v1/metrics but missing from docs/API.md: {undocumented:?}"
    );

    let emitted_templates: BTreeSet<String> = emitted.iter().map(|n| templated(n)).collect();
    let mut gone = Vec::new();
    for name in &documented {
        let live = if name.contains("{i}") {
            emitted_templates.contains(name)
        } else {
            emitted.contains(name)
        };
        if !live {
            gone.push(name.clone());
        }
    }
    assert!(
        gone.is_empty(),
        "counters documented in docs/API.md but absent from /v1/metrics: {gone:?} \
         (emitted: {emitted:?})"
    );
}
