//! Conformance suite for the deadline- and resource-aware scheduler
//! (docs/SCHEDULING.md): earliest-deadline-first admission over a
//! capacity-bounded GPU KV pool, plus infeasible-deadline pre-emption.
//!
//! The load-bearing invariants (ISSUE acceptance):
//! * EDF ordering — a later-submitted request with an earlier deadline is
//!   admitted first; requests without deadlines sort last and FIFO order
//!   breaks ties, and no request starves past its max-queue-wait bound.
//! * Capacity gating — a request needing more blocks than are *currently
//!   free* defers in the queue and admits after reclamation; one needing
//!   more blocks than the pool will *ever* have is rejected up front.
//! * Infeasible-deadline pre-emption — a decoding row that cannot finish
//!   by its deadline even at the fastest observed per-row pace retires
//!   early with partial text and its blocks return immediately.
//!
//! Every surviving/admitted request's tokens must be **bitwise identical**
//! to its isolated run — scheduling decisions never perturb numerics.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::rc::Rc;
use std::time::{Duration, Instant};

use hgca::config::{HgcaConfig, ServingConfig};
use hgca::engine::{Batcher, Engine, FinishReason, Policy, Request, RequestHandle};
use hgca::runtime::PjrtRuntime;
use hgca::util::json::Json;

fn runtime() -> Rc<PjrtRuntime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Rc::new(PjrtRuntime::new(&dir).expect("runtime"))
}

/// Ground truth: a fresh engine generates the prompt alone.
fn isolated(prompt: &str, max_new: usize) -> Vec<u8> {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let mut seq = engine.new_sequence(0, prompt.as_bytes());
    engine.generate(&mut seq, max_new).unwrap()
}

fn req(id: u64, prompt: &str, max_new: usize) -> Request {
    Request {
        id,
        prompt: prompt.as_bytes().to_vec(),
        max_new_tokens: max_new,
    }
}

fn deadline_in(secs: u64) -> RequestHandle {
    RequestHandle {
        deadline: Some(Instant::now() + Duration::from_secs(secs)),
        ..Default::default()
    }
}

// ---------------------------------------------------------------------
// EDF ordering (batcher-level, deterministic in ticks)
// ---------------------------------------------------------------------

#[test]
fn edf_admits_later_submitted_earlier_deadline_first() {
    let filler_prompt = "The railway company surveyed ";
    let b_prompt = "The granary stored ";
    let c_prompt = "The lighthouse keeper ";
    let want_b = isolated(b_prompt, 6);
    let want_c = isolated(c_prompt, 6);

    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    // one row: admission order is directly observable via admit_tick
    let mut batcher = Batcher::new(1);
    batcher.submit(req(0, filler_prompt, 4));
    let mut done = Vec::new();
    done.extend(batcher.tick(&mut engine).unwrap()); // filler occupies the row
    // B first, no deadline; C later, with a (loose) deadline: EDF must
    // admit C first when the row frees, FIFO would have picked B
    batcher.submit(req(1, b_prompt, 6));
    batcher.submit_with(req(2, c_prompt, 6), deadline_in(3600));
    done.extend(batcher.run_to_completion(&mut engine).unwrap());

    let b = done.iter().find(|c| c.id == 1).expect("B finished");
    let c = done.iter().find(|c| c.id == 2).expect("C finished");
    assert!(
        c.admit_tick < b.admit_tick,
        "earlier-deadline C must be admitted before earlier-submitted B \
         (C tick {}, B tick {})",
        c.admit_tick,
        b.admit_tick
    );
    assert_eq!(c.finish_reason, FinishReason::Length);
    assert_eq!(b.finish_reason, FinishReason::Length, "B admitted after C — not starved");
    // scheduling reordering never perturbs tokens
    assert_eq!(c.text, want_c, "C's tokens diverged from isolated run");
    assert_eq!(b.text, want_b, "B's tokens diverged from isolated run");
    assert_eq!(engine.kv_pool.in_use(), 0);
}

#[test]
fn fifo_breaks_ties_among_equal_and_absent_deadlines() {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let mut batcher = Batcher::new(1);
    batcher.submit(req(0, "The quarry supplied ", 3));
    let mut done = Vec::new();
    done.extend(batcher.tick(&mut engine).unwrap());
    // d1 and d2 share one deadline instant → submission order decides;
    // n3 has none → strictly last
    let shared = Instant::now() + Duration::from_secs(3600);
    let with = |_: u64| RequestHandle {
        deadline: Some(shared),
        ..Default::default()
    };
    batcher.submit_with(req(1, "The first equal ", 3), with(1));
    batcher.submit_with(req(2, "The second equal ", 3), with(2));
    batcher.submit(req(3, "The deadline-free ", 3));
    done.extend(batcher.run_to_completion(&mut engine).unwrap());

    let admit = |id: u64| done.iter().find(|c| c.id == id).unwrap().admit_tick;
    assert!(admit(1) < admit(2), "equal deadlines admit FIFO");
    assert!(admit(2) < admit(3), "no-deadline requests sort last");
    for c in &done {
        assert_eq!(c.finish_reason, FinishReason::Length);
    }
}

#[test]
fn no_deadline_request_never_starves_past_queue_bound() {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let mut batcher = Batcher::new(1);
    // the row stays busy much longer than B's wait bound
    batcher.submit(req(0, "The busy row decodes ", 30));
    // B: no deadline, bounded queue wait; C: deadlined, EDF-preferred
    batcher.submit_with(
        req(1, "The bounded waiter ", 4),
        RequestHandle {
            max_queue_ticks: Some(4),
            ..Default::default()
        },
    );
    batcher.submit_with(req(2, "The deadlined rival ", 4), deadline_in(3600));
    let done = batcher.run_to_completion(&mut engine).unwrap();

    // EDF never admits B ahead of C, but B still exits the queue the
    // moment its wait bound trips — bounded starvation, not unbounded
    let b = done.iter().find(|c| c.id == 1).expect("B resolved");
    assert_eq!(b.finish_reason, FinishReason::QueueTimeout);
    assert!(
        b.queue_ticks > 4 && b.queue_ticks <= 6,
        "B must be shed right after its bound (waited {} ticks)",
        b.queue_ticks
    );
    assert_eq!(b.decode_steps, 0, "shed before admission: no tokens");
    let c = done.iter().find(|c| c.id == 2).expect("C finished");
    assert_eq!(c.finish_reason, FinishReason::Length);
}

// ---------------------------------------------------------------------
// capacity gating (batcher-level)
// ---------------------------------------------------------------------

#[test]
fn request_larger_than_pool_capacity_rejected_up_front() {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let per_seq = engine.blocks_per_sequence();
    engine.set_kv_block_capacity(Some(per_seq - 1)); // can never fit one sequence
    let mut batcher = Batcher::new(2);
    batcher.submit(req(9, "The impossible request ", 4));
    let done = batcher.tick(&mut engine).unwrap();

    assert_eq!(done.len(), 1);
    assert_eq!(done[0].id, 9);
    assert_eq!(done[0].finish_reason, FinishReason::NoCapacity);
    assert_eq!(done[0].decode_steps, 0);
    assert!(done[0].text.is_empty(), "never admitted, never generated");
    assert_eq!(engine.kv_pool.acquired_blocks(), 0, "no KV was ever leased");
    assert_eq!(batcher.stats().retired, 1);
    assert_eq!(batcher.pending(), 0, "rejected, not queued forever");
}

#[test]
fn admission_defers_on_exhausted_pool_then_admits_after_reclamation() {
    let p1 = "The reservoir held ";
    let p2 = "The orchard yielded ";
    let want1 = isolated(p1, 8);
    let want2 = isolated(p2, 6);

    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let per_seq = engine.blocks_per_sequence();
    // exactly one sequence's worth of blocks, but TWO free batch rows:
    // KV availability, not row count, is the binding constraint
    engine.set_kv_block_capacity(Some(per_seq));
    let mut batcher = Batcher::new(2);
    batcher.submit(req(1, p1, 8));
    batcher.submit(req(2, p2, 6));
    let done = batcher.run_to_completion(&mut engine).unwrap();

    let c1 = done.iter().find(|c| c.id == 1).expect("R1 finished");
    let c2 = done.iter().find(|c| c.id == 2).expect("R2 finished");
    assert_eq!(c1.finish_reason, FinishReason::Length);
    assert_eq!(c2.finish_reason, FinishReason::Length);
    assert!(
        c2.admit_tick >= c1.finish_tick,
        "R2 must wait for R1's blocks (admitted tick {}, R1 finished tick {})",
        c2.admit_tick,
        c1.finish_tick
    );
    assert!(c2.queue_ticks > 0, "R2 observably queued");
    let stats = batcher.stats();
    assert!(stats.admissions_deferred > 0, "deferred admissions must be counted");
    // deferral delays, never perturbs: both outputs bitwise-identical
    assert_eq!(c1.text, want1);
    assert_eq!(c2.text, want2);
    assert_eq!(engine.kv_pool.in_use(), 0, "all blocks reclaimed");
    assert_eq!(
        engine.kv_pool.acquired_blocks(),
        2 * per_seq as u64,
        "exactly two admissions ever leased"
    );
}

// ---------------------------------------------------------------------
// infeasible-deadline pre-emption
// ---------------------------------------------------------------------

#[test]
fn infeasible_deadline_preempts_early_with_partial_text() {
    let prompt = "The aqueduct carried ";
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let mut batcher = Batcher::new(1);
    // 10M tokens in 60s is provably impossible after one observed decode
    // tick; the wall clock is nowhere near expiring when the row retires
    batcher.submit_with(req(5, prompt, 10_000_000), deadline_in(60));
    let start = Instant::now();
    let mut done = Vec::new();
    while done.is_empty() {
        assert!(
            start.elapsed() < Duration::from_secs(120),
            "pre-emption never fired (nor did the deadline sweep)"
        );
        done.extend(batcher.tick(&mut engine).unwrap());
    }
    assert!(
        start.elapsed() < Duration::from_secs(30),
        "pre-emption must fire long before the 60s deadline"
    );
    let c = &done[0];
    assert_eq!(c.id, 5);
    assert_eq!(c.finish_reason, FinishReason::Deadline);
    assert!(c.decode_steps >= 1, "at least one decode tick ran first");
    assert!(c.decode_steps < 10_000_000);
    assert_eq!(c.text.len(), c.decode_steps);
    // the partial text is bitwise the prefix an unconstrained run produces
    assert_eq!(c.text, isolated(prompt, c.decode_steps));
    let stats = batcher.stats();
    assert_eq!(stats.deadline_preempted, 1, "counted as a pre-emption");
    assert_eq!(stats.retired, 1);
    assert_eq!(engine.kv_pool.in_use(), 0, "blocks returned immediately");
}

#[test]
fn infeasible_deadline_preempts_prefilling_row_before_absorbing_the_prompt() {
    // ROADMAP satellite: the infeasibility proof extends to prefill. A
    // ~40 MB prompt is ~650k chunks; after ONE observed chunk cost the
    // lower bound (remaining chunks × fastest chunk) provably exceeds the
    // 10s deadline on any real machine, so the row retires at the next
    // tick's sweep instead of grinding chunks until the wall clock expires.
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let mut batcher = Batcher::new(1);
    let huge_prompt = "x".repeat(40_000_000);
    batcher.submit_with(
        Request {
            id: 7,
            prompt: huge_prompt.into_bytes(),
            max_new_tokens: 4,
        },
        deadline_in(10),
    );
    let start = Instant::now();
    let mut done = Vec::new();
    while done.is_empty() {
        assert!(
            start.elapsed() < Duration::from_secs(60),
            "prefill pre-emption never fired (nor did the deadline sweep)"
        );
        done.extend(batcher.tick(&mut engine).unwrap());
    }
    assert!(
        start.elapsed() < Duration::from_secs(8),
        "pre-emption must fire well before the 10s deadline"
    );
    let c = &done[0];
    assert_eq!(c.id, 7);
    assert_eq!(c.finish_reason, FinishReason::Deadline);
    assert_eq!(c.decode_steps, 0, "the prompt never finished absorbing");
    assert!(c.text.is_empty());
    let stats = batcher.stats();
    assert_eq!(stats.deadline_preempted_prefill, 1, "counted as a prefill pre-emption");
    assert_eq!(stats.deadline_preempted, 0, "not mistaken for a decode pre-emption");
    assert_eq!(stats.retired, 1);
    assert_eq!(engine.kv_pool.in_use(), 0, "blocks returned immediately");
}

#[test]
fn feasible_multi_chunk_prefill_deadline_is_never_preempted() {
    // a handful of chunks inside an hour is trivially feasible — the
    // prefill-side proof must stay conservative
    let prompt = "The careful archivist catalogued every ledger ".repeat(8); // ~6 chunks
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let want = {
        let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
        let mut seq = engine.new_sequence(0, prompt.as_bytes());
        engine.generate(&mut seq, 4).unwrap()
    };
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let mut batcher = Batcher::new(1);
    batcher.submit_with(
        Request {
            id: 1,
            prompt: prompt.as_bytes().to_vec(),
            max_new_tokens: 4,
        },
        deadline_in(3600),
    );
    let done = batcher.run_to_completion(&mut engine).unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].finish_reason, FinishReason::Length);
    assert_eq!(done[0].text, want);
    let stats = batcher.stats();
    assert_eq!(stats.deadline_preempted_prefill, 0);
    assert_eq!(stats.deadline_preempted, 0);
}

#[test]
fn feasible_deadline_is_never_preempted() {
    let prompt = "The ferry crossed ";
    let want = isolated(prompt, 5);
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let mut batcher = Batcher::new(1);
    // 5 tokens inside an hour is trivially feasible
    batcher.submit_with(req(1, prompt, 5), deadline_in(3600));
    let done = batcher.run_to_completion(&mut engine).unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].finish_reason, FinishReason::Length);
    assert_eq!(done[0].text, want);
    assert_eq!(batcher.stats().deadline_preempted, 0);
}

// ---------------------------------------------------------------------
// HTTP-level: capacity-bounded serving
// ---------------------------------------------------------------------

fn http_raw(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn http(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let out = http_raw(addr, method, path, body);
    let status: u16 = out.split_whitespace().nth(1).unwrap().parse().unwrap();
    let body = out.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    (status, body)
}

/// Spawn a server + engine loop with the given serving config; returns the
/// bound address.
fn spawn_server(serving: ServingConfig) -> std::net::SocketAddr {
    let (tx, rx) = std::sync::mpsc::channel();
    let (addr, _h) = hgca::server::serve("127.0.0.1:0", tx).unwrap();
    std::thread::spawn(move || {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let rt = Rc::new(PjrtRuntime::new(&dir).unwrap());
        let mr = rt.load_model("tiny").unwrap();
        let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
        let _ = hgca::server::api::engine_loop_with(&mut engine, rx, Batcher::new(4), serving);
    });
    addr
}

#[test]
fn http_never_fits_is_rejected_429_with_distinct_body() {
    // capacity 1 block < any sequence's n_layers × blk_num requirement
    let addr = spawn_server(ServingConfig {
        kv_blocks: Some(1),
        ..Default::default()
    });
    let (st, body) = http(
        addr,
        "POST",
        "/v1/generate",
        r#"{"prompt": "The doomed request ", "max_new_tokens": 4}"#,
    );
    assert_eq!(st, 429, "body: {body}");
    let j = Json::parse(&body).expect("well-formed JSON error");
    assert!(
        j.get("never_fits").and_then(|b| b.as_bool()).unwrap_or(false),
        "won't-ever-fit must be distinguishable from a transient shed: {body}"
    );
    assert_eq!(j.req_str("finish_reason").unwrap(), "capacity");
    assert_eq!(j.req_usize("kv_blocks_capacity").unwrap(), 1);
    assert!(j.req_usize("kv_blocks_needed").unwrap() > 1);

    // batch admissions hit the same check, one count per member
    let (st, _) = http(
        addr,
        "POST",
        "/v1/batch",
        r#"{"prompts": ["a", "b"], "max_new_tokens": 2}"#,
    );
    assert_eq!(st, 429);

    let (st, body) = http(addr, "GET", "/v1/metrics", "");
    assert_eq!(st, 200);
    let m = Json::parse(&body).unwrap();
    assert_eq!(m.req_f64("requests_rejected_capacity").unwrap(), 3.0);
    assert_eq!(m.req_f64("kv_blocks_capacity").unwrap(), 1.0);
    assert_eq!(m.req_f64("batch_submitted").unwrap(), 0.0, "never submitted");
    // the new scheduling counters are exported
    assert_eq!(m.req_f64("admissions_deferred").unwrap(), 0.0);
    assert_eq!(m.req_f64("deadline_preempted").unwrap(), 0.0);
}

#[test]
fn http_exhausted_pool_defers_until_blocks_reclaimed() {
    // headroom 0.25 × batch 4 = exactly one sequence's worth of blocks
    let addr = spawn_server(ServingConfig {
        kv_headroom: 0.25,
        ..Default::default()
    });
    // hog: long-running request that holds the whole pool (id 1)
    let hog = std::thread::spawn(move || {
        http(
            addr,
            "POST",
            "/v1/generate",
            r#"{"prompt": "The hog holds every block ", "max_new_tokens": 100000}"#,
        )
    });
    std::thread::sleep(Duration::from_millis(200));
    // small request: defers behind the hog's blocks (id 2)
    let small_prompt = "The patient visitor ";
    let want = isolated(small_prompt, 3);
    let small = std::thread::spawn(move || {
        let body = format!(r#"{{"prompt": "{small_prompt}", "max_new_tokens": 3}}"#);
        http(addr, "POST", "/v1/generate", &body)
    });
    std::thread::sleep(Duration::from_millis(200));
    // free the blocks: cancel the hog mid-decode
    let (st, body) = http(addr, "POST", "/v1/cancel", r#"{"id": 1}"#);
    assert_eq!(st, 200, "body: {body}");

    let (st, body) = small.join().unwrap();
    assert_eq!(st, 200, "deferred request must eventually admit: {body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.req_str("finish_reason").unwrap(), "length");
    // the wire `text` is the UTF-8-lossy rendering of the generated bytes;
    // compare against the same rendering of the isolated run
    assert_eq!(
        j.req_str("text").unwrap(),
        String::from_utf8_lossy(&want),
        "deferral must not perturb tokens"
    );
    let (st, body) = hog.join().unwrap();
    assert_eq!(st, 200);
    assert_eq!(Json::parse(&body).unwrap().req_str("finish_reason").unwrap(), "cancelled");

    let (st, body) = http(addr, "GET", "/v1/metrics", "");
    assert_eq!(st, 200);
    let m = Json::parse(&body).unwrap();
    assert!(
        m.req_f64("admissions_deferred").unwrap() >= 1.0,
        "the small request's wait must be visible: {body}"
    );
    assert_eq!(m.req_f64("kv_blocks_in_use").unwrap(), 0.0);
}
