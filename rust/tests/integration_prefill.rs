//! Conformance suite for chunked prefill + streaming generation:
//!
//! * a long-prompt admission mid-decode produces bitwise-identical tokens
//!   to isolated execution, and the decode-in-flight sequence advances at
//!   least once between consecutive prefill chunks (no head-of-line
//!   blocking);
//! * the per-tick prefill token budget is configurable and only changes
//!   scheduling, never tokens;
//! * `/v1/generate` with `"stream": true` emits a chunked-transfer NDJSON
//!   stream whose token sequence is byte-identical to the non-streamed
//!   response, and the stream counters surface on `/v1/metrics`.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::rc::Rc;

use hgca::config::HgcaConfig;
use hgca::engine::batcher::{Batcher, Request};
use hgca::engine::{Engine, Policy};
use hgca::runtime::PjrtRuntime;
use hgca::util::json::Json;

fn runtime() -> Rc<PjrtRuntime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Rc::new(PjrtRuntime::new(&dir).expect("runtime"))
}

/// 300 ASCII bytes — five 64-token prefill chunks, and long enough to spill
/// past the default 256-entry GPU window into the CPU store.
fn long_prompt() -> String {
    let mut s = String::new();
    let mut i = 0;
    while s.len() < 300 {
        s.push_str(&format!("Sector {i} of the survey covered the river basin. "));
        i += 1;
    }
    s.truncate(300);
    s
}

/// Ground truth: a fresh engine generates the prompt alone (monolithic
/// prefill via Engine::generate).
fn isolated(prompt: &str, max_new: usize) -> Vec<u8> {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let mut seq = engine.new_sequence(0, prompt.as_bytes());
    engine.generate(&mut seq, max_new).unwrap()
}

fn http_raw(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn http(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let out = http_raw(addr, method, path, body);
    let status: u16 = out.split_whitespace().nth(1).unwrap().parse().unwrap();
    let body = out.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    (status, body)
}

/// Reassemble the payload of a chunked-transfer response body.
fn decode_chunked(raw: &str) -> String {
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("");
    let mut out = String::new();
    let mut rest = body;
    loop {
        let Some((len_line, after)) = rest.split_once("\r\n") else {
            break;
        };
        let len = usize::from_str_radix(len_line.trim(), 16).unwrap_or(0);
        if len == 0 || after.len() < len {
            break;
        }
        out.push_str(&after[..len]);
        rest = after.get(len + 2..).unwrap_or("");
    }
    out
}

#[test]
fn long_prompt_admission_interleaves_with_decode_and_is_conformant() {
    let short = "The railway company surveyed ";
    let long = long_prompt();
    let want_short = isolated(short, 24);
    let want_long = isolated(&long, 8);

    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    // batch=4 matches a compiled artifact batch (the synthetic grid is {1, 4})
    let mut batcher = Batcher::new(4);
    batcher.submit(Request {
        id: 1,
        prompt: short.as_bytes().to_vec(),
        max_new_tokens: 24,
    });
    batcher.tick(&mut engine).unwrap();
    batcher.tick(&mut engine).unwrap();
    // a five-chunk prompt joins while request 1 is mid-decode
    batcher.submit(Request {
        id: 2,
        prompt: long.as_bytes().to_vec(),
        max_new_tokens: 8,
    });
    let mut done = Vec::new();
    let mut prev = batcher.stats();
    let mut chunked_ticks = 0u64;
    while batcher.pending() > 0 {
        done.extend(batcher.tick(&mut engine).unwrap());
        let s = batcher.stats();
        let chunks = s.prefill_chunks - prev.prefill_chunks;
        if chunks > 0 {
            chunked_ticks += 1;
            // the head-of-line invariant: a decode step ran in the same
            // tick, i.e. the in-flight sequence advanced between any two
            // consecutive prefill chunks of the long prompt
            assert!(
                s.decode_steps > prev.decode_steps,
                "prefill chunk scheduled without an interleaved decode step (tick {})",
                s.ticks
            );
            assert_eq!(chunks, 1, "default budget must schedule one chunk per tick");
        }
        prev = s;
    }
    // the long prompt alone needs ceil(300/64) = 5 chunk ticks
    assert!(
        chunked_ticks >= 5,
        "expected >= 5 chunked-prefill ticks, got {chunked_ticks}"
    );
    done.sort_by_key(|c| c.id);
    assert_eq!(done.len(), 2);
    assert_eq!(
        done[0].text, want_short,
        "decode-in-flight sequence perturbed by the chunked admission"
    );
    assert_eq!(
        done[1].text, want_long,
        "chunked prefill diverged from isolated execution"
    );
}

#[test]
fn prefill_budget_packs_multiple_chunks_per_tick() {
    let long = long_prompt();
    let want = isolated(&long, 6);

    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let mut batcher = Batcher::new(4).with_prefill_budget(10_000);
    batcher.submit(Request {
        id: 7,
        prompt: long.as_bytes().to_vec(),
        max_new_tokens: 6,
    });
    let done = batcher.run_to_completion(&mut engine).unwrap();
    let s = batcher.stats();
    assert_eq!(done.len(), 1);
    assert_eq!(
        done[0].text, want,
        "budget sizing changed tokens (must only change scheduling)"
    );
    assert_eq!(s.prefill_chunks, 5, "300 bytes = five 64-token chunks");
    // a large budget absorbs the whole prompt in the admission tick:
    // 6 tokens = 1 from prefill logits + 5 decode steps
    assert_eq!(s.decode_steps, 5);
    assert_eq!(s.ticks, 5);
}

#[test]
fn streamed_output_matches_non_streamed() {
    let prompt = "The expedition mapped the region around ";
    let expected = isolated(prompt, 12);

    let (tx, rx) = std::sync::mpsc::channel();
    let (addr, _h) = hgca::server::serve("127.0.0.1:0", tx).unwrap();
    let engine_thread = std::thread::spawn(move || {
        let rt = runtime();
        let mr = rt.load_model("tiny").unwrap();
        let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
        let _ = hgca::server::api::engine_loop(&mut engine, rx, 4);
    });

    // non-streamed reference through the same server
    let body = format!(r#"{{"prompt": "{prompt}", "max_new_tokens": 12}}"#);
    let (st, resp) = http(addr, "POST", "/v1/generate", &body);
    assert_eq!(st, 200, "body: {resp}");
    let j = Json::parse(&resp).unwrap();
    let plain_text = j.req_str("text").unwrap().to_string();
    assert_eq!(j.req_usize("completion_tokens").unwrap(), 12);

    // streamed: chunked transfer, one NDJSON line per token + summary
    let body = format!(r#"{{"prompt": "{prompt}", "max_new_tokens": 12, "stream": true}}"#);
    let raw = http_raw(addr, "POST", "/v1/generate", &body);
    assert!(raw.starts_with("HTTP/1.1 200"), "{raw}");
    assert!(raw.contains("Transfer-Encoding: chunked"), "{raw}");
    let payload = decode_chunked(&raw);
    let lines: Vec<&str> = payload.lines().collect();
    assert_eq!(lines.len(), 13, "12 token lines + final summary: {payload:?}");
    let mut bytes = Vec::new();
    for (i, line) in lines[..12].iter().enumerate() {
        let t = Json::parse(line).unwrap();
        assert_eq!(t.req_usize("index").unwrap(), i, "stream order");
        bytes.push(t.req_usize("byte").unwrap() as u8);
        assert!(t.get("done").is_none());
    }
    let fin = Json::parse(lines[12]).unwrap();
    assert_eq!(fin.get("done").and_then(|d| d.as_bool()), Some(true));
    assert_eq!(fin.req_usize("completion_tokens").unwrap(), 12);
    assert_eq!(fin.req_usize("prompt_tokens").unwrap(), prompt.len());

    // token identity: streamed bytes == isolated generation == the
    // non-streamed text for the same request
    assert_eq!(bytes, expected, "streamed tokens diverge from generation");
    assert_eq!(fin.req_str("text").unwrap(), plain_text);
    assert_eq!(String::from_utf8_lossy(&bytes).to_string(), plain_text);

    // stream + prefill counters surface on /v1/metrics
    let (st, m) = http(addr, "GET", "/v1/metrics", "");
    assert_eq!(st, 200);
    let j = Json::parse(&m).unwrap();
    assert!(
        j.req_f64("stream_flushes").unwrap() >= 13.0,
        "12 token flushes + 1 summary flush"
    );
    assert!(j.req_f64("prefill_chunks").unwrap() >= 2.0);
    assert!(j.req_f64("batch_prefill_chunks").unwrap() >= 2.0);
    assert!(j.req_f64("batch_decode_steps").unwrap() >= 11.0);
    assert!(j.req_f64("prefill_decode_interleave").unwrap() > 0.0);

    drop(engine_thread);
}
