//! Integration: policy accuracy ordering, re-evaluation, batching,
//! multi-turn append — over the real trained model + PJRT path.

use std::path::Path;
use std::rc::Rc;

use hgca::config::HgcaConfig;
use hgca::engine::batcher::{Batcher, Request};
use hgca::engine::{Engine, Policy};
use hgca::model::RefModel;
use hgca::runtime::PjrtRuntime;

fn runtime() -> Rc<PjrtRuntime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Rc::new(PjrtRuntime::new(&dir).expect("run `make artifacts` first"))
}

fn corpus(n: usize) -> Vec<u8> {
    let text =
        hgca::util::corpus::ensure_corpus(&Path::new(env!("CARGO_MANIFEST_DIR")).join("data/corpus.txt"))
            .expect("corpus");
    text[4096..4096 + n].to_vec()
}

fn small_cfg() -> HgcaConfig {
    HgcaConfig {
        blk_size: 8,
        blk_num: 4, // logical window 32 — forces heavy CPU-side traffic
        ..Default::default()
    }
}

fn ppl(policy: Policy, text: &[u8]) -> f64 {
    let rt = runtime();
    let mr = rt.load_model("tiny-small").unwrap();
    let mut engine = Engine::new(&mr, small_cfg(), policy);
    engine.perplexity(text, 32).unwrap()
}

#[test]
fn policy_accuracy_ordering() {
    // The paper's central accuracy claim (Table 1): HGCA ≈ full attention,
    // while aggressive fixed-budget sparsity (H2O at 20%) and static
    // windows degrade. Tolerances are loose — the point is the ordering.
    // Table 1's finding is "HGCA ≈ full attention" (sometimes better,
    // sometimes a hair worse); the sweep bench does the full grid. Here we
    // pin the bound that matters: hybrid attention stays within a few
    // percent of exact full attention while attending a fraction of the KV.
    let text = corpus(192);
    let full = ppl(Policy::FullOffload, &text);
    let hgca = ppl(Policy::Hgca { beta: 1.0 }, &text);
    let h2o = ppl(Policy::H2o { frac: 0.2 }, &text);
    let stat = ppl(Policy::Static { sinks: 4, recent: 8 }, &text);
    println!("full={full:.3} hgca={hgca:.3} h2o={h2o:.3} static={stat:.3}");
    for (name, p) in [("full", full), ("hgca", hgca), ("h2o", h2o), ("static", stat)] {
        assert!(p.is_finite() && p > 1.0, "{name} ppl {p} out of range");
    }
    // the quality ordering is a claim about *trained* weights; with the
    // synthetic-weight fallback only the sanity checks above apply
    let trained = runtime().load_model("tiny-small").unwrap().trained;
    if !trained {
        eprintln!("skipping ordering assertions (synthetic weights — run `make artifacts`)");
        return;
    }
    assert!(
        (hgca / full - 1.0).abs() < 0.10,
        "hgca {hgca} should track full attention {full}"
    );
    // baselines must at least be in a sane range (they discard context)
    assert!(h2o < full * 1.5 && stat < full * 1.5);
}

#[test]
fn beta_sweep_monotone_retention() {
    // larger β → stricter filtering → smaller contextual cache
    let rt = runtime();
    let mr = rt.load_model("tiny-small").unwrap();
    let text = corpus(128);
    let mut sizes = Vec::new();
    for beta in [0.25f32, 1.0, 4.0] {
        let mut cfg = small_cfg();
        cfg.beta = beta;
        let mut engine = Engine::new(&mr, cfg, Policy::Hgca { beta });
        let mut seq = engine.new_sequence(0, &text);
        engine.prefill(&mut seq).unwrap();
        let total: usize = seq.kv.layers.iter().map(|l| l.cpu.ctx_len_total()).sum();
        sizes.push(total);
    }
    println!("ctx sizes by beta: {sizes:?}");
    assert!(sizes[0] >= sizes[1] && sizes[1] >= sizes[2], "{sizes:?}");
}

#[test]
fn append_reevaluation_changes_ctx() {
    // multi-turn: a second prompt re-evaluates the contextual cache
    let rt = runtime();
    let mr = rt.load_model("tiny-small").unwrap();
    if !mr.trained {
        // with synthetic weights the attention mass is near-uniform and the
        // β-threshold selection may be degenerate (empty before and after)
        eprintln!("skipping: re-evaluation adaptivity needs trained weights");
        return;
    }
    let mut engine = Engine::new(&mr, small_cfg(), Policy::Hgca { beta: 1.0 });
    let text = corpus(256);
    let mut seq = engine.new_sequence(0, &text[..128]);
    engine.prefill(&mut seq).unwrap();
    let before: Vec<Vec<u32>> = seq.kv.layers[0]
        .cpu
        .ctx
        .iter()
        .map(|c| c.idx.clone())
        .collect();
    // append a second turn (64 = one chunk → real append path)
    seq.tokens.extend_from_slice(&text[128..192]);
    engine.prefill(&mut seq).unwrap();
    let after: Vec<Vec<u32>> = seq.kv.layers[0]
        .cpu
        .ctx
        .iter()
        .map(|c| c.idx.clone())
        .collect();
    assert_ne!(before, after, "re-evaluation should adapt the ctx cache");
}

#[test]
fn continuous_batcher_completes_all() {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let mut batcher = Batcher::new(4);
    for i in 0..6 {
        batcher.submit(Request {
            id: i,
            prompt: format!("request number {i} about the railway").into_bytes(),
            max_new_tokens: 4 + (i as usize % 3),
        });
    }
    let done = batcher.run_to_completion(&mut engine).unwrap();
    assert_eq!(done.len(), 6);
    for c in &done {
        let want = 4 + (c.id as usize % 3);
        assert_eq!(c.text.len(), want, "req {} text len", c.id);
    }
    assert!(engine.metrics.tokens > 0);
}

#[test]
fn deterministic_generation_with_greedy() {
    let rt = runtime();
    let mr = rt.load_model("tiny-small").unwrap();
    let gen = || {
        let mut engine = Engine::new(&mr, small_cfg(), Policy::Hgca { beta: 1.0 });
        let mut seq = engine.new_sequence(0, b"The expedition mapped the region around ");
        engine.generate(&mut seq, 24).unwrap()
    };
    assert_eq!(gen(), gen());
}

#[test]
fn hgca_tracks_transfer_bytes_and_memory() {
    let rt = runtime();
    let mr = rt.load_model("tiny-small").unwrap();
    let mut engine = Engine::new(&mr, small_cfg(), Policy::Hgca { beta: 1.0 });
    let text = corpus(200);
    let mut seq = engine.new_sequence(0, &text);
    engine.prefill(&mut seq).unwrap();
    assert!(seq.kv.evict_bytes > 0, "evictions must be accounted");
    assert!(engine.metrics.peak_cpu_kv_bytes > 0);
    assert!(engine.metrics.peak_gpu_kv_bytes > 0);
    // GPU pool is bounded by the window regardless of sequence length
    let bound = mr.cfg.n_layers * seq.kv.layers[0].gpu.size_bytes();
    assert!(engine.metrics.peak_gpu_kv_bytes <= bound);
}

#[test]
fn trained_model_beats_uniform_ppl() {
    // sanity: the trained tiny model actually learned the corpus
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let oracle = RefModel::new(mr.cfg.clone(), mr.weights.clone()).unwrap();
    let text = corpus(256);
    let p = oracle.perplexity(&text);
    println!("tiny oracle ppl over corpus slice: {p:.2}");
    if mr.trained {
        assert!(p < 24.0, "ppl {p} vs uniform 256");
    } else {
        // synthetic weights: only require a well-defined perplexity in the
        // byte-vocab range (≈ uniform)
        assert!(p.is_finite() && p > 1.0 && p < 1024.0, "ppl {p}");
    }
}

#[test]
fn sim_time_scales_with_context() {
    let rt = runtime();
    let mr = rt.load_model("tiny-small").unwrap();
    let mut engine = Engine::new(&mr, small_cfg(), Policy::FullOffload);
    let text = corpus(256);
    let mut seq = engine.new_sequence(0, &text);
    engine.prefill(&mut seq).unwrap();
    let sims = &engine.metrics.sim_tbt;
    assert!(sims.len() > 2);
    // later steps attend more KV → simulated time must grow
    assert!(sims.last().unwrap() >= sims.first().unwrap());
}
