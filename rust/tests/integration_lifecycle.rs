//! Conformance suite for the request-lifecycle subsystem: cancellation,
//! deadlines, queue-wait shedding, load shedding, and mid-stream
//! disconnect.
//!
//! The load-bearing invariant (ISSUE acceptance): retiring one request
//! mid-batch — cancelled, expired, or disconnected — leaves every
//! surviving request's token stream **bitwise-identical** to its isolated
//! run, and the retired request's GPU KV blocks are observably reclaimed
//! (the engine pool's free count is restored).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::rc::Rc;
use std::time::{Duration, Instant};

use hgca::config::{HgcaConfig, ServingConfig};
use hgca::engine::{Batcher, CancelReason, Engine, FinishReason, Policy, Request, RequestHandle};
use hgca::runtime::PjrtRuntime;
use hgca::util::json::Json;

fn runtime() -> Rc<PjrtRuntime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Rc::new(PjrtRuntime::new(&dir).expect("runtime"))
}

/// Ground truth: a fresh engine generates the prompt alone.
fn isolated(prompt: &str, max_new: usize) -> Vec<u8> {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let mut seq = engine.new_sequence(0, prompt.as_bytes());
    engine.generate(&mut seq, max_new).unwrap()
}

fn http_raw(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn http(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let out = http_raw(addr, method, path, body);
    let status: u16 = out.split_whitespace().nth(1).unwrap().parse().unwrap();
    let body = out.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    (status, body)
}

/// Reassemble the payload of a chunked-transfer response body.
fn decode_chunked(raw: &str) -> String {
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("");
    let mut out = String::new();
    let mut rest = body;
    loop {
        let Some((len_line, after)) = rest.split_once("\r\n") else {
            break;
        };
        let len = usize::from_str_radix(len_line.trim(), 16).unwrap_or(0);
        if len == 0 || after.len() < len {
            break;
        }
        out.push_str(&after[..len]);
        rest = after.get(len + 2..).unwrap_or("");
    }
    out
}

/// Poll `/v1/metrics` until `pred` holds (returns the last snapshot), or
/// panic after `secs` seconds — the "bounded number of ticks" assertions.
fn await_metrics(
    addr: std::net::SocketAddr,
    secs: u64,
    what: &str,
    pred: impl Fn(&Json) -> bool,
) -> Json {
    let deadline = Instant::now() + Duration::from_secs(secs);
    loop {
        let (st, body) = http(addr, "GET", "/v1/metrics", "");
        assert_eq!(st, 200);
        let j = Json::parse(&body).unwrap();
        if pred(&j) {
            return j;
        }
        assert!(
            Instant::now() < deadline,
            "timed out waiting for {what}; last metrics: {body}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

// ---------------------------------------------------------------------
// batcher-level lifecycle (no HTTP)
// ---------------------------------------------------------------------

#[test]
fn cancel_mid_batch_preserves_survivor_bitwise_and_reclaims_blocks() {
    let survivor_prompt = "The railway company surveyed ";
    let want = isolated(survivor_prompt, 24);

    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let cfg = HgcaConfig::default();
    let per_seq = mr.cfg.n_layers * cfg.blk_num;
    let mut engine = Engine::new(&mr, cfg, Policy::Hgca { beta: 1.0 });
    let mut batcher = Batcher::new(4);

    batcher.submit(Request {
        id: 1,
        prompt: survivor_prompt.as_bytes().to_vec(),
        max_new_tokens: 24,
    });
    let victim = RequestHandle::default();
    let token = victim.token.clone();
    batcher.submit_with(
        Request {
            id: 2,
            prompt: "The garrison stationed at the fort ".as_bytes().to_vec(),
            max_new_tokens: 64,
        },
        victim,
    );

    let mut done = Vec::new();
    for _ in 0..6 {
        done.extend(batcher.tick(&mut engine).unwrap());
    }
    assert!(done.is_empty(), "nothing should have finished yet");
    let in_use_before = engine.kv_pool.in_use();
    let reclaimed_before = engine.kv_pool.reclaimed_blocks();
    assert_eq!(in_use_before, 2 * per_seq, "two active sequences leased");

    // cancel the victim mid-decode; the next tick's sweep retires it
    assert!(token.trip(CancelReason::Cancelled));
    done.extend(batcher.tick(&mut engine).unwrap());
    let cancelled = done.iter().find(|c| c.id == 2).expect("victim retired");
    assert_eq!(cancelled.finish_reason, FinishReason::Cancelled);
    assert!(cancelled.decode_steps < 64, "retired with partial tokens");
    assert_eq!(cancelled.text.len(), cancelled.decode_steps);

    // GPU KV blocks observably reclaimed: pool free count restored
    assert_eq!(engine.kv_pool.in_use(), in_use_before - per_seq);
    assert_eq!(
        engine.kv_pool.reclaimed_blocks(),
        reclaimed_before + per_seq as u64
    );

    // the survivor's tokens are bitwise-identical to its isolated run
    done.extend(batcher.run_to_completion(&mut engine).unwrap());
    let survivor = done.iter().find(|c| c.id == 1).expect("survivor finished");
    assert_eq!(survivor.finish_reason, FinishReason::Length);
    assert_eq!(
        survivor.text, want,
        "mid-batch retirement perturbed a surviving request's tokens"
    );
    assert_eq!(engine.kv_pool.in_use(), 0, "all leases returned");
    assert_eq!(batcher.stats().retired, 1);
}

#[test]
fn deadline_expiry_retires_with_partial_tokens() {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let mut batcher = Batcher::new(4);
    batcher.submit_with(
        Request {
            id: 7,
            prompt: "The county court convened ".as_bytes().to_vec(),
            max_new_tokens: 10_000,
        },
        RequestHandle {
            deadline: Some(Instant::now() + Duration::from_millis(60)),
            ..Default::default()
        },
    );
    let done = batcher.run_to_completion(&mut engine).unwrap();
    assert_eq!(done.len(), 1);
    let c = &done[0];
    assert_eq!(c.id, 7);
    assert_eq!(c.finish_reason, FinishReason::Deadline);
    assert!(c.decode_steps < 10_000, "deadline must cut generation short");
    assert_eq!(c.text.len(), c.decode_steps);
    assert_eq!(engine.kv_pool.in_use(), 0, "expired row returned its blocks");
}

#[test]
fn queue_wait_bound_sheds_without_admission() {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    // one row: the second request can never be admitted while the first runs
    let mut batcher = Batcher::new(1);
    batcher.submit(Request {
        id: 1,
        prompt: "The railway ".as_bytes().to_vec(),
        max_new_tokens: 12,
    });
    batcher.submit_with(
        Request {
            id: 2,
            prompt: "The garrison ".as_bytes().to_vec(),
            max_new_tokens: 12,
        },
        RequestHandle {
            max_queue_ticks: Some(2),
            ..Default::default()
        },
    );
    let acquired_before = engine.kv_pool.acquired_blocks();
    let done = batcher.run_to_completion(&mut engine).unwrap();
    let shed = done.iter().find(|c| c.id == 2).expect("queued request shed");
    assert_eq!(shed.finish_reason, FinishReason::QueueTimeout);
    assert_eq!(shed.decode_steps, 0);
    assert!(shed.text.is_empty(), "shed request never generated");
    assert!(shed.queue_ticks > 2);
    let first = done.iter().find(|c| c.id == 1).unwrap();
    assert_eq!(first.finish_reason, FinishReason::Length);
    assert_eq!(first.text.len(), 12);
    // the shed request never allocated KV: exactly one sequence ever leased
    let cfg = HgcaConfig::default();
    assert_eq!(
        engine.kv_pool.acquired_blocks() - acquired_before,
        (mr.cfg.n_layers * cfg.blk_num) as u64
    );
}

// ---------------------------------------------------------------------
// HTTP-level lifecycle (server + engine loop)
// ---------------------------------------------------------------------

/// Spawn a server + engine loop with the given serving config; returns the
/// bound address.
fn spawn_server(serving: ServingConfig) -> std::net::SocketAddr {
    let (tx, rx) = std::sync::mpsc::channel();
    let (addr, _h) = hgca::server::serve("127.0.0.1:0", tx).unwrap();
    std::thread::spawn(move || {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let rt = Rc::new(PjrtRuntime::new(&dir).unwrap());
        let mr = rt.load_model("tiny").unwrap();
        let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
        let _ = hgca::server::api::engine_loop_with(&mut engine, rx, Batcher::new(4), serving);
    });
    addr
}

#[test]
fn mid_stream_disconnect_retires_row_and_preserves_concurrent_request() {
    let survivor_prompt = "The expedition mapped ";
    let want = isolated(survivor_prompt, 30);
    let addr = spawn_server(ServingConfig::default());

    // victim: a long streaming generation whose reader goes away
    let mut victim = TcpStream::connect(addr).unwrap();
    let body = r#"{"prompt": "The dead channel ", "max_new_tokens": 600, "stream": true}"#;
    write!(
        victim,
        "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    // read a few token lines so the stream is demonstrably live (the
    // headers are ~105 bytes; 400 bytes ⇒ several complete token lines)...
    let mut seen = Vec::new();
    let mut buf = [0u8; 256];
    while seen.len() < 400 {
        let n = victim.read(&mut buf).unwrap();
        assert!(n > 0, "stream ended before disconnect");
        seen.extend_from_slice(&buf[..n]);
    }
    // ...then a concurrent request joins the batch
    let handle = std::thread::spawn(move || {
        let body =
            format!(r#"{{"prompt": "{survivor_prompt}", "max_new_tokens": 30, "stream": true}}"#);
        http_raw(addr, "POST", "/v1/generate", &body)
    });
    // ...and the victim's reader drops mid-stream
    drop(victim);

    // the survivor's streamed bytes are bitwise-identical to isolation
    let raw = handle.join().unwrap();
    let payload = decode_chunked(&raw);
    let mut bytes = Vec::new();
    for line in payload.lines() {
        let j = Json::parse(line).unwrap();
        if j.get("done").is_none() {
            bytes.push(j.req_usize("byte").unwrap() as u8);
        }
    }
    assert_eq!(
        bytes, want,
        "concurrent request's tokens perturbed by the disconnect"
    );

    // the engine retires the dead row within a bounded number of ticks and
    // its KV blocks return to the pool (free count restored)
    await_metrics(addr, 30, "disconnect retirement", |j| {
        j.req_f64("requests_disconnected").unwrap() >= 1.0
            && j.req_f64("kv_blocks_in_use").unwrap() == 0.0
            && j.req_f64("kv_blocks_reclaimed").unwrap() >= 1.0
            && j.req_f64("batch_active").unwrap() == 0.0
    });
}

#[test]
fn non_streamed_disconnect_is_detected_by_the_read_side_watcher() {
    let addr = spawn_server(ServingConfig::default());
    // a long NON-streamed generation: nothing is written to the socket
    // until the whole response is ready, so a write failure can never
    // surface mid-flight — only the read-side EOF watcher can notice the
    // client is gone (docs/API.md "Disconnects")
    let mut victim = TcpStream::connect(addr).unwrap();
    let body = r#"{"prompt": "The abandoned request ", "max_new_tokens": 20000}"#;
    write!(
        victim,
        "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    // stay connected well past the half-close grace window (an immediate
    // half-close must NOT cancel — that path is pinned by the http.rs
    // unit tests), then hang up mid-generation
    std::thread::sleep(Duration::from_millis(500));
    drop(victim);

    // the watcher trips Disconnected, the engine loop retires the row
    // mid-flight, and the KV blocks return to the pool — long before the
    // 20000-token generation could have finished
    await_metrics(addr, 30, "non-streamed disconnect retirement", |j| {
        j.req_f64("requests_disconnected").unwrap() >= 1.0
            && j.req_f64("kv_blocks_in_use").unwrap() == 0.0
            && j.req_f64("batch_active").unwrap() == 0.0
    });
}

#[test]
fn deadline_ms_yields_summary_line_with_partial_tokens() {
    let addr = spawn_server(ServingConfig::default());
    let body =
        r#"{"prompt": "The harvest season ", "max_new_tokens": 5000, "deadline_ms": 90, "stream": true}"#;
    let raw = http_raw(addr, "POST", "/v1/generate", body);
    assert!(raw.starts_with("HTTP/1.1 200"), "{raw}");
    let payload = decode_chunked(&raw);
    let last = payload.lines().last().expect("summary line");
    let j = Json::parse(last).unwrap();
    assert_eq!(j.req_str("finish_reason").unwrap(), "deadline");
    assert!(j.get("done").and_then(|d| d.as_bool()).unwrap_or(false));
    let tokens = j.req_usize("completion_tokens").unwrap();
    assert!(tokens < 5000, "deadline must cut the stream short");
    // token lines carry the partial text that was generated before expiry
    assert_eq!(payload.lines().count(), tokens + 1);
    let m = await_metrics(addr, 10, "deadline counter", |j| {
        j.req_f64("requests_deadline_expired").unwrap() >= 1.0
    });
    assert_eq!(m.req_f64("kv_blocks_in_use").unwrap(), 0.0);
}

#[test]
fn shed_watermark_rejects_with_429_and_never_admits() {
    let addr = spawn_server(ServingConfig {
        shed_watermark: Some(1),
        ..Default::default()
    });
    // fill the single admission slot with a long-running request
    let first = std::thread::spawn(move || {
        http(
            addr,
            "POST",
            "/v1/generate",
            r#"{"prompt": "The quarry supplied stone ", "max_new_tokens": 1500}"#,
        )
    });
    std::thread::sleep(Duration::from_millis(100));
    // a second admission must be rejected immediately with well-formed JSON
    let (st, body) = http(
        addr,
        "POST",
        "/v1/generate",
        r#"{"prompt": "The second ", "max_new_tokens": 4}"#,
    );
    assert_eq!(st, 429, "body: {body}");
    let j = Json::parse(&body).expect("shed error must be well-formed JSON");
    assert!(j.req_str("error").unwrap().contains("overloaded"));
    assert!(j.get("shed").and_then(|s| s.as_bool()).unwrap_or(false));
    assert_eq!(j.req_usize("watermark").unwrap(), 1);

    let m = await_metrics(addr, 10, "shed counter", |j| {
        j.req_f64("requests_shed").unwrap() >= 1.0
    });
    // never admitted: exactly one request ever submitted to the batcher
    assert_eq!(m.req_f64("batch_submitted").unwrap(), 1.0);

    let (st, _) = first.join().unwrap();
    assert_eq!(st, 200, "the in-flight request completes normally");
}

#[test]
fn cancel_endpoint_ends_stream_with_cancelled_reason() {
    let addr = spawn_server(ServingConfig::default());
    // first request on this server → id 1
    let mut victim = TcpStream::connect(addr).unwrap();
    let body = r#"{"prompt": "The long cancelled story ", "max_new_tokens": 800, "stream": true}"#;
    write!(
        victim,
        "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    // wait until tokens are flowing (token lines carry the request id);
    // 400 bytes past the ~105-byte headers is several complete lines
    let mut seen = Vec::new();
    let mut buf = [0u8; 256];
    while seen.len() < 400 {
        let n = victim.read(&mut buf).unwrap();
        assert!(n > 0, "stream ended before cancel");
        seen.extend_from_slice(&buf[..n]);
    }
    let head = String::from_utf8_lossy(&seen);
    let first_line = decode_chunked(&head).lines().next().unwrap().to_string();
    assert_eq!(
        Json::parse(&first_line).unwrap().req_usize("id").unwrap(),
        1,
        "token lines carry the id /v1/cancel accepts"
    );

    let (st, body) = http(addr, "POST", "/v1/cancel", r#"{"id": 1}"#);
    assert_eq!(st, 200, "body: {body}");
    assert!(Json::parse(&body)
        .unwrap()
        .get("cancelled")
        .and_then(|c| c.as_bool())
        .unwrap_or(false));

    // the stream terminates with a cancelled summary line
    let mut rest = String::new();
    victim.read_to_string(&mut rest).unwrap();
    let full = format!("{head}{rest}");
    let payload = decode_chunked(&full);
    let last = payload.lines().last().unwrap();
    let j = Json::parse(last).unwrap();
    assert_eq!(j.req_str("finish_reason").unwrap(), "cancelled");
    assert!(j.req_usize("completion_tokens").unwrap() < 800);

    await_metrics(addr, 10, "cancel counter", |j| {
        j.req_f64("requests_cancelled").unwrap() >= 1.0
            && j.req_f64("kv_blocks_in_use").unwrap() == 0.0
    });

    // cancelling an unknown id reports not-found
    let (st, body) = http(addr, "POST", "/v1/cancel", r#"{"id": 99}"#);
    assert_eq!(st, 404);
    assert!(body.contains("false"));
}
