//! Conformance suite for the overlapped step pipeline (ISSUE 6): the
//! engine submits the CPU-sparse side non-blockingly right after the dense
//! artifact call and runs its serial KV bookkeeping while pool workers
//! crunch the sparse jobs, waiting only at the merge point.
//!
//! The load-bearing invariants:
//! * **Bitwise overlap conformance** — overlapped and forced-sequential
//!   stepping produce byte-identical tokens for every policy that touches
//!   the CPU side (hgca with multi-chunk append re-evaluation,
//!   full-offload) and trivially for gpu-only (the submit is skipped).
//!   The gather snapshots the CPU store *before* bookkeeping mutates any
//!   cache, and the chunk's overflow enters the store only after the
//!   merge, so reordering never changes the merge inputs.
//! * **Topology-independence survives the overlap** — 1/2/4 synthetic
//!   NUMA nodes reproduce the flat engine bit for bit, overlapped or not.
//! * **Dropping a [`PendingAttn`] without waiting is safe** — the handle
//!   settles its batch on drop, so the pool's queues and counters stay
//!   quiescent and later submissions are unperturbed.
//! * **The metrics split is observable** — `cpu_attn_overlap_secs`
//!   accumulates only under overlapped stepping, and the wait/busy split
//!   is populated whenever the CPU side runs.

use std::path::Path;
use std::rc::Rc;

use hgca::attention::{AttnPool, HeadJob, OwnedJobs, TaskSplit};
use hgca::config::HgcaConfig;
use hgca::engine::{Engine, Policy};
use hgca::metrics::Metrics;
use hgca::runtime::PjrtRuntime;
use hgca::topology::Topology;

fn runtime() -> Rc<PjrtRuntime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Rc::new(PjrtRuntime::new(&dir).expect("runtime"))
}

fn corpus(n: usize) -> Vec<u8> {
    let text = hgca::util::corpus::ensure_corpus(
        &Path::new(env!("CARGO_MANIFEST_DIR")).join("data/corpus.txt"),
    )
    .expect("corpus");
    text[4096..4096 + n].to_vec()
}

/// Logical window 32 → a 160-byte prompt overflows the GPU window during
/// chunked prefill (chunk 64 → three append steps), exercising eviction,
/// the CPU store, and append-time re-evaluation — the paths the overlap
/// reorders around.
fn small_cfg() -> HgcaConfig {
    HgcaConfig {
        blk_size: 8,
        blk_num: 4,
        ..Default::default()
    }
}

/// Generate `max_new` greedy tokens on a fresh engine, overlapped or
/// forced-sequential, on an `nodes`-node synthetic topology.
fn run(
    policy: Policy,
    nodes: usize,
    overlap: bool,
    prompt: &[u8],
    max_new: usize,
) -> (Vec<u8>, Metrics) {
    let rt = runtime();
    let mr = rt.load_model("tiny-small").unwrap();
    let mut engine = Engine::new(&mr, small_cfg(), policy);
    engine.overlap_cpu_attn = overlap;
    engine.set_topology(Topology::synthetic(nodes));
    let mut seq = engine.new_sequence(0, prompt);
    let out = engine.generate(&mut seq, max_new).unwrap();
    (out, engine.metrics.clone())
}

// ---------------------------------------------------------------------
// bitwise overlap conformance per policy
// ---------------------------------------------------------------------

#[test]
fn overlapped_hgca_matches_sequential_bitwise() {
    // multi-chunk prefill (append path + re-evaluation) + decode: the
    // full set of reordered bookkeeping must not perturb a single byte
    let prompt = corpus(160);
    let (seq_tokens, seq_m) = run(Policy::Hgca { beta: 1.0 }, 1, false, &prompt, 12);
    let (ovl_tokens, ovl_m) = run(Policy::Hgca { beta: 1.0 }, 1, true, &prompt, 12);
    assert_eq!(ovl_tokens, seq_tokens, "overlap is a pure scheduling change");
    // the overlap win is observable — and absent when forced sequential
    assert_eq!(seq_m.cpu_attn_overlap_secs, 0.0, "nothing hidden when serial");
    assert!(ovl_m.cpu_attn_overlap_secs > 0.0, "bookkeeping ran under the submit");
    for m in [&seq_m, &ovl_m] {
        assert!(m.cpu_attn_jobs > 0, "the CPU side actually ran");
        assert!(m.cpu_attn_tasks > 0);
        assert!(m.cpu_attn_wait_secs > 0.0);
        assert!(m.cpu_attn_busy_secs > 0.0, "pool-side busy time accounted");
    }
}

#[test]
fn overlapped_full_offload_matches_sequential_bitwise() {
    // full-offload attends the whole store every decode step — the
    // heaviest CPU side, and the one where overlap matters most
    let prompt = corpus(128);
    let (seq_tokens, _) = run(Policy::FullOffload, 1, false, &prompt, 10);
    let (ovl_tokens, m) = run(Policy::FullOffload, 1, true, &prompt, 10);
    assert_eq!(ovl_tokens, seq_tokens);
    assert!(m.cpu_attn_overlap_secs > 0.0);
}

#[test]
fn gpu_only_skips_the_cpu_side_entirely() {
    // no CPU store, no submission: the overlap flag is a no-op and every
    // cpu_attn counter stays at its default (prompt + decode fit the
    // 32-entry window, so gpu-only cannot OOM here)
    let prompt = b"The canal barge ";
    let (seq_tokens, seq_m) = run(Policy::GpuOnly, 1, false, prompt, 8);
    let (ovl_tokens, ovl_m) = run(Policy::GpuOnly, 1, true, prompt, 8);
    assert_eq!(ovl_tokens, seq_tokens);
    for m in [&seq_m, &ovl_m] {
        assert_eq!(m.cpu_attn_jobs, 0);
        assert_eq!(m.cpu_attn_tasks, 0);
        assert_eq!(m.cpu_attn_wait_secs, 0.0);
        assert_eq!(m.cpu_attn_busy_secs, 0.0);
        assert_eq!(m.cpu_attn_overlap_secs, 0.0);
    }
}

// ---------------------------------------------------------------------
// topology-independence survives the overlap
// ---------------------------------------------------------------------

#[test]
fn overlap_is_bitwise_identical_on_1_2_4_node_topologies() {
    let prompt = corpus(160);
    let (reference, _) = run(Policy::Hgca { beta: 1.0 }, 1, false, &prompt, 10);
    for nodes in [1usize, 2, 4] {
        for overlap in [false, true] {
            let (tokens, _) = run(Policy::Hgca { beta: 1.0 }, nodes, overlap, &prompt, 10);
            assert_eq!(
                tokens, reference,
                "nodes={nodes} overlap={overlap} must reproduce the flat \
                 sequential run bit for bit"
            );
        }
    }
}

// ---------------------------------------------------------------------
// PendingAttn drop-without-wait safety
// ---------------------------------------------------------------------

fn det_jobs(nj: usize, n: usize, dh: usize) -> Vec<(Vec<f32>, Vec<f32>, usize)> {
    (0..nj)
        .map(|j| {
            let k = (0..n * dh)
                .map(|i| ((j * 31 + i * 7) as f32 * 0.013).sin())
                .collect();
            let v = (0..n * dh)
                .map(|i| ((j * 17 + i * 5) as f32 * 0.011).cos())
                .collect();
            (k, v, n)
        })
        .collect()
}

#[test]
fn dropping_a_pending_submission_settles_the_batch() {
    let (nj, n, dh) = (6usize, 24usize, 8usize);
    let kvs = det_jobs(nj, n, dh);
    let q: Vec<f32> = (0..nj * dh).map(|i| (i as f32 * 0.02).sin()).collect();
    let pool = AttnPool::new(2);
    let pending = pool.submit_placed(
        OwnedJobs {
            kvs: kvs.clone(),
            q: q.clone(),
            q_valid: None,
        },
        1,
        dh,
        TaskSplit::EvenJobs { max_parallel: 4 },
        false,
        None,
    );
    // drop without wait(): must not panic, must not leak queued tasks,
    // and must leave the counters exactly as a waited submission would
    drop(pending);
    let s = pool.stats();
    assert_eq!(s.submissions, 1);
    assert_eq!(s.jobs, nj as u64);
    assert!(s.tasks >= 1);
    assert_eq!(s.queue_depth, 0, "drop drains + waits out the batch");

    // the pool stays fully serviceable: a follow-up blocking call is
    // bitwise identical to a fresh pool's answer
    let jobs: Vec<HeadJob<'_>> = kvs
        .iter()
        .map(|(k, v, n)| HeadJob { k, v, n: *n })
        .collect();
    let after = pool.run_masked(&jobs, &q, 1, dh, 4, true, None);
    let fresh = AttnPool::new(0).run_masked(&jobs, &q, 1, dh, 4, true, None);
    assert_eq!(after.o, fresh.o);
    assert_eq!(after.lse, fresh.lse);
    assert_eq!(after.probs, fresh.probs);
    assert_eq!(pool.stats().queue_depth, 0);
}
