//! f32-oracle quality harness for the tiered CPU KV store (ISSUE 9
//! acceptance):
//!
//! * **Oracle error bound** — int8-tiered attention over a store vs the
//!   f32 path over a bitwise-identical store: per-head max-abs error of
//!   the LSE-merged output stays ≤ 1e-2.
//! * **Bitwise determinism** — the quantized kernel's output is bitwise
//!   identical across pool worker counts {1, 2, 7, 64} and across
//!   1/2/4-node synthetic NUMA topologies (same contract the f32 path
//!   has always had).
//! * **Compression floor** — every int8-tiered head stores its K/V in at
//!   most 1/3 of the f32 bytes (`quant_bytes_saved` ≥ 2× the resident
//!   quantized bytes).
//!
//! The harness drives the real gather → pool → LSE-merge pipeline
//! (`Policy::gather_payloads` → `AttnPool::submit_tiered` →
//! `merge_states`) against plain `CpuLayerStore`s, so it needs no model
//! artifacts and pins exactly the layers the engine composes.

use hgca::attention::{merge_states, AttnPool, JobPayload, OwnedJobs, OwnedTieredJobs, TaskSplit};
use hgca::engine::Policy;
use hgca::kv::{CpuLayerStore, HeadTier, KvBlock};
use hgca::topology::Topology;
use hgca::util::rng::Rng;

const HEADS: usize = 4;
const DH: usize = 8;
const ENTRIES: usize = 128;

/// A store with `ENTRIES` seeded-random evicted entries per head. Same
/// seed → bitwise-identical store, which is what makes the quantized vs
/// f32 comparison an apples-to-apples oracle.
fn build_store(seed: u64) -> CpuLayerStore {
    let mut rng = Rng::new(seed);
    let mut blk = KvBlock::new(HEADS, DH, ENTRIES);
    rng.fill_normal(&mut blk.k, 0.7);
    rng.fill_normal(&mut blk.v, 0.7);
    for m in blk.maw.iter_mut() {
        *m = 0.1 + 0.9 * rng.f32();
    }
    for (t, p) in blk.pos.iter_mut().enumerate() {
        *p = t;
    }
    let mut s = CpuLayerStore::new(HEADS, DH);
    s.add_evicted(&blk, 1.0, ENTRIES * 2);
    s
}

fn queries(seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut q = vec![0.0f32; HEADS * DH];
    rng.fill_normal(&mut q, 0.7);
    q
}

/// Synthetic GPU-side partial state to merge the CPU side into (the
/// engine's window attention output). Finite lse of comparable magnitude
/// so the merge weights both sides.
fn gpu_partial(seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut o = vec![0.0f32; HEADS * DH];
    rng.fill_normal(&mut o, 0.7);
    let lse: Vec<f32> = (0..HEADS).map(|h| 3.0 + 0.25 * h as f32).collect();
    (o, lse)
}

fn tiered_payloads(store: &CpuLayerStore) -> Vec<JobPayload> {
    Policy::Hgca { beta: 1.0 }.gather_payloads(store, ENTRIES * 2, true)
}

#[test]
fn int8_tier_tracks_f32_oracle_within_1e2_after_merge() {
    let f32_store = build_store(42);
    let mut quant_store = build_store(42);
    for h in 0..HEADS {
        quant_store.set_tier(h, HeadTier::Int8);
    }
    let q = queries(7);
    let pool = AttnPool::new(2);

    // f32 reference over the identical store
    let f32_jobs: Vec<(Vec<f32>, Vec<f32>, usize)> =
        Policy::FullOffload.gather_jobs(&f32_store, ENTRIES * 2);
    let oracle = pool
        .submit_placed(
            OwnedJobs { kvs: f32_jobs, q: q.clone(), q_valid: None },
            1,
            DH,
            TaskSplit::EvenJobs { max_parallel: 4 },
            false,
            None,
        )
        .wait();

    let quant = pool
        .submit_tiered(
            OwnedTieredJobs { kvs: tiered_payloads(&quant_store), q, q_valid: None },
            1,
            DH,
            TaskSplit::EvenJobs { max_parallel: 4 },
            false,
            None,
        )
        .wait();
    for p in tiered_payloads(&quant_store) {
        assert!(matches!(p, JobPayload::Int8 { .. }), "every head must be int8-tiered");
    }

    // merge each into the same synthetic GPU partial state, then compare
    let (o_ref, lse_ref) = gpu_partial(99);
    let (mut o_a, mut lse_a) = (o_ref.clone(), lse_ref.clone());
    let (mut o_b, mut lse_b) = (o_ref, lse_ref);
    merge_states(&mut o_a, &mut lse_a, &oracle.o, &oracle.lse, DH);
    merge_states(&mut o_b, &mut lse_b, &quant.o, &quant.lse, DH);
    for h in 0..HEADS {
        let max_abs = (0..DH)
            .map(|j| (o_a[h * DH + j] - o_b[h * DH + j]).abs())
            .fold(0.0f32, f32::max);
        assert!(
            max_abs <= 1e-2,
            "head {h}: merged-output max-abs error {max_abs} exceeds 1e-2"
        );
        assert!(
            (lse_a[h] - lse_b[h]).abs() <= 1e-2,
            "head {h}: merged lse drift {}",
            (lse_a[h] - lse_b[h]).abs()
        );
    }
}

#[test]
fn quantized_path_bitwise_deterministic_across_workers_and_topologies() {
    let mut store = build_store(11);
    // mixed tiers: two int8 heads, one window-only, one f32
    store.set_tier(0, HeadTier::Int8);
    store.set_tier(1, HeadTier::Int8);
    store.set_tier(2, HeadTier::WindowOnly);
    let q = queries(13);
    let split = TaskSplit::ByEntries { per_task: 48, max_tasks: 16 };

    let reference = AttnPool::new(1)
        .submit_tiered(
            OwnedTieredJobs { kvs: tiered_payloads(&store), q: q.clone(), q_valid: None },
            1,
            DH,
            split,
            true,
            None,
        )
        .wait();
    assert!(
        hgca::attention::is_empty_lse(reference.lse[2]),
        "window-only head must produce the empty-LSE sentinel"
    );

    for workers in [1usize, 2, 7, 64] {
        let pool = AttnPool::new(workers);
        let out = pool
            .submit_tiered(
                OwnedTieredJobs { kvs: tiered_payloads(&store), q: q.clone(), q_valid: None },
                1,
                DH,
                split,
                true,
                None,
            )
            .wait();
        assert_eq!(out.o, reference.o, "workers={workers}");
        assert_eq!(out.lse, reference.lse, "workers={workers}");
        assert_eq!(out.probs, reference.probs, "workers={workers}");
    }
    for nodes in [1usize, 2, 4] {
        let pool = AttnPool::with_topology(3, Topology::synthetic(nodes));
        let map: Vec<usize> = (0..HEADS).map(|h| h % nodes).collect();
        let out = pool
            .submit_tiered(
                OwnedTieredJobs { kvs: tiered_payloads(&store), q: q.clone(), q_valid: None },
                1,
                DH,
                split,
                true,
                Some(&map),
            )
            .wait();
        assert_eq!(out.o, reference.o, "nodes={nodes}");
        assert_eq!(out.lse, reference.lse, "nodes={nodes}");
        assert_eq!(out.probs, reference.probs, "nodes={nodes}");
    }
}

#[test]
fn window_only_head_contributes_nothing_and_merge_keeps_gpu_state() {
    let mut store = build_store(21);
    store.set_tier(3, HeadTier::WindowOnly);
    let q = queries(23);
    let out = AttnPool::new(0)
        .submit_tiered(
            OwnedTieredJobs { kvs: tiered_payloads(&store), q, q_valid: None },
            1,
            DH,
            TaskSplit::EvenJobs { max_parallel: 4 },
            false,
            None,
        )
        .wait();
    // the dropped head's CPU partial is the empty sentinel → merging it
    // into the GPU state must leave that state untouched
    let (o_ref, lse_ref) = gpu_partial(31);
    let (mut o, mut lse) = (o_ref.clone(), lse_ref.clone());
    merge_states(&mut o, &mut lse, &out.o, &out.lse, DH);
    assert_eq!(&o[3 * DH..4 * DH], &o_ref[3 * DH..4 * DH]);
    assert_eq!(lse[3], lse_ref[3]);
    // the untiered heads DID contribute
    assert_ne!(&o[..DH], &o_ref[..DH]);
}

#[test]
fn int8_tier_compresses_at_least_three_fold() {
    let mut store = build_store(33);
    for h in 0..HEADS {
        store.set_tier(h, HeadTier::Int8);
    }
    let mut resident = 0usize;
    for h in 0..HEADS {
        let hs = &store.full[h];
        let qk = hs.qk.as_ref().expect("int8 head has quant k");
        let qv = hs.qv.as_ref().expect("int8 head has quant v");
        let actual = qk.size_bytes() + qv.size_bytes();
        let f32_equiv = 2 * ENTRIES * DH * 4;
        assert!(
            f32_equiv >= 3 * actual,
            "head {h}: {actual} quant bytes vs {f32_equiv} f32 bytes (< 3x)"
        );
        resident += actual;
    }
    assert!(
        store.quant_bytes_saved() as usize >= 2 * resident,
        "saved {} vs resident {resident}",
        store.quant_bytes_saved()
    );
}
