//! Integration: HTTP front-end ↔ engine loop round trips with the real
//! trained model.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::rc::Rc;

use hgca::config::HgcaConfig;
use hgca::engine::{Engine, Policy};
use hgca::runtime::PjrtRuntime;
use hgca::server::api::engine_loop;
use hgca::util::json::Json;

fn http(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    let status: u16 = out.split_whitespace().nth(1).unwrap().parse().unwrap();
    let body = out.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    (status, body)
}

#[test]
fn serve_generate_metrics_health() {
    let (tx, rx) = std::sync::mpsc::channel();
    let (addr, _h) = hgca::server::serve("127.0.0.1:0", tx).unwrap();

    // engine thread (owns the PJRT runtime; !Send types stay here)
    let engine_thread = std::thread::spawn(move || {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let rt = Rc::new(PjrtRuntime::new(&dir).unwrap());
        let mr = rt.load_model("tiny").unwrap();
        let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
        let _ = engine_loop(&mut engine, rx, 4);
    });

    let (st, body) = http(addr, "GET", "/health", "");
    assert_eq!(st, 200);
    assert!(body.contains("true"));

    let (st, body) = http(
        addr,
        "POST",
        "/v1/generate",
        r#"{"prompt": "The county court ", "max_new_tokens": 12}"#,
    );
    assert_eq!(st, 200, "body: {body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.req_usize("completion_tokens").unwrap(), 12);
    // 12 generated *bytes*; the UTF-8-lossy text may differ in length when
    // synthetic weights emit non-ASCII bytes
    assert!(!j.req_str("text").unwrap().is_empty());

    let (st, body) = http(addr, "GET", "/v1/metrics", "");
    assert_eq!(st, 200);
    let j = Json::parse(&body).unwrap();
    assert!(j.req_f64("tokens").unwrap() >= 11.0); // first token comes from prefill logits
    assert_eq!(j.req_str("policy").unwrap(), "hgca");

    let (st, _) = http(addr, "GET", "/nope", "");
    assert_eq!(st, 404);

    let (st, _) = http(addr, "POST", "/v1/generate", "{not json");
    assert_eq!(st, 400);

    let (st, body) = http(
        addr,
        "POST",
        "/v1/batch",
        r#"{"prompts": ["the railway", "the garrison"], "max_new_tokens": 5}"#,
    );
    assert_eq!(st, 200, "body: {body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.req_arr("completions").unwrap().len(), 2);

    drop(engine_thread); // server thread detaches; engine loop ends with channel
}
