//! Conformance suite for NUMA-aware execution domains (topology layer,
//! per-node attention queues, sharded KV stores, per-node GPU block
//! budgets, placement-aware EDF admission).
//!
//! The load-bearing invariants (ISSUE acceptance):
//! * **Bitwise topology conformance** — identical request streams yield
//!   bitwise-identical tokens on 1/2/4-node synthetic topologies, and a
//!   1-node topology reproduces the flat pool's scheduling decisions
//!   (admission ticks, defers, finish reasons) *exactly*.
//! * **Per-node capacity gating** — admission defers/admits exactly like
//!   the global pool did, at node granularity: a lease draws from one
//!   node's budget, never spills, and returns to the same budget.
//! * **Deterministic placement** — the least-loaded fitting node wins,
//!   ties broken by the lowest node id; a sequence's CPU shard map and
//!   GPU lease share the home node.
//! * **Never-fits keys on the largest node budget** — summed capacity
//!   across nodes is irrelevant because a lease never spans nodes.

use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;

use hgca::config::HgcaConfig;
use hgca::engine::{Batcher, Engine, FinishReason, Policy, Request};
use hgca::runtime::PjrtRuntime;
use hgca::topology::Topology;

fn runtime() -> Rc<PjrtRuntime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Rc::new(PjrtRuntime::new(&dir).expect("runtime"))
}

/// Ground truth: a fresh flat engine generates the prompt alone.
fn isolated(prompt: &str, max_new: usize) -> Vec<u8> {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let mut seq = engine.new_sequence(0, prompt.as_bytes());
    engine.generate(&mut seq, max_new).unwrap()
}

fn req(id: u64, prompt: &str, max_new: usize) -> Request {
    Request {
        id,
        prompt: prompt.as_bytes().to_vec(),
        max_new_tokens: max_new,
    }
}

/// Everything a scheduling decision leaves behind, per request.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Outcome {
    text: Vec<u8>,
    admit_tick: u64,
    queue_ticks: u64,
    finish_tick: u64,
    finish_reason: FinishReason,
}

/// Run one fixed request stream on an engine with `nodes` synthetic NUMA
/// domains and a total KV capacity of `total_blocks` (split evenly per
/// node), returning per-id outcomes plus the deferred-admission count.
fn run_stream(nodes: usize, total_blocks: usize, batch: usize) -> (BTreeMap<u64, Outcome>, u64) {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let topo = Topology::synthetic(nodes);
    engine.set_topology(topo.clone());
    let budgets: Vec<usize> = {
        let base = total_blocks / nodes;
        let rem = total_blocks % nodes;
        (0..nodes).map(|i| base + usize::from(i < rem)).collect()
    };
    engine.set_kv_node_budgets(budgets);
    let mut batcher = Batcher::new(batch);
    // six requests, submitted in two waves (the second mid-flight)
    batcher.submit(req(1, "The windmill ground ", 6));
    batcher.submit(req(2, "The ferry crossed ", 5));
    batcher.submit(req(3, "The orchard yielded ", 4));
    batcher.submit(req(4, "The quarry supplied ", 6));
    let mut done = Vec::new();
    done.extend(batcher.tick(&mut engine).unwrap());
    batcher.submit(req(5, "The lighthouse keeper ", 3));
    batcher.submit(req(6, "The granary stored ", 4));
    done.extend(batcher.run_to_completion(&mut engine).unwrap());
    assert_eq!(engine.kv_pool.in_use(), 0, "all leases reclaimed");
    let outcomes = done
        .into_iter()
        .map(|c| {
            (
                c.id,
                Outcome {
                    text: c.text,
                    admit_tick: c.admit_tick,
                    queue_ticks: c.queue_ticks,
                    finish_tick: c.finish_tick,
                    finish_reason: c.finish_reason,
                },
            )
        })
        .collect();
    (outcomes, batcher.stats().admissions_deferred)
}

// ---------------------------------------------------------------------
// bitwise topology conformance
// ---------------------------------------------------------------------

#[test]
fn topologies_1_2_4_yield_bitwise_identical_tokens_and_schedules() {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let per_seq = {
        let engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
        engine.blocks_per_sequence()
    };
    // capacity = one full batch, split per node: every node still holds
    // ≥ 1 sequence on 1/2/4 nodes, so only placement differs
    let total = per_seq * 4;
    let (flat, flat_defers) = run_stream(1, total, 4);
    assert_eq!(flat.len(), 6, "every request completes");
    for (id, o) in &flat {
        assert_eq!(o.finish_reason, FinishReason::Length, "request {id}");
    }
    // spot-pin two streams against isolated generation (scheduling never
    // perturbs numerics)
    assert_eq!(flat[&1].text, isolated("The windmill ground ", 6));
    assert_eq!(flat[&5].text, isolated("The lighthouse keeper ", 3));
    for nodes in [2usize, 4] {
        let (out, defers) = run_stream(nodes, total, 4);
        assert_eq!(
            out, flat,
            "{nodes}-node topology must reproduce the flat run bit for bit \
             (tokens AND scheduling metadata)"
        );
        assert_eq!(defers, flat_defers, "same deferral decisions on {nodes} nodes");
    }
}

#[test]
fn one_node_topology_reproduces_flat_pool_scheduling_exactly() {
    // a *contended* stream (capacity = one sequence, three requests) on
    // (a) the pre-NUMA flat capacity pool and (b) a 1-node budget pool:
    // every admission, defer, and retirement must land on the same tick
    let run = |numa: bool| -> (BTreeMap<u64, Outcome>, u64) {
        let rt = runtime();
        let mr = rt.load_model("tiny").unwrap();
        let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
        let per_seq = engine.blocks_per_sequence();
        if numa {
            engine.set_topology(Topology::synthetic(1));
            engine.set_kv_node_budgets(vec![per_seq]);
        } else {
            engine.set_kv_block_capacity(Some(per_seq));
        }
        let mut batcher = Batcher::new(2);
        batcher.submit(req(1, "The reservoir held ", 5));
        batcher.submit(req(2, "The aqueduct carried ", 4));
        batcher.submit(req(3, "The ferry crossed ", 3));
        let done = batcher.run_to_completion(&mut engine).unwrap();
        let outcomes = done
            .into_iter()
            .map(|c| {
                (
                    c.id,
                    Outcome {
                        text: c.text,
                        admit_tick: c.admit_tick,
                        queue_ticks: c.queue_ticks,
                        finish_tick: c.finish_tick,
                        finish_reason: c.finish_reason,
                    },
                )
            })
            .collect();
        (outcomes, batcher.stats().admissions_deferred)
    };
    let (flat, flat_defers) = run(false);
    let (numa, numa_defers) = run(true);
    assert!(flat_defers > 0, "the stream must actually contend on blocks");
    assert_eq!(numa, flat, "--numa-nodes 1 must change nothing");
    assert_eq!(numa_defers, flat_defers);
}

// ---------------------------------------------------------------------
// per-node capacity gating + lease accounting
// ---------------------------------------------------------------------

#[test]
fn per_node_budgets_gate_admission_at_node_granularity() {
    let p1 = "The first resident ";
    let p2 = "The second resident ";
    let p3 = "The patient visitor ";
    let want3 = isolated(p3, 3);

    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let per_seq = engine.blocks_per_sequence();
    engine.set_topology(Topology::synthetic(2));
    // one sequence per node, FOUR free batch rows: node budgets, not row
    // count, are the binding constraint (the old capacity test, at node
    // granularity)
    engine.set_kv_node_budgets(vec![per_seq, per_seq]);
    let mut batcher = Batcher::new(4);
    batcher.submit(req(1, p1, 8));
    batcher.submit(req(2, p2, 8));
    batcher.submit(req(3, p3, 3));
    let mut done = Vec::new();
    done.extend(batcher.tick(&mut engine).unwrap());
    // FIFO placement: R1 → node 0 (tie-break), R2 → node 1, R3 defers
    assert_eq!(engine.kv_pool.in_use_on(0), per_seq);
    assert_eq!(engine.kv_pool.in_use_on(1), per_seq);
    assert_eq!(engine.kv_pool.free_blocks_on(0), Some(0));
    assert_eq!(engine.kv_pool.free_blocks_on(1), Some(0));
    assert!(batcher.stats().admissions_deferred > 0, "R3 visibly deferred");
    assert_eq!(batcher.stats().active, 2);
    assert_eq!(batcher.stats().queued, 1);

    done.extend(batcher.run_to_completion(&mut engine).unwrap());
    let c1 = done.iter().find(|c| c.id == 1).expect("R1 finished");
    let c2 = done.iter().find(|c| c.id == 2).expect("R2 finished");
    let c3 = done.iter().find(|c| c.id == 3).expect("R3 finished");
    assert_eq!(c3.finish_reason, FinishReason::Length);
    assert!(
        c3.admit_tick >= c1.finish_tick.min(c2.finish_tick),
        "R3 must wait for a node's blocks (admitted tick {}, first reclaim tick {})",
        c3.admit_tick,
        c1.finish_tick.min(c2.finish_tick)
    );
    assert!(c3.queue_ticks > 0, "R3 observably queued");
    // deferral delays, never perturbs
    assert_eq!(c3.text, want3);
    assert_eq!(engine.kv_pool.in_use_on(0), 0);
    assert_eq!(engine.kv_pool.in_use_on(1), 0);
    assert_eq!(
        engine.kv_pool.acquired_blocks(),
        3 * per_seq as u64,
        "exactly three placements ever leased"
    );
}

#[test]
fn placement_is_deterministic_and_leases_live_on_their_home_node() {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let per_seq = engine.blocks_per_sequence();
    let topo = Topology::synthetic(2);
    engine.set_topology(topo.clone());
    engine.set_kv_node_budgets(vec![per_seq, per_seq]);

    let s1 = engine.try_new_sequence(1, b"alpha ").expect("node 0 free");
    assert_eq!(s1.kv.node, 0, "equal free budgets → lowest node id");
    let s2 = engine.try_new_sequence(2, b"beta ").expect("node 1 free");
    assert_eq!(s2.kv.node, 1, "node 0 full → least-loaded node 1");
    assert!(engine.try_new_sequence(3, b"gamma ").is_none(), "no node fits");

    // the CPU shard map is anchored on the home node: the two sequences'
    // maps are each other's rotation, and every entry names a real node
    let heads = engine.model().n_heads;
    assert_eq!(s1.kv.shard(), topo.shard_heads(heads, 0).as_slice());
    assert_eq!(s2.kv.shard(), topo.shard_heads(heads, 1).as_slice());
    for h in 0..heads {
        assert_eq!(s2.kv.node_of_head(h), (s1.kv.node_of_head(h) + 1) % 2);
    }

    // retirement restores exactly the home node's budget
    drop(s1);
    assert_eq!(engine.kv_pool.free_blocks_on(0), Some(per_seq));
    assert_eq!(engine.kv_pool.free_blocks_on(1), Some(0));
    let s3 = engine.try_new_sequence(3, b"gamma ").expect("node 0 reclaimed");
    assert_eq!(s3.kv.node, 0, "reclaimed node is the only fit");
    drop(s2);
    drop(s3);
    assert_eq!(engine.kv_pool.in_use(), 0);
}

#[test]
fn never_fits_keys_on_largest_node_budget_not_summed_capacity() {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let per_seq = engine.blocks_per_sequence();
    engine.set_topology(Topology::synthetic(2));
    // summed capacity comfortably exceeds one sequence, but NO single
    // node can hold a whole lease — the request can never be admitted
    engine.set_kv_node_budgets(vec![per_seq - 1, per_seq - 1]);
    assert!(engine.kv_pool.capacity().unwrap() > per_seq);
    assert!(engine.kv_pool.max_node_capacity().unwrap() < per_seq);

    let mut batcher = Batcher::new(2);
    batcher.submit(req(9, "The impossible request ", 4));
    let done = batcher.tick(&mut engine).unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].finish_reason, FinishReason::NoCapacity);
    assert_eq!(done[0].decode_steps, 0);
    assert_eq!(engine.kv_pool.acquired_blocks(), 0, "no KV was ever leased");
    assert_eq!(batcher.pending(), 0, "rejected, not queued forever");
}

// ---------------------------------------------------------------------
// generation paths on multi-node engines stay conformant
// ---------------------------------------------------------------------

#[test]
fn standalone_generation_on_a_multi_node_engine_matches_flat() {
    // the force path (hgca generate) on a 4-node engine: placement (node
    // 0 + rotated shard map) must not perturb a single byte
    let prompt = "The railway company surveyed ";
    let want = isolated(prompt, 8);
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    engine.set_topology(Topology::synthetic(4));
    let mut seq = engine.new_sequence(0, prompt.as_bytes());
    assert_eq!(seq.kv.node, 0, "force path places on node 0");
    assert!(seq.kv.shard().iter().all(|&n| n < 4));
    let out = engine.generate(&mut seq, 8).unwrap();
    assert_eq!(out, want);
}
