//! SIMD dispatch conformance (`tensor/simd`): every level this host can
//! run must agree with the scalar oracle — `dot_i8` and `max_abs`
//! bitwise, the f32 kernels to ≤ 1e-5 per element — and a forced level
//! must stay deterministic end to end (same-seed and NUMA-node-count
//! replay invariance) without ever silently falling back to detection.

use std::path::{Path, PathBuf};
use std::process::Command;

use hgca::attention::{run_tiered_at_level, JobPayload};
use hgca::kv::{QuantSlab, QUANT_BLOCK};
use hgca::tensor::simd::{supported_levels, Kernels, SimdLevel};
use hgca::util::proptest::{check, ensure, ensure_all_close, ensure_close};
use hgca::util::rng::Rng;

fn rand_f32(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, scale);
    v
}

fn rand_i8(rng: &mut Rng, n: usize) -> Vec<i8> {
    (0..n).map(|_| (rng.range(0, 255) as i32 - 127) as i8).collect()
}

/// The non-scalar levels to exercise. Empty on a scalar-only host — the
/// sweeps below then pass vacuously, which is the correct degradation:
/// there is nothing to conform.
fn simd_levels() -> Vec<SimdLevel> {
    supported_levels().into_iter().filter(|l| *l != SimdLevel::Scalar).collect()
}

// ------------------------------------------------------ kernel conformance

#[test]
fn dot_i8_is_bitwise_identical_to_scalar_at_every_level() {
    let scalar = Kernels::for_level(SimdLevel::Scalar);
    check("dot_i8 simd == scalar", 300, |rng| {
        // lengths cover empty, single-element, sub-lane, and every
        // non-multiple-of-lane tail for 8- and 16-byte vector steps
        let n = rng.range(0, 131);
        let a = rand_i8(rng, n);
        let b = rand_i8(rng, n);
        let want = (scalar.dot_i8)(&a, &b);
        for level in simd_levels() {
            let got = (Kernels::for_level(level).dot_i8)(&a, &b);
            ensure(got == want, format!("{level} n={n}: {got} != {want}"))?;
        }
        Ok(())
    });
}

#[test]
fn dot_i8_saturated_accumulation_matches_scalar() {
    // every element at the ±127 extremes, length far past one vector step
    let a: Vec<i8> = (0..1003).map(|i| if i % 2 == 0 { 127 } else { -127 }).collect();
    let b: Vec<i8> = (0..1003).map(|i| if i % 3 == 0 { -127 } else { 127 }).collect();
    let want = (Kernels::for_level(SimdLevel::Scalar).dot_i8)(&a, &b);
    for level in simd_levels() {
        assert_eq!((Kernels::for_level(level).dot_i8)(&a, &b), want, "{level}");
    }
}

#[test]
fn max_abs_is_bitwise_identical_to_scalar_at_every_level() {
    let scalar = Kernels::for_level(SimdLevel::Scalar);
    check("max_abs simd == scalar", 300, |rng| {
        let n = rng.range(0, 131);
        let mut v = rand_f32(rng, n, 2.0);
        // sprinkle huge magnitudes and negative zeros among the values
        for x in v.iter_mut() {
            let r = rng.f32();
            if r < 0.05 {
                *x = 1e30 * x.signum();
            } else if r < 0.1 {
                *x = -0.0;
            }
        }
        let want = (scalar.max_abs)(&v);
        for level in simd_levels() {
            let got = (Kernels::for_level(level).max_abs)(&v);
            ensure(
                got.to_bits() == want.to_bits(),
                format!("{level} n={n}: {got} != {want}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn f32_kernels_stay_within_1e5_of_scalar() {
    let scalar = Kernels::for_level(SimdLevel::Scalar);
    check("f32 kernels simd vs scalar", 200, |rng| {
        let n = rng.range(0, 300);
        let a = rand_f32(rng, n, 1.0);
        let b = rand_f32(rng, n, 1.0);
        let base = rand_f32(rng, n, 1.0);
        let w = rng.normal();
        let dot_ref = (scalar.dot)(&a, &b);
        let mut axpy_ref = base.clone();
        (scalar.axpy)(w, &b, &mut axpy_ref);
        let mut sm_ref = a.clone();
        let lse_ref = (scalar.softmax_lse)(&mut sm_ref);
        for level in simd_levels() {
            let kn = Kernels::for_level(level);
            ensure_close((kn.dot)(&a, &b), dot_ref, 1e-5, &format!("{level} dot n={n}"))?;
            let mut out = base.clone();
            (kn.axpy)(w, &b, &mut out);
            ensure_all_close(&out, &axpy_ref, 1e-5, &format!("{level} axpy n={n}"))?;
            let mut sm = a.clone();
            let lse = (kn.softmax_lse)(&mut sm);
            ensure_all_close(&sm, &sm_ref, 1e-5, &format!("{level} softmax n={n}"))?;
            ensure_close(lse, lse_ref, 1e-5, &format!("{level} lse n={n}"))?;
        }
        Ok(())
    });
}

#[test]
fn f32_dot_covers_every_tail_length_and_huge_values() {
    let scalar = Kernels::for_level(SimdLevel::Scalar);
    for n in 0..=67usize {
        // magnitudes spanning ±1e15 .. ±1e-15; dot(a, a) keeps every
        // product non-negative so the huge terms cannot cancel — the
        // reassociation error stays relative to the true magnitude
        let a: Vec<f32> = (0..n)
            .map(|i| {
                let mag = [1e15f32, 3.25, 1e-15, 42.0, 0.0, 7.5e7][i % 6];
                if i % 2 == 0 { mag } else { -mag }
            })
            .collect();
        let want = (scalar.dot)(&a, &a);
        for level in simd_levels() {
            let got = (Kernels::for_level(level).dot)(&a, &a);
            let tol = 1e-5 * want.abs().max(1.0);
            assert!((got - want).abs() <= tol, "{level} dot n={n}: {got} vs {want}");
        }
    }
}

#[test]
fn softmax_handles_extreme_score_spreads_like_scalar() {
    let scalar = Kernels::for_level(SimdLevel::Scalar);
    // one dominant score, the rest at -1e30: every exp underflows to
    // exactly 0 or 1 in every level because the exp pass is scalar libm
    // everywhere, so the whole result is bitwise-identical
    let base: Vec<f32> = (0..13).map(|i| if i == 4 { 1e30 } else { -1e30 }).collect();
    let mut sm_ref = base.clone();
    let lse_ref = (scalar.softmax_lse)(&mut sm_ref);
    for level in simd_levels() {
        let mut sm = base.clone();
        let lse = (Kernels::for_level(level).softmax_lse)(&mut sm);
        assert_eq!(lse.to_bits(), lse_ref.to_bits(), "{level} lse");
        for (i, (a, b)) in sm.iter().zip(sm_ref.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{level} prob {i}");
        }
    }
}

#[test]
fn tiered_attention_matches_scalar_at_every_level() {
    check("run_tiered_at_level simd vs scalar", 25, |rng| {
        let d_head = *rng.choice(&[8usize, 16, 24]);
        let n_query = rng.range(1, 4);
        let n1 = rng.range(1, 48);
        let n2 = rng.range(1, 48);
        let k1 = rand_f32(rng, n1 * d_head, 0.7);
        let v1 = rand_f32(rng, n1 * d_head, 1.0);
        let k2 = rand_f32(rng, n2 * d_head, 0.7);
        let v2 = rand_f32(rng, n2 * d_head, 1.0);
        let payloads = vec![
            JobPayload::F32(k1, v1, n1),
            JobPayload::Int8 {
                k: QuantSlab::from_f32(&k2, d_head, QUANT_BLOCK),
                v: QuantSlab::from_f32(&v2, d_head, QUANT_BLOCK),
            },
        ];
        let q = rand_f32(rng, payloads.len() * n_query * d_head, 0.7);
        let (o_ref, lse_ref) =
            run_tiered_at_level(SimdLevel::Scalar, &payloads, &q, n_query, d_head);
        for level in simd_levels() {
            let (o, lse) = run_tiered_at_level(level, &payloads, &q, n_query, d_head);
            ensure_all_close(&o, &o_ref, 1e-4, &format!("{level} output"))?;
            ensure_all_close(&lse, &lse_ref, 1e-4, &format!("{level} lse"))?;
        }
        Ok(())
    });
}

// ------------------------------------------------------- forced-level CLI

fn hgca_cmd() -> Command {
    let mut c = Command::new(env!("CARGO_BIN_EXE_hgca"));
    // the test process may itself run under a forced HGCA_SIMD (the CI
    // scalar leg); each subprocess pins its own level explicitly
    c.env_remove("HGCA_SIMD");
    c.current_dir(env!("CARGO_MANIFEST_DIR"));
    c
}

fn scenario_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .join("scenarios/steady_decode.scn")
}

/// `--verify` replays the scenario twice same-seed and across synthetic
/// NUMA node counts {1, 2, 4}; forcing each level through `HGCA_SIMD`
/// pins the determinism contract end to end — tokens bitwise-stable
/// within a level, at every level this host can run.
#[test]
fn replay_verify_passes_under_every_forced_simd_level() {
    let scn = scenario_path();
    for level in supported_levels() {
        let out = hgca_cmd()
            .env("HGCA_SIMD", level.name())
            .args(["replay", scn.to_str().unwrap(), "--verify"])
            .output()
            .expect("failed to spawn hgca");
        let stdout = String::from_utf8_lossy(&out.stdout);
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(
            out.status.success(),
            "HGCA_SIMD={level}: replay --verify failed\nstdout:\n{stdout}\nstderr:\n{stderr}"
        );
        assert!(stdout.contains("[verified]"), "HGCA_SIMD={level}: {stdout}");
    }
}

/// A forced level that does not parse must abort loudly — never silently
/// fall back to detection (the conformance sweep above relies on this).
#[test]
fn unknown_forced_level_aborts_instead_of_falling_back() {
    let out = hgca_cmd()
        .env("HGCA_SIMD", "avx512")
        .args(["info"])
        .output()
        .expect("failed to spawn hgca");
    assert!(!out.status.success(), "HGCA_SIMD=avx512 must not start");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("HGCA_SIMD"), "stderr: {stderr}");
}

#[test]
fn unknown_simd_flag_is_rejected() {
    let out = hgca_cmd()
        .args(["info", "--simd", "bogus"])
        .output()
        .expect("failed to spawn hgca");
    assert!(!out.status.success(), "--simd bogus must be rejected");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown SIMD level"), "stderr: {stderr}");
}

/// `--simd` outranks `HGCA_SIMD` (flag > env > detection).
#[test]
fn simd_flag_takes_precedence_over_env() {
    let best = supported_levels()[0];
    let out = hgca_cmd()
        .env("HGCA_SIMD", "scalar")
        .args(["info", "--simd", best.name()])
        .output()
        .expect("failed to spawn hgca");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains(&format!("simd dispatch: {best}")), "stdout: {stdout}");
}
