//! Integration: PJRT artifact path vs the rust oracle. Requires
//! `make artifacts` (run by `make test`).

use std::path::Path;
use std::rc::Rc;

use hgca::config::HgcaConfig;
use hgca::engine::{Engine, Policy};
use hgca::model::RefModel;
use hgca::runtime::{Executor, PjrtRuntime};

fn runtime() -> Rc<PjrtRuntime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Rc::new(PjrtRuntime::new(&dir).expect("run `make artifacts` before cargo test"))
}

#[test]
fn manifest_lists_trained_models() {
    let rt = runtime();
    for m in ["tiny", "tiny-small", "tiny-large"] {
        assert!(rt.manifest.models.contains_key(m), "missing model {m}");
        assert!(!rt.manifest.windows_for(m).is_empty());
    }
    // tiny has the full shape set
    assert_eq!(rt.manifest.windows_for("tiny"), vec![256, 1024]);
    assert_eq!(rt.manifest.batches_for("tiny"), vec![1, 4]);
}

#[test]
fn embed_matches_oracle() {
    let rt = runtime();
    let mr = rt.load_model("tiny-small").unwrap();
    let exec = Executor::new(&mr);
    let tokens = [65i32, 120];
    let positions = [0i32, 7];
    let hidden = exec.embed(1, 1, &tokens[..1], &positions[..1]).unwrap();
    let d = mr.cfg.d_model;
    let tok_emb = &mr.weights["tok_emb"];
    let pos_emb = &mr.weights["pos_emb"];
    for j in 0..d {
        let expect = tok_emb.data[65 * d + j] + pos_emb.data[j];
        assert!((hidden[j] - expect).abs() < 1e-6);
    }
}

#[test]
fn lm_head_matches_oracle_logits() {
    let rt = runtime();
    let mr = rt.load_model("tiny-small").unwrap();
    let oracle = RefModel::new(mr.cfg.clone(), mr.weights.clone()).unwrap();
    let text = b"the railway company";
    let (logits_ref, _) = oracle.forward(text, false);

    // run the engine in full-offload mode (numerically exact full attention)
    let cfg = HgcaConfig {
        blk_size: 4,
        blk_num: 2, // tiny window forces eviction + CPU path
        chunk: 64,
        ..Default::default()
    };
    let mut engine = Engine::new(&mr, cfg, Policy::FullOffload);
    let mut seq = engine.new_sequence(0, text);
    let logits = engine.prefill(&mut seq).unwrap();
    let last = logits_ref.last().unwrap();
    let max_err = logits
        .iter()
        .zip(last.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 2e-3, "PJRT path vs oracle: max err {max_err}");
}

#[test]
fn full_offload_perplexity_matches_oracle() {
    let rt = runtime();
    let mr = rt.load_model("tiny-small").unwrap();
    let oracle = RefModel::new(mr.cfg.clone(), mr.weights.clone()).unwrap();
    let text =
        hgca::util::corpus::ensure_corpus(&Path::new(env!("CARGO_MANIFEST_DIR")).join("data/corpus.txt"))
            .expect("corpus");
    let text = &text[..160];

    let ppl_ref = {
        // oracle ppl over positions >= 32 (same burn-in as the engine)
        let (logits, _) = oracle.forward(text, false);
        let mut nll = 0.0f64;
        let mut n = 0usize;
        for t in 31..text.len() - 1 {
            nll -= hgca::tensor::ops::log_softmax_at(&logits[t], text[t + 1] as usize) as f64;
            n += 1;
        }
        (nll / n as f64).exp()
    };

    let cfg = HgcaConfig {
        blk_size: 8,
        blk_num: 4, // window 32 ≪ 160 → most KV lives CPU-side
        ..Default::default()
    };
    let mut engine = Engine::new(&mr, cfg, Policy::FullOffload);
    let ppl = engine.perplexity(text, 32).unwrap();
    let rel = (ppl - ppl_ref).abs() / ppl_ref;
    assert!(
        rel < 0.02,
        "full-offload ppl {ppl:.4} vs oracle {ppl_ref:.4} (rel {rel:.4})"
    );
}

#[test]
fn decode_beyond_window_uses_cpu_store() {
    let rt = runtime();
    let mr = rt.load_model("tiny-small").unwrap();
    let cfg = HgcaConfig {
        blk_size: 8,
        blk_num: 4,
        ..Default::default()
    };
    let mut engine = Engine::new(&mr, cfg, Policy::Hgca { beta: 1.0 });
    let prompt = vec![b'a'; 100]; // >> window of 32
    let mut seq = engine.new_sequence(0, &prompt);
    engine.generate(&mut seq, 8).unwrap();
    let cpu_len = seq.kv.layers[0].cpu.len();
    assert!(cpu_len >= 100 - 32, "cpu store holds evicted KVs: {cpu_len}");
    assert!(seq.kv.window_len(0) <= 32);
    // per-head selectivity varies (the paper's Fig. 4 claim, live) — a
    // trained-weights property; synthetic weights may select nothing
    if mr.trained {
        let sel = seq.kv.layers[0].cpu.selectivity();
        assert!(sel.iter().any(|&s| s > 0.0), "some head keeps context: {sel:?}");
    }
}

#[test]
fn gpu_only_ooms_beyond_window() {
    let rt = runtime();
    let mr = rt.load_model("tiny-small").unwrap();
    let cfg = HgcaConfig {
        blk_size: 8,
        blk_num: 4,
        ..Default::default()
    };
    let mut engine = Engine::new(&mr, cfg, Policy::GpuOnly);
    let prompt = vec![b'x'; 64]; // 64 > 32-entry window
    let mut seq = engine.new_sequence(0, &prompt);
    let err = engine.prefill(&mut seq).unwrap_err();
    assert!(err.to_string().contains("OOM"), "got: {err}");
}

#[test]
fn batch4_artifacts_execute() {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let cfg = HgcaConfig::default();
    let mut engine = Engine::new(&mr, cfg, Policy::Hgca { beta: 1.0 });
    // four sequences decoded in one padded batch
    let mut seqs: Vec<_> = (0..4)
        .map(|i| {
            let mut s = engine.new_sequence(i, b"abc");
            let logits = engine.prefill(&mut s).unwrap();
            // seed one pending token from the prefill logits
            let t = hgca::tensor::ops::argmax(&logits) as u8;
            s.tokens.push(t);
            s
        })
        .collect();
    let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
    let out = engine.decode_step(&mut refs, 4, None).unwrap();
    assert_eq!(out.len(), 4);
}

#[test]
fn padded_batch_rows_do_not_corrupt_active_rows() {
    let rt = runtime();
    let mr = rt.load_model("tiny").unwrap();
    let cfg = HgcaConfig::default();

    // decode the same sequence alone (batch=1) and padded into batch=4
    let run = |engine: &mut Engine<'_>, batch: usize| -> Vec<f32> {
        let mut seq = engine.new_sequence(0, b"hello world");
        engine.prefill(&mut seq).unwrap();
        let mut refs = vec![&mut seq];
        let out = engine.decode_step(&mut refs, batch, Some(b"!")).unwrap();
        out[0].2.clone()
    };
    let mut e1 = Engine::new(&mr, cfg.clone(), Policy::Hgca { beta: 1.0 });
    let l1 = run(&mut e1, 1);
    let mut e4 = Engine::new(&mr, cfg, Policy::Hgca { beta: 1.0 });
    let l4 = run(&mut e4, 4);
    let max_err = l1
        .iter()
        .zip(l4.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "batch padding changed logits by {max_err}");
}

#[test]
fn runtime_stats_accumulate() {
    let rt = runtime();
    let mr = rt.load_model("tiny-small").unwrap();
    let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
    let mut seq = engine.new_sequence(0, b"hi");
    engine.generate(&mut seq, 4).unwrap();
    let st = mr.stats.borrow();
    assert!(st.calls > 0);
    assert!(st.exec_secs > 0.0);
}
