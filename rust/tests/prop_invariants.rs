//! Property tests over the coordinator's core invariants (mini-proptest
//! harness — see rust/src/util/proptest.rs): KV routing, batching-style
//! state transitions, sparsification algebra. These run without artifacts.

use hgca::config::{HgcaConfig, ModelConfig};
use hgca::kv::{KvBlock, KvManager, QuantSlab, QUANT_BLOCK};
use hgca::util::proptest::{check, ensure};
use hgca::util::rng::Rng;

fn model(heads: usize, dh: usize) -> ModelConfig {
    ModelConfig {
        name: "prop".into(),
        vocab: 256,
        n_layers: 2,
        d_model: heads * dh,
        n_heads: heads,
        d_ffn: 4 * heads * dh,
        max_pos: 4096,
        bytes_per_param: 4,
    }
}

fn random_kv(rng: &mut Rng, heads: usize, n: usize, dh: usize) -> (Vec<f32>, Vec<f32>) {
    let mut k = vec![0.0; heads * n * dh];
    let mut v = vec![0.0; heads * n * dh];
    rng.fill_normal(&mut k, 1.0);
    rng.fill_normal(&mut v, 1.0);
    (k, v)
}

#[test]
fn prop_no_entry_is_lost_or_duplicated() {
    // every inserted position ends up exactly once in window ∪ cpu store
    check("kv_conservation", 40, |rng| {
        let heads = 1 + rng.range(0, 4);
        let dh = 4;
        let m = model(heads, dh);
        let cfg = HgcaConfig {
            blk_size: 1 + rng.range(0, 4),
            blk_num: 1 + rng.range(0, 4),
            ..Default::default()
        };
        let mut kv = KvManager::new(&m, &cfg);
        let steps = rng.range(1, 60);
        for t in 0..steps {
            kv.make_room(0, 1);
            let (k, v) = random_kv(rng, heads, 1, dh);
            kv.append(0, &k, &v, &[t]);
            kv.advance(1);
        }
        let win: Vec<usize> = kv.layers[0].gpu.pos[..kv.layers[0].gpu.len].to_vec();
        let cpu: Vec<usize> = kv.layers[0].cpu.full[0].pos.clone();
        let mut all: Vec<usize> = win.iter().chain(cpu.iter()).copied().collect();
        all.sort_unstable();
        let expect: Vec<usize> = (0..steps).collect();
        ensure(all == expect, format!("win {win:?} cpu {cpu:?} vs 0..{steps}"))
    });
}

#[test]
fn prop_window_is_chronological_suffix() {
    check("window_suffix", 30, |rng| {
        let m = model(2, 4);
        let cfg = HgcaConfig {
            blk_size: 2,
            blk_num: 1 + rng.range(0, 3),
            ..Default::default()
        };
        let mut kv = KvManager::new(&m, &cfg);
        let steps = rng.range(1, 40);
        for t in 0..steps {
            kv.make_room(0, 1);
            let (k, v) = random_kv(rng, 2, 1, 4);
            kv.append(0, &k, &v, &[t]);
        }
        let gpu = &kv.layers[0].gpu;
        let pos = &gpu.pos[..gpu.len];
        // window holds the most recent entries, in order
        for (i, w) in pos.windows(2).enumerate() {
            ensure(w[0] + 1 == w[1], format!("gap at {i}: {pos:?}"))?;
        }
        ensure(
            *pos.last().unwrap() == steps - 1,
            format!("window must end at the frontline: {pos:?}"),
        )
    });
}

#[test]
fn prop_ctx_is_subset_of_full_store() {
    check("ctx_subset", 30, |rng| {
        let heads = 1 + rng.range(0, 3);
        let dh = 4;
        let mut store = hgca::kv::CpuLayerStore::new(heads, dh);
        let beta = rng.f32() * 2.0;
        for _ in 0..rng.range(1, 6) {
            let len = 1 + rng.range(0, 8);
            let mut blk = KvBlock::new(heads, dh, len);
            rng.fill_normal(&mut blk.k, 1.0);
            rng.fill_normal(&mut blk.v, 1.0);
            for m in blk.maw.iter_mut() {
                *m = rng.f32() * 0.5;
            }
            store.add_evicted(&blk, beta, 16);
        }
        for h in 0..heads {
            let ctx = &store.ctx[h];
            ensure(
                ctx.idx.iter().all(|&i| (i as usize) < store.full[h].len()),
                "ctx indices in range",
            )?;
            // packed k matches the indexed entries
            for (j, &i) in ctx.idx.iter().enumerate() {
                let a = &ctx.k[j * dh..(j + 1) * dh];
                let b = &store.full[h].k[i as usize * dh..(i as usize + 1) * dh];
                ensure(a == b, "packed ctx k mismatch")?;
            }
            // renormalized maw sums to ~1 when non-empty
            if !ctx.maw.is_empty() {
                let s: f32 = ctx.maw.iter().sum();
                ensure((s - 1.0).abs() < 1e-4, format!("maw sum {s}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_reevaluation_is_idempotent() {
    check("reeval_idempotent", 25, |rng| {
        let mut store = hgca::kv::CpuLayerStore::new(2, 4);
        let len = 4 + rng.range(0, 12);
        let mut blk = KvBlock::new(2, 4, len);
        rng.fill_normal(&mut blk.k, 1.0);
        for m in blk.maw.iter_mut() {
            *m = rng.f32();
        }
        store.add_evicted(&blk, 1.0, 8);
        let a_cpu: Vec<f32> = (0..2 * len).map(|_| rng.f32()).collect();
        store.reevaluate(&a_cpu, 1.0);
        let once: Vec<Vec<u32>> = store.ctx.iter().map(|c| c.idx.clone()).collect();
        store.reevaluate(&a_cpu, 1.0);
        let twice: Vec<Vec<u32>> = store.ctx.iter().map(|c| c.idx.clone()).collect();
        ensure(once == twice, "same scores → same selection")
    });
}

#[test]
fn prop_eviction_bytes_monotone() {
    check("evict_bytes_monotone", 20, |rng| {
        let m = model(2, 8);
        let cfg = HgcaConfig {
            blk_size: 2,
            blk_num: 2,
            ..Default::default()
        };
        let mut kv = KvManager::new(&m, &cfg);
        let mut last = 0u64;
        for t in 0..rng.range(5, 30) {
            kv.make_room(0, 1);
            let (k, v) = random_kv(rng, 2, 1, 8);
            kv.append(0, &k, &v, &[t]);
            ensure(kv.evict_bytes >= last, "evict bytes must not decrease")?;
            last = kv.evict_bytes;
        }
        Ok(())
    });
}

#[test]
fn prop_merge_then_split_roundtrip_random_layouts() {
    use hgca::attention::{merge_head, EMPTY_LSE};
    use hgca::tensor::ops::softmax_lse;
    check("merge_random_layouts", 40, |rng| {
        let dh = 1 + rng.range(0, 32);
        let n = rng.range(1, 50);
        let scores: Vec<f32> = (0..n).map(|_| rng.normal() * 3.0).collect();
        let values: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dh).map(|_| rng.normal()).collect())
            .collect();
        let attend = |idx: &[usize]| -> (Vec<f32>, f32) {
            if idx.is_empty() {
                return (vec![0.0; dh], EMPTY_LSE);
            }
            let mut s: Vec<f32> = idx.iter().map(|&i| scores[i]).collect();
            let lse = softmax_lse(&mut s);
            let mut o = vec![0.0; dh];
            for (w, &i) in s.iter().zip(idx.iter()) {
                for j in 0..dh {
                    o[j] += w * values[i][j];
                }
            }
            (o, lse)
        };
        // random disjoint split (either side may be empty)
        let mut a_idx = Vec::new();
        let mut b_idx = Vec::new();
        for i in 0..n {
            if rng.f32() < 0.5 {
                a_idx.push(i);
            } else {
                b_idx.push(i);
            }
        }
        let all: Vec<usize> = (0..n).collect();
        let (of, lf) = attend(&all);
        let (mut oa, la) = attend(&a_idx);
        let (ob, lb) = attend(&b_idx);
        let lm = merge_head(&mut oa, la, &ob, lb);
        hgca::util::proptest::ensure_all_close(&oa, &of, 2e-4, "o")?;
        hgca::util::proptest::ensure_close(lm, lf, 2e-4, "lse")
    });
}

#[test]
fn prop_int8_roundtrip_error_within_half_scale() {
    // symmetric int8: |x - dequant(quant(x))| ≤ scale/2 elementwise, for
    // every slab shape — all-zero, single-element, ±max-magnitude blocks,
    // and generic normals — at several scale-block lengths
    check("int8_roundtrip", 60, |rng| {
        let shape = rng.range(0, 4);
        let (n, dh) = if shape == 3 {
            (1usize, 1usize) // single-element slab
        } else {
            (1 + rng.range(0, 3 * QUANT_BLOCK), 1 + rng.range(0, 16))
        };
        let mut rows = vec![0.0f32; n * dh];
        match shape {
            0 => {} // all-zero blocks → scale 0, exact round-trip
            2 => {
                // ±max-magnitude entries mixed with small ones
                for v in rows.iter_mut() {
                    let r = rng.f32();
                    *v = if r < 0.25 {
                        1e30
                    } else if r < 0.5 {
                        -1e30
                    } else {
                        rng.normal()
                    };
                }
            }
            _ => rng.fill_normal(&mut rows, 2.0),
        }
        let block = *rng.choice(&[1usize, 2, 5, QUANT_BLOCK]);
        let s = QuantSlab::from_f32(&rows, dh, block);
        ensure(s.len() == n, format!("slab len {} vs {n}", s.len()))?;
        let deq = s.dequantize();
        for t in 0..n {
            let scale = s.scale_of(t);
            // a hair of slack for the f32 divide/multiply in scale itself
            let bound = scale / 2.0 + scale * 1e-5 + 1e-7;
            for j in 0..dh {
                let (x, y) = (rows[t * dh + j], deq[t * dh + j]);
                ensure(
                    (x - y).abs() <= bound,
                    format!("entry {t}[{j}]: {x} vs {y} exceeds scale/2 ({scale})"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quant_size_bytes_exactly_accounts_tiered_buffers() {
    // size_bytes() = quantized data (1 B/value) + per-block scales
    // (4 B each) + staged f32 tail originals (4 B each), exactly —
    // across random incremental append patterns
    check("quant_size_exact", 40, |rng| {
        let dh = 1 + rng.range(0, 12);
        let block = 1 + rng.range(0, 40);
        let mut s = QuantSlab::new(dh, block);
        let mut n = 0usize;
        for _ in 0..rng.range(1, 6) {
            let add = rng.range(0, 50);
            let mut rows = vec![0.0f32; add * dh];
            rng.fill_normal(&mut rows, 1.0);
            s.push_entries(&rows);
            n += add;
        }
        let expect = n * dh + n.div_ceil(block) * 4 + (n % block) * dh * 4;
        ensure(
            s.size_bytes() == expect,
            format!("size {} vs {expect} (n={n} dh={dh} block={block})", s.size_bytes()),
        )
    });
}
