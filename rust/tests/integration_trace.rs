//! Scenario-DSL conformance: the `format → parse → format` fixed point
//! over seeded-random ASTs, malformed-input robustness (spanned errors,
//! never a panic), and the checked-in `scenarios/*.scn` corpus.
//!
//! Fuzzing is driven by the same LCG the trace sampler uses
//! (`simulator::trace::Lcg`, the `util/corpus.rs` generator), across
//! ≥1000 seeds per property, so every failure is replayable from its
//! seed number alone.

use std::path::Path;

use hgca::simulator::trace::{parse, Arrival, Dist, Fault, Lcg, Scenario};

// ---------------------------------------------------------------------
// seeded-random AST generation
// ---------------------------------------------------------------------

fn gen_dist(r: &mut Lcg, lo: u64, hi: u64) -> Dist {
    match r.next() % 3 {
        0 => Dist::Fixed(r.randint(lo, hi)),
        1 => {
            let a = r.randint(lo, hi);
            let b = r.randint(a, hi);
            Dist::Uniform(a, b)
        }
        _ => {
            let n = r.randint(1, 4);
            Dist::Choice((0..n).map(|_| r.randint(lo, hi)).collect())
        }
    }
}

fn gen_arrival(r: &mut Lcg, nested: bool) -> Arrival {
    match r.next() % if nested { 2 } else { 3 } {
        0 => Arrival::Fixed {
            interval: r.randint(1, 50),
        },
        1 => Arrival::Bursty {
            period: r.randint(1, 50),
            size: r.randint(1, 10),
        },
        _ => {
            let n = r.randint(1, 3);
            Arrival::Phases((0..n).map(|_| (r.randint(1, 100), gen_arrival(r, true))).collect())
        }
    }
}

fn gen_fault(r: &mut Lcg) -> Fault {
    Fault {
        prob: (r.next() % 1001) as f64 / 1000.0,
        after: gen_dist(r, 0, 100),
    }
}

fn gen_scenario(r: &mut Lcg) -> Scenario {
    // prompt structures are mutually exclusive (parser-enforced): draw one
    // of {none, share_prefix, turns}; turns keeps per_session × grow
    // within the 4096-byte prompt ceiling
    let (share_prefix, turns) = match r.next() % 3 {
        0 => (Some((r.randint(1, 100), r.randint(1, 4096))), None),
        1 => (None, Some((r.randint(1, 16), r.randint(1, 256)))),
        _ => (None, None),
    };
    Scenario {
        name: format!("s{}", r.next() % 10_000),
        seed: r.next(),
        requests: r.randint(1, 500) as usize,
        batch: r.randint(1, 64) as usize,
        kv_slots: (r.next() % 2 == 0).then(|| r.randint(1, 100) as usize),
        queue_bound: (r.next() % 2 == 0).then(|| r.randint(0, 500)),
        watermark: (r.next() % 2 == 0).then(|| r.randint(1, 500) as usize),
        arrival: gen_arrival(r, false),
        prompt: gen_dist(r, 1, 4096),
        gen: gen_dist(r, 0, 1000),
        share_prefix,
        turns,
        deadline_ms: (r.next() % 2 == 0).then(|| gen_dist(r, 1, 86_400_000)),
        cancel: (r.next() % 2 == 0).then(|| gen_fault(r)),
        disconnect: (r.next() % 2 == 0).then(|| gen_fault(r)),
        stream: (r.next() % 1001) as f64 / 1000.0,
    }
}

// ---------------------------------------------------------------------
// properties
// ---------------------------------------------------------------------

/// `format → parse` recovers the exact AST, and a second `format` is a
/// fixed point — across ≥1000 LCG seeds.
#[test]
fn format_parse_format_is_a_fixed_point() {
    for seed in 0..1200u64 {
        let mut r = Lcg::new(seed);
        let scn = gen_scenario(&mut r);
        let text = scn.to_string();
        let parsed = parse(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: canonical text failed to parse: {e}\n{text}"));
        assert_eq!(parsed, scn, "seed {seed}: AST not recovered from\n{text}");
        assert_eq!(parsed.to_string(), text, "seed {seed}: format not a fixed point");
    }
}

/// Mutating valid scenario text never panics the parser; every rejection
/// carries a 1-based line/column span and a message.
#[test]
fn mutated_inputs_error_with_spans_never_panic() {
    for seed in 0..1200u64 {
        let mut r = Lcg::new(seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1));
        let text = gen_scenario(&mut r).to_string();
        let mut bytes = text.into_bytes();
        // 1-3 random mutations: delete, insert, or overwrite a byte with
        // grammar-adjacent characters (punctuation and digits hit the
        // parser's interesting paths far more often than raw noise)
        const ALPHABET: &[u8] = b"{}(),=:#.0123456789abz_ \n";
        for _ in 0..r.randint(1, 3) {
            if bytes.is_empty() {
                break;
            }
            let pos = (r.next() as usize) % bytes.len();
            match r.next() % 3 {
                0 => {
                    bytes.remove(pos);
                }
                1 => {
                    let c = ALPHABET[(r.next() as usize) % ALPHABET.len()];
                    bytes.insert(pos, c);
                }
                _ => {
                    bytes[pos] = ALPHABET[(r.next() as usize) % ALPHABET.len()];
                }
            }
        }
        let mutated = String::from_utf8_lossy(&bytes).into_owned();
        if let Err(e) = parse(&mutated) {
            assert!(e.line >= 1 && e.col >= 1, "seed {seed}: unspanned error {e:?}");
            assert!(!e.msg.is_empty(), "seed {seed}: empty error message");
            assert!(
                e.to_string().contains(&format!("line {}", e.line)),
                "seed {seed}: Display must carry the span"
            );
        }
        // an Ok is fine — some mutations (comments, whitespace, digits
        // inside numbers) keep the text valid
    }
}

/// Arbitrary byte garbage — including non-UTF-8 and control characters —
/// never panics the parser.
#[test]
fn raw_garbage_never_panics() {
    for seed in 0..1000u64 {
        let mut r = Lcg::new(seed.wrapping_mul(0xD1B54A32D192ED03).wrapping_add(7));
        let len = (r.next() as usize) % 200;
        let bytes: Vec<u8> = (0..len).map(|_| (r.next() % 256) as u8).collect();
        let text = String::from_utf8_lossy(&bytes).into_owned();
        let _ = parse(&text); // must return, Ok or Err — never panic
    }
}

/// Every checked-in scenario parses, its name matches its file name, and
/// its canonical form round-trips.
#[test]
fn checked_in_scenarios_parse_and_round_trip() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("scenarios");
    let mut seen = 0;
    let mut entries: Vec<_> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("{}: {e}", dir.display()))
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "scn"))
        .collect();
    entries.sort();
    for path in entries {
        let src = std::fs::read_to_string(&path).unwrap();
        let scn = parse(&src).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert_eq!(
            Some(scn.name.as_str()),
            path.file_stem().and_then(|s| s.to_str()),
            "scenario name must match its file name"
        );
        let canon = scn.to_string();
        assert_eq!(parse(&canon).unwrap(), scn, "{}", path.display());
        seen += 1;
    }
    assert!(seen >= 4, "expected the 4-6 checked-in scenarios, found {seen}");
}
