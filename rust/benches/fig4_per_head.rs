//! Fig. 4 — percentage of KV entries required for 0.99 cumulative
//! attention, per head, middle layer, two different contexts.
//! Real attention probabilities (wall domain).

use std::path::Path;
use std::rc::Rc;

use hgca::analysis::coverage_per_head;
use hgca::model::RefModel;
use hgca::runtime::PjrtRuntime;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Rc::new(PjrtRuntime::new(&dir).expect("make artifacts first"));
    let model = std::env::var("HGCA_MODEL").unwrap_or("tiny".into());
    let mr = rt.load_model(&model).unwrap();
    mr.warn_if_synthetic();
    let oracle = RefModel::new(mr.cfg.clone(), mr.weights.clone()).unwrap();
    let text = hgca::util::corpus::ensure_corpus(&Path::new(env!("CARGO_MANIFEST_DIR")).join("data/corpus.txt")).unwrap();
    let t_len = if hgca::bench::full_mode() { 512 } else { 224 };
    let mid = mr.cfg.n_layers / 2;

    println!("=== Fig. 4: % of KVs for 0.99 cumulative score, layer {mid}, two contexts ===");
    let mut all = Vec::new();
    for (ci, off) in [8000usize, 60000].iter().enumerate() {
        let (_, probs) = oracle.forward(&text[*off..*off + t_len], true);
        let cov = coverage_per_head(&probs[mid], 0.99);
        println!("\ncontext {} (corpus offset {off}):", ci + 1);
        println!("{:>6} {:>10}", "head", "% needed");
        for (h, c) in cov.iter().enumerate() {
            println!("{h:>6} {:>9.1}%", c * 100.0);
        }
        all.push(cov);
    }
    let spread = |c: &Vec<f32>| {
        let mn = c.iter().cloned().fold(f32::INFINITY, f32::min);
        let mx = c.iter().cloned().fold(0.0f32, f32::max);
        (mn, mx)
    };
    let (mn1, mx1) = spread(&all[0]);
    let (mn2, mx2) = spread(&all[1]);
    println!("\n[shape check] per-head disparity ctx1: {:.1}%..{:.1}%, ctx2: {:.1}%..{:.1}%",
        mn1 * 100.0, mx1 * 100.0, mn2 * 100.0, mx2 * 100.0);
    println!("(paper: 10%..80% spread at layer 16 of OPT-6.7B — per-head budgets must differ)");
}
