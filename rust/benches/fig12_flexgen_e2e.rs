//! Fig. 12 — end-to-end generation (128 tokens @ prefill 1920) under the
//! FlexGen setting: FlexGen vs H2O vs InfiniGen vs HGCA across OPT models
//! and batch sizes, with peak-memory and OOM reporting. Sim domain.

use hgca::baselines::{simulate_generation, E2eConfig, SystemKind};
use hgca::config::model::simulated;
use hgca::simulator::Testbed;
use hgca::util::fmt_bytes;

fn main() {
    let tb = Testbed::paper();
    let systems = [
        ("flexgen", SystemKind::FlexGen),
        ("h2o", SystemKind::H2o),
        ("infinigen", SystemKind::Infinigen),
        ("hgca", SystemKind::Hgca),
    ];
    let cases: &[(&str, f64, &[usize])] = if hgca::bench::full_mode() {
        &[
            ("opt-6.7b", 1.0, &[1, 2, 4, 8, 16, 32]),
            ("opt-30b", 0.75, &[1, 2, 4, 8]),
            ("opt-66b", 0.25, &[1, 2, 4, 8]),
        ]
    } else {
        &[
            ("opt-6.7b", 1.0, &[4, 16]),
            ("opt-30b", 0.75, &[4]),
            ("opt-66b", 0.25, &[4, 8]),
        ]
    };
    for (model, frac, batches) in cases {
        let m = simulated(model).unwrap();
        println!("\n=== Fig. 12: {model} (gpu weight frac {frac}) — 128 tokens @ prefill 1920 ===");
        println!(
            "{:>6} {:>10} {:>12} {:>10} {:>12} {:>12}",
            "batch", "system", "total (s)", "tok/s", "peak gpu", "peak host"
        );
        for &b in batches.iter() {
            for (name, sys) in systems {
                let r = simulate_generation(
                    &tb,
                    &m,
                    &E2eConfig {
                        system: sys,
                        batch: b,
                        gpu_weight_frac: *frac,
                        window: 102, // 5% of 2048, paper's HGCA setting
                        ..Default::default()
                    },
                );
                println!(
                    "{:>6} {:>10} {:>12} {:>10} {:>12} {:>12}",
                    b,
                    name,
                    if r.oom { "OOM".into() } else { format!("{:.2}", r.total_secs) },
                    if r.oom { "-".into() } else { format!("{:.1}", r.tokens_per_sec) },
                    fmt_bytes(r.peak_gpu_bytes as u64),
                    fmt_bytes(r.peak_host_bytes as u64),
                );
            }
        }
    }
    println!("\n[shape check] HGCA beats FlexGen/H2O at every batch; InfiniGen is");
    println!("competitive on speed but OOMs from rehearsal memory as model/batch grow.");
}
