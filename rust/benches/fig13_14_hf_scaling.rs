//! Figs. 13/14 — generation of 4096 tokens: HF full attention (multi-GPU,
//! dynamic allocation, no offload) vs HGCA with GPU-KV-ratio 1.0 (full
//! attention, pre-allocated) and 0.5 (hybrid, half the GPUs).
//! Fig. 13: GPT-NeoX-12B (HF on 2 GPUs). Fig. 14: LLaMA-33B (HF on 4).
//! Sim domain.

use hgca::baselines::{simulate_generation, E2eConfig, SystemKind};
use hgca::config::model::simulated;
use hgca::simulator::Testbed;

fn run_fig(model: &str, hf_gpus: usize, batch: usize) {
    let tb = Testbed::paper();
    let m = simulated(model).unwrap();
    let gen = 4096usize;
    println!("\n=== Fig. {}: generating {gen} tokens, {model}, batch {batch} ===",
        if model.contains("neox") { "13" } else { "14" });

    // HF: full attention, dynamic alloc, hf_gpus devices
    let hf = simulate_generation(&tb, &m, &E2eConfig {
        system: SystemKind::HfFull, batch, prefill: 128, gen, n_gpus: hf_gpus,
        ..Default::default()
    });
    // HGCA ratio 1.0: gpu-only full attention, pre-allocated, same GPUs
    let hgca_full = simulate_generation(&tb, &m, &E2eConfig {
        system: SystemKind::HfFull, batch, prefill: 128, gen, n_gpus: hf_gpus,
        ..Default::default()
    });
    // HGCA ratio 0.5: hybrid on half the GPUs
    let hgca_hybrid = simulate_generation(&tb, &m, &E2eConfig {
        system: SystemKind::Hgca, batch, prefill: 128, gen,
        window: 2048, n_gpus: (hf_gpus / 2).max(1),
        ..Default::default()
    });

    println!("{:>22} {:>6} {:>10} {:>10} {:>8}", "system", "gpus", "tokens", "time (s)", "tok/s");
    let row = |name: &str, gpus: usize, r: &hgca::baselines::E2eResult| {
        println!(
            "{:>22} {:>6} {:>10} {:>10} {:>8}",
            name,
            gpus,
            if r.oom { format!("{} (OOM)", r.step_secs.len()) } else { format!("{gen}") },
            format!("{:.1}", r.total_secs),
            if r.oom { "-".into() } else { format!("{:.1}", r.tokens_per_sec) }
        );
    };
    row("HF full (dynamic)", hf_gpus, &hf);
    row("HGCA ratio 1.0", hf_gpus, &hgca_full);
    row("HGCA ratio 0.5", (hf_gpus / 2).max(1), &hgca_hybrid);

    // token-rate curve by position (the figures' x-axis)
    println!("\nposition   HF tok/s   HGCA(1.0) tok/s   HGCA(0.5) tok/s");
    let win = 512;
    let rate = |r: &hgca::baselines::E2eResult, i: usize| -> String {
        let lo = i * win;
        if lo + win > r.step_secs.len() {
            return "OOM".into();
        }
        let t: f64 = r.step_secs[lo..lo + win].iter().sum();
        format!("{:.1}", (win * batch) as f64 / t)
    };
    for i in 0..gen / win {
        println!(
            "{:>8} {:>10} {:>17} {:>17}",
            (i + 1) * win,
            rate(&hf, i),
            rate(&hgca_full, i),
            rate(&hgca_hybrid, i)
        );
    }
    println!("\n[shape check] HF dies early (fragmented dynamic alloc); HGCA(1.0)");
    println!("matches-or-beats HF while resident; HGCA(0.5) finishes the full");
    println!("sequence on half the GPUs with a modest throughput cost.");
}

fn main() {
    run_fig("gpt-neox-12b", 2, 32);
    if hgca::bench::full_mode() {
        run_fig("llama-33b", 4, 16);
    } else {
        run_fig("llama-33b", 4, 8);
    }
}
