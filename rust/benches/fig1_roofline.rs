//! Fig. 1 — roofline model of attention stages in LLM serving.
//! Prints operational intensity and attainable throughput per stage on the
//! A6000 / Xeon-6430 ceilings, plus the regime classification the paper
//! argues from. All columns are analytic (sim domain).

use hgca::simulator::{AttnWork, DeviceSpec};

fn work(n_query: usize, n_kv: usize, batch: usize) -> AttnWork {
    AttnWork { batch, heads: 32, d_head: 128, n_query, n_kv, bytes_per_el: 2 }
}

fn main() {
    let gpu = DeviceSpec::a6000();
    let cpu = DeviceSpec::xeon6430();
    println!("=== Fig. 1: roofline of attention stages (OPT-6.7B shapes) ===");
    println!(
        "gpu ridge = {:.1} flop/B | cpu ridge = {:.1} flop/B",
        gpu.ridge_intensity(),
        cpu.ridge_intensity()
    );
    println!();
    println!("{:<22} {:>10} {:>14} {:>14} {:>12}", "stage", "intensity", "gpu TFLOP/s", "cpu TFLOP/s", "regime(gpu)");
    let stages: [(&str, AttnWork); 6] = [
        ("prefill 2k (1:1)", work(2048, 2048, 1)),
        ("prefill 512", work(512, 512, 4)),
        ("append q=32", work(32, 8192, 1)),
        ("append q=8", work(8, 8192, 1)),
        ("decode q=1 @8k", work(1, 8192, 1)),
        ("decode q=1 @32k", work(1, 32768, 1)),
    ];
    for (name, w) in stages {
        let i = w.intensity();
        let regime = if i > gpu.ridge_intensity() { "compute" } else { "memory" };
        println!(
            "{:<22} {:>10.2} {:>14.2} {:>14.2} {:>12}",
            name,
            i,
            gpu.attainable_flops(i) / 1e12,
            cpu.attainable_flops(i) / 1e12,
            regime
        );
    }
    println!();
    println!("roofline curves (attainable TFLOP/s vs intensity):");
    println!("{:>10} {:>12} {:>12}", "intensity", "a6000", "xeon6430");
    let mut i = 0.125f64;
    while i <= 512.0 {
        println!(
            "{:>10.3} {:>12.3} {:>12.3}",
            i,
            gpu.attainable_flops(i) / 1e12,
            cpu.attainable_flops(i) / 1e12
        );
        i *= 2.0;
    }
    println!("\n[shape check] decode/append sit left of the GPU ridge (memory-bound),");
    println!("where the CPU:GPU attainable ratio is bw-bound ({:.2}x), not flops-bound ({:.1}x).",
        cpu.mem_bw / gpu.mem_bw, gpu.peak_flops / cpu.peak_flops);
}
