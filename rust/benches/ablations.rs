//! Ablations of HGCA's design choices (DESIGN.md §Perf / paper §3):
//!   A1 block-granular vs per-token eviction (PCIe amortization footnote 2)
//!   A2 MAW moving-average factor α sensitivity (accuracy, real numerics)
//!   A3 head-packing: thread/task count vs per-head threads (§3.3)
//!   A4 merge payload vs raw-KV transfer (the zero-copy claim)

use std::path::Path;
use std::rc::Rc;

use hgca::attention::{sparse_attention, HeadJob};
use hgca::config::HgcaConfig;
use hgca::engine::{Engine, Policy};
use hgca::runtime::PjrtRuntime;
use hgca::simulator::{Interconnect, Testbed};
use hgca::util::rng::Rng;

fn main() {
    // ---- A1: eviction granularity (sim) ----
    println!("=== A1: eviction granularity — PCIe time to offload 4096 tokens (opt-6.7b layer) ===");
    let link = Interconnect::pcie4x16();
    let tok_bytes = 16384.0;
    println!("{:>10} {:>12}", "blk_size", "time (ms)");
    for blk in [1usize, 8, 32, 128, 512] {
        let t = link.transfer_time_n(4096 / blk, blk as f64 * tok_bytes);
        println!("{blk:>10} {:>12.2}", t * 1e3);
    }
    println!("(paper footnote 2: block batching amortizes DMA latency — {}x at blk 32)\n",
        (link.transfer_time_n(4096, tok_bytes) / link.transfer_time_n(128, 32.0 * tok_bytes)).round());

    // ---- A4: merge payload vs raw KV (sim) ----
    println!("=== A4: per-layer CPU→GPU payload, batch 4, opt-6.7b @16k context ===");
    let mb = Testbed::merge_bytes(4, 32, 128);
    let kv = 2.0 * 4.0 * 32.0 * 16384.0 * 128.0 * 2.0;
    println!("merge (O_cpu+lse): {:>10.1} KiB  → {:.3} ms", mb / 1024.0, link.transfer_time(mb) * 1e3);
    println!("raw KV reload:     {:>10.1} MiB → {:.1} ms  ({}x more)",
        kv / 1048576.0, link.transfer_time(kv) * 1e3, (kv / mb).round());

    // ---- A3: head packing (wall) ----
    println!("\n=== A3: head-packing — tasks vs wall time, 32 (row,head) jobs of 2048 KVs ===");
    let mut rng = Rng::new(1);
    let (dh, n, jobs_n) = (32usize, 2048usize, 32usize);
    let kvs: Vec<(Vec<f32>, Vec<f32>)> = (0..jobs_n)
        .map(|_| {
            let mut k = vec![0.0f32; n * dh];
            let mut v = vec![0.0f32; n * dh];
            rng.fill_normal(&mut k, 1.0);
            rng.fill_normal(&mut v, 1.0);
            (k, v)
        })
        .collect();
    let jobs: Vec<HeadJob> = kvs.iter().map(|(k, v)| HeadJob { k, v, n }).collect();
    let mut q = vec![0.0f32; jobs_n * dh];
    rng.fill_normal(&mut q, 0.2);
    println!("{:>10} {:>10} {:>12}", "threads", "tasks", "p50 (ms)");
    for threads in [1usize, 2, 4, 8, 32] {
        let mut tasks = 0;
        let s = hgca::bench::bench(2, 10, || {
            tasks = sparse_attention(&jobs, &q, 1, dh, threads, false).tasks;
        });
        println!("{threads:>10} {tasks:>10} {:>12.3}", s.p50 * 1e3);
    }
    println!("(paper §3.3: pack heads to ≈cores; per-head threads oversubscribe)");

    // ---- A2: MAW α sensitivity (wall, real numerics) ----
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if let Ok(rt) = PjrtRuntime::new(&dir) {
        let rt = Rc::new(rt);
        let mr = rt.load_model("tiny-small").unwrap();
        mr.warn_if_synthetic();
        let text = hgca::util::corpus::ensure_corpus(&Path::new(env!("CARGO_MANIFEST_DIR")).join("data/corpus.txt")).unwrap();
        let text = &text[1000..1000 + 192];
        println!("\n=== A2: MAW α sensitivity (ppl, window 32, beta 1.0) ===");
        println!("{:>8} {:>10}", "alpha", "ppl");
        for alpha in [0.05f32, 0.3, 0.7, 1.0] {
            let cfg = HgcaConfig {
                blk_size: 8,
                blk_num: 4,
                alpha,
                ..Default::default()
            };
            let mut e = Engine::new(&mr, cfg, Policy::Hgca { beta: 1.0 });
            let ppl = e.perplexity(text, 32).unwrap();
            println!("{alpha:>8.2} {ppl:>10.4}");
        }
        println!("(low α = long memory of attention history; α=1 = last-step only)");
    }
}
