//! Fig. 5 — attention weights vs KV position at two decoding depths for
//! one head: spatial locality (recency + sinks) and contextual locality
//! (persistent mid-sequence spikes). Real probabilities (wall domain).

use std::path::Path;
use std::rc::Rc;

use hgca::analysis::{critical_set, positional_weights};
use hgca::model::RefModel;
use hgca::runtime::PjrtRuntime;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Rc::new(PjrtRuntime::new(&dir).expect("make artifacts first"));
    let mr = rt.load_model(&std::env::var("HGCA_MODEL").unwrap_or("tiny".into())).unwrap();
    mr.warn_if_synthetic();
    let oracle = RefModel::new(mr.cfg.clone(), mr.weights.clone()).unwrap();
    let text = hgca::util::corpus::ensure_corpus(&Path::new(env!("CARGO_MANIFEST_DIR")).join("data/corpus.txt")).unwrap();
    let (t1, t2) = if hgca::bench::full_mode() { (256usize, 512usize) } else { (128, 255) };
    let (_, probs) = oracle.forward(&text[3000..3000 + t2 + 1], true);
    let mid = mr.cfg.n_layers / 2;
    let head = 1.min(mr.cfg.n_heads - 1);

    println!("=== Fig. 5: attention vs KV position, layer {mid} head {head}, decode @{t1} and @{t2} ===");
    println!("{:>8} {:>12} {:>12}", "pos", format!("w@{t1}"), format!("w@{t2}"));
    let w1 = positional_weights(&probs[mid], head, t1);
    let w2 = positional_weights(&probs[mid], head, t2);
    let stride = (t2 / 48).max(1);
    for p in (0..w2.len()).step_by(stride) {
        let a = if p < w1.len() { format!("{:.5}", w1[p]) } else { "-".into() };
        println!("{p:>8} {a:>12} {:>12.5}", w2[p]);
    }
    let c1 = critical_set(&w1, 0.9);
    let c2 = critical_set(&w2, 0.9);
    println!("\n[shape check] 90% critical set: {} of {} entries @{t1}; {} of {} @{t2}", 
        c1.len(), w1.len(), c2.len(), w2.len());
    // spatial locality: how much of the critical set is recent?
    let recent = |c: &Vec<usize>, t: usize| c.iter().filter(|&&p| p + 32 >= t).count();
    println!("critical entries within last 32 tokens: {}/{} @{t1}, {}/{} @{t2}",
        recent(&c1, t1), c1.len(), recent(&c2, t2), c2.len());
    // contextual locality: persistent old entries influential at both depths
    let old_persistent: Vec<usize> = c1.iter().filter(|p| c2.contains(p) && **p + 64 < t1).copied().collect();
    println!("persistent old (contextual) entries in both critical sets: {:?}",
        &old_persistent[..old_persistent.len().min(12)]);
    println!("(paper O-2: spatial locality + a few persistent contextual KVs)");
}
