//! Fig. 3 — heatmap of cumulative attention weights over (start window ×
//! recent window), for entry / middle / exit layers of the trained model
//! on the bundled corpus. Real attention probabilities (wall domain).

use std::path::Path;
use std::rc::Rc;

use hgca::analysis::{cumulative_heatmap, top_decile_mass};
use hgca::model::RefModel;
use hgca::runtime::PjrtRuntime;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Rc::new(PjrtRuntime::new(&dir).expect("make artifacts first"));
    let model = std::env::var("HGCA_MODEL").unwrap_or("tiny".into());
    let mr = rt.load_model(&model).unwrap();
    mr.warn_if_synthetic();
    let oracle = RefModel::new(mr.cfg.clone(), mr.weights.clone()).unwrap();
    let text = hgca::util::corpus::ensure_corpus(&Path::new(env!("CARGO_MANIFEST_DIR")).join("data/corpus.txt")).unwrap();
    let t_len = if hgca::bench::full_mode() { 512 } else { 192 };
    let (_, probs) = oracle.forward(&text[2000..2000 + t_len], true);

    let starts = [0usize, 4, 16, 64];
    let recents = [4usize, 16, 64, 128];
    let layers = [0usize, mr.cfg.n_layers / 2, mr.cfg.n_layers - 1];
    println!("=== Fig. 3: cumulative attention heatmap (model={model}, T={t_len}) ===");
    for &li in &layers {
        let grid = cumulative_heatmap(&probs[li], &starts, &recents);
        println!("\nlayer {li} (top-decile mass {:.3}):", top_decile_mass(&probs[li]));
        print!("{:>8}", "start\\rec");
        for r in recents {
            print!("{r:>8}");
        }
        println!();
        for (si, s) in starts.iter().enumerate() {
            print!("{s:>8}");
            for ri in 0..recents.len() {
                print!("{:>8.3}", grid[si][ri]);
            }
            println!();
        }
    }
    // paper's skew trend: deeper layers concentrate mass
    let skews: Vec<f32> = layers.iter().map(|&li| top_decile_mass(&probs[li])).collect();
    println!("\n[shape check] top-decile mass by layer {layers:?}: {skews:?}");
    println!("(paper O-1: distributions grow more skewed toward exit layers)");
}
