//! Fig. 11 — attention time breakdown at GPU KV = 1024: PCIe transfer vs
//! GPU kernel for the load path, vs CPU attn + merge for hybrid.
//! Sim domain (paper testbed, OPT-6.7B shapes).

use hgca::config::model::simulated;
use hgca::engine::Policy;
use hgca::simulator::Testbed;

fn main() {
    let tb = Testbed::paper();
    let m = simulated("opt-6.7b").unwrap();
    let g = 1024usize;
    let cpu_kvs: &[usize] = if hgca::bench::full_mode() {
        &[1024, 2048, 4096, 8192, 16384, 32768, 65536]
    } else {
        &[2048, 8192, 32768]
    };
    println!("=== Fig. 11: attention time breakdown (GPU KV = {g}, batch 4, sim ms) ===");
    println!(
        "{:>8} | {:>10} {:>10} {:>10} | {:>10} {:>10} {:>8} {:>10}",
        "cpu kv", "xfer", "gpu attn", "GPU+load", "gpu win", "cpu attn", "merge", "HYBRID"
    );
    for &c in cpu_kvs {
        let (_, off) = (
            0,
            Policy::FullOffload.sim_attention(&tb, &m, 4, 1, g, c, 0).1,
        );
        let pol = Policy::Hgca { beta: 1.0 };
        let (hybrid_wall, hy) = pol.sim_attention(&tb, &m, 4, 1, g, c, (c as f64 * 0.2) as usize);
        println!(
            "{:>8} | {:>9.2} {:>9.2} {:>9.2} | {:>9.2} {:>9.2} {:>7.3} {:>9.2}",
            c,
            off.get("pcie_kv_load") * 1e3,
            off.get("gpu_attn") * 1e3,
            off.total() * 1e3,
            hy.get("gpu_attn") * 1e3,
            hy.get("cpu_attn") * 1e3,
            hy.get("merge") * 1e3,
            hybrid_wall * 1e3,
        );
    }
    println!("\n[shape check] PCIe transfer grows linearly and dominates GPU+load;");
    println!("CPU attention is slower than the GPU kernel but merge is negligible,");
    println!("so hybrid wins overall (paper Fig. 11).");
}
