//! Hot-path microbenchmarks (wall domain, this machine): the inner loops
//! the §Perf pass optimizes — CPU sparse attention, LSE merge, MAW update,
//! window staging, PJRT call overhead. Baseline + after numbers live in
//! EXPERIMENTS.md §Perf.
//!
//! With `HGCA_BENCH_JSON=path` the pool-vs-spawn cases are also written as
//! a JSON document (`BENCH_*.json`) for the CI bench-regression gate
//! (`tools/bench_gate.rs`): per case, the pool-path p50/throughput, the
//! spawn baseline, and their speedup ratio.

use std::path::Path;
use std::rc::Rc;

use hgca::attention::{
    merge_states, sparse_attention, sparse_attention_spawn, AttnPool, HeadJob, TaskSplit,
};
use hgca::bench::bench;
use hgca::topology::Topology;
use hgca::util::json::Json;
use hgca::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(0);
    let dh = 32;
    let mut gate_cases: Vec<Json> = Vec::new();

    // ---- persistent pool vs per-call thread spawn ----
    // the decode hot path: small job counts (batch×heads ≤ 64), every step
    // one submission. The pool must win here — per-call spawn/join overhead
    // is the cost the tentpole removes.
    println!("== pool vs spawn (decode shapes) ==");
    for (jobs_n, n) in [(4usize, 256usize), (8, 512), (16, 512), (64, 1024)] {
        let kvs: Vec<(Vec<f32>, Vec<f32>)> = (0..jobs_n)
            .map(|_| {
                let mut k = vec![0.0f32; n * dh];
                let mut v = vec![0.0f32; n * dh];
                rng.fill_normal(&mut k, 1.0);
                rng.fill_normal(&mut v, 1.0);
                (k, v)
            })
            .collect();
        let jobs: Vec<HeadJob> = kvs.iter().map(|(k, v)| HeadJob { k, v, n }).collect();
        let mut q = vec![0.0f32; jobs_n * dh];
        rng.fill_normal(&mut q, 0.2);
        let threads = 4;
        let s_pool = bench(5, 60, || {
            let _ = sparse_attention(&jobs, &q, 1, dh, threads, false);
        });
        let s_spawn = bench(5, 60, || {
            let _ = sparse_attention_spawn(&jobs, &q, 1, dh, threads, false);
        });
        println!(
            "jobs={jobs_n:>3} n={n:>5} t={threads}: pool p50 {:>9.1} µs | spawn p50 {:>9.1} µs | speedup {:>5.2}x",
            s_pool.p50 * 1e6,
            s_spawn.p50 * 1e6,
            s_spawn.p50 / s_pool.p50
        );
        gate_cases.push(Json::obj(vec![
            ("jobs", Json::num(jobs_n as f64)),
            ("n", Json::num(n as f64)),
            ("threads", Json::num(threads as f64)),
            ("pool_p50_us", Json::num(s_pool.p50 * 1e6)),
            ("spawn_p50_us", Json::num(s_spawn.p50 * 1e6)),
            ("pool_calls_per_sec", Json::num(1.0 / s_pool.p50)),
            ("speedup", Json::num(s_spawn.p50 / s_pool.p50)),
        ]));
        // bitwise stability: repeated pool runs at different parallelism
        // caps must reproduce the spawn path exactly
        let reference = sparse_attention_spawn(&jobs, &q, 1, dh, 1, false);
        for cap in [1usize, 2, 7, 64] {
            let out = sparse_attention(&jobs, &q, 1, dh, cap, false);
            assert_eq!(out.o, reference.o, "pool output drifted at cap {cap}");
            assert_eq!(out.lse, reference.lse, "pool lse drifted at cap {cap}");
        }
    }
    println!();

    // ---- sharded (per-NUMA-node queues) vs flat pool ----
    // the NUMA tentpole's placement path on a decode-shaped submission:
    // tasks are routed to per-node queues via a shard map instead of one
    // flat injector. On a single-socket runner the two should be within
    // noise of each other (the gate's baseline speedup is set low enough
    // that only a real dispatch regression trips it); on multi-socket
    // hardware the sharded pool gains local-slab bandwidth.
    println!("== sharded (4-node synthetic) vs flat pool ==");
    {
        let (jobs_n, n, threads) = (32usize, 512usize, 4usize);
        let kvs: Vec<(Vec<f32>, Vec<f32>)> = (0..jobs_n)
            .map(|_| {
                let mut k = vec![0.0f32; n * dh];
                let mut v = vec![0.0f32; n * dh];
                rng.fill_normal(&mut k, 1.0);
                rng.fill_normal(&mut v, 1.0);
                (k, v)
            })
            .collect();
        let jobs: Vec<HeadJob> = kvs.iter().map(|(k, v)| HeadJob { k, v, n }).collect();
        let mut q = vec![0.0f32; jobs_n * dh];
        rng.fill_normal(&mut q, 0.2);
        // contiguous node runs so each packed task lands wholly on one node
        let nodes: Vec<usize> = (0..jobs_n).map(|j| j * 4 / jobs_n).collect();
        let flat = AttnPool::new(threads);
        let sharded = AttnPool::with_topology(threads, Topology::synthetic(4));
        let split = TaskSplit::EvenJobs { max_parallel: threads };
        let s_flat = bench(5, 60, || {
            let _ = flat.run_masked(&jobs, &q, 1, dh, threads, false, None);
        });
        let s_shard = bench(5, 60, || {
            let _ = sharded.run_placed(&jobs, &q, 1, dh, split, false, None, Some(&nodes));
        });
        println!(
            "jobs={jobs_n:>3} n={n:>5} t={threads}: sharded p50 {:>9.1} µs | flat p50 {:>9.1} µs | ratio {:>5.2}x",
            s_shard.p50 * 1e6,
            s_flat.p50 * 1e6,
            s_flat.p50 / s_shard.p50
        );
        gate_cases.push(Json::obj(vec![
            ("jobs", Json::num(jobs_n as f64)),
            ("n", Json::num(n as f64)),
            ("threads", Json::num(threads as f64)),
            // gated path = the sharded pool; baseline = the flat pool
            ("pool_p50_us", Json::num(s_shard.p50 * 1e6)),
            ("spawn_p50_us", Json::num(s_flat.p50 * 1e6)),
            ("pool_calls_per_sec", Json::num(1.0 / s_shard.p50)),
            ("speedup", Json::num(s_flat.p50 / s_shard.p50)),
        ]));
        // placement is a pure scheduling change: bitwise conformance
        let reference = flat.run_masked(&jobs, &q, 1, dh, threads, false, None);
        let placed = sharded.run_placed(&jobs, &q, 1, dh, split, false, None, Some(&nodes));
        assert_eq!(placed.o, reference.o, "sharded pool output drifted");
        assert_eq!(placed.lse, reference.lse, "sharded pool lse drifted");
        let st = sharded.stats();
        assert_eq!(st.numa_nodes, 4);
        assert_eq!(st.node_tasks.iter().sum::<u64>(), st.tasks);
    }
    println!();

    // ---- overlapped submit vs sequential step (the overlap tentpole) ----
    // Engine-shaped arms: each iteration clones fresh owned inputs (the
    // engine's gather produces owned KV copies every step) and runs one
    // sparse submission plus a serial bookkeeping payload — MAW updates on
    // a decode-shaped window cache, calibrated to roughly the sparse cost
    // so the target is runner-independent. The sequential arm waits before
    // bookkeeping (the pre-overlap engine); the overlapped arm submits,
    // bookkeeps, then waits. speedup = sequential_p50 / overlapped_p50.
    println!("== overlapped submit+bookkeeping vs sequential step ==");
    {
        use hgca::attention::OwnedJobs;
        use hgca::kv::GpuLayerCache;
        let (jobs_n, n, threads) = (16usize, 2048usize, 4usize);
        let kvs: Vec<(Vec<f32>, Vec<f32>, usize)> = (0..jobs_n)
            .map(|_| {
                let mut k = vec![0.0f32; n * dh];
                let mut v = vec![0.0f32; n * dh];
                rng.fill_normal(&mut k, 1.0);
                rng.fill_normal(&mut v, 1.0);
                (k, v, n)
            })
            .collect();
        let jobs: Vec<HeadJob> = kvs.iter().map(|(k, v, n)| HeadJob { k, v, n: *n }).collect();
        let mut q = vec![0.0f32; jobs_n * dh];
        rng.fill_normal(&mut q, 0.2);
        let pool = AttnPool::new(threads);
        let split = TaskSplit::EvenJobs { max_parallel: threads };
        let mut cache = GpuLayerCache::new(32, 128, 32, 32, 0.3);
        let wlen = 1024;
        let k0 = vec![0.1f32; 32 * wlen * 128];
        let v0 = vec![0.1f32; 32 * wlen * 128];
        let pos: Vec<usize> = (0..wlen).collect();
        cache.append(&k0, &v0, &pos);
        let a = vec![0.001f32; 32 * (wlen + 1)];
        let s_sparse = bench(3, 20, || {
            let _ = pool.run_placed(&jobs, &q, 1, dh, split, false, None, None);
        });
        let s_one = bench(3, 20, || {
            cache.update_maw(&a, wlen + 1, wlen, 0, 1);
        });
        let reps = ((s_sparse.p50 / s_one.p50.max(1e-9)).round() as usize).clamp(1, 256);
        let s_seq = bench(5, 40, || {
            let input = OwnedJobs { kvs: kvs.clone(), q: q.clone(), q_valid: None };
            let _ = pool.submit_placed(input, 1, dh, split, false, None).wait();
            for _ in 0..reps {
                cache.update_maw(&a, wlen + 1, wlen, 0, 1);
            }
        });
        let s_ovl = bench(5, 40, || {
            let input = OwnedJobs { kvs: kvs.clone(), q: q.clone(), q_valid: None };
            let p = pool.submit_placed(input, 1, dh, split, false, None);
            for _ in 0..reps {
                cache.update_maw(&a, wlen + 1, wlen, 0, 1);
            }
            let _ = p.wait();
        });
        println!(
            "jobs={jobs_n:>3} n={n:>5} t={threads} book_reps={reps}: overlapped p50 {:>9.1} µs | sequential p50 {:>9.1} µs | speedup {:>5.2}x",
            s_ovl.p50 * 1e6,
            s_seq.p50 * 1e6,
            s_seq.p50 / s_ovl.p50
        );
        gate_cases.push(Json::obj(vec![
            ("jobs", Json::num(jobs_n as f64)),
            ("n", Json::num(n as f64)),
            ("threads", Json::num(threads as f64)),
            // gated path = the overlapped step; baseline = forced-sequential
            ("pool_p50_us", Json::num(s_ovl.p50 * 1e6)),
            ("spawn_p50_us", Json::num(s_seq.p50 * 1e6)),
            ("pool_calls_per_sec", Json::num(1.0 / s_ovl.p50)),
            ("speedup", Json::num(s_seq.p50 / s_ovl.p50)),
        ]));
        // the overlap is a pure scheduling change: bitwise conformance
        let reference = pool.run_placed(&jobs, &q, 1, dh, split, false, None, None);
        let input = OwnedJobs { kvs: kvs.clone(), q: q.clone(), q_valid: None };
        let overlapped = pool.submit_placed(input, 1, dh, split, false, None).wait();
        assert_eq!(overlapped.o, reference.o, "overlapped output drifted");
        assert_eq!(overlapped.lse, reference.lse, "overlapped lse drifted");
    }
    println!();

    // ---- shared-prefix prefill: radix cache on vs off ----
    // 64 requests through the real batcher, all opening with the same
    // 128-byte prefix. The cached arm adopts the prefix KV snapshot at
    // admission instead of re-prefilling it; tokens must be bitwise
    // identical either way (adoption is pure memoization — greedy sampler,
    // RNG-free prefill). speedup = uncached_p50 / cached_p50.
    println!("== shared-prefix prefill fleet: radix cache on vs off ==");
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if let Ok(rt) = hgca::runtime::PjrtRuntime::new(&dir) {
        use hgca::config::HgcaConfig;
        use hgca::engine::{Batcher, Engine, Policy, Request};
        let rt = Rc::new(rt);
        let mr = rt.load_model("tiny").unwrap();
        let (fleet, prefix_len, tail_len, batch) = (64usize, 128usize, 64usize, 4usize);
        let corpus = hgca::util::corpus::generate(prefix_len + fleet * tail_len, 1);
        let prompts: Vec<Vec<u8>> = (0..fleet)
            .map(|i| {
                let mut p = corpus[..prefix_len].to_vec();
                p.extend_from_slice(&corpus[prefix_len + i * tail_len..prefix_len + (i + 1) * tail_len]);
                p
            })
            .collect();
        let run_fleet = |cached: bool| -> Vec<(u64, Vec<u8>)> {
            let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
            let bps = engine.blocks_per_sequence();
            // spare slots beyond the batch so cache entries can lease blocks
            engine.set_kv_block_capacity(Some((batch + 2) * bps));
            if cached {
                engine.enable_prefix_cache(32);
            }
            let mut b = Batcher::new(batch);
            for (i, p) in prompts.iter().enumerate() {
                b.submit(Request {
                    id: i as u64 + 1,
                    prompt: p.clone(),
                    max_new_tokens: 4,
                });
            }
            let mut out = Vec::new();
            while b.pending() > 0 {
                for c in b.tick(&mut engine).unwrap() {
                    out.push((c.id, c.text));
                }
            }
            out.sort();
            out
        };
        // bitwise conformance first: the cache must be invisible in tokens
        let uncached = run_fleet(false);
        let cached = run_fleet(true);
        assert_eq!(cached, uncached, "prefix-cache adoption changed generated tokens");
        let s_off = bench(1, 5, || {
            let _ = run_fleet(false);
        });
        let s_on = bench(1, 5, || {
            let _ = run_fleet(true);
        });
        println!(
            "fleet={fleet:>3} prefix={prefix_len} tail={tail_len}: cached p50 {:>9.1} ms | uncached p50 {:>9.1} ms | speedup {:>5.2}x",
            s_on.p50 * 1e3,
            s_off.p50 * 1e3,
            s_off.p50 / s_on.p50
        );
        gate_cases.push(Json::obj(vec![
            ("jobs", Json::num(fleet as f64)),
            ("n", Json::num((prefix_len + tail_len) as f64)),
            ("threads", Json::num(4.0)),
            // gated path = the cached fleet; baseline = cache disabled
            ("pool_p50_us", Json::num(s_on.p50 * 1e6)),
            ("spawn_p50_us", Json::num(s_off.p50 * 1e6)),
            ("pool_calls_per_sec", Json::num(1.0 / s_on.p50)),
            ("speedup", Json::num(s_off.p50 / s_on.p50)),
        ]));
    } else {
        println!("(skipped: no artifact runtime — baseline case is additive)");
    }
    println!();

    // ---- int8-tiered sparse attention vs f32 (the tiered-KV tentpole) ----
    // Same submission through the tiered pool path with every payload
    // int8-quantized vs all-f32: speedup = f32_p50 / int8_p50. The int8
    // kernel trades per-entry multiplies for i8 dots + one scale multiply;
    // on a scalar build the two are within ~2x of each other either way,
    // so the baseline speedup is set low — the gate trips only if the
    // quantized path collapses relative to f32. The win the tier buys is
    // resident bytes (~4x, printed below), not per-call latency.
    println!("== int8-tiered vs f32 sparse attention (full-store shape) ==");
    {
        use hgca::attention::{JobPayload, OwnedJobs, OwnedTieredJobs};
        use hgca::kv::{QuantSlab, QUANT_BLOCK};
        let (jobs_n, n, threads) = (8usize, 4096usize, 4usize);
        let kvs: Vec<(Vec<f32>, Vec<f32>, usize)> = (0..jobs_n)
            .map(|_| {
                let mut k = vec![0.0f32; n * dh];
                let mut v = vec![0.0f32; n * dh];
                rng.fill_normal(&mut k, 1.0);
                rng.fill_normal(&mut v, 1.0);
                (k, v, n)
            })
            .collect();
        let mut q = vec![0.0f32; jobs_n * dh];
        rng.fill_normal(&mut q, 0.2);
        let quant: Vec<(QuantSlab, QuantSlab)> = kvs
            .iter()
            .map(|(k, v, _)| {
                (QuantSlab::from_f32(k, dh, QUANT_BLOCK), QuantSlab::from_f32(v, dh, QUANT_BLOCK))
            })
            .collect();
        let pool = AttnPool::new(threads);
        let split = TaskSplit::EvenJobs { max_parallel: threads };
        let s_f32 = bench(3, 20, || {
            let input = OwnedJobs { kvs: kvs.clone(), q: q.clone(), q_valid: None };
            let _ = pool.submit_placed(input, 1, dh, split, false, None).wait();
        });
        let s_int8 = bench(3, 20, || {
            let input = OwnedTieredJobs {
                kvs: quant
                    .iter()
                    .map(|(k, v)| JobPayload::Int8 { k: k.clone(), v: v.clone() })
                    .collect(),
                q: q.clone(),
                q_valid: None,
            };
            let _ = pool.submit_tiered(input, 1, dh, split, false, None).wait();
        });
        let f32_bytes = 2 * n * dh * 4;
        let quant_bytes = quant[0].0.size_bytes() + quant[0].1.size_bytes();
        println!(
            "jobs={jobs_n:>3} n={n:>5} t={threads}: int8 p50 {:>9.1} µs | f32 p50 {:>9.1} µs | ratio {:>5.2}x | {:.2}x fewer KV bytes",
            s_int8.p50 * 1e6,
            s_f32.p50 * 1e6,
            s_f32.p50 / s_int8.p50,
            f32_bytes as f64 / quant_bytes as f64
        );
        gate_cases.push(Json::obj(vec![
            ("jobs", Json::num(jobs_n as f64)),
            ("n", Json::num(n as f64)),
            ("threads", Json::num(threads as f64)),
            // gated path = the int8-tiered submit; baseline = all-f32
            ("pool_p50_us", Json::num(s_int8.p50 * 1e6)),
            ("spawn_p50_us", Json::num(s_f32.p50 * 1e6)),
            ("pool_calls_per_sec", Json::num(1.0 / s_int8.p50)),
            ("speedup", Json::num(s_f32.p50 / s_int8.p50)),
        ]));
        // the tier's contract, checked on this shape too: ≥3x compression
        // and the quantized output tracks the f32 oracle within 1e-2
        assert!(
            f32_bytes >= 3 * quant_bytes,
            "int8 tier must compress ≥3x ({quant_bytes} vs {f32_bytes} bytes)"
        );
        let reference = {
            let input = OwnedJobs { kvs: kvs.clone(), q: q.clone(), q_valid: None };
            pool.submit_placed(input, 1, dh, split, false, None).wait()
        };
        let quant_out = {
            let input = OwnedTieredJobs {
                kvs: quant
                    .iter()
                    .map(|(k, v)| JobPayload::Int8 { k: k.clone(), v: v.clone() })
                    .collect(),
                q: q.clone(),
                q_valid: None,
            };
            pool.submit_tiered(input, 1, dh, split, false, None).wait()
        };
        for (i, (a, b)) in reference.o.iter().zip(quant_out.o.iter()).enumerate() {
            assert!(
                (a - b).abs() <= 1e-2,
                "int8 output drifted past the oracle bound at {i}: {a} vs {b}"
            );
        }
    }
    println!();

    // ---- SIMD kernel dispatch vs scalar (the dispatch-layer tentpole) ----
    // Conformance before timing: dot_i8 must match scalar bitwise and the
    // f32 kernels within 1e-5 (tests/integration_simd.rs pins the full
    // contract; the asserts here keep a broken table from publishing
    // numbers). On a host where detection picks scalar the cases are
    // skipped entirely — their baselines are flagged additive, so the gate
    // tolerates their absence and scalar-only runners stay green.
    println!("== simd kernels vs scalar (runtime dispatch) ==");
    {
        use hgca::attention::{run_tiered_at_level, JobPayload};
        use hgca::kv::{QuantSlab, QUANT_BLOCK};
        use hgca::tensor::simd::{detect, Kernels, SimdLevel};
        use std::hint::black_box;
        let level = detect();
        println!("detected dispatch level: {level}");
        if level == SimdLevel::Scalar {
            println!("(scalar-only host: simd-vs-scalar cases skipped)");
        } else {
            let kn = Kernels::for_level(level);
            let sc = Kernels::for_level(SimdLevel::Scalar);

            // f32 dot on decode-score shapes: 64 rows of length 2048
            let (rows, len) = (64usize, 2048usize);
            let mut a = vec![0.0f32; rows * len];
            let mut b = vec![0.0f32; rows * len];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut b, 1.0);
            for r in 0..rows {
                let (x, y) = (&a[r * len..(r + 1) * len], &b[r * len..(r + 1) * len]);
                let (want, got) = ((sc.dot)(x, y), (kn.dot)(x, y));
                assert!(
                    (want - got).abs() <= 1e-5 * want.abs().max(1.0),
                    "f32 dot drifted at row {r}: {got} vs {want}"
                );
            }
            let time_dot = |k: &'static Kernels| {
                bench(10, 200, || {
                    let mut acc = 0.0f32;
                    for r in 0..rows {
                        acc += (k.dot)(&a[r * len..(r + 1) * len], &b[r * len..(r + 1) * len]);
                    }
                    black_box(acc);
                })
            };
            let s_simd = time_dot(kn);
            let s_scalar = time_dot(sc);
            println!(
                "dot f32  rows={rows} len={len}: {level} p50 {:>8.1} µs | scalar p50 {:>8.1} µs | speedup {:>5.2}x",
                s_simd.p50 * 1e6,
                s_scalar.p50 * 1e6,
                s_scalar.p50 / s_simd.p50
            );
            gate_cases.push(Json::obj(vec![
                ("jobs", Json::num(1.0)),
                ("n", Json::num(len as f64)),
                ("threads", Json::num(1.0)),
                // gated path = dispatched f32 dot; baseline = scalar table
                ("pool_p50_us", Json::num(s_simd.p50 * 1e6)),
                ("spawn_p50_us", Json::num(s_scalar.p50 * 1e6)),
                ("pool_calls_per_sec", Json::num(1.0 / s_simd.p50)),
                ("speedup", Json::num(s_scalar.p50 / s_simd.p50)),
            ]));

            // int8 dot on the quantized-tier shape — bitwise conformance
            let qa: Vec<i8> =
                (0..rows * len).map(|_| (rng.range(0, 255) as i32 - 127) as i8).collect();
            let qb: Vec<i8> =
                (0..rows * len).map(|_| (rng.range(0, 255) as i32 - 127) as i8).collect();
            for r in 0..rows {
                let (x, y) = (&qa[r * len..(r + 1) * len], &qb[r * len..(r + 1) * len]);
                assert_eq!((sc.dot_i8)(x, y), (kn.dot_i8)(x, y), "dot_i8 drifted at row {r}");
            }
            let time_dot_i8 = |k: &'static Kernels| {
                bench(10, 200, || {
                    let mut acc = 0i32;
                    for r in 0..rows {
                        let x = &qa[r * len..(r + 1) * len];
                        let y = &qb[r * len..(r + 1) * len];
                        acc = acc.wrapping_add((k.dot_i8)(x, y));
                    }
                    black_box(acc);
                })
            };
            let s_simd = time_dot_i8(kn);
            let s_scalar = time_dot_i8(sc);
            println!(
                "dot int8 rows={rows} len={len}: {level} p50 {:>8.1} µs | scalar p50 {:>8.1} µs | speedup {:>5.2}x",
                s_simd.p50 * 1e6,
                s_scalar.p50 * 1e6,
                s_scalar.p50 / s_simd.p50
            );
            gate_cases.push(Json::obj(vec![
                ("jobs", Json::num(2.0)),
                ("n", Json::num(len as f64)),
                ("threads", Json::num(1.0)),
                // gated path = dispatched int8 dot; baseline = scalar table
                ("pool_p50_us", Json::num(s_simd.p50 * 1e6)),
                ("spawn_p50_us", Json::num(s_scalar.p50 * 1e6)),
                ("pool_calls_per_sec", Json::num(1.0 / s_simd.p50)),
                ("speedup", Json::num(s_scalar.p50 / s_simd.p50)),
            ]));

            // end-to-end tiered job range at the dispatch level vs the
            // scalar table: two f32 + two int8 payloads, single worker so
            // the comparison is kernel-bound, tolerance-checked first
            let (jobs_n, n) = (4usize, 4096usize);
            let payloads: Vec<JobPayload> = (0..jobs_n)
                .map(|j| {
                    let mut k = vec![0.0f32; n * dh];
                    let mut v = vec![0.0f32; n * dh];
                    rng.fill_normal(&mut k, 1.0);
                    rng.fill_normal(&mut v, 1.0);
                    if j % 2 == 0 {
                        JobPayload::F32(k, v, n)
                    } else {
                        JobPayload::Int8 {
                            k: QuantSlab::from_f32(&k, dh, QUANT_BLOCK),
                            v: QuantSlab::from_f32(&v, dh, QUANT_BLOCK),
                        }
                    }
                })
                .collect();
            let mut q = vec![0.0f32; jobs_n * dh];
            rng.fill_normal(&mut q, 0.2);
            let (o_ref, lse_ref) = run_tiered_at_level(SimdLevel::Scalar, &payloads, &q, 1, dh);
            let (o, lse) = run_tiered_at_level(level, &payloads, &q, 1, dh);
            for (i, (x, y)) in o.iter().zip(o_ref.iter()).enumerate() {
                assert!((x - y).abs() <= 1e-4, "tiered output drifted at {i}: {x} vs {y}");
            }
            for (i, (x, y)) in lse.iter().zip(lse_ref.iter()).enumerate() {
                assert!((x - y).abs() <= 1e-4 * x.abs().max(1.0), "tiered lse drifted at {i}");
            }
            let s_simd = bench(3, 30, || {
                let _ = run_tiered_at_level(level, &payloads, &q, 1, dh);
            });
            let s_scalar = bench(3, 30, || {
                let _ = run_tiered_at_level(SimdLevel::Scalar, &payloads, &q, 1, dh);
            });
            println!(
                "tiered   jobs={jobs_n} n={n}: {level} p50 {:>8.1} µs | scalar p50 {:>8.1} µs | speedup {:>5.2}x",
                s_simd.p50 * 1e6,
                s_scalar.p50 * 1e6,
                s_scalar.p50 / s_simd.p50
            );
            gate_cases.push(Json::obj(vec![
                ("jobs", Json::num(jobs_n as f64)),
                ("n", Json::num(n as f64)),
                ("threads", Json::num(1.0)),
                // gated path = tiered step at the dispatch level; baseline
                // = the same step forced through the scalar table
                ("pool_p50_us", Json::num(s_simd.p50 * 1e6)),
                ("spawn_p50_us", Json::num(s_scalar.p50 * 1e6)),
                ("pool_calls_per_sec", Json::num(1.0 / s_simd.p50)),
                ("speedup", Json::num(s_scalar.p50 / s_simd.p50)),
            ]));
        }
    }
    println!();

    // ---- CI gate dump (BENCH_*.json; see tools/bench_gate.rs) ----
    if let Ok(path) = std::env::var("HGCA_BENCH_JSON") {
        let doc = Json::obj(vec![
            ("schema", Json::num(1.0)),
            ("bench", Json::str("hotpath_micro/pool_vs_spawn")),
            ("cases", Json::arr(gate_cases)),
        ]);
        std::fs::write(&path, doc.to_string()).expect("write HGCA_BENCH_JSON");
        println!("wrote bench gate json: {path}");
        // gate mode runs only the gated cases — the remaining sections are
        // exploratory and nothing in CI consumes their numbers
        return;
    }

    // ---- CPU sparse attention across job counts/sizes ----
    for (jobs_n, n) in [(4usize, 512usize), (16, 512), (16, 4096), (64, 1024)] {
        let kvs: Vec<(Vec<f32>, Vec<f32>)> = (0..jobs_n)
            .map(|_| {
                let mut k = vec![0.0f32; n * dh];
                let mut v = vec![0.0f32; n * dh];
                rng.fill_normal(&mut k, 1.0);
                rng.fill_normal(&mut v, 1.0);
                (k, v)
            })
            .collect();
        let jobs: Vec<HeadJob> = kvs.iter().map(|(k, v)| HeadJob { k, v, n }).collect();
        let mut q = vec![0.0f32; jobs_n * dh];
        rng.fill_normal(&mut q, 0.2);
        for threads in [1usize, 4] {
            let s = bench(3, 15, || {
                let _ = sparse_attention(&jobs, &q, 1, dh, threads, false);
            });
            let gb = (2.0 * (jobs_n * n * dh * 4) as f64) / s.p50 / 1e9;
            println!(
                "cpu_attn jobs={jobs_n:>3} n={n:>5} threads={threads}: p50 {:>9.3} ms  ({gb:>5.2} GB/s)",
                s.p50 * 1e3
            );
        }
    }

    // ---- LSE merge ----
    let rows = 128;
    let mut og = vec![0.5f32; rows * dh];
    let mut lg = vec![0.1f32; rows];
    let oc = vec![0.25f32; rows * dh];
    let lc = vec![0.3f32; rows];
    let s = bench(10, 200, || {
        merge_states(&mut og, &mut lg, &oc, &lc, dh);
    });
    println!("merge_states rows={rows}: p50 {:.1} µs", s.p50 * 1e6);

    // ---- MAW update ----
    {
        use hgca::kv::GpuLayerCache;
        let mut c = GpuLayerCache::new(32, 128, 32, 32, 0.3); // opt-ish layer
        let n = 1024;
        let k = vec![0.1f32; 32 * n * 128];
        let v = vec![0.1f32; 32 * n * 128];
        let pos: Vec<usize> = (0..n).collect();
        c.append(&k, &v, &pos);
        let a = vec![0.001f32; 32 * (1024 + 1)];
        let s = bench(5, 100, || {
            c.update_maw(&a, 1025, 1024, 0, 1);
        });
        println!("maw_update 32h x 1024: p50 {:.1} µs", s.p50 * 1e6);
    }

    // ---- PJRT call overhead (artifact exec round trip) ----
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if let Ok(rt) = hgca::runtime::PjrtRuntime::new(&dir) {
        let rt = Rc::new(rt);
        let mr = rt.load_model("tiny-small").unwrap();
        let exec = hgca::runtime::Executor::new(&mr);
        let tokens = [5i32];
        let positions = [0i32];
        let _ = exec.embed(1, 1, &tokens, &positions).unwrap();
        let s = bench(5, 50, || {
            let _ = exec.embed(1, 1, &tokens, &positions).unwrap();
        });
        println!("pjrt embed call (b1 n1): p50 {:.1} µs", s.p50 * 1e6);
        let st = mr.stats.borrow();
        println!(
            "pjrt split: exec {:.1} µs/call, upload {:.1} µs, download {:.1} µs",
            st.exec_secs * 1e6 / st.calls as f64,
            st.upload_secs * 1e6 / st.calls as f64,
            st.download_secs * 1e6 / st.calls as f64
        );
    }

    // ---- end-to-end decode step (tiny, b=1) ----
    if let Ok(rt) = hgca::runtime::PjrtRuntime::new(&dir) {
        use hgca::config::HgcaConfig;
        use hgca::engine::{Engine, Policy};
        let rt = Rc::new(rt);
        let mr = rt.load_model("tiny").unwrap();
        let mut engine = Engine::new(&mr, HgcaConfig::default(), Policy::Hgca { beta: 1.0 });
        let mut seq = engine.new_sequence(0, &vec![b'a'; 300]);
        engine.generate(&mut seq, 40).unwrap();
        let s = hgca::util::stats::summarize(&engine.metrics.tbt[engine.metrics.tbt.len() - 40..]);
        println!(
            "decode step e2e (tiny, ctx 300+): p50 {:.2} ms  ({:.1} tok/s)",
            s.p50 * 1e3,
            1.0 / s.p50
        );
    }
}
