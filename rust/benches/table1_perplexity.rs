//! Table 1 — perplexity of HGCA hybrid attention vs full attention over
//! β ∈ {0.25, 0.5, 0.75, 1.0} × GPU-KV-ratio ∈ {0.25, 0.5, 0.75}, on the
//! trained models + bundled corpus. REAL end-to-end numerics through the
//! PJRT + CPU-sparse stack (wall domain). Fast mode evaluates tiny-small
//! only; HGCA_BENCH_FULL=1 runs all three trained models.

use std::path::Path;
use std::rc::Rc;

use hgca::config::HgcaConfig;
use hgca::engine::{Engine, Policy};
use hgca::runtime::PjrtRuntime;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Rc::new(PjrtRuntime::new(&dir).expect("make artifacts first"));
    let text = hgca::util::corpus::ensure_corpus(&Path::new(env!("CARGO_MANIFEST_DIR")).join("data/corpus.txt")).unwrap();
    let full_mode = hgca::bench::full_mode();
    let models: &[&str] = if full_mode {
        &["tiny-small", "tiny", "tiny-large"]
    } else {
        &["tiny-small"]
    };
    let len = if full_mode { 512 } else { 224 };
    let text = &text[1000..1000 + len];
    let betas = [0.25f32, 0.5, 0.75, 1.0];
    let ratios = [0.25f64, 0.5, 0.75];

    println!("=== Table 1: perplexity, full attention vs HGCA (len {len}) ===");
    for model in models {
        let mr = rt.load_model(model).unwrap();
        mr.warn_if_synthetic();
        let mk_cfg = |window: usize| HgcaConfig {
            blk_size: 8,
            blk_num: (window / 8).max(1),
            ..Default::default()
        };
        let mut full = Engine::new(&mr, mk_cfg(32), Policy::FullOffload);
        let baseline = full.perplexity(text, 32).unwrap();
        println!("\nmodel {model}  baseline (full attention) PPL = {baseline:.4}");
        print!("{:>9}", "ratio\\β");
        for b in betas {
            print!("{b:>9.2}");
        }
        println!();
        for ratio in ratios {
            let window = ((((len as f64) * ratio) / 8.0).ceil() as usize).max(1) * 8;
            print!("{ratio:>9.2}");
            for beta in betas {
                let mut cfg = mk_cfg(window);
                cfg.beta = beta;
                let mut e = Engine::new(&mr, cfg, Policy::Hgca { beta });
                let ppl = e.perplexity(text, 32).unwrap();
                let mark = if ppl <= baseline { "*" } else { " " };
                print!("{:>8.3}{mark}", ppl);
            }
            println!();
        }
        println!("(* = matches or beats full attention, as Table 1 highlights)");
    }
    // ---- int8 CPU-KV tier: quality delta vs the f32 store ----
    // Informational (not CI-gated): the same HGCA config with the whole
    // CPU store int8-quantized (`--kv-tier int8`) vs the default f32
    // store. The oracle bound lives in tests/integration_quant.rs; this
    // shows the end-to-end perplexity cost of the tier on real numerics.
    println!("\n=== int8 CPU-KV tier vs f32 store (model {}, len {len}) ===", models[0]);
    {
        let mr = rt.load_model(models[0]).unwrap();
        let window = ((((len as f64) * 0.5) / 8.0).ceil() as usize).max(1) * 8;
        let mk = |tier: hgca::kv::TierMode| HgcaConfig {
            blk_size: 8,
            blk_num: (window / 8).max(1),
            kv_tier: tier,
            ..Default::default()
        };
        let mut f = Engine::new(&mr, mk(hgca::kv::TierMode::F32), Policy::Hgca { beta: 1.0 });
        let p_f32 = f.perplexity(text, 32).unwrap();
        let mut q = Engine::new(&mr, mk(hgca::kv::TierMode::Int8), Policy::Hgca { beta: 1.0 });
        let p_int8 = q.perplexity(text, 32).unwrap();
        println!(
            "HGCA β=1.0 ratio=0.5: f32-store PPL = {p_f32:.4} | int8-store PPL = {p_int8:.4} | delta {:+.4}",
            p_int8 - p_f32
        );
    }

    println!("\n[shape check] HGCA tracks the full-attention baseline within a few");
    println!("percent across the grid; the GPU-KV ratio has no systematic effect");
    println!("(the paper's Table 1 observation).");
}
