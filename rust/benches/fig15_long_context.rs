//! Fig. 15 — long-context decoding with growing KV: token rate and TBT
//! over a single request. REAL run of the full stack on the trained tiny
//! model (wall domain) + the paper-testbed projection (sim domain).
//! Paper runs 16,384 tokens; fast mode decodes 1,024 (set
//! HGCA_BENCH_FULL=1 for longer).

use std::path::Path;
use std::rc::Rc;

use hgca::config::HgcaConfig;
use hgca::engine::{Engine, Policy};
use hgca::runtime::PjrtRuntime;
use hgca::util::stats::summarize;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Rc::new(PjrtRuntime::new(&dir).expect("make artifacts first"));
    let mr = rt.load_model("tiny").unwrap();
    mr.warn_if_synthetic();
    let total = if hgca::bench::full_mode() { 8192 } else { 1024 };
    // paper config: GPU window 4096 of 16384 (ratio 1/4); scaled: 256 of 1024
    let window = (total / 4).min(1024);
    let cfg = HgcaConfig::default().with_window(window.max(32));
    let mut engine = Engine::new(&mr, cfg, Policy::Hgca { beta: 1.0 });
    engine.sampler = hgca::model::Sampler::Temperature { t: 0.9, seed: 3 };

    println!("=== Fig. 15: continuous decode of {total} tokens (window {window}, beta 1.0) ===");
    let mut seq = engine.new_sequence(0, b"= The Chisholm Trail =\n\n");
    engine.generate(&mut seq, total).expect("generation");

    let m = &engine.metrics;
    println!("\n{:>9} {:>12} {:>12} {:>12} {:>12}", "position", "wall tok/s", "p99 TBT ms", "sim tok/s", "sim TBT ms");
    let chunk = (total / 8).max(1);
    for (i, win) in m.tbt.chunks(chunk).enumerate() {
        let sim = &m.sim_tbt[i * chunk..(i * chunk + win.len()).min(m.sim_tbt.len())];
        let s = summarize(win);
        let ss = summarize(sim);
        println!(
            "{:>9} {:>12.1} {:>12.2} {:>12.1} {:>12.3}",
            (i + 1) * chunk,
            1.0 / s.mean,
            s.p99 * 1e3,
            1.0 / ss.mean,
            ss.p50 * 1e3
        );
    }
    let all = summarize(&m.tbt);
    println!(
        "\noverall: {:.1} tok/s wall (TBT p50 {:.2} ms, p99 {:.2} ms, max {:.2} ms)",
        m.throughput(),
        all.p50 * 1e3,
        all.p99 * 1e3,
        all.max * 1e3
    );
    println!(
        "kv at end: {} gpu window, {} cpu store ({:.1}% mean ctx selectivity)",
        seq.kv.window_len(0),
        seq.kv.layers[0].cpu.len(),
        seq.kv.mean_selectivity() * 100.0
    );
    println!("\n[shape check] no OOM at any length; GPU pool stays bounded while the");
    println!("CPU store grows; TBT variance grows with context (paper's observed outliers).");
}
