//! Fig. 10 — single-layer hybrid-attention speedup over GPU-attention-with-
//! KV-load, as a heatmap over (GPU-resident KV × CPU-resident KV) for
//! three OPT models and several batch sizes. Sim domain (paper testbed).

use hgca::config::model::simulated;
use hgca::engine::Policy;
use hgca::simulator::Testbed;

fn main() {
    let tb = Testbed::paper();
    let models = ["opt-6.7b", "opt-13b", "opt-30b"];
    let batches: &[usize] = if hgca::bench::full_mode() { &[1, 4, 16, 32] } else { &[1, 8] };
    let gpu_kvs = [256usize, 1024, 4096];
    let cpu_kvs = [1024usize, 4096, 16384, 65536];
    // paper's Fig. 10 micro-bench runs *dense* CPU attention over the
    // offloaded entries (sparsification is an orthogonal end-to-end win);
    // set HGCA_FIG10_SPARSE=1 to apply the β=1 measured selectivity.
    let sel = if std::env::var("HGCA_FIG10_SPARSE").as_deref() == Ok("1") { 0.2 } else { 1.0 };

    for model in models {
        let m = simulated(model).unwrap();
        println!("\n=== Fig. 10: hybrid speedup vs GPU+load — {model} (d_head {}) ===", m.d_head());
        for &b in batches {
            println!("batch {b}:  (rows: gpu-resident KV; cols: cpu-resident KV)");
            print!("{:>8}", "gpu\\cpu");
            for c in cpu_kvs {
                print!("{c:>9}");
            }
            println!();
            for &g in &gpu_kvs {
                print!("{g:>8}");
                for &c in &cpu_kvs {
                    let n_sel = (c as f64 * sel) as usize;
                    let (hybrid, _) = Policy::Hgca { beta: 1.0 }.sim_attention(&tb, &m, b, 1, g, c, n_sel);
                    let (offload, _) = Policy::FullOffload.sim_attention(&tb, &m, b, 1, g, c, 0);
                    print!("{:>8.2}x", offload / hybrid);
                }
                println!();
            }
        }
    }
    println!("\n[shape check] speedup grows with CPU-resident KV share and batch size");
    println!("(paper: warmest cells at bottom-right of each heatmap)");
}
