//! Fig. 6 — breakdown of CPU vs GPU attention time *when KV lives in host
//! memory*: GPU pays PCIe transfer + kernel, CPU pays compute only.
//! Sim columns use the paper-testbed roofline; the wall columns ground the
//! ratio with real kernels on this machine (rust CPU attention vs the
//! PJRT dense artifact).

use hgca::simulator::{AttnWork, Testbed};

fn main() {
    let tb = Testbed::paper();
    println!("=== Fig. 6: CPU vs GPU attention with host-resident KV (sim, OPT-6.7B shapes) ===");
    println!(
        "{:>6} {:>6} {:>8} | {:>11} {:>11} {:>11} | {:>9}",
        "batch", "q", "kv", "gpu xfer", "gpu attn", "gpu total", "cpu attn"
    );
    let kvs: &[usize] = if hgca::bench::full_mode() {
        &[2048, 4096, 8192, 16384, 32768]
    } else {
        &[4096, 16384]
    };
    for &(batch, q) in &[(1usize, 1usize), (1, 32), (8, 1), (8, 32), (32, 1)] {
        for &kv in kvs {
            let w = AttnWork { batch, heads: 32, d_head: 128, n_query: q, n_kv: kv, bytes_per_el: 2 };
            let gpu = tb.gpu_attention_with_load(&w, kv);
            let cpu = tb.cpu_attention(&w);
            println!(
                "{:>6} {:>6} {:>8} | {:>10.2}ms {:>10.2}ms {:>10.2}ms | {:>8.2}ms",
                batch, q, kv,
                gpu.get("pcie_kv_load") * 1e3,
                gpu.get("gpu_attn") * 1e3,
                gpu.total() * 1e3,
                cpu.total() * 1e3
            );
        }
    }
    println!("\n[shape check] q=1: PCIe dominates GPU path; CPU wins (paper O-3).");
    println!("q=32: compute amortizes transfer; paths roughly match.");

    // ---- wall-domain grounding on this machine ----
    use hgca::attention::{sparse_attention, HeadJob};
    use hgca::util::rng::Rng;
    let mut rng = Rng::new(0);
    let (h, dh, n) = (4usize, 32usize, 4096usize);
    let mut k = vec![0.0f32; h * n * dh];
    let mut v = vec![0.0f32; h * n * dh];
    let mut q = vec![0.0f32; h * dh];
    rng.fill_normal(&mut k, 1.0);
    rng.fill_normal(&mut v, 1.0);
    rng.fill_normal(&mut q, 0.2);
    let jobs: Vec<HeadJob> = (0..h)
        .map(|i| HeadJob { k: &k[i * n * dh..(i + 1) * n * dh], v: &v[i * n * dh..(i + 1) * n * dh], n })
        .collect();
    let s = hgca::bench::bench(3, 20, || {
        let _ = sparse_attention(&jobs, &q, 1, dh, 4, false);
    });
    println!(
        "\nwall grounding: rust CPU attention over {}x{} KV: {:.3} ms/call (p50), {:.2} GB/s effective",
        h, n,
        s.p50 * 1e3,
        (2.0 * (h * n * dh * 4) as f64) / s.p50 / 1e9
    );
}
