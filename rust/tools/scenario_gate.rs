//! CI scenario-replay gate: compares a fresh `hgca replay --json` report
//! against the checked-in per-scenario baseline (`SCENARIO_baseline.json`)
//! and fails on latency/shed drift.
//!
//! Replay metrics are **tick-based** (batcher scheduler clock), so unlike
//! the wall-clock bench they are machine-portable: the same `(scenario,
//! seed)` produces the same tick metrics on any runner. The baseline
//! therefore pins three kinds of key per scenario:
//!
//! * exact keys (`completed`, `shed_watermark`, …) — the current value
//!   must match the baseline value exactly;
//! * `<metric>_max` — the current `<metric>` must be ≤ the bound;
//! * `<metric>_min` — the current `<metric>` must be ≥ the bound.
//!
//! Bounds exist so a baseline can assert "this overload scenario sheds,
//! and p99 queue wait stays under N ticks" without pinning every digit of
//! an emergent quantity; exact keys pin what is structurally guaranteed.
//! Scenario drift mirrors `bench_gate`: a current scenario missing from
//! the baseline is an error (an ungated scenario is a silent hole); a
//! baseline scenario missing from the report errors unless flagged
//! `"additive": true` (tolerated with a warning, gated once produced).
//! `--check-digest` additionally compares `outcome_digest` when the
//! baseline pins one (off by default: digests cover generated token
//! bytes, which a model/config change legitimately moves).
//!
//! Usage:
//!   scenario_gate [--baseline SCENARIO_baseline.json] [--current SCENARIO_ci.json]
//!                 [--check-digest]
//!   scenario_gate --refresh [--slack-pct 25] [--baseline ...] [--current ...]
//!
//! `--current` accepts a comma-separated list of reports (e.g.
//! `SCENARIO_ci.json,SCENARIO_int8_ci.json` — one replay per engine
//! config); their scenario entries are concatenated and gated against the
//! one baseline.
//!
//! Refresh after an intentional scheduling change with:
//!   cargo run --release --bin hgca -- replay scenarios/*.scn --verify --json SCENARIO_ci.json
//!   cargo run --release --bin scenario_gate -- --refresh
//! `--refresh` rewrites every `_max`/`_min` bound in the baseline from the
//! report's observed values plus a slack factor (`--slack-pct`, default
//! 25): `_max` bounds become `ceil(observed × (1 + slack))`, `_min` floors
//! become `floor(observed × (1 − slack))` clamped at 0. Exact keys,
//! digests, and `additive` markers are never touched — refresh re-derives
//! the conservative envelope, it does not change what is pinned. Review
//! the diff before committing.
//!
//! Exit codes: 0 pass, 1 drift, 2 usage/io error.

use std::collections::BTreeMap;

use hgca::util::argparse::Args;
use hgca::util::json::Json;

/// One scenario entry: its name, the digest (if present), the additive
/// marker, and every numeric field as a flat key → value map.
struct Entry {
    name: String,
    digest: Option<String>,
    additive: bool,
    nums: BTreeMap<String, f64>,
}

fn load(path: &str) -> Result<Vec<Entry>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
    let scenarios = doc
        .get("scenarios")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| format!("{path}: missing 'scenarios' array"))?;
    let mut out = Vec::with_capacity(scenarios.len());
    for s in scenarios {
        let obj = s
            .as_obj()
            .ok_or_else(|| format!("{path}: scenario entry is not an object"))?;
        let name = s
            .get("name")
            .and_then(|n| n.as_str())
            .ok_or_else(|| format!("{path}: scenario entry missing 'name'"))?
            .to_string();
        let mut nums = BTreeMap::new();
        for (k, v) in obj {
            if let Some(n) = v.as_f64() {
                nums.insert(k.clone(), n);
            }
        }
        out.push(Entry {
            digest: s.get("outcome_digest").and_then(|d| d.as_str()).map(String::from),
            additive: s.get("additive").and_then(|a| a.as_bool()).unwrap_or(false),
            name,
            nums,
        });
    }
    Ok(out)
}

/// Load one or more reports: `--current` accepts a comma-separated list
/// of paths (the CI job replays the scenario suite once per engine
/// config — default and `--kv-tier int8` — into separate reports); the
/// scenario entries are concatenated in order. Replay suffixes tiered
/// runs' scenario names (`steady_decode_int8`), so entries from the two
/// reports never collide.
fn load_many(paths: &str) -> Result<Vec<Entry>, String> {
    let mut out = Vec::new();
    for p in paths.split(',').filter(|p| !p.is_empty()) {
        out.extend(load(p)?);
    }
    Ok(out)
}

/// Scenario-drift report (same contract as `bench_gate::drift`): current
/// scenarios with no baseline entry are errors; baseline scenarios the
/// report lacks error unless additive (returned as warnings).
fn drift(baseline: &[Entry], current: &[Entry]) -> (Vec<String>, Vec<String>) {
    let mut errors = Vec::new();
    let mut warnings = Vec::new();
    for cur in current {
        if !baseline.iter().any(|b| b.name == cur.name) {
            errors.push(format!("current scenario '{}' missing from baseline", cur.name));
        }
    }
    for base in baseline {
        if !current.iter().any(|c| c.name == base.name) {
            let msg = format!("baseline scenario '{}' not present in the report", base.name);
            if base.additive {
                warnings.push(format!("{msg} (additive: tolerated, not gated)"));
            } else {
                errors.push(msg);
            }
        }
    }
    (errors, warnings)
}

/// Compare one scenario's current values against its baseline entry.
/// Returns human-readable violations (empty = pass).
fn check(base: &Entry, cur: &Entry, check_digest: bool) -> Vec<String> {
    let mut bad = Vec::new();
    for (key, &want) in &base.nums {
        // `seed` and `nodes` identify the run, not a gated metric — but
        // when the baseline pins them, a mismatch means the report was
        // produced with the wrong invocation, which IS an error; plain
        // exact comparison covers that too.
        if let Some(metric) = key.strip_suffix("_max") {
            match cur.nums.get(metric) {
                Some(&got) if got <= want => {}
                Some(&got) => bad.push(format!("{metric} = {got} exceeds bound {want}")),
                None => bad.push(format!("report lacks '{metric}' (bounded by '{key}')")),
            }
        } else if let Some(metric) = key.strip_suffix("_min") {
            match cur.nums.get(metric) {
                Some(&got) if got >= want => {}
                Some(&got) => bad.push(format!("{metric} = {got} below floor {want}")),
                None => bad.push(format!("report lacks '{metric}' (bounded by '{key}')")),
            }
        } else {
            match cur.nums.get(key) {
                Some(&got) if got == want => {}
                Some(&got) => bad.push(format!("{key} = {got}, baseline pins {want}")),
                None => bad.push(format!("report lacks pinned key '{key}'")),
            }
        }
    }
    if check_digest {
        if let (Some(want), Some(got)) = (&base.digest, &cur.digest) {
            if want != got {
                bad.push(format!("outcome_digest {got} != baseline {want}"));
            }
        }
    }
    bad
}

/// `--refresh` bound math: `_max` bounds get head-room above the observed
/// value, `_min` floors get foot-room below it, both integral (ceil/floor
/// keep the bound on the conservative side) and never negative.
fn refreshed_bound(key: &str, observed: f64, slack: f64) -> f64 {
    if key.ends_with("_max") {
        (observed * (1.0 + slack)).ceil()
    } else {
        (observed * (1.0 - slack)).floor().max(0.0)
    }
}

/// Two-space pretty printer: the checked-in baseline is hand-edited and
/// diffed, so `--refresh` must not flatten it to one line. (Key order is
/// normalized alphabetically — `Json::Obj` is a BTreeMap.)
fn pretty(v: &Json, indent: usize, out: &mut String) {
    let pad = "  ".repeat(indent + 1);
    match v {
        Json::Arr(a) if !a.is_empty() => {
            out.push_str("[\n");
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&pad);
                pretty(item, indent + 1, out);
            }
            out.push('\n');
            out.push_str(&"  ".repeat(indent));
            out.push(']');
        }
        Json::Obj(m) if !m.is_empty() => {
            out.push_str("{\n");
            for (i, (k, val)) in m.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&pad);
                out.push_str(&Json::str(k.clone()).to_string());
                out.push_str(": ");
                pretty(val, indent + 1, out);
            }
            out.push('\n');
            out.push_str(&"  ".repeat(indent));
            out.push('}');
        }
        other => out.push_str(&other.to_string()),
    }
}

/// Rewrite the baseline's `_max`/`_min` bounds from the current report
/// (see the module docs). Bounds whose scenario or metric the report
/// lacks are kept as-is, with a note.
fn refresh_baseline(baseline_path: &str, current_path: &str, slack: f64) -> Result<(), String> {
    let text = std::fs::read_to_string(baseline_path).map_err(|e| format!("{baseline_path}: {e}"))?;
    let mut doc = Json::parse(&text).map_err(|e| format!("{baseline_path}: {e}"))?;
    let current = load_many(current_path)?;
    println!("scenario gate: refreshing {baseline_path} from {current_path}");
    let scenarios = match &mut doc {
        Json::Obj(top) => match top.get_mut("scenarios") {
            Some(Json::Arr(s)) => s,
            _ => return Err(format!("{baseline_path}: missing 'scenarios' array")),
        },
        _ => return Err(format!("{baseline_path}: not a json object")),
    };
    let mut changed = 0usize;
    for s in scenarios.iter_mut() {
        let Json::Obj(obj) = s else { continue };
        let Some(name) = obj.get("name").and_then(|n| n.as_str()).map(String::from) else {
            continue;
        };
        let Some(cur) = current.iter().find(|c| c.name == name) else {
            println!("  {name}: not in the report, bounds kept");
            continue;
        };
        let keys: Vec<String> = obj
            .keys()
            .filter(|k| k.ends_with("_max") || k.ends_with("_min"))
            .cloned()
            .collect();
        for key in keys {
            let metric = key.strip_suffix("_max").or_else(|| key.strip_suffix("_min"));
            let metric = metric.expect("filtered on suffix above");
            match cur.nums.get(metric) {
                Some(&got) => {
                    let new = refreshed_bound(&key, got, slack);
                    let old = obj.get(&key).and_then(|v| v.as_f64());
                    if old != Some(new) {
                        changed += 1;
                        println!(
                            "  {name}.{key}: {} -> {new} (observed {got})",
                            old.map(|v| v.to_string()).unwrap_or_else(|| "?".into()),
                        );
                    }
                    obj.insert(key.clone(), Json::num(new));
                }
                None => println!("  {name}.{key}: report lacks '{metric}', bound kept"),
            }
        }
    }
    let mut out = String::new();
    pretty(&doc, 0, &mut out);
    out.push('\n');
    std::fs::write(baseline_path, out).map_err(|e| format!("{baseline_path}: {e}"))?;
    println!("refreshed {changed} bounds (slack {:.0}%) — review the diff before committing", slack * 100.0);
    Ok(())
}

fn run() -> Result<bool, String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["check-digest", "refresh"]).map_err(|e| e.to_string())?;
    let baseline_path = args.get_or("baseline", "SCENARIO_baseline.json");
    let current_path = args.get_or("current", "SCENARIO_ci.json");
    let check_digest = args.flag("check-digest");
    if args.flag("refresh") {
        let slack_pct = args.f64("slack-pct", 25.0).map_err(|e| e.to_string())?;
        if !(0.0..100.0).contains(&slack_pct) {
            return Err(format!("--slack-pct must be in [0, 100), got {slack_pct}"));
        }
        refresh_baseline(baseline_path, current_path, slack_pct / 100.0)?;
        return Ok(true);
    }

    let baseline = load(baseline_path)?;
    let current = load_many(current_path)?;
    println!("scenario gate: {current_path} vs {baseline_path}");

    let (errors, warnings) = drift(&baseline, &current);
    for w in &warnings {
        println!("  note: {w}");
    }
    if !errors.is_empty() {
        return Err(format!(
            "scenario drift — run `cargo run --release --bin hgca -- replay scenarios/*.scn \
             --verify --json {current_path}` and fold the new scenario into {baseline_path}:\n  {}",
            errors.join("\n  ")
        ));
    }

    let mut pass = true;
    let mut compared = 0;
    for cur in &current {
        let base = baseline
            .iter()
            .find(|b| b.name == cur.name)
            .expect("drift checked above");
        compared += 1;
        let bad = check(base, cur, check_digest);
        println!(
            "  {}: {} keys gated {}",
            cur.name,
            base.nums.len(),
            if bad.is_empty() { "ok" } else { "DRIFTED" },
        );
        for b in &bad {
            println!("      {b}");
        }
        pass &= bad.is_empty();
    }
    if compared == 0 {
        return Err("no comparable scenarios between baseline and report".into());
    }
    Ok(pass)
}

fn main() {
    match run() {
        Ok(true) => println!("scenario gate: PASS"),
        Ok(false) => {
            eprintln!("scenario gate: FAIL — replay metrics drifted from the baseline");
            std::process::exit(1);
        }
        Err(e) => {
            eprintln!("scenario gate: error: {e}");
            std::process::exit(2);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(name: &str, pairs: &[(&str, f64)], additive: bool) -> Entry {
        Entry {
            name: name.into(),
            digest: None,
            additive,
            nums: pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        }
    }

    #[test]
    fn exact_keys_pin_values() {
        let base = entry("s", &[("completed", 18.0)], false);
        assert!(check(&base, &entry("s", &[("completed", 18.0)], false), false).is_empty());
        let bad = check(&base, &entry("s", &[("completed", 17.0)], false), false);
        assert_eq!(bad.len(), 1);
        assert!(bad[0].contains("baseline pins"));
    }

    #[test]
    fn max_and_min_bounds() {
        let base = entry("s", &[("e2e_p99_ticks_max", 100.0), ("completed_min", 4.0)], false);
        let ok = entry("s", &[("e2e_p99_ticks", 60.0), ("completed", 9.0)], false);
        assert!(check(&base, &ok, false).is_empty());
        let slow = entry("s", &[("e2e_p99_ticks", 150.0), ("completed", 9.0)], false);
        assert!(check(&base, &slow, false)[0].contains("exceeds bound"));
        let starved = entry("s", &[("e2e_p99_ticks", 60.0), ("completed", 2.0)], false);
        assert!(check(&base, &starved, false)[0].contains("below floor"));
    }

    #[test]
    fn missing_metric_behind_a_bound_is_caught() {
        let base = entry("s", &[("shed_queue_max", 5.0)], false);
        let bad = check(&base, &entry("s", &[], false), false);
        assert!(bad[0].contains("report lacks"));
    }

    #[test]
    fn digest_only_gates_when_asked() {
        let mut base = entry("s", &[], false);
        base.digest = Some("aa".into());
        let mut cur = entry("s", &[], false);
        cur.digest = Some("bb".into());
        assert!(check(&base, &cur, false).is_empty());
        assert_eq!(check(&base, &cur, true).len(), 1);
    }

    #[test]
    fn refresh_slack_math() {
        // _max: head-room above the observed value, rounded up
        assert_eq!(refreshed_bound("ticks_max", 100.0, 0.25), 125.0);
        assert_eq!(refreshed_bound("ticks_max", 10.0, 0.25), 13.0); // ceil(12.5)
        assert_eq!(refreshed_bound("ticks_max", 0.0, 0.25), 0.0);
        // _min: foot-room below, rounded down, clamped at zero
        assert_eq!(refreshed_bound("completed_min", 100.0, 0.25), 75.0);
        assert_eq!(refreshed_bound("completed_min", 10.0, 0.25), 7.0); // floor(7.5)
        assert_eq!(refreshed_bound("completed_min", 0.0, 0.25), 0.0);
        assert_eq!(refreshed_bound("completed_min", 3.0, 0.9), 0.0); // floor(0.3)
        // zero slack pins the observed value exactly on both sides
        assert_eq!(refreshed_bound("x_max", 42.0, 0.0), 42.0);
        assert_eq!(refreshed_bound("x_min", 42.0, 0.0), 42.0);
    }

    #[test]
    fn pretty_output_reparses_identically() {
        let doc = Json::parse(
            r#"{"schema":1,"note":"n","scenarios":[{"name":"s","ticks_max":10,"empty":[],"nested":{"a":1.5}}]}"#,
        )
        .unwrap();
        let mut out = String::new();
        pretty(&doc, 0, &mut out);
        assert_eq!(Json::parse(&out).unwrap(), doc);
        assert!(out.contains("\n  \"scenarios\""), "objects are indented:\n{out}");
    }

    #[test]
    fn load_many_concatenates_comma_separated_reports() {
        let dir = std::env::temp_dir();
        let a = dir.join("scenario_gate_load_many_a.json");
        let b = dir.join("scenario_gate_load_many_b.json");
        std::fs::write(&a, r#"{"scenarios":[{"name":"steady","completed":18}]}"#).unwrap();
        std::fs::write(&b, r#"{"scenarios":[{"name":"steady_int8","completed":18}]}"#).unwrap();
        let joined = format!("{},{}", a.display(), b.display());
        let entries = load_many(&joined).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].name, "steady");
        assert_eq!(entries[1].name, "steady_int8");
        assert_eq!(entries[1].nums["completed"], 18.0);
        // a single path still works, and a missing file is a load error
        assert_eq!(load_many(&a.display().to_string()).unwrap().len(), 1);
        assert!(load_many("definitely_missing.json").is_err());
        let _ = std::fs::remove_file(&a);
        let _ = std::fs::remove_file(&b);
    }

    #[test]
    fn drift_mirrors_bench_gate_semantics() {
        let (errors, _) = drift(&[], &[entry("new", &[], false)]);
        assert!(errors[0].contains("missing from baseline"));
        let (errors, warnings) = drift(&[entry("old", &[], false)], &[]);
        assert_eq!((errors.len(), warnings.len()), (1, 0));
        let (errors, warnings) = drift(&[entry("old", &[], true)], &[]);
        assert_eq!((errors.len(), warnings.len()), (0, 1));
        assert!(warnings[0].contains("additive"));
    }
}
