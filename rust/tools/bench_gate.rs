//! CI bench-regression gate: compares a fresh `hotpath_micro` pool-vs-spawn
//! dump (`BENCH_ci.json`, emitted with `HGCA_BENCH_JSON=...`) against the
//! checked-in baseline (`BENCH_baseline.json`) and fails when the pool
//! path regresses beyond the tolerance.
//!
//! The gated metric is the pool/spawn **speedup ratio** per case: both
//! sides run on the same machine in the same process, so the ratio is the
//! machine-portable measure of pool-path throughput (an absolute-µs gate
//! would mostly measure the CI runner, not the code). `--absolute` adds a
//! raw `pool_calls_per_sec` comparison for same-machine baselines.
//!
//! Usage:
//!   bench_gate [--baseline BENCH_baseline.json] [--current BENCH_ci.json]
//!              [--max-regress-pct 25] [--absolute]
//!
//! Refresh the baseline after an intentional perf change with (absolute
//! path — cargo runs the bench with cwd set to the package root, not the
//! workspace root):
//!   HGCA_BENCH_JSON=$PWD/BENCH_baseline.json cargo bench --bench hotpath_micro
//!
//! Exit codes: 0 pass, 1 regression, 2 usage/io error.

use hgca::util::argparse::Args;
use hgca::util::json::Json;

struct Case {
    jobs: usize,
    n: usize,
    threads: usize,
    pool_calls_per_sec: f64,
    speedup: f64,
    /// Baseline-only marker for newly added bench cases: an `"additive":
    /// true` baseline case that the current dump does not produce is a
    /// warning, not case drift — so a baseline entry can land with (or
    /// ahead of) the bench change without breaking runs of an older bench
    /// binary. When the case IS produced, it is gated normally.
    additive: bool,
}

fn load(path: &str) -> Result<Vec<Case>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
    let cases = doc
        .get("cases")
        .and_then(|c| c.as_arr())
        .ok_or_else(|| format!("{path}: missing 'cases' array"))?;
    let mut out = Vec::with_capacity(cases.len());
    for c in cases {
        let f = |k: &str| -> Result<f64, String> {
            c.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| format!("{path}: case missing '{k}'"))
        };
        out.push(Case {
            jobs: f("jobs")? as usize,
            n: f("n")? as usize,
            threads: f("threads")? as usize,
            pool_calls_per_sec: f("pool_calls_per_sec")?,
            speedup: f("speedup")?,
            additive: c
                .get("additive")
                .and_then(|v| v.as_bool())
                .unwrap_or(false),
        });
    }
    Ok(out)
}

/// Case-drift report: current cases with no baseline entry are always an
/// error (an ungated case is a silent hole); baseline cases the bench did
/// not produce are an error *unless* flagged additive (returned separately
/// as warnings).
fn drift(baseline: &[Case], current: &[Case]) -> (Vec<String>, Vec<String>) {
    let mut errors = Vec::new();
    let mut warnings = Vec::new();
    for cur in current {
        if !baseline
            .iter()
            .any(|b| b.jobs == cur.jobs && b.n == cur.n && b.threads == cur.threads)
        {
            errors.push(format!(
                "current case jobs={} n={} t={} missing from baseline",
                cur.jobs, cur.n, cur.threads
            ));
        }
    }
    for base in baseline {
        if !current
            .iter()
            .any(|c| c.jobs == base.jobs && c.n == base.n && c.threads == base.threads)
        {
            let msg = format!(
                "baseline case jobs={} n={} t={} not produced by the bench",
                base.jobs, base.n, base.threads
            );
            if base.additive {
                warnings.push(format!("{msg} (additive: tolerated, not gated)"));
            } else {
                errors.push(msg);
            }
        }
    }
    (errors, warnings)
}

fn run() -> Result<bool, String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["absolute"]).map_err(|e| e.to_string())?;
    let baseline_path = args.get_or("baseline", "BENCH_baseline.json");
    let current_path = args.get_or("current", "BENCH_ci.json");
    let pct = args
        .f64("max-regress-pct", 25.0)
        .map_err(|e| e.to_string())?;
    let absolute = args.flag("absolute");
    let floor = 1.0 - pct / 100.0;

    let baseline = load(baseline_path)?;
    let current = load(current_path)?;
    println!("bench gate: {current_path} vs {baseline_path} (tolerance {pct}%)");

    // case drift is an error, not a silent skip: a renamed/added bench case
    // without a baseline refresh would otherwise leave it ungated, and a
    // baseline-only case would never be checked again. The one sanctioned
    // exception: a baseline case flagged `"additive": true` that the
    // current dump lacks (a newly added case run against an older bench
    // binary) — tolerated with a warning, gated as soon as it appears.
    let (errors, warnings) = drift(&baseline, &current);
    for w in &warnings {
        println!("  note: {w}");
    }
    if !errors.is_empty() {
        return Err(format!(
            "case drift — refresh the baseline (HGCA_BENCH_JSON=$PWD/{baseline_path} cargo bench \
             --bench hotpath_micro, from the workspace root):\n  {}",
            errors.join("\n  ")
        ));
    }

    let mut pass = true;
    let mut compared = 0;
    for cur in &current {
        let base = baseline
            .iter()
            .find(|b| b.jobs == cur.jobs && b.n == cur.n && b.threads == cur.threads)
            .expect("drift checked above");
        compared += 1;
        let rel = cur.speedup / base.speedup;
        let ok = rel >= floor;
        println!(
            "  jobs={:>3} n={:>5} t={}: speedup {:.2}x vs baseline {:.2}x ({:+.1}%) {}",
            cur.jobs,
            cur.n,
            cur.threads,
            cur.speedup,
            base.speedup,
            (rel - 1.0) * 100.0,
            if ok { "ok" } else { "REGRESSED" },
        );
        pass &= ok;
        if absolute {
            let arel = cur.pool_calls_per_sec / base.pool_calls_per_sec;
            let aok = arel >= floor;
            println!(
                "      pool {:.0} calls/s vs baseline {:.0} ({:+.1}%) {}",
                cur.pool_calls_per_sec,
                base.pool_calls_per_sec,
                (arel - 1.0) * 100.0,
                if aok { "ok" } else { "REGRESSED" },
            );
            pass &= aok;
        }
    }
    if compared == 0 {
        return Err("no comparable cases between baseline and current".into());
    }
    Ok(pass)
}

fn main() {
    match run() {
        Ok(true) => println!("bench gate: PASS"),
        Ok(false) => {
            eprintln!("bench gate: FAIL — pool-path throughput regressed past tolerance");
            std::process::exit(1);
        }
        Err(e) => {
            eprintln!("bench gate: error: {e}");
            std::process::exit(2);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn case(jobs: usize, additive: bool) -> Case {
        Case {
            jobs,
            n: 512,
            threads: 4,
            pool_calls_per_sec: 1000.0,
            speedup: 2.0,
            additive,
        }
    }

    #[test]
    fn matching_case_sets_have_no_drift() {
        let (errors, warnings) = drift(&[case(4, false)], &[case(4, false)]);
        assert!(errors.is_empty());
        assert!(warnings.is_empty());
    }

    #[test]
    fn current_only_case_is_always_an_error() {
        // an ungated case is a silent hole, additive or not
        let (errors, _) = drift(&[], &[case(4, false)]);
        assert_eq!(errors.len(), 1);
        assert!(errors[0].contains("missing from baseline"));
    }

    #[test]
    fn baseline_only_case_errors_unless_additive() {
        let (errors, warnings) = drift(&[case(4, false)], &[]);
        assert_eq!(errors.len(), 1);
        assert!(warnings.is_empty());
        let (errors, warnings) = drift(&[case(4, true)], &[]);
        assert!(errors.is_empty(), "additive baseline cases are tolerated");
        assert_eq!(warnings.len(), 1);
        assert!(warnings[0].contains("additive"));
    }

    #[test]
    fn additive_case_is_gated_once_produced() {
        // once the bench emits it, an additive case compares like any other
        let (errors, warnings) =
            drift(&[case(4, true), case(8, false)], &[case(4, false), case(8, false)]);
        assert!(errors.is_empty());
        assert!(warnings.is_empty());
    }
}
