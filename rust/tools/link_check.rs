//! Offline markdown link checker (CI `lint` job — see
//! .github/workflows/ci.yml).
//!
//! Walks README.md plus every `docs/*.md` file, extracts markdown links
//! `[text](target)`, and fails when a **repo-relative** target does not
//! exist on disk. External schemes (`http://`, `https://`, `mailto:`) and
//! pure in-page anchors (`#…`) are skipped — the gate is offline-safe by
//! construction: it never touches the network, it only keeps the growing
//! doc set's internal cross-links from rotting.
//!
//! Usage: `cargo run --bin link_check` (paths resolve from the crate
//! manifest, so the working directory does not matter).

use std::path::{Path, PathBuf};

/// One extracted link: the raw target plus its 1-based line number.
#[derive(Debug, PartialEq, Eq)]
struct Link {
    target: String,
    line: usize,
}

/// Extract `[text](target)` markdown links. Good enough for this repo's
/// docs: it keys on the `](` token, which never appears in our prose or
/// inline code outside a real link.
fn extract_links(text: &str) -> Vec<Link> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let mut rest = line;
        while let Some(pos) = rest.find("](") {
            let after = &rest[pos + 2..];
            let Some(end) = after.find(')') else {
                break;
            };
            out.push(Link {
                target: after[..end].to_string(),
                line: i + 1,
            });
            rest = &after[end + 1..];
        }
    }
    out
}

/// Whether a target is checkable on disk: repo-relative path, not an
/// external scheme or a pure in-page anchor.
fn is_local(target: &str) -> bool {
    !(target.is_empty()
        || target.starts_with('#')
        || target.starts_with("http://")
        || target.starts_with("https://")
        || target.starts_with("mailto:"))
}

/// Strip an in-page fragment (`file.md#section` → `file.md`).
fn strip_fragment(target: &str) -> &str {
    target.split('#').next().unwrap_or(target)
}

/// Check every local link of one file; returns human-readable failures.
fn check_file(md: &Path, repo_root: &Path) -> Vec<String> {
    let text = match std::fs::read_to_string(md) {
        Ok(t) => t,
        Err(e) => return vec![format!("{}: unreadable: {e}", md.display())],
    };
    let dir = md.parent().unwrap_or(repo_root);
    let mut failures = Vec::new();
    for link in extract_links(&text) {
        if !is_local(&link.target) {
            continue;
        }
        let path = strip_fragment(&link.target);
        if path.is_empty() {
            continue;
        }
        let resolved = dir.join(path);
        if !resolved.exists() {
            failures.push(format!(
                "{}:{}: broken link `{}` → {}",
                md.display(),
                link.line,
                link.target,
                resolved.display()
            ));
        }
    }
    failures
}

/// README.md + every markdown file under docs/.
fn doc_set(repo_root: &Path) -> Vec<PathBuf> {
    let mut files = vec![repo_root.join("README.md")];
    if let Ok(entries) = std::fs::read_dir(repo_root.join("docs")) {
        let mut docs: Vec<PathBuf> = entries
            .flatten()
            .map(|e| e.path())
            .filter(|p| p.extension().is_some_and(|e| e == "md"))
            .collect();
        docs.sort();
        files.extend(docs);
    }
    files
}

fn main() {
    // rust/ is the manifest dir; the repo root (README.md, docs/) is its
    // parent
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let repo_root = manifest.parent().unwrap_or(manifest);
    let mut failures = Vec::new();
    let mut checked = 0usize;
    for md in doc_set(repo_root) {
        if !md.exists() {
            failures.push(format!("{}: missing", md.display()));
            continue;
        }
        checked += 1;
        failures.extend(check_file(&md, repo_root));
    }
    if failures.is_empty() {
        println!("link_check: {checked} files OK");
    } else {
        for f in &failures {
            eprintln!("link_check: {f}");
        }
        eprintln!("link_check: {} broken link(s)", failures.len());
        std::process::exit(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extracts_links_with_lines() {
        let md = "# title\nsee [a](docs/A.md) and [b](B.md#frag)\n[c](https://x)\n";
        let links = extract_links(md);
        assert_eq!(
            links,
            vec![
                Link {
                    target: "docs/A.md".into(),
                    line: 2
                },
                Link {
                    target: "B.md#frag".into(),
                    line: 2
                },
                Link {
                    target: "https://x".into(),
                    line: 3
                },
            ]
        );
    }

    #[test]
    fn locality_filter() {
        assert!(is_local("docs/API.md"));
        assert!(is_local("../ROADMAP.md"));
        assert!(!is_local("https://example.com/x.md"));
        assert!(!is_local("http://example.com"));
        assert!(!is_local("mailto:a@b.c"));
        assert!(!is_local("#section"));
        assert!(!is_local(""));
    }

    #[test]
    fn fragments_are_stripped() {
        assert_eq!(strip_fragment("API.md#metrics"), "API.md");
        assert_eq!(strip_fragment("API.md"), "API.md");
    }

    #[test]
    fn repo_doc_set_has_no_broken_links() {
        // the real gate, runnable as a plain unit test too
        let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
        let root = manifest.parent().unwrap();
        let mut failures = Vec::new();
        for md in doc_set(root) {
            assert!(md.exists(), "{} missing", md.display());
            failures.extend(check_file(&md, root));
        }
        assert!(failures.is_empty(), "broken links:\n{}", failures.join("\n"));
    }

    #[test]
    fn broken_link_is_reported() {
        let dir = std::env::temp_dir().join("hgca_link_check_test");
        let _ = std::fs::create_dir_all(&dir);
        let md = dir.join("page.md");
        std::fs::write(&md, "[gone](no/such/file.md) [ok](page.md)\n").unwrap();
        let failures = check_file(&md, &dir);
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("no/such/file.md"));
    }
}
