//! GPU-side per-layer KV cache (Algorithm 1, GPU half).
//!
//! A pre-allocated window of W = blk_num × blk_size slots per (layer,
//! sequence), holding the most recent KV entries in chronological order,
//! with a per-(head, slot) moving-average attention weight (MAW). When an
//! append would exceed capacity, whole blocks are evicted from the oldest
//! end and handed to the CPU store together with their MAW (line 13).
//!
//! On real hardware this buffer lives in GPU memory and eviction is a
//! PCIe DMA; here the buffer is the exact tensor the PJRT artifact receives
//! as `k_win`/`v_win`, and the simulator charges transfer time.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use super::block::KvBlock;

/// Pool of GPU KV blocks with an optional hard capacity.
///
/// Every [`crate::engine::Sequence`] leases its per-layer window blocks
/// (`n_layers × blk_num`) from its engine's pool at creation and returns
/// them when it drops — including early retirement (cancel / deadline /
/// disconnect), which is what makes reclamation *observable*: the
/// free-count is restored and `reclaimed_blocks` advances the moment a
/// row is retired mid-batch.
///
/// A pool built with [`GpuBlockPool::with_capacity`] is the admission
/// currency of the scheduler (docs/SCHEDULING.md): [`GpuBlockPool::try_acquire`]
/// fails once the capacity is exhausted, and the continuous batcher defers
/// admission until enough blocks are reclaimed. A default pool
/// ([`GpuBlockPool::new`]) is unbounded and purely accounting, which is
/// what standalone engines (`hgca generate`, `ppl`, the benches) use. The
/// backing buffers live in [`GpuLayerCache`]; on real hardware the pool
/// would own the device allocator free list.
///
/// Acquire / fail / release under a capacity-1 pool:
///
/// ```
/// use std::sync::Arc;
/// use hgca::kv::GpuBlockPool;
///
/// let pool = Arc::new(GpuBlockPool::with_capacity(1));
/// let lease = pool.try_acquire(1).expect("1 of 1 blocks free");
/// assert!(pool.try_acquire(1).is_none(), "pool exhausted: acquisition fails");
/// assert_eq!(pool.free_blocks(), Some(0));
/// drop(lease); // RAII release — retiring a sequence returns its blocks
/// assert_eq!(pool.free_blocks(), Some(1));
/// assert!(pool.try_acquire(1).is_some(), "reclaimed blocks admit again");
/// assert!(pool.try_acquire(2).is_none(), "larger than capacity: can never fit");
/// ```
#[derive(Debug, Default)]
pub struct GpuBlockPool {
    capacity: Option<usize>,
    in_use: AtomicUsize,
    acquired: AtomicU64,
    reclaimed: AtomicU64,
}

impl GpuBlockPool {
    /// An empty **unbounded** pool (no blocks outstanding, acquisition
    /// never fails — pure accounting).
    pub fn new() -> GpuBlockPool {
        GpuBlockPool::default()
    }

    /// An empty pool with a hard capacity of `blocks`:
    /// [`GpuBlockPool::try_acquire`] fails once `in_use + requested`
    /// would exceed it.
    pub fn with_capacity(blocks: usize) -> GpuBlockPool {
        GpuBlockPool {
            capacity: Some(blocks),
            ..GpuBlockPool::default()
        }
    }

    /// The hard capacity, or `None` for an unbounded (accounting-only)
    /// pool.
    pub fn capacity(&self) -> Option<usize> {
        self.capacity
    }

    /// Blocks currently free under the capacity (`None` when unbounded).
    /// Saturates at 0 if force-[`acquire`](GpuBlockPool::acquire)s
    /// oversubscribed the pool.
    pub fn free_blocks(&self) -> Option<usize> {
        self.capacity.map(|c| c.saturating_sub(self.in_use()))
    }

    /// Lease `blocks` blocks from the pool **unconditionally**, bypassing
    /// any capacity bound. The lease returns them when dropped (RAII —
    /// retiring a sequence is the release). Capacity-gated callers (the
    /// batcher's admission path) use [`GpuBlockPool::try_acquire`]; this
    /// force path exists for unbounded pools and for cloning leases
    /// (`Clone` cannot fail, so it must bypass the bound).
    pub fn acquire(self: &Arc<Self>, blocks: usize) -> BlockLease {
        self.in_use.fetch_add(blocks, Ordering::AcqRel);
        self.acquired.fetch_add(blocks as u64, Ordering::AcqRel);
        BlockLease {
            pool: Arc::clone(self),
            blocks,
        }
    }

    /// Lease `blocks` blocks if they fit under the capacity; `None` when
    /// they do not (the caller defers — nothing is acquired). On an
    /// unbounded pool this never fails. The check-and-reserve is a single
    /// atomic compare-exchange, so concurrent acquirers cannot
    /// collectively overshoot the capacity.
    pub fn try_acquire(self: &Arc<Self>, blocks: usize) -> Option<BlockLease> {
        let Some(cap) = self.capacity else {
            return Some(self.acquire(blocks));
        };
        let mut cur = self.in_use.load(Ordering::Acquire);
        loop {
            if cur + blocks > cap {
                return None;
            }
            match self.in_use.compare_exchange(
                cur,
                cur + blocks,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => break,
                Err(observed) => cur = observed,
            }
        }
        self.acquired.fetch_add(blocks as u64, Ordering::AcqRel);
        Some(BlockLease {
            pool: Arc::clone(self),
            blocks,
        })
    }

    /// Blocks currently leased out.
    pub fn in_use(&self) -> usize {
        self.in_use.load(Ordering::Acquire)
    }

    /// Cumulative blocks ever leased.
    pub fn acquired_blocks(&self) -> u64 {
        self.acquired.load(Ordering::Acquire)
    }

    /// Cumulative blocks returned to the pool (the `kv_blocks_reclaimed`
    /// metric).
    pub fn reclaimed_blocks(&self) -> u64 {
        self.reclaimed.load(Ordering::Acquire)
    }
}

/// An RAII lease of GPU KV blocks; dropping it returns the blocks to the
/// pool and advances the reclaim counter.
#[derive(Debug)]
pub struct BlockLease {
    pool: Arc<GpuBlockPool>,
    blocks: usize,
}

impl BlockLease {
    /// Blocks this lease holds.
    pub fn blocks(&self) -> usize {
        self.blocks
    }
}

impl Clone for BlockLease {
    /// Cloning a lease acquires a fresh lease of the same size (the clone
    /// owns its own share — keeps `KvManager: Clone` honest). The clone is
    /// a *force* acquire: it may oversubscribe a bounded pool, because
    /// `Clone` cannot fail. Scheduler admission never clones leases; only
    /// explicit sequence copies (tests, analysis) do.
    fn clone(&self) -> BlockLease {
        self.pool.acquire(self.blocks)
    }
}

impl Drop for BlockLease {
    fn drop(&mut self) {
        self.pool.in_use.fetch_sub(self.blocks, Ordering::AcqRel);
        self.pool
            .reclaimed
            .fetch_add(self.blocks as u64, Ordering::AcqRel);
    }
}

/// The per-(layer, sequence) GPU window: recent KV entries + MAW tracking.
#[derive(Debug, Clone)]
pub struct GpuLayerCache {
    /// Attention heads.
    pub heads: usize,
    /// Head dimension.
    pub d_head: usize,
    /// Entries per eviction block.
    pub blk_size: usize,
    /// Blocks in the window (W = blk_num × blk_size).
    pub blk_num: usize,
    /// k/v laid out [H][W][dh] row-major — matches the artifact input.
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// maw[h * W + slot]
    pub maw: Vec<f32>,
    /// global token position per slot
    pub pos: Vec<usize>,
    /// number of valid slots (prefix of the buffer)
    pub len: usize,
    /// moving-average factor α
    pub alpha: f32,
}

impl GpuLayerCache {
    /// An empty window of `blk_num × blk_size` slots with MAW factor `alpha`.
    pub fn new(heads: usize, d_head: usize, blk_size: usize, blk_num: usize, alpha: f32) -> Self {
        let w = blk_size * blk_num;
        GpuLayerCache {
            heads,
            d_head,
            blk_size,
            blk_num,
            k: vec![0.0; heads * w * d_head],
            v: vec![0.0; heads * w * d_head],
            maw: vec![0.0; heads * w],
            pos: vec![0; w],
            len: 0,
            alpha,
        }
    }

    /// Window capacity W.
    pub fn window(&self) -> usize {
        self.blk_size * self.blk_num
    }

    /// Key vector of one (head, slot).
    pub fn k_at(&self, h: usize, slot: usize) -> &[f32] {
        let w = self.window();
        let o = (h * w + slot) * self.d_head;
        &self.k[o..o + self.d_head]
    }

    /// Value vector of one (head, slot).
    pub fn v_at(&self, h: usize, slot: usize) -> &[f32] {
        let w = self.window();
        let o = (h * w + slot) * self.d_head;
        &self.v[o..o + self.d_head]
    }

    /// Blocks that must be evicted before appending `n_new` entries
    /// (Algorithm 1 lines 10–11, block-aligned ceiling).
    pub fn blocks_to_evict(&self, n_new: usize) -> usize {
        let cap = self.window();
        let need = self.len + n_new;
        if need <= cap {
            0
        } else {
            (need - cap).div_ceil(self.blk_size)
        }
    }

    /// Evict the `n_blocks` oldest blocks; remaining entries shift to the
    /// buffer head (prefix-valid invariant, see module docs).
    pub fn evict(&mut self, n_blocks: usize) -> KvBlock {
        let n = n_blocks * self.blk_size;
        assert!(n <= self.len, "evicting {n} of {} entries", self.len);
        let w = self.window();
        let dh = self.d_head;
        let mut out = KvBlock::new(self.heads, dh, n);
        for h in 0..self.heads {
            let base = h * w * dh;
            out.k[h * n * dh..(h + 1) * n * dh]
                .copy_from_slice(&self.k[base..base + n * dh]);
            out.v[h * n * dh..(h + 1) * n * dh]
                .copy_from_slice(&self.v[base..base + n * dh]);
            out.maw[h * n..(h + 1) * n]
                .copy_from_slice(&self.maw[h * w..h * w + n]);
            // shift the survivors down
            self.k.copy_within(base + n * dh..base + self.len * dh, base);
            self.v.copy_within(base + n * dh..base + self.len * dh, base);
            self.maw.copy_within(h * w + n..h * w + self.len, h * w);
        }
        out.pos.copy_from_slice(&self.pos[..n]);
        self.pos.copy_within(n..self.len, 0);
        self.len -= n;
        out
    }

    /// Append `n_new` entries; `k_new`/`v_new` are [H][n_new][dh]
    /// head-major (as returned by the attifact's k_new output). Caller must
    /// have evicted first; panics on overflow.
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32], positions: &[usize]) {
        let n = positions.len();
        let w = self.window();
        let dh = self.d_head;
        assert!(self.len + n <= w, "append overflows window");
        assert_eq!(k_new.len(), self.heads * n * dh);
        for h in 0..self.heads {
            let dst = (h * w + self.len) * dh;
            self.k[dst..dst + n * dh].copy_from_slice(&k_new[h * n * dh..(h + 1) * n * dh]);
            self.v[dst..dst + n * dh].copy_from_slice(&v_new[h * n * dh..(h + 1) * n * dh]);
            // fresh entries start with zero MAW; first update seeds them
            for t in 0..n {
                self.maw[h * w + self.len + t] = 0.0;
            }
        }
        self.pos[self.len..self.len + n].copy_from_slice(positions);
        self.len += n;
    }

    /// MAW update (Algorithm 1 line 8): a_sum[h * s_total + slot] is the
    /// per-slot attention mass from the last attention call, where the
    /// first `valid_prior` slots correspond to buffer slots 0..valid_prior
    /// *before* the new tokens were appended, and the last n_new slots of
    /// a_sum correspond to the newly appended entries. `n_queries`
    /// normalizes chunked updates to a per-query average.
    pub fn update_maw(&mut self, a_sum: &[f32], s_total: usize, valid_prior: usize, n_new: usize, n_queries: usize) {
        let w = self.window();
        let inv_q = 1.0 / n_queries as f32;
        debug_assert_eq!(valid_prior + n_new, self.len);
        for h in 0..self.heads {
            let arow = &a_sum[h * s_total..(h + 1) * s_total];
            // existing slots: exponential moving average
            for slot in 0..valid_prior {
                let a = arow[slot] * inv_q;
                let m = &mut self.maw[h * w + slot];
                *m = (1.0 - self.alpha) * *m + self.alpha * a;
            }
            // new slots (tail of a_sum): seed with first observation
            for t in 0..n_new {
                let a = arow[s_total - n_new + t] * inv_q;
                self.maw[h * w + valid_prior + t] = a;
            }
        }
    }

    /// Resident bytes (k + v + maw; the paper's peak-GPU-KV metric).
    pub fn size_bytes(&self) -> usize {
        (self.k.len() + self.v.len() + self.maw.len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_pool_accounts_acquire_and_reclaim() {
        let pool = Arc::new(GpuBlockPool::new());
        let a = pool.acquire(8);
        let b = pool.acquire(4);
        assert_eq!(pool.in_use(), 12);
        assert_eq!(pool.acquired_blocks(), 12);
        assert_eq!(pool.reclaimed_blocks(), 0);
        drop(a);
        assert_eq!(pool.in_use(), 4);
        assert_eq!(pool.reclaimed_blocks(), 8);
        drop(b);
        assert_eq!(pool.in_use(), 0);
        assert_eq!(pool.reclaimed_blocks(), 12);
    }

    #[test]
    fn bounded_pool_gates_acquisition() {
        let pool = Arc::new(GpuBlockPool::with_capacity(8));
        assert_eq!(pool.capacity(), Some(8));
        assert_eq!(pool.free_blocks(), Some(8));
        let a = pool.try_acquire(5).expect("5 of 8 fits");
        assert_eq!(pool.free_blocks(), Some(3));
        assert!(pool.try_acquire(4).is_none(), "4 > 3 free must fail");
        assert_eq!(pool.in_use(), 5, "failed acquire reserves nothing");
        let b = pool.try_acquire(3).expect("exactly the remaining blocks");
        assert_eq!(pool.free_blocks(), Some(0));
        drop(a);
        assert_eq!(pool.free_blocks(), Some(5));
        assert!(pool.try_acquire(5).is_some());
        drop(b);
    }

    #[test]
    fn unbounded_pool_never_fails() {
        let pool = Arc::new(GpuBlockPool::new());
        assert_eq!(pool.capacity(), None);
        assert_eq!(pool.free_blocks(), None);
        let a = pool.try_acquire(1_000_000).expect("unbounded");
        assert_eq!(pool.in_use(), 1_000_000);
        drop(a);
    }

    #[test]
    fn force_acquire_bypasses_capacity() {
        let pool = Arc::new(GpuBlockPool::with_capacity(2));
        let a = pool.acquire(5); // documented escape hatch (lease cloning)
        assert_eq!(pool.in_use(), 5);
        assert_eq!(pool.free_blocks(), Some(0), "free saturates at zero");
        assert!(pool.try_acquire(1).is_none());
        drop(a);
        assert_eq!(pool.free_blocks(), Some(2));
    }

    #[test]
    fn lease_clone_owns_its_share() {
        let pool = Arc::new(GpuBlockPool::new());
        let a = pool.acquire(3);
        let b = a.clone();
        assert_eq!(b.blocks(), 3);
        assert_eq!(pool.in_use(), 6);
        drop(a);
        assert_eq!(pool.in_use(), 3);
        drop(b);
        assert_eq!(pool.in_use(), 0);
        assert_eq!(pool.reclaimed_blocks(), 6);
    }

    fn cache() -> GpuLayerCache {
        GpuLayerCache::new(2, 4, 2, 3, 0.5) // H=2, dh=4, W=6
    }

    fn fill(c: &mut GpuLayerCache, n: usize, start_pos: usize) {
        let dh = c.d_head;
        let mut k = vec![0.0; c.heads * n * dh];
        let v = vec![0.5; c.heads * n * dh];
        for h in 0..c.heads {
            for t in 0..n {
                for j in 0..dh {
                    k[(h * n + t) * dh + j] = (start_pos + t) as f32 + h as f32 * 100.0;
                }
            }
        }
        let pos: Vec<usize> = (start_pos..start_pos + n).collect();
        c.append(&k, &v, &pos);
    }

    #[test]
    fn append_and_layout() {
        let mut c = cache();
        fill(&mut c, 3, 0);
        assert_eq!(c.len, 3);
        assert_eq!(c.k_at(0, 2)[0], 2.0);
        assert_eq!(c.k_at(1, 2)[0], 102.0);
        assert_eq!(c.pos[..3], [0, 1, 2]);
    }

    #[test]
    fn evict_takes_oldest_and_shifts() {
        let mut c = cache();
        fill(&mut c, 6, 0);
        assert_eq!(c.blocks_to_evict(1), 1);
        let blk = c.evict(1);
        assert_eq!(blk.len, 2);
        assert_eq!(blk.pos, vec![0, 1]);
        assert_eq!(blk.k_at(1, 1)[0], 101.0);
        assert_eq!(c.len, 4);
        assert_eq!(c.k_at(0, 0)[0], 2.0); // shifted
        assert_eq!(c.pos[..4], [2, 3, 4, 5]);
    }

    #[test]
    fn blocks_to_evict_ceiling() {
        let mut c = cache();
        fill(&mut c, 5, 0);
        assert_eq!(c.blocks_to_evict(1), 0); // 5+1 = 6 fits
        assert_eq!(c.blocks_to_evict(2), 1); // 7 > 6 → 1 block
        assert_eq!(c.blocks_to_evict(4), 2); // 9 > 6 → ceil(3/2)=2
    }

    #[test]
    fn maw_ema_and_seed() {
        let mut c = cache();
        fill(&mut c, 2, 0);
        // first update: 2 prior... actually both are new (seed)
        let s = 3; // pretend attention saw 3 slots: 2 window (none valid prior) — craft:
        // do a simpler scenario: entries appended, then update with all as new
        let a: Vec<f32> = vec![0.1, 0.3, 0.0, 0.2, 0.4, 0.0]; // [H=2][s=3]
        c.update_maw(&a, 3, 0, 2, 1);
        // new slots read from tail of a_sum rows: row0 tail = [0.3, 0.0]
        assert!((c.maw[0] - 0.3).abs() < 1e-6);
        assert!((c.maw[1] - 0.0).abs() < 1e-6);
        // second update: both slots now prior; EMA with alpha=.5
        let a2: Vec<f32> = vec![0.4, 0.2, 0.8, 0.6, 0.0, 0.0];
        c.update_maw(&a2[..], 3, 2, 0, 1);
        assert!((c.maw[0] - (0.5 * 0.3 + 0.5 * 0.4)).abs() < 1e-6);
    }

    #[test]
    fn chunk_update_normalizes_by_queries() {
        let mut c = cache();
        fill(&mut c, 2, 0);
        let a: Vec<f32> = vec![0.0, 2.0, 0.0, 4.0]; // [2 heads][2 slots], 4 queries
        c.update_maw(&a, 2, 0, 2, 4);
        assert!((c.maw[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn append_overflow_panics() {
        let mut c = cache();
        fill(&mut c, 6, 0);
        fill(&mut c, 1, 6);
    }

    #[test]
    fn multi_block_evict() {
        let mut c = cache();
        fill(&mut c, 6, 10);
        let blk = c.evict(2);
        assert_eq!(blk.len, 4);
        assert_eq!(blk.pos, vec![10, 11, 12, 13]);
        assert_eq!(c.len, 2);
        assert_eq!(c.pos[..2], [14, 15]);
    }
}
