//! GPU-side per-layer KV cache (Algorithm 1, GPU half).
//!
//! A pre-allocated window of W = blk_num × blk_size slots per (layer,
//! sequence), holding the most recent KV entries in chronological order,
//! with a per-(head, slot) moving-average attention weight (MAW). When an
//! append would exceed capacity, whole blocks are evicted from the oldest
//! end and handed to the CPU store together with their MAW (line 13).
//!
//! On real hardware this buffer lives in GPU memory and eviction is a
//! PCIe DMA; here the buffer is the exact tensor the PJRT artifact receives
//! as `k_win`/`v_win`, and the simulator charges transfer time.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::topology::NodeId;

use super::block::KvBlock;
use super::cow::CowVec;

/// Pool of GPU KV blocks with optional **per-NUMA-node** hard budgets.
///
/// Every [`crate::engine::Sequence`] leases its per-layer window blocks
/// (`n_layers × blk_num`) from its engine's pool at creation and returns
/// them when it drops — including early retirement (cancel / deadline /
/// disconnect), which is what makes reclamation *observable*: the
/// free-count is restored and `reclaimed_blocks` advances the moment a
/// row is retired mid-batch.
///
/// A pool built with [`GpuBlockPool::with_capacity`] (one budget) or
/// [`GpuBlockPool::with_node_budgets`] (one budget per topology node) is
/// the admission currency of the scheduler (docs/SCHEDULING.md):
/// [`GpuBlockPool::try_acquire_on`] fails once its node's budget is
/// exhausted, and the continuous batcher defers admission until enough
/// blocks are reclaimed — placement picks the least-loaded node that can
/// hold the lease ([`GpuBlockPool::pick_node`], deterministic tie-break by
/// node id). A default pool ([`GpuBlockPool::new`]) is unbounded and
/// purely accounting (one implicit node), which is what standalone
/// engines (`hgca generate`, `ppl`, the benches) use. A single-budget pool
/// behaves exactly like the pre-NUMA capacity pool. The backing buffers
/// live in [`GpuLayerCache`]; on real hardware each budget would own one
/// NUMA node's share of the device allocator free list.
///
/// Acquire / fail / release under a capacity-1 pool:
///
/// ```
/// use std::sync::Arc;
/// use hgca::kv::GpuBlockPool;
///
/// let pool = Arc::new(GpuBlockPool::with_capacity(1));
/// let lease = pool.try_acquire(1).expect("1 of 1 blocks free");
/// assert!(pool.try_acquire(1).is_none(), "pool exhausted: acquisition fails");
/// assert_eq!(pool.free_blocks(), Some(0));
/// drop(lease); // RAII release — retiring a sequence returns its blocks
/// assert_eq!(pool.free_blocks(), Some(1));
/// assert!(pool.try_acquire(1).is_some(), "reclaimed blocks admit again");
/// assert!(pool.try_acquire(2).is_none(), "larger than capacity: can never fit");
/// ```
///
/// Placement across two node budgets:
///
/// ```
/// use std::sync::Arc;
/// use hgca::kv::GpuBlockPool;
///
/// let pool = Arc::new(GpuBlockPool::with_node_budgets(vec![4, 4]));
/// assert_eq!(pool.pick_node(4), Some(0), "equal free → lowest node id");
/// let a = pool.try_acquire_on(0, 4).expect("node 0 fits");
/// assert_eq!(pool.pick_node(4), Some(1), "node 0 full → node 1");
/// assert_eq!(pool.pick_node(5), None, "no node can hold 5 — defer");
/// assert_eq!(a.node(), 0);
/// drop(a);
/// assert_eq!(pool.free_blocks_on(0), Some(4));
/// ```
#[derive(Debug)]
pub struct GpuBlockPool {
    /// Per-node hard budgets; empty = unbounded single-domain pool.
    budgets: Vec<usize>,
    /// Per-node blocks leased (always ≥ 1 entry; unbounded pools use one).
    in_use: Vec<AtomicUsize>,
    acquired: AtomicU64,
    reclaimed: AtomicU64,
}

impl Default for GpuBlockPool {
    fn default() -> Self {
        GpuBlockPool::new()
    }
}

impl GpuBlockPool {
    /// An empty **unbounded** pool (no blocks outstanding, acquisition
    /// never fails — pure accounting, one implicit node).
    pub fn new() -> GpuBlockPool {
        GpuBlockPool {
            budgets: Vec::new(),
            in_use: vec![AtomicUsize::new(0)],
            acquired: AtomicU64::new(0),
            reclaimed: AtomicU64::new(0),
        }
    }

    /// An empty single-node pool with a hard capacity of `blocks`:
    /// [`GpuBlockPool::try_acquire`] fails once `in_use + requested`
    /// would exceed it. Identical to `with_node_budgets(vec![blocks])`.
    pub fn with_capacity(blocks: usize) -> GpuBlockPool {
        GpuBlockPool::with_node_budgets(vec![blocks])
    }

    /// An empty pool whose capacity is split into one hard budget per
    /// NUMA node: node `i` owns `budgets[i]` blocks and leases placed on
    /// it never spill into another node's budget. Panics on an empty
    /// budget list (an unbounded pool is [`GpuBlockPool::new`]).
    pub fn with_node_budgets(budgets: Vec<usize>) -> GpuBlockPool {
        assert!(!budgets.is_empty(), "a bounded pool needs ≥ 1 node budget");
        let in_use = budgets.iter().map(|_| AtomicUsize::new(0)).collect();
        GpuBlockPool {
            budgets,
            in_use,
            acquired: AtomicU64::new(0),
            reclaimed: AtomicU64::new(0),
        }
    }

    /// Memory domains this pool is split into (1 for unbounded and
    /// single-capacity pools).
    pub fn nodes(&self) -> usize {
        self.in_use.len()
    }

    /// The total hard capacity (sum of node budgets), or `None` for an
    /// unbounded (accounting-only) pool.
    pub fn capacity(&self) -> Option<usize> {
        (!self.budgets.is_empty()).then(|| self.budgets.iter().sum())
    }

    /// Node `node`'s hard budget (`None` when unbounded or out of range).
    pub fn capacity_on(&self, node: NodeId) -> Option<usize> {
        self.budgets.get(node).copied()
    }

    /// The largest single-node budget — the biggest lease any request can
    /// ever hold, since a lease never spans nodes. This (not the total
    /// capacity) is what a never-fits check must key on. `None` when
    /// unbounded.
    pub fn max_node_capacity(&self) -> Option<usize> {
        self.budgets.iter().copied().max()
    }

    /// Blocks currently free across all budgets (`None` when unbounded).
    /// Saturates at 0 if force-[`acquire`](GpuBlockPool::acquire)s
    /// oversubscribed the pool.
    pub fn free_blocks(&self) -> Option<usize> {
        self.capacity().map(|c| c.saturating_sub(self.in_use()))
    }

    /// Blocks currently free under node `node`'s budget (`None` when
    /// unbounded or out of range). Saturates at 0 under force-acquires.
    pub fn free_blocks_on(&self, node: NodeId) -> Option<usize> {
        self.budgets
            .get(node)
            .map(|&c| c.saturating_sub(self.in_use_on(node)))
    }

    /// The node a new lease of `blocks` should draw from: the node with
    /// the **most free blocks** that can hold the whole lease, ties broken
    /// by the lowest node id (deterministic — the conformance suite pins
    /// this). `None` when no node currently fits (the caller defers).
    /// Unbounded pools always place on node 0.
    pub fn pick_node(&self, blocks: usize) -> Option<NodeId> {
        if self.budgets.is_empty() {
            return Some(0);
        }
        let mut best: Option<(usize, NodeId)> = None;
        for node in 0..self.budgets.len() {
            let free = self.free_blocks_on(node).unwrap_or(0);
            let improves = match best {
                None => true,
                // strict '>' keeps the lowest node id on equal free counts
                Some((best_free, _)) => free > best_free,
            };
            if free >= blocks && improves {
                best = Some((free, node));
            }
        }
        best.map(|(_, node)| node)
    }

    /// Lease `blocks` blocks from node `node` **unconditionally**,
    /// bypassing the budget. The lease returns them when dropped (RAII —
    /// retiring a sequence is the release). Capacity-gated callers (the
    /// batcher's admission path) use [`GpuBlockPool::try_acquire_on`];
    /// this force path exists for unbounded pools and for cloning leases
    /// (`Clone` cannot fail, so it must bypass the bound).
    pub fn acquire_on(self: &Arc<Self>, node: NodeId, blocks: usize) -> BlockLease {
        let node = node % self.nodes();
        self.in_use[node].fetch_add(blocks, Ordering::AcqRel);
        self.acquired.fetch_add(blocks as u64, Ordering::AcqRel);
        BlockLease {
            pool: Arc::clone(self),
            blocks,
            node,
        }
    }

    /// [`GpuBlockPool::acquire_on`] node 0 — the pre-NUMA force path
    /// (unbounded standalone engines, lease cloning).
    pub fn acquire(self: &Arc<Self>, blocks: usize) -> BlockLease {
        self.acquire_on(0, blocks)
    }

    /// Lease `blocks` blocks from node `node`'s budget if they fit; `None`
    /// when they do not (the caller defers — nothing is acquired) or the
    /// node does not exist. On an unbounded pool this never fails (the
    /// single implicit node absorbs everything). The check-and-reserve is
    /// a single atomic compare-exchange per node, so concurrent acquirers
    /// cannot collectively overshoot a budget.
    pub fn try_acquire_on(self: &Arc<Self>, node: NodeId, blocks: usize) -> Option<BlockLease> {
        if self.budgets.is_empty() {
            return Some(self.acquire_on(node, blocks));
        }
        let &cap = self.budgets.get(node)?;
        let slot = &self.in_use[node];
        let mut cur = slot.load(Ordering::Acquire);
        loop {
            if cur + blocks > cap {
                return None;
            }
            match slot.compare_exchange(cur, cur + blocks, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => break,
                Err(observed) => cur = observed,
            }
        }
        self.acquired.fetch_add(blocks as u64, Ordering::AcqRel);
        Some(BlockLease {
            pool: Arc::clone(self),
            blocks,
            node,
        })
    }

    /// Placement-resolving acquire: lease `blocks` from the least-loaded
    /// node that fits them ([`GpuBlockPool::pick_node`]); `None` when no
    /// node currently can. Retries if a concurrent acquirer races the
    /// chosen node away.
    pub fn try_acquire(self: &Arc<Self>, blocks: usize) -> Option<BlockLease> {
        loop {
            let node = self.pick_node(blocks)?;
            if let Some(lease) = self.try_acquire_on(node, blocks) {
                return Some(lease);
            }
        }
    }

    /// Blocks currently leased out across all nodes.
    pub fn in_use(&self) -> usize {
        self.in_use.iter().map(|n| n.load(Ordering::Acquire)).sum()
    }

    /// Blocks currently leased from node `node` (0 when out of range).
    pub fn in_use_on(&self, node: NodeId) -> usize {
        self.in_use.get(node).map_or(0, |n| n.load(Ordering::Acquire))
    }

    /// Cumulative blocks ever leased.
    pub fn acquired_blocks(&self) -> u64 {
        self.acquired.load(Ordering::Acquire)
    }

    /// Cumulative blocks returned to the pool (the `kv_blocks_reclaimed`
    /// metric).
    pub fn reclaimed_blocks(&self) -> u64 {
        self.reclaimed.load(Ordering::Acquire)
    }
}

/// An RAII lease of GPU KV blocks; dropping it returns the blocks to the
/// node budget it was drawn from and advances the reclaim counter.
#[derive(Debug)]
pub struct BlockLease {
    pool: Arc<GpuBlockPool>,
    blocks: usize,
    node: NodeId,
}

impl BlockLease {
    /// Blocks this lease holds.
    pub fn blocks(&self) -> usize {
        self.blocks
    }

    /// The NUMA node whose budget this lease draws from (0 on unbounded
    /// and single-capacity pools).
    pub fn node(&self) -> NodeId {
        self.node
    }
}

impl Clone for BlockLease {
    /// Cloning a lease acquires a fresh lease of the same size **on the
    /// same node** (the clone owns its own share — keeps
    /// `KvManager: Clone` honest). The clone is a *force* acquire: it may
    /// oversubscribe a bounded budget, because `Clone` cannot fail.
    /// Scheduler admission never clones leases; only explicit sequence
    /// copies (tests, analysis) do.
    fn clone(&self) -> BlockLease {
        self.pool.acquire_on(self.node, self.blocks)
    }
}

impl Drop for BlockLease {
    fn drop(&mut self) {
        self.pool.in_use[self.node].fetch_sub(self.blocks, Ordering::AcqRel);
        self.pool
            .reclaimed
            .fetch_add(self.blocks as u64, Ordering::AcqRel);
    }
}

/// The per-(layer, sequence) GPU window: recent KV entries + MAW tracking.
///
/// The `k`/`v`/`pos` buffers are [`CowVec`]s: a prefix-cache snapshot and
/// its adopters share one physical window until a sequence's own
/// append/evict diverges it (copy-on-write against the snapshot — the
/// "shared window blocks" half of the radix cache). `maw` is EMA-updated
/// on every step, so it stays a plain `Vec`.
#[derive(Debug, Clone)]
pub struct GpuLayerCache {
    /// Attention heads.
    pub heads: usize,
    /// Head dimension.
    pub d_head: usize,
    /// Entries per eviction block.
    pub blk_size: usize,
    /// Blocks in the window (W = blk_num × blk_size).
    pub blk_num: usize,
    /// k/v laid out [H][W][dh] row-major — matches the artifact input.
    pub k: CowVec<f32>,
    pub v: CowVec<f32>,
    /// maw[h * W + slot]
    pub maw: Vec<f32>,
    /// global token position per slot
    pub pos: CowVec<usize>,
    /// number of valid slots (prefix of the buffer)
    pub len: usize,
    /// moving-average factor α
    pub alpha: f32,
}

impl GpuLayerCache {
    /// An empty window of `blk_num × blk_size` slots with MAW factor `alpha`.
    pub fn new(heads: usize, d_head: usize, blk_size: usize, blk_num: usize, alpha: f32) -> Self {
        let w = blk_size * blk_num;
        GpuLayerCache {
            heads,
            d_head,
            blk_size,
            blk_num,
            k: vec![0.0; heads * w * d_head].into(),
            v: vec![0.0; heads * w * d_head].into(),
            maw: vec![0.0; heads * w],
            pos: vec![0; w].into(),
            len: 0,
            alpha,
        }
    }

    /// Window capacity W.
    pub fn window(&self) -> usize {
        self.blk_size * self.blk_num
    }

    /// Key vector of one (head, slot).
    pub fn k_at(&self, h: usize, slot: usize) -> &[f32] {
        let w = self.window();
        let o = (h * w + slot) * self.d_head;
        &self.k[o..o + self.d_head]
    }

    /// Value vector of one (head, slot).
    pub fn v_at(&self, h: usize, slot: usize) -> &[f32] {
        let w = self.window();
        let o = (h * w + slot) * self.d_head;
        &self.v[o..o + self.d_head]
    }

    /// Blocks that must be evicted before appending `n_new` entries
    /// (Algorithm 1 lines 10–11, block-aligned ceiling).
    pub fn blocks_to_evict(&self, n_new: usize) -> usize {
        let cap = self.window();
        let need = self.len + n_new;
        if need <= cap {
            0
        } else {
            (need - cap).div_ceil(self.blk_size)
        }
    }

    /// Evict the `n_blocks` oldest blocks; remaining entries shift to the
    /// buffer head (prefix-valid invariant, see module docs).
    pub fn evict(&mut self, n_blocks: usize) -> KvBlock {
        let n = n_blocks * self.blk_size;
        assert!(n <= self.len, "evicting {n} of {} entries", self.len);
        let w = self.window();
        let dh = self.d_head;
        let mut out = KvBlock::new(self.heads, dh, n);
        for h in 0..self.heads {
            let base = h * w * dh;
            out.k[h * n * dh..(h + 1) * n * dh]
                .copy_from_slice(&self.k[base..base + n * dh]);
            out.v[h * n * dh..(h + 1) * n * dh]
                .copy_from_slice(&self.v[base..base + n * dh]);
            out.maw[h * n..(h + 1) * n]
                .copy_from_slice(&self.maw[h * w..h * w + n]);
            // shift the survivors down
            let len = self.len;
            self.k.make_mut().copy_within(base + n * dh..base + len * dh, base);
            self.v.make_mut().copy_within(base + n * dh..base + len * dh, base);
            self.maw.copy_within(h * w + n..h * w + self.len, h * w);
        }
        out.pos.copy_from_slice(&self.pos[..n]);
        let len = self.len;
        self.pos.make_mut().copy_within(n..len, 0);
        self.len -= n;
        out
    }

    /// Append `n_new` entries; `k_new`/`v_new` are [H][n_new][dh]
    /// head-major (as returned by the attifact's k_new output). Caller must
    /// have evicted first; panics on overflow.
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32], positions: &[usize]) {
        let n = positions.len();
        let w = self.window();
        let dh = self.d_head;
        assert!(self.len + n <= w, "append overflows window");
        assert_eq!(k_new.len(), self.heads * n * dh);
        for h in 0..self.heads {
            let dst = (h * w + self.len) * dh;
            self.k.make_mut()[dst..dst + n * dh]
                .copy_from_slice(&k_new[h * n * dh..(h + 1) * n * dh]);
            self.v.make_mut()[dst..dst + n * dh]
                .copy_from_slice(&v_new[h * n * dh..(h + 1) * n * dh]);
            // fresh entries start with zero MAW; first update seeds them
            for t in 0..n {
                self.maw[h * w + self.len + t] = 0.0;
            }
        }
        let len = self.len;
        self.pos.make_mut()[len..len + n].copy_from_slice(positions);
        self.len += n;
    }

    /// MAW update (Algorithm 1 line 8): a_sum[h * s_total + slot] is the
    /// per-slot attention mass from the last attention call, where the
    /// first `valid_prior` slots correspond to buffer slots 0..valid_prior
    /// *before* the new tokens were appended, and the last n_new slots of
    /// a_sum correspond to the newly appended entries. `n_queries`
    /// normalizes chunked updates to a per-query average.
    pub fn update_maw(&mut self, a_sum: &[f32], s_total: usize, valid_prior: usize, n_new: usize, n_queries: usize) {
        let w = self.window();
        let inv_q = 1.0 / n_queries as f32;
        debug_assert_eq!(valid_prior + n_new, self.len);
        for h in 0..self.heads {
            let arow = &a_sum[h * s_total..(h + 1) * s_total];
            // existing slots: exponential moving average
            for slot in 0..valid_prior {
                let a = arow[slot] * inv_q;
                let m = &mut self.maw[h * w + slot];
                *m = (1.0 - self.alpha) * *m + self.alpha * a;
            }
            // new slots (tail of a_sum): seed with first observation
            for t in 0..n_new {
                let a = arow[s_total - n_new + t] * inv_q;
                self.maw[h * w + valid_prior + t] = a;
            }
        }
    }

    /// Resident bytes (k + v + maw; the paper's peak-GPU-KV metric).
    pub fn size_bytes(&self) -> usize {
        (self.k.len() + self.v.len() + self.maw.len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_pool_accounts_acquire_and_reclaim() {
        let pool = Arc::new(GpuBlockPool::new());
        let a = pool.acquire(8);
        let b = pool.acquire(4);
        assert_eq!(pool.in_use(), 12);
        assert_eq!(pool.acquired_blocks(), 12);
        assert_eq!(pool.reclaimed_blocks(), 0);
        drop(a);
        assert_eq!(pool.in_use(), 4);
        assert_eq!(pool.reclaimed_blocks(), 8);
        drop(b);
        assert_eq!(pool.in_use(), 0);
        assert_eq!(pool.reclaimed_blocks(), 12);
    }

    #[test]
    fn bounded_pool_gates_acquisition() {
        let pool = Arc::new(GpuBlockPool::with_capacity(8));
        assert_eq!(pool.capacity(), Some(8));
        assert_eq!(pool.free_blocks(), Some(8));
        let a = pool.try_acquire(5).expect("5 of 8 fits");
        assert_eq!(pool.free_blocks(), Some(3));
        assert!(pool.try_acquire(4).is_none(), "4 > 3 free must fail");
        assert_eq!(pool.in_use(), 5, "failed acquire reserves nothing");
        let b = pool.try_acquire(3).expect("exactly the remaining blocks");
        assert_eq!(pool.free_blocks(), Some(0));
        drop(a);
        assert_eq!(pool.free_blocks(), Some(5));
        assert!(pool.try_acquire(5).is_some());
        drop(b);
    }

    #[test]
    fn unbounded_pool_never_fails() {
        let pool = Arc::new(GpuBlockPool::new());
        assert_eq!(pool.capacity(), None);
        assert_eq!(pool.free_blocks(), None);
        let a = pool.try_acquire(1_000_000).expect("unbounded");
        assert_eq!(pool.in_use(), 1_000_000);
        drop(a);
    }

    #[test]
    fn force_acquire_bypasses_capacity() {
        let pool = Arc::new(GpuBlockPool::with_capacity(2));
        let a = pool.acquire(5); // documented escape hatch (lease cloning)
        assert_eq!(pool.in_use(), 5);
        assert_eq!(pool.free_blocks(), Some(0), "free saturates at zero");
        assert!(pool.try_acquire(1).is_none());
        drop(a);
        assert_eq!(pool.free_blocks(), Some(2));
    }

    #[test]
    fn node_budgets_gate_independently() {
        let pool = Arc::new(GpuBlockPool::with_node_budgets(vec![4, 2]));
        assert_eq!(pool.nodes(), 2);
        assert_eq!(pool.capacity(), Some(6));
        assert_eq!(pool.capacity_on(0), Some(4));
        assert_eq!(pool.capacity_on(1), Some(2));
        assert_eq!(pool.capacity_on(2), None);
        assert_eq!(pool.max_node_capacity(), Some(4));
        let a = pool.try_acquire_on(0, 3).expect("3 of 4 on node 0");
        assert_eq!(a.node(), 0);
        assert_eq!(pool.free_blocks_on(0), Some(1));
        assert_eq!(pool.free_blocks_on(1), Some(2));
        // node 0 exhausted for 2 blocks, but node 1 still fits them
        assert!(pool.try_acquire_on(0, 2).is_none(), "budgets never spill");
        let b = pool.try_acquire_on(1, 2).expect("node 1's own budget");
        assert_eq!(b.node(), 1);
        assert_eq!(pool.in_use(), 5);
        assert_eq!(pool.in_use_on(0), 3);
        assert_eq!(pool.in_use_on(1), 2);
        drop(a);
        assert_eq!(pool.in_use_on(0), 0, "lease returns to its own node");
        assert_eq!(pool.in_use_on(1), 2);
        drop(b);
        assert_eq!(pool.reclaimed_blocks(), 5);
    }

    #[test]
    fn pick_node_prefers_most_free_with_id_tiebreak() {
        let pool = Arc::new(GpuBlockPool::with_node_budgets(vec![4, 4, 4]));
        // all equal → lowest id
        assert_eq!(pool.pick_node(2), Some(0));
        let _a = pool.try_acquire_on(0, 2).unwrap();
        // node 0 has 2 free, nodes 1/2 have 4 → node 1 (ties to lowest id)
        assert_eq!(pool.pick_node(2), Some(1));
        let _b = pool.try_acquire_on(1, 3).unwrap();
        // free: [2, 1, 4] → node 2
        assert_eq!(pool.pick_node(2), Some(2));
        // a lease larger than every node's remaining free → defer
        assert_eq!(pool.pick_node(5), None);
        // larger than any node's TOTAL budget: never placeable
        assert_eq!(pool.pick_node(9), None);
        assert!(pool.max_node_capacity().unwrap() < 9);
    }

    #[test]
    fn placement_resolving_try_acquire_spreads_leases() {
        let pool = Arc::new(GpuBlockPool::with_node_budgets(vec![4, 4]));
        let a = pool.try_acquire(4).expect("node 0");
        assert_eq!(a.node(), 0);
        let b = pool.try_acquire(4).expect("node 1");
        assert_eq!(b.node(), 1);
        assert!(pool.try_acquire(1).is_none(), "both budgets exhausted");
        drop(a);
        let c = pool.try_acquire(4).expect("reclaimed node 0");
        assert_eq!(c.node(), 0);
        drop(b);
        drop(c);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn single_budget_pool_equals_pre_numa_capacity_pool() {
        // with_capacity must stay bit-for-bit the old admission behaviour
        let pool = Arc::new(GpuBlockPool::with_capacity(8));
        assert_eq!(pool.nodes(), 1);
        assert_eq!(pool.max_node_capacity(), Some(8));
        assert_eq!(pool.pick_node(8), Some(0));
        assert_eq!(pool.pick_node(9), None);
        let a = pool.try_acquire(5).unwrap();
        assert_eq!(a.node(), 0);
        assert_eq!(pool.free_blocks_on(0), Some(3));
        drop(a);
    }

    #[test]
    fn clone_stays_on_its_node() {
        let pool = Arc::new(GpuBlockPool::with_node_budgets(vec![4, 4]));
        let a = pool.try_acquire_on(1, 3).unwrap();
        let b = a.clone();
        assert_eq!(b.node(), 1);
        assert_eq!(pool.in_use_on(1), 6, "force clone oversubscribes its node");
        assert_eq!(pool.in_use_on(0), 0);
        drop(a);
        drop(b);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn lease_clone_owns_its_share() {
        let pool = Arc::new(GpuBlockPool::new());
        let a = pool.acquire(3);
        let b = a.clone();
        assert_eq!(b.blocks(), 3);
        assert_eq!(pool.in_use(), 6);
        drop(a);
        assert_eq!(pool.in_use(), 3);
        drop(b);
        assert_eq!(pool.in_use(), 0);
        assert_eq!(pool.reclaimed_blocks(), 6);
    }

    fn cache() -> GpuLayerCache {
        GpuLayerCache::new(2, 4, 2, 3, 0.5) // H=2, dh=4, W=6
    }

    fn fill(c: &mut GpuLayerCache, n: usize, start_pos: usize) {
        let dh = c.d_head;
        let mut k = vec![0.0; c.heads * n * dh];
        let v = vec![0.5; c.heads * n * dh];
        for h in 0..c.heads {
            for t in 0..n {
                for j in 0..dh {
                    k[(h * n + t) * dh + j] = (start_pos + t) as f32 + h as f32 * 100.0;
                }
            }
        }
        let pos: Vec<usize> = (start_pos..start_pos + n).collect();
        c.append(&k, &v, &pos);
    }

    #[test]
    fn append_and_layout() {
        let mut c = cache();
        fill(&mut c, 3, 0);
        assert_eq!(c.len, 3);
        assert_eq!(c.k_at(0, 2)[0], 2.0);
        assert_eq!(c.k_at(1, 2)[0], 102.0);
        assert_eq!(c.pos[..3], [0, 1, 2]);
    }

    #[test]
    fn evict_takes_oldest_and_shifts() {
        let mut c = cache();
        fill(&mut c, 6, 0);
        assert_eq!(c.blocks_to_evict(1), 1);
        let blk = c.evict(1);
        assert_eq!(blk.len, 2);
        assert_eq!(blk.pos, vec![0, 1]);
        assert_eq!(blk.k_at(1, 1)[0], 101.0);
        assert_eq!(c.len, 4);
        assert_eq!(c.k_at(0, 0)[0], 2.0); // shifted
        assert_eq!(c.pos[..4], [2, 3, 4, 5]);
    }

    #[test]
    fn blocks_to_evict_ceiling() {
        let mut c = cache();
        fill(&mut c, 5, 0);
        assert_eq!(c.blocks_to_evict(1), 0); // 5+1 = 6 fits
        assert_eq!(c.blocks_to_evict(2), 1); // 7 > 6 → 1 block
        assert_eq!(c.blocks_to_evict(4), 2); // 9 > 6 → ceil(3/2)=2
    }

    #[test]
    fn maw_ema_and_seed() {
        let mut c = cache();
        fill(&mut c, 2, 0);
        // first update: 2 prior... actually both are new (seed)
        let s = 3; // pretend attention saw 3 slots: 2 window (none valid prior) — craft:
        // do a simpler scenario: entries appended, then update with all as new
        let a: Vec<f32> = vec![0.1, 0.3, 0.0, 0.2, 0.4, 0.0]; // [H=2][s=3]
        c.update_maw(&a, 3, 0, 2, 1);
        // new slots read from tail of a_sum rows: row0 tail = [0.3, 0.0]
        assert!((c.maw[0] - 0.3).abs() < 1e-6);
        assert!((c.maw[1] - 0.0).abs() < 1e-6);
        // second update: both slots now prior; EMA with alpha=.5
        let a2: Vec<f32> = vec![0.4, 0.2, 0.8, 0.6, 0.0, 0.0];
        c.update_maw(&a2[..], 3, 2, 0, 1);
        assert!((c.maw[0] - (0.5 * 0.3 + 0.5 * 0.4)).abs() < 1e-6);
    }

    #[test]
    fn chunk_update_normalizes_by_queries() {
        let mut c = cache();
        fill(&mut c, 2, 0);
        let a: Vec<f32> = vec![0.0, 2.0, 0.0, 4.0]; // [2 heads][2 slots], 4 queries
        c.update_maw(&a, 2, 0, 2, 4);
        assert!((c.maw[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn append_overflow_panics() {
        let mut c = cache();
        fill(&mut c, 6, 0);
        fill(&mut c, 1, 6);
    }

    #[test]
    fn multi_block_evict() {
        let mut c = cache();
        fill(&mut c, 6, 10);
        let blk = c.evict(2);
        assert_eq!(blk.len, 4);
        assert_eq!(blk.pos, vec![10, 11, 12, 13]);
        assert_eq!(c.len, 2);
        assert_eq!(c.pos[..2], [14, 15]);
    }
}
