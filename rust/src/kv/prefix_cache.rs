//! Cross-request prefix KV reuse: a radix trie over token chunks.
//!
//! Production traffic shares system prompts and few-shot preambles
//! across thousands of requests, so prefill work and KV storage are
//! massively duplicated. This cache keys **chunk-aligned token
//! prefixes** (the batcher's prefill chunk, `cfg.chunk` tokens, is the
//! natural snapshot grain: after every chunk the per-sequence KV state
//! is a pure function of the prefix bytes and the config — prefill is
//! RNG-free and row-independent) to lease-free [`KvManager`] snapshots.
//! A newly admitted sequence adopts the longest cached prefix instead of
//! re-running those prefill chunks; the snapshot's CowVec slabs
//! (`kv/cow.rs`) make the adoption an `Arc` bump per buffer, shared
//! copy-on-write until the adopter's own generation diverges.
//!
//! **Block accounting.** A cached entry holds its own [`BlockLease`]
//! sized by the snapshot's *occupied* window blocks
//! ([`KvManager::blocks_in_windows`]) — the cache is a first-class
//! tenant of the same capacity-bounded pool sequences lease from, so
//! `pool.in_use` = Σ live-sequence leases + Σ cache-entry leases at all
//! times. When capacity-gated admission needs blocks back, the engine
//! calls [`PrefixCache::evict_for_blocks`], which drops entries in LRU
//! order (dropping the entry drops its lease — the blocks observably
//! return). An insert that cannot lease its blocks is simply skipped:
//! caching never starves admission.
//!
//! **Why adoption is bitwise-safe.** The sampler is greedy and prefill
//! consumes no RNG, so the KV state after N chunk-aligned prompt tokens
//! is identical whether computed fresh or restored from a snapshot.
//! Adopted length is capped at `prompt.len() - 1`: the final prefill
//! chunk must still run so the first sampled token comes from real
//! logits. NUMA placement is metadata-only ([`KvManager::reanchor`]), so
//! adoption is bitwise-identical across node counts too —
//! `tests/integration_prefix.rs` pins cache-on ≡ cache-off across 1/2/4
//! synthetic nodes.

use std::sync::Arc;

use super::gpu_pool::{BlockLease, GpuBlockPool};
use super::manager::KvManager;

/// Cumulative prefix-cache counters (`prefix_*` on `/v1/metrics` and in
/// replay reports). All zeros while the cache is disabled.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct PrefixStats {
    /// lookups that adopted a cached prefix
    pub hits: u64,
    /// lookups that found no usable prefix
    pub misses: u64,
    /// snapshots inserted (refreshing an existing entry does not count)
    pub insertions: u64,
    /// entries dropped by LRU eviction or capacity pressure
    pub evictions: u64,
    /// prompt tokens *not* re-prefilled thanks to adoption
    pub tokens_reused: u64,
    /// entries currently resident
    pub entries: u64,
    /// GPU window blocks currently leased by cache entries
    pub cached_blocks: u64,
}

/// One cached snapshot: the KV state after `prefix_len` chunk-aligned
/// tokens, plus the lease covering the blocks its windows occupy.
#[derive(Debug)]
struct Entry {
    prefix_len: usize,
    snapshot: Arc<KvManager>,
    /// Blocks this entry charges the pool; dropping the entry drops the
    /// lease, returning them. `None` only on unbounded pools.
    lease: Option<BlockLease>,
    blocks: usize,
    /// monotone recency stamp (larger = more recently used)
    last_use: u64,
}

/// A trie node at a chunk boundary; the edge *into* a node is one full
/// chunk of prompt bytes.
#[derive(Debug, Default)]
struct TrieNode {
    /// (chunk bytes, child node index) — linear scan keeps child order
    /// deterministic (insertion order), and fan-out per node is tiny in
    /// practice (few distinct system prompts).
    children: Vec<(Box<[u8]>, usize)>,
    entry: Option<Entry>,
}

/// Radix/prefix KV cache over chunk-aligned token prefixes.
///
/// Not thread-safe by design: it lives inside the engine, which owns the
/// whole serving hot path on one thread.
#[derive(Debug)]
pub struct PrefixCache {
    /// arena; index 0 is the root (empty prefix — never holds an entry)
    nodes: Vec<TrieNode>,
    /// prefill chunk size — every edge is exactly this many tokens
    chunk: usize,
    /// hard cap on resident entries (LRU evicts past it)
    max_entries: usize,
    pool: Arc<GpuBlockPool>,
    /// recency clock for LRU (bumped on every hit/insert)
    clock: u64,
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
    tokens_reused: u64,
}

impl PrefixCache {
    /// An empty cache leasing entry storage from `pool`. `chunk` is the
    /// engine's prefill chunk (`cfg.chunk`); `max_entries` bounds resident
    /// snapshots (LRU past it).
    pub fn new(pool: Arc<GpuBlockPool>, chunk: usize, max_entries: usize) -> PrefixCache {
        assert!(chunk > 0, "chunk-aligned cache needs a nonzero chunk");
        assert!(max_entries > 0, "a zero-entry cache cannot hold anything");
        PrefixCache {
            nodes: vec![TrieNode::default()],
            chunk,
            max_entries,
            pool,
            clock: 0,
            hits: 0,
            misses: 0,
            insertions: 0,
            evictions: 0,
            tokens_reused: 0,
        }
    }

    /// Longest cached prefix of `prompt` usable by a new sequence:
    /// chunk-aligned and **strictly shorter than the prompt** (the final
    /// prefill chunk must run so the first token samples from real
    /// logits). On a hit returns `(prefix_len, snapshot)` — a lease-free
    /// deep clone sharing every KV slab copy-on-write — and counts
    /// `hits`/`tokens_reused`; otherwise counts a miss.
    pub fn lookup(&mut self, prompt: &[u8]) -> Option<(usize, KvManager)> {
        let max_chunks = prompt.len().saturating_sub(1) / self.chunk;
        let mut node = 0usize;
        let mut best: Option<usize> = None; // node index holding the best entry
        for c in 0..max_chunks {
            let chunk = &prompt[c * self.chunk..(c + 1) * self.chunk];
            let Some(&(_, next)) = self.nodes[node]
                .children
                .iter()
                .find(|(edge, _)| &**edge == chunk)
            else {
                break;
            };
            node = next;
            if self.nodes[node].entry.is_some() {
                best = Some(node);
            }
        }
        match best {
            Some(idx) => {
                self.clock += 1;
                let entry = self.nodes[idx].entry.as_mut().expect("best holds an entry");
                entry.last_use = self.clock;
                let prefix_len = entry.prefix_len;
                self.hits += 1;
                self.tokens_reused += prefix_len as u64;
                Some((prefix_len, entry.snapshot.snapshot()))
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Cache `kv` as the state after `prompt[..prefix_len]` (`prefix_len`
    /// must be chunk-aligned, nonzero, and ≤ the prompt). The snapshot's
    /// occupied window blocks are leased from the pool on the snapshot's
    /// home node; if they don't fit even after LRU eviction, the insert
    /// is skipped — the cache never outbids admission. Refreshing an
    /// existing prefix only bumps its recency.
    pub fn insert(&mut self, prompt: &[u8], prefix_len: usize, kv: &KvManager) {
        debug_assert!(prefix_len > 0 && prefix_len % self.chunk == 0);
        debug_assert!(prefix_len <= prompt.len());
        let n_chunks = prefix_len / self.chunk;
        let mut node = 0usize;
        for c in 0..n_chunks {
            let chunk = &prompt[c * self.chunk..(c + 1) * self.chunk];
            let next = match self.nodes[node]
                .children
                .iter()
                .find(|(edge, _)| &**edge == chunk)
            {
                Some(&(_, next)) => next,
                None => {
                    let idx = self.nodes.len();
                    self.nodes.push(TrieNode::default());
                    self.nodes[node].children.push((chunk.into(), idx));
                    idx
                }
            };
            node = next;
        }
        self.clock += 1;
        if let Some(entry) = self.nodes[node].entry.as_mut() {
            // same chunk-aligned prefix ⇒ deterministically the same KV —
            // keep the resident snapshot, refresh its recency
            entry.last_use = self.clock;
            return;
        }
        if self.entries() >= self.max_entries as u64 {
            self.evict_lru();
        }
        let blocks = kv.blocks_in_windows();
        let lease = if self.pool.capacity().is_some() {
            let mut lease = self.pool.try_acquire_on(kv.node, blocks);
            if lease.is_none() {
                // LRU entries are worth less than a fresh hot prefix
                self.evict_for_blocks(blocks);
                lease = self.pool.try_acquire_on(kv.node, blocks);
            }
            match lease {
                Some(l) => Some(l),
                None => return, // no headroom — skip caching
            }
        } else {
            None // unbounded accounting-only pool: nothing to charge
        };
        self.insertions += 1;
        self.nodes[node].entry = Some(Entry {
            prefix_len,
            snapshot: Arc::new(kv.snapshot()),
            lease,
            blocks,
            last_use: self.clock,
        });
    }

    /// Drop LRU entries until at least `needed` blocks have been
    /// returned to the pool (or the cache is empty). Returns the blocks
    /// actually freed. Called by admission when a sequence lease fails —
    /// the LRU-vs-capacity interaction (docs/SCHEDULING.md).
    pub fn evict_for_blocks(&mut self, needed: usize) -> usize {
        let mut freed = 0;
        while freed < needed {
            match self.evict_lru() {
                Some(blocks) => freed += blocks,
                None => break,
            }
        }
        freed
    }

    /// Drop every entry (used when the pool is re-sized under the cache).
    pub fn clear(&mut self) {
        while self.evict_lru().is_some() {}
    }

    fn evict_lru(&mut self) -> Option<usize> {
        let idx = self
            .nodes
            .iter()
            .enumerate()
            .filter_map(|(i, n)| n.entry.as_ref().map(|e| (e.last_use, i)))
            .min()?
            .1;
        let entry = self.nodes[idx].entry.take().expect("selected entry");
        self.evictions += 1;
        Some(entry.blocks) // dropping `entry` drops its lease
    }

    /// The configured residency cap.
    pub fn max_entries(&self) -> usize {
        self.max_entries
    }

    /// Entries currently resident.
    pub fn entries(&self) -> u64 {
        self.nodes.iter().filter(|n| n.entry.is_some()).count() as u64
    }

    /// GPU window blocks currently leased by cache entries.
    pub fn cached_blocks(&self) -> u64 {
        self.nodes
            .iter()
            .filter_map(|n| n.entry.as_ref())
            .map(|e| e.lease.as_ref().map_or(0, BlockLease::blocks) as u64)
            .sum()
    }

    /// Counter snapshot for `/v1/metrics` and replay reports.
    pub fn stats(&self) -> PrefixStats {
        PrefixStats {
            hits: self.hits,
            misses: self.misses,
            insertions: self.insertions,
            evictions: self.evictions,
            tokens_reused: self.tokens_reused,
            entries: self.entries(),
            cached_blocks: self.cached_blocks(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::trained;
    use crate::config::HgcaConfig;

    const CHUNK: usize = 4;

    fn cfg() -> HgcaConfig {
        HgcaConfig {
            blk_size: 2,
            blk_num: 2,
            chunk: CHUNK,
            ..Default::default()
        }
    }

    /// A KvManager that has absorbed `n` deterministic layer-0 entries
    /// (appended in window-sized steps, so long prefixes exercise
    /// eviction too) — enough structure for block accounting without
    /// running a model.
    fn kv_with(n: usize) -> KvManager {
        let model = trained("tiny-small").unwrap(); // 2 layers, 2 heads, dh 32
        let mut m = KvManager::new(&model, &cfg());
        let mut done = 0;
        while done < n {
            let step = (n - done).min(2);
            let k = vec![1.0; 2 * step * 32];
            let v = vec![-1.0; 2 * step * 32];
            let pos: Vec<usize> = (done..done + step).collect();
            m.make_room(0, step);
            m.append(0, &k, &v, &pos);
            done += step;
        }
        m.advance(n);
        m
    }

    fn cache(pool: &Arc<GpuBlockPool>) -> PrefixCache {
        PrefixCache::new(Arc::clone(pool), CHUNK, 8)
    }

    /// Deterministic LCG for the property tests (same constants as the
    /// corpus generator).
    struct Lcg(u64);
    impl Lcg {
        fn next(&mut self) -> u64 {
            self.0 = self
                .0
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            self.0 >> 33
        }
    }

    #[test]
    fn miss_then_insert_then_hit() {
        let pool = Arc::new(GpuBlockPool::new());
        let mut c = cache(&pool);
        let prompt = b"abcdefgh full prompt".to_vec();
        assert!(c.lookup(&prompt).is_none());
        c.insert(&prompt, CHUNK, &kv_with(CHUNK));
        let (len, snap) = c.lookup(&prompt).expect("cached prefix adopted");
        assert_eq!(len, CHUNK);
        assert_eq!(snap.seq_len, CHUNK);
        assert_eq!(snap.leased_blocks(), 0, "adopted snapshots are lease-free");
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.insertions), (1, 1, 1));
        assert_eq!(s.tokens_reused, CHUNK as u64);
        assert_eq!(s.entries, 1);
    }

    #[test]
    fn lookup_prefers_the_longest_prefix_and_caps_below_prompt_len() {
        let pool = Arc::new(GpuBlockPool::new());
        let mut c = cache(&pool);
        let prompt = b"aaaabbbbccccdd".to_vec(); // 14 bytes, chunks: aaaa bbbb cccc
        c.insert(&prompt, CHUNK, &kv_with(CHUNK));
        c.insert(&prompt, 2 * CHUNK, &kv_with(2 * CHUNK));
        let (len, _) = c.lookup(&prompt).unwrap();
        assert_eq!(len, 2 * CHUNK, "deepest entry wins");
        // a prompt that IS exactly a cached prefix must not adopt all of
        // itself — the final chunk has to produce first-token logits
        let exact = b"aaaabbbb".to_vec();
        let (len, _) = c.lookup(&exact).unwrap();
        assert_eq!(len, CHUNK);
        // diverging second chunk falls back to the shared first chunk
        let fork = b"aaaaZZZZcccc".to_vec();
        let (len, _) = c.lookup(&fork).unwrap();
        assert_eq!(len, CHUNK);
        // diverging first chunk shares nothing
        assert!(c.lookup(&b"XXXXbbbbcccc".to_vec()).is_none());
    }

    #[test]
    fn entries_lease_real_blocks_and_eviction_returns_them() {
        // 2 layers × blk_num 2 = 4 blocks per full window; kv_with(4)
        // occupies layer 0 fully (2 blocks), layer 1 empty → 2 blocks
        let pool = Arc::new(GpuBlockPool::with_capacity(8));
        let mut c = cache(&pool);
        let p1 = b"aaaa tail".to_vec();
        let p2 = b"bbbb tail".to_vec();
        c.insert(&p1, CHUNK, &kv_with(CHUNK));
        c.insert(&p2, CHUNK, &kv_with(CHUNK));
        assert_eq!(c.cached_blocks(), 4);
        assert_eq!(pool.in_use(), 4, "cache entries are pool tenants");
        let freed = c.evict_for_blocks(3);
        assert!(freed >= 3);
        assert_eq!(pool.in_use() as u64, c.cached_blocks());
        c.clear();
        assert_eq!(pool.in_use(), 0, "every cached block observably returned");
        assert_eq!(c.stats().evictions, 2);
    }

    #[test]
    fn insert_skips_when_blocks_never_fit() {
        let pool = Arc::new(GpuBlockPool::with_capacity(1)); // < 2 blocks needed
        let mut c = cache(&pool);
        c.insert(&b"aaaa tail".to_vec(), CHUNK, &kv_with(CHUNK));
        assert_eq!(c.entries(), 0, "no headroom → no caching");
        assert_eq!(pool.in_use(), 0, "failed insert leases nothing");
        assert!(c.lookup(&b"aaaa tail".to_vec()).is_none());
    }

    #[test]
    fn eviction_never_touches_a_live_sequence_lease() {
        let pool = Arc::new(GpuBlockPool::with_capacity(6));
        // a live sequence holds 4 blocks (its full-window lease)
        let mut live = kv_with(CHUNK);
        let lease = pool.try_acquire(live.blocks_needed()).expect("4 of 6");
        live.attach_lease(lease);
        let mut c = cache(&pool);
        c.insert(&b"aaaa tail".to_vec(), CHUNK, &kv_with(CHUNK)); // 2 blocks
        assert_eq!(pool.in_use(), 6);
        // demanding more than the cache holds frees only cache blocks
        let freed = c.evict_for_blocks(100);
        assert_eq!(freed, 2);
        assert_eq!(pool.in_use(), 4, "the live lease is untouched");
        assert_eq!(live.leased_blocks(), 4);
    }

    #[test]
    fn lru_evicts_the_least_recently_used_entry() {
        let pool = Arc::new(GpuBlockPool::new());
        let mut c = cache(&pool);
        let old = b"aaaa tail".to_vec();
        let hot = b"bbbb tail".to_vec();
        c.insert(&old, CHUNK, &kv_with(CHUNK));
        c.insert(&hot, CHUNK, &kv_with(CHUNK));
        c.lookup(&old).unwrap();
        c.lookup(&hot).unwrap();
        c.lookup(&old).unwrap(); // old is now the most recent
        c.evict_for_blocks(1);
        assert!(c.lookup(&old).is_some(), "recently-used survives");
        assert!(c.lookup(&hot).is_none(), "LRU victim evicted");
    }

    #[test]
    fn max_entries_bounds_residency() {
        let pool = Arc::new(GpuBlockPool::new());
        let mut c = PrefixCache::new(Arc::clone(&pool), CHUNK, 2);
        for b in [b'a', b'b', b'c', b'd'] {
            let prompt = vec![b; CHUNK + 1];
            c.insert(&prompt, CHUNK, &kv_with(CHUNK));
        }
        assert_eq!(c.entries(), 2);
        assert_eq!(c.stats().evictions, 2);
    }

    #[test]
    fn refreshing_an_existing_prefix_adds_nothing() {
        let pool = Arc::new(GpuBlockPool::with_capacity(8));
        let mut c = cache(&pool);
        let p = b"aaaa tail".to_vec();
        c.insert(&p, CHUNK, &kv_with(CHUNK));
        let before = pool.in_use();
        c.insert(&p, CHUNK, &kv_with(CHUNK));
        assert_eq!(c.entries(), 1);
        assert_eq!(c.stats().insertions, 1);
        assert_eq!(pool.in_use(), before, "refresh leases nothing new");
    }

    /// Property sweep under seeded-random token streams: trie invariants
    /// (a hit is always a true chunk-aligned prefix strictly shorter than
    /// the prompt), lease accounting never underflows, and the pool
    /// balance `in_use == cache.cached_blocks()` holds after every
    /// operation (no live sequences in this sweep).
    #[test]
    fn property_random_streams_keep_invariants() {
        for seed in 1..=20u64 {
            let mut rng = Lcg(seed);
            let pool = Arc::new(GpuBlockPool::with_capacity(16));
            let mut c = PrefixCache::new(Arc::clone(&pool), CHUNK, 4);
            for _ in 0..60 {
                // small alphabet → frequent shared prefixes
                let len = 1 + (rng.next() % (4 * CHUNK as u64)) as usize;
                let prompt: Vec<u8> =
                    (0..len).map(|_| b'a' + (rng.next() % 3) as u8).collect();
                match rng.next() % 3 {
                    0 => {
                        if let Some((plen, snap)) = c.lookup(&prompt) {
                            assert!(plen % CHUNK == 0 && plen > 0);
                            assert!(plen < prompt.len(), "must leave a final chunk");
                            assert_eq!(snap.seq_len, plen);
                            assert_eq!(snap.leased_blocks(), 0);
                        }
                    }
                    1 => {
                        let chunks = prompt.len() / CHUNK;
                        if chunks > 0 {
                            let plen = CHUNK * (1 + (rng.next() % chunks as u64) as usize);
                            c.insert(&prompt, plen, &kv_with(plen));
                        }
                    }
                    _ => {
                        c.evict_for_blocks((rng.next() % 4) as usize);
                    }
                }
                assert_eq!(
                    pool.in_use() as u64,
                    c.cached_blocks(),
                    "seed {seed}: pool in_use must equal the cache's leased blocks"
                );
                assert!(c.entries() <= 4);
            }
            c.clear();
            assert_eq!(pool.in_use(), 0, "seed {seed}: clear returns every block");
            let s = c.stats();
            assert_eq!(s.entries, 0);
            assert_eq!(s.insertions, s.evictions, "every insert eventually evicted");
        }
    }
}
