//! Int8 KV quantization for the CPU-side store (the tiered-KV tentpole).
//!
//! A [`QuantSlab`] holds one head's K (or V) entries as symmetric int8
//! with one f32 scale per block of [`QUANT_BLOCK`] entries:
//! `q = round(x / scale).clamp(-127, 127)`, `scale = max_abs / 127`
//! (0 for an all-zero block), so the round-trip error is ≤ `scale / 2`
//! elementwise. The attention kernel dots quantized bytes with a single
//! i32 accumulation and multiplies by the scales once per (query, entry)
//! — no dequantized f32 copy is ever materialized (see
//! `attention/cpu_attention.rs::run_job_range_tiered` and the accelerator
//! guide's int8 + per-block-scale recipe).
//!
//! **Stale-scale safety:** the f32 originals of the current *partial*
//! tail block are staged in the slab (`tail_f32`), so every append
//! re-quantizes the tail block from originals — the block's scale always
//! reflects every entry it covers, and quantization error never
//! compounds across appends. Mutation sites in `kv/cpu_store.rs`
//! (`add_evicted`, `reevaluate`) go through [`QuantSlab::push_entries`],
//! which is what pins the "never serve stale scales" regression test.

/// Entries per scale block in the full-store slabs. The contextual cache
/// uses per-entry scales (`block = 1`) because its entries are gathered
/// from arbitrary store positions.
pub const QUANT_BLOCK: usize = 32;

/// One head's K or V slab, quantized to int8 with per-block scales.
#[derive(Debug, Clone, Default)]
pub struct QuantSlab {
    /// Quantized entries, `len() * d_head` bytes, block-major in time.
    data: Vec<i8>,
    /// One scale per block of `block` entries (last block may be partial).
    scales: Vec<f32>,
    /// f32 originals of the current partial tail block
    /// (`(len() % block) * d_head` values) — appends re-quantize the tail
    /// from these, never from already-rounded bytes.
    tail_f32: Vec<f32>,
    /// Values per entry.
    d_head: usize,
    /// Entries per scale block (≥ 1).
    block: usize,
    /// Entries stored.
    n: usize,
}

impl QuantSlab {
    /// An empty slab with the given entry width and scale-block length.
    pub fn new(d_head: usize, block: usize) -> QuantSlab {
        assert!(block >= 1, "scale block must hold at least one entry");
        QuantSlab {
            data: Vec::new(),
            scales: Vec::new(),
            tail_f32: Vec::new(),
            d_head,
            block,
            n: 0,
        }
    }

    /// Quantize a whole f32 slab (`n * d_head` values) in one call.
    pub fn from_f32(rows: &[f32], d_head: usize, block: usize) -> QuantSlab {
        let mut s = QuantSlab::new(d_head, block);
        s.push_entries(rows);
        s
    }

    /// Entries stored.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Values per entry.
    pub fn d_head(&self) -> usize {
        self.d_head
    }

    /// Entries per scale block.
    pub fn block(&self) -> usize {
        self.block
    }

    fn tail_entries(&self) -> usize {
        self.tail_f32.len() / self.d_head.max(1)
    }

    /// Append `rows.len() / d_head` entries, re-quantizing the partial
    /// tail block from its staged f32 originals so its scale covers every
    /// entry in the block (the stale-scale fix).
    pub fn push_entries(&mut self, rows: &[f32]) {
        let dh = self.d_head;
        assert_eq!(rows.len() % dh.max(1), 0, "rows must be whole entries");
        if rows.is_empty() {
            return;
        }
        // drop the previously-emitted partial tail; it re-emits below from
        // the retained originals together with the new entries
        let tail = self.tail_entries();
        self.data.truncate((self.n - tail) * dh);
        self.scales.truncate((self.n - tail) / self.block);
        self.tail_f32.extend_from_slice(rows);
        self.n += rows.len() / dh;
        let bw = self.block * dh;
        let mut start = 0usize;
        while self.tail_f32.len() - start >= bw {
            let dstart = self.data.len();
            self.data.resize(dstart + bw, 0);
            let scale = quantize_into(&self.tail_f32[start..start + bw], &mut self.data[dstart..]);
            self.scales.push(scale);
            start += bw;
        }
        self.tail_f32.drain(..start);
        if !self.tail_f32.is_empty() {
            let dstart = self.data.len();
            self.data.resize(dstart + self.tail_f32.len(), 0);
            let scale = quantize_into(&self.tail_f32, &mut self.data[dstart..]);
            self.scales.push(scale);
        }
        debug_assert_eq!(self.data.len(), self.n * dh);
        debug_assert_eq!(self.scales.len(), self.n.div_ceil(self.block));
    }

    /// Append one already-quantized entry with its own scale. Only valid
    /// on per-entry-scale slabs (`block == 1`) — the contextual cache's
    /// gather path, which copies bytes + scales from the full-store slab
    /// so packing adds no quantization error.
    pub fn push_quantized(&mut self, bytes: &[i8], scale: f32) {
        assert_eq!(self.block, 1, "per-entry push needs block == 1");
        assert_eq!(bytes.len(), self.d_head);
        self.data.extend_from_slice(bytes);
        self.scales.push(scale);
        self.n += 1;
    }

    /// The quantized bytes of entry `t`.
    pub fn entry(&self, t: usize) -> &[i8] {
        &self.data[t * self.d_head..(t + 1) * self.d_head]
    }

    /// The scale of entry `t`'s block.
    pub fn scale_of(&self, t: usize) -> f32 {
        self.scales[t / self.block]
    }

    /// Dequantize entry `t` into `out` (tests + oracle comparisons).
    pub fn dequantize_entry(&self, t: usize, out: &mut [f32]) {
        let s = self.scale_of(t);
        for (o, &q) in out.iter_mut().zip(self.entry(t)) {
            *o = q as f32 * s;
        }
    }

    /// Dequantize the whole slab (tests only — the serving path never
    /// materializes this).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n * self.d_head];
        for t in 0..self.n {
            let dh = self.d_head;
            self.dequantize_entry(t, &mut out[t * dh..(t + 1) * dh]);
        }
        out
    }

    /// Exact heap bytes of the tiered buffers: quantized data (1 B/value),
    /// scales (4 B each), and the staged f32 tail originals (4 B each).
    pub fn size_bytes(&self) -> usize {
        self.data.len() + self.scales.len() * 4 + self.tail_f32.len() * 4
    }
}

/// Quantize one block of f32 values directly into `out` (same length —
/// no temporary allocation), returning the block scale (`max_abs / 127`;
/// 0 for an all-zero block). The max-abs scan goes through the dispatched
/// SIMD kernel layer ([`crate::tensor::simd`]); it is exact at every
/// level, so block scales never depend on the dispatch level. The
/// round-to-nearest itself stays scalar deliberately: SSE/AVX `roundps`
/// is round-half-to-even while `f32::round` is round-half-away-from-zero,
/// and quantized bytes must be bit-identical across levels.
fn quantize_into(vals: &[f32], out: &mut [i8]) -> f32 {
    debug_assert_eq!(vals.len(), out.len());
    let max_abs = crate::tensor::simd::max_abs(vals);
    if max_abs == 0.0 {
        out.fill(0);
        return 0.0;
    }
    let scale = max_abs / 127.0;
    let inv = 127.0 / max_abs;
    for (o, &v) in out.iter_mut().zip(vals.iter()) {
        *o = (v * inv).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

/// Quantize one f32 row (a query) to int8 in `out`, returning its scale.
/// Writes in place — this runs once per (job, query) on the tiered
/// attention path, so it must not allocate.
pub fn quantize_row(row: &[f32], out: &mut [i8]) -> f32 {
    assert_eq!(row.len(), out.len(), "quantize_row length mismatch");
    quantize_into(row, out)
}

/// Integer dot product of two int8 rows (one i32 accumulation; the
/// caller applies `scale_a * scale_b` once on the result).
/// Runtime-dispatched ([`crate::tensor::simd`]); i32 adds are
/// associative, so every dispatch level is bitwise-identical.
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    crate::tensor::simd::dot_i8(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_within_half_scale() {
        let rows: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        let s = QuantSlab::from_f32(&rows, 8, 4);
        let deq = s.dequantize();
        for (t, (a, b)) in rows.chunks(8).zip(deq.chunks(8)).enumerate() {
            let scale = s.scale_of(t);
            for (x, y) in a.iter().zip(b.iter()) {
                assert!(
                    (x - y).abs() <= scale / 2.0 + 1e-7,
                    "entry {t}: {x} vs {y} (scale {scale})"
                );
            }
        }
    }

    #[test]
    fn all_zero_block_has_zero_scale() {
        let s = QuantSlab::from_f32(&[0.0; 16], 4, 4);
        assert_eq!(s.scale_of(0), 0.0);
        assert!(s.dequantize().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn incremental_push_matches_one_shot() {
        // appends re-quantize the tail from originals, so pushing entry by
        // entry must yield byte-identical data + scales to one big push
        let rows: Vec<f32> = (0..40).map(|i| (i as f32).cos() * 2.0).collect();
        let dh = 4;
        let whole = QuantSlab::from_f32(&rows, dh, 3);
        let mut inc = QuantSlab::new(dh, 3);
        for chunk in rows.chunks(dh) {
            inc.push_entries(chunk);
        }
        assert_eq!(whole.len(), inc.len());
        for t in 0..whole.len() {
            assert_eq!(whole.entry(t), inc.entry(t), "entry {t}");
            assert_eq!(whole.scale_of(t), inc.scale_of(t), "scale of {t}");
        }
    }

    #[test]
    fn size_bytes_is_exact() {
        let rows: Vec<f32> = (0..28).map(|i| i as f32).collect(); // 7 entries, dh 4
        let s = QuantSlab::from_f32(&rows, 4, 2);
        // 7 entries × 4 B data + 4 scale blocks × 4 B + 1-entry tail × 4 vals × 4 B
        assert_eq!(s.size_bytes(), 28 + 4 * 4 + 4 * 4);
    }

    #[test]
    fn integer_dot_matches_scaled_f32_dot() {
        let a: Vec<f32> = (0..16).map(|i| (i as f32 * 0.7).sin()).collect();
        let b: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).cos()).collect();
        let mut qa = vec![0i8; 16];
        let mut qb = vec![0i8; 16];
        let sa = quantize_row(&a, &mut qa);
        let sb = quantize_row(&b, &mut qb);
        let exact: f32 = a.iter().zip(b.iter()).map(|(x, y)| x * y).sum();
        let quant = dot_i8(&qa, &qb) as f32 * (sa * sb);
        assert!((exact - quant).abs() < 0.05, "{exact} vs {quant}");
    }

    #[test]
    fn per_entry_scale_push() {
        let mut s = QuantSlab::new(2, 1);
        s.push_quantized(&[127, -127], 0.5);
        s.push_quantized(&[10, 0], 0.25);
        assert_eq!(s.len(), 2);
        assert_eq!(s.scale_of(0), 0.5);
        assert_eq!(s.scale_of(1), 0.25);
        let mut out = [0.0f32; 2];
        s.dequantize_entry(0, &mut out);
        assert_eq!(out, [63.5, -63.5]);
    }
}
