//! Per-sequence KV manager: glues the GPU window and CPU store per layer
//! and implements the full Algorithm 1 flow for decode and append steps.

use std::sync::Arc;

use crate::config::{HgcaConfig, ModelConfig};
use crate::topology::{NodeId, Topology};

use super::cpu_store::CpuLayerStore;
use super::gpu_pool::{BlockLease, GpuBlockPool, GpuLayerCache};

/// One layer's split KV state: the GPU window + the CPU store.
#[derive(Debug, Clone)]
pub struct LayerKv {
    /// Recent entries, resident on the "GPU" (the artifact's k_win/v_win).
    pub gpu: GpuLayerCache,
    /// Evicted entries + the selected contextual cache, resident on the CPU.
    pub cpu: CpuLayerStore,
}

/// KV state for one sequence across all layers.
#[derive(Debug, Clone)]
pub struct KvManager {
    /// Per-layer GPU window + CPU store.
    pub layers: Vec<LayerKv>,
    /// The HGCA tunables this manager was built with.
    pub cfg: HgcaConfig,
    /// total tokens absorbed so far (= next position)
    pub seq_len: usize,
    /// cumulative bytes moved over the (simulated) PCIe link by evictions
    pub evict_bytes: u64,
    /// The NUMA node this sequence was placed on: its GPU block lease
    /// draws from this node's budget, and its head shard map is anchored
    /// here (0 on flat topologies).
    pub node: NodeId,
    /// Per-head NUMA shard map, identical across layers
    /// ([`Topology::shard_heads`] anchored at [`KvManager::node`]) — the
    /// engine dispatches head `h`'s CPU attention job to `shard[h]`'s
    /// queue.
    shard: Vec<NodeId>,
    /// GPU block lease held against the engine's [`GpuBlockPool`];
    /// dropping the manager (sequence retirement — normal or early)
    /// returns the blocks to the pool
    lease: Option<BlockLease>,
}

impl KvManager {
    /// Empty KV state for one sequence of `model` under `cfg` on a flat
    /// single-node topology (every pre-NUMA caller's layout, bit for bit).
    pub fn new(model: &ModelConfig, cfg: &HgcaConfig) -> KvManager {
        KvManager::new_on(model, cfg, &Topology::single(), 0)
    }

    /// Empty KV state for one sequence **placed on `node`** of `topo`: the
    /// per-head shard map round-robins head slabs across nodes starting at
    /// the home node (`(node + h) % nodes`), and every layer's
    /// [`CpuLayerStore`] records it, so CPU attention jobs can be
    /// dispatched to the queues owning their slabs. On a single-node
    /// topology this is exactly [`KvManager::new`]. Placement changes
    /// where work runs and which budget the lease draws from — never the
    /// stored bytes or selection numerics.
    pub fn new_on(
        model: &ModelConfig,
        cfg: &HgcaConfig,
        topo: &Topology,
        node: NodeId,
    ) -> KvManager {
        let shard = topo.shard_heads(model.n_heads, node);
        let layers = (0..model.n_layers)
            .map(|_| LayerKv {
                gpu: GpuLayerCache::new(
                    model.n_heads,
                    model.d_head(),
                    cfg.blk_size,
                    cfg.blk_num,
                    cfg.alpha,
                ),
                cpu: CpuLayerStore::new_sharded(model.n_heads, model.d_head(), shard.clone()),
            })
            .collect();
        KvManager {
            layers,
            cfg: cfg.clone(),
            seq_len: 0,
            evict_bytes: 0,
            node,
            shard,
            lease: None,
        }
    }

    /// The per-head NUMA shard map (len == heads; all 0 when flat).
    pub fn shard(&self) -> &[NodeId] {
        &self.shard
    }

    /// The NUMA node owning head `h`'s CPU slabs.
    pub fn node_of_head(&self, h: usize) -> NodeId {
        self.shard[h]
    }

    /// GPU window blocks this manager needs to lease (`n_layers × blk_num`)
    /// — the admission currency of a capacity-bounded pool.
    pub fn blocks_needed(&self) -> usize {
        self.layers.len() * self.cfg.blk_num
    }

    /// Lease this manager's GPU window blocks (`n_layers × blk_num`) from
    /// `pool`, bypassing any capacity bound (force acquire — standalone
    /// engines and tests). The lease is released when the manager drops, so
    /// retiring a sequence — finished, cancelled, expired, or disconnected
    /// — restores the pool's free count (observable via
    /// [`GpuBlockPool::in_use`]). Capacity-gated admission goes through
    /// [`GpuBlockPool::try_acquire`] + [`KvManager::attach_lease`] instead.
    pub fn lease_from(&mut self, pool: &Arc<GpuBlockPool>) {
        self.lease = Some(pool.acquire(self.blocks_needed()));
    }

    /// Attach a lease acquired up front (capacity-gated admission: the
    /// scheduler acquires via [`GpuBlockPool::try_acquire_on`] *before*
    /// building the sequence, so a failed acquisition allocates nothing).
    /// The lease's node should match this manager's placement — the
    /// "same node end to end" invariant. Any previously held lease is
    /// released.
    pub fn attach_lease(&mut self, lease: BlockLease) {
        debug_assert_eq!(lease.blocks(), self.blocks_needed());
        debug_assert_eq!(lease.node(), self.node, "lease and KV placement diverge");
        self.lease = Some(lease);
    }

    /// Blocks currently leased from the engine's pool (0 when unleased).
    pub fn leased_blocks(&self) -> usize {
        self.lease.as_ref().map_or(0, BlockLease::blocks)
    }

    /// A **lease-free** deep-shallow copy for the prefix cache: every
    /// layer's KV state is cloned (an O(1) `Arc` bump per CowVec slab —
    /// see [`super::cow::CowVec`]), but no lease is carried or acquired.
    /// The derived `Clone` would force-acquire a fresh lease on the same
    /// node ([`BlockLease::clone`]), silently oversubscribing a bounded
    /// budget; the cache instead accounts its own storage explicitly
    /// ([`super::prefix_cache::PrefixCache`]) and adopters acquire their
    /// own full lease through normal capacity-gated admission.
    pub fn snapshot(&self) -> KvManager {
        KvManager {
            layers: self.layers.clone(),
            cfg: self.cfg.clone(),
            seq_len: self.seq_len,
            evict_bytes: self.evict_bytes,
            node: self.node,
            shard: self.shard.clone(),
            lease: None,
        }
    }

    /// GPU window blocks *actually occupied* across layers (block-aligned
    /// ceiling of each window's valid length) — what a cached snapshot
    /// costs the pool, as opposed to [`KvManager::blocks_needed`], the
    /// full-window worst case a live sequence leases.
    pub fn blocks_in_windows(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.gpu.len.div_ceil(l.gpu.blk_size))
            .sum()
    }

    /// Re-anchor a snapshot's NUMA placement at `node` of `topo`: recompute
    /// the head shard map and rewrite every layer's `node_of` record. Pure
    /// placement metadata — slab contents are untouched (and still shared
    /// with the snapshot), which is why adoption stays bitwise-identical
    /// across topologies. Used when a sequence on node B adopts a prefix
    /// cached from a sequence that lived on node A.
    pub fn reanchor(&mut self, topo: &Topology, node: NodeId) {
        let heads = self.shard.len();
        self.node = node;
        self.shard = topo.shard_heads(heads, node);
        for l in &mut self.layers {
            l.cpu.node_of = self.shard.clone();
        }
    }

    /// Make room in layer `li` for `n_new` entries, offloading evicted
    /// blocks to the CPU store with evict-time selection (Algorithm 1
    /// lines 10–14 + 23–25). Returns evicted byte count (for transfer
    /// accounting).
    pub fn make_room(&mut self, li: usize, n_new: usize) -> usize {
        let layer = &mut self.layers[li];
        let nb = layer.gpu.blocks_to_evict(n_new);
        if nb == 0 {
            return 0;
        }
        let denom = layer.gpu.window();
        let blk = layer.gpu.evict(nb);
        let bytes = blk.size_bytes();
        layer.cpu.add_evicted(&blk, self.cfg.beta, denom);
        self.evict_bytes += bytes as u64;
        bytes
    }

    /// Append new KV entries to layer `li`'s GPU window.
    pub fn append(&mut self, li: usize, k_new: &[f32], v_new: &[f32], positions: &[usize]) {
        self.layers[li].gpu.append(k_new, v_new, positions);
    }

    /// Window state consumed by the attention artifact.
    pub fn window_len(&self, li: usize) -> usize {
        self.layers[li].gpu.len
    }

    /// Advance the sequence counter after all layers processed a step.
    pub fn advance(&mut self, n_tokens: usize) {
        self.seq_len += n_tokens;
    }

    /// Memory accounting (paper metric: peak KV memory).
    pub fn gpu_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.gpu.size_bytes()).sum()
    }

    /// CPU-resident KV bytes across layers.
    pub fn cpu_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.cpu.size_bytes()).sum()
    }

    /// Average per-head selected fraction across layers (sparsity metric).
    pub fn mean_selectivity(&self) -> f32 {
        let mut total = 0.0;
        let mut count = 0;
        for l in &self.layers {
            if l.cpu.is_empty() {
                continue;
            }
            for s in l.cpu.selectivity() {
                total += s;
                count += 1;
            }
        }
        if count == 0 {
            0.0
        } else {
            total / count as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::trained;

    fn mk() -> KvManager {
        let model = trained("tiny-small").unwrap(); // 2 layers, 2 heads, dh 32
        let cfg = HgcaConfig {
            blk_size: 2,
            blk_num: 2,
            ..Default::default()
        };
        KvManager::new(&model, &cfg)
    }

    fn kv(n: usize, heads: usize, dh: usize, val: f32) -> (Vec<f32>, Vec<f32>) {
        (vec![val; heads * n * dh], vec![-val; heads * n * dh])
    }

    #[test]
    fn fills_window_before_evicting() {
        let mut m = mk();
        let (k, v) = kv(1, 2, 32, 1.0);
        for t in 0..4 {
            assert_eq!(m.make_room(0, 1), 0);
            m.append(0, &k, &v, &[t]);
        }
        assert_eq!(m.window_len(0), 4);
        assert!(m.layers[0].cpu.is_empty());
    }

    #[test]
    fn eviction_flows_to_cpu_store() {
        let mut m = mk();
        let (k, v) = kv(1, 2, 32, 1.0);
        for t in 0..5 {
            m.make_room(0, 1);
            m.append(0, &k, &v, &[t]);
        }
        // 5th append forced one block (2 entries) out
        assert_eq!(m.window_len(0), 3);
        assert_eq!(m.layers[0].cpu.len(), 2);
        assert!(m.evict_bytes > 0);
    }

    #[test]
    fn layers_are_independent() {
        let mut m = mk();
        let (k, v) = kv(1, 2, 32, 1.0);
        for t in 0..5 {
            m.make_room(0, 1);
            m.append(0, &k, &v, &[t]);
        }
        assert_eq!(m.window_len(1), 0);
        assert!(m.layers[1].cpu.is_empty());
    }

    #[test]
    fn chunk_append_evicts_multiple_blocks() {
        let mut m = mk();
        let (k, v) = kv(3, 2, 32, 1.0);
        let pos: Vec<usize> = (0..3).collect();
        m.make_room(0, 3);
        m.append(0, &k, &v, &pos);
        // now 3 in window (cap 4); appending 3 more → need 2 evicted → 1 block
        let (k2, v2) = kv(3, 2, 32, 2.0);
        let pos2: Vec<usize> = (3..6).collect();
        m.make_room(0, 3);
        assert_eq!(m.window_len(0), 1);
        m.append(0, &k2, &v2, &pos2);
        assert_eq!(m.window_len(0), 4);
        assert_eq!(m.layers[0].cpu.len(), 2);
    }

    #[test]
    fn memory_accounting_nonzero() {
        let m = mk();
        assert!(m.gpu_bytes() > 0);
        assert_eq!(m.cpu_bytes(), 0);
    }

    #[test]
    fn attached_lease_returns_blocks_on_drop() {
        let pool = Arc::new(crate::kv::GpuBlockPool::with_capacity(4));
        let mut m = mk(); // 2 layers × blk_num 2 → 4 blocks
        assert_eq!(m.blocks_needed(), 4);
        let lease = pool.try_acquire(m.blocks_needed()).expect("fits exactly");
        m.attach_lease(lease);
        assert_eq!(m.leased_blocks(), 4);
        assert!(pool.try_acquire(1).is_none(), "pool exhausted");
        drop(m);
        assert_eq!(pool.in_use(), 0);
        assert_eq!(pool.free_blocks(), Some(4));
    }

    #[test]
    fn placed_manager_shards_heads_from_its_home_node() {
        let model = trained("tiny-small").unwrap(); // 2 layers, 2 heads
        let cfg = HgcaConfig::default();
        let topo = Topology::synthetic(4);
        let m = KvManager::new_on(&model, &cfg, &topo, 2);
        assert_eq!(m.node, 2);
        assert_eq!(m.shard(), &[2, 3], "round-robin anchored at the home node");
        assert_eq!(m.node_of_head(1), 3);
        for l in &m.layers {
            assert_eq!(l.cpu.node_of, vec![2, 3], "every layer records the map");
        }
        // flat construction is the single-node special case
        let flat = KvManager::new(&model, &cfg);
        assert_eq!(flat.node, 0);
        assert_eq!(flat.shard(), &[0, 0]);
    }

    #[test]
    fn node_placed_lease_accounts_on_its_budget() {
        let model = trained("tiny-small").unwrap();
        let cfg = HgcaConfig {
            blk_size: 2,
            blk_num: 2,
            ..Default::default()
        };
        let topo = Topology::synthetic(2);
        let pool = Arc::new(crate::kv::GpuBlockPool::with_node_budgets(vec![4, 4]));
        let mut m = KvManager::new_on(&model, &cfg, &topo, 1);
        let lease = pool.try_acquire_on(1, m.blocks_needed()).expect("node 1 fits");
        m.attach_lease(lease);
        assert_eq!(pool.in_use_on(1), 4);
        assert_eq!(pool.in_use_on(0), 0);
        drop(m);
        assert_eq!(pool.in_use_on(1), 0, "retirement restores the home budget");
    }

    #[test]
    fn snapshot_is_lease_free_and_reanchor_moves_placement_only() {
        let pool = Arc::new(crate::kv::GpuBlockPool::with_capacity(4));
        let mut m = mk();
        let (k, v) = kv(1, 2, 32, 1.0);
        for t in 0..3 {
            m.make_room(0, 1);
            m.append(0, &k, &v, &[t]);
        }
        m.advance(3);
        m.attach_lease(pool.try_acquire(m.blocks_needed()).unwrap());
        let snap = m.snapshot();
        assert_eq!(snap.leased_blocks(), 0, "snapshots never hold pool blocks");
        assert_eq!(pool.in_use(), 4, "snapshotting acquires nothing");
        assert_eq!(snap.seq_len, 3);
        assert_eq!(&*snap.layers[0].gpu.k, &*m.layers[0].gpu.k);
        // occupied: layer 0 has 3 entries (blk_size 2 → 2 blocks), layer 1 none
        assert_eq!(snap.blocks_in_windows(), 2);
        // re-anchoring rewrites the shard map but not the slabs
        let mut moved = snap.snapshot();
        moved.reanchor(&Topology::synthetic(2), 1);
        assert_eq!(moved.node, 1);
        assert_eq!(moved.shard(), &[1, 0]);
        assert_eq!(moved.layers[1].cpu.node_of, vec![1, 0]);
        assert_eq!(&*moved.layers[0].gpu.k, &*snap.layers[0].gpu.k);
        drop(m);
        assert_eq!(pool.in_use(), 0, "only the live sequence held blocks");
    }

    #[test]
    fn lease_returns_blocks_on_drop() {
        let pool = Arc::new(crate::kv::GpuBlockPool::new());
        let mut m = mk(); // 2 layers × blk_num 2 → 4 blocks
        assert_eq!(m.leased_blocks(), 0);
        m.lease_from(&pool);
        assert_eq!(m.leased_blocks(), 4);
        assert_eq!(pool.in_use(), 4);
        drop(m);
        assert_eq!(pool.in_use(), 0);
        assert_eq!(pool.reclaimed_blocks(), 4);
    }
}
