//! Per-sequence KV manager: glues the GPU window and CPU store per layer
//! and implements the full Algorithm 1 flow for decode and append steps.

use std::sync::Arc;

use crate::config::{HgcaConfig, ModelConfig};

use super::cpu_store::CpuLayerStore;
use super::gpu_pool::{BlockLease, GpuBlockPool, GpuLayerCache};

/// One layer's split KV state: the GPU window + the CPU store.
#[derive(Debug, Clone)]
pub struct LayerKv {
    /// Recent entries, resident on the "GPU" (the artifact's k_win/v_win).
    pub gpu: GpuLayerCache,
    /// Evicted entries + the selected contextual cache, resident on the CPU.
    pub cpu: CpuLayerStore,
}

/// KV state for one sequence across all layers.
#[derive(Debug, Clone)]
pub struct KvManager {
    /// Per-layer GPU window + CPU store.
    pub layers: Vec<LayerKv>,
    /// The HGCA tunables this manager was built with.
    pub cfg: HgcaConfig,
    /// total tokens absorbed so far (= next position)
    pub seq_len: usize,
    /// cumulative bytes moved over the (simulated) PCIe link by evictions
    pub evict_bytes: u64,
    /// GPU block lease held against the engine's [`GpuBlockPool`];
    /// dropping the manager (sequence retirement — normal or early)
    /// returns the blocks to the pool
    lease: Option<BlockLease>,
}

impl KvManager {
    /// Empty KV state for one sequence of `model` under `cfg`.
    pub fn new(model: &ModelConfig, cfg: &HgcaConfig) -> KvManager {
        let layers = (0..model.n_layers)
            .map(|_| LayerKv {
                gpu: GpuLayerCache::new(
                    model.n_heads,
                    model.d_head(),
                    cfg.blk_size,
                    cfg.blk_num,
                    cfg.alpha,
                ),
                cpu: CpuLayerStore::new(model.n_heads, model.d_head()),
            })
            .collect();
        KvManager {
            layers,
            cfg: cfg.clone(),
            seq_len: 0,
            evict_bytes: 0,
            lease: None,
        }
    }

    /// GPU window blocks this manager needs to lease (`n_layers × blk_num`)
    /// — the admission currency of a capacity-bounded pool.
    pub fn blocks_needed(&self) -> usize {
        self.layers.len() * self.cfg.blk_num
    }

    /// Lease this manager's GPU window blocks (`n_layers × blk_num`) from
    /// `pool`, bypassing any capacity bound (force acquire — standalone
    /// engines and tests). The lease is released when the manager drops, so
    /// retiring a sequence — finished, cancelled, expired, or disconnected
    /// — restores the pool's free count (observable via
    /// [`GpuBlockPool::in_use`]). Capacity-gated admission goes through
    /// [`GpuBlockPool::try_acquire`] + [`KvManager::attach_lease`] instead.
    pub fn lease_from(&mut self, pool: &Arc<GpuBlockPool>) {
        self.lease = Some(pool.acquire(self.blocks_needed()));
    }

    /// Attach a lease acquired up front (capacity-gated admission: the
    /// scheduler acquires via [`GpuBlockPool::try_acquire`] *before*
    /// building the sequence, so a failed acquisition allocates nothing).
    /// Any previously held lease is released.
    pub fn attach_lease(&mut self, lease: BlockLease) {
        debug_assert_eq!(lease.blocks(), self.blocks_needed());
        self.lease = Some(lease);
    }

    /// Blocks currently leased from the engine's pool (0 when unleased).
    pub fn leased_blocks(&self) -> usize {
        self.lease.as_ref().map_or(0, BlockLease::blocks)
    }

    /// Make room in layer `li` for `n_new` entries, offloading evicted
    /// blocks to the CPU store with evict-time selection (Algorithm 1
    /// lines 10–14 + 23–25). Returns evicted byte count (for transfer
    /// accounting).
    pub fn make_room(&mut self, li: usize, n_new: usize) -> usize {
        let layer = &mut self.layers[li];
        let nb = layer.gpu.blocks_to_evict(n_new);
        if nb == 0 {
            return 0;
        }
        let denom = layer.gpu.window();
        let blk = layer.gpu.evict(nb);
        let bytes = blk.size_bytes();
        layer.cpu.add_evicted(&blk, self.cfg.beta, denom);
        self.evict_bytes += bytes as u64;
        bytes
    }

    /// Append new KV entries to layer `li`'s GPU window.
    pub fn append(&mut self, li: usize, k_new: &[f32], v_new: &[f32], positions: &[usize]) {
        self.layers[li].gpu.append(k_new, v_new, positions);
    }

    /// Window state consumed by the attention artifact.
    pub fn window_len(&self, li: usize) -> usize {
        self.layers[li].gpu.len
    }

    /// Advance the sequence counter after all layers processed a step.
    pub fn advance(&mut self, n_tokens: usize) {
        self.seq_len += n_tokens;
    }

    /// Memory accounting (paper metric: peak KV memory).
    pub fn gpu_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.gpu.size_bytes()).sum()
    }

    /// CPU-resident KV bytes across layers.
    pub fn cpu_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.cpu.size_bytes()).sum()
    }

    /// Average per-head selected fraction across layers (sparsity metric).
    pub fn mean_selectivity(&self) -> f32 {
        let mut total = 0.0;
        let mut count = 0;
        for l in &self.layers {
            if l.cpu.is_empty() {
                continue;
            }
            for s in l.cpu.selectivity() {
                total += s;
                count += 1;
            }
        }
        if count == 0 {
            0.0
        } else {
            total / count as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::trained;

    fn mk() -> KvManager {
        let model = trained("tiny-small").unwrap(); // 2 layers, 2 heads, dh 32
        let cfg = HgcaConfig {
            blk_size: 2,
            blk_num: 2,
            ..Default::default()
        };
        KvManager::new(&model, &cfg)
    }

    fn kv(n: usize, heads: usize, dh: usize, val: f32) -> (Vec<f32>, Vec<f32>) {
        (vec![val; heads * n * dh], vec![-val; heads * n * dh])
    }

    #[test]
    fn fills_window_before_evicting() {
        let mut m = mk();
        let (k, v) = kv(1, 2, 32, 1.0);
        for t in 0..4 {
            assert_eq!(m.make_room(0, 1), 0);
            m.append(0, &k, &v, &[t]);
        }
        assert_eq!(m.window_len(0), 4);
        assert!(m.layers[0].cpu.is_empty());
    }

    #[test]
    fn eviction_flows_to_cpu_store() {
        let mut m = mk();
        let (k, v) = kv(1, 2, 32, 1.0);
        for t in 0..5 {
            m.make_room(0, 1);
            m.append(0, &k, &v, &[t]);
        }
        // 5th append forced one block (2 entries) out
        assert_eq!(m.window_len(0), 3);
        assert_eq!(m.layers[0].cpu.len(), 2);
        assert!(m.evict_bytes > 0);
    }

    #[test]
    fn layers_are_independent() {
        let mut m = mk();
        let (k, v) = kv(1, 2, 32, 1.0);
        for t in 0..5 {
            m.make_room(0, 1);
            m.append(0, &k, &v, &[t]);
        }
        assert_eq!(m.window_len(1), 0);
        assert!(m.layers[1].cpu.is_empty());
    }

    #[test]
    fn chunk_append_evicts_multiple_blocks() {
        let mut m = mk();
        let (k, v) = kv(3, 2, 32, 1.0);
        let pos: Vec<usize> = (0..3).collect();
        m.make_room(0, 3);
        m.append(0, &k, &v, &pos);
        // now 3 in window (cap 4); appending 3 more → need 2 evicted → 1 block
        let (k2, v2) = kv(3, 2, 32, 2.0);
        let pos2: Vec<usize> = (3..6).collect();
        m.make_room(0, 3);
        assert_eq!(m.window_len(0), 1);
        m.append(0, &k2, &v2, &pos2);
        assert_eq!(m.window_len(0), 4);
        assert_eq!(m.layers[0].cpu.len(), 2);
    }

    #[test]
    fn memory_accounting_nonzero() {
        let m = mk();
        assert!(m.gpu_bytes() > 0);
        assert_eq!(m.cpu_bytes(), 0);
    }

    #[test]
    fn attached_lease_returns_blocks_on_drop() {
        let pool = Arc::new(crate::kv::GpuBlockPool::with_capacity(4));
        let mut m = mk(); // 2 layers × blk_num 2 → 4 blocks
        assert_eq!(m.blocks_needed(), 4);
        let lease = pool.try_acquire(m.blocks_needed()).expect("fits exactly");
        m.attach_lease(lease);
        assert_eq!(m.leased_blocks(), 4);
        assert!(pool.try_acquire(1).is_none(), "pool exhausted");
        drop(m);
        assert_eq!(pool.in_use(), 0);
        assert_eq!(pool.free_blocks(), Some(4));
    }

    #[test]
    fn lease_returns_blocks_on_drop() {
        let pool = Arc::new(crate::kv::GpuBlockPool::new());
        let mut m = mk(); // 2 layers × blk_num 2 → 4 blocks
        assert_eq!(m.leased_blocks(), 0);
        m.lease_from(&pool);
        assert_eq!(m.leased_blocks(), 4);
        assert_eq!(pool.in_use(), 4);
        drop(m);
        assert_eq!(pool.in_use(), 0);
        assert_eq!(pool.reclaimed_blocks(), 4);
    }
}
