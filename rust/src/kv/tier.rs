//! Per-head KV tier selection (the HeadInfer-style half of the tiered-KV
//! tentpole): pick, per head, how its CPU-resident KV is stored —
//! [`HeadTier::F32`], [`HeadTier::Int8`], or [`HeadTier::WindowOnly`] —
//! from the head's observed attention-mass distribution, reusing the
//! saliency statistics of `analysis/attn_stats.rs` (`coverage_per_head`,
//! `top_decile_mass`) over the store's MAW rows.
//!
//! The global override (`hgca serve --kv-tier {f32,int8,auto}`) maps to
//! [`TierMode`]: `F32` disables tiering entirely (the default — bitwise
//! identical to the pre-tier engine), `Int8` quantizes every head, and
//! `Auto` decides per head:
//!
//! * **diffuse** heads (high 90%-mass coverage — attention spread over
//!   many entries) go `Int8`: per-entry rounding error washes out across
//!   the many attended entries, and diffuse heads are exactly the ones
//!   whose stores grow largest, so they buy the most capacity;
//! * **extremely peaked** heads (tiny coverage *and* top-decile mass ≈
//!   everything) go `WindowOnly`: their old-context mass rides on a
//!   handful of entries already favored by the β-selection window, so
//!   dropping the long tail costs the least;
//! * everything else stays `F32`.
//!
//! Decisions defer until a head has seen [`TierPolicy::min_entries`]
//! evicted entries — tiering on a near-empty store would read noise.
//! Applied tiers ratchet one way ([`CpuLayerStore::set_tier`]).

use crate::analysis::{coverage_per_head, top_decile_mass};

use super::cpu_store::{CpuLayerStore, HeadTier};

/// Global tier override (the `--kv-tier` flag; see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TierMode {
    /// No tiering: every head stays on the f32 path (bitwise-identical
    /// tokens to the pre-tier engine).
    #[default]
    F32,
    /// Quantize every head's CPU-resident KV to int8.
    Int8,
    /// Per-head decisions from the saliency stats (module docs).
    Auto,
}

impl TierMode {
    /// Parse the `--kv-tier` flag value.
    pub fn parse(s: &str) -> anyhow::Result<TierMode> {
        Ok(match s {
            "f32" => TierMode::F32,
            "int8" => TierMode::Int8,
            "auto" => TierMode::Auto,
            other => anyhow::bail!("unknown kv tier '{other}' (expected f32|int8|auto)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TierMode::F32 => "f32",
            TierMode::Int8 => "int8",
            TierMode::Auto => "auto",
        }
    }
}

/// Per-head tier chooser. Stateless: [`TierPolicy::decide`] reads the
/// store's MAW rows fresh every call, and [`TierPolicy::apply`] feeds the
/// decisions through the store's one-way ratchet.
#[derive(Debug, Clone)]
pub struct TierPolicy {
    pub mode: TierMode,
    /// Entries a head must hold before `Auto` decides (noise gate).
    pub min_entries: usize,
    /// `Auto`: coverage-to-reach-90%-mass above this ⇒ diffuse ⇒ `Int8`.
    pub diffuse_coverage: f32,
    /// `Auto`: coverage below this *and* top-decile mass above
    /// [`TierPolicy::peak_mass`] ⇒ `WindowOnly`.
    pub peak_coverage: f32,
    /// `Auto`: top-decile mass threshold for the `WindowOnly` branch.
    pub peak_mass: f32,
}

impl Default for TierPolicy {
    fn default() -> Self {
        TierPolicy {
            mode: TierMode::F32,
            min_entries: 64,
            diffuse_coverage: 0.5,
            peak_coverage: 0.05,
            peak_mass: 0.95,
        }
    }
}

impl TierPolicy {
    pub fn new(mode: TierMode) -> TierPolicy {
        TierPolicy {
            mode,
            ..TierPolicy::default()
        }
    }

    /// The target tier per head. `F32` mode returns all-`F32`; `Int8`
    /// returns all-`Int8` once past the noise gate; `Auto` maps each
    /// head's normalized MAW row through the saliency stats.
    pub fn decide(&self, store: &CpuLayerStore) -> Vec<HeadTier> {
        let n = store.len();
        if self.mode == TierMode::F32 || n < self.min_entries {
            return vec![HeadTier::F32; store.heads];
        }
        if self.mode == TierMode::Int8 {
            return vec![HeadTier::Int8; store.heads];
        }
        store
            .full
            .iter()
            .map(|hs| {
                // normalize a copy so the 90%-mass target is meaningful on
                // raw (un-normalized) MAW rows
                let sum: f32 = hs.maw.iter().sum();
                if sum <= 0.0 {
                    // no recorded mass: the diffuse case by convention
                    return HeadTier::Int8;
                }
                let row: Vec<f32> = hs.maw.iter().map(|m| m / sum).collect();
                let head_probs = vec![vec![row]];
                let cov = coverage_per_head(&head_probs, 0.9)[0];
                let peak = top_decile_mass(&head_probs);
                if cov > self.diffuse_coverage {
                    HeadTier::Int8
                } else if cov < self.peak_coverage && peak > self.peak_mass {
                    HeadTier::WindowOnly
                } else {
                    HeadTier::F32
                }
            })
            .collect()
    }

    /// Decide and apply through [`CpuLayerStore::set_tier`] (the one-way
    /// ratchet drops any decision that would loosen an earlier one).
    pub fn apply(&self, store: &mut CpuLayerStore) {
        if self.mode == TierMode::F32 {
            return; // fast path: never touches the store
        }
        for (h, tier) in self.decide(store).into_iter().enumerate() {
            store.set_tier(h, tier);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::KvBlock;

    fn store_with_maw(maws: &[Vec<f32>]) -> CpuLayerStore {
        let heads = maws.len();
        let dh = 2;
        let len = maws[0].len();
        let mut blk = KvBlock::new(heads, dh, len);
        for h in 0..heads {
            for t in 0..len {
                blk.maw[h * len + t] = maws[h][t];
                blk.k[(h * len + t) * dh] = (t + 1) as f32;
                blk.v[(h * len + t) * dh] = -((t + 1) as f32);
            }
        }
        let mut s = CpuLayerStore::new(heads, dh);
        s.add_evicted(&blk, 1.0, len * 2);
        s
    }

    fn diffuse_row(n: usize) -> Vec<f32> {
        vec![1.0 / n as f32; n]
    }

    fn peaked_row(n: usize) -> Vec<f32> {
        let mut r = vec![1e-6; n];
        r[0] = 1.0;
        r
    }

    #[test]
    fn f32_mode_never_tiers() {
        let mut s = store_with_maw(&[diffuse_row(128)]);
        TierPolicy::new(TierMode::F32).apply(&mut s);
        assert_eq!(s.tier_counts(), (1, 0, 0));
    }

    #[test]
    fn int8_mode_tiers_every_head_past_gate() {
        let mut s = store_with_maw(&[diffuse_row(128), peaked_row(128)]);
        TierPolicy::new(TierMode::Int8).apply(&mut s);
        assert_eq!(s.tier_counts(), (0, 2, 0));
    }

    #[test]
    fn min_entries_gates_decisions() {
        let s = store_with_maw(&[diffuse_row(8)]);
        let p = TierPolicy::new(TierMode::Int8);
        assert_eq!(p.decide(&s), vec![HeadTier::F32]);
    }

    #[test]
    fn auto_maps_diffuse_to_int8_and_peaked_to_window() {
        let n = 256;
        // middle head: ~95% of mass on 32 entries — coverage ≈ 0.12 sits
        // between the diffuse and peaked thresholds, so neither fires
        let mut mid = vec![0.05 / 224.0; n];
        for m in mid.iter_mut().take(32) {
            *m = 0.95 / 32.0;
        }
        let s = store_with_maw(&[diffuse_row(n), peaked_row(n), mid]);
        let p = TierPolicy::new(TierMode::Auto);
        let tiers = p.decide(&s);
        assert_eq!(tiers[0], HeadTier::Int8, "uniform head is diffuse");
        assert_eq!(tiers[1], HeadTier::WindowOnly, "single-spike head");
        assert_eq!(tiers[2], HeadTier::F32, "in-between head stays f32");
    }

    #[test]
    fn zero_mass_head_defaults_to_int8() {
        let s = store_with_maw(&[vec![0.0; 128]]);
        let p = TierPolicy::new(TierMode::Auto);
        assert_eq!(p.decide(&s), vec![HeadTier::Int8]);
    }
}
