//! Locality-aware KV cache management (paper §3.2, Algorithm 1).

pub mod block;
pub mod cpu_store;
pub mod gpu_pool;
pub mod manager;

pub use block::KvBlock;
pub use cpu_store::CpuLayerStore;
pub use gpu_pool::GpuLayerCache;
pub use manager::KvManager;
