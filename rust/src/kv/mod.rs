//! Locality-aware KV cache management (paper §3.2, Algorithm 1).
//!
//! Per (sequence, layer), KV entries live in exactly one of two places:
//! the recent-window [`GpuLayerCache`] (a circular block buffer that is the
//! attention artifact's `k_win`/`v_win` input) or the [`CpuLayerStore`]
//! (every evicted entry, plus the β-threshold-selected *contextual cache*
//! the CPU sparse attention reads). [`KvBlock`] is the eviction granule;
//! [`KvManager`] glues the two halves per layer and does the Algorithm 1
//! bookkeeping (make-room, append, MAW advance).

pub mod block;
pub mod cow;
pub mod cpu_store;
pub mod gpu_pool;
pub mod manager;
pub mod prefix_cache;
pub mod quant;
pub mod tier;

pub use block::KvBlock;
pub use cow::CowVec;
pub use cpu_store::{CpuLayerStore, HeadTier};
pub use gpu_pool::{BlockLease, GpuBlockPool, GpuLayerCache};
pub use manager::KvManager;
pub use prefix_cache::{PrefixCache, PrefixStats};
pub use quant::{QuantSlab, QUANT_BLOCK};
pub use tier::{TierMode, TierPolicy};
