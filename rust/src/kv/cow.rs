//! Copy-on-write vectors for shared KV snapshots.
//!
//! The prefix cache (`kv/prefix_cache.rs`) stores a full [`KvManager`]
//! snapshot per cached prefix, and every adopting sequence starts from a
//! clone of that snapshot. The bulk of a snapshot is the per-layer KV
//! slabs — the GPU window's `k`/`v`/`pos` buffers and the CPU full
//! store's per-head `k`/`v`/`pos` — which an adopter only *extends or
//! rewrites lazily* as its own generation diverges. [`CowVec`] makes the
//! snapshot clone O(1) per buffer (an `Arc` bump) and defers the byte
//! copy to the first mutation (`Arc::make_mut`), so N sequences sharing
//! a hot system prompt share one physical copy of its KV until each
//! actually appends past it.
//!
//! Reads go through `Deref<Target = [T]>`, so slice-indexing call sites
//! (`&store.k[a..b]`) are untouched; mutation sites call
//! [`CowVec::make_mut`] explicitly, which is the complete audit surface
//! for "who pays the copy".

use std::ops::Deref;
use std::sync::Arc;

/// A clone-on-write growable buffer: cloning is an `Arc` bump; the first
/// mutation after a clone copies the storage (standard `Arc::make_mut`
/// semantics — unique owners mutate in place with zero overhead).
#[derive(Debug, Clone, Default)]
pub struct CowVec<T: Clone>(Arc<Vec<T>>);

impl<T: Clone> CowVec<T> {
    pub fn new() -> Self {
        CowVec(Arc::new(Vec::new()))
    }

    /// Mutable access to the underlying vector, copying it first iff the
    /// storage is currently shared with another `CowVec` clone.
    pub fn make_mut(&mut self) -> &mut Vec<T> {
        Arc::make_mut(&mut self.0)
    }

    /// True when this buffer physically shares storage with another clone
    /// (diagnostic; used by the sharing assertions in the prefix-cache
    /// tests).
    pub fn is_shared(&self) -> bool {
        Arc::strong_count(&self.0) > 1
    }
}

impl<T: Clone> Deref for CowVec<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        &self.0
    }
}

impl<T: Clone> From<Vec<T>> for CowVec<T> {
    fn from(v: Vec<T>) -> Self {
        CowVec(Arc::new(v))
    }
}

impl<T: Clone + PartialEq> PartialEq for CowVec<T> {
    fn eq(&self, other: &Self) -> bool {
        **self == **other
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clone_shares_until_first_mutation() {
        let mut a: CowVec<u32> = vec![1, 2, 3].into();
        let b = a.clone();
        assert!(a.is_shared() && b.is_shared());
        assert_eq!(&*a, &*b);
        a.make_mut().push(4);
        assert!(!a.is_shared() && !b.is_shared());
        assert_eq!(&*a, &[1, 2, 3, 4]);
        assert_eq!(&*b, &[1, 2, 3], "the other clone keeps the snapshot");
    }

    #[test]
    fn unique_owner_mutates_in_place() {
        let mut a: CowVec<u8> = vec![7].into();
        let before = a.as_ptr();
        a.make_mut()[0] = 9;
        assert_eq!(a.as_ptr(), before, "no copy without a second owner");
        assert_eq!(a[0], 9);
    }

    #[test]
    fn deref_supports_slicing() {
        let a: CowVec<f32> = vec![0.0, 1.0, 2.0, 3.0].into();
        assert_eq!(&a[1..3], &[1.0, 2.0]);
        assert_eq!(a.len(), 4);
        assert!(!CowVec::<f32>::new().is_shared());
    }
}
