//! KV block: the eviction granule (Algorithm 1 footnote: offloads are
//! batched at block granularity to amortize PCIe cost).

/// A block of `len` KV entries for all heads of one layer, head-major:
/// `k[h * len * d_head + t * d_head + j]`. MAW travels with the block
/// (Algorithm 1 line 13: eviction transfers KV + A_evict together).
#[derive(Debug, Clone, PartialEq)]
pub struct KvBlock {
    pub heads: usize,
    pub d_head: usize,
    pub len: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// maw[h * len + t] — per-head moving-average attention weight.
    pub maw: Vec<f32>,
    /// Global token position of each entry (chronological).
    pub pos: Vec<usize>,
}

impl KvBlock {
    /// A zeroed block of `len` entries per head.
    pub fn new(heads: usize, d_head: usize, len: usize) -> KvBlock {
        KvBlock {
            heads,
            d_head,
            len,
            k: vec![0.0; heads * len * d_head],
            v: vec![0.0; heads * len * d_head],
            maw: vec![0.0; heads * len],
            pos: vec![0; len],
        }
    }

    /// Key vector of one (head, entry).
    pub fn k_at(&self, h: usize, t: usize) -> &[f32] {
        let o = (h * self.len + t) * self.d_head;
        &self.k[o..o + self.d_head]
    }

    /// Value vector of one (head, entry).
    pub fn v_at(&self, h: usize, t: usize) -> &[f32] {
        let o = (h * self.len + t) * self.d_head;
        &self.v[o..o + self.d_head]
    }

    /// MAW of one (head, entry).
    pub fn maw_at(&self, h: usize, t: usize) -> f32 {
        self.maw[h * self.len + t]
    }

    /// Transfer size (the simulated PCIe eviction cost is charged on this).
    pub fn size_bytes(&self) -> usize {
        (self.k.len() + self.v.len() + self.maw.len()) * 4 + self.pos.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_head_major() {
        let mut b = KvBlock::new(2, 3, 4);
        b.k[(1 * 4 + 2) * 3] = 7.0; // head 1, entry 2, dim 0
        assert_eq!(b.k_at(1, 2)[0], 7.0);
        assert_eq!(b.k_at(0, 0), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn size_accounting() {
        let b = KvBlock::new(4, 32, 16);
        // 2 * 4*16*32 f32 + 4*16 maw f32 + 16 pos u64
        assert_eq!(b.size_bytes(), (2 * 4 * 16 * 32 + 4 * 16) * 4 + 16 * 8);
    }
}
