//! CPU-side per-layer KV store (Algorithm 1, CPU half; §3.2.2).
//!
//! Holds every evicted KV entry (nothing is ever dropped — entries below
//! the threshold stay available for re-evaluation) plus the *contextual
//! cache*: the per-head subset selected by the β-threshold rule
//!
//! ```text
//! keep(h, i)  ⇔  maw[h][i] > β / denom
//! ```
//!
//! where denom is the GPU window length at evict-time selection and the
//! CPU cache length at append-time re-evaluation (Algorithm 1 lines 19–24).
//! Selected entries are stored contiguously per head (§3.3: contiguous
//! arrangement enables efficient parallel CPU attention), with MAW
//! re-normalized to sum to 1 per head.

use crate::topology::NodeId;

use super::block::KvBlock;
use super::cow::CowVec;
use super::quant::{QuantSlab, QUANT_BLOCK};

/// Storage tier of one head's CPU-resident KV (the tiered-KV tentpole).
/// Tiers only ever *tighten* (`F32 → Int8 → WindowOnly`) — see
/// [`CpuLayerStore::set_tier`] — so a head's numerics never silently gain
/// precision mid-sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HeadTier {
    /// Full-precision f32 slabs — today's path, bitwise unchanged.
    #[default]
    F32,
    /// Symmetric int8 with per-block scales ([`QuantSlab`]); dot products
    /// run against the quantized bytes (one i32 accumulation + one scale
    /// multiply — no dequantized copy).
    Int8,
    /// Entries beyond the GPU window are dropped for this head: MAW/pos
    /// bookkeeping is kept (so the store's per-head length invariant and
    /// re-evaluation shapes survive) but no K/V bytes are stored and the
    /// contextual cache stays empty.
    WindowOnly,
}

/// Per-head growable KV arrays.
///
/// The `k`/`v`/`pos` slabs are [`CowVec`]s so a prefix-cache snapshot
/// shares them with every adopting sequence at zero copy cost; `maw` is
/// rewritten by every append-time re-evaluation, so sharing it would
/// only defer a copy that always happens — it stays a plain `Vec`.
#[derive(Debug, Clone, Default)]
pub struct HeadStore {
    pub k: CowVec<f32>,   // [n][dh] row-major
    pub v: CowVec<f32>,
    pub maw: Vec<f32>, // [n]
    pub pos: CowVec<usize>,
    /// Storage tier ([`HeadTier::F32`] keeps this head on the pre-tier
    /// path bit for bit).
    pub tier: HeadTier,
    /// Int8 K slab (`Some` iff `tier == Int8`; `k` is empty then).
    pub qk: Option<QuantSlab>,
    /// Int8 V slab (`Some` iff `tier == Int8`; `v` is empty then).
    pub qv: Option<QuantSlab>,
}

impl HeadStore {
    /// Entries stored for this head.
    pub fn len(&self) -> usize {
        self.maw.len()
    }
    /// True when no entries have been evicted to this head yet.
    pub fn is_empty(&self) -> bool {
        self.maw.is_empty()
    }
}

/// Contiguous per-head contextual cache (the sparse-attention working set).
#[derive(Debug, Clone, Default)]
pub struct HeadCtx {
    /// indices into the head's full store
    pub idx: Vec<u32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// re-normalized MAW (sums to 1 per head when non-empty)
    pub maw: Vec<f32>,
    /// Packed int8 K for an `Int8`-tier head (per-entry scales — the
    /// bytes/scales are copied from the full-store slab, so packing adds
    /// no quantization error). `k`/`v` stay empty then.
    pub qk: Option<QuantSlab>,
    /// Packed int8 V for an `Int8`-tier head.
    pub qv: Option<QuantSlab>,
}

impl HeadCtx {
    /// Selected entries for this head.
    pub fn len(&self) -> usize {
        self.idx.len()
    }
    /// True when the β-threshold selected nothing for this head.
    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }
}

/// The CPU half of one layer's KV state: every evicted entry per head
/// (`full`) plus the contiguous selected subset (`ctx`) the sparse
/// attention actually reads.
///
/// Head slabs are **sharded across NUMA nodes**: `node_of[h]` names the
/// node that owns head `h`'s slabs (round-robined by the topology's shard
/// map — see [`crate::topology::Topology::shard_heads`]), so the engine
/// can dispatch each head's `sparse_attention*` job to the worker queue
/// whose pinned workers read the slab from local memory. The map is
/// placement metadata: slab *contents* and selection numerics are
/// identical on every topology (a flat store maps every head to node 0).
#[derive(Debug, Clone)]
pub struct CpuLayerStore {
    /// Attention heads.
    pub heads: usize,
    /// Head dimension.
    pub d_head: usize,
    /// Per-head full store (nothing is ever dropped).
    pub full: Vec<HeadStore>,
    /// Per-head contextual cache (the β-selected working set).
    pub ctx: Vec<HeadCtx>,
    /// Per-head owning NUMA node (len == `heads`; all 0 when flat).
    pub node_of: Vec<NodeId>,
}

impl CpuLayerStore {
    /// An empty flat store for `heads` heads (every slab on node 0 — the
    /// single-domain layout every pre-NUMA caller gets).
    pub fn new(heads: usize, d_head: usize) -> Self {
        CpuLayerStore::new_sharded(heads, d_head, vec![0; heads])
    }

    /// An empty store whose head slabs are sharded per `node_of`
    /// (`node_of[h]` = the NUMA node owning head `h`'s slabs). Panics when
    /// the map length does not match `heads`.
    pub fn new_sharded(heads: usize, d_head: usize, node_of: Vec<NodeId>) -> Self {
        assert_eq!(node_of.len(), heads, "shard map must cover every head");
        CpuLayerStore {
            heads,
            d_head,
            full: (0..heads).map(|_| HeadStore::default()).collect(),
            ctx: (0..heads).map(|_| HeadCtx::default()).collect(),
            node_of,
        }
    }

    /// The NUMA node owning head `h`'s slabs.
    pub fn node_of_head(&self, h: usize) -> NodeId {
        self.node_of[h]
    }

    /// Entries per head (identical across heads — eviction is whole-block).
    pub fn len(&self) -> usize {
        self.full[0].len()
    }

    /// True while nothing has been evicted to this layer.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total selected entries across heads (sparsity diagnostics).
    pub fn ctx_len_total(&self) -> usize {
        self.ctx.iter().map(|c| c.len()).sum()
    }

    /// Absorb an evicted block and immediately run evict-time selection on
    /// the *incoming* entries (Algorithm 1 lines 23–25): salient newcomers
    /// join the contextual cache; everything joins the full store.
    /// `denom` is the GPU window length (A_gpu.size).
    pub fn add_evicted(&mut self, blk: &KvBlock, beta: f32, denom: usize) {
        assert_eq!(blk.heads, self.heads);
        assert_eq!(blk.d_head, self.d_head);
        let dh = self.d_head;
        let threshold = beta / denom.max(1) as f32;
        for h in 0..self.heads {
            let start = self.full[h].len();
            let hk = &blk.k[h * blk.len * dh..(h + 1) * blk.len * dh];
            let hv = &blk.v[h * blk.len * dh..(h + 1) * blk.len * dh];
            match self.full[h].tier {
                HeadTier::F32 => {
                    self.full[h].k.make_mut().extend_from_slice(hk);
                    self.full[h].v.make_mut().extend_from_slice(hv);
                }
                HeadTier::Int8 => {
                    // push_entries re-quantizes the partial tail block from
                    // its staged f32 originals, so the block scale always
                    // covers every entry it spans (never stale)
                    self.full[h].qk.as_mut().expect("int8 slab").push_entries(hk);
                    self.full[h].qv.as_mut().expect("int8 slab").push_entries(hv);
                }
                HeadTier::WindowOnly => {} // bytes dropped; bookkeeping below
            }
            self.full[h]
                .maw
                .extend_from_slice(&blk.maw[h * blk.len..(h + 1) * blk.len]);
            self.full[h].pos.make_mut().extend_from_slice(&blk.pos);
            // select salient newcomers into the contextual cache
            if self.full[h].tier != HeadTier::WindowOnly {
                for t in 0..blk.len {
                    if blk.maw_at(h, t) > threshold {
                        let i = start + t;
                        self.ctx[h].idx.push(i as u32);
                        match self.full[h].tier {
                            HeadTier::F32 => {
                                self.ctx[h].k.extend_from_slice(&hk[t * dh..(t + 1) * dh]);
                                self.ctx[h].v.extend_from_slice(&hv[t * dh..(t + 1) * dh]);
                            }
                            HeadTier::Int8 => {
                                // copy the just-quantized bytes + scales so
                                // the packed ctx serves the exact values the
                                // full store serves
                                let qk = self.full[h].qk.as_ref().expect("int8 slab");
                                let qv = self.full[h].qv.as_ref().expect("int8 slab");
                                let ck = self.ctx[h].qk.as_mut().expect("int8 ctx");
                                ck.push_quantized(qk.entry(i), qk.scale_of(i));
                                let cv = self.ctx[h].qv.as_mut().expect("int8 ctx");
                                cv.push_quantized(qv.entry(i), qv.scale_of(i));
                            }
                            HeadTier::WindowOnly => unreachable!(),
                        }
                        self.ctx[h].maw.push(blk.maw_at(h, t));
                    }
                }
            }
            Self::renormalize(&mut self.ctx[h].maw);
        }
    }

    /// Append-time re-evaluation (§3.2.2 "Re-evaluation"; Algorithm 1 lines
    /// 19–22): given fresh attention weights over the *full* CPU store
    /// (a_cpu[h * n + i]), rebuild each head's contextual cache. Previously
    /// pruned entries can be reinstated; stale ones are dropped.
    pub fn reevaluate(&mut self, a_cpu: &[f32], beta: f32) {
        let n = self.len();
        assert_eq!(a_cpu.len(), self.heads * n);
        let dh = self.d_head;
        let threshold = beta / n.max(1) as f32;
        for h in 0..self.heads {
            let store = &self.full[h];
            let tier = store.tier;
            let ctx = &mut self.ctx[h];
            ctx.idx.clear();
            ctx.k.clear();
            ctx.v.clear();
            ctx.maw.clear();
            if let Some(q) = ctx.qk.as_mut() {
                *q = QuantSlab::new(dh, 1);
            }
            if let Some(q) = ctx.qv.as_mut() {
                *q = QuantSlab::new(dh, 1);
            }
            if tier != HeadTier::WindowOnly {
                for i in 0..n {
                    let a = a_cpu[h * n + i];
                    if a > threshold {
                        ctx.idx.push(i as u32);
                        match tier {
                            HeadTier::F32 => {
                                ctx.k.extend_from_slice(&store.k[i * dh..(i + 1) * dh]);
                                ctx.v.extend_from_slice(&store.v[i * dh..(i + 1) * dh]);
                            }
                            HeadTier::Int8 => {
                                // rebuild from the *current* store bytes +
                                // scales, so re-evaluation never leaves the
                                // packed ctx behind a re-quantized tail
                                let qk = store.qk.as_ref().expect("int8 slab");
                                let qv = store.qv.as_ref().expect("int8 slab");
                                let ck = ctx.qk.as_mut().expect("int8 ctx");
                                ck.push_quantized(qk.entry(i), qk.scale_of(i));
                                let cv = ctx.qv.as_mut().expect("int8 ctx");
                                cv.push_quantized(qv.entry(i), qv.scale_of(i));
                            }
                            HeadTier::WindowOnly => unreachable!(),
                        }
                        ctx.maw.push(a);
                    }
                }
            }
            // also refresh the stored MAW so future re-evals see history
            for i in 0..n {
                self.full[h].maw[i] = a_cpu[h * n + i];
            }
            Self::renormalize(&mut self.ctx[h].maw);
        }
    }

    /// Move head `h` to `tier`. Tiers are a **one-way ratchet**
    /// (`F32 → Int8 → WindowOnly`): a request that would loosen the tier
    /// is ignored, because the dropped precision (or the dropped bytes)
    /// cannot be recovered. Existing slab contents migrate: `Int8`
    /// quantizes the current f32 slabs (and re-packs the contextual cache
    /// from the quantized bytes); `WindowOnly` drops K/V outright and
    /// empties the contextual cache, keeping MAW/pos so the store's
    /// per-head length invariant survives.
    pub fn set_tier(&mut self, h: usize, tier: HeadTier) {
        let rank = |t: HeadTier| match t {
            HeadTier::F32 => 0,
            HeadTier::Int8 => 1,
            HeadTier::WindowOnly => 2,
        };
        let cur = self.full[h].tier;
        if rank(tier) <= rank(cur) && tier != cur {
            return; // never loosen
        }
        if tier == cur {
            return;
        }
        let dh = self.d_head;
        match tier {
            HeadTier::F32 => unreachable!("ratchet checked above"),
            HeadTier::Int8 => {
                assert_eq!(cur, HeadTier::F32);
                let hs = &mut self.full[h];
                hs.qk = Some(QuantSlab::from_f32(&hs.k, dh, QUANT_BLOCK));
                hs.qv = Some(QuantSlab::from_f32(&hs.v, dh, QUANT_BLOCK));
                hs.k = CowVec::default();
                hs.v = CowVec::default();
                hs.tier = HeadTier::Int8;
                // re-pack the contextual cache from the quantized bytes so
                // the serving path and the store agree on every value
                let qk = self.full[h].qk.as_ref().expect("just set");
                let qv = self.full[h].qv.as_ref().expect("just set");
                let ctx = &mut self.ctx[h];
                let mut ck = QuantSlab::new(dh, 1);
                let mut cv = QuantSlab::new(dh, 1);
                for &i in &ctx.idx {
                    let i = i as usize;
                    ck.push_quantized(qk.entry(i), qk.scale_of(i));
                    cv.push_quantized(qv.entry(i), qv.scale_of(i));
                }
                ctx.k.clear();
                ctx.v.clear();
                ctx.qk = Some(ck);
                ctx.qv = Some(cv);
            }
            HeadTier::WindowOnly => {
                let hs = &mut self.full[h];
                hs.k = CowVec::default();
                hs.v = CowVec::default();
                hs.qk = None;
                hs.qv = None;
                hs.tier = HeadTier::WindowOnly;
                self.ctx[h] = HeadCtx::default();
            }
        }
    }

    /// The tier of head `h`.
    pub fn tier(&self, h: usize) -> HeadTier {
        self.full[h].tier
    }

    /// Heads per tier: `(f32, int8, window_only)`.
    pub fn tier_counts(&self) -> (usize, usize, usize) {
        let mut c = (0usize, 0usize, 0usize);
        for hs in &self.full {
            match hs.tier {
                HeadTier::F32 => c.0 += 1,
                HeadTier::Int8 => c.1 += 1,
                HeadTier::WindowOnly => c.2 += 1,
            }
        }
        c
    }

    /// Bytes saved by int8-tiered heads vs holding the same entries in
    /// f32: Σ over Int8 heads of `2·n·d_head·4 − (qk + qv actual bytes)`.
    pub fn quant_bytes_saved(&self) -> u64 {
        let dh = self.d_head;
        self.full
            .iter()
            .filter(|hs| hs.tier == HeadTier::Int8)
            .map(|hs| {
                let f32_equiv = 2 * hs.len() * dh * 4;
                let actual = hs.qk.as_ref().map_or(0, QuantSlab::size_bytes)
                    + hs.qv.as_ref().map_or(0, QuantSlab::size_bytes);
                f32_equiv.saturating_sub(actual) as u64
            })
            .sum()
    }

    fn renormalize(maw: &mut [f32]) {
        let sum: f32 = maw.iter().sum();
        if sum > 0.0 {
            for m in maw.iter_mut() {
                *m /= sum;
            }
        }
    }

    /// Per-head selected fraction (paper reports 30%…<1% at β = 1).
    pub fn selectivity(&self) -> Vec<f32> {
        let n = self.len().max(1) as f32;
        self.ctx.iter().map(|c| c.len() as f32 / n).collect()
    }

    /// Resident bytes (full store + contextual cache; the paper's peak
    /// CPU-KV metric). Tiered heads account their quantized buffers +
    /// scales exactly ([`QuantSlab::size_bytes`]); f32 heads are the
    /// pre-tier arithmetic unchanged.
    pub fn size_bytes(&self) -> usize {
        let full: usize = self
            .full
            .iter()
            .map(|h| {
                (h.k.len() + h.v.len() + h.maw.len()) * 4
                    + h.pos.len() * 8
                    + h.qk.as_ref().map_or(0, QuantSlab::size_bytes)
                    + h.qv.as_ref().map_or(0, QuantSlab::size_bytes)
            })
            .sum();
        let ctx: usize = self
            .ctx
            .iter()
            .map(|c| {
                (c.k.len() + c.v.len() + c.maw.len()) * 4
                    + c.idx.len() * 4
                    + c.qk.as_ref().map_or(0, QuantSlab::size_bytes)
                    + c.qv.as_ref().map_or(0, QuantSlab::size_bytes)
            })
            .sum();
        full + ctx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blk_with_maw(heads: usize, dh: usize, maws: &[&[f32]]) -> KvBlock {
        let len = maws[0].len();
        let mut b = KvBlock::new(heads, dh, len);
        for h in 0..heads {
            for t in 0..len {
                b.maw[h * len + t] = maws[h][t];
                for j in 0..dh {
                    b.k[(h * len + t) * dh + j] = (h * 1000 + t * 10 + j) as f32;
                    b.v[(h * len + t) * dh + j] = -((h * 1000 + t * 10 + j) as f32);
                }
            }
        }
        for (t, p) in b.pos.iter_mut().enumerate() {
            *p = t + 100;
        }
        b
    }

    #[test]
    fn add_evicted_selects_above_threshold() {
        let mut s = CpuLayerStore::new(2, 2);
        // window denom = 4 → threshold = 1/4 = 0.25 at beta=1
        let blk = blk_with_maw(2, 2, &[&[0.3, 0.1, 0.5], &[0.01, 0.02, 0.03]]);
        s.add_evicted(&blk, 1.0, 4);
        assert_eq!(s.len(), 3);
        assert_eq!(s.ctx[0].idx, vec![0, 2]); // 0.3 and 0.5 pass
        assert!(s.ctx[1].is_empty()); // head 1 all below
        // contiguous packed k for selected entries
        assert_eq!(s.ctx[0].k.len(), 2 * 2);
        assert_eq!(&s.ctx[0].k[2..4], s.full[0].k[4..6].to_vec().as_slice());
    }

    #[test]
    fn ctx_maw_renormalized() {
        let mut s = CpuLayerStore::new(1, 2);
        let blk = blk_with_maw(1, 2, &[&[0.4, 0.4]]);
        s.add_evicted(&blk, 1.0, 4);
        let sum: f32 = s.ctx[0].maw.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!((s.ctx[0].maw[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn per_head_selectivity_varies() {
        // paper: skewed heads keep few entries, flat heads keep many
        let mut s = CpuLayerStore::new(2, 2);
        let blk = blk_with_maw(2, 2, &[&[0.9, 0.001, 0.001, 0.001], &[0.3, 0.3, 0.3, 0.3]]);
        s.add_evicted(&blk, 1.0, 8); // threshold 0.125
        let sel = s.selectivity();
        assert!(sel[0] < sel[1]);
        assert_eq!(s.ctx[0].len(), 1);
        assert_eq!(s.ctx[1].len(), 4);
    }

    #[test]
    fn beta_controls_aggressiveness() {
        let blk = blk_with_maw(1, 2, &[&[0.05, 0.1, 0.2, 0.4]]);
        let mut strict = CpuLayerStore::new(1, 2);
        strict.add_evicted(&blk, 2.0, 8); // threshold .25
        let mut loose = CpuLayerStore::new(1, 2);
        loose.add_evicted(&blk, 0.25, 8); // threshold .03125
        assert!(strict.ctx[0].len() < loose.ctx[0].len());
        assert_eq!(strict.ctx[0].len(), 1); // only 0.4 > 0.25
        assert_eq!(loose.ctx[0].len(), 4); // all > 0.03125
    }

    #[test]
    fn reevaluate_reinstates_and_drops() {
        let mut s = CpuLayerStore::new(1, 2);
        let blk = blk_with_maw(1, 2, &[&[0.5, 0.001, 0.5, 0.001]]);
        s.add_evicted(&blk, 1.0, 4); // threshold .25: keeps {0, 2}
        assert_eq!(s.ctx[0].idx, vec![0, 2]);
        // new context flips importance: entries 1,3 now hot (threshold 1/4)
        let a_cpu = vec![0.01, 0.6, 0.01, 0.38];
        s.reevaluate(&a_cpu, 1.0);
        assert_eq!(s.ctx[0].idx, vec![1, 3]);
        // stored maw refreshed
        assert!((s.full[0].maw[1] - 0.6).abs() < 1e-6);
        // packed data matches reinstated entries
        assert_eq!(&s.ctx[0].k[0..2], &s.full[0].k[2..4]);
    }

    #[test]
    fn full_store_never_shrinks() {
        let mut s = CpuLayerStore::new(1, 2);
        s.add_evicted(&blk_with_maw(1, 2, &[&[0.001, 0.001]]), 1.0, 4);
        assert_eq!(s.len(), 2);
        assert!(s.ctx[0].is_empty());
        s.reevaluate(&vec![0.0, 0.0], 1.0);
        assert_eq!(s.len(), 2); // still retrievable later
    }

    #[test]
    fn sharded_store_records_head_placement_without_changing_selection() {
        let blk = blk_with_maw(2, 2, &[&[0.3, 0.1, 0.5], &[0.01, 0.02, 0.03]]);
        let mut flat = CpuLayerStore::new(2, 2);
        let mut sharded = CpuLayerStore::new_sharded(2, 2, vec![1, 0]);
        flat.add_evicted(&blk, 1.0, 4);
        sharded.add_evicted(&blk, 1.0, 4);
        assert_eq!(flat.node_of, vec![0, 0]);
        assert_eq!(sharded.node_of_head(0), 1);
        assert_eq!(sharded.node_of_head(1), 0);
        // placement metadata only: selection + slab contents identical
        assert_eq!(flat.ctx[0].idx, sharded.ctx[0].idx);
        assert_eq!(flat.ctx[0].k, sharded.ctx[0].k);
        assert_eq!(flat.full[1].maw, sharded.full[1].maw);
    }

    #[test]
    #[should_panic]
    fn shard_map_must_cover_every_head() {
        CpuLayerStore::new_sharded(4, 2, vec![0, 1]);
    }

    #[test]
    fn multiple_blocks_accumulate() {
        let mut s = CpuLayerStore::new(1, 2);
        s.add_evicted(&blk_with_maw(1, 2, &[&[0.5, 0.5]]), 1.0, 4);
        s.add_evicted(&blk_with_maw(1, 2, &[&[0.5, 0.5]]), 1.0, 4);
        assert_eq!(s.len(), 4);
        assert_eq!(s.ctx[0].len(), 4);
        assert_eq!(s.ctx[0].idx, vec![0, 1, 2, 3]);
    }

    #[test]
    fn int8_tier_migrates_slabs_and_saves_bytes() {
        // a full scale block (32 entries) so compression dominates the
        // staged-tail overhead
        let maw = [0.5f32; 32];
        let mut s = CpuLayerStore::new(2, 2);
        let blk = blk_with_maw(2, 2, &[&maw, &maw]);
        s.add_evicted(&blk, 1.0, 4);
        let f32_bytes = s.size_bytes();
        s.set_tier(0, HeadTier::Int8);
        assert_eq!(s.tier(0), HeadTier::Int8);
        assert_eq!(s.tier(1), HeadTier::F32);
        assert_eq!(s.tier_counts(), (1, 1, 0));
        // the f32 slabs are gone; the quant slabs cover the same entries
        assert!(s.full[0].k.is_empty());
        assert_eq!(s.full[0].qk.as_ref().unwrap().len(), 32);
        // the ctx re-packed with matching selection (all 32 pass 1/4)
        assert_eq!(s.ctx[0].idx.len(), 32);
        assert_eq!(s.ctx[0].qk.as_ref().unwrap().len(), 32);
        assert!(s.ctx[0].k.is_empty());
        assert!(s.size_bytes() < f32_bytes);
        // ≥ 3× compression on the int8 slabs: saved ≥ 2 × resident
        let resident = s.full[0].qk.as_ref().unwrap().size_bytes()
            + s.full[0].qv.as_ref().unwrap().size_bytes();
        assert!(
            s.quant_bytes_saved() as usize >= 2 * resident,
            "saved {} vs resident {resident}",
            s.quant_bytes_saved()
        );
        // and later evictions keep flowing into the quant slabs
        s.add_evicted(&blk_with_maw(2, 2, &[&[0.9, 0.0, 0.0], &[0.0; 3]]), 1.0, 4);
        assert_eq!(s.full[0].qk.as_ref().unwrap().len(), 35);
        assert_eq!(s.full[1].k.len(), 35 * 2, "f32 head untouched");
    }

    #[test]
    fn window_only_tier_drops_bytes_keeps_bookkeeping() {
        let mut s = CpuLayerStore::new(2, 2);
        s.add_evicted(&blk_with_maw(2, 2, &[&[0.5, 0.5], &[0.5, 0.5]]), 1.0, 4);
        s.set_tier(0, HeadTier::WindowOnly);
        assert!(s.full[0].k.is_empty() && s.full[0].qk.is_none());
        assert!(s.ctx[0].is_empty());
        // length invariant survives (maw/pos kept) so reevaluate's shape
        // assertion and cross-head accounting still hold
        assert_eq!(s.full[0].len(), 2);
        assert_eq!(s.len(), 2);
        s.add_evicted(&blk_with_maw(2, 2, &[&[0.9, 0.9], &[0.9, 0.9]]), 1.0, 4);
        assert_eq!(s.full[0].len(), 4);
        assert!(s.ctx[0].is_empty(), "window-only head never selects");
        assert_eq!(s.ctx[1].len(), 4);
        // reevaluation runs with zeroed scores for the dropped head
        s.reevaluate(&vec![0.1; 2 * 4], 1.0);
        assert!(s.ctx[0].is_empty());
    }

    #[test]
    fn tier_is_a_one_way_ratchet() {
        let mut s = CpuLayerStore::new(1, 2);
        s.add_evicted(&blk_with_maw(1, 2, &[&[0.5, 0.5]]), 1.0, 4);
        s.set_tier(0, HeadTier::Int8);
        s.set_tier(0, HeadTier::F32); // ignored
        assert_eq!(s.tier(0), HeadTier::Int8);
        s.set_tier(0, HeadTier::WindowOnly);
        s.set_tier(0, HeadTier::Int8); // ignored
        assert_eq!(s.tier(0), HeadTier::WindowOnly);
    }

    /// Regression: before the tail-staging fix, appending to an int8 head
    /// re-used the tail block's *old* scale for entries whose block now
    /// holds a larger-magnitude newcomer, so dequantized values clipped at
    /// the stale max. `add_evicted` must re-quantize the tail block from
    /// f32 originals on every mutation.
    #[test]
    fn int8_append_never_serves_stale_scales() {
        let dh = 2;
        let mut s = CpuLayerStore::new(1, dh);
        s.set_tier(0, HeadTier::Int8); // tier first: all appends quantized
        // first block: small magnitudes → small scale
        let mut blk = KvBlock::new(1, dh, 1);
        blk.k.copy_from_slice(&[0.5, -0.5]);
        blk.v.copy_from_slice(&[0.25, 0.25]);
        blk.maw[0] = 0.9;
        s.add_evicted(&blk, 1.0, 4);
        // second entry lands in the same scale block with 100× magnitude
        let mut blk2 = KvBlock::new(1, dh, 1);
        blk2.k.copy_from_slice(&[50.0, -50.0]);
        blk2.v.copy_from_slice(&[25.0, 25.0]);
        blk2.maw[0] = 0.9;
        s.add_evicted(&blk2, 1.0, 4);
        let qk = s.full[0].qk.as_ref().unwrap();
        // with a stale 0.5-max scale the newcomer would clip at ±0.5;
        // with the re-quantized block scale both entries round-trip
        let mut out = [0.0f32; 2];
        qk.dequantize_entry(1, &mut out);
        let scale = qk.scale_of(1);
        assert!((out[0] - 50.0).abs() <= scale / 2.0 + 1e-6, "{out:?} scale {scale}");
        qk.dequantize_entry(0, &mut out);
        assert!((out[0] - 0.5).abs() <= scale / 2.0 + 1e-6, "{out:?} scale {scale}");
        // the ctx packed at first-append time kept its copy-time scale —
        // also not stale (bytes + scale always travel together)
        let ck = s.ctx[0].qk.as_ref().unwrap();
        ck.dequantize_entry(0, &mut out);
        assert!((out[0] - 0.5).abs() <= 0.5 / 127.0 / 2.0 + 1e-6, "{out:?}");
    }

    #[test]
    fn tiered_size_bytes_is_exact() {
        let dh = 4;
        let mut s = CpuLayerStore::new(2, dh);
        let blk = blk_with_maw(2, 4, &[&[0.5, 0.5, 0.5], &[0.5, 0.5, 0.5]]);
        s.add_evicted(&blk, 1.0, 4);
        s.set_tier(0, HeadTier::Int8);
        let h0 = &s.full[0];
        let c0 = &s.ctx[0];
        let expect_h0 = h0.maw.len() * 4
            + h0.pos.len() * 8
            + h0.qk.as_ref().unwrap().size_bytes()
            + h0.qv.as_ref().unwrap().size_bytes();
        let expect_c0 = c0.maw.len() * 4
            + c0.idx.len() * 4
            + c0.qk.as_ref().unwrap().size_bytes()
            + c0.qv.as_ref().unwrap().size_bytes();
        let h1 = &s.full[1];
        let c1 = &s.ctx[1];
        let expect_h1 = (h1.k.len() + h1.v.len() + h1.maw.len()) * 4 + h1.pos.len() * 8;
        let expect_c1 = (c1.k.len() + c1.v.len() + c1.maw.len()) * 4 + c1.idx.len() * 4;
        assert_eq!(s.size_bytes(), expect_h0 + expect_c0 + expect_h1 + expect_c1);
    }
}
