//! CPU-side per-layer KV store (Algorithm 1, CPU half; §3.2.2).
//!
//! Holds every evicted KV entry (nothing is ever dropped — entries below
//! the threshold stay available for re-evaluation) plus the *contextual
//! cache*: the per-head subset selected by the β-threshold rule
//!
//! ```text
//! keep(h, i)  ⇔  maw[h][i] > β / denom
//! ```
//!
//! where denom is the GPU window length at evict-time selection and the
//! CPU cache length at append-time re-evaluation (Algorithm 1 lines 19–24).
//! Selected entries are stored contiguously per head (§3.3: contiguous
//! arrangement enables efficient parallel CPU attention), with MAW
//! re-normalized to sum to 1 per head.

use crate::topology::NodeId;

use super::block::KvBlock;
use super::cow::CowVec;

/// Per-head growable KV arrays.
///
/// The `k`/`v`/`pos` slabs are [`CowVec`]s so a prefix-cache snapshot
/// shares them with every adopting sequence at zero copy cost; `maw` is
/// rewritten by every append-time re-evaluation, so sharing it would
/// only defer a copy that always happens — it stays a plain `Vec`.
#[derive(Debug, Clone, Default)]
pub struct HeadStore {
    pub k: CowVec<f32>,   // [n][dh] row-major
    pub v: CowVec<f32>,
    pub maw: Vec<f32>, // [n]
    pub pos: CowVec<usize>,
}

impl HeadStore {
    /// Entries stored for this head.
    pub fn len(&self) -> usize {
        self.maw.len()
    }
    /// True when no entries have been evicted to this head yet.
    pub fn is_empty(&self) -> bool {
        self.maw.is_empty()
    }
}

/// Contiguous per-head contextual cache (the sparse-attention working set).
#[derive(Debug, Clone, Default)]
pub struct HeadCtx {
    /// indices into the head's full store
    pub idx: Vec<u32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// re-normalized MAW (sums to 1 per head when non-empty)
    pub maw: Vec<f32>,
}

impl HeadCtx {
    /// Selected entries for this head.
    pub fn len(&self) -> usize {
        self.idx.len()
    }
    /// True when the β-threshold selected nothing for this head.
    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }
}

/// The CPU half of one layer's KV state: every evicted entry per head
/// (`full`) plus the contiguous selected subset (`ctx`) the sparse
/// attention actually reads.
///
/// Head slabs are **sharded across NUMA nodes**: `node_of[h]` names the
/// node that owns head `h`'s slabs (round-robined by the topology's shard
/// map — see [`crate::topology::Topology::shard_heads`]), so the engine
/// can dispatch each head's `sparse_attention*` job to the worker queue
/// whose pinned workers read the slab from local memory. The map is
/// placement metadata: slab *contents* and selection numerics are
/// identical on every topology (a flat store maps every head to node 0).
#[derive(Debug, Clone)]
pub struct CpuLayerStore {
    /// Attention heads.
    pub heads: usize,
    /// Head dimension.
    pub d_head: usize,
    /// Per-head full store (nothing is ever dropped).
    pub full: Vec<HeadStore>,
    /// Per-head contextual cache (the β-selected working set).
    pub ctx: Vec<HeadCtx>,
    /// Per-head owning NUMA node (len == `heads`; all 0 when flat).
    pub node_of: Vec<NodeId>,
}

impl CpuLayerStore {
    /// An empty flat store for `heads` heads (every slab on node 0 — the
    /// single-domain layout every pre-NUMA caller gets).
    pub fn new(heads: usize, d_head: usize) -> Self {
        CpuLayerStore::new_sharded(heads, d_head, vec![0; heads])
    }

    /// An empty store whose head slabs are sharded per `node_of`
    /// (`node_of[h]` = the NUMA node owning head `h`'s slabs). Panics when
    /// the map length does not match `heads`.
    pub fn new_sharded(heads: usize, d_head: usize, node_of: Vec<NodeId>) -> Self {
        assert_eq!(node_of.len(), heads, "shard map must cover every head");
        CpuLayerStore {
            heads,
            d_head,
            full: (0..heads).map(|_| HeadStore::default()).collect(),
            ctx: (0..heads).map(|_| HeadCtx::default()).collect(),
            node_of,
        }
    }

    /// The NUMA node owning head `h`'s slabs.
    pub fn node_of_head(&self, h: usize) -> NodeId {
        self.node_of[h]
    }

    /// Entries per head (identical across heads — eviction is whole-block).
    pub fn len(&self) -> usize {
        self.full[0].len()
    }

    /// True while nothing has been evicted to this layer.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total selected entries across heads (sparsity diagnostics).
    pub fn ctx_len_total(&self) -> usize {
        self.ctx.iter().map(|c| c.len()).sum()
    }

    /// Absorb an evicted block and immediately run evict-time selection on
    /// the *incoming* entries (Algorithm 1 lines 23–25): salient newcomers
    /// join the contextual cache; everything joins the full store.
    /// `denom` is the GPU window length (A_gpu.size).
    pub fn add_evicted(&mut self, blk: &KvBlock, beta: f32, denom: usize) {
        assert_eq!(blk.heads, self.heads);
        assert_eq!(blk.d_head, self.d_head);
        let dh = self.d_head;
        let threshold = beta / denom.max(1) as f32;
        for h in 0..self.heads {
            let start = self.full[h].len();
            let hk = &blk.k[h * blk.len * dh..(h + 1) * blk.len * dh];
            let hv = &blk.v[h * blk.len * dh..(h + 1) * blk.len * dh];
            self.full[h].k.make_mut().extend_from_slice(hk);
            self.full[h].v.make_mut().extend_from_slice(hv);
            self.full[h]
                .maw
                .extend_from_slice(&blk.maw[h * blk.len..(h + 1) * blk.len]);
            self.full[h].pos.make_mut().extend_from_slice(&blk.pos);
            // select salient newcomers into the contextual cache
            for t in 0..blk.len {
                if blk.maw_at(h, t) > threshold {
                    let i = start + t;
                    self.ctx[h].idx.push(i as u32);
                    self.ctx[h].k.extend_from_slice(&hk[t * dh..(t + 1) * dh]);
                    self.ctx[h].v.extend_from_slice(&hv[t * dh..(t + 1) * dh]);
                    self.ctx[h].maw.push(blk.maw_at(h, t));
                }
            }
            Self::renormalize(&mut self.ctx[h].maw);
        }
    }

    /// Append-time re-evaluation (§3.2.2 "Re-evaluation"; Algorithm 1 lines
    /// 19–22): given fresh attention weights over the *full* CPU store
    /// (a_cpu[h * n + i]), rebuild each head's contextual cache. Previously
    /// pruned entries can be reinstated; stale ones are dropped.
    pub fn reevaluate(&mut self, a_cpu: &[f32], beta: f32) {
        let n = self.len();
        assert_eq!(a_cpu.len(), self.heads * n);
        let dh = self.d_head;
        let threshold = beta / n.max(1) as f32;
        for h in 0..self.heads {
            let store = &self.full[h];
            let ctx = &mut self.ctx[h];
            ctx.idx.clear();
            ctx.k.clear();
            ctx.v.clear();
            ctx.maw.clear();
            for i in 0..n {
                let a = a_cpu[h * n + i];
                if a > threshold {
                    ctx.idx.push(i as u32);
                    ctx.k.extend_from_slice(&store.k[i * dh..(i + 1) * dh]);
                    ctx.v.extend_from_slice(&store.v[i * dh..(i + 1) * dh]);
                    ctx.maw.push(a);
                }
            }
            // also refresh the stored MAW so future re-evals see history
            for i in 0..n {
                self.full[h].maw[i] = a_cpu[h * n + i];
            }
            Self::renormalize(&mut self.ctx[h].maw);
        }
    }

    fn renormalize(maw: &mut [f32]) {
        let sum: f32 = maw.iter().sum();
        if sum > 0.0 {
            for m in maw.iter_mut() {
                *m /= sum;
            }
        }
    }

    /// Per-head selected fraction (paper reports 30%…<1% at β = 1).
    pub fn selectivity(&self) -> Vec<f32> {
        let n = self.len().max(1) as f32;
        self.ctx.iter().map(|c| c.len() as f32 / n).collect()
    }

    /// Resident bytes (full store + contextual cache; the paper's peak
    /// CPU-KV metric).
    pub fn size_bytes(&self) -> usize {
        let full: usize = self
            .full
            .iter()
            .map(|h| (h.k.len() + h.v.len() + h.maw.len()) * 4 + h.pos.len() * 8)
            .sum();
        let ctx: usize = self
            .ctx
            .iter()
            .map(|c| (c.k.len() + c.v.len() + c.maw.len()) * 4 + c.idx.len() * 4)
            .sum();
        full + ctx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blk_with_maw(heads: usize, dh: usize, maws: &[&[f32]]) -> KvBlock {
        let len = maws[0].len();
        let mut b = KvBlock::new(heads, dh, len);
        for h in 0..heads {
            for t in 0..len {
                b.maw[h * len + t] = maws[h][t];
                for j in 0..dh {
                    b.k[(h * len + t) * dh + j] = (h * 1000 + t * 10 + j) as f32;
                    b.v[(h * len + t) * dh + j] = -((h * 1000 + t * 10 + j) as f32);
                }
            }
        }
        for (t, p) in b.pos.iter_mut().enumerate() {
            *p = t + 100;
        }
        b
    }

    #[test]
    fn add_evicted_selects_above_threshold() {
        let mut s = CpuLayerStore::new(2, 2);
        // window denom = 4 → threshold = 1/4 = 0.25 at beta=1
        let blk = blk_with_maw(2, 2, &[&[0.3, 0.1, 0.5], &[0.01, 0.02, 0.03]]);
        s.add_evicted(&blk, 1.0, 4);
        assert_eq!(s.len(), 3);
        assert_eq!(s.ctx[0].idx, vec![0, 2]); // 0.3 and 0.5 pass
        assert!(s.ctx[1].is_empty()); // head 1 all below
        // contiguous packed k for selected entries
        assert_eq!(s.ctx[0].k.len(), 2 * 2);
        assert_eq!(&s.ctx[0].k[2..4], s.full[0].k[4..6].to_vec().as_slice());
    }

    #[test]
    fn ctx_maw_renormalized() {
        let mut s = CpuLayerStore::new(1, 2);
        let blk = blk_with_maw(1, 2, &[&[0.4, 0.4]]);
        s.add_evicted(&blk, 1.0, 4);
        let sum: f32 = s.ctx[0].maw.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!((s.ctx[0].maw[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn per_head_selectivity_varies() {
        // paper: skewed heads keep few entries, flat heads keep many
        let mut s = CpuLayerStore::new(2, 2);
        let blk = blk_with_maw(2, 2, &[&[0.9, 0.001, 0.001, 0.001], &[0.3, 0.3, 0.3, 0.3]]);
        s.add_evicted(&blk, 1.0, 8); // threshold 0.125
        let sel = s.selectivity();
        assert!(sel[0] < sel[1]);
        assert_eq!(s.ctx[0].len(), 1);
        assert_eq!(s.ctx[1].len(), 4);
    }

    #[test]
    fn beta_controls_aggressiveness() {
        let blk = blk_with_maw(1, 2, &[&[0.05, 0.1, 0.2, 0.4]]);
        let mut strict = CpuLayerStore::new(1, 2);
        strict.add_evicted(&blk, 2.0, 8); // threshold .25
        let mut loose = CpuLayerStore::new(1, 2);
        loose.add_evicted(&blk, 0.25, 8); // threshold .03125
        assert!(strict.ctx[0].len() < loose.ctx[0].len());
        assert_eq!(strict.ctx[0].len(), 1); // only 0.4 > 0.25
        assert_eq!(loose.ctx[0].len(), 4); // all > 0.03125
    }

    #[test]
    fn reevaluate_reinstates_and_drops() {
        let mut s = CpuLayerStore::new(1, 2);
        let blk = blk_with_maw(1, 2, &[&[0.5, 0.001, 0.5, 0.001]]);
        s.add_evicted(&blk, 1.0, 4); // threshold .25: keeps {0, 2}
        assert_eq!(s.ctx[0].idx, vec![0, 2]);
        // new context flips importance: entries 1,3 now hot (threshold 1/4)
        let a_cpu = vec![0.01, 0.6, 0.01, 0.38];
        s.reevaluate(&a_cpu, 1.0);
        assert_eq!(s.ctx[0].idx, vec![1, 3]);
        // stored maw refreshed
        assert!((s.full[0].maw[1] - 0.6).abs() < 1e-6);
        // packed data matches reinstated entries
        assert_eq!(&s.ctx[0].k[0..2], &s.full[0].k[2..4]);
    }

    #[test]
    fn full_store_never_shrinks() {
        let mut s = CpuLayerStore::new(1, 2);
        s.add_evicted(&blk_with_maw(1, 2, &[&[0.001, 0.001]]), 1.0, 4);
        assert_eq!(s.len(), 2);
        assert!(s.ctx[0].is_empty());
        s.reevaluate(&vec![0.0, 0.0], 1.0);
        assert_eq!(s.len(), 2); // still retrievable later
    }

    #[test]
    fn sharded_store_records_head_placement_without_changing_selection() {
        let blk = blk_with_maw(2, 2, &[&[0.3, 0.1, 0.5], &[0.01, 0.02, 0.03]]);
        let mut flat = CpuLayerStore::new(2, 2);
        let mut sharded = CpuLayerStore::new_sharded(2, 2, vec![1, 0]);
        flat.add_evicted(&blk, 1.0, 4);
        sharded.add_evicted(&blk, 1.0, 4);
        assert_eq!(flat.node_of, vec![0, 0]);
        assert_eq!(sharded.node_of_head(0), 1);
        assert_eq!(sharded.node_of_head(1), 0);
        // placement metadata only: selection + slab contents identical
        assert_eq!(flat.ctx[0].idx, sharded.ctx[0].idx);
        assert_eq!(flat.ctx[0].k, sharded.ctx[0].k);
        assert_eq!(flat.full[1].maw, sharded.full[1].maw);
    }

    #[test]
    #[should_panic]
    fn shard_map_must_cover_every_head() {
        CpuLayerStore::new_sharded(4, 2, vec![0, 1]);
    }

    #[test]
    fn multiple_blocks_accumulate() {
        let mut s = CpuLayerStore::new(1, 2);
        s.add_evicted(&blk_with_maw(1, 2, &[&[0.5, 0.5]]), 1.0, 4);
        s.add_evicted(&blk_with_maw(1, 2, &[&[0.5, 0.5]]), 1.0, 4);
        assert_eq!(s.len(), 4);
        assert_eq!(s.ctx[0].len(), 4);
        assert_eq!(s.ctx[0].idx, vec![0, 1, 2, 3]);
    }
}
