//! CPU-local sparse attention (paper §3.3).
//!
//! Each (batch row, head) attends its own variable-length KV subset — the
//! contextual cache during decode, the full CPU store during append
//! re-evaluation. Jobs are packed into ≈`threads` contiguous tasks
//! (the paper's adjacent-head merging, §3.3: thread count stays near
//! batch×heads / cores instead of one thread per head) and every job writes
//! to a disjoint slice of a pre-allocated output buffer (the paper's
//! pinned-buffer offsets).
//!
//! Execution goes through the persistent worker pool
//! ([`super::pool::AttnPool`]) — long-lived workers, no per-call thread
//! spawn. The original scoped-spawn implementation survives as
//! [`sparse_attention_spawn_masked`] for the pool-vs-spawn microbenchmarks
//! and as an independent conformance reference; both paths share
//! [`run_job_range`] so their numerics are identical by construction.
//!
//! Returns partial outputs + log-sum-exp per (row, head, query) for the
//! LSE merge, and optionally the per-slot attention mass (A_cpu) used by
//! MAW re-evaluation (Algorithm 1 line 19).

use crate::kv::quant::{quantize_row, QuantSlab};
use crate::tensor::simd::{self, Kernels, SimdLevel};

use super::pool::{AttnPool, JobPayload, TaskSplit};

/// One (row, head) unit of work: attention over `n` KV entries stored
/// contiguously ([n][d_head] row-major).
#[derive(Debug, Clone, Copy)]
pub struct HeadJob<'a> {
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub n: usize,
}

/// One (row, head) unit of work on the **tiered** path: either a plain
/// f32 job (identical numerics to [`HeadJob`] by construction — the F32
/// branch of [`run_job_range_tiered`] is the [`run_job_range`] loop body
/// verbatim) or an int8 job over quantized slabs. The task split and
/// placement plan treat both identically (only `n()` matters).
#[derive(Debug, Clone, Copy)]
pub(crate) enum KernelJob<'a> {
    F32(HeadJob<'a>),
    Quant { k: &'a QuantSlab, v: &'a QuantSlab },
}

impl KernelJob<'_> {
    /// KV entries this job attends (the task-split sizing input).
    pub(crate) fn n(&self) -> usize {
        match self {
            KernelJob::F32(j) => j.n,
            KernelJob::Quant { k, .. } => k.len(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct CpuAttnOutput {
    /// [jobs][n_query][d_head]
    pub o: Vec<f32>,
    /// [jobs][n_query]; EMPTY (-1e30) where the job had no entries
    pub lse: Vec<f32>,
    /// per-job attention mass per KV slot, summed over queries ([n] each);
    /// only filled when requested
    pub probs: Option<Vec<Vec<f32>>>,
    /// number of spawned tasks (diagnostics; ≈ min(threads, jobs))
    pub tasks: usize,
    /// summed task execution seconds across workers + caller-assist for
    /// this submission (pool paths only; the spawn reference path reports
    /// 0.0). Under overlapped execution this is the honest "CPU sparse
    /// work" figure — the submitter's wall wait also covers its own
    /// bookkeeping and is tracked separately (`cpu_attn_wait_secs`).
    pub busy_secs: f64,
}

// The sentinel has exactly one definition (attention::merge) — re-exported
// here so job producers and the LSE merge can never drift apart.
pub use super::merge::EMPTY_LSE;

/// q is [jobs][n_query][d_head] flat, aligned with `jobs`.
pub fn sparse_attention(
    jobs: &[HeadJob<'_>],
    q: &[f32],
    n_query: usize,
    d_head: usize,
    threads: usize,
    want_probs: bool,
) -> CpuAttnOutput {
    sparse_attention_masked(jobs, q, n_query, d_head, threads, want_probs, None)
}

/// Like [`sparse_attention`] but with an optional per-job count of *valid*
/// query rows (chunk padding support): rows >= q_valid[job] are skipped --
/// zero output, EMPTY lse, and no contribution to `probs`.
///
/// Runs on the process-wide persistent pool ([`AttnPool::global`]);
/// `threads` caps how many packed tasks the call splits into. Results are
/// bitwise independent of both the cap and the pool size.
#[allow(clippy::too_many_arguments)]
pub fn sparse_attention_masked(
    jobs: &[HeadJob<'_>],
    q: &[f32],
    n_query: usize,
    d_head: usize,
    threads: usize,
    want_probs: bool,
    q_valid: Option<&[usize]>,
) -> CpuAttnOutput {
    AttnPool::global().run_masked(jobs, q, n_query, d_head, threads, want_probs, q_valid)
}

/// [`sparse_attention_masked`] with an explicit per-job NUMA node map (the
/// KV shard map — see `kv::CpuLayerStore::node_of`): each packed task is
/// dispatched to the pool queue owning its first job's slab, so workers
/// pinned to that node stream local memory. Placement never changes the
/// task plan or the numerics — output is bitwise identical to the unplaced
/// call on any topology.
#[allow(clippy::too_many_arguments)]
pub fn sparse_attention_masked_placed(
    jobs: &[HeadJob<'_>],
    q: &[f32],
    n_query: usize,
    d_head: usize,
    threads: usize,
    want_probs: bool,
    q_valid: Option<&[usize]>,
    nodes: &[crate::topology::NodeId],
) -> CpuAttnOutput {
    AttnPool::global().run_placed(
        jobs,
        q,
        n_query,
        d_head,
        TaskSplit::EvenJobs { max_parallel: threads },
        want_probs,
        q_valid,
        Some(nodes),
    )
}

/// Append-time sparse attention with a task split sized by store length
/// (ROADMAP's pool-aware append re-evaluation).
///
/// Decode submissions split into ≈`cpu_threads` equal-job tasks
/// ([`sparse_attention_masked`]) because every head's contextual cache has
/// similar size. Append-time re-evaluation instead attends each head's
/// *full* CPU store (Algorithm 1 line 19), whose length grows with the
/// sequence and can vary widely — so here the split follows accumulated KV
/// entries: a task closes at `entries_per_task` entries, soft-capped at
/// `max_tasks` tasks. Packing only changes scheduling; outputs are bitwise
/// identical to every other split.
#[allow(clippy::too_many_arguments)]
pub fn sparse_attention_append(
    jobs: &[HeadJob<'_>],
    q: &[f32],
    n_query: usize,
    d_head: usize,
    entries_per_task: usize,
    max_tasks: usize,
    want_probs: bool,
    q_valid: Option<&[usize]>,
) -> CpuAttnOutput {
    AttnPool::global().run_split(
        jobs,
        q,
        n_query,
        d_head,
        TaskSplit::ByEntries {
            per_task: entries_per_task,
            max_tasks,
        },
        want_probs,
        q_valid,
    )
}

/// [`sparse_attention_append`] with a per-job NUMA node map (see
/// [`sparse_attention_masked_placed`]) — the append-time re-evaluation
/// path with shard-aware dispatch.
#[allow(clippy::too_many_arguments)]
pub fn sparse_attention_append_placed(
    jobs: &[HeadJob<'_>],
    q: &[f32],
    n_query: usize,
    d_head: usize,
    entries_per_task: usize,
    max_tasks: usize,
    want_probs: bool,
    q_valid: Option<&[usize]>,
    nodes: &[crate::topology::NodeId],
) -> CpuAttnOutput {
    AttnPool::global().run_placed(
        jobs,
        q,
        n_query,
        d_head,
        TaskSplit::ByEntries {
            per_task: entries_per_task,
            max_tasks,
        },
        want_probs,
        q_valid,
        Some(nodes),
    )
}

/// The original per-call scoped-spawn implementation. Kept as (a) the
/// baseline for the pool-vs-spawn microbenchmarks (benches/hotpath_micro)
/// and (b) an execution-independent reference the conformance tests compare
/// the pool against.
pub fn sparse_attention_spawn(
    jobs: &[HeadJob<'_>],
    q: &[f32],
    n_query: usize,
    d_head: usize,
    threads: usize,
    want_probs: bool,
) -> CpuAttnOutput {
    sparse_attention_spawn_masked(jobs, q, n_query, d_head, threads, want_probs, None)
}

/// See [`sparse_attention_spawn`].
#[allow(clippy::too_many_arguments)]
pub fn sparse_attention_spawn_masked(
    jobs: &[HeadJob<'_>],
    q: &[f32],
    n_query: usize,
    d_head: usize,
    threads: usize,
    want_probs: bool,
    q_valid: Option<&[usize]>,
) -> CpuAttnOutput {
    let nj = jobs.len();
    assert_eq!(q.len(), nj * n_query * d_head, "q layout mismatch");
    let mut o = vec![0.0f32; nj * n_query * d_head];
    let mut lse = vec![EMPTY_LSE; nj * n_query];
    let mut probs: Vec<Vec<f32>> = if want_probs {
        jobs.iter().map(|j| vec![0.0; j.n]).collect()
    } else {
        Vec::new()
    };

    let threads = threads.max(1).min(nj.max(1));
    // contiguous job ranges per task — the "adjacent head packing"
    let per_task = nj.div_ceil(threads.max(1)).max(1);
    let mut tasks = 0;

    if nj == 0 {
        return CpuAttnOutput {
            o,
            lse,
            probs: want_probs.then_some(probs),
            tasks: 0,
            busy_secs: 0.0,
        };
    }

    std::thread::scope(|s| {
        let mut o_rest: &mut [f32] = &mut o;
        let mut lse_rest: &mut [f32] = &mut lse;
        let mut probs_rest: &mut [Vec<f32>] = &mut probs;
        let mut start = 0;
        while start < nj {
            let count = per_task.min(nj - start);
            let (o_task, o_next) = o_rest.split_at_mut(count * n_query * d_head);
            let (lse_task, lse_next) = lse_rest.split_at_mut(count * n_query);
            let (p_task, p_next) = if want_probs {
                probs_rest.split_at_mut(count)
            } else {
                (&mut [][..], &mut [][..])
            };
            o_rest = o_next;
            lse_rest = lse_next;
            probs_rest = p_next;
            let task_jobs = &jobs[start..start + count];
            let task_q = &q[start * n_query * d_head..(start + count) * n_query * d_head];
            let task_valid = q_valid.map(|v| &v[start..start + count]);
            tasks += 1;
            s.spawn(move || {
                run_job_range(
                    task_jobs, task_q, n_query, d_head, o_task, lse_task, p_task, want_probs,
                    task_valid,
                )
            });
            start += count;
        }
    });

    CpuAttnOutput {
        o,
        lse,
        probs: want_probs.then_some(probs),
        tasks,
        busy_secs: 0.0,
    }
}

/// Shared per-range kernel: attention for a contiguous job range, writing a
/// disjoint output slice. Both the pool tasks and the spawn path call this,
/// so the two execution strategies are numerically identical by
/// construction. Runs on the process-wide SIMD dispatch table
/// ([`crate::tensor::simd::kernels`]) — hoisted once per range, so the hot
/// loops pay one indirect call per kernel invocation and every thread in
/// the pool uses the same table (the per-level determinism contract).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_job_range(
    jobs: &[HeadJob<'_>],
    q: &[f32],
    n_query: usize,
    d_head: usize,
    o: &mut [f32],
    lse: &mut [f32],
    probs: &mut [Vec<f32>],
    want_probs: bool,
    q_valid: Option<&[usize]>,
) {
    run_job_range_with(
        simd::kernels(),
        jobs,
        q,
        n_query,
        d_head,
        o,
        lse,
        probs,
        want_probs,
        q_valid,
    )
}

#[allow(clippy::too_many_arguments)]
fn run_job_range_with(
    kn: &Kernels,
    jobs: &[HeadJob<'_>],
    q: &[f32],
    n_query: usize,
    d_head: usize,
    o: &mut [f32],
    lse: &mut [f32],
    probs: &mut [Vec<f32>],
    want_probs: bool,
    q_valid: Option<&[usize]>,
) {
    // reused score buffer — zero allocation per job in the steady state
    let max_n = jobs.iter().map(|j| j.n).max().unwrap_or(0);
    let mut scores = vec![0.0f32; max_n];
    for (ji, job) in jobs.iter().enumerate() {
        if job.n == 0 {
            continue; // lse stays EMPTY, o stays zero
        }
        debug_assert_eq!(job.k.len(), job.n * d_head);
        let nq_limit = q_valid.map(|v| v[ji].min(n_query)).unwrap_or(n_query);
        for nq in 0..nq_limit {
            let qv = &q[(ji * n_query + nq) * d_head..(ji * n_query + nq + 1) * d_head];
            let sc = &mut scores[..job.n];
            for (t, sv) in sc.iter_mut().enumerate() {
                *sv = (kn.dot)(qv, &job.k[t * d_head..(t + 1) * d_head]);
            }
            let l = (kn.softmax_lse)(sc);
            lse[ji * n_query + nq] = l;
            let orow = &mut o[(ji * n_query + nq) * d_head..(ji * n_query + nq + 1) * d_head];
            for (t, &w) in sc.iter().enumerate() {
                if w != 0.0 {
                    (kn.axpy)(w, &job.v[t * d_head..(t + 1) * d_head], orow);
                }
            }
            if want_probs {
                for (t, &w) in sc.iter().enumerate() {
                    probs[ji][t] += w;
                }
            }
        }
    }
}

/// Tiered twin of [`run_job_range`]: the `F32` arm is that function's loop
/// body verbatim (so f32 jobs on the tiered path are bitwise-identical to
/// the plain path), and the `Quant` arm quantizes the query row once per
/// (job, query), dots int8 bytes with a single i32 accumulation, and
/// applies `scale_q * scale_k` once per entry — no dequantized K/V copy is
/// ever materialized. Same LSE-merge contract: empty jobs leave `lse` at
/// `EMPTY_LSE` and `o` at zero.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_job_range_tiered(
    jobs: &[KernelJob<'_>],
    q: &[f32],
    n_query: usize,
    d_head: usize,
    o: &mut [f32],
    lse: &mut [f32],
    probs: &mut [Vec<f32>],
    want_probs: bool,
    q_valid: Option<&[usize]>,
) {
    run_job_range_tiered_with(
        simd::kernels(),
        jobs,
        q,
        n_query,
        d_head,
        o,
        lse,
        probs,
        want_probs,
        q_valid,
    )
}

#[allow(clippy::too_many_arguments)]
fn run_job_range_tiered_with(
    kn: &Kernels,
    jobs: &[KernelJob<'_>],
    q: &[f32],
    n_query: usize,
    d_head: usize,
    o: &mut [f32],
    lse: &mut [f32],
    probs: &mut [Vec<f32>],
    want_probs: bool,
    q_valid: Option<&[usize]>,
) {
    // reused score + quantized-query buffers — zero allocation per job in
    // the steady state
    let max_n = jobs.iter().map(|j| j.n()).max().unwrap_or(0);
    let mut scores = vec![0.0f32; max_n];
    let mut q_i8 = vec![0i8; d_head];
    for (ji, job) in jobs.iter().enumerate() {
        if job.n() == 0 {
            continue; // lse stays EMPTY, o stays zero
        }
        let nq_limit = q_valid.map(|v| v[ji].min(n_query)).unwrap_or(n_query);
        match job {
            KernelJob::F32(job) => {
                debug_assert_eq!(job.k.len(), job.n * d_head);
                for nq in 0..nq_limit {
                    let qv = &q[(ji * n_query + nq) * d_head..(ji * n_query + nq + 1) * d_head];
                    let sc = &mut scores[..job.n];
                    for (t, sv) in sc.iter_mut().enumerate() {
                        *sv = (kn.dot)(qv, &job.k[t * d_head..(t + 1) * d_head]);
                    }
                    let l = (kn.softmax_lse)(sc);
                    lse[ji * n_query + nq] = l;
                    let orow =
                        &mut o[(ji * n_query + nq) * d_head..(ji * n_query + nq + 1) * d_head];
                    for (t, &w) in sc.iter().enumerate() {
                        if w != 0.0 {
                            (kn.axpy)(w, &job.v[t * d_head..(t + 1) * d_head], orow);
                        }
                    }
                    if want_probs {
                        for (t, &w) in sc.iter().enumerate() {
                            probs[ji][t] += w;
                        }
                    }
                }
            }
            KernelJob::Quant { k, v } => {
                let n = k.len();
                debug_assert_eq!(v.len(), n);
                debug_assert_eq!(k.d_head(), d_head);
                for nq in 0..nq_limit {
                    let qv = &q[(ji * n_query + nq) * d_head..(ji * n_query + nq + 1) * d_head];
                    let sq = quantize_row(qv, &mut q_i8);
                    let sc = &mut scores[..n];
                    for (t, sv) in sc.iter_mut().enumerate() {
                        *sv = (kn.dot_i8)(&q_i8, k.entry(t)) as f32 * (sq * k.scale_of(t));
                    }
                    let l = (kn.softmax_lse)(sc);
                    lse[ji * n_query + nq] = l;
                    let orow =
                        &mut o[(ji * n_query + nq) * d_head..(ji * n_query + nq + 1) * d_head];
                    for (t, &w) in sc.iter().enumerate() {
                        if w != 0.0 {
                            let ws = w * v.scale_of(t);
                            for (oj, &b) in orow.iter_mut().zip(v.entry(t)) {
                                *oj += ws * b as f32;
                            }
                        }
                    }
                    if want_probs {
                        for (t, &w) in sc.iter().enumerate() {
                            probs[ji][t] += w;
                        }
                    }
                }
            }
        }
    }
}

/// Single-threaded tiered-kernel reference at an **explicit** dispatch
/// level — the conformance surface for the SIMD layer. Benches and tests
/// use it to run the exact `run_job_range_tiered` loop under two levels
/// side by side in one process (the process-global dispatch freezes once,
/// so it cannot be switched in-process; this bypasses it via
/// [`Kernels::for_level`]). The serving path never calls this — it always
/// goes through the frozen global table.
///
/// `q` is `[jobs][n_query][d_head]` flat, aligned with `payloads`.
/// Returns `(o, lse)` with the same layout and `EMPTY_LSE` contract as
/// [`CpuAttnOutput`]. Panics if `level` is unsupported on this host.
pub fn run_tiered_at_level(
    level: SimdLevel,
    payloads: &[JobPayload],
    q: &[f32],
    n_query: usize,
    d_head: usize,
) -> (Vec<f32>, Vec<f32>) {
    let jobs: Vec<KernelJob<'_>> = payloads
        .iter()
        .map(|p| match p {
            JobPayload::F32(k, v, n) => KernelJob::F32(HeadJob { k, v, n: *n }),
            JobPayload::Int8 { k, v } => KernelJob::Quant { k, v },
        })
        .collect();
    let nj = jobs.len();
    assert_eq!(q.len(), nj * n_query * d_head, "q layout mismatch");
    let mut o = vec![0.0f32; nj * n_query * d_head];
    let mut lse = vec![EMPTY_LSE; nj * n_query];
    let mut probs: Vec<Vec<f32>> = Vec::new();
    run_job_range_tiered_with(
        Kernels::for_level(level),
        &jobs,
        q,
        n_query,
        d_head,
        &mut o,
        &mut lse,
        &mut probs,
        false,
        None,
    );
    (o, lse)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{axpy, dot, softmax_lse};
    use crate::util::proptest::{check, ensure_all_close, ensure_close};
    use crate::util::rng::Rng;

    fn naive_one(q: &[f32], k: &[f32], v: &[f32], n: usize, dh: usize) -> (Vec<f32>, f32) {
        let mut s: Vec<f32> = (0..n).map(|t| dot(q, &k[t * dh..(t + 1) * dh])).collect();
        let lse = softmax_lse(&mut s);
        let mut o = vec![0.0; dh];
        for (t, &w) in s.iter().enumerate() {
            axpy(w, &v[t * dh..(t + 1) * dh], &mut o);
        }
        (o, lse)
    }

    fn rand_kv(rng: &mut Rng, n: usize, dh: usize) -> (Vec<f32>, Vec<f32>) {
        let mut k = vec![0.0; n * dh];
        let mut v = vec![0.0; n * dh];
        rng.fill_normal(&mut k, 1.0);
        rng.fill_normal(&mut v, 1.0);
        (k, v)
    }

    #[test]
    fn single_job_matches_naive() {
        let mut rng = Rng::new(1);
        let (dh, n) = (8, 13);
        let (k, v) = rand_kv(&mut rng, n, dh);
        let mut q = vec![0.0; dh];
        rng.fill_normal(&mut q, 1.0);
        let jobs = [HeadJob { k: &k, v: &v, n }];
        let out = sparse_attention(&jobs, &q, 1, dh, 1, false);
        let (oe, le) = naive_one(&q, &k, &v, n, dh);
        for j in 0..dh {
            assert!((out.o[j] - oe[j]).abs() < 1e-5);
        }
        assert!((out.lse[0] - le).abs() < 1e-5);
    }

    #[test]
    fn empty_job_gets_empty_lse() {
        let dh = 4;
        let q = vec![1.0; dh];
        let jobs = [HeadJob { k: &[], v: &[], n: 0 }];
        let out = sparse_attention(&jobs, &q, 1, dh, 2, false);
        assert_eq!(out.lse[0], EMPTY_LSE);
        assert!(out.o.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn threading_does_not_change_results() {
        let mut rng = Rng::new(2);
        let dh = 16;
        let kvs: Vec<(Vec<f32>, Vec<f32>, usize)> = (0..9)
            .map(|i| {
                let n = 1 + i * 3;
                let (k, v) = rand_kv(&mut rng, n, dh);
                (k, v, n)
            })
            .collect();
        let jobs: Vec<HeadJob> = kvs
            .iter()
            .map(|(k, v, n)| HeadJob { k, v, n: *n })
            .collect();
        let mut q = vec![0.0; jobs.len() * dh];
        rng.fill_normal(&mut q, 1.0);
        let a = sparse_attention(&jobs, &q, 1, dh, 1, true);
        let b = sparse_attention(&jobs, &q, 1, dh, 4, true);
        let c = sparse_attention(&jobs, &q, 1, dh, 16, true);
        assert_eq!(a.o, b.o);
        assert_eq!(a.o, c.o);
        assert_eq!(a.lse, b.lse);
        assert_eq!(a.probs, c.probs);
        assert!(b.tasks <= 4);
        assert_eq!(c.tasks, 9); // capped at job count
    }

    #[test]
    fn probs_sum_to_queries() {
        let mut rng = Rng::new(3);
        let (dh, n, nq) = (8, 10, 3);
        let (k, v) = rand_kv(&mut rng, n, dh);
        let jobs = [HeadJob { k: &k, v: &v, n }];
        let mut q = vec![0.0; nq * dh];
        rng.fill_normal(&mut q, 1.0);
        let out = sparse_attention(&jobs, &q, nq, dh, 1, true);
        let total: f32 = out.probs.as_ref().unwrap()[0].iter().sum();
        assert!((total - nq as f32).abs() < 1e-4);
    }

    #[test]
    fn multi_query_matches_per_query() {
        let mut rng = Rng::new(4);
        let (dh, n, nq) = (8, 7, 4);
        let (k, v) = rand_kv(&mut rng, n, dh);
        let jobs = [HeadJob { k: &k, v: &v, n }];
        let mut q = vec![0.0; nq * dh];
        rng.fill_normal(&mut q, 1.0);
        let out = sparse_attention(&jobs, &q, nq, dh, 1, false);
        for i in 0..nq {
            let (oe, le) = naive_one(&q[i * dh..(i + 1) * dh], &k, &v, n, dh);
            for j in 0..dh {
                assert!((out.o[i * dh + j] - oe[j]).abs() < 1e-5);
            }
            assert!((out.lse[i] - le).abs() < 1e-5);
        }
    }

    #[test]
    fn all_empty_jobs_return_empty_lse_without_panicking() {
        // every job has n == 0 — nothing to attend anywhere
        let dh = 8;
        let nj = 5;
        let jobs: Vec<HeadJob> = (0..nj).map(|_| HeadJob { k: &[], v: &[], n: 0 }).collect();
        let q = vec![1.0; nj * dh];
        for threads in [1usize, 3, 64] {
            let out = sparse_attention(&jobs, &q, 1, dh, threads, true);
            assert!(out.lse.iter().all(|&l| l == EMPTY_LSE));
            assert!(out.o.iter().all(|&x| x == 0.0));
            assert!(out.probs.as_ref().unwrap().iter().all(|p| p.is_empty()));
        }
    }

    #[test]
    fn zero_valid_query_rows_yield_empty_outputs() {
        // q_valid = 0: the job has KV entries but no live queries
        let mut rng = Rng::new(9);
        let (dh, n, nq) = (8, 12, 3);
        let (k, v) = rand_kv(&mut rng, n, dh);
        let jobs = [HeadJob { k: &k, v: &v, n }];
        let mut q = vec![0.0; nq * dh];
        rng.fill_normal(&mut q, 1.0);
        let out = sparse_attention_masked(&jobs, &q, nq, dh, 4, true, Some(&[0]));
        assert!(out.lse.iter().all(|&l| l == EMPTY_LSE));
        assert!(out.o.iter().all(|&x| x == 0.0));
        let total: f32 = out.probs.as_ref().unwrap()[0].iter().sum();
        assert_eq!(total, 0.0, "masked rows contribute no attention mass");
    }

    #[test]
    fn partial_q_valid_matches_unmasked_prefix() {
        // rows below q_valid must equal the unmasked computation; rows at or
        // above it must be inert
        let mut rng = Rng::new(10);
        let (dh, n, nq) = (8, 9, 4);
        let (k, v) = rand_kv(&mut rng, n, dh);
        let jobs = [HeadJob { k: &k, v: &v, n }];
        let mut q = vec![0.0; nq * dh];
        rng.fill_normal(&mut q, 1.0);
        let full = sparse_attention(&jobs, &q, nq, dh, 2, false);
        let masked = sparse_attention_masked(&jobs, &q, nq, dh, 2, false, Some(&[2]));
        assert_eq!(&masked.o[..2 * dh], &full.o[..2 * dh]);
        assert_eq!(&masked.lse[..2], &full.lse[..2]);
        assert!(masked.o[2 * dh..].iter().all(|&x| x == 0.0));
        assert!(masked.lse[2..].iter().all(|&l| l == EMPTY_LSE));
    }

    #[test]
    fn single_job_many_threads_does_not_overdecompose() {
        // one job, absurd thread cap: exactly one task, correct output
        let mut rng = Rng::new(11);
        let (dh, n) = (16, 21);
        let (k, v) = rand_kv(&mut rng, n, dh);
        let jobs = [HeadJob { k: &k, v: &v, n }];
        let mut q = vec![0.0; dh];
        rng.fill_normal(&mut q, 1.0);
        let out = sparse_attention(&jobs, &q, 1, dh, 4096, false);
        assert_eq!(out.tasks, 1);
        let (oe, le) = naive_one(&q, &k, &v, n, dh);
        for j in 0..dh {
            assert!((out.o[j] - oe[j]).abs() < 1e-5);
        }
        assert!((out.lse[0] - le).abs() < 1e-5);
    }

    #[test]
    fn mixed_empty_and_nonempty_jobs_across_thread_counts() {
        let mut rng = Rng::new(12);
        let dh = 8;
        let kvs: Vec<(Vec<f32>, Vec<f32>, usize)> = (0..11)
            .map(|i| {
                let n = if i % 3 == 0 { 0 } else { 1 + i };
                let (k, v) = rand_kv(&mut rng, n, dh);
                (k, v, n)
            })
            .collect();
        let jobs: Vec<HeadJob> = kvs
            .iter()
            .map(|(k, v, n)| HeadJob { k, v, n: *n })
            .collect();
        let mut q = vec![0.0; jobs.len() * dh];
        rng.fill_normal(&mut q, 1.0);
        let base = sparse_attention(&jobs, &q, 1, dh, 1, false);
        for threads in [2usize, 7, 64] {
            let out = sparse_attention(&jobs, &q, 1, dh, threads, false);
            assert_eq!(out.o, base.o, "threads={threads}");
            assert_eq!(out.lse, base.lse, "threads={threads}");
        }
        for (ji, (_, _, n)) in kvs.iter().enumerate() {
            if *n == 0 {
                assert_eq!(base.lse[ji], EMPTY_LSE);
            } else {
                assert!(base.lse[ji].is_finite());
            }
        }
    }

    #[test]
    fn prop_thread_invariance_and_correctness() {
        check("cpu_attn_threads", 25, |rng: &mut Rng| {
            let dh = *rng.choice(&[4usize, 8, 32]);
            let njobs = rng.range(1, 12);
            let nq = rng.range(1, 4);
            let kvs: Vec<(Vec<f32>, Vec<f32>, usize)> = (0..njobs)
                .map(|_| {
                    let n = rng.range(0, 30);
                    let (k, v) = rand_kv(rng, n, dh);
                    (k, v, n)
                })
                .collect();
            let jobs: Vec<HeadJob> = kvs
                .iter()
                .map(|(k, v, n)| HeadJob { k, v, n: *n })
                .collect();
            let mut q = vec![0.0; njobs * nq * dh];
            rng.fill_normal(&mut q, 1.0);
            let t1 = sparse_attention(&jobs, &q, nq, dh, 1, false);
            let tn = sparse_attention(&jobs, &q, nq, dh, rng.range(2, 9), false);
            ensure_all_close(&t1.o, &tn.o, 1e-6, "o")?;
            ensure_all_close(&t1.lse, &tn.lse, 1e-6, "lse")?;
            // spot-check one non-empty job against naive
            for (ji, (k, v, n)) in kvs.iter().enumerate() {
                if *n == 0 {
                    continue;
                }
                let (oe, le) = naive_one(&q[ji * nq * dh..ji * nq * dh + dh], k, v, *n, dh);
                ensure_all_close(&t1.o[ji * nq * dh..ji * nq * dh + dh], &oe, 1e-4, "o_naive")?;
                ensure_close(t1.lse[ji * nq], le, 1e-4, "lse_naive")?;
                break;
            }
            Ok(())
        });
    }

    #[test]
    fn tiered_f32_arm_is_bitwise_identical_to_plain_kernel() {
        let mut rng = Rng::new(21);
        let dh = 8;
        let kvs: Vec<(Vec<f32>, Vec<f32>, usize)> = (0..6)
            .map(|i| {
                let n = if i == 2 { 0 } else { 3 + i * 5 };
                let (k, v) = rand_kv(&mut rng, n, dh);
                (k, v, n)
            })
            .collect();
        let jobs: Vec<HeadJob> = kvs
            .iter()
            .map(|(k, v, n)| HeadJob { k, v, n: *n })
            .collect();
        let tiered: Vec<KernelJob> = jobs.iter().map(|j| KernelJob::F32(*j)).collect();
        let nq = 2;
        let mut q = vec![0.0; jobs.len() * nq * dh];
        rng.fill_normal(&mut q, 1.0);
        let mut o_a = vec![0.0; jobs.len() * nq * dh];
        let mut o_b = o_a.clone();
        let mut lse_a = vec![EMPTY_LSE; jobs.len() * nq];
        let mut lse_b = lse_a.clone();
        let mut p_a: Vec<Vec<f32>> = kvs.iter().map(|(_, _, n)| vec![0.0; *n]).collect();
        let mut p_b = p_a.clone();
        run_job_range(&jobs, &q, nq, dh, &mut o_a, &mut lse_a, &mut p_a, true, None);
        run_job_range_tiered(&tiered, &q, nq, dh, &mut o_b, &mut lse_b, &mut p_b, true, None);
        assert_eq!(o_a, o_b);
        assert_eq!(lse_a, lse_b);
        assert_eq!(p_a, p_b);
    }

    #[test]
    fn quant_arm_tracks_f32_oracle() {
        use crate::kv::quant::QuantSlab;
        let mut rng = Rng::new(22);
        let dh = 8;
        let n = 48;
        let (k, v) = rand_kv(&mut rng, n, dh);
        let qk = QuantSlab::from_f32(&k, dh, 32);
        let qv = QuantSlab::from_f32(&v, dh, 32);
        let mut q = vec![0.0; dh];
        rng.fill_normal(&mut q, 1.0);
        let f32_jobs = [HeadJob { k: &k, v: &v, n }];
        let quant_jobs = [KernelJob::Quant { k: &qk, v: &qv }];
        let mut o_a = vec![0.0; dh];
        let mut o_b = vec![0.0; dh];
        let mut lse_a = vec![EMPTY_LSE; 1];
        let mut lse_b = vec![EMPTY_LSE; 1];
        let mut p_a = vec![vec![0.0; n]];
        let mut p_b = vec![vec![0.0; n]];
        run_job_range(&f32_jobs, &q, 1, dh, &mut o_a, &mut lse_a, &mut p_a, true, None);
        run_job_range_tiered(&quant_jobs, &q, 1, dh, &mut o_b, &mut lse_b, &mut p_b, true, None);
        for (a, b) in o_a.iter().zip(o_b.iter()) {
            assert!((a - b).abs() <= 1e-2, "output drift: {a} vs {b}");
        }
        assert!((lse_a[0] - lse_b[0]).abs() <= 1e-2, "lse drift");
        let mass: f32 = p_b[0].iter().sum();
        assert!((mass - 1.0).abs() < 1e-4, "quant probs still a distribution");
    }

    #[test]
    fn empty_quant_job_leaves_empty_lse() {
        use crate::kv::quant::QuantSlab;
        let dh = 4;
        let qk = QuantSlab::new(dh, 1);
        let qv = QuantSlab::new(dh, 1);
        let jobs = [KernelJob::Quant { k: &qk, v: &qv }];
        let q = vec![1.0; dh];
        let mut o = vec![0.0; dh];
        let mut lse = vec![EMPTY_LSE; 1];
        let mut probs = vec![vec![]];
        run_job_range_tiered(&jobs, &q, 1, dh, &mut o, &mut lse, &mut probs, true, None);
        assert_eq!(lse[0], EMPTY_LSE);
        assert!(o.iter().all(|&x| x == 0.0));
    }
}
