//! Dense attention reference (rust oracle). Semantics identical to
//! python/compile/kernels/ref.py::attention_with_lse; used to cross-check
//! the PJRT artifacts and as the full-attention baseline ("HF full").

use crate::tensor::ops::{axpy, dot, softmax_lse};

/// Attention of one query over `n` KV entries ([n][dh] contiguous) with an
/// optional additive bias per slot. Returns (o, lse).
pub fn attend_one(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d_head: usize,
    bias: Option<&[f32]>,
) -> (Vec<f32>, f32) {
    let mut scores: Vec<f32> = (0..n)
        .map(|t| dot(q, &k[t * d_head..(t + 1) * d_head]))
        .collect();
    if let Some(b) = bias {
        for (s, &bv) in scores.iter_mut().zip(b.iter()) {
            *s += bv;
        }
    }
    let lse = softmax_lse(&mut scores);
    let mut o = vec![0.0; d_head];
    for (t, &w) in scores.iter().enumerate() {
        axpy(w, &v[t * d_head..(t + 1) * d_head], &mut o);
    }
    (o, lse)
}

/// Full softmax probabilities of one query (analysis path, Figs. 3–5).
pub fn attend_probs(q: &[f32], k: &[f32], n: usize, d_head: usize) -> Vec<f32> {
    let mut scores: Vec<f32> = (0..n)
        .map(|t| dot(q, &k[t * d_head..(t + 1) * d_head]))
        .collect();
    softmax_lse(&mut scores);
    scores
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_keys_give_uniform_probs() {
        let dh = 4;
        let q = vec![1.0; dh];
        let k = vec![0.5; 3 * dh];
        let p = attend_probs(&q, &k, 3, dh);
        for &w in &p {
            assert!((w - 1.0 / 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn bias_shifts_selection() {
        let dh = 2;
        let q = vec![0.0; dh]; // all scores 0 without bias
        let k = vec![0.0; 3 * dh];
        let mut v = vec![0.0; 3 * dh];
        v[2 * dh] = 1.0; // entry 2 has v = [1, 0]
        let bias = [0.0, 0.0, 50.0];
        let (o, _) = attend_one(&q, &k, &v, 3, dh, Some(&bias));
        assert!((o[0] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn matches_cpu_sparse_attention() {
        use crate::attention::cpu_attention::{sparse_attention, HeadJob};
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        let (dh, n) = (16, 21);
        let mut k = vec![0.0; n * dh];
        let mut v = vec![0.0; n * dh];
        let mut q = vec![0.0; dh];
        rng.fill_normal(&mut k, 1.0);
        rng.fill_normal(&mut v, 1.0);
        rng.fill_normal(&mut q, 1.0);
        let (o1, l1) = attend_one(&q, &k, &v, n, dh, None);
        let out = sparse_attention(&[HeadJob { k: &k, v: &v, n }], &q, 1, dh, 2, false);
        for j in 0..dh {
            assert!((o1[j] - out.o[j]).abs() < 1e-6);
        }
        assert!((l1 - out.lse[0]).abs() < 1e-6);
    }
}
