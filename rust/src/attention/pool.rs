//! Persistent CPU attention worker pool (paper §3.3, production form),
//! sharded into per-NUMA-node queues.
//!
//! The seed implementation spawned fresh `std::thread`s on every
//! `sparse_attention` call — fine for one long prefill, ruinous for decode
//! serving where each step submits batch×heads tiny jobs and the per-call
//! spawn/join cost dominates. This pool keeps a fixed set of long-lived
//! workers behind **one FIFO queue per NUMA node**
//! ([`crate::topology::Topology`]):
//!
//! * **submit/wait** — [`AttnPool::run_masked`] packs the (row, head) jobs
//!   into contiguous ranges ("adjacent head merging"), enqueues one task per
//!   range, and blocks until the batch completes. Each task writes a
//!   disjoint slice of pre-allocated output buffers, exactly as the spawn
//!   path did.
//! * **non-blocking submit** — [`AttnPool::submit_placed`] takes **owned**
//!   inputs ([`OwnedJobs`]), enqueues the same planned tasks, and returns a
//!   [`PendingAttn`] handle immediately; `wait()` performs the blocking
//!   path's caller-assist drain + completion wait. Inputs and outputs live
//!   in Arc'd storage every task keeps alive, so the submitter can run
//!   serial work (the engine's KV bookkeeping) concurrently with the
//!   sparse jobs — the HGCA overlap — and even drop the handle without
//!   waiting. The blocking entry points are thin submit + wait wrappers
//!   over the same core.
//! * **placement** — [`AttnPool::run_placed`] takes a per-job node map (the
//!   KV shard map, see `kv::CpuLayerStore`): each task lands on the queue
//!   of its first job's node, so the workers pinned to that node stream
//!   their local KV slabs. Unplaced submissions round-robin tasks across
//!   queues. On a single-node topology there is exactly one queue and the
//!   pool behaves bit-for-bit like the original flat injector.
//! * **work stealing** — workers drain their own node's queue first and
//!   steal from other nodes (deterministic wrap order) when idle, so
//!   placement is an optimization, never a progress hazard. The submitting
//!   thread doesn't idle either: it pops tasks — its home node first —
//!   until its batch drains (caller-assist), so progress is guaranteed even
//!   with zero workers. Cross-node *worker* executions are counted per
//!   node ([`PoolStats::node_steals`]) so locality regressions are
//!   visible; the unpinned caller's off-home pops are routine and tracked
//!   separately ([`PoolStats::caller_assist_cross_node`]).
//! * **determinism** — task packing ([`TaskSplit`]) depends only on the
//!   job shapes and the split parameters, never on worker count, topology,
//!   or scheduling, and every job's arithmetic touches only its own
//!   inputs/outputs. Results are therefore **bitwise identical** across
//!   pool sizes, parallelism caps, split strategies, topologies, and
//!   repeated runs. The conformance suites pin this.
//! * **split strategies** — decode packs by job count
//!   ([`TaskSplit::EvenJobs`], heads have similar working sets); append-time
//!   full-store re-evaluation packs by KV entries
//!   ([`TaskSplit::ByEntries`]), so parallelism follows the store length
//!   instead of the decode cap.
//!
//! Multiple engines (threads) may share one pool; tasks from concurrent
//! submissions interleave in FIFO order per node queue. [`AttnPool::global`]
//! is the process-wide instance used by `sparse_attention*`; its size comes
//! from `HGCA_POOL_THREADS` or `available_parallelism`, and its topology
//! from [`Topology::detect`] (`HGCA_NUMA_NODES` / sysfs) — or from
//! [`AttnPool::init_global`] when the serving binary passes `--numa-nodes`
//! before first use.

use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

use crate::kv::QuantSlab;
use crate::topology::{NodeId, Topology};

use super::cpu_attention::{
    run_job_range, run_job_range_tiered, CpuAttnOutput, HeadJob, KernelJob, EMPTY_LSE,
};

/// How a submission's (row, head) jobs are packed into contiguous pool
/// tasks. The plan depends only on the job list and the split parameters —
/// never on worker availability, scheduling, or topology (placement assigns
/// each *planned* task a queue; it never reshapes the plan) — which is what
/// keeps pool output bitwise identical across pool sizes (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskSplit {
    /// At most `max_parallel` contiguous tasks of (near-)equal *job count* —
    /// the decode path, where every head's working set (the contextual
    /// cache) has similar size and job count is a good proxy for work.
    EvenJobs {
        /// Upper bound on the number of packed tasks (the engine passes
        /// `cfg.cpu_threads`).
        max_parallel: usize,
    },
    /// Contiguous tasks sized by accumulated *KV entries*: a task closes
    /// once adding the next job would exceed `per_task` entries. This is
    /// the append path (full-store re-evaluation, Algorithm 1 line 19),
    /// where per-head lengths grow with the sequence and the right
    /// parallelism follows the store size rather than the decode cap
    /// (ROADMAP: pool-aware append re-evaluation).
    ByEntries {
        /// Target KV entries per task (≥ 1; a single job larger than this
        /// still forms one task — jobs are never split).
        per_task: usize,
        /// Soft cap on task count: when the greedy split produces more,
        /// adjacent tasks are merged down to at most this many.
        max_tasks: usize,
    },
}

impl TaskSplit {
    /// Contiguous per-task job counts (in job order; sums to `jobs.len()`).
    pub(crate) fn plan(&self, jobs: &[HeadJob<'_>]) -> Vec<usize> {
        let ns: Vec<usize> = jobs.iter().map(|j| j.n).collect();
        self.plan_counts(&ns)
    }

    /// [`TaskSplit::plan`] over bare per-job entry counts — the plan never
    /// looks at anything but `n`, so the f32 and tiered paths share one
    /// packing (a tiered job and an f32 job with equal `n` split
    /// identically, which is what keeps placement and determinism
    /// tier-independent).
    pub(crate) fn plan_counts(&self, ns: &[usize]) -> Vec<usize> {
        let nj = ns.len();
        if nj == 0 {
            return Vec::new();
        }
        match *self {
            TaskSplit::EvenJobs { max_parallel } => {
                let threads = max_parallel.max(1).min(nj);
                let per_task = nj.div_ceil(threads).max(1);
                let mut counts = Vec::with_capacity(nj.div_ceil(per_task));
                let mut start = 0;
                while start < nj {
                    let c = per_task.min(nj - start);
                    counts.push(c);
                    start += c;
                }
                counts
            }
            TaskSplit::ByEntries { per_task, max_tasks } => {
                let per_task = per_task.max(1);
                let mut counts = Vec::new();
                let (mut cur_jobs, mut cur_entries) = (0usize, 0usize);
                for &n in ns {
                    if cur_jobs > 0 && cur_entries + n > per_task {
                        counts.push(cur_jobs);
                        cur_jobs = 0;
                        cur_entries = 0;
                    }
                    cur_jobs += 1;
                    cur_entries += n;
                }
                if cur_jobs > 0 {
                    counts.push(cur_jobs);
                }
                let max_tasks = max_tasks.max(1);
                if counts.len() > max_tasks {
                    // merge adjacent tasks down to the cap (deterministic)
                    let group = counts.len().div_ceil(max_tasks);
                    counts = counts.chunks(group).map(|g| g.iter().sum::<usize>()).collect();
                }
                counts
            }
        }
    }
}

/// One queued unit of work: a type-erased closure over a contiguous job
/// range, plus the batch it belongs to.
struct Task {
    run: Box<dyn FnOnce() + Send + 'static>,
    batch: Arc<BatchState>,
}

/// Completion tracking for one submission.
struct BatchState {
    remaining: Mutex<usize>,
    done_cv: Condvar,
    /// set when any task of this batch panicked — the submitter must not
    /// treat the (partially written) outputs as valid
    poisoned: AtomicBool,
    /// summed task execution nanoseconds for **this submission** (pool-side
    /// busy time — distinct from the submitter's wall wait, which under
    /// overlapped execution also covers its own bookkeeping work)
    busy_ns: AtomicU64,
}

impl BatchState {
    fn new(n: usize) -> Arc<BatchState> {
        Arc::new(BatchState {
            remaining: Mutex::new(n),
            done_cv: Condvar::new(),
            poisoned: AtomicBool::new(false),
            busy_ns: AtomicU64::new(0),
        })
    }

    fn finish_one(&self) {
        let mut rem = self.remaining.lock().unwrap();
        *rem -= 1;
        if *rem == 0 {
            self.done_cv.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        *self.remaining.lock().unwrap() == 0
    }

    fn wait(&self) {
        let mut rem = self.remaining.lock().unwrap();
        while *rem > 0 {
            rem = self.done_cv.wait(rem).unwrap();
        }
    }
}

#[derive(Default)]
struct Counters {
    submissions: AtomicU64,
    tasks: AtomicU64,
    jobs: AtomicU64,
    busy_ns: AtomicU64,
    queue_peak: AtomicUsize,
    pinned_workers: AtomicUsize,
}

/// One NUMA node's FIFO injector.
struct NodeQueue {
    queue: Mutex<VecDeque<Task>>,
}

struct Shared {
    /// One FIFO queue per topology node (always ≥ 1).
    queues: Vec<NodeQueue>,
    /// Sleep coordination: producers take this lock while notifying, and
    /// sleepers re-check the queued count under it before waiting — a push
    /// between a sleeper's check and its wait can therefore never be
    /// missed (the producer blocks on this lock until the sleeper waits).
    idle: Mutex<()>,
    work_cv: Condvar,
    shutdown: AtomicBool,
    /// Tasks currently queued across every node (depth/peak accounting).
    queued: AtomicUsize,
    counters: Counters,
    /// Tasks enqueued per node (placement accounting).
    node_tasks: Vec<AtomicU64>,
    /// Tasks a node's **pinned worker** executed from *another* node's
    /// queue — the cross-node steal count (the locality signal).
    node_steals: Vec<AtomicU64>,
    /// Tasks the submitting thread drained from a queue other than its
    /// batch's home node. Counted separately from worker steals: the
    /// caller isn't pinned anywhere, so its cross-node pops are routine
    /// under healthy load and must not pollute the locality signal.
    caller_steals: AtomicU64,
}

impl Shared {
    fn pop_from(&self, node: usize) -> Option<Task> {
        let t = self.queues[node].queue.lock().unwrap().pop_front();
        if t.is_some() {
            self.queued.fetch_sub(1, Ordering::Relaxed);
        }
        t
    }

    /// Pop preferring `home`'s queue, then the remaining nodes in
    /// deterministic wrap order. Returns the task and the node whose queue
    /// held it.
    fn pop_task_preferring(&self, home: usize) -> Option<(Task, usize)> {
        let n = self.queues.len();
        for i in 0..n {
            let node = (home + i) % n;
            if let Some(t) = self.pop_from(node) {
                return Some((t, node));
            }
        }
        None
    }

    fn any_queued(&self) -> bool {
        self.queued.load(Ordering::Relaxed) > 0
    }

    /// Wake sleeping workers after pushing work (see the `idle` field for
    /// why the lock is held around the notify).
    fn signal_work(&self) {
        let _g = self.idle.lock().unwrap();
        self.work_cv.notify_all();
    }

    /// Run one task, catching panics so the batch completion count is
    /// decremented no matter what (a waiter must never hang, and queued
    /// sibling tasks must never outlive their borrowed buffers — see the
    /// SAFETY notes in `submit_core`). Returns the panic payload, if any.
    ///
    /// Invoking `run` consumes the closure, so everything it captured —
    /// including its `Arc<PendingStorage>` keep-alive — is dropped *before*
    /// `finish_one` wakes the waiter; [`PendingAttn::wait`] relies on that
    /// to reclaim the storage with `Arc::try_unwrap`.
    fn run_task(&self, task: Task) -> Option<Box<dyn std::any::Any + Send>> {
        let Task { run, batch } = task;
        let t0 = Instant::now();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(run));
        let dt = t0.elapsed().as_nanos() as u64;
        self.counters.busy_ns.fetch_add(dt, Ordering::Relaxed);
        batch.busy_ns.fetch_add(dt, Ordering::Relaxed);
        if result.is_err() {
            batch.poisoned.store(true, Ordering::SeqCst);
        }
        batch.finish_one();
        result.err()
    }

    /// [`Shared::run_task`] on behalf of a **worker** pinned to `home`,
    /// counting a cross-node steal when the task came from another node.
    fn run_for_worker(
        &self,
        task: Task,
        from: usize,
        home: usize,
    ) -> Option<Box<dyn std::any::Any + Send>> {
        if from != home {
            self.node_steals[home].fetch_add(1, Ordering::Relaxed);
        }
        self.run_task(task)
    }

    /// [`Shared::run_task`] on behalf of the submitting thread
    /// (caller-assist), counting its cross-node pops separately — they
    /// are routine, not a locality regression.
    fn run_for_caller(
        &self,
        task: Task,
        from: usize,
        home: usize,
    ) -> Option<Box<dyn std::any::Any + Send>> {
        if from != home {
            self.caller_steals.fetch_add(1, Ordering::Relaxed);
        }
        self.run_task(task)
    }
}

/// Owned inputs for a non-blocking submission
/// ([`AttnPool::submit_placed`]): per-job KV copies plus the flat query
/// block. The engine's gather loop already produces exactly this shape
/// (owned copies out of the CPU store), so handing it to the pool moves
/// vectors — it never re-copies KV.
pub struct OwnedJobs {
    /// per job: contiguous `[n][d_head]` K and V copies + entry count `n`
    pub kvs: Vec<(Vec<f32>, Vec<f32>, usize)>,
    /// `[jobs][n_query][d_head]` flat queries, aligned with `kvs`
    pub q: Vec<f32>,
    /// per-job count of valid query rows (`None` = all rows valid)
    pub q_valid: Option<Vec<usize>>,
}

/// One job's owned KV payload on the tiered submission path
/// ([`AttnPool::submit_tiered`]): the f32 shape [`OwnedJobs`] uses, or a
/// pair of quantized slabs for an int8-tiered head. The task split and
/// placement only ever read [`JobPayload::n`].
pub enum JobPayload {
    /// Contiguous `[n][d_head]` K and V copies + entry count `n` —
    /// identical layout (and, through the kernel's F32 arm, identical
    /// numerics) to the plain f32 path.
    F32(Vec<f32>, Vec<f32>, usize),
    /// Quantized K and V slabs for an int8-tiered head.
    Int8 { k: QuantSlab, v: QuantSlab },
}

impl JobPayload {
    /// KV entries in this job.
    pub fn n(&self) -> usize {
        match self {
            JobPayload::F32(_, _, n) => *n,
            JobPayload::Int8 { k, .. } => k.len(),
        }
    }
}

/// Owned inputs for a tiered non-blocking submission
/// ([`AttnPool::submit_tiered`]) — [`OwnedJobs`] with per-job tier choice.
pub struct OwnedTieredJobs {
    /// Per-job payloads (f32 or quantized), in job order.
    pub kvs: Vec<JobPayload>,
    /// `[jobs][n_query][d_head]` flat queries, aligned with `kvs`
    pub q: Vec<f32>,
    /// per-job count of valid query rows (`None` = all rows valid)
    pub q_valid: Option<Vec<usize>>,
}

/// The owned-input variants a [`PendingStorage`] can hold (tasks borrow
/// into whichever is present).
enum OwnedAny {
    F32(OwnedJobs),
    Tiered(OwnedTieredJobs),
}

/// Output buffers the tasks of one submission write into (disjoint slices
/// handed out at submit time).
struct OutBufs {
    o: Vec<f32>,
    lse: Vec<f32>,
    probs: Vec<Vec<f32>>,
}

/// Heap home of one submission's data: the owned inputs its tasks borrow
/// (`None` on the blocking path, whose inputs live in the caller's frame
/// under the historical block-before-return contract) plus the output
/// buffers. Shared as `Arc<PendingStorage>` by the [`PendingAttn`] handle
/// *and every task closure*, so the data outlives the last task no matter
/// when — or whether — the submitter waits. This owned storage is what
/// lets `submit_placed` return without blocking.
struct PendingStorage {
    owned: Option<OwnedAny>,
    out: UnsafeCell<OutBufs>,
}

// SAFETY: `owned` is never written after construction (tasks only read
// through shared borrows). `out` is only touched through pairwise-disjoint
// `&mut` slices split off before the tasks are published (split_at_mut),
// and the handle re-reads it only after batch completion — the batch
// mutex provides the happens-before edge from every task's writes.
unsafe impl Send for PendingStorage {}
unsafe impl Sync for PendingStorage {}

/// Handle to an in-flight submission ([`AttnPool::submit_placed`] /
/// `submit_core`). [`PendingAttn::wait`] performs the blocking path's
/// caller-assist drain + completion wait and returns the output; dropping
/// the handle without waiting is safe — the drop drains and waits out the
/// batch (swallowing task panics, since it may already be unwinding), so
/// queues and counters are quiescent and nothing leaks. The handle owns
/// [`Arc`]s only (no borrows), so it can outlive the submitting frame.
pub struct PendingAttn {
    shared: Arc<Shared>,
    batch: Arc<BatchState>,
    /// `Some` until consumed by [`PendingAttn::wait`]
    storage: Option<Arc<PendingStorage>>,
    /// node of the batch's first task — where caller-assist pops first
    home: usize,
    n_tasks: usize,
    want_probs: bool,
}

impl PendingAttn {
    /// Caller-assist drain + completion wait: pop tasks — home node first,
    /// then the other queues, possibly from concurrent submissions — until
    /// this batch drains, wait out stragglers running on other threads,
    /// then hand back the submission's output. Identical scheduling to the
    /// blocking `run_placed` (which is now literally submit + wait).
    ///
    /// # Panics
    ///
    /// Re-raises a panic from a task the *caller* ran, and asserts that no
    /// worker-run task of this submission panicked (the output would be
    /// garbage). In both cases the batch is fully settled first.
    pub fn wait(mut self) -> CpuAttnOutput {
        while !self.batch.is_done() {
            let Some((task, from)) = self.shared.pop_task_preferring(self.home) else {
                break;
            };
            if let Some(payload) = self.shared.run_for_caller(task, from, self.home) {
                // a task the caller ran panicked: propagate to the caller
                // (Drop settles the rest of the batch first)
                std::panic::resume_unwind(payload);
            }
        }
        self.batch.wait();
        // a task that panicked on a worker completed its batch slot (so we
        // never hang) but its output range is garbage — surface the failure
        // on the submitting thread instead of returning partial results
        assert!(
            !self.batch.poisoned.load(Ordering::SeqCst),
            "attention pool: a task of this submission panicked"
        );
        let mut storage = self.storage.take().expect("storage present until wait");
        let (n_tasks, want_probs) = (self.n_tasks, self.want_probs);
        let busy_secs = self.batch.busy_ns.load(Ordering::Relaxed) as f64 / 1e9;
        drop(self); // batch settled + storage taken → Drop is a no-op
        // every task dropped its storage keep-alive before completing its
        // batch slot (see `run_task`), so this Arc is the last one; the
        // loop only guards against an unwinding task still tearing down
        let bufs = loop {
            match Arc::try_unwrap(storage) {
                Ok(s) => break s.out.into_inner(),
                Err(back) => {
                    storage = back;
                    std::thread::yield_now();
                }
            }
        };
        CpuAttnOutput {
            o: bufs.o,
            lse: bufs.lse,
            probs: want_probs.then_some(bufs.probs),
            tasks: n_tasks,
            busy_secs,
        }
    }
}

impl Drop for PendingAttn {
    fn drop(&mut self) {
        if self.storage.is_none() {
            return; // consumed by wait(): batch already settled
        }
        // dropped without wait() — or unwinding out of wait's assist loop.
        // Memory is already safe (tasks keep the storage alive via their
        // own Arc clones); draining + waiting here keeps the pool's queues
        // and counters quiescent when the handle dies, and mirrors the old
        // BatchGuard unwind contract. Panic payloads are swallowed: we may
        // already be unwinding, and a double panic would abort.
        while !self.batch.is_done() {
            match self.shared.pop_task_preferring(self.home) {
                Some((t, from)) => {
                    let _ = self.shared.run_for_caller(t, from, self.home);
                }
                None => break,
            }
        }
        self.batch.wait();
    }
}

/// Read-only snapshot of pool activity (serving metrics endpoint).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PoolStats {
    pub workers: usize,
    /// queues (= topology nodes) the pool is sharded into
    pub numa_nodes: usize,
    /// workers whose best-effort CPU-affinity pin succeeded
    pub pinned_workers: usize,
    /// run_masked calls
    pub submissions: u64,
    /// packed tasks executed (≈ submissions × min(parallelism, jobs))
    pub tasks: u64,
    /// (row, head) jobs processed
    pub jobs: u64,
    /// summed task execution time across workers + caller-assist
    pub busy_secs: f64,
    /// tasks currently queued (across every node)
    pub queue_depth: usize,
    /// high-water mark of the total queue depth at enqueue time
    pub queue_peak: usize,
    /// tasks enqueued per node (len = numa_nodes)
    pub node_tasks: Vec<u64>,
    /// tasks node i's **pinned workers** ran from *other* nodes' queues
    pub node_steals: Vec<u64>,
    /// tasks the submitting thread drained from queues other than its
    /// batch's home node (caller-assist is unpinned, so these are routine
    /// and deliberately excluded from the locality signal)
    pub caller_assist_cross_node: u64,
}

impl PoolStats {
    /// Total cross-node **worker** executions (the locality-regression
    /// signal — near 0 under balanced, well-placed load; caller-assist
    /// drains are counted separately).
    pub fn cross_node_steals(&self) -> u64 {
        self.node_steals.iter().sum()
    }
}

/// Persistent worker pool for CPU sparse attention, one queue per NUMA
/// node of its [`Topology`].
pub struct AttnPool {
    shared: Arc<Shared>,
    topology: Topology,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// The process-wide pool instance (see [`AttnPool::global`] /
/// [`AttnPool::init_global`]).
static GLOBAL: OnceLock<AttnPool> = OnceLock::new();

fn global_workers() -> usize {
    std::env::var("HGCA_POOL_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        })
}

impl AttnPool {
    /// Spawn a flat (single-node) pool with `workers` long-lived threads.
    /// Zero workers is valid: every submission then runs entirely on the
    /// calling thread (the caller-assist path), which is the
    /// deterministic-latency configuration some tests use.
    pub fn new(workers: usize) -> AttnPool {
        AttnPool::with_topology(workers, Topology::single())
    }

    /// Spawn a pool sharded across `topology`'s nodes: one FIFO queue per
    /// node, workers assigned round-robin (worker *i* homes on node
    /// `i % nodes`) and best-effort pinned to their node's CPU set
    /// ([`Topology::pin_current_thread`] — a no-op on synthetic
    /// topologies). A single-node topology reproduces the original flat
    /// pool exactly.
    pub fn with_topology(workers: usize, topology: Topology) -> AttnPool {
        let nodes = topology.nodes();
        let shared = Arc::new(Shared {
            queues: (0..nodes)
                .map(|_| NodeQueue {
                    queue: Mutex::new(VecDeque::new()),
                })
                .collect(),
            idle: Mutex::new(()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            queued: AtomicUsize::new(0),
            counters: Counters::default(),
            node_tasks: (0..nodes).map(|_| AtomicU64::new(0)).collect(),
            node_steals: (0..nodes).map(|_| AtomicU64::new(0)).collect(),
            caller_steals: AtomicU64::new(0),
        });
        let handles = (0..workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                let topo = topology.clone();
                let home = topo.node_of(i);
                std::thread::Builder::new()
                    .name(format!("hgca-attn-{home}-{i}"))
                    .spawn(move || {
                        if topo.pin_current_thread(home) {
                            sh.counters.pinned_workers.fetch_add(1, Ordering::Relaxed);
                        }
                        worker_loop(&sh, home);
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        AttnPool {
            shared,
            topology,
            workers: handles,
        }
    }

    /// The process-wide pool used by `sparse_attention*`. Sized by
    /// `HGCA_POOL_THREADS` when set, else `available_parallelism`; sharded
    /// per [`Topology::detect`] (`HGCA_NUMA_NODES` env override, then
    /// sysfs, else flat) unless [`AttnPool::init_global`] supplied an
    /// explicit topology first.
    pub fn global() -> &'static AttnPool {
        GLOBAL.get_or_init(|| AttnPool::with_topology(global_workers(), Topology::detect()))
    }

    /// Initialize the process-wide pool with an explicit topology (the
    /// serving binary's `--numa-nodes`, parsed *before* anything touches
    /// the pool). Returns `false` when the pool was already initialized —
    /// the topology then came from the first caller's [`Topology::detect`]
    /// and the explicit one is ignored (callers should surface that).
    pub fn init_global(topology: Topology) -> bool {
        let mut initialized = false;
        GLOBAL.get_or_init(|| {
            initialized = true;
            AttnPool::with_topology(global_workers(), topology)
        });
        initialized
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// The topology this pool is sharded over.
    pub fn topology(&self) -> &Topology {
        &self.topology
    }

    pub fn stats(&self) -> PoolStats {
        let c = &self.shared.counters;
        PoolStats {
            workers: self.workers.len(),
            numa_nodes: self.shared.queues.len(),
            pinned_workers: c.pinned_workers.load(Ordering::Relaxed),
            submissions: c.submissions.load(Ordering::Relaxed),
            tasks: c.tasks.load(Ordering::Relaxed),
            jobs: c.jobs.load(Ordering::Relaxed),
            busy_secs: c.busy_ns.load(Ordering::Relaxed) as f64 / 1e9,
            queue_depth: self.shared.queued.load(Ordering::Relaxed),
            queue_peak: c.queue_peak.load(Ordering::Relaxed),
            node_tasks: self
                .shared
                .node_tasks
                .iter()
                .map(|t| t.load(Ordering::Relaxed))
                .collect(),
            node_steals: self
                .shared
                .node_steals
                .iter()
                .map(|s| s.load(Ordering::Relaxed))
                .collect(),
            caller_assist_cross_node: self.shared.caller_steals.load(Ordering::Relaxed),
        }
    }

    /// Pool-backed sparse attention: identical contract and numerics to the
    /// per-call-spawn path (`sparse_attention_spawn_masked`), minus the
    /// thread spawn/join per call. `max_parallel` caps how many packed
    /// tasks the submission splits into (the engine passes
    /// `cfg.cpu_threads`); output is bitwise independent of both this cap
    /// and the pool's worker count.
    ///
    /// This is the submit/wait entry point: the call enqueues one task per
    /// packed job range and blocks until every task has completed (workers
    /// and the calling thread drain the same queues).
    ///
    /// # Example
    ///
    /// ```
    /// use hgca::attention::{AttnPool, HeadJob};
    ///
    /// let pool = AttnPool::new(2);
    /// // one head attending 3 KV entries of dimension 4
    /// let k = vec![0.0_f32; 3 * 4]; // zero keys → uniform softmax
    /// let v = vec![1.0_f32; 3 * 4];
    /// let jobs = [HeadJob { k: &k, v: &v, n: 3 }];
    /// let q = vec![0.5_f32; 4];
    /// let out = pool.run_masked(&jobs, &q, 1, 4, 1, false, None);
    /// assert_eq!(out.o.len(), 4); // [jobs][n_query][d_head]
    /// assert!((out.o[0] - 1.0).abs() < 1e-6); // mean of identical values
    /// assert!((out.lse[0] - 3.0_f32.ln()).abs() < 1e-6);
    /// ```
    #[allow(clippy::too_many_arguments)]
    pub fn run_masked(
        &self,
        jobs: &[HeadJob<'_>],
        q: &[f32],
        n_query: usize,
        d_head: usize,
        max_parallel: usize,
        want_probs: bool,
        q_valid: Option<&[usize]>,
    ) -> CpuAttnOutput {
        self.run_placed(
            jobs,
            q,
            n_query,
            d_head,
            TaskSplit::EvenJobs { max_parallel },
            want_probs,
            q_valid,
            None,
        )
    }

    /// [`run_masked`](AttnPool::run_masked) with an explicit [`TaskSplit`].
    /// Packing only changes scheduling: outputs are bitwise identical for
    /// every split (each job's arithmetic touches only its own inputs and
    /// its own disjoint output range).
    #[allow(clippy::too_many_arguments)]
    pub fn run_split(
        &self,
        jobs: &[HeadJob<'_>],
        q: &[f32],
        n_query: usize,
        d_head: usize,
        split: TaskSplit,
        want_probs: bool,
        q_valid: Option<&[usize]>,
    ) -> CpuAttnOutput {
        self.run_placed(jobs, q, n_query, d_head, split, want_probs, q_valid, None)
    }

    /// [`run_split`](AttnPool::run_split) with an explicit per-job node
    /// map (the KV shard map): each planned task is enqueued on the queue
    /// of its **first job's** node (`nodes[start] % numa_nodes` — out-of-
    /// range nodes wrap, so a shard map built for a wider topology still
    /// routes deterministically). `None` round-robins tasks across queues
    /// by task index. Placement changes *which queue runs a task*, never
    /// the task plan or the numerics — outputs stay bitwise identical to
    /// every other placement (and to the flat pool).
    #[allow(clippy::too_many_arguments)]
    pub fn run_placed(
        &self,
        jobs: &[HeadJob<'_>],
        q: &[f32],
        n_query: usize,
        d_head: usize,
        split: TaskSplit,
        want_probs: bool,
        q_valid: Option<&[usize]>,
        nodes: Option<&[NodeId]>,
    ) -> CpuAttnOutput {
        let nj = jobs.len();
        assert_eq!(q.len(), nj * n_query * d_head, "q layout mismatch");
        if let Some(map) = nodes {
            assert_eq!(map.len(), nj, "node map must align with jobs");
        }
        if nj == 0 {
            // early-out before any counter/storage work (an empty
            // submission is not a submission — see the stats tests)
            return CpuAttnOutput {
                o: Vec::new(),
                lse: Vec::new(),
                probs: want_probs.then_some(Vec::new()),
                tasks: 0,
                busy_secs: 0.0,
            };
        }
        let storage = Arc::new(PendingStorage {
            owned: None,
            out: UnsafeCell::new(out_bufs_for(jobs, n_query, d_head, want_probs)),
        });
        // SAFETY: the job/q/q_valid borrows the tasks capture point into
        // the *caller's frame*; `wait()` below blocks until the batch
        // completes, so they outlive every task — the historical blocking
        // contract of this entry point.
        let pending = unsafe {
            self.submit_core(
                jobs, q, n_query, d_head, split, want_probs, q_valid, nodes, storage,
            )
        };
        pending.wait()
    }

    /// Non-blocking [`run_placed`](AttnPool::run_placed): enqueue the
    /// planned tasks — same [`TaskSplit`] plan, same per-node placement,
    /// same counters — and return immediately with a [`PendingAttn`]
    /// handle. The submission's inputs are **owned** (moved into Arc'd
    /// storage every task keeps alive), so nothing borrows the caller's
    /// frame and the caller is free to run serial work — the engine's KV
    /// bookkeeping — while workers crunch the sparse jobs; `wait()` then
    /// performs exactly the blocking path's caller-assist drain +
    /// completion wait. Outputs are bitwise identical to `run_placed` for
    /// the same inputs: the overlap changes *when* the caller blocks,
    /// never the plan, the placement, or the numerics.
    pub fn submit_placed(
        &self,
        input: OwnedJobs,
        n_query: usize,
        d_head: usize,
        split: TaskSplit,
        want_probs: bool,
        nodes: Option<&[NodeId]>,
    ) -> PendingAttn {
        let nj = input.kvs.len();
        assert_eq!(input.q.len(), nj * n_query * d_head, "q layout mismatch");
        if let Some(v) = &input.q_valid {
            assert_eq!(v.len(), nj, "q_valid must align with jobs");
        }
        if let Some(map) = nodes {
            assert_eq!(map.len(), nj, "node map must align with jobs");
        }
        for (k, v, n) in &input.kvs {
            debug_assert_eq!(k.len(), *n * d_head, "k layout mismatch");
            debug_assert_eq!(v.len(), *n * d_head, "v layout mismatch");
        }
        let out = OutBufs {
            o: vec![0.0f32; nj * n_query * d_head],
            lse: vec![EMPTY_LSE; nj * n_query],
            probs: if want_probs {
                input.kvs.iter().map(|(_, _, n)| vec![0.0; *n]).collect()
            } else {
                Vec::new()
            },
        };
        let storage = Arc::new(PendingStorage {
            owned: Some(OwnedAny::F32(input)),
            out: UnsafeCell::new(out),
        });
        let Some(OwnedAny::F32(owned)) = storage.owned.as_ref() else {
            unreachable!("owned f32 input just stored");
        };
        let jobs: Vec<HeadJob<'_>> = owned
            .kvs
            .iter()
            .map(|(k, v, n)| HeadJob { k, v, n: *n })
            .collect();
        // SAFETY: every borrow the tasks capture points into `storage`,
        // which each task closure keeps alive via its own Arc clone — the
        // data outlives the batch regardless of when (or whether) the
        // caller waits, even if this handle is dropped immediately.
        unsafe {
            self.submit_core(
                &jobs,
                &owned.q,
                n_query,
                d_head,
                split,
                want_probs,
                owned.q_valid.as_deref(),
                nodes,
                Arc::clone(&storage),
            )
        }
    }

    /// Shared submission core: plan tasks, split `storage`'s output
    /// buffers into disjoint per-task slices, enqueue with placement, and
    /// return the handle. Does **not** block (beyond queue locks).
    ///
    /// # Safety
    ///
    /// Every borrow reachable through `jobs` / `q` / `q_valid` is
    /// promoted to `'static` for the queued closures. The caller must
    /// guarantee those borrows stay valid until the returned handle's
    /// batch completes — either because they point into `storage` itself
    /// (the owned `submit_placed` path) or because the caller blocks on
    /// the batch before its frame unwinds (the `run_placed` path, whose
    /// `PendingAttn` — waited *or* dropped — settles the batch first).
    /// Output slices are pairwise disjoint by construction (split_at_mut),
    /// so concurrent tasks never alias.
    #[allow(clippy::too_many_arguments)]
    unsafe fn submit_core(
        &self,
        jobs: &[HeadJob<'_>],
        q: &[f32],
        n_query: usize,
        d_head: usize,
        split: TaskSplit,
        want_probs: bool,
        q_valid: Option<&[usize]>,
        nodes: Option<&[NodeId]>,
        storage: Arc<PendingStorage>,
    ) -> PendingAttn {
        let nj = jobs.len();
        debug_assert!(nj > 0, "callers early-out empty submissions");

        // contiguous job ranges per task — the "adjacent head packing";
        // depends only on the job shapes, never on worker availability
        let counts = split.plan(jobs);
        let n_tasks = counts.len();
        let batch = BatchState::new(n_tasks);
        let nqueues = self.shared.queues.len();

        let c = &self.shared.counters;
        c.submissions.fetch_add(1, Ordering::Relaxed);
        c.tasks.fetch_add(n_tasks as u64, Ordering::Relaxed);
        c.jobs.fetch_add(nj as u64, Ordering::Relaxed);

        // the caller assists on the node of the batch's first task
        let mut home = 0usize;
        {
            // the one &mut to the output buffers; split below into
            // disjoint per-task slices before any task is published
            let bufs: &mut OutBufs = &mut *storage.out.get();
            let mut o_rest: &mut [f32] = &mut bufs.o;
            let mut lse_rest: &mut [f32] = &mut bufs.lse;
            let mut probs_rest: &mut [Vec<f32>] = &mut bufs.probs;
            let mut start = 0;
            for (ti, &count) in counts.iter().enumerate() {
                let (o_task, o_next) = o_rest.split_at_mut(count * n_query * d_head);
                let (lse_task, lse_next) = lse_rest.split_at_mut(count * n_query);
                let (p_task, p_next) = if want_probs {
                    probs_rest.split_at_mut(count)
                } else {
                    (&mut [][..], &mut [][..])
                };
                o_rest = o_next;
                lse_rest = lse_next;
                probs_rest = p_next;
                let task_jobs = &jobs[start..start + count];
                let task_q = &q[start * n_query * d_head..(start + count) * n_query * d_head];
                let task_valid = q_valid.map(|v| &v[start..start + count]);
                // each task keeps the storage alive until it finishes; the
                // clone is dropped when the closure is consumed, strictly
                // before the task's batch slot completes (see `run_task`)
                let hold = Arc::clone(&storage);
                let run: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    run_job_range(
                        task_jobs, task_q, n_query, d_head, o_task, lse_task, p_task, want_probs,
                        task_valid,
                    );
                    drop(hold);
                });
                // SAFETY: the 'static promotion is sound under this
                // function's contract — see `# Safety` above.
                let run: Box<dyn FnOnce() + Send + 'static> = std::mem::transmute(run);
                // placement: the first job's node owns the task's slabs;
                // unplaced submissions spread round-robin by task index
                let node = match nodes {
                    Some(map) => map[start] % nqueues,
                    None => ti % nqueues,
                };
                if ti == 0 {
                    home = node;
                }
                // count BEFORE publishing the task: a racing worker's pop
                // (and its decrement) must never observe a task the counter
                // hasn't seen, or `queued` wraps below zero
                let depth = self.shared.queued.fetch_add(1, Ordering::Relaxed) + 1;
                c.queue_peak.fetch_max(depth, Ordering::Relaxed);
                self.shared.node_tasks[node].fetch_add(1, Ordering::Relaxed);
                self.shared.queues[node].queue.lock().unwrap().push_back(Task {
                    run,
                    batch: Arc::clone(&batch),
                });
                start += count;
            }
            self.shared.signal_work();
        }

        PendingAttn {
            shared: Arc::clone(&self.shared),
            batch,
            storage: Some(storage),
            home,
            n_tasks,
            want_probs,
        }
    }

    /// [`submit_placed`](AttnPool::submit_placed) for tiered KV: per-job
    /// payloads may be f32 copies or quantized int8 slabs
    /// ([`JobPayload`]). Same non-blocking contract, same [`TaskSplit`]
    /// plan (packing reads only each job's entry count, see
    /// [`TaskSplit::plan_counts`]), same placement and counters, same
    /// LSE-merge output shape — an all-f32 payload list produces bitwise
    /// the same bits as [`submit_placed`](AttnPool::submit_placed), and a
    /// quantized job's output is deterministic across pool sizes and
    /// topologies exactly like the f32 path.
    pub fn submit_tiered(
        &self,
        input: OwnedTieredJobs,
        n_query: usize,
        d_head: usize,
        split: TaskSplit,
        want_probs: bool,
        nodes: Option<&[NodeId]>,
    ) -> PendingAttn {
        let nj = input.kvs.len();
        assert_eq!(input.q.len(), nj * n_query * d_head, "q layout mismatch");
        if let Some(v) = &input.q_valid {
            assert_eq!(v.len(), nj, "q_valid must align with jobs");
        }
        if let Some(map) = nodes {
            assert_eq!(map.len(), nj, "node map must align with jobs");
        }
        for p in &input.kvs {
            match p {
                JobPayload::F32(k, v, n) => {
                    debug_assert_eq!(k.len(), *n * d_head, "k layout mismatch");
                    debug_assert_eq!(v.len(), *n * d_head, "v layout mismatch");
                }
                JobPayload::Int8 { k, v } => {
                    debug_assert_eq!(k.d_head(), d_head, "quant k width mismatch");
                    debug_assert_eq!(v.len(), k.len(), "quant k/v length mismatch");
                }
            }
        }
        let out = OutBufs {
            o: vec![0.0f32; nj * n_query * d_head],
            lse: vec![EMPTY_LSE; nj * n_query],
            probs: if want_probs {
                input.kvs.iter().map(|p| vec![0.0; p.n()]).collect()
            } else {
                Vec::new()
            },
        };
        let storage = Arc::new(PendingStorage {
            owned: Some(OwnedAny::Tiered(input)),
            out: UnsafeCell::new(out),
        });
        let Some(OwnedAny::Tiered(owned)) = storage.owned.as_ref() else {
            unreachable!("owned tiered input just stored");
        };
        let jobs: Vec<KernelJob<'_>> = owned
            .kvs
            .iter()
            .map(|p| match p {
                JobPayload::F32(k, v, n) => KernelJob::F32(HeadJob { k, v, n: *n }),
                JobPayload::Int8 { k, v } => KernelJob::Quant { k, v },
            })
            .collect();
        // SAFETY: every borrow the tasks capture points into `storage`,
        // which each task closure keeps alive via its own Arc clone — the
        // data outlives the batch regardless of when (or whether) the
        // caller waits, even if this handle is dropped immediately.
        unsafe {
            self.submit_core_tiered(
                &jobs,
                &owned.q,
                n_query,
                d_head,
                split,
                want_probs,
                owned.q_valid.as_deref(),
                nodes,
                Arc::clone(&storage),
            )
        }
    }

    /// Tiered twin of `submit_core`: identical planning, placement,
    /// counters, and buffer-splitting — the tasks run
    /// [`run_job_range_tiered`] instead of [`run_job_range`]. Kept as a
    /// separate body so the f32 hot path's codegen (and its bitwise
    /// conformance suites) are untouched by tiering.
    ///
    /// # Safety
    ///
    /// Same contract as `submit_core`: every borrow reachable through
    /// `jobs` / `q` / `q_valid` must stay valid until the returned
    /// handle's batch completes (here they always point into `storage`,
    /// the owned `submit_tiered` path).
    #[allow(clippy::too_many_arguments)]
    unsafe fn submit_core_tiered(
        &self,
        jobs: &[KernelJob<'_>],
        q: &[f32],
        n_query: usize,
        d_head: usize,
        split: TaskSplit,
        want_probs: bool,
        q_valid: Option<&[usize]>,
        nodes: Option<&[NodeId]>,
        storage: Arc<PendingStorage>,
    ) -> PendingAttn {
        let nj = jobs.len();
        debug_assert!(nj > 0, "callers early-out empty submissions");

        // contiguous job ranges per task — the "adjacent head packing";
        // depends only on the job shapes, never on worker availability
        let ns: Vec<usize> = jobs.iter().map(|j| j.n()).collect();
        let counts = split.plan_counts(&ns);
        let n_tasks = counts.len();
        let batch = BatchState::new(n_tasks);
        let nqueues = self.shared.queues.len();

        let c = &self.shared.counters;
        c.submissions.fetch_add(1, Ordering::Relaxed);
        c.tasks.fetch_add(n_tasks as u64, Ordering::Relaxed);
        c.jobs.fetch_add(nj as u64, Ordering::Relaxed);

        // the caller assists on the node of the batch's first task
        let mut home = 0usize;
        {
            // the one &mut to the output buffers; split below into
            // disjoint per-task slices before any task is published
            let bufs: &mut OutBufs = &mut *storage.out.get();
            let mut o_rest: &mut [f32] = &mut bufs.o;
            let mut lse_rest: &mut [f32] = &mut bufs.lse;
            let mut probs_rest: &mut [Vec<f32>] = &mut bufs.probs;
            let mut start = 0;
            for (ti, &count) in counts.iter().enumerate() {
                let (o_task, o_next) = o_rest.split_at_mut(count * n_query * d_head);
                let (lse_task, lse_next) = lse_rest.split_at_mut(count * n_query);
                let (p_task, p_next) = if want_probs {
                    probs_rest.split_at_mut(count)
                } else {
                    (&mut [][..], &mut [][..])
                };
                o_rest = o_next;
                lse_rest = lse_next;
                probs_rest = p_next;
                let task_jobs = &jobs[start..start + count];
                let task_q = &q[start * n_query * d_head..(start + count) * n_query * d_head];
                let task_valid = q_valid.map(|v| &v[start..start + count]);
                // each task keeps the storage alive until it finishes; the
                // clone is dropped when the closure is consumed, strictly
                // before the task's batch slot completes (see `run_task`)
                let hold = Arc::clone(&storage);
                let run: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    run_job_range_tiered(
                        task_jobs, task_q, n_query, d_head, o_task, lse_task, p_task, want_probs,
                        task_valid,
                    );
                    drop(hold);
                });
                // SAFETY: the 'static promotion is sound under this
                // function's contract — see `# Safety` above.
                let run: Box<dyn FnOnce() + Send + 'static> = std::mem::transmute(run);
                // placement: the first job's node owns the task's slabs;
                // unplaced submissions spread round-robin by task index
                let node = match nodes {
                    Some(map) => map[start] % nqueues,
                    None => ti % nqueues,
                };
                if ti == 0 {
                    home = node;
                }
                // count BEFORE publishing the task: a racing worker's pop
                // (and its decrement) must never observe a task the counter
                // hasn't seen, or `queued` wraps below zero
                let depth = self.shared.queued.fetch_add(1, Ordering::Relaxed) + 1;
                c.queue_peak.fetch_max(depth, Ordering::Relaxed);
                self.shared.node_tasks[node].fetch_add(1, Ordering::Relaxed);
                self.shared.queues[node].queue.lock().unwrap().push_back(Task {
                    run,
                    batch: Arc::clone(&batch),
                });
                start += count;
            }
            self.shared.signal_work();
        }

        PendingAttn {
            shared: Arc::clone(&self.shared),
            batch,
            storage: Some(storage),
            home,
            n_tasks,
            want_probs,
        }
    }
}

/// Fresh output buffers sized for `jobs` (zero `o`, sentinel `lse`,
/// per-job probs only when requested).
fn out_bufs_for(jobs: &[HeadJob<'_>], n_query: usize, d_head: usize, want_probs: bool) -> OutBufs {
    OutBufs {
        o: vec![0.0f32; jobs.len() * n_query * d_head],
        lse: vec![EMPTY_LSE; jobs.len() * n_query],
        probs: if want_probs {
            jobs.iter().map(|j| vec![0.0; j.n]).collect()
        } else {
            Vec::new()
        },
    }
}

impl Drop for AttnPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.signal_work();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(sh: &Shared, home: usize) {
    loop {
        if sh.shutdown.load(Ordering::SeqCst) {
            return;
        }
        if let Some((task, from)) = sh.pop_task_preferring(home) {
            // a panicking task must not kill the worker or strand its
            // batch; run_task catches, completes the batch slot, and hands
            // back the payload — report it and keep serving
            if sh.run_for_worker(task, from, home).is_some() {
                eprintln!(
                    "hgca attention pool: task panicked (batch slot completed, worker continues)"
                );
            }
            continue;
        }
        // sleep path: re-check the queued count under the idle lock so a
        // producer's push + notify cannot slip between check and wait
        let guard = sh.idle.lock().unwrap();
        if sh.shutdown.load(Ordering::SeqCst) {
            return;
        }
        if sh.any_queued() {
            continue; // guard drops; rescan the queues
        }
        drop(sh.work_cv.wait(guard).unwrap());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::cpu_attention::sparse_attention_spawn_masked;
    use crate::util::proptest::{check, ensure, ensure_all_close};
    use crate::util::rng::Rng;

    fn rand_jobs(
        rng: &mut Rng,
        nj: usize,
        dh: usize,
        max_n: usize,
    ) -> Vec<(Vec<f32>, Vec<f32>, usize)> {
        (0..nj)
            .map(|_| {
                let n = rng.range(0, max_n + 1);
                let mut k = vec![0.0; n * dh];
                let mut v = vec![0.0; n * dh];
                rng.fill_normal(&mut k, 1.0);
                rng.fill_normal(&mut v, 1.0);
                (k, v, n)
            })
            .collect()
    }

    fn as_jobs(kvs: &[(Vec<f32>, Vec<f32>, usize)]) -> Vec<HeadJob<'_>> {
        kvs.iter()
            .map(|(k, v, n)| HeadJob { k, v, n: *n })
            .collect()
    }

    #[test]
    fn pool_output_bitwise_stable_across_pool_sizes_and_caps() {
        let mut rng = Rng::new(0xA11);
        let dh = 16;
        let kvs = rand_jobs(&mut rng, 13, dh, 40);
        let jobs = as_jobs(&kvs);
        let nq = 2;
        let mut q = vec![0.0; jobs.len() * nq * dh];
        rng.fill_normal(&mut q, 1.0);
        let q_valid: Vec<usize> = (0..jobs.len()).map(|i| i % (nq + 1)).collect();

        let reference = AttnPool::new(0).run_masked(&jobs, &q, nq, dh, 1, true, Some(&q_valid));
        for workers in [0usize, 1, 2, 7] {
            let pool = AttnPool::new(workers);
            for cap in [1usize, 2, 7, 64] {
                let out = pool.run_masked(&jobs, &q, nq, dh, cap, true, Some(&q_valid));
                assert_eq!(out.o, reference.o, "workers={workers} cap={cap}");
                assert_eq!(out.lse, reference.lse, "workers={workers} cap={cap}");
                assert_eq!(out.probs, reference.probs, "workers={workers} cap={cap}");
                assert_eq!(out.tasks, 13.min(cap));
            }
        }
    }

    #[test]
    fn sharded_pool_bitwise_matches_flat_for_every_topology() {
        // the tentpole conformance: topology is a pure placement change —
        // same tasks, same disjoint writes, bitwise-identical output
        let mut rng = Rng::new(0xD44);
        let dh = 16;
        let kvs = rand_jobs(&mut rng, 12, dh, 40);
        let jobs = as_jobs(&kvs);
        let mut q = vec![0.0; jobs.len() * dh];
        rng.fill_normal(&mut q, 1.0);
        let flat = AttnPool::new(2).run_masked(&jobs, &q, 1, dh, 4, true, None);
        for nodes in [1usize, 2, 4] {
            for workers in [0usize, 3] {
                let pool = AttnPool::with_topology(workers, Topology::synthetic(nodes));
                // shard map like the KV store's: head h → (h % nodes)
                let map: Vec<usize> = (0..jobs.len()).map(|j| j % nodes).collect();
                let out = pool.run_placed(
                    &jobs,
                    &q,
                    1,
                    dh,
                    TaskSplit::EvenJobs { max_parallel: 4 },
                    true,
                    None,
                    Some(&map),
                );
                assert_eq!(out.o, flat.o, "nodes={nodes} workers={workers}");
                assert_eq!(out.lse, flat.lse, "nodes={nodes} workers={workers}");
                assert_eq!(out.probs, flat.probs, "nodes={nodes} workers={workers}");
                assert_eq!(out.tasks, flat.tasks, "plan must not depend on topology");
            }
        }
    }

    #[test]
    fn placed_tasks_land_on_their_nodes_and_caller_drains_count_separately() {
        // zero workers: the caller (homed on the first task's node, 0)
        // drains everything — node 1's tasks are deterministic cross-node
        // caller-assist pops, which must NOT register as worker steals
        // (the locality signal stays 0 for a healthy submit/assist cycle)
        let kvs: Vec<(Vec<f32>, Vec<f32>, usize)> = (0..4)
            .map(|_| (vec![0.0; 8 * 4], vec![0.0; 8 * 4], 8))
            .collect();
        let jobs = as_jobs(&kvs);
        let q = vec![0.0; jobs.len() * 4];
        let pool = AttnPool::with_topology(0, Topology::synthetic(2));
        let map = [0usize, 0, 1, 1];
        let out = pool.run_placed(
            &jobs,
            &q,
            1,
            4,
            TaskSplit::EvenJobs { max_parallel: 4 },
            false,
            None,
            Some(&map),
        );
        assert_eq!(out.tasks, 4);
        let s = pool.stats();
        assert_eq!(s.numa_nodes, 2);
        assert_eq!(s.node_tasks, vec![2, 2], "tasks routed per the shard map");
        assert_eq!(s.node_steals, vec![0, 0], "no pinned worker stole anything");
        assert_eq!(s.cross_node_steals(), 0, "locality signal clean under caller-assist");
        assert_eq!(s.caller_assist_cross_node, 2, "caller's off-home pops counted apart");
        assert_eq!(s.queue_depth, 0);
    }

    #[test]
    fn out_of_range_nodes_wrap_and_unplaced_tasks_round_robin() {
        let kvs: Vec<(Vec<f32>, Vec<f32>, usize)> = (0..4)
            .map(|_| (vec![0.0; 4 * 4], vec![0.0; 4 * 4], 4))
            .collect();
        let jobs = as_jobs(&kvs);
        let q = vec![0.0; jobs.len() * 4];
        // a shard map built for a 4-node topology routed into a 2-node pool
        let pool = AttnPool::with_topology(0, Topology::synthetic(2));
        let map = [2usize, 2, 7, 7]; // wraps to nodes 0, 0, 1, 1
        pool.run_placed(
            &jobs,
            &q,
            1,
            4,
            TaskSplit::EvenJobs { max_parallel: 4 },
            false,
            None,
            Some(&map),
        );
        assert_eq!(pool.stats().node_tasks, vec![2, 2]);
        // unplaced submissions spread across queues by task index
        let pool2 = AttnPool::with_topology(0, Topology::synthetic(2));
        pool2.run_masked(&jobs, &q, 1, 4, 4, false, None);
        assert_eq!(pool2.stats().node_tasks, vec![2, 2]);
    }

    #[test]
    fn pool_matches_spawn_path_bitwise() {
        let mut rng = Rng::new(0xB22);
        let dh = 8;
        let kvs = rand_jobs(&mut rng, 9, dh, 30);
        let jobs = as_jobs(&kvs);
        let mut q = vec![0.0; jobs.len() * dh];
        rng.fill_normal(&mut q, 1.0);
        let pool = AttnPool::new(3);
        let a = pool.run_masked(&jobs, &q, 1, dh, 4, true, None);
        let b = sparse_attention_spawn_masked(&jobs, &q, 1, dh, 4, true, None);
        assert_eq!(a.o, b.o);
        assert_eq!(a.lse, b.lse);
        assert_eq!(a.probs, b.probs);
        assert_eq!(a.tasks, b.tasks);
    }

    #[test]
    fn submit_placed_matches_run_placed_bitwise() {
        // the overlap API is a pure scheduling change: owned-input submit +
        // deferred wait produces the same bits as the blocking call
        let mut rng = Rng::new(0xE55);
        let dh = 16;
        let kvs = rand_jobs(&mut rng, 10, dh, 30);
        let jobs = as_jobs(&kvs);
        let nq = 2;
        let mut q = vec![0.0; jobs.len() * nq * dh];
        rng.fill_normal(&mut q, 1.0);
        let q_valid: Vec<usize> = (0..jobs.len()).map(|i| i % (nq + 1)).collect();
        let map: Vec<usize> = (0..jobs.len()).map(|j| j % 2).collect();
        let split = TaskSplit::EvenJobs { max_parallel: 4 };
        for workers in [0usize, 3] {
            let pool = AttnPool::with_topology(workers, Topology::synthetic(2));
            let blocking =
                pool.run_placed(&jobs, &q, nq, dh, split, true, Some(&q_valid), Some(&map));
            let input = OwnedJobs {
                kvs: kvs.clone(),
                q: q.clone(),
                q_valid: Some(q_valid.clone()),
            };
            let pending = pool.submit_placed(input, nq, dh, split, true, Some(&map));
            let out = pending.wait();
            assert_eq!(out.o, blocking.o, "workers={workers}");
            assert_eq!(out.lse, blocking.lse, "workers={workers}");
            assert_eq!(out.probs, blocking.probs, "workers={workers}");
            assert_eq!(out.tasks, blocking.tasks, "same plan either way");
            assert!(out.busy_secs >= 0.0 && out.busy_secs.is_finite());
            let s = pool.stats();
            assert_eq!(s.submissions, 2, "submit counts like a blocking call");
            assert_eq!(s.queue_depth, 0, "both batches fully drained");
        }
    }

    #[test]
    fn submit_tiered_all_f32_matches_submit_placed_bitwise() {
        // an all-f32 tiered submission must be indistinguishable from the
        // plain owned path: same plan, same kernel arithmetic, same bits
        let mut rng = Rng::new(0xF66);
        let dh = 8;
        let kvs = rand_jobs(&mut rng, 9, dh, 24);
        let nq = 2;
        let mut q = vec![0.0; kvs.len() * nq * dh];
        rng.fill_normal(&mut q, 1.0);
        let q_valid: Vec<usize> = (0..kvs.len()).map(|i| i % (nq + 1)).collect();
        let split = TaskSplit::EvenJobs { max_parallel: 4 };
        let pool = AttnPool::new(2);
        let plain = pool
            .submit_placed(
                OwnedJobs {
                    kvs: kvs.clone(),
                    q: q.clone(),
                    q_valid: Some(q_valid.clone()),
                },
                nq,
                dh,
                split,
                true,
                None,
            )
            .wait();
        let tiered = pool
            .submit_tiered(
                OwnedTieredJobs {
                    kvs: kvs
                        .iter()
                        .map(|(k, v, n)| JobPayload::F32(k.clone(), v.clone(), *n))
                        .collect(),
                    q: q.clone(),
                    q_valid: Some(q_valid.clone()),
                },
                nq,
                dh,
                split,
                true,
                None,
            )
            .wait();
        assert_eq!(plain.o, tiered.o);
        assert_eq!(plain.lse, tiered.lse);
        assert_eq!(plain.probs, tiered.probs);
        assert_eq!(plain.tasks, tiered.tasks);
    }

    #[test]
    fn tiered_quant_output_bitwise_stable_across_pools_topologies_and_splits() {
        // mixed f32 + int8 jobs: the quantized kernel must be exactly as
        // schedule-independent as the f32 one
        let mut rng = Rng::new(0xF77);
        let dh = 8;
        let kvs = rand_jobs(&mut rng, 10, dh, 40);
        let mut q = vec![0.0; kvs.len() * dh];
        rng.fill_normal(&mut q, 1.0);
        let payloads = |kvs: &[(Vec<f32>, Vec<f32>, usize)]| -> Vec<JobPayload> {
            kvs.iter()
                .enumerate()
                .map(|(i, (k, v, n))| {
                    if i % 2 == 0 {
                        JobPayload::Int8 {
                            k: QuantSlab::from_f32(k, dh, 4),
                            v: QuantSlab::from_f32(v, dh, 4),
                        }
                    } else {
                        JobPayload::F32(k.clone(), v.clone(), *n)
                    }
                })
                .collect()
        };
        let reference = AttnPool::new(0)
            .submit_tiered(
                OwnedTieredJobs {
                    kvs: payloads(&kvs),
                    q: q.clone(),
                    q_valid: None,
                },
                1,
                dh,
                TaskSplit::EvenJobs { max_parallel: 1 },
                true,
                None,
            )
            .wait();
        for nodes in [1usize, 2, 4] {
            for workers in [0usize, 3] {
                let pool = AttnPool::with_topology(workers, Topology::synthetic(nodes));
                let map: Vec<usize> = (0..kvs.len()).map(|j| j % nodes).collect();
                for split in [
                    TaskSplit::EvenJobs { max_parallel: 7 },
                    TaskSplit::EvenJobs { max_parallel: 64 },
                    TaskSplit::ByEntries { per_task: 16, max_tasks: 8 },
                ] {
                    let out = pool
                        .submit_tiered(
                            OwnedTieredJobs {
                                kvs: payloads(&kvs),
                                q: q.clone(),
                                q_valid: None,
                            },
                            1,
                            dh,
                            split,
                            true,
                            Some(&map),
                        )
                        .wait();
                    assert_eq!(out.o, reference.o, "nodes={nodes} workers={workers}");
                    assert_eq!(out.lse, reference.lse, "nodes={nodes} workers={workers}");
                    assert_eq!(out.probs, reference.probs, "nodes={nodes} workers={workers}");
                }
            }
        }
    }

    #[test]
    fn empty_submission_returns_immediately() {
        let pool = AttnPool::new(2);
        let out = pool.run_masked(&[], &[], 1, 8, 4, true, None);
        assert!(out.o.is_empty());
        assert!(out.lse.is_empty());
        assert_eq!(out.tasks, 0);
        assert_eq!(pool.stats().submissions, 0); // early-out before counting
    }

    #[test]
    fn stats_count_submissions_tasks_jobs() {
        let mut rng = Rng::new(3);
        let dh = 4;
        let kvs = rand_jobs(&mut rng, 6, dh, 10);
        let jobs = as_jobs(&kvs);
        let mut q = vec![0.0; jobs.len() * dh];
        rng.fill_normal(&mut q, 1.0);
        let pool = AttnPool::new(2);
        pool.run_masked(&jobs, &q, 1, dh, 3, false, None);
        pool.run_masked(&jobs, &q, 1, dh, 6, false, None);
        let s = pool.stats();
        assert_eq!(s.workers, 2);
        assert_eq!(s.numa_nodes, 1);
        assert_eq!(s.submissions, 2);
        assert_eq!(s.jobs, 12);
        assert_eq!(s.tasks, 3 + 6);
        assert_eq!(s.node_tasks, vec![3 + 6], "single node owns every task");
        assert_eq!(s.node_steals, vec![0], "nothing to steal across one node");
        assert_eq!(s.queue_depth, 0, "queue drains after completion");
        assert!(s.queue_peak >= 1);
    }

    #[test]
    fn shared_pool_across_threads() {
        // concurrent submissions from several engine threads interleave
        // safely and each caller gets its own correct outputs
        let pool = std::sync::Arc::new(AttnPool::with_topology(3, Topology::synthetic(2)));
        let mut handles = Vec::new();
        for seed in 0..4u64 {
            let pool = std::sync::Arc::clone(&pool);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(seed);
                let dh = 8;
                let kvs: Vec<(Vec<f32>, Vec<f32>, usize)> = (0..7)
                    .map(|_| {
                        let n = 1 + rng.range(0, 20);
                        let mut k = vec![0.0; n * dh];
                        let mut v = vec![0.0; n * dh];
                        rng.fill_normal(&mut k, 1.0);
                        rng.fill_normal(&mut v, 1.0);
                        (k, v, n)
                    })
                    .collect();
                let jobs: Vec<HeadJob> = kvs
                    .iter()
                    .map(|(k, v, n)| HeadJob { k, v, n: *n })
                    .collect();
                let mut q = vec![0.0; jobs.len() * dh];
                rng.fill_normal(&mut q, 1.0);
                let nodes: Vec<usize> = (0..jobs.len()).map(|j| j % 2).collect();
                let single = sparse_attention_spawn_masked(&jobs, &q, 1, dh, 1, false, None);
                for _ in 0..16 {
                    let out = pool.run_placed(
                        &jobs,
                        &q,
                        1,
                        dh,
                        TaskSplit::EvenJobs { max_parallel: 4 },
                        false,
                        None,
                        Some(&nodes),
                    );
                    assert_eq!(out.o, single.o);
                    assert_eq!(out.lse, single.lse);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn entries_split_bitwise_matches_even_split() {
        // the append-path split must be a pure scheduling change: outputs
        // bitwise identical to the decode-path split for every sizing
        let mut rng = Rng::new(0xC33);
        let dh = 8;
        let kvs = rand_jobs(&mut rng, 11, dh, 40);
        let jobs = as_jobs(&kvs);
        let mut q = vec![0.0; jobs.len() * dh];
        rng.fill_normal(&mut q, 1.0);
        let pool = AttnPool::new(3);
        let reference = pool.run_masked(&jobs, &q, 1, dh, 4, true, None);
        for per_task in [1usize, 8, 64, 10_000] {
            for max_tasks in [1usize, 3, 64] {
                let out = pool.run_split(
                    &jobs,
                    &q,
                    1,
                    dh,
                    TaskSplit::ByEntries { per_task, max_tasks },
                    true,
                    None,
                );
                assert_eq!(out.o, reference.o, "per_task={per_task} max_tasks={max_tasks}");
                assert_eq!(out.lse, reference.lse, "per_task={per_task}");
                assert_eq!(out.probs, reference.probs, "per_task={per_task}");
                assert!(out.tasks <= max_tasks.max(1));
            }
        }
    }

    #[test]
    fn entries_split_task_count_follows_store_size() {
        // 8 uniform jobs of 16 entries each (128 total)
        let kvs: Vec<(Vec<f32>, Vec<f32>, usize)> = (0..8)
            .map(|_| (vec![0.0; 16 * 4], vec![0.0; 16 * 4], 16))
            .collect();
        let jobs = as_jobs(&kvs);
        let q = vec![0.0; jobs.len() * 4];
        let pool = AttnPool::new(0);
        let tasks = |per_task: usize, max_tasks: usize| {
            pool.run_split(
                &jobs,
                &q,
                1,
                4,
                TaskSplit::ByEntries { per_task, max_tasks },
                false,
                None,
            )
            .tasks
        };
        assert_eq!(tasks(32, 64), 4); // 2 jobs (32 entries) per task
        assert_eq!(tasks(1_000, 64), 1); // small store → one task
        assert_eq!(tasks(1, 64), 8); // per-job tasks at minimum granularity
        assert_eq!(tasks(1, 3), 3); // soft cap merges adjacent tasks
    }

    #[test]
    fn entries_split_handles_empty_jobs() {
        // zero-entry jobs accumulate no weight and never stall the plan
        let kvs: Vec<(Vec<f32>, Vec<f32>, usize)> =
            (0..5).map(|_| (Vec::new(), Vec::new(), 0)).collect();
        let jobs = as_jobs(&kvs);
        let q = vec![1.0; jobs.len() * 4];
        let pool = AttnPool::new(1);
        let out = pool.run_split(
            &jobs,
            &q,
            1,
            4,
            TaskSplit::ByEntries { per_task: 64, max_tasks: 4 },
            true,
            None,
        );
        assert_eq!(out.tasks, 1); // all-zero entries pack into one task
        assert!(out.lse.iter().all(|&l| l == EMPTY_LSE));
        assert!(out.o.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn prop_pool_matches_single_thread_reference() {
        // satellite: pool output ≡ single-threaded reference for random job
        // shapes at every parallelism cap in {1, 2, 7, 64}
        let pool = AttnPool::new(4);
        check("pool_vs_reference", 20, |rng: &mut Rng| {
            let dh = *rng.choice(&[4usize, 8, 32]);
            let nj = rng.range(1, 20);
            let nq = rng.range(1, 4);
            let kvs = rand_jobs(rng, nj, dh, 24);
            let jobs = as_jobs(&kvs);
            let mut q = vec![0.0; nj * nq * dh];
            rng.fill_normal(&mut q, 1.0);
            let reference = sparse_attention_spawn_masked(&jobs, &q, nq, dh, 1, false, None);
            for cap in [1usize, 2, 7, 64] {
                let out = pool.run_masked(&jobs, &q, nq, dh, cap, false, None);
                ensure_all_close(&out.o, &reference.o, 1e-5, "o")?;
                ensure_all_close(&out.lse, &reference.lse, 1e-5, "lse")?;
                ensure(
                    out.o == reference.o && out.lse == reference.lse,
                    "pool output must be bitwise identical to the reference",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_sharded_pool_matches_reference_across_topologies() {
        // random shapes × random shard maps: placement never touches bits
        let pools: Vec<AttnPool> = [1usize, 2, 4]
            .iter()
            .map(|&n| AttnPool::with_topology(2, Topology::synthetic(n)))
            .collect();
        check("sharded_pool_vs_reference", 12, |rng: &mut Rng| {
            let dh = *rng.choice(&[4usize, 8]);
            let nj = rng.range(1, 16);
            let kvs = rand_jobs(rng, nj, dh, 24);
            let jobs = as_jobs(&kvs);
            let mut q = vec![0.0; nj * dh];
            rng.fill_normal(&mut q, 1.0);
            let map: Vec<usize> = (0..nj).map(|_| rng.range(0, 4)).collect();
            let reference = sparse_attention_spawn_masked(&jobs, &q, 1, dh, 1, false, None);
            for pool in &pools {
                let out = pool.run_placed(
                    &jobs,
                    &q,
                    1,
                    dh,
                    TaskSplit::EvenJobs { max_parallel: 3 },
                    false,
                    None,
                    Some(&map),
                );
                ensure(
                    out.o == reference.o && out.lse == reference.lse,
                    "sharded pool output must be bitwise identical to the reference",
                )?;
            }
            Ok(())
        });
    }
}
