//! Persistent CPU attention worker pool (paper §3.3, production form).
//!
//! The seed implementation spawned fresh `std::thread`s on every
//! `sparse_attention` call — fine for one long prefill, ruinous for decode
//! serving where each step submits batch×heads tiny jobs and the per-call
//! spawn/join cost dominates. This pool keeps a fixed set of long-lived
//! workers behind a shared FIFO injector queue:
//!
//! * **submit/wait** — [`AttnPool::run_masked`] packs the (row, head) jobs
//!   into contiguous ranges ("adjacent head merging"), enqueues one task per
//!   range, and blocks until the batch completes. Each task writes a
//!   disjoint slice of the caller's pre-allocated output buffers, exactly as
//!   the spawn path did.
//! * **work stealing** — the submitting thread doesn't idle: it pops tasks
//!   from the same queue until its batch drains (caller-assist), so progress
//!   is guaranteed even with zero workers and small batches finish at
//!   near-inline latency.
//! * **determinism** — task packing ([`TaskSplit`]) depends only on the
//!   job shapes and the split parameters, never on worker count or
//!   scheduling, and every job's arithmetic touches only its own
//!   inputs/outputs. Results are therefore **bitwise identical** across
//!   pool sizes, parallelism caps, split strategies, and repeated runs.
//!   The conformance suite pins this.
//! * **split strategies** — decode packs by job count
//!   ([`TaskSplit::EvenJobs`], heads have similar working sets); append-time
//!   full-store re-evaluation packs by KV entries
//!   ([`TaskSplit::ByEntries`]), so parallelism follows the store length
//!   instead of the decode cap.
//!
//! Multiple engines (threads) may share one pool; tasks from concurrent
//! submissions interleave in FIFO order. [`AttnPool::global`] is the
//! process-wide instance used by `sparse_attention*`; its size comes from
//! `HGCA_POOL_THREADS` or `available_parallelism`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

use super::cpu_attention::{run_job_range, CpuAttnOutput, HeadJob, EMPTY_LSE};

/// How a submission's (row, head) jobs are packed into contiguous pool
/// tasks. The plan depends only on the job list and the split parameters —
/// never on worker availability or scheduling — which is what keeps pool
/// output bitwise identical across pool sizes (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskSplit {
    /// At most `max_parallel` contiguous tasks of (near-)equal *job count* —
    /// the decode path, where every head's working set (the contextual
    /// cache) has similar size and job count is a good proxy for work.
    EvenJobs {
        /// Upper bound on the number of packed tasks (the engine passes
        /// `cfg.cpu_threads`).
        max_parallel: usize,
    },
    /// Contiguous tasks sized by accumulated *KV entries*: a task closes
    /// once adding the next job would exceed `per_task` entries. This is
    /// the append path (full-store re-evaluation, Algorithm 1 line 19),
    /// where per-head lengths grow with the sequence and the right
    /// parallelism follows the store size rather than the decode cap
    /// (ROADMAP: pool-aware append re-evaluation).
    ByEntries {
        /// Target KV entries per task (≥ 1; a single job larger than this
        /// still forms one task — jobs are never split).
        per_task: usize,
        /// Soft cap on task count: when the greedy split produces more,
        /// adjacent tasks are merged down to at most this many.
        max_tasks: usize,
    },
}

impl TaskSplit {
    /// Contiguous per-task job counts (in job order; sums to `jobs.len()`).
    pub(crate) fn plan(&self, jobs: &[HeadJob<'_>]) -> Vec<usize> {
        let nj = jobs.len();
        if nj == 0 {
            return Vec::new();
        }
        match *self {
            TaskSplit::EvenJobs { max_parallel } => {
                let threads = max_parallel.max(1).min(nj);
                let per_task = nj.div_ceil(threads).max(1);
                let mut counts = Vec::with_capacity(nj.div_ceil(per_task));
                let mut start = 0;
                while start < nj {
                    let c = per_task.min(nj - start);
                    counts.push(c);
                    start += c;
                }
                counts
            }
            TaskSplit::ByEntries { per_task, max_tasks } => {
                let per_task = per_task.max(1);
                let mut counts = Vec::new();
                let (mut cur_jobs, mut cur_entries) = (0usize, 0usize);
                for job in jobs {
                    if cur_jobs > 0 && cur_entries + job.n > per_task {
                        counts.push(cur_jobs);
                        cur_jobs = 0;
                        cur_entries = 0;
                    }
                    cur_jobs += 1;
                    cur_entries += job.n;
                }
                if cur_jobs > 0 {
                    counts.push(cur_jobs);
                }
                let max_tasks = max_tasks.max(1);
                if counts.len() > max_tasks {
                    // merge adjacent tasks down to the cap (deterministic)
                    let group = counts.len().div_ceil(max_tasks);
                    counts = counts.chunks(group).map(|g| g.iter().sum::<usize>()).collect();
                }
                counts
            }
        }
    }
}

/// One queued unit of work: a type-erased closure over a contiguous job
/// range, plus the batch it belongs to.
struct Task {
    run: Box<dyn FnOnce() + Send + 'static>,
    batch: Arc<BatchState>,
}

/// Completion tracking for one submission.
struct BatchState {
    remaining: Mutex<usize>,
    done_cv: Condvar,
    /// set when any task of this batch panicked — the submitter must not
    /// treat the (partially written) outputs as valid
    poisoned: AtomicBool,
}

impl BatchState {
    fn new(n: usize) -> Arc<BatchState> {
        Arc::new(BatchState {
            remaining: Mutex::new(n),
            done_cv: Condvar::new(),
            poisoned: AtomicBool::new(false),
        })
    }

    fn finish_one(&self) {
        let mut rem = self.remaining.lock().unwrap();
        *rem -= 1;
        if *rem == 0 {
            self.done_cv.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        *self.remaining.lock().unwrap() == 0
    }

    fn wait(&self) {
        let mut rem = self.remaining.lock().unwrap();
        while *rem > 0 {
            rem = self.done_cv.wait(rem).unwrap();
        }
    }
}

#[derive(Default)]
struct Counters {
    submissions: AtomicU64,
    tasks: AtomicU64,
    jobs: AtomicU64,
    busy_ns: AtomicU64,
    queue_peak: AtomicUsize,
}

struct Shared {
    queue: Mutex<VecDeque<Task>>,
    work_cv: Condvar,
    shutdown: AtomicBool,
    counters: Counters,
}

impl Shared {
    fn pop_task(&self) -> Option<Task> {
        self.queue.lock().unwrap().pop_front()
    }

    /// Run one task, catching panics so the batch completion count is
    /// decremented no matter what (a waiter must never hang, and queued
    /// sibling tasks must never outlive their borrowed buffers — see the
    /// SAFETY notes in `run_masked`). Returns the panic payload, if any.
    fn run_task(&self, task: Task) -> Option<Box<dyn std::any::Any + Send>> {
        let Task { run, batch } = task;
        let t0 = Instant::now();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(run));
        self.counters
            .busy_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        if result.is_err() {
            batch.poisoned.store(true, Ordering::SeqCst);
        }
        batch.finish_one();
        result.err()
    }
}

/// Unwind guard for a submission: if `run_masked` unwinds (a caller-assist
/// task re-raised a panic), this drains and waits out the whole batch
/// before the caller's stack frame — which the queued tasks borrow — is
/// torn down. On the normal path the batch is already done and this is a
/// no-op.
struct BatchGuard<'p> {
    shared: &'p Shared,
    batch: &'p Arc<BatchState>,
}

impl Drop for BatchGuard<'_> {
    fn drop(&mut self) {
        while !self.batch.is_done() {
            match self.shared.pop_task() {
                // panics here are already being reported by the unwind in
                // flight; swallow them to avoid a double-panic abort
                Some(t) => {
                    let _ = self.shared.run_task(t);
                }
                None => break,
            }
        }
        self.batch.wait();
    }
}

/// Read-only snapshot of pool activity (serving metrics endpoint).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PoolStats {
    pub workers: usize,
    /// run_masked calls
    pub submissions: u64,
    /// packed tasks executed (≈ submissions × min(parallelism, jobs))
    pub tasks: u64,
    /// (row, head) jobs processed
    pub jobs: u64,
    /// summed task execution time across workers + caller-assist
    pub busy_secs: f64,
    /// tasks currently queued
    pub queue_depth: usize,
    /// high-water mark of the queue depth at enqueue time
    pub queue_peak: usize,
}

/// Persistent worker pool for CPU sparse attention.
pub struct AttnPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl AttnPool {
    /// Spawn a pool with `workers` long-lived threads. Zero workers is
    /// valid: every submission then runs entirely on the calling thread
    /// (the caller-assist path), which is the deterministic-latency
    /// configuration some tests use.
    pub fn new(workers: usize) -> AttnPool {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            counters: Counters::default(),
        });
        let handles = (0..workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("hgca-attn-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn pool worker")
            })
            .collect();
        AttnPool {
            shared,
            workers: handles,
        }
    }

    /// The process-wide pool used by `sparse_attention*`. Sized by
    /// `HGCA_POOL_THREADS` when set, else `available_parallelism`.
    pub fn global() -> &'static AttnPool {
        static GLOBAL: OnceLock<AttnPool> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let n = std::env::var("HGCA_POOL_THREADS")
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or_else(|| {
                    std::thread::available_parallelism()
                        .map(|n| n.get())
                        .unwrap_or(4)
                });
            AttnPool::new(n)
        })
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    pub fn stats(&self) -> PoolStats {
        let c = &self.shared.counters;
        PoolStats {
            workers: self.workers.len(),
            submissions: c.submissions.load(Ordering::Relaxed),
            tasks: c.tasks.load(Ordering::Relaxed),
            jobs: c.jobs.load(Ordering::Relaxed),
            busy_secs: c.busy_ns.load(Ordering::Relaxed) as f64 / 1e9,
            queue_depth: self.shared.queue.lock().unwrap().len(),
            queue_peak: c.queue_peak.load(Ordering::Relaxed),
        }
    }

    /// Pool-backed sparse attention: identical contract and numerics to the
    /// per-call-spawn path (`sparse_attention_spawn_masked`), minus the
    /// thread spawn/join per call. `max_parallel` caps how many packed
    /// tasks the submission splits into (the engine passes
    /// `cfg.cpu_threads`); output is bitwise independent of both this cap
    /// and the pool's worker count.
    ///
    /// This is the submit/wait entry point: the call enqueues one task per
    /// packed job range and blocks until every task has completed (workers
    /// and the calling thread drain the same queue).
    ///
    /// # Example
    ///
    /// ```
    /// use hgca::attention::{AttnPool, HeadJob};
    ///
    /// let pool = AttnPool::new(2);
    /// // one head attending 3 KV entries of dimension 4
    /// let k = vec![0.0_f32; 3 * 4]; // zero keys → uniform softmax
    /// let v = vec![1.0_f32; 3 * 4];
    /// let jobs = [HeadJob { k: &k, v: &v, n: 3 }];
    /// let q = vec![0.5_f32; 4];
    /// let out = pool.run_masked(&jobs, &q, 1, 4, 1, false, None);
    /// assert_eq!(out.o.len(), 4); // [jobs][n_query][d_head]
    /// assert!((out.o[0] - 1.0).abs() < 1e-6); // mean of identical values
    /// assert!((out.lse[0] - 3.0_f32.ln()).abs() < 1e-6);
    /// ```
    #[allow(clippy::too_many_arguments)]
    pub fn run_masked(
        &self,
        jobs: &[HeadJob<'_>],
        q: &[f32],
        n_query: usize,
        d_head: usize,
        max_parallel: usize,
        want_probs: bool,
        q_valid: Option<&[usize]>,
    ) -> CpuAttnOutput {
        self.run_split(
            jobs,
            q,
            n_query,
            d_head,
            TaskSplit::EvenJobs { max_parallel },
            want_probs,
            q_valid,
        )
    }

    /// [`run_masked`](AttnPool::run_masked) with an explicit [`TaskSplit`].
    /// Packing only changes scheduling: outputs are bitwise identical for
    /// every split (each job's arithmetic touches only its own inputs and
    /// its own disjoint output range).
    #[allow(clippy::too_many_arguments)]
    pub fn run_split(
        &self,
        jobs: &[HeadJob<'_>],
        q: &[f32],
        n_query: usize,
        d_head: usize,
        split: TaskSplit,
        want_probs: bool,
        q_valid: Option<&[usize]>,
    ) -> CpuAttnOutput {
        let nj = jobs.len();
        assert_eq!(q.len(), nj * n_query * d_head, "q layout mismatch");
        let mut o = vec![0.0f32; nj * n_query * d_head];
        let mut lse = vec![EMPTY_LSE; nj * n_query];
        let mut probs: Vec<Vec<f32>> = if want_probs {
            jobs.iter().map(|j| vec![0.0; j.n]).collect()
        } else {
            Vec::new()
        };
        if nj == 0 {
            return CpuAttnOutput {
                o,
                lse,
                probs: want_probs.then_some(probs),
                tasks: 0,
            };
        }

        // contiguous job ranges per task — the "adjacent head packing";
        // depends only on the job shapes, never on worker availability
        let counts = split.plan(jobs);
        let n_tasks = counts.len();
        let batch = BatchState::new(n_tasks);

        let c = &self.shared.counters;
        c.submissions.fetch_add(1, Ordering::Relaxed);
        c.tasks.fetch_add(n_tasks as u64, Ordering::Relaxed);
        c.jobs.fetch_add(nj as u64, Ordering::Relaxed);

        {
            let mut o_rest: &mut [f32] = &mut o;
            let mut lse_rest: &mut [f32] = &mut lse;
            let mut probs_rest: &mut [Vec<f32>] = &mut probs;
            let mut queue = self.shared.queue.lock().unwrap();
            let mut start = 0;
            for &count in &counts {
                let (o_task, o_next) = o_rest.split_at_mut(count * n_query * d_head);
                let (lse_task, lse_next) = lse_rest.split_at_mut(count * n_query);
                let (p_task, p_next) = if want_probs {
                    probs_rest.split_at_mut(count)
                } else {
                    (&mut [][..], &mut [][..])
                };
                o_rest = o_next;
                lse_rest = lse_next;
                probs_rest = p_next;
                let task_jobs = &jobs[start..start + count];
                let task_q = &q[start * n_query * d_head..(start + count) * n_query * d_head];
                let task_valid = q_valid.map(|v| &v[start..start + count]);
                let run: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    run_job_range(
                        task_jobs, task_q, n_query, d_head, o_task, lse_task, p_task, want_probs,
                        task_valid,
                    )
                });
                // SAFETY: every borrow captured by `run` outlives this call —
                // run_split blocks on batch completion before returning, so
                // the 'static promotion never outlives the borrowed data.
                // Output slices are pairwise disjoint by construction
                // (split_at_mut), so concurrent tasks never alias.
                let run: Box<dyn FnOnce() + Send + 'static> =
                    unsafe { std::mem::transmute(run) };
                queue.push_back(Task {
                    run,
                    batch: Arc::clone(&batch),
                });
                start += count;
            }
            let depth = queue.len();
            c.queue_peak.fetch_max(depth, Ordering::Relaxed);
            drop(queue);
            self.shared.work_cv.notify_all();
        }

        // caller-assist: steal tasks (FIFO, possibly from other concurrent
        // submissions) until this batch completes, then wait out stragglers.
        // The guard keeps the unwind path sound: should a re-raised task
        // panic unwind this frame, it drains + waits the batch before the
        // borrowed buffers drop.
        let guard = BatchGuard {
            shared: &self.shared,
            batch: &batch,
        };
        while !batch.is_done() {
            let Some(task) = self.shared.pop_task() else {
                break;
            };
            if let Some(payload) = self.shared.run_task(task) {
                // a task the *caller* ran panicked: propagate to the caller
                // (the guard settles the rest of the batch first)
                std::panic::resume_unwind(payload);
            }
        }
        batch.wait();
        drop(guard);
        // a task that panicked on a worker completed its batch slot (so we
        // never hang) but its output range is garbage — surface the failure
        // on the submitting thread instead of returning partial results
        assert!(
            !batch.poisoned.load(Ordering::SeqCst),
            "attention pool: a task of this submission panicked"
        );

        CpuAttnOutput {
            o,
            lse,
            probs: want_probs.then_some(probs),
            tasks: n_tasks,
        }
    }
}

impl Drop for AttnPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.work_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(sh: &Shared) {
    loop {
        let task = {
            let mut queue = sh.queue.lock().unwrap();
            loop {
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(t) = queue.pop_front() {
                    break t;
                }
                queue = sh.work_cv.wait(queue).unwrap();
            }
        };
        // a panicking task must not kill the worker or strand its batch;
        // run_task catches, completes the batch slot, and hands back the
        // payload — report it and keep serving
        if sh.run_task(task).is_some() {
            eprintln!("hgca attention pool: task panicked (batch slot completed, worker continues)");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::cpu_attention::sparse_attention_spawn_masked;
    use crate::util::proptest::{check, ensure, ensure_all_close};
    use crate::util::rng::Rng;

    fn rand_jobs(
        rng: &mut Rng,
        nj: usize,
        dh: usize,
        max_n: usize,
    ) -> Vec<(Vec<f32>, Vec<f32>, usize)> {
        (0..nj)
            .map(|_| {
                let n = rng.range(0, max_n + 1);
                let mut k = vec![0.0; n * dh];
                let mut v = vec![0.0; n * dh];
                rng.fill_normal(&mut k, 1.0);
                rng.fill_normal(&mut v, 1.0);
                (k, v, n)
            })
            .collect()
    }

    fn as_jobs(kvs: &[(Vec<f32>, Vec<f32>, usize)]) -> Vec<HeadJob<'_>> {
        kvs.iter()
            .map(|(k, v, n)| HeadJob { k, v, n: *n })
            .collect()
    }

    #[test]
    fn pool_output_bitwise_stable_across_pool_sizes_and_caps() {
        let mut rng = Rng::new(0xA11);
        let dh = 16;
        let kvs = rand_jobs(&mut rng, 13, dh, 40);
        let jobs = as_jobs(&kvs);
        let nq = 2;
        let mut q = vec![0.0; jobs.len() * nq * dh];
        rng.fill_normal(&mut q, 1.0);
        let q_valid: Vec<usize> = (0..jobs.len()).map(|i| i % (nq + 1)).collect();

        let reference = AttnPool::new(0).run_masked(&jobs, &q, nq, dh, 1, true, Some(&q_valid));
        for workers in [0usize, 1, 2, 7] {
            let pool = AttnPool::new(workers);
            for cap in [1usize, 2, 7, 64] {
                let out = pool.run_masked(&jobs, &q, nq, dh, cap, true, Some(&q_valid));
                assert_eq!(out.o, reference.o, "workers={workers} cap={cap}");
                assert_eq!(out.lse, reference.lse, "workers={workers} cap={cap}");
                assert_eq!(out.probs, reference.probs, "workers={workers} cap={cap}");
                assert_eq!(out.tasks, 13.min(cap));
            }
        }
    }

    #[test]
    fn pool_matches_spawn_path_bitwise() {
        let mut rng = Rng::new(0xB22);
        let dh = 8;
        let kvs = rand_jobs(&mut rng, 9, dh, 30);
        let jobs = as_jobs(&kvs);
        let mut q = vec![0.0; jobs.len() * dh];
        rng.fill_normal(&mut q, 1.0);
        let pool = AttnPool::new(3);
        let a = pool.run_masked(&jobs, &q, 1, dh, 4, true, None);
        let b = sparse_attention_spawn_masked(&jobs, &q, 1, dh, 4, true, None);
        assert_eq!(a.o, b.o);
        assert_eq!(a.lse, b.lse);
        assert_eq!(a.probs, b.probs);
        assert_eq!(a.tasks, b.tasks);
    }

    #[test]
    fn empty_submission_returns_immediately() {
        let pool = AttnPool::new(2);
        let out = pool.run_masked(&[], &[], 1, 8, 4, true, None);
        assert!(out.o.is_empty());
        assert!(out.lse.is_empty());
        assert_eq!(out.tasks, 0);
        assert_eq!(pool.stats().submissions, 0); // early-out before counting
    }

    #[test]
    fn stats_count_submissions_tasks_jobs() {
        let mut rng = Rng::new(3);
        let dh = 4;
        let kvs = rand_jobs(&mut rng, 6, dh, 10);
        let jobs = as_jobs(&kvs);
        let mut q = vec![0.0; jobs.len() * dh];
        rng.fill_normal(&mut q, 1.0);
        let pool = AttnPool::new(2);
        pool.run_masked(&jobs, &q, 1, dh, 3, false, None);
        pool.run_masked(&jobs, &q, 1, dh, 6, false, None);
        let s = pool.stats();
        assert_eq!(s.workers, 2);
        assert_eq!(s.submissions, 2);
        assert_eq!(s.jobs, 12);
        assert_eq!(s.tasks, 3 + 6);
        assert_eq!(s.queue_depth, 0, "queue drains after completion");
        assert!(s.queue_peak >= 1);
    }

    #[test]
    fn shared_pool_across_threads() {
        // concurrent submissions from several engine threads interleave
        // safely and each caller gets its own correct outputs
        let pool = std::sync::Arc::new(AttnPool::new(3));
        let mut handles = Vec::new();
        for seed in 0..4u64 {
            let pool = std::sync::Arc::clone(&pool);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(seed);
                let dh = 8;
                let kvs: Vec<(Vec<f32>, Vec<f32>, usize)> = (0..7)
                    .map(|_| {
                        let n = 1 + rng.range(0, 20);
                        let mut k = vec![0.0; n * dh];
                        let mut v = vec![0.0; n * dh];
                        rng.fill_normal(&mut k, 1.0);
                        rng.fill_normal(&mut v, 1.0);
                        (k, v, n)
                    })
                    .collect();
                let jobs: Vec<HeadJob> = kvs
                    .iter()
                    .map(|(k, v, n)| HeadJob { k, v, n: *n })
                    .collect();
                let mut q = vec![0.0; jobs.len() * dh];
                rng.fill_normal(&mut q, 1.0);
                let single = sparse_attention_spawn_masked(&jobs, &q, 1, dh, 1, false, None);
                for _ in 0..16 {
                    let out = pool.run_masked(&jobs, &q, 1, dh, 4, false, None);
                    assert_eq!(out.o, single.o);
                    assert_eq!(out.lse, single.lse);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn entries_split_bitwise_matches_even_split() {
        // the append-path split must be a pure scheduling change: outputs
        // bitwise identical to the decode-path split for every sizing
        let mut rng = Rng::new(0xC33);
        let dh = 8;
        let kvs = rand_jobs(&mut rng, 11, dh, 40);
        let jobs = as_jobs(&kvs);
        let mut q = vec![0.0; jobs.len() * dh];
        rng.fill_normal(&mut q, 1.0);
        let pool = AttnPool::new(3);
        let reference = pool.run_masked(&jobs, &q, 1, dh, 4, true, None);
        for per_task in [1usize, 8, 64, 10_000] {
            for max_tasks in [1usize, 3, 64] {
                let out = pool.run_split(
                    &jobs,
                    &q,
                    1,
                    dh,
                    TaskSplit::ByEntries { per_task, max_tasks },
                    true,
                    None,
                );
                assert_eq!(out.o, reference.o, "per_task={per_task} max_tasks={max_tasks}");
                assert_eq!(out.lse, reference.lse, "per_task={per_task}");
                assert_eq!(out.probs, reference.probs, "per_task={per_task}");
                assert!(out.tasks <= max_tasks.max(1));
            }
        }
    }

    #[test]
    fn entries_split_task_count_follows_store_size() {
        // 8 uniform jobs of 16 entries each (128 total)
        let kvs: Vec<(Vec<f32>, Vec<f32>, usize)> = (0..8)
            .map(|_| (vec![0.0; 16 * 4], vec![0.0; 16 * 4], 16))
            .collect();
        let jobs = as_jobs(&kvs);
        let q = vec![0.0; jobs.len() * 4];
        let pool = AttnPool::new(0);
        let tasks = |per_task: usize, max_tasks: usize| {
            pool.run_split(
                &jobs,
                &q,
                1,
                4,
                TaskSplit::ByEntries { per_task, max_tasks },
                false,
                None,
            )
            .tasks
        };
        assert_eq!(tasks(32, 64), 4); // 2 jobs (32 entries) per task
        assert_eq!(tasks(1_000, 64), 1); // small store → one task
        assert_eq!(tasks(1, 64), 8); // per-job tasks at minimum granularity
        assert_eq!(tasks(1, 3), 3); // soft cap merges adjacent tasks
    }

    #[test]
    fn entries_split_handles_empty_jobs() {
        // zero-entry jobs accumulate no weight and never stall the plan
        let kvs: Vec<(Vec<f32>, Vec<f32>, usize)> =
            (0..5).map(|_| (Vec::new(), Vec::new(), 0)).collect();
        let jobs = as_jobs(&kvs);
        let q = vec![1.0; jobs.len() * 4];
        let pool = AttnPool::new(1);
        let out = pool.run_split(
            &jobs,
            &q,
            1,
            4,
            TaskSplit::ByEntries { per_task: 64, max_tasks: 4 },
            true,
            None,
        );
        assert_eq!(out.tasks, 1); // all-zero entries pack into one task
        assert!(out.lse.iter().all(|&l| l == EMPTY_LSE));
        assert!(out.o.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn prop_pool_matches_single_thread_reference() {
        // satellite: pool output ≡ single-threaded reference for random job
        // shapes at every parallelism cap in {1, 2, 7, 64}
        let pool = AttnPool::new(4);
        check("pool_vs_reference", 20, |rng: &mut Rng| {
            let dh = *rng.choice(&[4usize, 8, 32]);
            let nj = rng.range(1, 20);
            let nq = rng.range(1, 4);
            let kvs = rand_jobs(rng, nj, dh, 24);
            let jobs = as_jobs(&kvs);
            let mut q = vec![0.0; nj * nq * dh];
            rng.fill_normal(&mut q, 1.0);
            let reference = sparse_attention_spawn_masked(&jobs, &q, nq, dh, 1, false, None);
            for cap in [1usize, 2, 7, 64] {
                let out = pool.run_masked(&jobs, &q, nq, dh, cap, false, None);
                ensure_all_close(&out.o, &reference.o, 1e-5, "o")?;
                ensure_all_close(&out.lse, &reference.lse, 1e-5, "lse")?;
                ensure(
                    out.o == reference.o && out.lse == reference.lse,
                    "pool output must be bitwise identical to the reference",
                )?;
            }
            Ok(())
        });
    }
}
