//! Hybrid attention primitives (paper §3.3): CPU-side sparse attention on a
//! persistent worker pool, the log-sum-exp merge, and a dense reference
//! oracle.
//!
//! The data flow mirrors Algorithm 2: the GPU artifact produces a partial
//! attention state (output + log-sum-exp) over the recent window, the
//! [`cpu_attention`] kernels produce partial states over the CPU-resident
//! selected entries, and [`merge`] fuses the two into attention over the
//! union — so the CPU side never ships raw KV back over PCIe, only one
//! `(o, lse)` pair per (row, head).

pub mod cpu_attention;
pub mod dense_ref;
pub mod merge;
pub mod pool;

pub use cpu_attention::{
    run_tiered_at_level, sparse_attention, sparse_attention_append,
    sparse_attention_append_placed, sparse_attention_masked, sparse_attention_masked_placed,
    sparse_attention_spawn, CpuAttnOutput, HeadJob,
};
pub use merge::{is_empty_lse, merge_head, merge_states, EMPTY_LSE};
pub use pool::{
    AttnPool, JobPayload, OwnedJobs, OwnedTieredJobs, PendingAttn, PoolStats, TaskSplit,
};
