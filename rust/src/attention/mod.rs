//! Hybrid attention primitives (paper §3.3): CPU-side sparse attention on a
//! persistent worker pool, the log-sum-exp merge, and a dense reference
//! oracle.

pub mod cpu_attention;
pub mod dense_ref;
pub mod merge;
pub mod pool;

pub use cpu_attention::{
    sparse_attention, sparse_attention_masked, sparse_attention_spawn, CpuAttnOutput, HeadJob,
};
pub use merge::{merge_head, merge_states, EMPTY_LSE};
pub use pool::{AttnPool, PoolStats};
