//! Hybrid attention primitives (paper §3.3): CPU-side multithreaded sparse
//! attention, the log-sum-exp merge, and a dense reference oracle.

pub mod cpu_attention;
pub mod dense_ref;
pub mod merge;

pub use cpu_attention::{sparse_attention, CpuAttnOutput, HeadJob};
pub use merge::{merge_head, merge_states, EMPTY_LSE};
