//! Log-sum-exp state merge (Algorithm 2 line 13; §3.3 "Merging states").
//!
//! Combines two locally-normalized partial attentions over disjoint KV sets
//! into the attention over their union:
//!
//! ```text
//! z = e^{lse_c} + e^{lse_g}
//! O = (e^{lse_c}·O_cpu + e^{lse_g}·O_gpu) / z
//! ```
//!
//! computed with the max-subtraction trick for stability. Mirrors
//! python/compile/kernels/ref.py::merge_lse and FlashInfer's merge_state.

/// Merge one head's states. Returns the merged lse.
/// `o_acc` holds O_a on entry and the merged output on exit (the paper's
/// in-place accumulation into the GPU output buffer).
///
/// # Example
///
/// Two partial attentions over singleton KV sets with equal scores merge
/// into uniform attention over their union:
///
/// ```
/// use hgca::attention::merge_head;
///
/// // each side attended one entry with score 0 → lse = ln(e⁰) = 0
/// let mut o_gpu = vec![1.0_f32];
/// let o_cpu = [3.0_f32];
/// let lse = merge_head(&mut o_gpu, 0.0, &o_cpu, 0.0);
/// assert!((o_gpu[0] - 2.0).abs() < 1e-6); // (1 + 3) / 2
/// assert!((lse - 2.0_f32.ln()).abs() < 1e-6); // log-sum-exp of {0, 0}
/// ```
pub fn merge_head(o_acc: &mut [f32], lse_a: f32, o_b: &[f32], lse_b: f32) -> f32 {
    debug_assert_eq!(o_acc.len(), o_b.len());
    // Emptiness is a *sentinel* comparison, never a magnitude threshold: a
    // genuine partial with a huge-negative lse (a real softmax over deeply
    // negative scores) must survive the merge, not get zeroed. Producers
    // mark "no entries" with exactly EMPTY_LSE (or -inf for an all-masked
    // row), and both values round-trip bitwise — see `is_empty_lse`.
    let empty_a = is_empty_lse(lse_a);
    let empty_b = is_empty_lse(lse_b);
    if empty_a && empty_b {
        // both sides empty — leave zeros
        for v in o_acc.iter_mut() {
            *v = 0.0;
        }
        return f32::NEG_INFINITY;
    }
    if empty_b {
        return lse_a; // identity: o_acc already holds O_a
    }
    if empty_a {
        o_acc.copy_from_slice(o_b);
        return lse_b;
    }
    let m = lse_a.max(lse_b);
    let wa = (lse_a - m).exp();
    let wb = (lse_b - m).exp();
    let z = wa + wb;
    let ia = wa / z;
    let ib = wb / z;
    for (a, &b) in o_acc.iter_mut().zip(o_b.iter()) {
        *a = ia * *a + ib * b;
    }
    m + z.ln()
}

/// Batched merge over [rows][heads]: o_* laid out [row][head][d_head],
/// lse_* laid out [row][head]. CPU side may mark absent heads with
/// lse = -inf (e.g. empty contextual cache), which merges as identity.
pub fn merge_states(
    o_gpu: &mut [f32],
    lse_gpu: &mut [f32],
    o_cpu: &[f32],
    lse_cpu: &[f32],
    d_head: usize,
) {
    assert_eq!(o_gpu.len(), o_cpu.len());
    assert_eq!(lse_gpu.len(), lse_cpu.len());
    assert_eq!(o_gpu.len(), lse_gpu.len() * d_head);
    for (i, lg) in lse_gpu.iter_mut().enumerate() {
        let o = &mut o_gpu[i * d_head..(i + 1) * d_head];
        let oc = &o_cpu[i * d_head..(i + 1) * d_head];
        *lg = merge_head(o, *lg, oc, lse_cpu[i]);
    }
}

/// lse value denoting "no entries on this side".
///
/// This is the **single** definition of the sentinel (re-exported from
/// `attention::cpu_attention` and `attention` itself); producer and
/// consumer can never drift apart. Both the CPU job kernel and the dense
/// artifact emit it bitwise: `softmax_lse` over an empty score row
/// computes `-1e30 + ln(1e-30)`, and the `≈ -69` addend vanishes below
/// the f32 ulp at 1e30 — the result is exactly `-1e30`.
pub const EMPTY_LSE: f32 = -1e30;

/// `true` iff `lse` marks an empty side: the exact [`EMPTY_LSE`] sentinel
/// or `-inf` (a fold over zero scores before the sentinel clamp). Any
/// other value — however negative — is a genuine partial.
#[inline]
pub fn is_empty_lse(lse: f32) -> bool {
    lse == EMPTY_LSE || lse == f32::NEG_INFINITY
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::softmax_lse;
    use crate::util::proptest::{check, ensure_all_close, ensure_close};
    use crate::util::rng::Rng;

    /// Naive attention over explicit scores/values; returns (o, lse).
    fn naive(scores: &[f32], values: &[Vec<f32>], dh: usize) -> (Vec<f32>, f32) {
        let mut p = scores.to_vec();
        let lse = softmax_lse(&mut p);
        let mut o = vec![0.0; dh];
        for (w, v) in p.iter().zip(values.iter()) {
            for j in 0..dh {
                o[j] += w * v[j];
            }
        }
        (o, lse)
    }

    #[test]
    fn merge_equals_union_small() {
        let dh = 3;
        let scores = [0.5f32, -1.0, 2.0, 0.3, 1.1];
        let values: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32, -(i as f32), 0.5]).collect();
        let (of, lf) = naive(&scores, &values, dh);
        let (mut oa, la) = naive(&scores[..2], &values[..2], dh);
        let (ob, lb) = naive(&scores[2..], &values[2..], dh);
        let lm = merge_head(&mut oa, la, &ob, lb);
        for j in 0..dh {
            assert!((oa[j] - of[j]).abs() < 1e-5, "{:?} vs {:?}", oa, of);
        }
        assert!((lm - lf).abs() < 1e-5);
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut o = vec![1.0, 2.0, 3.0];
        let l = merge_head(&mut o, 0.7, &[9.0, 9.0, 9.0], EMPTY_LSE);
        assert_eq!(o, vec![1.0, 2.0, 3.0]);
        assert!((l - 0.7).abs() < 1e-6);
    }

    #[test]
    fn merge_both_empty_stays_empty() {
        let mut o = vec![5.0, 5.0];
        let l = merge_head(&mut o, EMPTY_LSE, &[7.0, 7.0], EMPTY_LSE);
        assert_eq!(o, vec![0.0, 0.0]);
        assert_eq!(l, f32::NEG_INFINITY);
    }

    #[test]
    fn huge_negative_lse_is_a_partial_not_a_sentinel() {
        // regression: the old check treated any m < -1e29 as "both sides
        // empty" and zeroed the output. A genuine partial just above the
        // sentinel must merge as a real (dominated or dominating) side.
        let lse_real = -0.5e30_f32; // < -1e29, but NOT the sentinel
        assert!(!is_empty_lse(lse_real));

        // real-vs-empty: the real side survives verbatim
        let mut o = vec![1.0, -2.0];
        let l = merge_head(&mut o, lse_real, &[9.0, 9.0], EMPTY_LSE);
        assert_eq!(o, vec![1.0, -2.0], "real partial must not be zeroed");
        assert_eq!(l, lse_real);

        // empty-vs-real, accumulator side: o_b is copied through
        let mut o = vec![5.0, 5.0];
        let l = merge_head(&mut o, EMPTY_LSE, &[3.0, 4.0], lse_real);
        assert_eq!(o, vec![3.0, 4.0]);
        assert_eq!(l, lse_real);

        // two real huge-negative partials merge by weight, not to zero
        let mut o = vec![1.0];
        let l = merge_head(&mut o, lse_real, &[3.0], lse_real);
        assert!((o[0] - 2.0).abs() < 1e-6, "equal lse → mean, got {o:?}");
        // the + ln 2 addend vanishes below the f32 ulp at 0.5e30
        assert!(l >= lse_real && l.is_finite());
    }

    #[test]
    fn sentinel_boundary_values() {
        // exactly the sentinel → empty
        assert!(is_empty_lse(EMPTY_LSE));
        assert!(is_empty_lse(f32::NEG_INFINITY));
        // one ulp above/below the sentinel → a genuine partial
        let above = f32::from_bits(EMPTY_LSE.to_bits() - 1); // toward 0
        let below = f32::from_bits(EMPTY_LSE.to_bits() + 1); // more negative
        assert!(above > EMPTY_LSE && !is_empty_lse(above));
        assert!(below < EMPTY_LSE && !is_empty_lse(below));
        for &lse in &[above, below] {
            let mut o = vec![7.0];
            let l = merge_head(&mut o, lse, &[0.0], EMPTY_LSE);
            assert_eq!(o, vec![7.0], "near-sentinel partial survives");
            assert_eq!(l, lse);
        }
    }

    #[test]
    fn merge_extreme_lse_stable() {
        let mut o = vec![1.0];
        let l = merge_head(&mut o, 100.0, &[2.0], -100.0);
        assert!(o[0].is_finite() && l.is_finite());
        assert!((o[0] - 1.0).abs() < 1e-6); // the +100 side dominates fully
    }

    #[test]
    fn batched_merge_matches_per_head() {
        let dh = 2;
        let mut og = vec![1.0, 0.0, 0.0, 1.0];
        let mut lg = vec![0.5, 1.5];
        let oc = vec![0.0, 1.0, 1.0, 0.0];
        let lc = vec![0.5, EMPTY_LSE];
        let mut og2 = og.clone();
        let l0 = merge_head(&mut og2[0..2], 0.5, &oc[0..2], 0.5);
        merge_states(&mut og, &mut lg, &oc, &lc, dh);
        assert_eq!(&og[0..2], &og2[0..2]);
        assert!((lg[0] - l0).abs() < 1e-6);
        assert_eq!(&og[2..4], &[0.0, 1.0]); // empty cpu side → unchanged
    }

    #[test]
    fn prop_merge_equals_union() {
        check("merge_union", 50, |rng: &mut Rng| {
            let dh = 1 + rng.range(1, 16);
            let n = rng.range(2, 40);
            let split = rng.range(1, n);
            let scale = 0.1 + rng.f32() * 10.0;
            let scores: Vec<f32> = (0..n).map(|_| rng.normal() * scale).collect();
            let values: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..dh).map(|_| rng.normal()).collect())
                .collect();
            let (of, lf) = naive(&scores, &values, dh);
            let (mut oa, la) = naive(&scores[..split], &values[..split], dh);
            let (ob, lb) = naive(&scores[split..], &values[split..], dh);
            let lm = merge_head(&mut oa, la, &ob, lb);
            ensure_all_close(&oa, &of, 1e-4, "o")?;
            ensure_close(lm, lf, 1e-4, "lse")
        });
    }

    #[test]
    fn prop_merge_associative_multiway() {
        // k ≥ 3 disjoint partitions: left fold, right fold, and the direct
        // union must agree — the property that lets the engine merge GPU,
        // contextual-cache, and append partials in any order
        check("merge_associative", 40, |rng: &mut Rng| {
            let dh = 1 + rng.range(1, 12);
            let k_parts = rng.range(3, 7);
            let n = k_parts + rng.range(0, 30);
            let scores: Vec<f32> = (0..n).map(|_| rng.normal() * 4.0).collect();
            let values: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..dh).map(|_| rng.normal()).collect())
                .collect();
            // assign every entry to a partition; keep each non-degenerate by
            // seeding one entry per partition first
            let mut part = vec![0usize; n];
            for (i, p) in part.iter_mut().enumerate().take(k_parts) {
                *p = i;
            }
            for p in part.iter_mut().skip(k_parts) {
                *p = rng.range(0, k_parts);
            }
            let partials: Vec<(Vec<f32>, f32)> = (0..k_parts)
                .map(|pi| {
                    let idx: Vec<usize> =
                        (0..n).filter(|&i| part[i] == pi).collect();
                    let mut s: Vec<f32> = idx.iter().map(|&i| scores[i]).collect();
                    let lse = softmax_lse(&mut s);
                    let mut o = vec![0.0; dh];
                    for (w, &i) in s.iter().zip(idx.iter()) {
                        for j in 0..dh {
                            o[j] += w * values[i][j];
                        }
                    }
                    (o, lse)
                })
                .collect();
            let (of, lf) = naive(&scores, &values, dh);

            // left fold: ((p0 ⊕ p1) ⊕ p2) ⊕ …
            let (mut o_l, mut l_l) = partials[0].clone();
            for (o, l) in &partials[1..] {
                l_l = merge_head(&mut o_l, l_l, o, *l);
            }
            // right fold: p0 ⊕ (p1 ⊕ (p2 ⊕ …))
            let (mut o_r, mut l_r) = partials[k_parts - 1].clone();
            for (o, l) in partials[..k_parts - 1].iter().rev() {
                // merge_head accumulates into its first arg; swap via commutativity
                l_r = merge_head(&mut o_r, l_r, o, *l);
            }
            ensure_all_close(&o_l, &of, 2e-4, "left fold vs union")?;
            ensure_close(l_l, lf, 2e-4, "left lse")?;
            ensure_all_close(&o_r, &o_l, 2e-4, "right fold vs left fold")?;
            ensure_close(l_r, l_l, 2e-4, "right lse vs left lse")
        });
    }

    #[test]
    fn prop_merge_commutative() {
        check("merge_commutative", 30, |rng: &mut Rng| {
            let dh = 4;
            let oa: Vec<f32> = (0..dh).map(|_| rng.normal()).collect();
            let ob: Vec<f32> = (0..dh).map(|_| rng.normal()).collect();
            let la = rng.normal() * 5.0;
            let lb = rng.normal() * 5.0;
            let mut x = oa.clone();
            let lx = merge_head(&mut x, la, &ob, lb);
            let mut y = ob.clone();
            let ly = merge_head(&mut y, lb, &oa, la);
            ensure_all_close(&x, &y, 1e-5, "o")?;
            ensure_close(lx, ly, 1e-5, "lse")
        });
    }
}
