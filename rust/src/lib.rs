//! # HGCA — Hybrid GPU-CPU Attention for Long Context LLM Inference
//!
//! Production-shaped reproduction of Deng et al., 2025 (see DESIGN.md) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * [`runtime`] loads AOT-compiled XLA artifacts (the "GPU" device) via the
//!   PJRT C API and executes the dense windowed attention + FFN graph.
//! * [`kv`] implements the paper's locality-aware KV cache manager
//!   (Algorithm 1): GPU circular-buffer pool with MAW tracking, CPU store
//!   with per-head β-threshold sparsification and append-time re-evaluation.
//! * [`attention`] is the CPU-side multithreaded per-head sparse attention
//!   plus the log-sum-exp merge (Algorithm 2).
//! * [`engine`] orchestrates hybrid attention per layer, generation,
//!   continuous batching; [`server`] exposes an HTTP API.
//! * [`baselines`] reimplements FlexGen / H2O / InfiniGen / HF-full as
//!   pluggable policies for the paper's comparisons.
//! * [`simulator`] provides the roofline/PCIe cost models standing in for
//!   the paper's A6000/Xeon/PCIe testbed (DESIGN.md §1).

pub mod analysis;
pub mod attention;
pub mod baselines;
pub mod bench;
pub mod config;
pub mod engine;
pub mod kv;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod server;
pub mod simulator;
pub mod sparse;
pub mod tensor;
pub mod topology;
pub mod util;
