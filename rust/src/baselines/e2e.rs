//! End-to-end generation cost model for the large simulated models
//! (Figs. 12–14): steps a simulated clock through prefill + decode,
//! tracking KV placement, PCIe traffic, per-system memory overheads and
//! OOM conditions on the paper's testbed (A6000 48 GB).

use crate::config::ModelConfig;
use crate::engine::Policy;
use crate::simulator::{Breakdown, Testbed};

pub const A6000_BYTES: usize = 48 * 1024 * 1024 * 1024;
pub const HOST_BYTES: usize = 512 * 1024 * 1024 * 1024;

/// Which serving system's composition rules apply.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SystemKind {
    /// FlexGen-style: full attention, KV 100% host-resident, loaded on
    /// demand (the paper's FlexGen baseline configuration).
    FlexGen,
    /// H2O on FlexGen: sparse top-20% attention, selected set on GPU.
    H2o,
    /// InfiniGen on FlexGen: predictive prefetch + rehearsal memory.
    Infinigen,
    /// HGCA: recent window on GPU, per-head sparse CPU attention.
    Hgca,
    /// HF-style full attention with dynamic allocation, no offload.
    HfFull,
}

#[derive(Debug, Clone)]
pub struct E2eConfig {
    pub system: SystemKind,
    pub batch: usize,
    pub prefill: usize,
    pub gen: usize,
    /// fraction of model weights resident on GPU (paper: 0.75 for OPT-30B,
    /// 0.25 for OPT-66B, 1.0 for smaller)
    pub gpu_weight_frac: f64,
    /// HGCA GPU window (KV entries)
    pub window: usize,
    /// measured mean per-head selectivity for HGCA (from the trained
    /// model; paper reports ≤ 30% per head at β = 1)
    pub hgca_selectivity: f64,
    /// top-k fraction for H2O / InfiniGen (paper: 0.2)
    pub topk_frac: f64,
    /// number of GPUs (Figs. 13/14 scale HF/HGCA across devices)
    pub n_gpus: usize,
}

impl Default for E2eConfig {
    fn default() -> Self {
        E2eConfig {
            system: SystemKind::Hgca,
            batch: 1,
            prefill: 1920,
            gen: 128,
            gpu_weight_frac: 1.0,
            window: 1024,
            hgca_selectivity: 0.2,
            topk_frac: 0.2,
            n_gpus: 1,
        }
    }
}

#[derive(Debug, Clone)]
pub struct E2eResult {
    pub total_secs: f64,
    pub decode_secs: f64,
    pub prefill_secs: f64,
    pub breakdown: Breakdown,
    pub peak_gpu_bytes: usize,
    pub peak_host_bytes: usize,
    pub oom: bool,
    pub tokens_per_sec: f64,
    /// per-step wall times (token-rate curves, Figs. 13–15)
    pub step_secs: Vec<f64>,
}

fn policy_for(system: SystemKind, cfg: &E2eConfig) -> Policy {
    match system {
        SystemKind::FlexGen => Policy::FullOffload,
        SystemKind::H2o => Policy::H2o { frac: cfg.topk_frac as f32 },
        SystemKind::Infinigen => Policy::Infinigen { frac: cfg.topk_frac as f32 },
        SystemKind::Hgca => Policy::Hgca { beta: 1.0 },
        SystemKind::HfFull => Policy::GpuOnly,
    }
}

/// Step the simulated clock through an entire generation run.
pub fn simulate_generation(tb: &Testbed, model: &ModelConfig, cfg: &E2eConfig) -> E2eResult {
    let policy = policy_for(cfg.system, cfg);
    let kv_tok = model.kv_bytes_per_token() * cfg.batch; // all layers
    let weight_gpu = (model.weight_bytes() as f64 * cfg.gpu_weight_frac) as usize;
    let gpu_budget = A6000_BYTES * cfg.n_gpus;

    let mut breakdown = Breakdown::new();
    let mut peak_gpu = weight_gpu;
    let mut peak_host = model.weight_bytes() - weight_gpu;
    let mut oom = false;
    let mut step_secs = Vec::with_capacity(cfg.gen);

    // ---- prefill: compute-bound GEMM over the prompt + KV placement ----
    let prefill_secs = tb.prefill_weights(model, cfg.batch, cfg.prefill) / cfg.n_gpus as f64;
    breakdown.add("prefill", prefill_secs);
    // where do prompt KVs land?
    let (mut n_gpu_kv, mut n_cpu_kv) = match cfg.system {
        // paper setup: FlexGen-family places 100% of KV in host memory
        SystemKind::FlexGen | SystemKind::Infinigen => (0usize, cfg.prefill),
        SystemKind::H2o => (((cfg.prefill as f64) * cfg.topk_frac) as usize, 0),
        SystemKind::Hgca => {
            let on_gpu = cfg.prefill.min(cfg.window);
            (on_gpu, cfg.prefill - on_gpu)
        }
        SystemKind::HfFull => (cfg.prefill, 0),
    };
    // prompt KV must cross PCIe when host-resident
    if n_cpu_kv > 0 {
        breakdown.add(
            "pcie_kv_offload",
            tb.link.transfer_time((n_cpu_kv * kv_tok) as f64),
        );
    }

    // ---- decode loop ----
    let mut decode_secs = 0.0;
    for _t in 0..cfg.gen {
        // attention: use per-layer sizes (uniform across layers here) and
        // multiply by layer count — each layer attends its own KV
        let n_sel = match cfg.system {
            SystemKind::Hgca => (n_cpu_kv as f64 * cfg.hgca_selectivity) as usize,
            SystemKind::H2o => 0, // selected set already inside n_gpu_kv
            SystemKind::Infinigen | SystemKind::FlexGen => {
                (n_cpu_kv as f64 * cfg.topk_frac) as usize
            }
            SystemKind::HfFull => 0,
        };
        let (attn_wall, attn_bd) = policy.sim_attention(
            tb,
            model,
            cfg.batch,
            1,
            n_gpu_kv,
            n_cpu_kv,
            n_sel,
        );
        let weights = tb.decode_step_weights(model, cfg.batch, cfg.gpu_weight_frac);
        let step = attn_wall * model.n_layers as f64 / cfg.n_gpus as f64
            + weights.total() / cfg.n_gpus as f64;
        decode_secs += step;
        step_secs.push(step);
        for (l, s) in &attn_bd.segments {
            breakdown.add(l, s * model.n_layers as f64 / cfg.n_gpus as f64);
        }
        breakdown.add("weights", weights.total() / cfg.n_gpus as f64);

        // KV growth per new token
        match cfg.system {
            SystemKind::HfFull => n_gpu_kv += 1,
            SystemKind::Hgca => {
                if n_gpu_kv < cfg.window {
                    n_gpu_kv += 1;
                } else {
                    n_cpu_kv += 1; // block eviction amortized per-token
                }
            }
            SystemKind::H2o => {
                n_gpu_kv = (((cfg.prefill + _t) as f64) * cfg.topk_frac) as usize;
            }
            SystemKind::FlexGen | SystemKind::Infinigen => n_cpu_kv += 1,
        }

        // memory accounting + OOM checks (per step, peak-tracked)
        // HF's dynamic allocation fragments (paper §5.2: HGCA's
        // pre-allocated pool avoids this); charge a fragmentation factor.
        let frag = if cfg.system == SystemKind::HfFull { 5 } else { 4 };
        let mut gpu_mem = weight_gpu + n_gpu_kv * kv_tok * frag / 4;
        let mut host_mem = (model.weight_bytes() - weight_gpu) + n_cpu_kv * kv_tok;
        if cfg.system == SystemKind::Infinigen {
            // rehearsal buffers live in *GPU* memory per in-flight entry —
            // the OOM driver the paper observes
            let per_entry = policy.overhead_bytes_per_entry(model)
                * model.n_layers
                * model.n_heads
                * cfg.batch;
            gpu_mem += (n_cpu_kv + n_gpu_kv) * per_entry;
            host_mem += (n_cpu_kv + n_gpu_kv) * per_entry;
        }
        if cfg.system == SystemKind::FlexGen {
            // staging buffer for the KV reload of the largest layer batch
            gpu_mem += n_cpu_kv * kv_tok / model.n_layers;
        }
        peak_gpu = peak_gpu.max(gpu_mem);
        peak_host = peak_host.max(host_mem);
        if gpu_mem > gpu_budget || host_mem > HOST_BYTES {
            oom = true;
            break;
        }
    }

    let total = prefill_secs + decode_secs;
    E2eResult {
        total_secs: total,
        decode_secs,
        prefill_secs,
        breakdown: breakdown.collapsed(),
        peak_gpu_bytes: peak_gpu,
        peak_host_bytes: peak_host,
        oom,
        tokens_per_sec: if oom || decode_secs == 0.0 {
            0.0
        } else {
            (cfg.gen * cfg.batch) as f64 / decode_secs
        },
        step_secs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::simulated;

    fn run(system: SystemKind, model: &str, batch: usize) -> E2eResult {
        let tb = Testbed::paper();
        let m = simulated(model).unwrap();
        let frac = match model {
            "opt-30b" => 0.75,
            "opt-66b" => 0.25,
            _ => 1.0,
        };
        simulate_generation(
            &tb,
            &m,
            &E2eConfig {
                system,
                batch,
                gpu_weight_frac: frac,
                // paper fig 12: HGCA keeps 5% of KV on GPU
                window: ((1920 + 128) / 20).max(64),
                ..Default::default()
            },
        )
    }

    #[test]
    fn fig12_shape_hgca_beats_flexgen_and_h2o() {
        for model in ["opt-6.7b", "opt-30b"] {
            let hgca = run(SystemKind::Hgca, model, 4);
            let flexgen = run(SystemKind::FlexGen, model, 4);
            let h2o = run(SystemKind::H2o, model, 4);
            assert!(!hgca.oom);
            assert!(
                hgca.total_secs < flexgen.total_secs,
                "{model}: hgca {} vs flexgen {}",
                hgca.total_secs,
                flexgen.total_secs
            );
            assert!(
                hgca.total_secs < h2o.total_secs * 1.6,
                "{model}: hgca should be competitive with h2o (sparser but GPU-bound)"
            );
        }
    }

    #[test]
    fn fig12_infinigen_memory_pressure() {
        // InfiniGen's rehearsal overhead must dwarf HGCA's footprint and
        // OOM first as batch grows (paper's observation on OPT-66B)
        let inf = run(SystemKind::Infinigen, "opt-66b", 8);
        let hgca = run(SystemKind::Hgca, "opt-66b", 8);
        assert!(
            inf.peak_gpu_bytes > hgca.peak_gpu_bytes,
            "inf {} vs hgca {}",
            inf.peak_gpu_bytes,
            hgca.peak_gpu_bytes
        );
        assert!(inf.oom, "infinigen should OOM on opt-66b at batch 8");
        assert!(!hgca.oom, "hgca must survive (peak {})", hgca.peak_gpu_bytes);
    }

    #[test]
    fn fig13_hf_ooms_on_long_generation() {
        // GPT-NeoX-12B on 2 GPUs: HF (no offload) dies as KV grows; HGCA
        // scales to the full 4096 tokens on a single GPU
        let tb = Testbed::paper();
        let m = simulated("gpt-neox-12b").unwrap();
        let hf = simulate_generation(
            &tb,
            &m,
            &E2eConfig {
                system: SystemKind::HfFull,
                batch: 32,
                prefill: 128,
                gen: 4096,
                n_gpus: 2,
                ..Default::default()
            },
        );
        let hgca = simulate_generation(
            &tb,
            &m,
            &E2eConfig {
                system: SystemKind::Hgca,
                batch: 32,
                prefill: 128,
                gen: 4096,
                window: 256,
                n_gpus: 1,
                ..Default::default()
            },
        );
        assert!(hf.oom, "HF without offload must OOM");
        assert!(!hgca.oom, "HGCA must finish on one GPU");
    }

    #[test]
    fn batch_scaling_increases_throughput() {
        let t1 = run(SystemKind::Hgca, "opt-6.7b", 1);
        let t8 = run(SystemKind::Hgca, "opt-6.7b", 8);
        assert!(t8.tokens_per_sec > t1.tokens_per_sec * 2.0);
    }

    #[test]
    fn step_times_grow_with_context() {
        let r = run(SystemKind::Hgca, "opt-6.7b", 1);
        assert!(r.step_secs.last().unwrap() >= r.step_secs.first().unwrap());
    }
}
