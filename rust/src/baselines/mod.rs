//! End-to-end baseline system models for the paper's comparisons
//! (Figs. 12–14). Accuracy-side baselines run through the real engine via
//! [`crate::engine::Policy`]; this module adds the *system-level* cost
//! composition — weight placement, per-step KV movement, OOM detection —
//! for the large simulated models (OPT-30B/66B etc.) that cannot
//! materialize on this machine.

pub mod e2e;

pub use e2e::{simulate_generation, E2eConfig, E2eResult, SystemKind};
