//! H2O-style top-k selection (§2.2): keep the fixed fraction of entries
//! with the highest cumulative attention scores, regardless of how the
//! per-head distribution actually looks — the rigidity HGCA's adaptive
//! threshold removes.

use super::{SelectInput, SparsePolicy};

#[derive(Debug, Clone)]
pub struct TopK {
    /// fraction of entries to keep (the paper configures H2O at 0.2)
    pub fraction: f32,
    /// keep at least this many when any exist
    pub min_keep: usize,
}

impl TopK {
    pub fn new(fraction: f32) -> Self {
        assert!((0.0..=1.0).contains(&fraction));
        TopK {
            fraction,
            min_keep: 1,
        }
    }
}

impl SparsePolicy for TopK {
    fn select(&self, input: &SelectInput<'_>) -> Vec<u32> {
        let n = input.maw.len();
        if n == 0 {
            return Vec::new();
        }
        let k = ((n as f32 * self.fraction).round() as usize)
            .max(self.min_keep)
            .min(n);
        let mut idx: Vec<u32> = (0..n as u32).collect();
        // partial selection by score, descending
        idx.sort_by(|&a, &b| {
            input.maw[b as usize]
                .partial_cmp(&input.maw[a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut out: Vec<u32> = idx[..k].to_vec();
        out.sort_unstable(); // chronological order for contiguous gathers
        out
    }

    fn name(&self) -> &'static str {
        "h2o-topk"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::demo_input;

    #[test]
    fn keeps_exact_fraction() {
        let (maw, pos) = demo_input();
        let sel = TopK::new(0.2).select(&SelectInput { maw: &maw, pos: &pos, seq_len: 10 });
        assert_eq!(sel.len(), 2);
        assert_eq!(sel, vec![3, 7]); // top-2 scores, sorted by index
    }

    #[test]
    fn fixed_budget_ignores_distribution_shape() {
        // the failure mode HGCA fixes: flat distribution still keeps 20%
        let maw = vec![0.1; 10];
        let pos: Vec<usize> = (0..10).collect();
        let sel = TopK::new(0.2).select(&SelectInput { maw: &maw, pos: &pos, seq_len: 10 });
        assert_eq!(sel.len(), 2); // arbitrary 2 of 10 equal entries
    }

    #[test]
    fn min_keep_applies() {
        let maw = vec![0.5, 0.5];
        let pos = vec![0, 1];
        let sel = TopK::new(0.01).select(&SelectInput { maw: &maw, pos: &pos, seq_len: 2 });
        assert_eq!(sel.len(), 1);
    }

    #[test]
    fn empty_input_empty_output() {
        let sel = TopK::new(0.2).select(&SelectInput { maw: &[], pos: &[], seq_len: 0 });
        assert!(sel.is_empty());
    }

    #[test]
    fn full_fraction_keeps_all() {
        let (maw, pos) = demo_input();
        let sel = TopK::new(1.0).select(&SelectInput { maw: &maw, pos: &pos, seq_len: 10 });
        assert_eq!(sel.len(), 10);
    }
}
