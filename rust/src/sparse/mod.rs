//! KV-selection policies: HGCA's per-head adaptive threshold plus the
//! baselines the paper compares against (§2.2, §5). All policies answer
//! the same question — *which CPU-resident KV entries should sparse
//! attention visit for a given head?* — so baseline engines differ only in
//! the policy they plug in.

pub mod head_threshold;
pub mod infinigen;
pub mod static_window;
pub mod topk;

pub use head_threshold::HeadThreshold;
pub use infinigen::InfinigenPredict;
pub use static_window::StaticWindow;
pub use topk::TopK;

/// Selection context for one attention head of one layer.
pub struct SelectInput<'a> {
    /// historical attention weight per entry (MAW or cumulative score)
    pub maw: &'a [f32],
    /// global token position per entry
    pub pos: &'a [usize],
    /// current sequence length (decoding frontline)
    pub seq_len: usize,
}

pub trait SparsePolicy: Send + Sync {
    /// Indices of entries this head should attend.
    fn select(&self, input: &SelectInput<'_>) -> Vec<u32>;

    /// Extra working memory the policy needs per KV entry, in bytes
    /// (InfiniGen's rehearsal buffers; 0 for the others). Feeds the
    /// memory accounting in Fig. 12.
    fn overhead_bytes_per_entry(&self) -> usize {
        0
    }

    fn name(&self) -> &'static str;
}

#[cfg(test)]
pub(crate) fn demo_input() -> (Vec<f32>, Vec<usize>) {
    // 10 entries: one strong spike at 3, mild at 7, noise elsewhere
    let maw = vec![0.01, 0.02, 0.01, 0.60, 0.02, 0.01, 0.02, 0.25, 0.03, 0.03];
    let pos = (0..10).collect();
    (maw, pos)
}
