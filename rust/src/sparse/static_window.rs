//! StreamingLLM/Longformer-style static selection (§2.2, Fig. 2a): a fixed
//! global window of early "attention sink" tokens plus a recency window.
//! No runtime adaptation — the baseline that misses contextually-important
//! middle tokens (paper Fig. 5's dotted box).

use super::{SelectInput, SparsePolicy};

#[derive(Debug, Clone)]
pub struct StaticWindow {
    /// first `sinks` tokens of the sequence are always kept
    pub sinks: usize,
    /// most recent `recent` tokens are kept
    pub recent: usize,
}

impl StaticWindow {
    pub fn new(sinks: usize, recent: usize) -> Self {
        StaticWindow { sinks, recent }
    }
}

impl SparsePolicy for StaticWindow {
    fn select(&self, input: &SelectInput<'_>) -> Vec<u32> {
        let cutoff = input.seq_len.saturating_sub(self.recent);
        input
            .pos
            .iter()
            .enumerate()
            .filter(|(_, &p)| p < self.sinks || p >= cutoff)
            .map(|(i, _)| i as u32)
            .collect()
    }

    fn name(&self) -> &'static str {
        "static-sink-window"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_sinks_and_recent() {
        let pos: Vec<usize> = (0..20).collect();
        let maw = vec![0.05; 20];
        let sel = StaticWindow::new(2, 4).select(&SelectInput {
            maw: &maw,
            pos: &pos,
            seq_len: 20,
        });
        assert_eq!(sel, vec![0, 1, 16, 17, 18, 19]);
    }

    #[test]
    fn ignores_maw_entirely() {
        let pos: Vec<usize> = (0..10).collect();
        let hot = {
            let mut m = vec![0.0; 10];
            m[5] = 1.0; // contextually crucial token in the middle
            m
        };
        let sel = StaticWindow::new(1, 2).select(&SelectInput {
            maw: &hot,
            pos: &pos,
            seq_len: 10,
        });
        assert!(!sel.contains(&5), "static policy is blind to importance");
    }

    #[test]
    fn non_contiguous_positions() {
        // CPU store holds evicted entries; positions may be sparse
        let pos = vec![0, 3, 7, 90, 95];
        let maw = vec![0.1; 5];
        let sel = StaticWindow::new(4, 10).select(&SelectInput {
            maw: &maw,
            pos: &pos,
            seq_len: 100,
        });
        assert_eq!(sel, vec![0, 1, 3, 4]); // pos 0,3 are sinks; 90,95 recent
    }

    #[test]
    fn short_sequence_keeps_all() {
        let pos: Vec<usize> = (0..5).collect();
        let maw = vec![0.2; 5];
        let sel = StaticWindow::new(4, 8).select(&SelectInput {
            maw: &maw,
            pos: &pos,
            seq_len: 5,
        });
        assert_eq!(sel.len(), 5);
    }
}
