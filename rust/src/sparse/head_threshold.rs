//! HGCA's per-head adaptive threshold (§3.2.2): keep entry i iff
//! maw[i] > β / n. Heads with peaked attention keep few entries; flat
//! heads keep many — the adaptivity the paper's Fig. 4 motivates.

use super::{SelectInput, SparsePolicy};

#[derive(Debug, Clone)]
pub struct HeadThreshold {
    pub beta: f32,
}

impl HeadThreshold {
    pub fn new(beta: f32) -> Self {
        HeadThreshold { beta }
    }
}

impl SparsePolicy for HeadThreshold {
    fn select(&self, input: &SelectInput<'_>) -> Vec<u32> {
        let n = input.maw.len();
        let threshold = self.beta / n.max(1) as f32;
        input
            .maw
            .iter()
            .enumerate()
            .filter(|(_, &m)| m > threshold)
            .map(|(i, _)| i as u32)
            .collect()
    }

    fn name(&self) -> &'static str {
        "hgca-head-threshold"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::demo_input;
    use crate::util::proptest::{check, ensure};
    use crate::util::rng::Rng;

    #[test]
    fn selects_spikes_at_beta_one() {
        let (maw, pos) = demo_input();
        let sel = HeadThreshold::new(1.0).select(&SelectInput { maw: &maw, pos: &pos, seq_len: 10 });
        // threshold = 0.1: keeps 0.60 and 0.25 only
        assert_eq!(sel, vec![3, 7]);
    }

    #[test]
    fn smaller_beta_keeps_more() {
        let (maw, pos) = demo_input();
        let strict = HeadThreshold::new(1.0)
            .select(&SelectInput { maw: &maw, pos: &pos, seq_len: 10 })
            .len();
        let loose = HeadThreshold::new(0.1)
            .select(&SelectInput { maw: &maw, pos: &pos, seq_len: 10 })
            .len();
        assert!(loose > strict);
    }

    #[test]
    fn uniform_distribution_keeps_nothing_at_beta_one() {
        // exactly uniform weights equal the threshold → strict inequality drops all
        let maw = vec![0.1; 10];
        let pos: Vec<usize> = (0..10).collect();
        let sel = HeadThreshold::new(1.0).select(&SelectInput { maw: &maw, pos: &pos, seq_len: 10 });
        assert!(sel.is_empty());
    }

    #[test]
    fn beta_zero_keeps_everything_positive() {
        let (maw, pos) = demo_input();
        let sel = HeadThreshold::new(0.0).select(&SelectInput { maw: &maw, pos: &pos, seq_len: 10 });
        assert_eq!(sel.len(), 10);
    }

    #[test]
    fn prop_selected_mass_dominates() {
        // entries kept under β=1 must carry at least their proportional mass
        check("threshold_mass", 30, |rng: &mut Rng| {
            let n = rng.range(4, 100);
            let mut maw: Vec<f32> = (0..n).map(|_| rng.f32().powi(4)).collect();
            let sum: f32 = maw.iter().sum::<f32>().max(1e-9);
            for m in maw.iter_mut() {
                *m /= sum;
            }
            let pos: Vec<usize> = (0..n).collect();
            let sel = HeadThreshold::new(1.0).select(&SelectInput { maw: &maw, pos: &pos, seq_len: n });
            let kept: f32 = sel.iter().map(|&i| maw[i as usize]).sum();
            let frac = sel.len() as f32 / n as f32;
            ensure(
                kept >= frac - 1e-5,
                format!("kept mass {kept} < kept fraction {frac}"),
            )
        });
    }

    #[test]
    fn prop_monotone_in_beta() {
        check("threshold_monotone", 30, |rng: &mut Rng| {
            let n = rng.range(1, 60);
            let maw: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let pos: Vec<usize> = (0..n).collect();
            let b1 = rng.f32() * 2.0;
            let b2 = b1 + rng.f32();
            let s1 = HeadThreshold::new(b1).select(&SelectInput { maw: &maw, pos: &pos, seq_len: n });
            let s2 = HeadThreshold::new(b2).select(&SelectInput { maw: &maw, pos: &pos, seq_len: n });
            ensure(
                s2.len() <= s1.len() && s2.iter().all(|i| s1.contains(i)),
                "higher beta must select a subset",
            )
        });
    }
}
