//! InfiniGen-style predictive selection (§2.2): uses the *previous* step's
//! query to rehearse attention and prefetch the predicted-important KV
//! entries for the next step. Faithful to the mechanism at the policy
//! level: selection quality equals last-step scores (not current), and the
//! rehearsal costs extra memory per entry — the overhead that drives
//! InfiniGen's OOMs in the paper's Fig. 12.

use super::{SelectInput, SparsePolicy};

#[derive(Debug, Clone)]
pub struct InfinigenPredict {
    /// fraction of entries prefetched per step (paper config: 0.2)
    pub fraction: f32,
    /// bytes of rehearsal state per KV entry (partial-weight speculation
    /// buffers; sized after InfiniGen's partial query/key cache)
    pub rehearsal_bytes: usize,
}

impl InfinigenPredict {
    pub fn new(fraction: f32) -> Self {
        InfinigenPredict {
            fraction,
            // speculation keeps a low-rank sketch of K plus the last query
            // per entry ≈ d_head fp16 — dominant term in its memory overhead
            rehearsal_bytes: 128 * 2,
        }
    }

    /// Selection using *stale* scores: the caller passes last-step scores
    /// via `input.maw` shifted one step — the prediction may miss entries
    /// that just became important (captured by accuracy benches).
    fn topk(&self, scores: &[f32]) -> Vec<u32> {
        let n = scores.len();
        if n == 0 {
            return Vec::new();
        }
        let k = ((n as f32 * self.fraction).round() as usize).max(1).min(n);
        let mut idx: Vec<u32> = (0..n as u32).collect();
        idx.sort_by(|&a, &b| {
            scores[b as usize]
                .partial_cmp(&scores[a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut out: Vec<u32> = idx[..k].to_vec();
        out.sort_unstable();
        out
    }
}

impl SparsePolicy for InfinigenPredict {
    fn select(&self, input: &SelectInput<'_>) -> Vec<u32> {
        self.topk(input.maw)
    }

    fn overhead_bytes_per_entry(&self) -> usize {
        self.rehearsal_bytes
    }

    fn name(&self) -> &'static str {
        "infinigen-predict"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::demo_input;

    #[test]
    fn selects_topk_like() {
        let (maw, pos) = demo_input();
        let sel = InfinigenPredict::new(0.2).select(&SelectInput {
            maw: &maw,
            pos: &pos,
            seq_len: 10,
        });
        assert_eq!(sel, vec![3, 7]);
    }

    #[test]
    fn carries_memory_overhead() {
        let p = InfinigenPredict::new(0.2);
        assert!(p.overhead_bytes_per_entry() > 0);
        // per-entry overhead is comparable to a fp16 head vector
        assert_eq!(p.overhead_bytes_per_entry(), 256);
    }

    #[test]
    fn stale_scores_miss_new_spikes() {
        // simulate staleness: entry 9 just became hot but the rehearsal
        // scores (passed as maw) still show the old distribution
        let stale = vec![0.3, 0.3, 0.1, 0.1, 0.05, 0.05, 0.04, 0.03, 0.02, 0.01];
        let pos: Vec<usize> = (0..10).collect();
        let sel = InfinigenPredict::new(0.2).select(&SelectInput {
            maw: &stale,
            pos: &pos,
            seq_len: 10,
        });
        assert!(!sel.contains(&9));
    }
}
