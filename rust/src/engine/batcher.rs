//! Continuous batcher: admits queued requests into free batch rows each
//! step, retires finished sequences (vLLM-style iteration-level
//! scheduling, shaped to the fixed-batch artifacts).
//!
//! Scheduling is strict FIFO admission: whenever a batch row frees up, the
//! oldest queued request takes it. With bounded `max_new_tokens` this gives
//! a hard no-starvation bound — a request queued behind `Q` others waits at
//! most `ceil(Q / batch) × max_target` ticks before admission — which the
//! conformance suite (tests/integration_pool.rs) checks via the per-request
//! `admit_tick` / `queue_ticks` accounting recorded on every [`Completion`].
//!
//! One `tick` = one fused decode step: every active sequence contributes its
//! (row, head) jobs to a single CPU-pool submission inside
//! `Engine::decode_step`, merged per-sequence via the LSE merge.

use std::collections::VecDeque;

use anyhow::Result;

use crate::engine::{Engine, Sequence};

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
}

#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub text: Vec<u8>,
    pub prompt_len: usize,
    pub decode_steps: usize,
    /// ticks spent waiting in the queue before admission
    pub queue_ticks: u64,
    /// tick at which the request entered the batch
    pub admit_tick: u64,
    /// tick at which the request completed
    pub finish_tick: u64,
}

struct Queued {
    req: Request,
    submit_tick: u64,
}

struct Active {
    seq: Sequence,
    target: usize,
    generated: usize,
    admit_tick: u64,
    queue_ticks: u64,
}

/// Aggregate scheduling statistics (serving metrics endpoint).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BatcherStats {
    pub ticks: u64,
    pub submitted: u64,
    pub completed: u64,
    /// requests currently queued (not yet admitted)
    pub queued: usize,
    /// requests currently decoding
    pub active: usize,
    /// mean fraction of batch rows occupied per tick (0..=1)
    pub mean_occupancy: f64,
    /// worst queue wait observed across completed requests, in ticks
    pub max_queue_ticks: u64,
}

/// Iteration-level scheduler over a fixed-batch engine.
pub struct Batcher {
    pub batch: usize,
    queue: VecDeque<Queued>,
    active: Vec<Active>,
    tick_count: u64,
    submitted: u64,
    completed: u64,
    occupancy_rows: u64,
    max_queue_ticks: u64,
}

impl Batcher {
    pub fn new(batch: usize) -> Batcher {
        assert!(batch > 0, "batch must be positive");
        Batcher {
            batch,
            queue: VecDeque::new(),
            active: Vec::new(),
            tick_count: 0,
            submitted: 0,
            completed: 0,
            occupancy_rows: 0,
            max_queue_ticks: 0,
        }
    }

    /// Enqueue a request; it joins the running batch at the next tick with a
    /// free row (continuous admission — no drain barrier).
    pub fn submit(&mut self, req: Request) {
        self.submitted += 1;
        self.queue.push_back(Queued {
            req,
            submit_tick: self.tick_count,
        });
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    pub fn stats(&self) -> BatcherStats {
        BatcherStats {
            ticks: self.tick_count,
            submitted: self.submitted,
            completed: self.completed,
            queued: self.queue.len(),
            active: self.active.len(),
            mean_occupancy: if self.tick_count == 0 {
                0.0
            } else {
                self.occupancy_rows as f64 / (self.tick_count * self.batch as u64) as f64
            },
            max_queue_ticks: self.max_queue_ticks,
        }
    }

    /// Run one scheduling iteration: admit + prefill newcomers FIFO into
    /// free rows (prefill is per-sequence, batch=1 artifacts), then one
    /// fused decode step over all active rows. Returns newly finished
    /// completions.
    pub fn tick(&mut self, engine: &mut Engine<'_>) -> Result<Vec<Completion>> {
        let mut finished = Vec::new();
        // ---- admit (FIFO — the no-starvation invariant) ----
        while self.active.len() < self.batch {
            let Some(Queued { req, submit_tick }) = self.queue.pop_front() else {
                break;
            };
            let queue_ticks = self.tick_count - submit_tick;
            self.max_queue_ticks = self.max_queue_ticks.max(queue_ticks);
            let mut seq = engine.new_sequence(req.id, &req.prompt);
            let logits = engine.prefill(&mut seq)?;
            // first sampled token comes from the prefill logits
            let mut generated = 0;
            if !logits.is_empty() && req.max_new_tokens > 0 {
                let t = engine.sampler.sample(&logits, &mut engine.rng);
                seq.tokens.push(t);
                generated = 1;
            }
            if generated >= req.max_new_tokens {
                // zero-token request (or degenerate prompt): retire without
                // ever occupying a decode row
                let prompt_len = seq.tokens.len() - generated;
                self.completed += 1;
                finished.push(Completion {
                    id: seq.id,
                    text: seq.tokens[prompt_len..].to_vec(),
                    prompt_len,
                    decode_steps: generated,
                    queue_ticks,
                    admit_tick: self.tick_count,
                    finish_tick: self.tick_count,
                });
                continue;
            }
            self.active.push(Active {
                seq,
                target: req.max_new_tokens,
                generated,
                admit_tick: self.tick_count,
                queue_ticks,
            });
        }
        if self.active.is_empty() {
            return Ok(finished);
        }
        // ---- one fused decode step over the active rows ----
        // (all sequences' (row, head) jobs land in a single worker-pool
        // submission inside the engine; outputs merge per-sequence)
        {
            let mut refs: Vec<&mut Sequence> = self.active.iter_mut().map(|a| &mut a.seq).collect();
            engine.decode_step(&mut refs, self.batch, None)?;
        }
        self.occupancy_rows += self.active.len() as u64;
        self.tick_count += 1;
        for a in self.active.iter_mut() {
            a.generated += 1;
        }
        // ---- retire finished ----
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].generated >= self.active[i].target {
                let a = self.active.swap_remove(i);
                let prompt_len = a.seq.tokens.len() - a.generated;
                self.completed += 1;
                finished.push(Completion {
                    id: a.seq.id,
                    text: a.seq.tokens[prompt_len..].to_vec(),
                    prompt_len,
                    decode_steps: a.generated,
                    queue_ticks: a.queue_ticks,
                    admit_tick: a.admit_tick,
                    finish_tick: self.tick_count,
                });
            } else {
                i += 1;
            }
        }
        Ok(finished)
    }

    /// Drive ticks until every submitted request completes. Returns the
    /// completions produced *by these ticks* — completions already handed
    /// out by earlier manual `tick` calls are the caller's to keep (the
    /// batcher retains nothing, so long-running servers don't accumulate).
    pub fn run_to_completion(&mut self, engine: &mut Engine<'_>) -> Result<Vec<Completion>> {
        let mut done = Vec::new();
        while self.pending() > 0 {
            done.extend(self.tick(engine)?);
        }
        Ok(done)
    }
}
