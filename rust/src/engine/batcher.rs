//! Continuous batcher: admits queued requests into free batch rows each
//! step, retires finished sequences (vLLM-style iteration-level
//! scheduling, shaped to the fixed-batch artifacts).

use std::collections::VecDeque;

use anyhow::Result;

use crate::engine::{Engine, Sequence};

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
}

#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub text: Vec<u8>,
    pub prompt_len: usize,
    pub decode_steps: usize,
}

struct Active {
    seq: Sequence,
    target: usize,
    generated: usize,
}

/// Iteration-level scheduler over a fixed-batch engine.
pub struct Batcher {
    pub batch: usize,
    queue: VecDeque<Request>,
    active: Vec<Active>,
    done: Vec<Completion>,
    next_admit: usize,
}

impl Batcher {
    pub fn new(batch: usize) -> Batcher {
        Batcher {
            batch,
            queue: VecDeque::new(),
            active: Vec::new(),
            done: Vec::new(),
            next_admit: 0,
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    /// Run one scheduling iteration: admit + prefill newcomers (prefill is
    /// per-sequence, batch=1 artifacts), then one batched decode step over
    /// all active rows. Returns newly finished completions.
    pub fn tick(&mut self, engine: &mut Engine<'_>) -> Result<Vec<Completion>> {
        // admit
        while self.active.len() < self.batch {
            let Some(req) = self.queue.pop_front() else { break };
            let mut seq = engine.new_sequence(req.id, &req.prompt);
            let logits = engine.prefill(&mut seq)?;
            // first sampled token comes from the prefill logits
            let mut generated = 0;
            if !logits.is_empty() && req.max_new_tokens > 0 {
                let t = engine.sampler.sample(&logits, &mut engine.rng);
                seq.tokens.push(t);
                generated = 1;
            }
            self.active.push(Active {
                seq,
                target: req.max_new_tokens,
                generated,
            });
            self.next_admit += 1;
        }
        if self.active.is_empty() {
            return Ok(Vec::new());
        }
        // batched decode over the active rows
        {
            let mut refs: Vec<&mut Sequence> = self.active.iter_mut().map(|a| &mut a.seq).collect();
            engine.decode_step(&mut refs, self.batch, None)?;
        }
        for a in self.active.iter_mut() {
            a.generated += 1;
        }
        // retire finished
        let mut finished = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].generated >= self.active[i].target {
                let a = self.active.swap_remove(i);
                let prompt_len = a.seq.tokens.len() - a.generated;
                finished.push(Completion {
                    id: a.seq.id,
                    text: a.seq.tokens[prompt_len..].to_vec(),
                    prompt_len,
                    decode_steps: a.generated,
                });
            } else {
                i += 1;
            }
        }
        self.done.extend(finished.clone());
        Ok(finished)
    }

    /// Drive ticks until every submitted request completes.
    pub fn run_to_completion(&mut self, engine: &mut Engine<'_>) -> Result<Vec<Completion>> {
        while self.pending() > 0 {
            self.tick(engine)?;
        }
        Ok(std::mem::take(&mut self.done))
    }
}
