//! The HGCA inference engine: per-layer hybrid attention (Algorithm 2),
//! chunked prefill/append, batched decode, teacher-forced evaluation.
//!
//! Real numerics flow through the PJRT artifacts ("GPU") + the rust CPU
//! sparse attention; simulated time is charged per the active policy on
//! the paper's testbed model (DESIGN.md §1 — two timing domains).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::attention::{
    merge_states, AttnPool, CpuAttnOutput, JobPayload, OwnedJobs, OwnedTieredJobs, PendingAttn,
    TaskSplit, EMPTY_LSE,
};
use crate::config::{HgcaConfig, ModelConfig};
use crate::kv::{GpuBlockPool, KvManager, PrefixCache, PrefixStats, TierMode, TierPolicy};
use crate::metrics::{Metrics, Timer};
use crate::model::Sampler;
use crate::runtime::{Executor, ModelRuntime};
use crate::simulator::Testbed;
use crate::topology::{NodeId, Topology};
use crate::util::rng::Rng;

use super::strategy::Policy;

/// One in-flight sequence.
pub struct Sequence {
    /// Caller-assigned id (echoed in completions / token events).
    pub id: u64,
    /// All known tokens: prompt + generated; entries past `processed` are
    /// pending (not yet absorbed into the KV caches).
    pub tokens: Vec<u8>,
    /// Per-layer GPU window + CPU store for this sequence.
    pub kv: KvManager,
    /// tokens already absorbed into the KV cache
    pub processed: usize,
}

impl Sequence {
    /// A fresh sequence holding `prompt` as pending tokens (flat
    /// single-node placement).
    pub fn new(id: u64, prompt: &[u8], model: &ModelConfig, cfg: &HgcaConfig) -> Sequence {
        Sequence::new_on(id, prompt, model, cfg, &Topology::single(), 0)
    }

    /// [`Sequence::new`] **placed on `node`** of `topo`: the KV manager
    /// anchors its head shard map there and the scheduler leases the GPU
    /// window blocks from that node's budget, so the sequence's CPU jobs
    /// and GPU lease share a memory domain end to end.
    pub fn new_on(
        id: u64,
        prompt: &[u8],
        model: &ModelConfig,
        cfg: &HgcaConfig,
        topo: &Topology,
        node: NodeId,
    ) -> Sequence {
        Sequence {
            id,
            tokens: prompt.to_vec(),
            kv: KvManager::new_on(model, cfg, topo, node),
            processed: 0,
        }
    }

    /// Tokens absorbed so far across GPU window + CPU store.
    pub fn total_kv_entries(&self) -> usize {
        self.kv.seq_len
    }
}

/// The hybrid-attention inference engine (one model, any number of
/// sequences). Single-threaded by design: the engine thread owns the
/// runtime; parallelism lives below (the CPU attention pool) and above
/// (the continuous batcher admitting concurrent requests).
pub struct Engine<'m> {
    /// Model runtime (compiled artifacts + weights).
    pub mr: &'m ModelRuntime,
    /// HGCA tunables (window, chunk, β, thread caps…).
    pub cfg: HgcaConfig,
    /// Attention placement policy (HGCA or a paper baseline).
    pub policy: Policy,
    /// Simulated-hardware cost model (the paper's testbed).
    pub testbed: Testbed,
    /// Token sampler (greedy by default — the determinism tests rely on it).
    pub sampler: Sampler,
    /// Serving counters (throughput, TBT, memory peaks, prefill chunks…).
    pub metrics: Metrics,
    /// Sampler randomness (unused by greedy).
    pub rng: Rng,
    /// GPU KV block pool: every sequence leases its window blocks here
    /// ([`Engine::new_sequence`] force-leases, [`Engine::try_new_sequence`]
    /// is capacity-gated) and returns them when it drops (normal retire or
    /// lifecycle cancellation), so reclamation is observable
    /// (`kv_blocks_in_use` / `kv_blocks_reclaimed` on `/v1/metrics`).
    /// Unbounded by default; the serving loop bounds it via
    /// [`Engine::set_kv_block_capacity`] / [`Engine::set_kv_node_budgets`]
    /// so admission gates on actual KV availability.
    pub kv_pool: Arc<GpuBlockPool>,
    /// NUMA execution domains this engine places sequences over: the
    /// home-node choice at admission, the per-head shard maps, and the
    /// per-node KV budgets all derive from it. Defaults to the flat
    /// single-node topology (standalone engines behave exactly as before
    /// the NUMA refactor); `hgca serve` sets it from `--numa-nodes` /
    /// detection via [`Engine::set_topology`].
    pub topology: Topology,
    /// Overlap the CPU-sparse attention with the per-layer KV bookkeeping
    /// (the paper's headline GPU∥CPU parallelism): gather + submit the
    /// sparse jobs right after the dense artifact returns, run the serial
    /// append/MAW/eviction bookkeeping while pool workers crunch, then
    /// wait + merge. `false` forces the pre-overlap sequential order
    /// (submit, wait, then bookkeeping) — bitwise identical tokens either
    /// way (the conformance suite pins this); the toggle exists for A/B
    /// benchmarking and as the bisection lever.
    pub overlap_cpu_attn: bool,
    /// Cross-request prefix KV cache (radix trie over chunk-aligned token
    /// prefixes, `kv/prefix_cache.rs`). `None` — the default — means
    /// admission and prefill behave exactly as before the cache existed.
    /// Enabled by [`Engine::enable_prefix_cache`] (`hgca serve
    /// --prefix-cache`); the batcher then admits through
    /// [`Engine::try_new_sequence_cached`] and feeds snapshots back via
    /// [`Engine::cache_prefix`] after each prefill chunk.
    prefix: Option<PrefixCache>,
    /// scratch: batch window staging buffers, reused across steps
    k_win: Vec<f32>,
    v_win: Vec<f32>,
}

impl<'m> Engine<'m> {
    /// An engine over `mr` with the paper testbed, greedy sampling, and
    /// fresh metrics.
    pub fn new(mr: &'m ModelRuntime, cfg: HgcaConfig, policy: Policy) -> Engine<'m> {
        Engine {
            mr,
            cfg,
            policy,
            testbed: Testbed::paper(),
            sampler: Sampler::Greedy,
            metrics: Metrics::new(),
            rng: Rng::new(0x48474341),
            kv_pool: Arc::new(GpuBlockPool::new()),
            topology: Topology::single(),
            overlap_cpu_attn: true,
            prefix: None,
            k_win: Vec::new(),
            v_win: Vec::new(),
        }
    }

    /// The model configuration this engine serves.
    pub fn model(&self) -> &ModelConfig {
        &self.mr.cfg
    }

    /// Smallest compiled attention window that fits the logical window.
    fn artifact_window(&self) -> Result<usize> {
        let lw = self.cfg.window();
        let windows = self.mr.rt.manifest.windows_for(&self.mr.cfg.name);
        windows
            .iter()
            .copied()
            .find(|&w| w >= lw)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no compiled attention window ≥ {lw} for model {} (compiled: {windows:?})",
                    self.mr.cfg.name
                )
            })
    }

    /// GPU KV blocks one sequence of this engine leases
    /// (`n_layers × blk_num`) — the admission currency when
    /// [`Engine::kv_pool`] is capacity-bounded.
    pub fn blocks_per_sequence(&self) -> usize {
        self.mr.cfg.n_layers * self.cfg.blk_num
    }

    /// Replace [`Engine::kv_pool`] with a fresh pool of the given hard
    /// capacity (`None` = unbounded accounting-only pool, the
    /// [`Engine::new`] default). Call **before** any sequence exists:
    /// leases already outstanding keep their original pool alive and
    /// return to it, so they would be invisible to the new pool's
    /// accounting. The serving loop applies the configured capacity here
    /// at startup (see [`crate::config::ServingConfig::effective_kv_blocks`]).
    pub fn set_kv_block_capacity(&mut self, capacity: Option<usize>) {
        self.kv_pool = Arc::new(match capacity {
            Some(blocks) => GpuBlockPool::with_capacity(blocks),
            None => GpuBlockPool::new(),
        });
        self.rebind_prefix_cache();
    }

    /// Replace [`Engine::kv_pool`] with a fresh pool whose capacity is
    /// split into **per-node budgets** (`budgets[i]` blocks on node `i` —
    /// normally [`crate::config::ServingConfig::effective_node_budgets`]
    /// over [`Engine::topology`]). Same before-any-sequence caveat as
    /// [`Engine::set_kv_block_capacity`]; a one-element budget list is
    /// exactly that method.
    pub fn set_kv_node_budgets(&mut self, budgets: Vec<usize>) {
        self.kv_pool = Arc::new(GpuBlockPool::with_node_budgets(budgets));
        self.rebind_prefix_cache();
    }

    /// Re-create an enabled prefix cache against the current pool (the
    /// pool-replacing setters above call this so cached entries never hold
    /// leases against a retired pool).
    fn rebind_prefix_cache(&mut self) {
        if let Some(cache) = self.prefix.take() {
            self.prefix = Some(PrefixCache::new(
                Arc::clone(&self.kv_pool),
                self.cfg.chunk,
                cache.max_entries(),
            ));
        }
    }

    /// Turn on cross-request prefix KV reuse: admissions through
    /// [`Engine::try_new_sequence_cached`] consult a radix cache of up to
    /// `max_entries` chunk-aligned prefix snapshots before re-running
    /// prefill chunks. Call after the pool is bounded
    /// ([`Engine::set_kv_node_budgets`]) — the cache leases its entry
    /// storage from [`Engine::kv_pool`].
    pub fn enable_prefix_cache(&mut self, max_entries: usize) {
        self.prefix = Some(PrefixCache::new(
            Arc::clone(&self.kv_pool),
            self.cfg.chunk,
            max_entries,
        ));
    }

    /// Whether cross-request prefix reuse is on.
    pub fn prefix_cache_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Prefix-cache counters (all-zero when the cache is disabled — the
    /// metrics endpoint emits them unconditionally so the schema is stable).
    pub fn prefix_stats(&self) -> PrefixStats {
        self.prefix.as_ref().map(PrefixCache::stats).unwrap_or_default()
    }

    /// Set the NUMA topology sequences are placed over. Call **before**
    /// any sequence exists (placement is recorded per sequence at
    /// construction) and pair with matching pool budgets
    /// ([`Engine::set_kv_node_budgets`]).
    pub fn set_topology(&mut self, topology: Topology) {
        self.topology = topology;
    }

    /// A fresh [`Sequence`] sized for this engine's model + config, with
    /// its GPU window blocks force-leased from [`Engine::kv_pool`]
    /// (bypasses any capacity bound — standalone generation paths; placed
    /// on node 0). Capacity-gated, placement-aware admission uses
    /// [`Engine::try_new_sequence`].
    pub fn new_sequence(&self, id: u64, prompt: &[u8]) -> Sequence {
        let mut seq = Sequence::new_on(id, prompt, &self.mr.cfg, &self.cfg, &self.topology, 0);
        seq.kv.lease_from(&self.kv_pool);
        seq
    }

    /// [`Engine::new_sequence`] gated on KV availability and
    /// **placement-aware**: the blocks are acquired *first* via the
    /// pool's placement-resolving [`GpuBlockPool::try_acquire`] (the
    /// least-loaded node whose budget holds the whole lease, deterministic
    /// tie-break by node id), and `None` is returned — nothing allocated —
    /// when no node currently fits them. The sequence is then built **on
    /// the lease's node**: its head shard map and its GPU lease share the
    /// memory domain end to end. This is the batcher's admission path: a
    /// request whose blocks don't fit anywhere waits in the queue instead
    /// of joining the batch.
    pub fn try_new_sequence(&self, id: u64, prompt: &[u8]) -> Option<Sequence> {
        // the pool's placement-resolving acquire retries internally, so a
        // concurrent acquirer racing the picked node away cannot turn a
        // still-placeable request into a spurious deferral
        let lease = self.kv_pool.try_acquire(self.blocks_per_sequence())?;
        let node = lease.node();
        let mut seq = Sequence::new_on(id, prompt, &self.mr.cfg, &self.cfg, &self.topology, node);
        seq.kv.attach_lease(lease);
        Some(seq)
    }

    /// [`Engine::try_new_sequence`] with cross-request prefix reuse (the
    /// batcher's admission path once `--prefix-cache` is on; identical to
    /// it when the cache is disabled). Two cache interactions:
    ///
    /// 1. **LRU vs capacity**: if the sequence lease doesn't fit, cached
    ///    entries are LRU-evicted to make room and the acquire retried —
    ///    live admission always outbids cached prefixes
    ///    (docs/SCHEDULING.md). Still `None` when even an empty cache
    ///    can't free enough.
    /// 2. **Adoption**: the longest cached chunk-aligned prefix of
    ///    `prompt` (strictly shorter than it) seeds the sequence's KV —
    ///    re-anchored to the lease's node (placement metadata only, so
    ///    tokens stay bitwise-identical to a cold prefill) with
    ///    `processed` advanced past the adopted tokens, so prefill resumes
    ///    at the first un-cached chunk.
    pub fn try_new_sequence_cached(&mut self, id: u64, prompt: &[u8]) -> Option<Sequence> {
        if self.prefix.is_none() {
            return self.try_new_sequence(id, prompt);
        }
        let blocks = self.blocks_per_sequence();
        let lease = match self.kv_pool.try_acquire(blocks) {
            Some(l) => l,
            None => {
                let cache = self.prefix.as_mut().expect("checked above");
                if cache.evict_for_blocks(blocks) == 0 {
                    return None; // nothing cached to reclaim — defer
                }
                self.kv_pool.try_acquire(blocks)?
            }
        };
        let node = lease.node();
        let cache = self.prefix.as_mut().expect("checked above");
        match cache.lookup(prompt) {
            Some((prefix_len, mut kv)) => {
                kv.reanchor(&self.topology, node);
                kv.attach_lease(lease);
                Some(Sequence {
                    id,
                    tokens: prompt.to_vec(),
                    kv,
                    processed: prefix_len,
                })
            }
            None => {
                let mut seq =
                    Sequence::new_on(id, prompt, &self.mr.cfg, &self.cfg, &self.topology, node);
                seq.kv.attach_lease(lease);
                Some(seq)
            }
        }
    }

    /// Offer a mid-prefill sequence's KV state to the prefix cache (the
    /// batcher calls this after every prefill chunk). No-op unless the
    /// cache is on and the state is adoptable: chunk-aligned, nonzero, and
    /// strictly inside the prompt (the final chunk's state is never cached
    /// — adopters must run it themselves to get first-token logits).
    pub fn cache_prefix(&mut self, seq: &Sequence) {
        let Some(cache) = self.prefix.as_mut() else {
            return;
        };
        let p = seq.processed;
        if p == 0 || p % self.cfg.chunk != 0 || p >= seq.tokens.len() {
            return;
        }
        cache.insert(&seq.tokens, p, &seq.kv);
    }

    // ------------------------------------------------------------------
    // core step: process `n` already-known tokens per active sequence
    // (decode: n = 1 new token; prefill chunk: n = cfg.chunk) and return
    // the logits of the last position per row.
    // ------------------------------------------------------------------
    fn step(
        &mut self,
        seqs: &mut [&mut Sequence],
        batch: usize,
        n: usize,
        need_logits: bool,
    ) -> Result<Vec<Vec<f32>>> {
        let valid: Vec<usize> = seqs.iter().map(|_| n).collect();
        self.step_masked(seqs, batch, n, &valid, need_logits)
    }

    /// `step` with per-row valid token counts: rows may carry fewer than
    /// `n` real tokens (chunk padding); padded query rows are inert in the
    /// artifact (n_valid mask) and never appended to the caches.
    fn step_masked(
        &mut self,
        seqs: &mut [&mut Sequence],
        batch: usize,
        n: usize,
        valid: &[usize],
        need_logits: bool,
    ) -> Result<Vec<Vec<f32>>> {
        let model = self.mr.cfg.clone();
        let (h_n, dh, d) = (model.n_heads, model.d_head(), model.d_model);
        // logical window (eviction capacity) vs compiled artifact window:
        // the artifact buffer may be larger; win_len masks the unused tail.
        let lw = self.cfg.window();
        let w = self.artifact_window()?;
        let nactive = seqs.len();
        assert!(nactive <= batch);
        let is_append = n > 1;
        let wall = Timer::start();
        let mut sim_secs = 0.0f64;

        // ---- token + position staging (padded rows repeat pos 0/token 0) ----
        let mut tokens = vec![0i32; batch * n];
        let mut positions = vec![0i32; batch * n];
        for (b, seq) in seqs.iter().enumerate() {
            for i in 0..valid[b] {
                let p = seq.processed + i;
                tokens[b * n + i] = seq.tokens[p] as i32;
                positions[b * n + i] = p as i32;
            }
        }

        let exec = Executor::new(self.mr);
        let mut hidden = exec.embed(batch, n, &tokens, &positions)?;

        // ---- per-layer hybrid attention ----
        let s_total = w + n;
        self.k_win.resize(batch * h_n * w * dh, 0.0);
        self.v_win.resize(batch * h_n * w * dh, 0.0);
        // per-job NUMA node map for the CPU-side dispatch (the sequences'
        // head shard maps + node-0 padding rows): layer-invariant, so build
        // it once for the whole step instead of once per layer
        let job_nodes: Vec<NodeId> = if self.policy.uses_cpu_side() {
            let mut map = Vec::with_capacity(batch * h_n);
            for seq in seqs.iter() {
                map.extend_from_slice(seq.kv.shard());
            }
            map.resize(batch * h_n, 0);
            map
        } else {
            Vec::new()
        };
        for li in 0..model.n_layers {
            // eviction (Algorithm 1 lines 10–14) + window staging
            let mut win_len = vec![0i32; batch];
            let mut prior_len = vec![0usize; batch];
            for (b, seq) in seqs.iter_mut().enumerate() {
                if matches!(self.policy, Policy::GpuOnly) {
                    if seq.kv.layers[li].gpu.blocks_to_evict(valid[b]) > 0 {
                        bail!(
                            "OOM: sequence {} exceeds GPU KV window ({} entries) under gpu-only \
                             policy",
                            seq.id,
                            self.cfg.window()
                        );
                    }
                } else {
                    // a chunk larger than the logical window sends its
                    // oldest (v - lw) entries straight to the CPU store
                    seq.kv.make_room(li, valid[b].min(lw));
                }
                let gpu = &seq.kv.layers[li].gpu;
                let len = gpu.len;
                prior_len[b] = len;
                win_len[b] = len as i32;
                // per-head strided copy: cache rows are lw-wide, the
                // artifact buffer is w-wide (w ≥ lw; tail is masked)
                let row = b * h_n * w * dh;
                for h in 0..h_n {
                    let src = h * lw * dh;
                    let dst = row + h * w * dh;
                    self.k_win[dst..dst + lw * dh].copy_from_slice(&gpu.k[src..src + lw * dh]);
                    self.v_win[dst..dst + lw * dh].copy_from_slice(&gpu.v[src..src + lw * dh]);
                }
            }

            let mut n_valid = vec![0i32; batch];
            for (b, &v) in valid.iter().enumerate() {
                n_valid[b] = v as i32;
            }
            let mut out = exec.attn_step(
                li, batch, w, n, &hidden, &self.k_win, &self.v_win, &win_len, &n_valid,
            )?;

            // ---- CPU-side gather + non-blocking submit (Algorithm 2
            // lines 6–7, 11–12), overlapped with the bookkeeping below ----
            // Conformance argument: the gather snapshots the CPU store
            // BEFORE any of this layer's bookkeeping mutates caches — the
            // append/MAW loop below touches only the GPU window, and this
            // chunk's overflow reaches the store only after wait() (the
            // deferred drain) — so submitting here is bitwise identical to
            // the old gather-after-bookkeeping order (identical merge
            // inputs); it just stops serializing the two sides.
            let mut pending: Option<(PendingAttn, Timer)> = None;
            let mut cpu_done: Option<(CpuAttnOutput, f64)> = None;
            let mut cpu_jobs = 0u64;
            let mut sel_total = 0usize;
            // the tiered submission path only engages for HGCA with a
            // non-default --kv-tier; every other combination runs the f32
            // path below, literally unchanged
            let kv_tiered =
                self.cfg.kv_tier != TierMode::F32 && matches!(self.policy, Policy::Hgca { .. });
            if self.policy.uses_cpu_side() && !kv_tiered {
                // per-(row, head) jobs; on append attend the FULL store so
                // re-evaluation sees complete scores (§3.2.2). `job_nodes`
                // (built once above) aligns with this gather: the pool
                // dispatches each packed task to the queue owning its
                // slabs — placement only, never numerics
                let mut gathered: Vec<(Vec<f32>, Vec<f32>, usize)> =
                    Vec::with_capacity(batch * h_n);
                for seq in seqs.iter() {
                    let store = &seq.kv.layers[li].cpu;
                    let g = if is_append && !store.is_empty() {
                        Policy::FullOffload.gather_jobs(store, seq.kv.seq_len)
                    } else {
                        self.policy.gather_jobs(store, seq.kv.seq_len)
                    };
                    debug_assert_eq!(g.len(), h_n);
                    gathered.extend(g);
                }
                for _ in nactive..batch {
                    for _ in 0..h_n {
                        gathered.push((Vec::new(), Vec::new(), 0));
                    }
                }
                cpu_jobs = gathered.len() as u64;
                sel_total = gathered.iter().map(|(_, _, cnt)| *cnt).sum();
                let mut q_valid = Vec::with_capacity(gathered.len());
                for b in 0..batch {
                    let v = if b < nactive { valid[b] } else { 0 };
                    for _ in 0..h_n {
                        q_valid.push(v);
                    }
                }
                // append re-evaluation (or a full-offload-style decode)
                // spans the FULL store per head: size the task split by
                // store length, not the decode parallelism cap
                let split = if is_append || self.policy.decode_attends_full_store() {
                    TaskSplit::ByEntries {
                        per_task: self.cfg.append_entries_per_task,
                        max_tasks: self.cfg.cpu_threads.saturating_mul(4).max(1),
                    }
                } else {
                    TaskSplit::EvenJobs { max_parallel: self.cfg.cpu_threads }
                };
                // one pool submission carries every active sequence's jobs
                // for this layer (continuous batching: cross-request work
                // is fused, then split back per sequence by the LSE merge).
                // The gathered KV copies and the artifact's q MOVE into
                // the submission's owned storage — no re-copies
                let input = OwnedJobs {
                    kvs: gathered,
                    q: std::mem::take(&mut out.q),
                    q_valid: Some(q_valid),
                };
                let t = Timer::start();
                let p = AttnPool::global()
                    .submit_placed(input, n, dh, split, is_append, Some(&job_nodes));
                if self.overlap_cpu_attn {
                    // pool workers crunch the sparse jobs while this
                    // thread runs the serial KV bookkeeping below
                    pending = Some((p, t));
                } else {
                    // forced-sequential reference path: finish the sparse
                    // side before bookkeeping (the pre-overlap engine)
                    let done = p.wait();
                    let secs = t.secs();
                    cpu_done = Some((done, secs));
                }
            } else if self.policy.uses_cpu_side() {
                // tiered twin of the block above: the gather hands each
                // head's payload in its stored form — f32 copies, or the
                // int8 slabs themselves (bytes + scales move, nothing is
                // dequantized host-side). Same placement, same TaskSplit
                // selection (packing reads only entry counts), same
                // LSE-merge contract downstream.
                let mut gathered: Vec<JobPayload> = Vec::with_capacity(batch * h_n);
                for seq in seqs.iter() {
                    let store = &seq.kv.layers[li].cpu;
                    let full = is_append && !store.is_empty();
                    let g = self.policy.gather_payloads(store, seq.kv.seq_len, full);
                    debug_assert_eq!(g.len(), h_n);
                    gathered.extend(g);
                }
                for _ in nactive..batch {
                    for _ in 0..h_n {
                        gathered.push(JobPayload::F32(Vec::new(), Vec::new(), 0));
                    }
                }
                cpu_jobs = gathered.len() as u64;
                sel_total = gathered.iter().map(JobPayload::n).sum();
                let mut q_valid = Vec::with_capacity(gathered.len());
                for b in 0..batch {
                    let v = if b < nactive { valid[b] } else { 0 };
                    for _ in 0..h_n {
                        q_valid.push(v);
                    }
                }
                let split = if is_append || self.policy.decode_attends_full_store() {
                    TaskSplit::ByEntries {
                        per_task: self.cfg.append_entries_per_task,
                        max_tasks: self.cfg.cpu_threads.saturating_mul(4).max(1),
                    }
                } else {
                    TaskSplit::EvenJobs { max_parallel: self.cfg.cpu_threads }
                };
                let input = OwnedTieredJobs {
                    kvs: gathered,
                    q: std::mem::take(&mut out.q),
                    q_valid: Some(q_valid),
                };
                let t = Timer::start();
                let p = AttnPool::global()
                    .submit_tiered(input, n, dh, split, is_append, Some(&job_nodes));
                if self.overlap_cpu_attn {
                    pending = Some((p, t));
                } else {
                    let done = p.wait();
                    let secs = t.secs();
                    cpu_done = Some((done, secs));
                }
            }

            // append new KV + MAW update per row; chunk entries beyond the
            // logical window overflow into the CPU store — but only AFTER
            // this step's CPU attention (they were already attended inside
            // the artifact as causal chunk slots; adding them first would
            // double-count them in the LSE merge).
            let mut deferred: Vec<(usize, crate::kv::KvBlock)> = Vec::new();
            for (b, seq) in seqs.iter_mut().enumerate() {
                let v_cnt = valid[b];
                if v_cnt == 0 {
                    continue;
                }
                let overflow = v_cnt.saturating_sub(lw);
                let row = b * h_n * n * dh;
                let k_new = &out.k_new[row..row + h_n * n * dh];
                let v_new = &out.v_new[row..row + h_n * n * dh];
                let arow = &out.a_sum[b * h_n * s_total..(b + 1) * h_n * s_total];
                if overflow > 0 {
                    // package the oldest `overflow` entries as an evicted
                    // block, with their first-observed attention mass as MAW
                    let mut blk = crate::kv::KvBlock::new(h_n, dh, overflow);
                    for h in 0..h_n {
                        let src = (h * n) * dh;
                        blk.k[h * overflow * dh..(h + 1) * overflow * dh]
                            .copy_from_slice(&k_new[src..src + overflow * dh]);
                        blk.v[h * overflow * dh..(h + 1) * overflow * dh]
                            .copy_from_slice(&v_new[src..src + overflow * dh]);
                        for t in 0..overflow {
                            blk.maw[h * overflow + t] =
                                arow[h * s_total + (s_total - n) + t] / v_cnt as f32;
                        }
                    }
                    for (t, p) in blk.pos.iter_mut().enumerate() {
                        *p = seq.processed + t;
                    }
                    deferred.push((b, blk));
                    // append the surviving tail [overflow..v_cnt) per head
                    let keep = v_cnt - overflow;
                    let mut kk = vec![0.0f32; h_n * keep * dh];
                    let mut vv = vec![0.0f32; h_n * keep * dh];
                    for h in 0..h_n {
                        let src = (h * n + overflow) * dh;
                        kk[h * keep * dh..(h + 1) * keep * dh]
                            .copy_from_slice(&k_new[src..src + keep * dh]);
                        vv[h * keep * dh..(h + 1) * keep * dh]
                            .copy_from_slice(&v_new[src..src + keep * dh]);
                    }
                    let pos: Vec<usize> =
                        (seq.processed + overflow..seq.processed + v_cnt).collect();
                    seq.kv.append(li, &kk, &vv, &pos);
                    // compact a_sum: window prefix + the kept new slots
                    let compact = compact_asum(arow, h_n, s_total, prior_len[b], n, overflow, keep);
                    seq.kv.layers[li].gpu.update_maw(
                        &compact,
                        prior_len[b] + keep,
                        prior_len[b],
                        keep,
                        v_cnt,
                    );
                } else if v_cnt == n {
                    let pos: Vec<usize> = (seq.processed..seq.processed + n).collect();
                    seq.kv.append(li, k_new, v_new, &pos);
                    seq.kv.layers[li]
                        .gpu
                        .update_maw(arow, s_total, prior_len[b], n, n);
                } else {
                    // padded chunk: append only the v_cnt valid entries
                    let mut kk = vec![0.0f32; h_n * v_cnt * dh];
                    let mut vv = vec![0.0f32; h_n * v_cnt * dh];
                    for h in 0..h_n {
                        let src = (h * n) * dh;
                        kk[h * v_cnt * dh..(h + 1) * v_cnt * dh]
                            .copy_from_slice(&k_new[src..src + v_cnt * dh]);
                        vv[h * v_cnt * dh..(h + 1) * v_cnt * dh]
                            .copy_from_slice(&v_new[src..src + v_cnt * dh]);
                    }
                    let pos: Vec<usize> = (seq.processed..seq.processed + v_cnt).collect();
                    seq.kv.append(li, &kk, &vv, &pos);
                    let compact = compact_asum(arow, h_n, s_total, prior_len[b], n, 0, v_cnt);
                    seq.kv.layers[li].gpu.update_maw(
                        &compact,
                        prior_len[b] + v_cnt,
                        prior_len[b],
                        v_cnt,
                        v_cnt,
                    );
                }
            }

            // ---- wait for the sparse side, then merge (Algorithm 2 line 13) ----
            let mut o_gpu = out.o_gpu;
            let mut lse_gpu = out.lse;
            if self.policy.uses_cpu_side() {
                let (cpu_out, wait_secs, book_secs) = match cpu_done {
                    // forced-sequential: the sparse side already completed
                    // before the bookkeeping — nothing was hidden
                    Some((done, secs)) => (done, secs, 0.0),
                    None => {
                        let (p, t) = pending.take().expect("cpu-side submission in flight");
                        // time the submission has had to itself so far ==
                        // the serial bookkeeping span hidden under sparse
                        // execution (the overlap win)
                        let book = t.secs();
                        let done = p.wait();
                        (done, t.secs(), book)
                    }
                };
                self.metrics.observe_cpu_attn(
                    wait_secs,
                    cpu_out.busy_secs,
                    cpu_jobs,
                    cpu_out.tasks as u64,
                );
                self.metrics.observe_cpu_attn_overlap(book_secs);

                merge_states(&mut o_gpu, &mut lse_gpu, &cpu_out.o, &cpu_out.lse, dh);

                // append-time re-evaluation (Algorithm 1 lines 19–22)
                if is_append {
                    if let (Policy::Hgca { beta }, Some(probs)) = (&self.policy, &cpu_out.probs) {
                        let beta = *beta;
                        for (b, seq) in seqs.iter_mut().enumerate() {
                            let store = &mut seq.kv.layers[li].cpu;
                            let cnt = store.len();
                            if cnt == 0 {
                                continue;
                            }
                            let mut a_cpu = vec![0.0f32; h_n * cnt];
                            let qn = valid[b].max(1) as f32;
                            for h in 0..h_n {
                                let p = &probs[b * h_n + h];
                                for (i, &m) in p.iter().enumerate() {
                                    a_cpu[h * cnt + i] = m / qn;
                                }
                            }
                            store.reevaluate(&a_cpu, beta);
                        }
                    }
                }
                // flush this chunk's overflow into the CPU store (with
                // evict-time selection) now that attention is complete
                for (b, blk) in deferred.drain(..) {
                    let beta = self.cfg.beta;
                    let denom = lw;
                    seqs[b].kv.layers[li].cpu.add_evicted(&blk, beta, denom);
                    seqs[b].kv.evict_bytes += blk_bytes(&blk);
                }
                // tier selection rides the eviction path: re-decide per
                // head now that new entries (and refreshed MAW) are in the
                // store — the one-way ratchet means this only tightens
                if kv_tiered {
                    let tp = TierPolicy::new(self.cfg.kv_tier);
                    for seq in seqs.iter_mut() {
                        let store = &mut seq.kv.layers[li].cpu;
                        if !store.is_empty() {
                            tp.apply(store);
                        }
                    }
                }
                // H2O/Static: discard unselected permanently
                if self.policy.discards_unselected() {
                    for seq in seqs.iter_mut() {
                        let store = &mut seq.kv.layers[li].cpu;
                        if !store.is_empty() {
                            prune_store(store, &self.policy, seqs_len_hint(store));
                        }
                    }
                }
                // simulated time for this layer (per the active policy)
                let (n_win, n_cpu, n_sel) = kv_sizes(seqs, li, sel_total, h_n);
                let (t, _) = self.policy.sim_attention(
                    &self.testbed,
                    &model,
                    nactive.max(1),
                    n,
                    n_win,
                    n_cpu,
                    n_sel,
                );
                sim_secs += t;
            } else {
                for (b, blk) in deferred.drain(..) {
                    let beta = self.cfg.beta;
                    seqs[b].kv.layers[li].cpu.add_evicted(&blk, beta, lw);
                }
                let n_win = seqs.iter().map(|s| s.kv.window_len(li)).max().unwrap_or(0);
                let (t, _) = self.policy.sim_attention(
                    &self.testbed,
                    &model,
                    nactive.max(1),
                    n,
                    n_win,
                    0,
                    0,
                );
                sim_secs += t;
            }

            // lse values for fully-empty rows (padding) stay EMPTY; their
            // o is zero — harmless, rows are masked out at sampling.
            debug_assert!(lse_gpu.iter().all(|l| l.is_finite() || *l <= EMPTY_LSE));

            // o layout [B,H,N,dh] → o_merged [B,N,D]: for n=1 this is a
            // straight copy; for chunks transpose (H, N).
            let o_merged = heads_to_flat(&o_gpu, batch, h_n, n, dh);
            hidden = exec.post_attn(li, batch, n, &hidden, &o_merged)?;
            let _ = d;
        }

        // per-step weight-streaming cost (shared by every policy)
        sim_secs += self
            .testbed
            .decode_step_weights(&model, nactive.max(1), 1.0)
            .total()
            * if is_append { n as f64 } else { 1.0 };

        for (b, seq) in seqs.iter_mut().enumerate() {
            seq.processed += valid[b];
            seq.kv.advance(valid[b]);
        }

        // memory + timing bookkeeping
        let gpu_b: usize = seqs.iter().map(|s| s.kv.gpu_bytes()).sum();
        let cpu_b: usize = seqs.iter().map(|s| s.kv.cpu_bytes()).sum();
        self.metrics.observe_memory(gpu_b, cpu_b);
        let (mut t_f32, mut t_int8, mut t_win, mut saved) = (0u64, 0u64, 0u64, 0u64);
        for seq in seqs.iter() {
            for layer in &seq.kv.layers {
                let (f, i, w) = layer.cpu.tier_counts();
                t_f32 += f as u64;
                t_int8 += i as u64;
                t_win += w as u64;
                saved += layer.cpu.quant_bytes_saved();
            }
        }
        self.metrics.observe_kv_tiers(t_f32, t_int8, t_win, saved);
        self.metrics
            .record_step(wall.secs(), sim_secs, if is_append { 0 } else { nactive as u64 });

        if need_logits {
            // logits only needed at the last *valid* position per row
            let last = slice_last_valid(&hidden, batch, n, self.mr.cfg.d_model, valid);
            let logits = exec.lm_head(batch, &last)?;
            let v = self.mr.cfg.vocab;
            Ok((0..nactive)
                .map(|b| logits[b * v..(b + 1) * v].to_vec())
                .collect())
        } else {
            Ok(Vec::new())
        }
    }

    /// Absorb **one chunk** (at most `cfg.chunk` tokens) of the sequence's
    /// pending tokens into the KV cache. This is the scheduling granule of
    /// chunked prefill: the continuous batcher calls it between decode
    /// ticks so a long prompt admission never stalls running sequences.
    ///
    /// Returns `Some(logits)` of the last valid position once the final
    /// pending token has been absorbed (`None` while chunks remain). A
    /// sequence with nothing pending returns `Some(empty)` without running
    /// a step. One call is one artifact step — splitting a prefill across
    /// calls is bitwise identical to running [`Engine::prefill`] in one go,
    /// and steps for *other* sequences in between do not perturb it (no
    /// cross-sequence state below the engine API).
    pub fn prefill_step(&mut self, seq: &mut Sequence) -> Result<Option<Vec<f32>>> {
        if seq.processed >= seq.tokens.len() {
            return Ok(Some(Vec::new()));
        }
        let chunk = self.cfg.chunk;
        let remaining = seq.tokens.len() - seq.processed;
        let need = remaining <= chunk;
        let out = if remaining == 1 {
            self.step(&mut [seq], 1, 1, need)?
        } else {
            // padded chunk: one artifact call regardless of remainder
            let v = remaining.min(chunk);
            self.step_masked(&mut [seq], 1, chunk, &[v], need)?
        };
        self.metrics.prefill_tokens += remaining.min(chunk) as u64;
        self.metrics.prefill_chunks += 1;
        if need {
            Ok(Some(out.into_iter().next().unwrap_or_default()))
        } else {
            Ok(None)
        }
    }

    /// Absorb a sequence's pending tokens (prompt or forced text) into the
    /// KV cache: full chunks via the append artifact, remainder token-wise.
    /// Returns last-position logits when the caller needs them.
    pub fn prefill(&mut self, seq: &mut Sequence) -> Result<Vec<f32>> {
        let mut logits = Vec::new();
        while seq.processed < seq.tokens.len() {
            if let Some(l) = self.prefill_step(seq)? {
                logits = l;
            }
        }
        Ok(logits)
    }

    /// One batched decode step. `forced` supplies the *input* token per row
    /// (teacher forcing); with `None`, each row consumes its one pending
    /// token (appended by the previous sample) and a new token is sampled
    /// from the produced logits. Returns (id, token, logits-of-next).
    pub fn decode_step(
        &mut self,
        seqs: &mut [&mut Sequence],
        batch: usize,
        forced: Option<&[u8]>,
    ) -> Result<Vec<(u64, u8, Vec<f32>)>> {
        if let Some(f) = forced {
            for (b, seq) in seqs.iter_mut().enumerate() {
                anyhow::ensure!(
                    seq.processed == seq.tokens.len(),
                    "forced decode with pending tokens on sequence {}",
                    seq.id
                );
                seq.tokens.push(f[b]);
            }
        }
        for seq in seqs.iter() {
            anyhow::ensure!(
                seq.processed + 1 == seq.tokens.len(),
                "decode_step needs exactly one pending token (seq {}: {} processed, {} total)",
                seq.id,
                seq.processed,
                seq.tokens.len()
            );
        }
        let logits = self.step(seqs, batch, 1, true)?;
        let mut out = Vec::with_capacity(seqs.len());
        for (b, seq) in seqs.iter_mut().enumerate() {
            let tok = match forced {
                Some(f) => f[b],
                None => {
                    let next = self.sampler.sample(&logits[b], &mut self.rng);
                    seq.tokens.push(next);
                    next
                }
            };
            out.push((seq.id, tok, logits[b].clone()));
        }
        Ok(out)
    }

    /// Generate `n_new` tokens for one sequence (prefill + decode loop).
    pub fn generate(&mut self, seq: &mut Sequence, n_new: usize) -> Result<Vec<u8>> {
        if seq.processed < seq.tokens.len() {
            let logits = self.prefill(seq)?;
            if !logits.is_empty() && n_new > 0 {
                let next = self.sampler.sample(&logits, &mut self.rng);
                seq.tokens.push(next);
            }
        }
        let mut out: Vec<u8> = seq.tokens[seq.processed.min(seq.tokens.len())..].to_vec();
        while out.len() < n_new {
            let step = self.decode_step(&mut [seq], 1, None)?;
            out.push(step[0].1);
        }
        out.truncate(n_new);
        Ok(out)
    }

    /// Teacher-forced perplexity of `text` under this engine's policy —
    /// the Table 1 measurement (full generation path, not just token 1).
    /// The first `burn_in` positions are excluded (no context yet).
    pub fn perplexity(&mut self, text: &[u8], burn_in: usize) -> Result<f64> {
        anyhow::ensure!(text.len() >= burn_in + 2, "text too short");
        let mut seq = self.new_sequence(0, &text[..burn_in.max(1)]);
        let logits0 = self.prefill(&mut seq)?;
        let mut nll = 0.0f64;
        let mut count = 0usize;
        // logits0 predicts text[burn_in]
        nll -= crate::tensor::ops::log_softmax_at(&logits0, text[burn_in] as usize) as f64;
        count += 1;
        for t in burn_in..text.len() - 1 {
            let step = self.decode_step(&mut [&mut seq], 1, Some(&text[t..t + 1]))?;
            let logits = &step[0].2;
            nll -= crate::tensor::ops::log_softmax_at(logits, text[t + 1] as usize) as f64;
            count += 1;
        }
        Ok((nll / count as f64).exp())
    }
}

/// [B,H,N,dh] → [B,N,H*dh]
fn heads_to_flat(o: &[f32], batch: usize, h_n: usize, n: usize, dh: usize) -> Vec<f32> {
    if n == 1 {
        return o.to_vec(); // [B,H,1,dh] ≡ [B,1,H*dh]
    }
    let d = h_n * dh;
    let mut out = vec![0.0f32; batch * n * d];
    for b in 0..batch {
        for h in 0..h_n {
            for t in 0..n {
                let src = ((b * h_n + h) * n + t) * dh;
                let dst = (b * n + t) * d + h * dh;
                out[dst..dst + dh].copy_from_slice(&o[src..src + dh]);
            }
        }
    }
    out
}

/// hidden [B,N,D] → [B,1,D] taking the last valid position of each row.
fn slice_last_valid(hidden: &[f32], batch: usize, n: usize, d: usize, valid: &[usize]) -> Vec<f32> {
    if n == 1 {
        return hidden.to_vec();
    }
    let mut out = vec![0.0f32; batch * d];
    for b in 0..batch {
        let v = valid.get(b).copied().unwrap_or(n).max(1);
        let src = (b * n + (v - 1)) * d;
        out[b * d..(b + 1) * d].copy_from_slice(&hidden[src..src + d]);
    }
    out
}

fn blk_bytes(blk: &crate::kv::KvBlock) -> u64 {
    blk.size_bytes() as u64
}

/// Compact a_sum rows: window prefix [0..prior) + new slots
/// [S-n+skip .. S-n+skip+keep) into a contiguous [heads][prior+keep] buffer.
fn compact_asum(
    arow: &[f32],
    h_n: usize,
    s_total: usize,
    prior: usize,
    n: usize,
    skip: usize,
    keep: usize,
) -> Vec<f32> {
    let width = prior + keep;
    let mut out = vec![0.0f32; h_n * width];
    for h in 0..h_n {
        let src = &arow[h * s_total..(h + 1) * s_total];
        out[h * width..h * width + prior].copy_from_slice(&src[..prior]);
        let new0 = s_total - n + skip;
        out[h * width + prior..(h + 1) * width].copy_from_slice(&src[new0..new0 + keep]);
    }
    out
}

/// Per-layer KV sizes for the simulator. `sel_total` is the summed
/// selected-entry count across the layer's gathered jobs (the gather
/// itself has already moved into the pool submission by the time timing
/// runs, so the caller pre-computes the sum at gather time).
fn kv_sizes(
    seqs: &[&mut Sequence],
    li: usize,
    sel_total: usize,
    h_n: usize,
) -> (usize, usize, usize) {
    let n_win = seqs.iter().map(|s| s.kv.window_len(li)).max().unwrap_or(0);
    let n_cpu = seqs
        .iter()
        .map(|s| s.kv.layers[li].cpu.len())
        .max()
        .unwrap_or(0);
    // mean selected entries per head (rounded up)
    let denom = (seqs.len() * h_n).max(1);
    (n_win, n_cpu, sel_total.div_ceil(denom))
}

/// For H2O/Static: shrink the full store to the policy's selected set.
fn prune_store(store: &mut crate::kv::CpuLayerStore, policy: &Policy, seq_len: usize) {
    let dh = store.d_head;
    for h in 0..store.heads {
        let hs = &store.full[h];
        let sel: Vec<u32> = match policy {
            Policy::H2o { frac } => {
                use crate::sparse::{SelectInput, SparsePolicy, TopK};
                TopK::new(*frac).select(&SelectInput {
                    maw: &hs.maw,
                    pos: &hs.pos,
                    seq_len,
                })
            }
            Policy::Static { sinks, recent } => {
                use crate::sparse::{SelectInput, SparsePolicy, StaticWindow};
                StaticWindow::new(*sinks, *recent).select(&SelectInput {
                    maw: &hs.maw,
                    pos: &hs.pos,
                    seq_len,
                })
            }
            _ => return,
        };
        let mut nk = Vec::with_capacity(sel.len() * dh);
        let mut nv = Vec::with_capacity(sel.len() * dh);
        let mut nm = Vec::with_capacity(sel.len());
        let mut np = Vec::with_capacity(sel.len());
        for &i in &sel {
            let i = i as usize;
            nk.extend_from_slice(&hs.k[i * dh..(i + 1) * dh]);
            nv.extend_from_slice(&hs.v[i * dh..(i + 1) * dh]);
            nm.push(hs.maw[i]);
            np.push(hs.pos[i]);
        }
        let hs = &mut store.full[h];
        hs.k = nk.into();
        hs.v = nv.into();
        hs.maw = nm;
        hs.pos = np.into();
    }
}

fn seqs_len_hint(store: &crate::kv::CpuLayerStore) -> usize {
    store.full[0].pos.last().map(|p| p + 1).unwrap_or(0)
}
