//! Request lifecycle: cancellation tokens, deadlines, and finish reasons.
//!
//! Every admitted request carries a [`RequestHandle`]; the batcher checks
//! it at the top of each tick ([`crate::engine::Batcher::tick`]) and
//! retires tripped/expired rows mid-batch — their GPU KV block lease
//! returns to the [`crate::kv::GpuBlockPool`], their CPU store drops with
//! the sequence, and pending prefill chunks are descheduled. Request
//! *exit* is a first-class scheduler event, exactly like admission
//! (Orca-style iteration-level scheduling).
//!
//! The token is the only piece of engine state that other threads touch:
//! the HTTP layer trips it when a stream write fails (client disconnect,
//! see `server/http.rs`), `/v1/cancel` trips it by request id, and tests
//! trip it directly. A token trips exactly once — the first reason wins.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Why a request was asked to stop before reaching its token budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum CancelReason {
    /// Explicit cancellation (`/v1/cancel` or an in-process token trip).
    Cancelled = 1,
    /// The request's deadline passed.
    Deadline = 2,
    /// The client stopped reading its response stream.
    Disconnected = 3,
    /// The request exceeded its max-queue-wait admission bound.
    QueueTimeout = 4,
}

/// A shared one-shot cancellation flag. Cheap to clone (one `Arc`);
/// `Send + Sync` so connection threads can trip it while the engine
/// thread polls it between ticks.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicU8>);

const LIVE: u8 = 0;

impl CancelToken {
    /// A live (untripped) token.
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Trip the token with `reason`. Only the first trip takes effect;
    /// returns whether this call was the one that tripped it.
    pub fn trip(&self, reason: CancelReason) -> bool {
        self.0
            .compare_exchange(LIVE, reason as u8, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// The reason the token was tripped with, if any.
    pub fn tripped(&self) -> Option<CancelReason> {
        match self.0.load(Ordering::Acquire) {
            LIVE => None,
            1 => Some(CancelReason::Cancelled),
            2 => Some(CancelReason::Deadline),
            3 => Some(CancelReason::Disconnected),
            _ => Some(CancelReason::QueueTimeout),
        }
    }
}

/// Lifecycle state attached to a request at submission. The default
/// handle never expires and can only exit early via its token.
#[derive(Debug, Clone, Default)]
pub struct RequestHandle {
    /// One-shot cancellation flag owned by this request (what
    /// `/v1/cancel` trips).
    pub token: CancelToken,
    /// A second, *shared* token this request also observes — used to link
    /// every member of a `/v1/batch` group to its connection, so a
    /// dropped client cancels the whole group while `/v1/cancel` still
    /// targets one member.
    pub link: Option<CancelToken>,
    /// Absolute wall-clock deadline; the row retires with partial tokens
    /// when it passes.
    pub deadline: Option<Instant>,
    /// Max ticks the request may wait in the admission queue before it is
    /// shed (never admitted, never allocates KV).
    pub max_queue_ticks: Option<u64>,
}

impl RequestHandle {
    /// Whether the deadline has passed as of `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }

    /// The reason this request was asked to stop: its own token first,
    /// then the linked (connection) token.
    pub fn tripped(&self) -> Option<CancelReason> {
        self.token
            .tripped()
            .or_else(|| self.link.as_ref().and_then(|t| t.tripped()))
    }
}

/// How a request ended. Serialized as the `finish_reason` field of every
/// completion (full responses, stream summary lines, batch items).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated its full `max_new_tokens` budget (the only normal exit).
    Length,
    /// Explicitly cancelled; `text` holds the tokens generated so far.
    Cancelled,
    /// Deadline expired; `text` holds the tokens generated so far.
    Deadline,
    /// Client disconnected mid-stream; the row was retired.
    Disconnected,
    /// Shed from the admission queue (max-queue-wait exceeded) — zero
    /// tokens, no KV was ever allocated.
    QueueTimeout,
    /// The request's KV block requirement exceeds the pool's total
    /// capacity — it can *never* be admitted, no matter how long it waits
    /// (distinct from a transient shed: retrying without a bigger
    /// `--kv-blocks` cannot succeed). Zero tokens, no KV allocated.
    NoCapacity,
}

impl FinishReason {
    /// Wire representation (docs/API.md `finish_reason` values).
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Deadline => "deadline",
            FinishReason::Disconnected => "disconnected",
            FinishReason::QueueTimeout => "shed",
            FinishReason::NoCapacity => "capacity",
        }
    }

    /// The finish reason a tripped token maps to.
    pub fn from_cancel(r: CancelReason) -> FinishReason {
        match r {
            CancelReason::Cancelled => FinishReason::Cancelled,
            CancelReason::Deadline => FinishReason::Deadline,
            CancelReason::Disconnected => FinishReason::Disconnected,
            CancelReason::QueueTimeout => FinishReason::QueueTimeout,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn token_trips_once_first_reason_wins() {
        let t = CancelToken::new();
        assert_eq!(t.tripped(), None);
        assert!(t.trip(CancelReason::Deadline));
        assert!(!t.trip(CancelReason::Cancelled));
        assert_eq!(t.tripped(), Some(CancelReason::Deadline));
    }

    #[test]
    fn clones_share_state() {
        let t = CancelToken::new();
        let c = t.clone();
        t.trip(CancelReason::Disconnected);
        assert_eq!(c.tripped(), Some(CancelReason::Disconnected));
    }

    #[test]
    fn deadline_expiry() {
        let now = Instant::now();
        let h = RequestHandle {
            deadline: Some(now + Duration::from_millis(5)),
            ..Default::default()
        };
        assert!(!h.expired(now));
        assert!(h.expired(now + Duration::from_millis(6)));
        assert!(!RequestHandle::default().expired(now));
    }

    #[test]
    fn linked_token_trips_handle_but_not_sibling_tokens() {
        let conn = CancelToken::new();
        let a = RequestHandle {
            link: Some(conn.clone()),
            ..Default::default()
        };
        let b = RequestHandle {
            link: Some(conn.clone()),
            ..Default::default()
        };
        // cancelling member a does not touch member b
        a.token.trip(CancelReason::Cancelled);
        assert_eq!(a.tripped(), Some(CancelReason::Cancelled));
        assert_eq!(b.tripped(), None);
        // the shared connection token reaches every member
        conn.trip(CancelReason::Disconnected);
        assert_eq!(b.tripped(), Some(CancelReason::Disconnected));
        // a's own token still wins for a
        assert_eq!(a.tripped(), Some(CancelReason::Cancelled));
    }

    #[test]
    fn wire_names() {
        assert_eq!(FinishReason::Length.as_str(), "length");
        assert_eq!(FinishReason::NoCapacity.as_str(), "capacity");
        assert_eq!(
            FinishReason::from_cancel(CancelReason::QueueTimeout).as_str(),
            "shed"
        );
        assert_eq!(
            FinishReason::from_cancel(CancelReason::Disconnected).as_str(),
            "disconnected"
        );
    }
}
