//! Attention-placement policies: HGCA plus every baseline the paper
//! compares against (§5). A policy decides (a) which CPU-resident KV
//! entries the sparse side attends (numerics → accuracy results) and
//! (b) how the step is charged on the simulated testbed (→ performance
//! results, Figs. 6/10–14).

use crate::attention::JobPayload;
use crate::config::ModelConfig;
use crate::kv::cpu_store::{CpuLayerStore, HeadTier};
use crate::simulator::{AttnWork, Breakdown, Testbed};
use crate::sparse::{SelectInput, SparsePolicy, StaticWindow, TopK};

#[derive(Debug, Clone)]
pub enum Policy {
    /// HGCA hybrid attention: GPU dense window ∥ CPU sparse over the
    /// per-head contextual cache (evict-time β selection + append re-eval).
    Hgca { beta: f32 },
    /// Full attention, no offloading (HF-style): OOMs when the window fills.
    GpuOnly,
    /// Full attention with KV offload (FlexGen-style): CPU-resident KV is
    /// attended exactly (numerics = full attention), but the simulated cost
    /// is the PCIe reload the paper measures.
    FullOffload,
    /// H2O: fixed top-`frac` by cumulative attention; unselected entries
    /// are *discarded permanently* (accuracy impact) but stay on-GPU
    /// (no reload cost).
    H2o { frac: f32 },
    /// InfiniGen: predictive top-`frac` prefetch from CPU memory; keeps
    /// everything (no accuracy loss vs H2O at same frac) but pays
    /// rehearsal memory overhead + prefetch transfers.
    Infinigen { frac: f32 },
    /// StreamingLLM-style static sinks + recency window.
    Static { sinks: usize, recent: usize },
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Hgca { .. } => "hgca",
            Policy::GpuOnly => "gpu-only",
            Policy::FullOffload => "full-offload",
            Policy::H2o { .. } => "h2o",
            Policy::Infinigen { .. } => "infinigen",
            Policy::Static { .. } => "static",
        }
    }

    /// Does this policy attend CPU-resident entries at decode time?
    pub fn uses_cpu_side(&self) -> bool {
        !matches!(self, Policy::GpuOnly)
    }

    /// Does the decode-time gather scale with the *full* CPU store rather
    /// than a bounded selection? Store-sized working sets use the
    /// entries-based pool task split ([`crate::attention::TaskSplit::ByEntries`])
    /// even at decode time, so CPU parallelism follows the store length —
    /// the same pool-aware sizing append-time re-evaluation uses. HGCA's
    /// decode set (the contextual cache) and the top-k/static baselines are
    /// selection-bounded, so they keep the equal-job split.
    pub fn decode_attends_full_store(&self) -> bool {
        matches!(self, Policy::FullOffload)
    }

    /// Build the per-head (k, v) gather for one layer's CPU-side attention.
    /// Returns (k, v, n) per head — contiguous buffers ready for HeadJob.
    /// HGCA uses the pre-packed contextual cache (zero gather — §3.3);
    /// other policies gather from the full store on the fly.
    pub fn gather_jobs(
        &self,
        store: &CpuLayerStore,
        seq_len: usize,
    ) -> Vec<(Vec<f32>, Vec<f32>, usize)> {
        let dh = store.d_head;
        match self {
            Policy::GpuOnly => (0..store.heads).map(|_| (Vec::new(), Vec::new(), 0)).collect(),
            Policy::Hgca { .. } => store
                .ctx
                .iter()
                .map(|c| (c.k.clone(), c.v.clone(), c.len()))
                .collect(),
            Policy::FullOffload => store
                .full
                .iter()
                .map(|h| (h.k.to_vec(), h.v.to_vec(), h.len()))
                .collect(),
            Policy::H2o { frac } | Policy::Infinigen { frac } => {
                let pol = TopK::new(*frac);
                store
                    .full
                    .iter()
                    .map(|h| {
                        let sel = pol.select(&SelectInput {
                            maw: &h.maw,
                            pos: &h.pos,
                            seq_len,
                        });
                        gather(&h.k, &h.v, &sel, dh)
                    })
                    .collect()
            }
            Policy::Static { sinks, recent } => {
                let pol = StaticWindow::new(*sinks, *recent);
                store
                    .full
                    .iter()
                    .map(|h| {
                        let sel = pol.select(&SelectInput {
                            maw: &h.maw,
                            pos: &h.pos,
                            seq_len,
                        });
                        gather(&h.k, &h.v, &sel, dh)
                    })
                    .collect()
            }
        }
    }

    /// Tier-aware twin of [`Policy::gather_jobs`] for the engine's tiered
    /// submission path ([`crate::attention::AttnPool::submit_tiered`]):
    /// int8-tiered heads hand the pool their quantized slabs (bytes +
    /// scales move, nothing dequantizes), `WindowOnly` heads yield empty
    /// jobs (their CPU side contributes nothing — the LSE merge then
    /// reduces to the GPU window), and f32 heads produce exactly the
    /// payload `gather_jobs` would.
    ///
    /// `full_store` selects the append-time re-evaluation gather (whole
    /// store per head, the `FullOffload`-shaped working set) instead of
    /// the policy's decode selection; under HGCA the decode set is the
    /// pre-packed contextual cache, whose quantized twin was packed at
    /// selection time.
    pub fn gather_payloads(
        &self,
        store: &CpuLayerStore,
        seq_len: usize,
        full_store: bool,
    ) -> Vec<JobPayload> {
        if full_store {
            return store
                .full
                .iter()
                .map(|h| match h.tier {
                    HeadTier::F32 => JobPayload::F32(h.k.to_vec(), h.v.to_vec(), h.len()),
                    HeadTier::Int8 => JobPayload::Int8 {
                        k: h.qk.clone().expect("int8 head has quant k slab"),
                        v: h.qv.clone().expect("int8 head has quant v slab"),
                    },
                    HeadTier::WindowOnly => JobPayload::F32(Vec::new(), Vec::new(), 0),
                })
                .collect();
        }
        match self {
            Policy::Hgca { .. } => store
                .ctx
                .iter()
                .map(|c| match (&c.qk, &c.qv) {
                    (Some(qk), Some(qv)) => JobPayload::Int8 {
                        k: qk.clone(),
                        v: qv.clone(),
                    },
                    _ => JobPayload::F32(c.k.clone(), c.v.clone(), c.len()),
                })
                .collect(),
            // no other policy tiers its store; fall back to the f32 gather
            _ => self
                .gather_jobs(store, seq_len)
                .into_iter()
                .map(|(k, v, n)| JobPayload::F32(k, v, n))
                .collect(),
        }
    }

    /// Simulated wall time + breakdown of one layer's attention step.
    /// `n_win`: GPU-window entries; `n_cpu`: CPU-resident entries;
    /// `n_sel`: entries the CPU side actually attends.
    #[allow(clippy::too_many_arguments)]
    pub fn sim_attention(
        &self,
        tb: &Testbed,
        model: &ModelConfig,
        batch: usize,
        n_query: usize,
        n_win: usize,
        n_cpu: usize,
        n_sel: usize,
    ) -> (f64, Breakdown) {
        let w = |n_kv: usize| AttnWork {
            batch,
            heads: model.n_heads,
            d_head: model.d_head(),
            n_query,
            n_kv,
            bytes_per_el: model.bytes_per_param,
        };
        match self {
            Policy::Hgca { .. } => {
                let mb = Testbed::merge_bytes(batch, model.n_heads, model.d_head());
                tb.hybrid_attention(&w(n_win + n_query), &w(n_sel), mb)
            }
            Policy::GpuOnly => {
                let b = tb.gpu_resident_attention(&w(n_win + n_query));
                (b.total(), b)
            }
            Policy::FullOffload => {
                let b = tb.gpu_attention_with_load(&w(n_win + n_cpu + n_query), n_cpu);
                (b.total(), b)
            }
            Policy::H2o { .. } | Policy::Static { .. } => {
                // selected set stays on-GPU; attention over window + selection
                let b = tb.gpu_resident_attention(&w(n_win + n_sel + n_query));
                (b.total(), b)
            }
            Policy::Infinigen { .. } => {
                // prefetch the predicted set over PCIe, overlapped with the
                // previous layer's compute: charge max(transfer, attn)
                let attn = tb.gpu_resident_attention(&w(n_win + n_sel + n_query));
                let prefetch = tb.link.transfer_time(w(n_sel).kv_bytes());
                let mut b = Breakdown::new();
                b.add("gpu_attn", attn.total());
                b.add("pcie_prefetch", (prefetch - attn.total()).max(0.0));
                (b.total(), b)
            }
        }
    }

    /// Extra CPU memory bytes per stored KV entry (InfiniGen rehearsal).
    pub fn overhead_bytes_per_entry(&self, model: &ModelConfig) -> usize {
        match self {
            Policy::Infinigen { .. } => model.d_head() * 2,
            _ => 0,
        }
    }

    /// H2O discards unselected entries permanently.
    pub fn discards_unselected(&self) -> bool {
        matches!(self, Policy::H2o { .. } | Policy::Static { .. })
    }
}

fn gather(k: &[f32], v: &[f32], sel: &[u32], dh: usize) -> (Vec<f32>, Vec<f32>, usize) {
    let mut gk = Vec::with_capacity(sel.len() * dh);
    let mut gv = Vec::with_capacity(sel.len() * dh);
    for &i in sel {
        let i = i as usize;
        gk.extend_from_slice(&k[i * dh..(i + 1) * dh]);
        gv.extend_from_slice(&v[i * dh..(i + 1) * dh]);
    }
    (gk, gv, sel.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::KvBlock;

    fn store_with(maws: &[&[f32]]) -> CpuLayerStore {
        let heads = maws.len();
        let dh = 2;
        let len = maws[0].len();
        let mut blk = KvBlock::new(heads, dh, len);
        for h in 0..heads {
            for t in 0..len {
                blk.maw[h * len + t] = maws[h][t];
                blk.k[(h * len + t) * dh] = (h * 100 + t) as f32;
                blk.v[(h * len + t) * dh] = -((h * 100 + t) as f32);
            }
        }
        for (t, p) in blk.pos.iter_mut().enumerate() {
            *p = t;
        }
        let mut s = CpuLayerStore::new(heads, dh);
        s.add_evicted(&blk, 1.0, len * 2);
        s
    }

    #[test]
    fn hgca_uses_packed_ctx() {
        let s = store_with(&[&[0.9, 0.01, 0.8, 0.01]]);
        let jobs = Policy::Hgca { beta: 1.0 }.gather_jobs(&s, 10);
        assert_eq!(jobs[0].2, 2); // threshold 1/8: 0.9 and 0.8
        assert_eq!(jobs[0].0[0], 0.0); // entry 0's k
        assert_eq!(jobs[0].0[2], 2.0); // entry 2's k
    }

    #[test]
    fn full_offload_attends_everything() {
        let s = store_with(&[&[0.0, 0.0, 0.0]]);
        let jobs = Policy::FullOffload.gather_jobs(&s, 10);
        assert_eq!(jobs[0].2, 3);
    }

    #[test]
    fn h2o_gathers_fixed_fraction() {
        let maw = [0.5, 0.1, 0.2, 0.05, 0.05, 0.04, 0.03, 0.02, 0.01, 0.0];
        let s = store_with(&[&maw]);
        let jobs = Policy::H2o { frac: 0.2 }.gather_jobs(&s, 10);
        assert_eq!(jobs[0].2, 2);
        assert_eq!(jobs[0].0[0], 0.0); // top entries 0 and 2, sorted
        assert_eq!(jobs[0].0[2], 2.0);
    }

    #[test]
    fn gpu_only_has_no_jobs() {
        let s = store_with(&[&[0.5, 0.5]]);
        let jobs = Policy::GpuOnly.gather_jobs(&s, 4);
        assert_eq!(jobs[0].2, 0);
        assert!(!Policy::GpuOnly.uses_cpu_side());
    }

    #[test]
    fn sim_hybrid_faster_than_offload_at_scale() {
        let tb = Testbed::paper();
        let model = crate::config::model::simulated("opt-6.7b").unwrap();
        let (h, _) = Policy::Hgca { beta: 1.0 }.sim_attention(&tb, &model, 4, 1, 1024, 16384, 3000);
        let (f, _) = Policy::FullOffload.sim_attention(&tb, &model, 4, 1, 1024, 16384, 0);
        assert!(f / h > 2.0, "hybrid {h} vs offload {f}");
    }

    #[test]
    fn sim_h2o_cheap_but_discards() {
        let tb = Testbed::paper();
        let model = crate::config::model::simulated("opt-6.7b").unwrap();
        let p = Policy::H2o { frac: 0.2 };
        let (t, _) = p.sim_attention(&tb, &model, 1, 1, 1024, 8192, 1638);
        assert!(t < 0.01);
        assert!(p.discards_unselected());
        assert!(!Policy::Hgca { beta: 1.0 }.discards_unselected());
    }

    #[test]
    fn full_offload_decode_is_store_sized() {
        // only full-offload gathers the whole store at decode time, so only
        // it opts into the entries-based split outside append steps
        assert!(Policy::FullOffload.decode_attends_full_store());
        assert!(!Policy::Hgca { beta: 1.0 }.decode_attends_full_store());
        assert!(!Policy::H2o { frac: 0.2 }.decode_attends_full_store());
        assert!(!Policy::Static { sinks: 4, recent: 64 }.decode_attends_full_store());
        assert!(!Policy::GpuOnly.decode_attends_full_store());
    }

    #[test]
    fn gather_payloads_respects_tiers() {
        let maw = [0.5f32; 32];
        let mut s = store_with(&[&maw[..], &maw[..]]);
        s.set_tier(0, HeadTier::Int8);
        s.set_tier(1, HeadTier::WindowOnly);
        let p = Policy::Hgca { beta: 1.0 };
        // append-time gather: whole store per head
        let full = p.gather_payloads(&s, 64, true);
        assert!(matches!(&full[0], JobPayload::Int8 { k, .. } if k.len() == 32));
        assert_eq!(full[1].n(), 0, "window-only head offers no CPU job");
        // decode gather: the packed ctx, quantized twin for the int8 head
        let dec = p.gather_payloads(&s, 64, false);
        assert!(matches!(&dec[0], JobPayload::Int8 { .. }));
        assert_eq!(dec[1].n(), 0);
    }

    #[test]
    fn gather_payloads_matches_gather_jobs_when_untiered() {
        let s = store_with(&[&[0.9, 0.01, 0.8, 0.01]]);
        let p = Policy::Hgca { beta: 1.0 };
        let jobs = p.gather_jobs(&s, 10);
        let payloads = p.gather_payloads(&s, 10, false);
        match &payloads[0] {
            JobPayload::F32(k, v, n) => {
                assert_eq!((k, v, *n), (&jobs[0].0, &jobs[0].1, jobs[0].2));
            }
            _ => panic!("untiered head must gather f32"),
        }
    }

    #[test]
    fn infinigen_overhead_positive() {
        let model = crate::config::model::simulated("opt-6.7b").unwrap();
        assert!(Policy::Infinigen { frac: 0.2 }.overhead_bytes_per_entry(&model) > 0);
        assert_eq!(Policy::Hgca { beta: 1.0 }.overhead_bytes_per_entry(&model), 0);
    }
}
