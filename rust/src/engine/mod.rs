//! Inference engine: hybrid attention orchestration (Algorithm 2),
//! generation loops, continuous batching, policy strategies.
//!
//! * [`engine`] runs one hybrid step: dense window attention on the
//!   artifact ("GPU") in parallel with CPU sparse attention over the
//!   selected store entries, fused by the LSE merge.
//! * [`batcher`] schedules sequences over the fixed-batch artifacts:
//!   earliest-deadline-first admission gated on GPU KV block leases
//!   (FIFO among equal deadlines), chunked prefill interleaved with fused
//!   decode steps, infeasible-deadline pre-emption, per-token events for
//!   streaming. Policy walkthrough: docs/SCHEDULING.md.
//! * [`strategy`] selects which CPU entries are attended and how the step
//!   is charged on the simulated testbed (HGCA + paper baselines).
//! * [`lifecycle`] makes request *exit* a first-class scheduler event:
//!   cancellation tokens, deadlines, queue-wait bounds, finish reasons.

pub mod batcher;
pub mod engine;
pub mod lifecycle;
pub mod strategy;

pub use batcher::{Batcher, BatcherStats, Completion, Request, TokenEvent};
pub use engine::{Engine, Sequence};
pub use lifecycle::{CancelReason, CancelToken, FinishReason, RequestHandle};
pub use strategy::Policy;
