//! Inference engine: hybrid attention orchestration (Algorithm 2),
//! generation loops, continuous batching, policy strategies.

pub mod batcher;
pub mod engine;
pub mod strategy;

pub use batcher::{Batcher, BatcherStats, Completion, Request};
pub use engine::{Engine, Sequence};
pub use strategy::Policy;
