//! Model architecture configs.
//!
//! Two families:
//! * **trained** — the byte-level demo models exported by `make artifacts`
//!   (`tiny`, `tiny-small`, `tiny-large`); real weights + real numerics.
//! * **simulated** — config-accurate shapes of the paper's evaluation models
//!   (OPT-6.7B…66B, GPT-NeoX-12B, LLaMA-2-7B/13B, LLaMA/Vicuna-33B) used by
//!   the performance benches through the device cost model; weights never
//!   materialize.

use anyhow::Result;

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ffn: usize,
    pub max_pos: usize,
    /// fp16 bytes/param for simulated models, fp32 for trained ones.
    pub bytes_per_param: usize,
}

impl ModelConfig {
    pub fn d_head(&self) -> usize {
        debug_assert_eq!(self.d_model % self.n_heads, 0);
        self.d_model / self.n_heads
    }

    /// Per-token, per-layer KV bytes (K + V, all heads).
    pub fn kv_bytes_per_token_layer(&self) -> usize {
        2 * self.d_model * self.bytes_per_param
    }

    /// Per-token KV bytes across all layers.
    pub fn kv_bytes_per_token(&self) -> usize {
        self.n_layers * self.kv_bytes_per_token_layer()
    }

    pub fn param_count(&self) -> usize {
        let (d, f, l) = (self.d_model, self.d_ffn, self.n_layers);
        let per_layer = 4 * d * d + 4 * d + 2 * d * f + f + d + 4 * d;
        self.vocab * d + self.max_pos * d + l * per_layer + 2 * d
    }

    pub fn weight_bytes(&self) -> usize {
        self.param_count() * self.bytes_per_param
    }

    /// Parse the python-exported `<name>_config.json`.
    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: j.req_str("name")?.to_string(),
            vocab: j.req_usize("vocab")?,
            n_layers: j.req_usize("n_layers")?,
            d_model: j.req_usize("d_model")?,
            n_heads: j.req_usize("n_heads")?,
            d_ffn: j.req_usize("d_ffn")?,
            max_pos: j.req_usize("max_pos")?,
            bytes_per_param: 4,
        })
    }
}

fn m(name: &str, n_layers: usize, d_model: usize, n_heads: usize, max_pos: usize) -> ModelConfig {
    ModelConfig {
        name: name.into(),
        vocab: 50272,
        n_layers,
        d_model,
        n_heads,
        d_ffn: 4 * d_model,
        max_pos,
        bytes_per_param: 2, // fp16 serving
    }
}

/// Simulated model presets — layer/head/dim taken from the published configs.
/// The paper's micro-bench (Fig. 10) notes all OPT models share d_head = 128.
pub fn simulated(name: &str) -> Option<ModelConfig> {
    Some(match name {
        "opt-6.7b" => m("opt-6.7b", 32, 4096, 32, 2048),
        "opt-13b" => m("opt-13b", 40, 5120, 40, 2048),
        "opt-30b" => m("opt-30b", 48, 7168, 56, 2048),
        "opt-66b" => m("opt-66b", 64, 9216, 72, 2048),
        "gpt-neox-12b" => {
            let mut c = m("gpt-neox-12b", 36, 5120, 40, 2048);
            c.vocab = 50432;
            c
        }
        "llama-2-7b" => {
            let mut c = m("llama-2-7b", 32, 4096, 32, 4096);
            c.vocab = 32000;
            c.d_ffn = 11008;
            c
        }
        "llama-2-13b" => {
            let mut c = m("llama-2-13b", 40, 5120, 40, 4096);
            c.vocab = 32000;
            c.d_ffn = 13824;
            c
        }
        "llama-33b" | "vicuna-33b" => {
            let mut c = m("llama-33b", 60, 6656, 52, 2048);
            c.vocab = 32000;
            c.d_ffn = 17920;
            c
        }
        _ => return None,
    })
}

/// Built-in copies of the trained configs (authoritative copy is the
/// exported JSON; these are used when artifacts are absent, e.g. unit tests).
pub fn trained(name: &str) -> Option<ModelConfig> {
    let mk = |name: &str, n_layers, d_model, n_heads, d_ffn| ModelConfig {
        name: name.into(),
        vocab: 256,
        n_layers,
        d_model,
        n_heads,
        d_ffn,
        max_pos: 20480,
        bytes_per_param: 4,
    };
    Some(match name {
        "tiny" => mk("tiny", 4, 128, 4, 512),
        "tiny-small" => mk("tiny-small", 2, 64, 2, 256),
        "tiny-large" => mk("tiny-large", 6, 192, 6, 768),
        _ => return None,
    })
}

pub fn lookup(name: &str) -> Option<ModelConfig> {
    trained(name).or_else(|| simulated(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opt_presets_have_paper_head_dim() {
        for name in ["opt-6.7b", "opt-13b", "opt-30b", "opt-66b"] {
            let c = simulated(name).unwrap();
            assert_eq!(c.d_head(), 128, "{name}");
        }
    }

    #[test]
    fn param_counts_are_plausible() {
        // within 20% of the nameplate size (simulated models fp16)
        let cases = [("opt-6.7b", 6.7e9), ("opt-13b", 13e9), ("opt-30b", 30e9), ("opt-66b", 66e9)];
        for (name, want) in cases {
            let c = simulated(name).unwrap();
            let got = c.param_count() as f64;
            assert!(
                (got / want - 1.0).abs() < 0.2,
                "{name}: {got:.3e} vs {want:.3e}"
            );
        }
    }

    #[test]
    fn kv_bytes_formula() {
        let c = simulated("opt-6.7b").unwrap();
        // 2 (K+V) * 4096 * 2 bytes = 16 KiB per token per layer
        assert_eq!(c.kv_bytes_per_token_layer(), 16384);
        assert_eq!(c.kv_bytes_per_token(), 16384 * 32);
    }

    #[test]
    fn trained_matches_python_configs() {
        let t = trained("tiny").unwrap();
        assert_eq!((t.n_layers, t.d_model, t.n_heads, t.d_ffn), (4, 128, 4, 512));
        assert_eq!(t.d_head(), 32);
        let s = trained("tiny-small").unwrap();
        assert_eq!((s.n_layers, s.d_model), (2, 64));
    }

    #[test]
    fn lookup_both_families() {
        assert!(lookup("tiny").is_some());
        assert!(lookup("opt-66b").is_some());
        assert!(lookup("nope").is_none());
    }

    #[test]
    fn from_json_parses_exported_config() {
        let j = Json::parse(
            r#"{"name":"tiny","vocab":256,"n_layers":4,"d_model":128,
                "n_heads":4,"d_ffn":512,"max_pos":20480,"d_head":32}"#,
        )
        .unwrap();
        let c = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c, trained("tiny").unwrap());
    }
}
