//! User-facing configuration: model architectures + HGCA runtime knobs.

pub mod model;
pub mod runtime;

pub use model::ModelConfig;
pub use runtime::{HgcaConfig, ServingConfig};
