//! HGCA runtime configuration (Algorithm 1's tunables + engine knobs).

/// Everything the KV manager + hybrid attention need. Defaults follow the
/// paper's evaluation settings (β = 1, MAW α = 0.3, block-granular eviction).
#[derive(Debug, Clone, PartialEq)]
pub struct HgcaConfig {
    /// KV entries per eviction block (Algorithm 1: blk_size).
    pub blk_size: usize,
    /// Blocks in the per-layer GPU circular buffer (blk_num);
    /// window W = blk_num * blk_size.
    pub blk_num: usize,
    /// Moving-average factor for attention-weight tracking (α, line 8).
    pub alpha: f32,
    /// Sparsification threshold factor (β, §3.2.2). Entry kept iff
    /// maw > β / window_len.
    pub beta: f32,
    /// CPU threads for sparse attention (heads get packed, §3.3).
    pub cpu_threads: usize,
    /// KV entries per CPU task for append-time full-store re-evaluation
    /// (the pool-aware split: task count follows the store length instead
    /// of the decode parallelism cap — see
    /// [`crate::attention::sparse_attention_append`]). Larger values mean
    /// fewer, longer tasks.
    pub append_entries_per_task: usize,
    /// Prefill/append chunk length (must match a compiled artifact).
    pub chunk: usize,
    /// Max batch rows (must match a compiled artifact batch).
    pub max_batch: usize,
    /// Disable the CPU side entirely (GPU-only full attention; "GPU KV
    /// ratio 1" configuration in Figs. 13/14).
    pub gpu_only: bool,
}

impl Default for HgcaConfig {
    fn default() -> Self {
        HgcaConfig {
            blk_size: 32,
            blk_num: 8,
            alpha: 0.3,
            beta: 1.0,
            // oversubscribing threads costs context switches (§3.3); match
            // the cores we actually have
            cpu_threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            append_entries_per_task: 1024,
            chunk: 64,
            max_batch: 4,
            gpu_only: false,
        }
    }
}

impl HgcaConfig {
    /// GPU window length W.
    pub fn window(&self) -> usize {
        self.blk_size * self.blk_num
    }

    pub fn with_window(mut self, window: usize) -> Self {
        assert_eq!(window % self.blk_size, 0, "window must be block-aligned");
        self.blk_num = window / self.blk_size;
        self
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.blk_size > 0, "blk_size must be positive");
        anyhow::ensure!(self.blk_num > 0, "blk_num must be positive");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.alpha),
            "alpha must be in [0,1]"
        );
        anyhow::ensure!(self.beta >= 0.0, "beta must be non-negative");
        anyhow::ensure!(self.cpu_threads > 0, "cpu_threads must be positive");
        anyhow::ensure!(
            self.append_entries_per_task > 0,
            "append_entries_per_task must be positive"
        );
        anyhow::ensure!(self.chunk > 0 && self.max_batch > 0, "chunk/batch positive");
        Ok(())
    }
}

/// Serving-layer lifecycle knobs (`hgca serve` flags): defaults applied to
/// every admitted request plus the admission-control watermark. Engine
/// tunables stay in [`HgcaConfig`]; these only shape scheduling.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ServingConfig {
    /// Default deadline applied to requests that do not carry their own
    /// `deadline_ms` (`--deadline-default`). `None` = no default deadline.
    pub deadline_default_ms: Option<u64>,
    /// Load-shedding watermark (`--shed-watermark`): when batch occupancy
    /// + queue depth would exceed this, new admissions are rejected with
    /// an immediate 429-style JSON error instead of queuing unboundedly.
    /// `None` = never shed.
    pub shed_watermark: Option<usize>,
    /// Max ticks a request may wait in the admission queue
    /// (`--max-queue-ticks`) before it is shed. `None` = wait forever.
    pub max_queue_ticks: Option<u64>,
}

impl ServingConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        if let Some(w) = self.shed_watermark {
            anyhow::ensure!(w > 0, "shed watermark must be positive");
        }
        if let Some(ms) = self.deadline_default_ms {
            anyhow::ensure!(ms > 0, "default deadline must be positive");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serving_config_validation() {
        ServingConfig::default().validate().unwrap();
        let ok = ServingConfig {
            deadline_default_ms: Some(500),
            shed_watermark: Some(8),
            max_queue_ticks: Some(64),
        };
        ok.validate().unwrap();
        let bad = ServingConfig {
            shed_watermark: Some(0),
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = ServingConfig {
            deadline_default_ms: Some(0),
            ..Default::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn default_window() {
        let c = HgcaConfig::default();
        assert_eq!(c.window(), 256);
        c.validate().unwrap();
    }

    #[test]
    fn with_window_adjusts_blocks() {
        let c = HgcaConfig::default().with_window(1024);
        assert_eq!(c.blk_num, 32);
        assert_eq!(c.window(), 1024);
    }

    #[test]
    #[should_panic]
    fn unaligned_window_panics() {
        HgcaConfig::default().with_window(100);
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut c = HgcaConfig::default();
        c.alpha = 1.5;
        assert!(c.validate().is_err());
        let mut c = HgcaConfig::default();
        c.blk_size = 0;
        assert!(c.validate().is_err());
        let mut c = HgcaConfig::default();
        c.beta = -0.1;
        assert!(c.validate().is_err());
    }
}
