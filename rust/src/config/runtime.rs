//! HGCA runtime configuration (Algorithm 1's tunables + engine knobs).

/// Everything the KV manager + hybrid attention need. Defaults follow the
/// paper's evaluation settings (β = 1, MAW α = 0.3, block-granular eviction).
#[derive(Debug, Clone, PartialEq)]
pub struct HgcaConfig {
    /// KV entries per eviction block (Algorithm 1: blk_size).
    pub blk_size: usize,
    /// Blocks in the per-layer GPU circular buffer (blk_num);
    /// window W = blk_num * blk_size.
    pub blk_num: usize,
    /// Moving-average factor for attention-weight tracking (α, line 8).
    pub alpha: f32,
    /// Sparsification threshold factor (β, §3.2.2). Entry kept iff
    /// maw > β / window_len.
    pub beta: f32,
    /// CPU threads for sparse attention (heads get packed, §3.3).
    pub cpu_threads: usize,
    /// KV entries per CPU task for append-time full-store re-evaluation
    /// (the pool-aware split: task count follows the store length instead
    /// of the decode parallelism cap — see
    /// [`crate::attention::sparse_attention_append`]). Larger values mean
    /// fewer, longer tasks.
    pub append_entries_per_task: usize,
    /// Prefill/append chunk length (must match a compiled artifact).
    pub chunk: usize,
    /// Max batch rows (must match a compiled artifact batch).
    pub max_batch: usize,
    /// Disable the CPU side entirely (GPU-only full attention; "GPU KV
    /// ratio 1" configuration in Figs. 13/14).
    pub gpu_only: bool,
    /// CPU KV storage tier override (`--kv-tier {f32,int8,auto}`): `F32`
    /// (default) keeps every head on the f32 path — bitwise-identical
    /// tokens to the pre-tier engine; `Int8` quantizes every head's
    /// CPU-resident KV; `Auto` picks per head from the observed attention
    /// mass (see [`crate::kv::TierPolicy`]). Only the HGCA policy tiers
    /// its store.
    pub kv_tier: crate::kv::TierMode,
    /// SIMD kernel dispatch override (`--simd {auto,avx2,sse4,neon,scalar}`):
    /// `None` (= `auto`, the default) lets runtime feature detection pick
    /// the best table; an explicit level forces it for the whole process
    /// (applied before the first kernel call — see
    /// [`crate::tensor::simd::configure`]). Results are bitwise-stable
    /// within a level; across levels `dot_i8` is bitwise-identical and the
    /// f32 kernels are within 1e-5 per element.
    pub simd: Option<crate::tensor::simd::SimdLevel>,
}

impl Default for HgcaConfig {
    fn default() -> Self {
        HgcaConfig {
            blk_size: 32,
            blk_num: 8,
            alpha: 0.3,
            beta: 1.0,
            // oversubscribing threads costs context switches (§3.3); match
            // the cores we actually have
            cpu_threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            append_entries_per_task: 1024,
            chunk: 64,
            max_batch: 4,
            gpu_only: false,
            kv_tier: crate::kv::TierMode::F32,
            simd: None,
        }
    }
}

impl HgcaConfig {
    /// GPU window length W.
    pub fn window(&self) -> usize {
        self.blk_size * self.blk_num
    }

    pub fn with_window(mut self, window: usize) -> Self {
        assert_eq!(window % self.blk_size, 0, "window must be block-aligned");
        self.blk_num = window / self.blk_size;
        self
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.blk_size > 0, "blk_size must be positive");
        anyhow::ensure!(self.blk_num > 0, "blk_num must be positive");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.alpha),
            "alpha must be in [0,1]"
        );
        anyhow::ensure!(self.beta >= 0.0, "beta must be non-negative");
        anyhow::ensure!(self.cpu_threads > 0, "cpu_threads must be positive");
        anyhow::ensure!(
            self.append_entries_per_task > 0,
            "append_entries_per_task must be positive"
        );
        anyhow::ensure!(self.chunk > 0 && self.max_batch > 0, "chunk/batch positive");
        if let Some(level) = self.simd {
            anyhow::ensure!(level.supported(), "--simd {level}: unsupported on this host");
        }
        Ok(())
    }
}

/// Serving-layer scheduling knobs (`hgca serve` flags): defaults applied
/// to every admitted request, the admission-control watermark, and the
/// GPU KV pool capacity. Engine tunables stay in [`HgcaConfig`]; these
/// only shape scheduling (policy walkthrough: docs/SCHEDULING.md).
#[derive(Debug, Clone, PartialEq)]
pub struct ServingConfig {
    /// Default deadline applied to requests that do not carry their own
    /// `deadline_ms` (`--deadline-default`). `None` = no default deadline.
    pub deadline_default_ms: Option<u64>,
    /// Load-shedding watermark (`--shed-watermark`): when batch occupancy
    /// + queue depth would exceed this, new admissions are rejected with
    /// an immediate 429-style JSON error instead of queuing unboundedly.
    /// `None` = never shed.
    pub shed_watermark: Option<usize>,
    /// Max ticks a request may wait in the admission queue
    /// (`--max-queue-ticks`) before it is shed. `None` = wait forever.
    pub max_queue_ticks: Option<u64>,
    /// Explicit GPU KV pool capacity in blocks (`--kv-blocks`). `None`
    /// derives the capacity from the model shape:
    /// `blocks_per_sequence × batch rows × kv_headroom` (see
    /// [`ServingConfig::effective_kv_blocks`]).
    pub kv_blocks: Option<usize>,
    /// Headroom factor for the derived KV capacity (`--kv-headroom`,
    /// default 1.0 — exactly enough blocks for a full batch of
    /// sequences). Values < 1 make KV availability, not row count, the
    /// binding admission constraint; values > 1 leave slack.
    pub kv_headroom: f64,
    /// Cross-request prefix KV reuse (`--prefix-cache`): admission
    /// consults a radix cache of chunk-aligned prompt-prefix snapshots
    /// and adopts the longest hit instead of re-running those prefill
    /// chunks. Off by default — tokens are bitwise-identical either way
    /// (the conformance suite pins this); the cache trades pool blocks
    /// for skipped prefill work. Cached entries lease blocks from the
    /// same pool sequences use, so pair it with headroom above one full
    /// batch (`--kv-headroom` > 1 or explicit `--kv-blocks`) or the
    /// cache will have nothing to lease and every lookup will miss.
    pub prefix_cache: bool,
    /// Max resident prefix-cache entries (`--prefix-cache-entries`,
    /// default 32) — LRU evicts past this, and capacity pressure from
    /// admission evicts below it.
    pub prefix_cache_entries: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            deadline_default_ms: None,
            shed_watermark: None,
            max_queue_ticks: None,
            kv_blocks: None,
            kv_headroom: 1.0,
            prefix_cache: false,
            prefix_cache_entries: 32,
        }
    }
}

impl ServingConfig {
    /// The GPU KV pool capacity (blocks) the server runs with: the
    /// explicit `--kv-blocks` value when given, otherwise derived from
    /// the model shape as `ceil(blocks_per_seq × batch_rows ×
    /// kv_headroom)` (≥ 1). With the default headroom of 1.0 the derived
    /// pool holds exactly one full batch of sequences, so KV gating
    /// coincides with row gating — admission behaviour is unchanged until
    /// the operator tightens either knob.
    pub fn effective_kv_blocks(&self, blocks_per_seq: usize, batch_rows: usize) -> usize {
        self.kv_blocks.unwrap_or_else(|| {
            let derived = (blocks_per_seq * batch_rows) as f64 * self.kv_headroom;
            (derived.ceil() as usize).max(1)
        })
    }

    /// Split [`ServingConfig::effective_kv_blocks`] into one hard budget
    /// per NUMA node, in **whole-sequence units**: the capacity's
    /// `total / blocks_per_seq` lease slots are dealt round-robin to the
    /// lowest node ids first, then any sub-lease remainder blocks are
    /// spread evenly (deterministic; the budgets sum exactly to the
    /// total). Distributing raw blocks instead would strand a sub-lease
    /// remainder on *every* node whenever `nodes` does not divide the slot
    /// count — e.g. 192 blocks (6 × 32-block sequences) over 4 nodes as
    /// `[48, 48, 48, 48]` admits only 4 sequences; the slot-wise split
    /// `[64, 64, 32, 32]` admits all 6, keeping the documented
    /// "headroom 1.0 = exactly one full batch" guarantee on every
    /// topology. A one-node topology yields `[total]` — the pre-NUMA
    /// single-capacity pool, bit for bit. With fewer slots than nodes,
    /// the tail nodes hold only remainder blocks and never receive a
    /// lease; if *no* node can hold one, requests are rejected as
    /// never-fitting (leases never span nodes).
    pub fn effective_node_budgets(
        &self,
        blocks_per_seq: usize,
        batch_rows: usize,
        nodes: usize,
    ) -> Vec<usize> {
        let nodes = nodes.max(1);
        let total = self.effective_kv_blocks(blocks_per_seq, batch_rows);
        let bps = blocks_per_seq.max(1);
        let slots = total / bps;
        let leftover = total - slots * bps;
        let (slot_base, slot_rem) = (slots / nodes, slots % nodes);
        let (left_base, left_rem) = (leftover / nodes, leftover % nodes);
        (0..nodes)
            .map(|i| {
                (slot_base + usize::from(i < slot_rem)) * bps
                    + left_base
                    + usize::from(i < left_rem)
            })
            .collect()
    }

    /// Watermark admission test shared by the HTTP front door and the
    /// trace-replay harness: shed when the queue is already non-empty and
    /// accepting `incoming` more requests would push `pending + incoming`
    /// past the watermark. An idle server (`pending == 0`) always accepts,
    /// even under a watermark smaller than the burst — shedding exists to
    /// bound *queueing*, not to refuse work to an empty machine.
    pub fn should_shed(&self, pending: usize, incoming: usize) -> bool {
        match self.shed_watermark {
            Some(w) => pending > 0 && pending + incoming > w,
            None => false,
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if let Some(w) = self.shed_watermark {
            anyhow::ensure!(w > 0, "shed watermark must be positive");
        }
        if let Some(ms) = self.deadline_default_ms {
            anyhow::ensure!(ms > 0, "default deadline must be positive");
        }
        if let Some(b) = self.kv_blocks {
            anyhow::ensure!(b > 0, "kv blocks capacity must be positive");
        }
        anyhow::ensure!(
            self.kv_headroom.is_finite() && self.kv_headroom > 0.0,
            "kv headroom must be a positive finite factor"
        );
        anyhow::ensure!(
            self.prefix_cache_entries > 0,
            "prefix cache entry cap must be positive"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serving_config_validation() {
        ServingConfig::default().validate().unwrap();
        let ok = ServingConfig {
            deadline_default_ms: Some(500),
            shed_watermark: Some(8),
            max_queue_ticks: Some(64),
            kv_blocks: Some(128),
            kv_headroom: 1.5,
            prefix_cache: true,
            prefix_cache_entries: 8,
        };
        ok.validate().unwrap();
        let bad = ServingConfig {
            shed_watermark: Some(0),
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = ServingConfig {
            deadline_default_ms: Some(0),
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = ServingConfig {
            kv_blocks: Some(0),
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        for headroom in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let bad = ServingConfig {
                kv_headroom: headroom,
                ..Default::default()
            };
            assert!(bad.validate().is_err(), "headroom {headroom} must fail");
        }
        let bad = ServingConfig {
            prefix_cache_entries: 0,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn effective_kv_blocks_explicit_and_derived() {
        // explicit capacity wins over the derivation
        let c = ServingConfig {
            kv_blocks: Some(7),
            kv_headroom: 3.0,
            ..Default::default()
        };
        assert_eq!(c.effective_kv_blocks(32, 4), 7);
        // default headroom 1.0 → exactly one full batch of sequences
        assert_eq!(ServingConfig::default().effective_kv_blocks(32, 4), 128);
        // fractional headroom rounds up and never hits zero
        let tight = ServingConfig {
            kv_headroom: 0.3,
            ..Default::default()
        };
        assert_eq!(tight.effective_kv_blocks(32, 4), 39); // ceil(128 × 0.3)
        let tiny = ServingConfig {
            kv_headroom: 1e-9,
            ..Default::default()
        };
        assert_eq!(tiny.effective_kv_blocks(1, 1), 1);
    }

    #[test]
    fn node_budgets_split_the_effective_capacity() {
        let c = ServingConfig::default();
        // one node: the single-capacity pool, exactly
        assert_eq!(c.effective_node_budgets(32, 4, 1), vec![128]);
        // even split
        assert_eq!(c.effective_node_budgets(32, 4, 2), vec![64, 64]);
        assert_eq!(c.effective_node_budgets(32, 4, 4), vec![32; 4]);
        // slot remainders go to the lowest node ids, sum is exact
        let c = ServingConfig {
            kv_blocks: Some(10),
            ..Default::default()
        };
        assert_eq!(c.effective_node_budgets(1, 1, 4), vec![3, 3, 2, 2]);
        assert_eq!(c.effective_node_budgets(1, 1, 4).iter().sum::<usize>(), 10);
        // zero-node input is clamped to one
        assert_eq!(c.effective_node_budgets(1, 1, 0), vec![10]);
    }

    #[test]
    fn node_budgets_deal_whole_sequence_slots_not_raw_blocks() {
        // batch 6 × 32 blocks over 4 nodes: a raw even split ([48; 4])
        // would fit only one lease per node (4 of 6 rows admissible, 64
        // blocks stranded); dealing slots keeps the full batch admissible
        let c = ServingConfig::default();
        let budgets = c.effective_node_budgets(32, 6, 4);
        assert_eq!(budgets, vec![64, 64, 32, 32]);
        assert_eq!(budgets.iter().sum::<usize>(), 192);
        assert_eq!(
            budgets.iter().map(|b| b / 32).sum::<usize>(),
            6,
            "every slot of the full batch must be admissible somewhere"
        );
        // capacity below one lease: nothing fits anywhere (never-fits),
        // but the accounting still sums to the configured total
        let tiny = ServingConfig {
            kv_blocks: Some(10),
            ..Default::default()
        };
        assert_eq!(tiny.effective_node_budgets(32, 1, 2), vec![5, 5]);
    }

    #[test]
    fn default_window() {
        let c = HgcaConfig::default();
        assert_eq!(c.window(), 256);
        c.validate().unwrap();
    }

    #[test]
    fn with_window_adjusts_blocks() {
        let c = HgcaConfig::default().with_window(1024);
        assert_eq!(c.blk_num, 32);
        assert_eq!(c.window(), 1024);
    }

    #[test]
    #[should_panic]
    fn unaligned_window_panics() {
        HgcaConfig::default().with_window(100);
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut c = HgcaConfig::default();
        c.alpha = 1.5;
        assert!(c.validate().is_err());
        let mut c = HgcaConfig::default();
        c.blk_size = 0;
        assert!(c.validate().is_err());
        let mut c = HgcaConfig::default();
        c.beta = -0.1;
        assert!(c.validate().is_err());
    }
}
