//! Rust-native reference transformer (oracle).
//!
//! Mirrors python/compile/model.py::full_forward exactly: byte vocab,
//! learned positions, pre-LN, GELU FFN, tied LM head. Used for
//! (a) cross-checking the PJRT artifact path, (b) the full-attention
//! reference in Table 1, and (c) the attention-pattern analysis
//! (Figs. 3–5) which needs per-layer attention probabilities.

use anyhow::{anyhow, Result};

use crate::config::ModelConfig;
use crate::tensor::ops::{affine, gelu_slice, layernorm, softmax_lse};
use crate::tensor::{Tensor, Weights};

pub struct LayerRefs<'a> {
    pub ln1_g: &'a Tensor,
    pub ln1_b: &'a Tensor,
    pub wq: &'a Tensor,
    pub bq: &'a Tensor,
    pub wk: &'a Tensor,
    pub bk: &'a Tensor,
    pub wv: &'a Tensor,
    pub bv: &'a Tensor,
    pub wo: &'a Tensor,
    pub bo: &'a Tensor,
    pub ln2_g: &'a Tensor,
    pub ln2_b: &'a Tensor,
    pub w1: &'a Tensor,
    pub b1: &'a Tensor,
    pub w2: &'a Tensor,
    pub b2: &'a Tensor,
}

pub struct RefModel {
    pub cfg: ModelConfig,
    pub weights: Weights,
}

impl RefModel {
    pub fn new(cfg: ModelConfig, weights: Weights) -> Result<RefModel> {
        // validate presence of every tensor up front
        for name in ["tok_emb", "pos_emb", "lnf_g", "lnf_b"] {
            if !weights.contains_key(name) {
                return Err(anyhow!("missing weight '{name}'"));
            }
        }
        for li in 0..cfg.n_layers {
            for f in [
                "ln1_g", "ln1_b", "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo", "ln2_g",
                "ln2_b", "w1", "b1", "w2", "b2",
            ] {
                let key = format!("layer{li}.{f}");
                if !weights.contains_key(&key) {
                    return Err(anyhow!("missing weight '{key}'"));
                }
            }
        }
        Ok(RefModel { cfg, weights })
    }

    pub fn layer(&self, li: usize) -> LayerRefs<'_> {
        let g = |f: &str| &self.weights[&format!("layer{li}.{f}")];
        LayerRefs {
            ln1_g: g("ln1_g"),
            ln1_b: g("ln1_b"),
            wq: g("wq"),
            bq: g("bq"),
            wk: g("wk"),
            bk: g("bk"),
            wv: g("wv"),
            bv: g("bv"),
            wo: g("wo"),
            bo: g("bo"),
            ln2_g: g("ln2_g"),
            ln2_b: g("ln2_b"),
            w1: g("w1"),
            b1: g("b1"),
            w2: g("w2"),
            b2: g("b2"),
        }
    }

    /// Full causal forward over `tokens`; returns logits [T][vocab] and,
    /// when `capture` is set, per-layer attention probabilities
    /// probs[layer][h][t][0..=t].
    pub fn forward(
        &self,
        tokens: &[u8],
        capture: bool,
    ) -> (Vec<Vec<f32>>, Vec<Vec<Vec<Vec<f32>>>>) {
        let cfg = &self.cfg;
        let (t_len, d, h_n, dh) = (tokens.len(), cfg.d_model, cfg.n_heads, cfg.d_head());
        let scale = 1.0 / (dh as f32).sqrt();
        let tok_emb = &self.weights["tok_emb"];
        let pos_emb = &self.weights["pos_emb"];

        // hidden [T][D]
        let mut hidden = vec![0.0f32; t_len * d];
        for (t, &tok) in tokens.iter().enumerate() {
            let e = &tok_emb.data[tok as usize * d..(tok as usize + 1) * d];
            let p = &pos_emb.data[t * d..(t + 1) * d];
            for j in 0..d {
                hidden[t * d + j] = e[j] + p[j];
            }
        }

        let mut all_probs = Vec::new();
        let mut x = vec![0.0f32; d];
        for li in 0..cfg.n_layers {
            let lw = self.layer(li);
            // per-head caches for this layer
            let mut q = vec![0.0f32; t_len * d];
            let mut k = vec![0.0f32; t_len * d];
            let mut v = vec![0.0f32; t_len * d];
            for t in 0..t_len {
                layernorm(&hidden[t * d..(t + 1) * d], &lw.ln1_g.data, &lw.ln1_b.data, &mut x);
                affine(&x, lw.wq, &lw.bq.data, &mut q[t * d..(t + 1) * d]);
                affine(&x, lw.wk, &lw.bk.data, &mut k[t * d..(t + 1) * d]);
                affine(&x, lw.wv, &lw.bv.data, &mut v[t * d..(t + 1) * d]);
            }
            for qv in q.iter_mut() {
                *qv *= scale;
            }
            let mut layer_probs: Vec<Vec<Vec<f32>>> = if capture {
                vec![Vec::with_capacity(t_len); h_n]
            } else {
                Vec::new()
            };
            // attention per (t, head): causal over 0..=t
            let mut o = vec![0.0f32; t_len * d];
            let mut scores: Vec<f32> = Vec::with_capacity(t_len);
            for t in 0..t_len {
                for h in 0..h_n {
                    let qh = &q[t * d + h * dh..t * d + (h + 1) * dh];
                    scores.clear();
                    for s in 0..=t {
                        let kh = &k[s * d + h * dh..s * d + (h + 1) * dh];
                        scores.push(crate::tensor::ops::dot(qh, kh));
                    }
                    softmax_lse(&mut scores);
                    let oh = &mut o[t * d + h * dh..t * d + (h + 1) * dh];
                    for (s, &w) in scores.iter().enumerate() {
                        let vh = &v[s * d + h * dh..s * d + (h + 1) * dh];
                        for j in 0..dh {
                            oh[j] += w * vh[j];
                        }
                    }
                    if capture {
                        layer_probs[h].push(scores.clone());
                    }
                }
            }
            if capture {
                all_probs.push(layer_probs);
            }
            // post-attention: projection + residual + FFN
            let mut y = vec![0.0f32; d];
            let mut f1 = vec![0.0f32; cfg.d_ffn];
            let mut f2 = vec![0.0f32; d];
            for t in 0..t_len {
                affine(&o[t * d..(t + 1) * d], lw.wo, &lw.bo.data, &mut y);
                let hrow = &mut hidden[t * d..(t + 1) * d];
                for j in 0..d {
                    hrow[j] += y[j];
                }
                layernorm(hrow, &lw.ln2_g.data, &lw.ln2_b.data, &mut x);
                affine(&x, lw.w1, &lw.b1.data, &mut f1);
                gelu_slice(&mut f1);
                affine(&f1, lw.w2, &lw.b2.data, &mut f2);
                for j in 0..d {
                    hrow[j] += f2[j];
                }
            }
        }

        // LM head (tied): logits[t][v] = ln_f(h) @ tok_emb^T
        let lnf_g = &self.weights["lnf_g"];
        let lnf_b = &self.weights["lnf_b"];
        let vcb = cfg.vocab;
        let mut logits = vec![vec![0.0f32; vcb]; t_len];
        for t in 0..t_len {
            layernorm(&hidden[t * d..(t + 1) * d], &lnf_g.data, &lnf_b.data, &mut x);
            for tok in 0..vcb {
                logits[t][tok] =
                    crate::tensor::ops::dot(&x, &tok_emb.data[tok * d..(tok + 1) * d]);
            }
        }
        (logits, all_probs)
    }

    /// Teacher-forced perplexity over a byte string (full attention).
    pub fn perplexity(&self, text: &[u8]) -> f64 {
        let (logits, _) = self.forward(text, false);
        let mut nll = 0.0f64;
        let n = text.len() - 1;
        for t in 0..n {
            nll -= crate::tensor::ops::log_softmax_at(&logits[t], text[t + 1] as usize) as f64;
        }
        (nll / n as f64).exp()
    }
}

/// Synthetic random weights for tests that don't need trained artifacts.
pub fn random_weights(cfg: &ModelConfig, seed: u64) -> Weights {
    use crate::util::rng::Rng;
    fn add(w: &mut Weights, name: String, shape: &[usize], rng: &mut Rng, std: f32) {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        w.insert(name, t);
    }
    let mut rng = Rng::new(seed);
    let mut w = Weights::new();
    let d = cfg.d_model;
    add(&mut w, "tok_emb".into(), &[cfg.vocab, d], &mut rng, 0.02);
    add(&mut w, "pos_emb".into(), &[cfg.max_pos, d], &mut rng, 0.02);
    for li in 0..cfg.n_layers {
        for f in ["wq", "wk", "wv", "wo"] {
            add(&mut w, format!("layer{li}.{f}"), &[d, d], &mut rng, 0.02);
        }
        for f in ["bq", "bk", "bv", "bo", "ln1_b", "ln2_b"] {
            add(&mut w, format!("layer{li}.{f}"), &[d], &mut rng, 0.0);
        }
        add(&mut w, format!("layer{li}.w1"), &[d, cfg.d_ffn], &mut rng, 0.02);
        add(&mut w, format!("layer{li}.b1"), &[cfg.d_ffn], &mut rng, 0.0);
        add(&mut w, format!("layer{li}.w2"), &[cfg.d_ffn, d], &mut rng, 0.02);
        add(&mut w, format!("layer{li}.b2"), &[d], &mut rng, 0.0);
        for f in ["ln1_g", "ln2_g"] {
            let t = Tensor::full(&[d], 1.0);
            w.insert(format!("layer{li}.{f}"), t);
        }
    }
    w.insert("lnf_g".into(), Tensor::full(&[d], 1.0));
    w.insert("lnf_b".into(), Tensor::zeros(&[d]));
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::trained;

    fn small_model() -> RefModel {
        let mut cfg = trained("tiny-small").unwrap();
        cfg.max_pos = 64; // keep the random pos_emb small for tests
        let w = random_weights(&cfg, 42);
        RefModel::new(cfg, w).unwrap()
    }

    #[test]
    fn forward_shapes() {
        let m = small_model();
        let (logits, probs) = m.forward(b"hello", true);
        assert_eq!(logits.len(), 5);
        assert_eq!(logits[0].len(), 256);
        assert_eq!(probs.len(), m.cfg.n_layers);
        assert_eq!(probs[0].len(), m.cfg.n_heads);
        assert_eq!(probs[0][0][3].len(), 4); // causal: t=3 sees 4 entries
    }

    #[test]
    fn probs_rows_sum_to_one() {
        let m = small_model();
        let (_, probs) = m.forward(b"abcdef", true);
        for lp in &probs {
            for hp in lp {
                for row in hp {
                    let s: f32 = row.iter().sum();
                    assert!((s - 1.0).abs() < 1e-5);
                }
            }
        }
    }

    #[test]
    fn causality_prefix_invariance() {
        // logits at position t must not depend on later tokens
        let m = small_model();
        let (a, _) = m.forward(b"abcXYZ", false);
        let (b, _) = m.forward(b"abcQQQ", false);
        for j in 0..256 {
            assert!((a[2][j] - b[2][j]).abs() < 1e-5);
        }
    }

    #[test]
    fn missing_weight_rejected() {
        let mut cfg = trained("tiny-small").unwrap();
        cfg.max_pos = 16;
        let mut w = random_weights(&cfg, 0);
        w.remove("layer1.wq");
        assert!(RefModel::new(cfg, w).is_err());
    }

    #[test]
    fn perplexity_finite_positive() {
        let m = small_model();
        let p = m.perplexity(b"the quick brown fox");
        assert!(p.is_finite() && p > 1.0);
    }
}
