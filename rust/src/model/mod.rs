//! Model substrate: the rust reference transformer (oracle), samplers.

pub mod sampler;
pub mod transformer;

pub use sampler::Sampler;
pub use transformer::{random_weights, RefModel};
