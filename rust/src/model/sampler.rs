//! Token sampling: greedy and temperature sampling over byte logits.

use crate::tensor::ops::argmax;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub enum Sampler {
    Greedy,
    Temperature { t: f32, seed: u64 },
}

impl Sampler {
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> u8 {
        match self {
            Sampler::Greedy => argmax(logits) as u8,
            Sampler::Temperature { t, .. } => {
                let mut p: Vec<f32> = logits.iter().map(|&l| l / t.max(1e-3)).collect();
                crate::tensor::ops::softmax_lse(&mut p);
                let r = rng.f32();
                let mut acc = 0.0;
                for (i, &w) in p.iter().enumerate() {
                    acc += w;
                    if r < acc {
                        return i as u8;
                    }
                }
                (p.len() - 1) as u8
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut l = vec![0.0f32; 256];
        l[65] = 10.0;
        let mut rng = Rng::new(0);
        assert_eq!(Sampler::Greedy.sample(&l, &mut rng), 65);
    }

    #[test]
    fn temperature_respects_strong_peak() {
        let mut l = vec![-50.0f32; 256];
        l[66] = 50.0;
        let mut rng = Rng::new(0);
        let s = Sampler::Temperature { t: 1.0, seed: 0 };
        for _ in 0..10 {
            assert_eq!(s.sample(&l, &mut rng), 66);
        }
    }

    #[test]
    fn temperature_sampling_is_diverse_on_flat() {
        let l = vec![0.0f32; 256];
        let mut rng = Rng::new(1);
        let s = Sampler::Temperature { t: 1.0, seed: 0 };
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..64 {
            seen.insert(s.sample(&l, &mut rng));
        }
        assert!(seen.len() > 16);
    }
}
