//! Attention-pattern analysis over the trained model — regenerates the
//! paper's motivation figures (Figs. 3–5) from real attention
//! probabilities captured by the rust oracle forward.

/// probs[h][t] is the softmax row of query t at one layer (len t+1).
pub type LayerProbs = Vec<Vec<Vec<f32>>>;

/// Fig. 3: cumulative attention mass of the last query inside a
/// (start-window × recent-window) grid, averaged over heads.
/// Returns grid[si][ri] ∈ [0, 1].
pub fn cumulative_heatmap(
    probs: &LayerProbs,
    start_windows: &[usize],
    recent_windows: &[usize],
) -> Vec<Vec<f32>> {
    let heads = probs.len();
    let t_last = probs[0].len() - 1;
    let row_len = t_last + 1;
    let mut grid = vec![vec![0.0f32; recent_windows.len()]; start_windows.len()];
    for (si, &s) in start_windows.iter().enumerate() {
        for (ri, &r) in recent_windows.iter().enumerate() {
            let mut total = 0.0f32;
            for hp in probs.iter() {
                let row = &hp[t_last];
                let start_sum: f32 = row[..s.min(row_len)].iter().sum();
                let recent_from = row_len.saturating_sub(r).max(s.min(row_len));
                let recent_sum: f32 = row[recent_from..].iter().sum();
                total += start_sum + recent_sum;
            }
            grid[si][ri] = total / heads as f32;
        }
    }
    let _ = heads;
    grid
}

/// Fig. 4: fraction of KV entries needed to reach `target` cumulative
/// attention per head (at the last query of the captured layer).
pub fn coverage_per_head(probs: &LayerProbs, target: f32) -> Vec<f32> {
    probs
        .iter()
        .map(|hp| {
            let row = hp.last().unwrap();
            let mut sorted = row.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let mut acc = 0.0f32;
            let mut k = 0usize;
            for &w in &sorted {
                acc += w;
                k += 1;
                if acc >= target {
                    break;
                }
            }
            k as f32 / row.len() as f32
        })
        .collect()
}

/// Fig. 5: attention weight by KV position for one head at one decoding
/// position (query index `t`).
pub fn positional_weights(probs: &LayerProbs, head: usize, t: usize) -> Vec<f32> {
    probs[head][t].clone()
}

/// Entries needed (by position, greedy-by-weight) to reach `target`
/// cumulative mass — the paper's red-line threshold in Fig. 5.
pub fn critical_set(row: &[f32], target: f32) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..row.len()).collect();
    idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
    let mut acc = 0.0;
    let mut out = Vec::new();
    for &i in &idx {
        if acc >= target {
            break;
        }
        acc += row[i];
        out.push(i);
    }
    out.sort_unstable();
    out
}

/// Skewness proxy: share of mass held by the top-10% entries, averaged
/// over heads (used to show entry→exit layer skew growth, Fig. 3's trend).
pub fn top_decile_mass(probs: &LayerProbs) -> f32 {
    let mut total = 0.0f32;
    for hp in probs.iter() {
        let row = hp.last().unwrap();
        let mut sorted = row.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let k = (sorted.len() / 10).max(1);
        total += sorted[..k].iter().sum::<f32>();
    }
    total / probs.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    /// hand-built layer: 2 heads, 4 queries; head 0 peaked on slot 0,
    /// head 1 uniform.
    fn demo() -> LayerProbs {
        let mut h0 = Vec::new();
        let mut h1 = Vec::new();
        for t in 0..4usize {
            let n = t + 1;
            let mut peaked = vec![0.05 / n as f32; n];
            peaked[0] += 0.95;
            let s: f32 = peaked.iter().sum();
            for p in peaked.iter_mut() {
                *p /= s;
            }
            h0.push(peaked);
            h1.push(vec![1.0 / n as f32; n]);
        }
        vec![h0, h1]
    }

    #[test]
    fn heatmap_monotone_in_windows() {
        let probs = demo();
        let grid = cumulative_heatmap(&probs, &[0, 1, 2], &[0, 1, 2]);
        // larger windows capture at least as much mass
        for si in 0..3 {
            for ri in 1..3 {
                assert!(grid[si][ri] >= grid[si][ri - 1] - 1e-6);
            }
        }
        for ri in 0..3 {
            for si in 1..3 {
                assert!(grid[si][ri] >= grid[si - 1][ri] - 1e-6);
            }
        }
        // full coverage reaches ~1
        let full = cumulative_heatmap(&probs, &[4], &[4]);
        assert!((full[0][0] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn coverage_separates_peaked_from_uniform() {
        let cov = coverage_per_head(&demo(), 0.95);
        assert!(cov[0] < cov[1], "peaked head needs fewer entries: {cov:?}");
        assert!((cov[1] - 1.0).abs() < 0.26); // uniform needs ~all
    }

    #[test]
    fn critical_set_reaches_target() {
        let row = vec![0.5, 0.1, 0.05, 0.3, 0.05];
        let set = critical_set(&row, 0.8);
        let mass: f32 = set.iter().map(|&i| row[i]).sum();
        assert!(mass >= 0.8);
        assert!(set.contains(&0) && set.contains(&3));
    }

    #[test]
    fn top_decile_higher_for_peaked() {
        let peaked = demo();
        let uniform: LayerProbs = vec![peaked[1].clone(), peaked[1].clone()];
        assert!(top_decile_mass(&peaked) > top_decile_mass(&uniform));
    }
}
