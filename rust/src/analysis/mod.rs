//! Attention-pattern analysis toolkit (paper §2.3, Figs. 3–5).

pub mod attn_stats;

pub use attn_stats::{coverage_per_head, critical_set, cumulative_heatmap, positional_weights, top_decile_mass, LayerProbs};
