//! `hgca` — leader binary: serve / generate / ppl / analyze / simulate.
//!
//! Python never runs here; the binary is self-contained once
//! `make artifacts` has produced the compiled HLO + weights.

use std::path::PathBuf;
use std::rc::Rc;

use anyhow::Result;

use hgca::config::HgcaConfig;
use hgca::engine::{Engine, Policy};
use hgca::runtime::PjrtRuntime;
use hgca::util::argparse::Args;

const USAGE: &str = "\
hgca — Hybrid GPU-CPU Attention serving engine (paper reproduction)

USAGE:
  hgca serve    [--addr 127.0.0.1:8471] [--model tiny] [--policy hgca] [--beta 1.0]
                [--batch 4] [--prefill-budget TOKENS]   # prompt tokens absorbed per tick
                [--deadline-default MS]   # deadline applied when a request has none
                [--shed-watermark N]      # reject admissions (429) past N pending
                [--max-queue-ticks N]     # shed queued requests waiting > N ticks
                [--kv-blocks N]           # hard GPU KV pool capacity (blocks);
                                          # default: model shape × batch × headroom
                [--kv-headroom F]         # derived-capacity factor (default 1.0)
                [--numa-nodes N]          # NUMA execution domains (default: detect
                                          # from HGCA_NUMA_NODES / sysfs; 1 = flat).
                                          # Shards the attention pool, KV stores,
                                          # and block budgets per node
                [--prefix-cache]          # cross-request prefix KV reuse (radix
                                          # cache); tokens are bitwise identical
                                          # either way — pair with --kv-blocks or
                                          # --kv-headroom > 1 so spare blocks exist
                [--prefix-cache-entries N]  # resident cached prefixes cap (default 32)
                [--kv-tier f32|int8|auto] # CPU KV storage tier: f32 (default,
                                          # bitwise-identical to the untiered
                                          # engine), int8 (quantize every head),
                                          # auto (per-head from attention stats)
                # admission is earliest-deadline-first, gated on KV block
                # availability; POST /v1/generate accepts "stream": true for
                # chunked-transfer token streaming, "deadline_ms" per request,
                # and POST /v1/cancel {"id": N} cancels mid-flight; see
                # docs/API.md + docs/SCHEDULING.md
  hgca generate --prompt TEXT [--max-new 64] [--model tiny] [--policy hgca]
  hgca ppl      [--len 512] [--model tiny] [--policy hgca] [--beta 1.0] [--window 256]
  hgca analyze  [--model tiny] [--len 256]      # attention-pattern stats (Figs. 3-5)
  hgca simulate [--system hgca|flexgen|h2o|infinigen|hf] [--model opt-6.7b] [--batch 4]
  hgca replay   FILE.scn ... [--nodes N] [--seed N] [--json PATH] [--verify]
                [--prefix-cache] [--no-prefix-cache] [--kv-tier f32|int8|auto]
                # replay scenario-DSL workload traces (docs/SCENARIOS.md)
                # against the real serving stack; --verify re-runs each
                # scenario (same seed twice, then 1/2/4 synthetic NUMA
                # nodes) and fails unless outcomes are bitwise identical;
                # --json writes the gate-ready report (tools/scenario_gate.rs);
                # the prefix cache auto-enables for scenarios that declare
                # share_prefix/turns — the flags force it on or off
  hgca info                                     # manifest + artifact inventory

COMMON FLAGS:
  --artifacts DIR   artifact directory (default: ./artifacts)
  --window N        GPU KV window (must match a compiled artifact; default 256)
  --threads N       CPU attention threads (default 4)
  --simd LEVEL      SIMD kernel dispatch: auto (default; runtime feature
                    detection), avx2, sse4, neon, or scalar. Applies
                    process-wide and freezes at startup; HGCA_SIMD env is
                    the same override with lower precedence. dot_i8 is
                    bitwise-identical across levels, f32 kernels within
                    1e-5; tokens are bitwise-stable within a level
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_policy(args: &Args) -> Result<Policy> {
    let beta = args.f64("beta", 1.0)? as f32;
    Ok(match args.get_or("policy", "hgca") {
        "hgca" => Policy::Hgca { beta },
        "gpu-only" | "hf" => Policy::GpuOnly,
        "full-offload" | "flexgen" => Policy::FullOffload,
        "h2o" => Policy::H2o { frac: args.f64("frac", 0.2)? as f32 },
        "infinigen" => Policy::Infinigen { frac: args.f64("frac", 0.2)? as f32 },
        "static" => Policy::Static {
            sinks: args.usize("sinks", 4)?,
            recent: args.usize("recent", 64)?,
        },
        other => anyhow::bail!("unknown policy '{other}'"),
    })
}

fn engine_config(args: &Args) -> Result<HgcaConfig> {
    let mut cfg = HgcaConfig {
        beta: args.f64("beta", 1.0)? as f32,
        cpu_threads: args.usize("threads", 4)?,
        alpha: args.f64("alpha", 0.3)? as f32,
        kv_tier: hgca::kv::TierMode::parse(args.get_or("kv-tier", "f32"))?,
        simd: hgca::tensor::simd::SimdLevel::parse(args.get_or("simd", "auto"))?,
        ..Default::default()
    };
    cfg = cfg.with_window(args.usize("window", 256)?);
    cfg.validate()?;
    Ok(cfg)
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..], &["full", "verify", "prefix-cache", "no-prefix-cache"])?;
    // Freeze the SIMD dispatch level before anything can touch a kernel
    // (model warmup and the attention pool both hit the hot loops):
    // --simd flag > HGCA_SIMD env > runtime feature detection. The table
    // freezes exactly once per process, so this must precede model/pool
    // setup or a later override would be rejected.
    let simd_request = hgca::tensor::simd::SimdLevel::parse(args.get_or("simd", "auto"))?;
    let simd_level = hgca::tensor::simd::configure(simd_request)?;
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));

    match cmd.as_str() {
        "info" => {
            let rt = PjrtRuntime::new(&dir)?;
            println!("platform: {}", rt.client.platform_name());
            println!("simd dispatch: {simd_level}");
            println!("models:");
            for (name, cfg) in &rt.manifest.models {
                println!(
                    "  {name}: {} layers, d={}, {} heads, {} params",
                    cfg.n_layers,
                    cfg.d_model,
                    cfg.n_heads,
                    cfg.param_count()
                );
            }
            println!("artifacts: {}", rt.manifest.artifacts.len());
            for a in &rt.manifest.artifacts {
                println!("  {} (b={}, w={}, n={})", a.name, a.batch, a.window,
                         a.inputs.first().map(|i| *i.shape.get(1).unwrap_or(&1)).unwrap_or(1));
            }
        }
        "generate" => {
            let rt = Rc::new(PjrtRuntime::new(&dir)?);
            let mr = rt.load_model(args.get_or("model", "tiny"))?;
            let cfg = engine_config(&args)?;
            let policy = parse_policy(&args)?;
            let mut engine = Engine::new(&mr, cfg, policy);
            let prompt = args
                .get("prompt")
                .ok_or_else(|| anyhow::anyhow!("--prompt required"))?
                .as_bytes()
                .to_vec();
            let max_new = args.usize("max-new", 64)?;
            let mut seq = engine.new_sequence(0, &prompt);
            let out = engine.generate(&mut seq, max_new)?;
            println!("{}", String::from_utf8_lossy(&out));
            let m = &engine.metrics;
            eprintln!(
                "# {} tokens, wall {:.1} tok/s, sim {:.1} tok/s, gpu-kv {}, cpu-kv {}",
                out.len(),
                m.throughput(),
                m.sim_throughput(),
                hgca::util::fmt_bytes(m.peak_gpu_kv_bytes as u64),
                hgca::util::fmt_bytes(m.peak_cpu_kv_bytes as u64),
            );
        }
        "ppl" => {
            let rt = Rc::new(PjrtRuntime::new(&dir)?);
            let mr = rt.load_model(args.get_or("model", "tiny"))?;
            let cfg = engine_config(&args)?;
            let policy = parse_policy(&args)?;
            let text = load_eval_text(&args)?;
            let len = args.usize("len", 512)?.min(text.len());
            let mut engine = Engine::new(&mr, cfg, policy);
            let ppl = engine.perplexity(&text[..len], 32)?;
            println!("policy={} len={len} ppl={ppl:.4}", engine.policy.name());
        }
        "analyze" => {
            let rt = Rc::new(PjrtRuntime::new(&dir)?);
            let mr = rt.load_model(args.get_or("model", "tiny"))?;
            let model = hgca::model::RefModel::new(mr.cfg.clone(), mr.weights.clone())?;
            let text = load_eval_text(&args)?;
            let len = args.usize("len", 256)?.min(text.len());
            let (_, probs) = model.forward(&text[..len], true);
            println!("layer  top10%mass  min_cov99  max_cov99");
            for (li, lp) in probs.iter().enumerate() {
                let cov = hgca::analysis::coverage_per_head(lp, 0.99);
                let mass = hgca::analysis::top_decile_mass(lp);
                let (mn, mx) = (
                    cov.iter().cloned().fold(f32::INFINITY, f32::min),
                    cov.iter().cloned().fold(0.0f32, f32::max),
                );
                println!("{li:>5}  {mass:>10.3}  {mn:>9.3}  {mx:>9.3}");
            }
        }
        "simulate" => {
            use hgca::baselines::{simulate_generation, E2eConfig, SystemKind};
            use hgca::simulator::Testbed;
            let system = match args.get_or("system", "hgca") {
                "hgca" => SystemKind::Hgca,
                "flexgen" => SystemKind::FlexGen,
                "h2o" => SystemKind::H2o,
                "infinigen" => SystemKind::Infinigen,
                "hf" => SystemKind::HfFull,
                other => anyhow::bail!("unknown system '{other}'"),
            };
            let model = hgca::config::model::lookup(args.get_or("model", "opt-6.7b"))
                .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
            let cfg = E2eConfig {
                system,
                batch: args.usize("batch", 4)?,
                prefill: args.usize("prefill", 1920)?,
                gen: args.usize("gen", 128)?,
                gpu_weight_frac: args.f64("weight-frac", 1.0)?,
                window: args.usize("window", 1024)?,
                n_gpus: args.usize("gpus", 1)?,
                ..Default::default()
            };
            let r = simulate_generation(&Testbed::paper(), &model, &cfg);
            println!(
                "system={} model={} batch={} → total {:.2}s (prefill {:.2}s, decode {:.2}s) \
                 {:.1} tok/s | peak gpu {} host {}{}",
                args.get_or("system", "hgca"),
                model.name,
                cfg.batch,
                r.total_secs,
                r.prefill_secs,
                r.decode_secs,
                r.tokens_per_sec,
                hgca::util::fmt_bytes(r.peak_gpu_bytes as u64),
                hgca::util::fmt_bytes(r.peak_host_bytes as u64),
                if r.oom { " [OOM]" } else { "" },
            );
            for (l, s) in &r.breakdown.segments {
                println!("  {l:<18} {}", hgca::util::fmt_secs(*s));
            }
        }
        "replay" => {
            use hgca::engine::FinishReason;
            use hgca::simulator::trace::{parse, replay, ReplayOptions, ReplayReport};
            use hgca::util::json::Json;
            anyhow::ensure!(
                !args.positional.is_empty(),
                "usage: hgca replay FILE.scn ... [--nodes N] [--seed N] [--json PATH] [--verify]"
            );
            let rt = Rc::new(PjrtRuntime::new(&dir)?);
            let mr = rt.load_model(args.get_or("model", "tiny"))?;
            let cfg = engine_config(&args)?;
            let policy = parse_policy(&args)?;
            let nodes = args.usize("nodes", 1)?;
            anyhow::ensure!(nodes >= 1, "--nodes must be ≥ 1");
            let seed = match args.get("seed") {
                Some(s) => Some(
                    s.parse::<u64>()
                        .map_err(|_| anyhow::anyhow!("--seed: expected integer, got '{s}'"))?,
                ),
                None => None,
            };
            // None lets replay() auto-enable the prefix cache for scenarios
            // that declare share_prefix/turns; the flags force it either way
            let prefix_cache = if args.flag("prefix-cache") {
                Some(true)
            } else if args.flag("no-prefix-cache") {
                Some(false)
            } else {
                None
            };
            let mut entries = Vec::new();
            for path in &args.positional {
                let src = std::fs::read_to_string(path)
                    .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                let scn = parse(&src).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                // every run gets a fresh engine: the engine RNG seeds at
                // construction, which is what makes runs comparable at all
                let run = |n: usize| -> Result<ReplayReport> {
                    let mut engine = Engine::new(&mr, cfg.clone(), policy.clone());
                    replay(&mut engine, &scn, &ReplayOptions { nodes: n, seed, prefix_cache })
                };
                let mut report = run(nodes)?;
                // a tiered run is a different workload for gating purposes:
                // suffix the scenario name so its report row matches a
                // distinct baseline entry (e.g. steady_decode_int8)
                if cfg.kv_tier != hgca::kv::TierMode::F32 {
                    report.scenario = format!("{}_{}", report.scenario, cfg.kv_tier.name());
                }
                if args.flag("verify") {
                    let again = run(nodes)?;
                    anyhow::ensure!(
                        again.outcomes == report.outcomes,
                        "{}: outcomes differ between two same-seed runs",
                        scn.name
                    );
                    for n in [1usize, 2, 4] {
                        if n != nodes {
                            let alt = run(n)?;
                            anyhow::ensure!(
                                alt.outcomes == report.outcomes,
                                "{}: outcomes differ between {nodes} and {n} synthetic NUMA nodes",
                                scn.name
                            );
                        }
                    }
                }
                println!(
                    "{}: {} requests, {} ticks, peak {}/{} active/queued — \
                     {} completed, {} shed, {} cancelled, {} disconnected — \
                     digest {:016x}{}",
                    report.scenario,
                    report.outcomes.len(),
                    report.ticks,
                    report.peak_active,
                    report.peak_queued,
                    report.count(FinishReason::Length),
                    report.count(FinishReason::QueueTimeout),
                    report.count(FinishReason::Cancelled),
                    report.count(FinishReason::Disconnected),
                    report.digest(),
                    if args.flag("verify") { " [verified]" } else { "" },
                );
                entries.push(report.to_json());
            }
            if let Some(out) = args.get("json") {
                let doc = Json::obj(vec![
                    ("schema", Json::num(1.0)),
                    ("scenarios", Json::arr(entries)),
                ]);
                std::fs::write(out, format!("{doc}\n"))
                    .map_err(|e| anyhow::anyhow!("{out}: {e}"))?;
                println!("report written to {out}");
            }
        }
        "serve" => {
            // resolve the NUMA topology FIRST: the global attention pool
            // freezes its topology at first use, and model warmup below
            // already submits to it — parsing --numa-nodes any later
            // would silently hand global-pool callers a flat pool
            let topology = match args.get("numa-nodes") {
                Some(n) => {
                    let n: usize = n
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--numa-nodes: expected integer"))?;
                    anyhow::ensure!(n >= 1, "--numa-nodes must be ≥ 1");
                    hgca::topology::Topology::synthetic(n)
                }
                None => hgca::topology::Topology::detect(),
            };
            if !hgca::attention::AttnPool::init_global(topology.clone()) {
                eprintln!(
                    "warning: attention pool was initialized before serve parsed its flags; \
                     --numa-nodes applies to KV budgets and placement only"
                );
            }
            let rt = Rc::new(PjrtRuntime::new(&dir)?);
            let mr = rt.load_model(args.get_or("model", "tiny"))?;
            mr.warmup()?;
            let cfg = engine_config(&args)?;
            let policy = parse_policy(&args)?;
            let mut engine = Engine::new(&mr, cfg, policy);
            engine.set_topology(topology.clone());
            let addr = args.get_or("addr", "127.0.0.1:8471").to_string();
            let (tx, rx) = std::sync::mpsc::channel();
            let (local, _handle) = hgca::server::serve(&addr, tx)?;
            println!(
                "hgca serving on http://{local} (policy={}, simd={simd_level})",
                engine.policy.name()
            );
            let mut batcher = hgca::engine::Batcher::new(args.usize("batch", 4)?);
            if let Some(budget) = args.get("prefill-budget") {
                batcher = batcher.with_prefill_budget(budget.parse()?);
            }
            let serving = hgca::config::ServingConfig {
                deadline_default_ms: match args.get("deadline-default") {
                    Some(ms) => Some(ms.parse()?),
                    None => None,
                },
                shed_watermark: match args.get("shed-watermark") {
                    Some(n) => Some(n.parse()?),
                    None => None,
                },
                max_queue_ticks: match args.get("max-queue-ticks") {
                    Some(n) => Some(n.parse()?),
                    None => None,
                },
                kv_blocks: match args.get("kv-blocks") {
                    Some(n) => Some(n.parse()?),
                    None => None,
                },
                kv_headroom: args.f64("kv-headroom", 1.0)?,
                prefix_cache: args.flag("prefix-cache"),
                prefix_cache_entries: args.usize("prefix-cache-entries", 32)?,
            };
            serving.validate()?;
            // resolve the pool capacity once and pin it as the explicit
            // value, so the line logged here is by construction the one
            // the engine loop enforces (the loop splits it across the
            // topology's nodes)
            let capacity = serving.effective_kv_blocks(engine.blocks_per_sequence(), batcher.batch);
            let serving = hgca::config::ServingConfig {
                kv_blocks: Some(capacity),
                ..serving
            };
            let budgets = serving.effective_node_budgets(
                engine.blocks_per_sequence(),
                batcher.batch,
                topology.nodes(),
            );
            println!(
                "kv pool: {capacity} blocks capacity ({} per sequence, {} batch rows); \
                 numa: {topology}, node budgets {budgets:?}",
                engine.blocks_per_sequence(),
                batcher.batch,
            );
            hgca::server::api::engine_loop_with(&mut engine, rx, batcher, serving)?;
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

fn load_eval_text(args: &Args) -> Result<Vec<u8>> {
    let path = args.get_or("text", "data/corpus.txt");
    // generated deterministically when the file is missing (same bytes as
    // the python exporter — see util::corpus)
    hgca::util::corpus::ensure_corpus(std::path::Path::new(path))
}
