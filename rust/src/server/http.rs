//! Minimal HTTP/1.1 server over std::net (tokio/hyper are not in the
//! vendored registry). One acceptor thread + a worker pool feeding the
//! single-threaded engine loop through channels — Python never appears on
//! this path; the engine thread owns the PJRT runtime.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{Receiver, Sender};

use anyhow::{anyhow, Result};

#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: String,
}

#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub body: String,
}

impl HttpResponse {
    pub fn json(status: u16, body: String) -> HttpResponse {
        HttpResponse { status, body }
    }
}

pub fn parse_request(stream: &mut TcpStream) -> Result<HttpRequest> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| anyhow!("bad request line"))?.to_string();
    let path = parts.next().ok_or_else(|| anyhow!("bad request line"))?.to_string();
    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_len = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_len];
    if content_len > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(HttpRequest {
        method,
        path,
        body: String::from_utf8_lossy(&body).to_string(),
    })
}

pub fn write_response(stream: &mut TcpStream, resp: &HttpResponse) -> Result<()> {
    let reason = match resp.status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Error",
    };
    write!(
        stream,
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        resp.status,
        reason,
        resp.body.len(),
        resp.body
    )?;
    stream.flush()?;
    Ok(())
}

/// A parsed request paired with a one-shot reply channel.
pub struct Incoming {
    pub req: HttpRequest,
    pub reply: Sender<HttpResponse>,
}

/// Accept loop: parses each connection and forwards it to the engine
/// thread; replies synchronously when the engine answers. Returns the
/// bound local address (port 0 supported for tests).
pub fn serve(addr: &str, tx: Sender<Incoming>) -> Result<(std::net::SocketAddr, std::thread::JoinHandle<()>)> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let handle = std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut stream) = stream else { continue };
            let tx = tx.clone();
            std::thread::spawn(move || {
                let resp = match parse_request(&mut stream) {
                    Ok(req) => {
                        let (rtx, rrx): (Sender<HttpResponse>, Receiver<HttpResponse>) =
                            std::sync::mpsc::channel();
                        if tx.send(Incoming { req, reply: rtx }).is_ok() {
                            rrx.recv().unwrap_or(HttpResponse::json(
                                500,
                                r#"{"error":"engine gone"}"#.into(),
                            ))
                        } else {
                            HttpResponse::json(500, r#"{"error":"server shutting down"}"#.into())
                        }
                    }
                    Err(e) => HttpResponse::json(400, format!(r#"{{"error":"{e}"}}"#)),
                };
                let _ = write_response(&mut stream, &resp);
            });
        }
    });
    Ok((local, handle))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_over_loopback() {
        let (tx, rx) = std::sync::mpsc::channel();
        let (addr, _h) = serve("127.0.0.1:0", tx).unwrap();
        // echo engine
        std::thread::spawn(move || {
            for inc in rx {
                let body = format!(
                    r#"{{"path":"{}","echo":{}}}"#,
                    inc.req.path,
                    if inc.req.body.is_empty() { "null".into() } else { inc.req.body.clone() }
                );
                let _ = inc.reply.send(HttpResponse::json(200, body));
            }
        });
        let mut s = TcpStream::connect(addr).unwrap();
        write!(
            s,
            "POST /gen HTTP/1.1\r\nContent-Length: 8\r\n\r\n{{\"a\": 1}}"
        )
        .unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200"));
        assert!(out.contains(r#""path":"/gen""#));
        assert!(out.contains(r#""a": 1"#));
    }

    #[test]
    fn bad_request_line_is_400() {
        let (tx, _rx) = std::sync::mpsc::channel();
        let (addr, _h) = serve("127.0.0.1:0", tx).unwrap();
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 400"));
    }
}
