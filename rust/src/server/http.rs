//! Minimal HTTP/1.1 server over std::net (tokio/hyper are not in the
//! vendored registry). One acceptor thread + a worker pool feeding the
//! single-threaded engine loop through channels — Python never appears on
//! this path; the engine thread owns the PJRT runtime.
//!
//! Two reply shapes (see [`ServerReply`]): a complete JSON response in one
//! shot, or a chunked-transfer stream of NDJSON lines the engine loop
//! flushes token by token (`/v1/generate` with `"stream": true`).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{Receiver, Sender};

use anyhow::{anyhow, Result};

use crate::engine::lifecycle::{CancelReason, CancelToken};
use crate::util::json::Json;

/// A parsed HTTP request (method + path + body; headers beyond
/// `Content-Length` are ignored).
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: String,
}

/// A complete (non-streamed) HTTP response body with its status code.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub body: String,
}

impl HttpResponse {
    /// A response carrying a JSON body.
    pub fn json(status: u16, body: String) -> HttpResponse {
        HttpResponse { status, body }
    }
}

/// `{"error": "<msg>"}` with proper JSON string escaping (error messages —
/// notably the JSON parser's own — can contain double quotes; interpolating
/// them raw would produce malformed bodies).
pub fn error_json(status: u16, msg: impl std::fmt::Display) -> HttpResponse {
    let body = Json::obj(vec![("error", Json::str(msg.to_string()))]).to_string();
    HttpResponse::json(status, body)
}

/// One reply fragment from the engine loop to an HTTP connection.
///
/// A request is answered either by a single [`ServerReply::Full`], or by a
/// sequence of [`ServerReply::Chunk`]s terminated by [`ServerReply::End`]
/// (wire format: `Transfer-Encoding: chunked`, one NDJSON line per chunk,
/// flushed as produced so clients see tokens while the engine decodes).
#[derive(Debug, Clone)]
pub enum ServerReply {
    /// The whole response at once.
    Full(HttpResponse),
    /// One chunk of a streamed response (the first chunk sends the headers).
    Chunk(String),
    /// Terminates a streamed response.
    End,
}

/// Read one request off the socket (request line, `Content-Length`, body).
pub fn parse_request(stream: &mut TcpStream) -> Result<HttpRequest> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| anyhow!("bad request line"))?.to_string();
    let path = parts.next().ok_or_else(|| anyhow!("bad request line"))?.to_string();
    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_len = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_len];
    if content_len > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(HttpRequest {
        method,
        path,
        body: String::from_utf8_lossy(&body).to_string(),
    })
}

/// Write a complete (Content-Length-framed) response.
pub fn write_response(stream: &mut TcpStream, resp: &HttpResponse) -> Result<()> {
    let reason = match resp.status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        503 => "Service Unavailable",
        _ => "Error",
    };
    write!(
        stream,
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        resp.status,
        reason,
        resp.body.len(),
        resp.body
    )?;
    stream.flush()?;
    Ok(())
}

/// Send the status line + headers of a chunked-transfer stream.
fn write_stream_head(stream: &mut TcpStream) -> Result<()> {
    write!(
        stream,
        "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
    )?;
    stream.flush()?;
    Ok(())
}

/// Send one transfer chunk and flush it (this flush is what puts a token on
/// the wire while the engine keeps decoding).
fn write_stream_chunk(stream: &mut TcpStream, data: &str) -> Result<()> {
    write!(stream, "{:x}\r\n{}\r\n", data.len(), data)?;
    stream.flush()?;
    Ok(())
}

/// Send the zero-length terminal chunk.
fn write_stream_tail(stream: &mut TcpStream) -> Result<()> {
    write!(stream, "0\r\n\r\n")?;
    stream.flush()?;
    Ok(())
}

/// Forward engine replies to the socket until the request is answered: one
/// [`ServerReply::Full`], or a `Chunk…End` stream. A dropped sender (engine
/// gone) terminates an open stream gracefully and maps to a 500 otherwise.
///
/// A *write* failure means the client stopped reading (disconnect): the
/// request's cancellation token is tripped so the engine loop retires the
/// row instead of generating into a dead channel (ROADMAP streaming
/// backpressure), and the GPU KV blocks return to the pool.
fn pump_replies(
    stream: &mut TcpStream,
    rrx: &Receiver<ServerReply>,
    cancel: &CancelToken,
) -> Result<()> {
    let out = pump_replies_inner(stream, rrx);
    if out.is_err() {
        // the socket rejected a write — nobody is reading this response
        cancel.trip(CancelReason::Disconnected);
    }
    out
}

fn pump_replies_inner(stream: &mut TcpStream, rrx: &Receiver<ServerReply>) -> Result<()> {
    match rrx.recv() {
        Ok(ServerReply::Full(resp)) => write_response(stream, &resp),
        Ok(ServerReply::Chunk(first)) => {
            write_stream_head(stream)?;
            write_stream_chunk(stream, &first)?;
            loop {
                match rrx.recv() {
                    Ok(ServerReply::Chunk(c)) => write_stream_chunk(stream, &c)?,
                    // End, a stray Full, or a dropped sender all close the
                    // stream; the terminal chunk tells the client it's whole
                    Ok(ServerReply::End) | Ok(ServerReply::Full(_)) | Err(_) => break,
                }
            }
            write_stream_tail(stream)
        }
        Ok(ServerReply::End) | Err(_) => write_response(
            stream,
            &HttpResponse::json(500, r#"{"error":"engine gone"}"#.into()),
        ),
    }
}

/// Grace window for the read-side EOF watcher: a FIN arriving this soon
/// after the request body was read is a legitimate send-then-half-close
/// client (it still reads the response), not a disconnect. Past the
/// window, EOF on the read side means the client hung up mid-flight.
const HALF_CLOSE_GRACE: std::time::Duration = std::time::Duration::from_millis(250);

/// Read-side disconnect watcher for *non-streamed* requests (streamed
/// requests already learn of disconnects from write failures — a
/// non-streamed request writes nothing until generation finishes, so
/// without this the engine would decode an entire response for a client
/// that hung up at tick one).
///
/// Watches the connection's read side after the request body is consumed:
/// * EOF *inside* [`HALF_CLOSE_GRACE`] — a legitimate client half-close
///   right after sending the request; ignored (the client still reads).
/// * EOF (or a hard error like ECONNRESET) *after* the grace window — the
///   client went away; trips [`CancelReason::Disconnected`] so the engine
///   loop retires the row and returns its KV blocks mid-flight.
/// * Stray readable bytes — ignored (a pipelining client's business).
///
/// The connection thread sets `done` once the response is written; the
/// watcher polls it between read timeouts and exits without tripping.
fn watch_disconnect(
    stream: TcpStream,
    cancel: CancelToken,
    done: std::sync::Arc<std::sync::atomic::AtomicBool>,
) {
    use std::sync::atomic::Ordering;
    if stream
        .set_read_timeout(Some(std::time::Duration::from_millis(50)))
        .is_err()
    {
        return; // no timeout support — better no watcher than a hang
    }
    let mut stream = stream;
    let start = std::time::Instant::now();
    let mut buf = [0u8; 64];
    loop {
        if done.load(Ordering::Acquire) {
            return;
        }
        match stream.read(&mut buf) {
            Ok(0) => {
                // half-close: benign if it follows the request
                // immediately, a hang-up if the request has been in
                // flight for a while
                if start.elapsed() > HALF_CLOSE_GRACE && !done.load(Ordering::Acquire) {
                    cancel.trip(CancelReason::Disconnected);
                }
                return;
            }
            Ok(_) => continue,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => {
                // hard reset — unambiguous even inside the grace window
                if !done.load(Ordering::Acquire) {
                    cancel.trip(CancelReason::Disconnected);
                }
                return;
            }
        }
    }
}

/// A parsed request paired with its reply channel (single [`ServerReply::Full`]
/// send, or a `Chunk…End` stream for streamed generation) and the
/// connection's cancellation token — tripped by the connection thread on
/// write failure so the engine loop can retire the request's batch row.
pub struct Incoming {
    pub req: HttpRequest,
    pub reply: Sender<ServerReply>,
    pub cancel: CancelToken,
}

/// Accept loop: parses each connection and forwards it to the engine
/// thread; replies when the engine answers (streamed replies are flushed
/// chunk by chunk as they arrive). Returns the bound local address (port 0
/// supported for tests).
pub fn serve(addr: &str, tx: Sender<Incoming>) -> Result<(std::net::SocketAddr, std::thread::JoinHandle<()>)> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let handle = std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut stream) = stream else { continue };
            let tx = tx.clone();
            std::thread::spawn(move || {
                match parse_request(&mut stream) {
                    Ok(req) => {
                        let (rtx, rrx): (Sender<ServerReply>, Receiver<ServerReply>) =
                            std::sync::mpsc::channel();
                        let cancel = CancelToken::new();
                        let inc = Incoming {
                            req,
                            reply: rtx,
                            cancel: cancel.clone(),
                        };
                        if tx.send(inc).is_ok() {
                            // read-side EOF watcher: catches clients that
                            // hang up while a non-streamed response is
                            // still generating (write-side failures only
                            // surface once something is written)
                            let done = std::sync::Arc::new(
                                std::sync::atomic::AtomicBool::new(false),
                            );
                            if let Ok(rs) = stream.try_clone() {
                                let c = cancel.clone();
                                let d = done.clone();
                                std::thread::spawn(move || watch_disconnect(rs, c, d));
                            }
                            let _ = pump_replies(&mut stream, &rrx, &cancel);
                            done.store(true, std::sync::atomic::Ordering::Release);
                        } else {
                            let _ = write_response(
                                &mut stream,
                                &HttpResponse::json(
                                    500,
                                    r#"{"error":"server shutting down"}"#.into(),
                                ),
                            );
                        }
                    }
                    Err(e) => {
                        let _ = write_response(&mut stream, &error_json(400, e));
                    }
                }
            });
        }
    });
    Ok((local, handle))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_over_loopback() {
        let (tx, rx) = std::sync::mpsc::channel();
        let (addr, _h) = serve("127.0.0.1:0", tx).unwrap();
        // echo engine
        std::thread::spawn(move || {
            for inc in rx {
                let body = format!(
                    r#"{{"path":"{}","echo":{}}}"#,
                    inc.req.path,
                    if inc.req.body.is_empty() { "null".into() } else { inc.req.body.clone() }
                );
                let _ = inc.reply.send(ServerReply::Full(HttpResponse::json(200, body)));
            }
        });
        let mut s = TcpStream::connect(addr).unwrap();
        write!(
            s,
            "POST /gen HTTP/1.1\r\nContent-Length: 8\r\n\r\n{{\"a\": 1}}"
        )
        .unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200"));
        assert!(out.contains(r#""path":"/gen""#));
        assert!(out.contains(r#""a": 1"#));
    }

    #[test]
    fn bad_request_line_is_400() {
        let (tx, _rx) = std::sync::mpsc::channel();
        let (addr, _h) = serve("127.0.0.1:0", tx).unwrap();
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 400"));
    }

    #[test]
    fn streamed_reply_uses_chunked_transfer() {
        let (tx, rx) = std::sync::mpsc::channel();
        let (addr, _h) = serve("127.0.0.1:0", tx).unwrap();
        // engine that streams two lines then ends
        std::thread::spawn(move || {
            for inc in rx {
                let _ = inc.reply.send(ServerReply::Chunk("{\"token\":\"a\"}\n".into()));
                let _ = inc.reply.send(ServerReply::Chunk("{\"done\":true}\n".into()));
                let _ = inc.reply.send(ServerReply::End);
            }
        });
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "POST /stream HTTP/1.1\r\nContent-Length: 0\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200"), "{out}");
        assert!(out.contains("Transfer-Encoding: chunked"));
        assert!(out.contains("{\"token\":\"a\"}"));
        assert!(out.contains("{\"done\":true}"));
        assert!(out.ends_with("0\r\n\r\n"), "missing terminal chunk: {out:?}");
    }

    #[test]
    fn write_failure_trips_disconnect_token() {
        let (tx, rx) = std::sync::mpsc::channel();
        let (addr, _h) = serve("127.0.0.1:0", tx).unwrap();
        let (ctx, crx) = std::sync::mpsc::channel();
        // engine that streams forever (until the send side fails)
        std::thread::spawn(move || {
            for inc in rx {
                ctx.send(inc.cancel.clone()).unwrap();
                let mut i = 0;
                while inc
                    .reply
                    .send(ServerReply::Chunk(format!("{{\"i\":{i}}}\n")))
                    .is_ok()
                {
                    i += 1;
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
            }
        });
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET /stream HTTP/1.1\r\n\r\n").unwrap();
        let mut buf = [0u8; 256];
        let _ = s.read(&mut buf).unwrap(); // headers + first chunk arrived
        drop(s); // client stops reading — the dead-channel case
        let token = crx.recv().unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while token.tripped().is_none() && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert_eq!(token.tripped(), Some(CancelReason::Disconnected));
    }

    #[test]
    fn immediate_half_close_after_body_is_not_a_disconnect() {
        let (tx, rx) = std::sync::mpsc::channel();
        let (addr, _h) = serve("127.0.0.1:0", tx).unwrap();
        let (ctx, crx) = std::sync::mpsc::channel();
        // engine that answers slowly — long enough for a wrongly-tripped
        // watcher to have fired (the reply lands well past the grace
        // window)
        std::thread::spawn(move || {
            for inc in rx {
                ctx.send(inc.cancel.clone()).unwrap();
                std::thread::sleep(std::time::Duration::from_millis(600));
                let _ = inc
                    .reply
                    .send(ServerReply::Full(HttpResponse::json(200, "{\"ok\":true}".into())));
            }
        });
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "POST /gen HTTP/1.1\r\nContent-Length: 8\r\n\r\n{{\"a\": 1}}").unwrap();
        // legitimate half-close: the request is fully sent, the client
        // only reads from here on
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200"), "{out}");
        assert!(out.contains("\"ok\":true"));
        let token = crx.recv().unwrap();
        assert_eq!(token.tripped(), None, "half-close right after the body must not cancel");
    }

    #[test]
    fn mid_flight_hangup_trips_disconnect_without_any_write() {
        let (tx, rx) = std::sync::mpsc::channel();
        let (addr, _h) = serve("127.0.0.1:0", tx).unwrap();
        let (ctx, crx) = std::sync::mpsc::channel();
        // engine that never answers — only the read-side watcher can
        // notice the client is gone (nothing is ever written)
        std::thread::spawn(move || {
            for inc in rx {
                ctx.send((inc.cancel.clone(), inc.reply.clone())).unwrap();
            }
        });
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "POST /gen HTTP/1.1\r\nContent-Length: 8\r\n\r\n{{\"a\": 1}}").unwrap();
        let (token, _reply_keepalive) = crx.recv().unwrap();
        // hang up well past the half-close grace window
        std::thread::sleep(std::time::Duration::from_millis(500));
        drop(s);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while token.tripped().is_none() && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert_eq!(token.tripped(), Some(CancelReason::Disconnected));
    }

    #[test]
    fn dropped_engine_closes_stream_with_terminal_chunk() {
        let (tx, rx) = std::sync::mpsc::channel();
        let (addr, _h) = serve("127.0.0.1:0", tx).unwrap();
        std::thread::spawn(move || {
            for inc in rx {
                let _ = inc.reply.send(ServerReply::Chunk("{\"token\":\"x\"}\n".into()));
                // sender dropped without End — client must still see a
                // complete chunked framing
            }
        });
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET /stream HTTP/1.1\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert!(out.contains("{\"token\":\"x\"}"));
        assert!(out.ends_with("0\r\n\r\n"));
    }
}
