//! JSON API: request routing + the engine service loop.
//!
//! Endpoints:
//!   POST /v1/generate  {"prompt": "...", "max_new_tokens": 32}
//!   GET  /v1/metrics   → serving metrics snapshot
//!   GET  /health

use std::sync::mpsc::Receiver;

use anyhow::Result;

use crate::engine::batcher::{Batcher, Request};
use crate::engine::Engine;
use crate::util::json::Json;

use super::http::{HttpResponse, Incoming};

pub fn handle_generate(engine: &mut Engine<'_>, body: &str, next_id: u64) -> HttpResponse {
    let parsed = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return HttpResponse::json(400, format!(r#"{{"error":"bad json: {e}"}}"#)),
    };
    let prompt = parsed
        .get("prompt")
        .and_then(|p| p.as_str())
        .unwrap_or("")
        .as_bytes()
        .to_vec();
    if prompt.is_empty() {
        return HttpResponse::json(400, r#"{"error":"empty prompt"}"#.into());
    }
    let max_new = parsed
        .get("max_new_tokens")
        .and_then(|v| v.as_usize())
        .unwrap_or(32);

    let mut seq = engine.new_sequence(next_id, &prompt);
    match engine.generate(&mut seq, max_new) {
        Ok(tokens) => {
            let text = String::from_utf8_lossy(&tokens).to_string();
            let out = Json::obj(vec![
                ("id", Json::num(next_id as f64)),
                ("text", Json::str(text)),
                ("prompt_tokens", Json::num(prompt.len() as f64)),
                ("completion_tokens", Json::num(tokens.len() as f64)),
            ]);
            HttpResponse::json(200, out.to_string())
        }
        Err(e) => HttpResponse::json(500, format!(r#"{{"error":"{e}"}}"#)),
    }
}

pub fn handle_metrics(engine: &Engine<'_>) -> HttpResponse {
    let m = &engine.metrics;
    let tbt = m.tbt_summary();
    let out = Json::obj(vec![
        ("tokens", Json::num(m.tokens as f64)),
        ("prefill_tokens", Json::num(m.prefill_tokens as f64)),
        ("throughput_tok_s", Json::num(m.throughput())),
        ("sim_throughput_tok_s", Json::num(m.sim_throughput())),
        (
            "tbt_p50_ms",
            Json::num(tbt.as_ref().map(|s| s.p50 * 1e3).unwrap_or(0.0)),
        ),
        (
            "tbt_p99_ms",
            Json::num(tbt.as_ref().map(|s| s.p99 * 1e3).unwrap_or(0.0)),
        ),
        ("peak_gpu_kv_bytes", Json::num(m.peak_gpu_kv_bytes as f64)),
        ("peak_cpu_kv_bytes", Json::num(m.peak_cpu_kv_bytes as f64)),
        ("policy", Json::str(engine.policy.name())),
    ]);
    HttpResponse::json(200, out.to_string())
}

/// The engine service loop: single thread owns the PJRT runtime and serves
/// requests from the HTTP acceptor. Uses the continuous batcher when
/// multiple requests are queued.
pub fn engine_loop(engine: &mut Engine<'_>, rx: Receiver<Incoming>, batch: usize) -> Result<()> {
    let mut next_id = 0u64;
    let mut batcher = Batcher::new(batch);
    for inc in rx {
        match (inc.req.method.as_str(), inc.req.path.as_str()) {
            ("GET", "/health") => {
                let _ = inc.reply.send(HttpResponse::json(200, r#"{"ok":true}"#.into()));
            }
            ("GET", "/v1/metrics") => {
                let _ = inc.reply.send(handle_metrics(engine));
            }
            ("POST", "/v1/generate") => {
                next_id += 1;
                // fast path: serve immediately (single in-flight request);
                // the batcher path is exercised by serve_bench which floods
                // requests through submit() directly.
                let resp = handle_generate(engine, &inc.req.body, next_id);
                let _ = inc.reply.send(resp);
            }
            ("POST", "/v1/batch") => {
                // batch probe: {"prompts": [...], "max_new_tokens": n}
                next_id += 1;
                let resp = handle_batch(engine, &mut batcher, &inc.req.body, &mut next_id);
                let _ = inc.reply.send(resp);
            }
            _ => {
                let _ = inc
                    .reply
                    .send(HttpResponse::json(404, r#"{"error":"not found"}"#.into()));
            }
        }
    }
    Ok(())
}

fn handle_batch(
    engine: &mut Engine<'_>,
    batcher: &mut Batcher,
    body: &str,
    next_id: &mut u64,
) -> HttpResponse {
    let parsed = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return HttpResponse::json(400, format!(r#"{{"error":"bad json: {e}"}}"#)),
    };
    let Some(prompts) = parsed.get("prompts").and_then(|p| p.as_arr()) else {
        return HttpResponse::json(400, r#"{"error":"missing prompts"}"#.into());
    };
    let max_new = parsed
        .get("max_new_tokens")
        .and_then(|v| v.as_usize())
        .unwrap_or(16);
    for p in prompts {
        let Some(text) = p.as_str() else {
            return HttpResponse::json(400, r#"{"error":"prompt not a string"}"#.into());
        };
        *next_id += 1;
        batcher.submit(Request {
            id: *next_id,
            prompt: text.as_bytes().to_vec(),
            max_new_tokens: max_new,
        });
    }
    match batcher.run_to_completion(engine) {
        Ok(done) => {
            let items: Vec<Json> = done
                .iter()
                .map(|c| {
                    Json::obj(vec![
                        ("id", Json::num(c.id as f64)),
                        ("text", Json::str(String::from_utf8_lossy(&c.text).to_string())),
                    ])
                })
                .collect();
            HttpResponse::json(200, Json::obj(vec![("completions", Json::arr(items))]).to_string())
        }
        Err(e) => HttpResponse::json(500, format!(r#"{{"error":"{e}"}}"#)),
    }
}
