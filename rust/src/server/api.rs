//! JSON API: request routing + the engine service loop.
//!
//! Endpoints:
//!   POST /v1/generate  {"prompt": "...", "max_new_tokens": 32}
//!   POST /v1/batch     {"prompts": [...], "max_new_tokens": 16}
//!   GET  /v1/metrics   → serving metrics snapshot (engine + pool + batcher)
//!   GET  /health
//!
//! The engine loop is a continuous-batching scheduler: every POST is
//! admitted into the running batch (no serialization of concurrent
//! requests), one batcher tick runs per loop iteration, and responses are
//! routed back per-request as sequences retire. GET endpoints answer
//! between ticks, so metrics/health stay live while decodes are in flight.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};

use anyhow::Result;

use crate::attention::AttnPool;
use crate::engine::batcher::{Batcher, Completion, Request};
use crate::engine::Engine;
use crate::util::json::Json;

use super::http::{HttpResponse, Incoming};

/// One-shot synchronous generate (kept for single-request callers and the
/// serve_bench smoke phase; the serving loop uses the batcher instead).
pub fn handle_generate(engine: &mut Engine<'_>, body: &str, next_id: u64) -> HttpResponse {
    let (prompt, max_new) = match parse_generate(body) {
        Ok(p) => p,
        Err(resp) => return *resp,
    };
    let mut seq = engine.new_sequence(next_id, &prompt);
    match engine.generate(&mut seq, max_new) {
        Ok(tokens) => completion_json(next_id, &prompt, &tokens),
        Err(e) => HttpResponse::json(500, format!(r#"{{"error":"{e}"}}"#)),
    }
}

fn parse_generate(body: &str) -> Result<(Vec<u8>, usize), Box<HttpResponse>> {
    let parsed = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => {
            return Err(Box::new(HttpResponse::json(
                400,
                format!(r#"{{"error":"bad json: {e}"}}"#),
            )))
        }
    };
    let prompt = parsed
        .get("prompt")
        .and_then(|p| p.as_str())
        .unwrap_or("")
        .as_bytes()
        .to_vec();
    if prompt.is_empty() {
        return Err(Box::new(HttpResponse::json(
            400,
            r#"{"error":"empty prompt"}"#.into(),
        )));
    }
    let max_new = parsed
        .get("max_new_tokens")
        .and_then(|v| v.as_usize())
        .unwrap_or(32);
    Ok((prompt, max_new))
}

fn completion_json(id: u64, prompt: &[u8], tokens: &[u8]) -> HttpResponse {
    let text = String::from_utf8_lossy(tokens).to_string();
    let out = Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("text", Json::str(text)),
        ("prompt_tokens", Json::num(prompt.len() as f64)),
        ("completion_tokens", Json::num(tokens.len() as f64)),
    ]);
    HttpResponse::json(200, out.to_string())
}

pub fn handle_metrics(engine: &Engine<'_>, batcher: Option<&Batcher>) -> HttpResponse {
    let m = &engine.metrics;
    let tbt = m.tbt_summary();
    let pool = AttnPool::global().stats();
    let mut fields = vec![
        ("tokens", Json::num(m.tokens as f64)),
        ("prefill_tokens", Json::num(m.prefill_tokens as f64)),
        ("throughput_tok_s", Json::num(m.throughput())),
        ("sim_throughput_tok_s", Json::num(m.sim_throughput())),
        (
            "tbt_p50_ms",
            Json::num(tbt.as_ref().map(|s| s.p50 * 1e3).unwrap_or(0.0)),
        ),
        (
            "tbt_p99_ms",
            Json::num(tbt.as_ref().map(|s| s.p99 * 1e3).unwrap_or(0.0)),
        ),
        ("peak_gpu_kv_bytes", Json::num(m.peak_gpu_kv_bytes as f64)),
        ("peak_cpu_kv_bytes", Json::num(m.peak_cpu_kv_bytes as f64)),
        ("policy", Json::str(engine.policy.name())),
        // persistent CPU attention pool (tentpole counters)
        ("pool_workers", Json::num(pool.workers as f64)),
        ("pool_submissions", Json::num(pool.submissions as f64)),
        ("pool_tasks", Json::num(pool.tasks as f64)),
        ("pool_jobs", Json::num(pool.jobs as f64)),
        ("pool_busy_secs", Json::num(pool.busy_secs)),
        ("pool_queue_depth", Json::num(pool.queue_depth as f64)),
        ("pool_queue_peak", Json::num(pool.queue_peak as f64)),
    ];
    if let Some(b) = batcher {
        let s = b.stats();
        fields.push(("batch_rows", Json::num(b.batch as f64)));
        fields.push(("batch_ticks", Json::num(s.ticks as f64)));
        fields.push(("batch_submitted", Json::num(s.submitted as f64)));
        fields.push(("batch_completed", Json::num(s.completed as f64)));
        fields.push(("batch_queued", Json::num(s.queued as f64)));
        fields.push(("batch_active", Json::num(s.active as f64)));
        fields.push(("batch_mean_occupancy", Json::num(s.mean_occupancy)));
        fields.push(("batch_max_queue_ticks", Json::num(s.max_queue_ticks as f64)));
    }
    HttpResponse::json(200, Json::obj(fields).to_string())
}

/// Where a completion's response goes.
enum Waiter {
    /// a /v1/generate request: respond when its sequence retires
    Single {
        reply: Sender<HttpResponse>,
        prompt: Vec<u8>,
    },
    /// one member of a /v1/batch group: respond when the whole group is done
    Group { key: u64 },
}

struct Group {
    reply: Sender<HttpResponse>,
    remaining: usize,
    items: Vec<(u64, Vec<u8>)>,
}

/// The engine service loop: single thread owns the model runtime and serves
/// requests from the HTTP acceptor through the continuous batcher. New
/// requests are admitted into the running batch at tick granularity;
/// nothing blocks behind a long generation.
pub fn engine_loop(engine: &mut Engine<'_>, rx: Receiver<Incoming>, batch: usize) -> Result<()> {
    let mut next_id = 0u64;
    let mut batcher = Batcher::new(batch);
    let mut waiters: HashMap<u64, Waiter> = HashMap::new();
    let mut groups: HashMap<u64, Group> = HashMap::new();
    let mut next_group = 0u64;
    let mut open = true;

    while open || batcher.pending() > 0 {
        // block only when idle; otherwise drain whatever has arrived and
        // keep ticking the batch
        if batcher.pending() == 0 && open {
            match rx.recv() {
                Ok(inc) => admit(
                    engine, &mut batcher, &mut waiters, &mut groups, &mut next_id,
                    &mut next_group, inc,
                ),
                Err(_) => {
                    open = false;
                    continue;
                }
            }
        }
        loop {
            match rx.try_recv() {
                Ok(inc) => admit(
                    engine, &mut batcher, &mut waiters, &mut groups, &mut next_id,
                    &mut next_group, inc,
                ),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        if batcher.pending() > 0 {
            match batcher.tick(engine) {
                Ok(finished) => {
                    for c in finished {
                        resolve(&mut waiters, &mut groups, c);
                    }
                }
                Err(e) => {
                    // an engine failure poisons every in-flight request:
                    // fail them all explicitly, then surface the error
                    let msg = HttpResponse::json(500, format!(r#"{{"error":"{e}"}}"#));
                    for (_, w) in waiters.drain() {
                        if let Waiter::Single { reply, .. } = w {
                            let _ = reply.send(msg.clone());
                        }
                    }
                    for (_, g) in groups.drain() {
                        let _ = g.reply.send(msg.clone());
                    }
                    return Err(e);
                }
            }
        }
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn admit(
    engine: &mut Engine<'_>,
    batcher: &mut Batcher,
    waiters: &mut HashMap<u64, Waiter>,
    groups: &mut HashMap<u64, Group>,
    next_id: &mut u64,
    next_group: &mut u64,
    inc: Incoming,
) {
    match (inc.req.method.as_str(), inc.req.path.as_str()) {
        ("GET", "/health") => {
            let _ = inc.reply.send(HttpResponse::json(200, r#"{"ok":true}"#.into()));
        }
        ("GET", "/v1/metrics") => {
            let _ = inc.reply.send(handle_metrics(engine, Some(batcher)));
        }
        ("POST", "/v1/generate") => match parse_generate(&inc.req.body) {
            Ok((prompt, max_new)) => {
                *next_id += 1;
                batcher.submit(Request {
                    id: *next_id,
                    prompt: prompt.clone(),
                    max_new_tokens: max_new,
                });
                waiters.insert(
                    *next_id,
                    Waiter::Single {
                        reply: inc.reply,
                        prompt,
                    },
                );
            }
            Err(resp) => {
                let _ = inc.reply.send(*resp);
            }
        },
        ("POST", "/v1/batch") => {
            // batch probe: {"prompts": [...], "max_new_tokens": n}
            match parse_batch(&inc.req.body) {
                Ok((prompts, max_new)) => {
                    *next_group += 1;
                    let key = *next_group;
                    groups.insert(
                        key,
                        Group {
                            reply: inc.reply,
                            remaining: prompts.len(),
                            items: Vec::with_capacity(prompts.len()),
                        },
                    );
                    for p in prompts {
                        *next_id += 1;
                        batcher.submit(Request {
                            id: *next_id,
                            prompt: p,
                            max_new_tokens: max_new,
                        });
                        waiters.insert(*next_id, Waiter::Group { key });
                    }
                }
                Err(resp) => {
                    let _ = inc.reply.send(*resp);
                }
            }
        }
        _ => {
            let _ = inc
                .reply
                .send(HttpResponse::json(404, r#"{"error":"not found"}"#.into()));
        }
    }
}

fn parse_batch(body: &str) -> Result<(Vec<Vec<u8>>, usize), Box<HttpResponse>> {
    let parsed = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => {
            return Err(Box::new(HttpResponse::json(
                400,
                format!(r#"{{"error":"bad json: {e}"}}"#),
            )))
        }
    };
    let Some(prompts) = parsed.get("prompts").and_then(|p| p.as_arr()) else {
        return Err(Box::new(HttpResponse::json(
            400,
            r#"{"error":"missing prompts"}"#.into(),
        )));
    };
    let max_new = parsed
        .get("max_new_tokens")
        .and_then(|v| v.as_usize())
        .unwrap_or(16);
    let mut out = Vec::with_capacity(prompts.len());
    for p in prompts {
        let Some(text) = p.as_str() else {
            return Err(Box::new(HttpResponse::json(
                400,
                r#"{"error":"prompt not a string"}"#.into(),
            )));
        };
        out.push(text.as_bytes().to_vec());
    }
    if out.is_empty() {
        return Err(Box::new(HttpResponse::json(
            400,
            r#"{"error":"empty prompts"}"#.into(),
        )));
    }
    Ok((out, max_new))
}

fn resolve(waiters: &mut HashMap<u64, Waiter>, groups: &mut HashMap<u64, Group>, c: Completion) {
    match waiters.remove(&c.id) {
        Some(Waiter::Single { reply, prompt }) => {
            let _ = reply.send(completion_json(c.id, &prompt, &c.text));
        }
        Some(Waiter::Group { key }) => {
            let done = {
                let g = groups.get_mut(&key).expect("group for member");
                g.items.push((c.id, c.text));
                g.remaining -= 1;
                g.remaining == 0
            };
            if done {
                let mut g = groups.remove(&key).expect("group complete");
                g.items.sort_by_key(|(id, _)| *id);
                let items: Vec<Json> = g
                    .items
                    .iter()
                    .map(|(id, text)| {
                        Json::obj(vec![
                            ("id", Json::num(*id as f64)),
                            ("text", Json::str(String::from_utf8_lossy(text).to_string())),
                        ])
                    })
                    .collect();
                let _ = g.reply.send(HttpResponse::json(
                    200,
                    Json::obj(vec![("completions", Json::arr(items))]).to_string(),
                ));
            }
        }
        None => {
            // waiter dropped (client hung up mid-flight) — nothing to do
        }
    }
}
