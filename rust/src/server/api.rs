//! JSON API: request routing + the engine service loop.
//!
//! Endpoints (full schemas in docs/API.md):
//!   POST /v1/generate  {"prompt": "...", "max_new_tokens": 32, "stream": false,
//!                       "deadline_ms": 2000}
//!   POST /v1/batch     {"prompts": [...], "max_new_tokens": 16, "deadline_ms": 2000}
//!   POST /v1/cancel    {"id": N} → trips the request's cancellation token
//!   GET  /v1/metrics   → serving metrics snapshot (engine + pool + batcher)
//!   GET  /health
//!
//! Every admitted request carries a lifecycle handle (cancellation token +
//! optional deadline + max-queue-wait bound); the batcher retires tripped
//! rows mid-batch and their completion arrives with a `finish_reason`
//! (`length`/`cancelled`/`deadline`/`disconnected`/`shed`/`capacity`).
//! Admission is earliest-deadline-first over a capacity-bounded GPU KV
//! pool (docs/SCHEDULING.md): the engine loop sizes the pool from
//! [`crate::config::ServingConfig::effective_kv_blocks`] at startup, a
//! request whose blocks don't currently fit defers in the queue, and one
//! that can *never* fit (more blocks than the pool's total capacity) is
//! rejected up front with a 429 carrying `"never_fits": true`. When batch
//! occupancy + queue depth exceed the configured watermark
//! ([`crate::config::ServingConfig::shed_watermark`]), new admissions are
//! rejected immediately with a 429-style JSON error (load shedding —
//! distinct from the never-fits rejection: a shed request can succeed on
//! retry once the queue drains).
//!
//! The engine loop is a continuous-batching scheduler: every POST is
//! admitted into the running batch (no serialization of concurrent
//! requests), one batcher tick runs per loop iteration, and responses are
//! routed back per-request as sequences retire. Long prompts are absorbed
//! as budgeted prefill chunks between decode ticks (chunked prefill), so
//! an admission never stalls in-flight generations. With `"stream": true`,
//! `/v1/generate` replies over chunked transfer encoding: one NDJSON token
//! line per generated token, flushed as the engine loop produces it, then
//! a final summary line — the streamed token sequence is byte-identical to
//! the non-streamed `text`. GET endpoints answer between ticks, so
//! metrics/health stay live while decodes are in flight.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::attention::AttnPool;
use crate::config::ServingConfig;
use crate::engine::batcher::{Batcher, Completion, Request};
use crate::engine::lifecycle::{CancelReason, CancelToken, FinishReason, RequestHandle};
use crate::engine::Engine;
use crate::metrics::Metrics;
use crate::util::json::Json;

use super::http::{error_json, HttpResponse, Incoming, ServerReply};

/// One-shot synchronous generate (kept for single-request callers and the
/// serve_bench smoke phase; the serving loop uses the batcher instead).
/// Always replies in full, and lifecycle fields are engine-loop features:
/// `stream` and `deadline_ms` are validated but **ignored** here — there
/// is no tick boundary to check a token or deadline at. Serve real
/// traffic through [`engine_loop`].
pub fn handle_generate(engine: &mut Engine<'_>, body: &str, next_id: u64) -> HttpResponse {
    let (prompt, max_new, _stream, _deadline) = match parse_generate(body) {
        Ok(p) => p,
        Err(resp) => return *resp,
    };
    let mut seq = engine.new_sequence(next_id, &prompt);
    match engine.generate(&mut seq, max_new) {
        Ok(tokens) => completion_json(next_id, &prompt, &tokens, FinishReason::Length),
        Err(e) => error_json(500, e),
    }
}

type GenerateParams = (Vec<u8>, usize, bool, Option<u64>);

fn parse_generate(body: &str) -> Result<GenerateParams, Box<HttpResponse>> {
    let parsed = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return Err(Box::new(error_json(400, format!("bad json: {e}")))),
    };
    let prompt = parsed
        .get("prompt")
        .and_then(|p| p.as_str())
        .unwrap_or("")
        .as_bytes()
        .to_vec();
    if prompt.is_empty() {
        return Err(Box::new(HttpResponse::json(
            400,
            r#"{"error":"empty prompt"}"#.into(),
        )));
    }
    let max_new = parsed
        .get("max_new_tokens")
        .and_then(|v| v.as_usize())
        .unwrap_or(32);
    let stream = parsed
        .get("stream")
        .and_then(|v| v.as_bool())
        .unwrap_or(false);
    let deadline_ms = parse_deadline_ms(&parsed)?;
    Ok((prompt, max_new, stream, deadline_ms))
}

/// Extract + validate the optional `deadline_ms` field (shared by
/// `/v1/generate` and `/v1/batch` so the two endpoints cannot diverge).
/// A present-but-invalid value (wrong type, non-finite, ≤ 0) is a 400 —
/// silently ignoring it would run the request unbounded while the client
/// believes a deadline is in force.
fn parse_deadline_ms(parsed: &Json) -> Result<Option<u64>, Box<HttpResponse>> {
    let Some(v) = parsed.get("deadline_ms") else {
        return Ok(None);
    };
    if matches!(v, Json::Null) {
        return Ok(None);
    }
    match v.as_f64().filter(|ms| ms.is_finite() && *ms > 0.0) {
        // ceil: a fractional deadline in (0,1) must not truncate to an
        // instantly-expired 0 ms
        Some(ms) => Ok(Some(ms.ceil() as u64)),
        None => Err(Box::new(HttpResponse::json(
            400,
            r#"{"error":"deadline_ms must be a positive number"}"#.into(),
        ))),
    }
}

/// The response fields shared by the non-streamed body and the streamed
/// summary line (the wire contract says they match). `finish_reason` is
/// `length` for a normal completion; lifecycle retirements deliver their
/// partial `text` with the retiring reason.
fn completion_fields(
    id: u64,
    prompt: &[u8],
    tokens: &[u8],
    reason: FinishReason,
) -> Vec<(&'static str, Json)> {
    vec![
        ("id", Json::num(id as f64)),
        ("text", Json::str(String::from_utf8_lossy(tokens).to_string())),
        ("prompt_tokens", Json::num(prompt.len() as f64)),
        ("completion_tokens", Json::num(tokens.len() as f64)),
        ("finish_reason", Json::str(reason.as_str())),
    ]
}

fn completion_json(id: u64, prompt: &[u8], tokens: &[u8], reason: FinishReason) -> HttpResponse {
    HttpResponse::json(
        200,
        Json::obj(completion_fields(id, prompt, tokens, reason)).to_string(),
    )
}

/// The 503 body for a request shed from the admission queue after
/// exceeding its max-queue-wait bound (it never occupied a row).
fn queue_timeout_json(id: u64) -> HttpResponse {
    HttpResponse::json(
        503,
        Json::obj(vec![
            ("error", Json::str("queue wait exceeded")),
            ("id", Json::num(id as f64)),
            ("finish_reason", Json::str(FinishReason::QueueTimeout.as_str())),
        ])
        .to_string(),
    )
}

/// The 429 body for a request whose KV block requirement exceeds the
/// pool's **total** capacity — it can never be admitted, so unlike a
/// watermark shed (`"shed": true`) a plain retry cannot succeed:
/// `"never_fits": true` tells the client to stop retrying (or the
/// operator to raise `--kv-blocks` / `--kv-headroom`).
fn capacity_reject_json(needed: usize, capacity: usize) -> HttpResponse {
    HttpResponse::json(
        429,
        Json::obj(vec![
            ("error", Json::str("request KV requirement exceeds pool capacity")),
            ("never_fits", Json::Bool(true)),
            ("kv_blocks_needed", Json::num(needed as f64)),
            ("kv_blocks_capacity", Json::num(capacity as f64)),
            ("finish_reason", Json::str(FinishReason::NoCapacity.as_str())),
        ])
        .to_string(),
    )
}

/// Up-front never-fits check: `Some(429)` when one sequence's window
/// blocks exceed the pool's **largest node budget** — a lease never spans
/// nodes, so summed capacity across nodes is irrelevant (admission could
/// defer forever; reject instead — the batcher applies the same rule to
/// directly-submitted requests). `None` on unbounded pools or when the
/// blocks fit on some node.
fn capacity_check(engine: &Engine<'_>) -> Option<HttpResponse> {
    let capacity = engine.kv_pool.max_node_capacity()?;
    let needed = engine.blocks_per_sequence();
    (needed > capacity).then(|| capacity_reject_json(needed, capacity))
}

/// One streamed token line: `{"byte":B,"id":R,"index":N,"token":"s"}` +
/// newline. `byte` carries the exact generated byte so clients can
/// reconstruct the byte-identical sequence even when a byte is not valid
/// UTF-8 on its own; `id` is the request id `/v1/cancel` accepts.
fn token_line(id: u64, index: usize, byte: u8) -> String {
    let mut line = Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("index", Json::num(index as f64)),
        ("byte", Json::num(byte as f64)),
        (
            "token",
            Json::str(String::from_utf8_lossy(&[byte]).to_string()),
        ),
    ])
    .to_string();
    line.push('\n');
    line
}

/// The final summary line of a stream: same fields as the non-streamed
/// response, plus `"done": true`.
fn final_line(c: &Completion, prompt: &[u8]) -> String {
    let mut fields = completion_fields(c.id, prompt, &c.text, c.finish_reason);
    fields.push(("done", Json::Bool(true)));
    let mut line = Json::obj(fields).to_string();
    line.push('\n');
    line
}

/// Serving metrics snapshot: engine counters, pool counters, and (when the
/// batcher is live) scheduling counters. Counter meanings are documented in
/// docs/API.md.
pub fn handle_metrics(engine: &Engine<'_>, batcher: Option<&Batcher>) -> HttpResponse {
    let m = &engine.metrics;
    let tbt = m.tbt_summary();
    let pool = AttnPool::global().stats();
    let mut fields = vec![
        ("tokens", Json::num(m.tokens as f64)),
        ("prefill_tokens", Json::num(m.prefill_tokens as f64)),
        ("prefill_chunks", Json::num(m.prefill_chunks as f64)),
        ("stream_flushes", Json::num(m.stream_flushes as f64)),
        ("throughput_tok_s", Json::num(m.throughput())),
        ("sim_throughput_tok_s", Json::num(m.sim_throughput())),
        (
            "tbt_p50_ms",
            Json::num(tbt.as_ref().map(|s| s.p50 * 1e3).unwrap_or(0.0)),
        ),
        (
            "tbt_p99_ms",
            Json::num(tbt.as_ref().map(|s| s.p99 * 1e3).unwrap_or(0.0)),
        ),
        ("peak_gpu_kv_bytes", Json::num(m.peak_gpu_kv_bytes as f64)),
        ("peak_cpu_kv_bytes", Json::num(m.peak_cpu_kv_bytes as f64)),
        ("cpu_attn_wait_secs", Json::num(m.cpu_attn_wait_secs)),
        ("cpu_attn_busy_secs", Json::num(m.cpu_attn_busy_secs)),
        ("cpu_attn_overlap_secs", Json::num(m.cpu_attn_overlap_secs)),
        ("cpu_attn_jobs", Json::num(m.cpu_attn_jobs as f64)),
        ("cpu_attn_tasks", Json::num(m.cpu_attn_tasks as f64)),
        ("policy", Json::str(engine.policy.name())),
        // persistent CPU attention pool (tentpole counters)
        ("pool_workers", Json::num(pool.workers as f64)),
        ("pool_submissions", Json::num(pool.submissions as f64)),
        ("pool_tasks", Json::num(pool.tasks as f64)),
        ("pool_jobs", Json::num(pool.jobs as f64)),
        ("pool_busy_secs", Json::num(pool.busy_secs)),
        ("pool_queue_depth", Json::num(pool.queue_depth as f64)),
        ("pool_queue_peak", Json::num(pool.queue_peak as f64)),
        // NUMA execution domains (numa_nodes = the serving topology; the
        // per-node pool/kv counters below make locality regressions —
        // cross-node steals, lopsided budgets — visible)
        ("numa_nodes", Json::num(engine.topology.nodes() as f64)),
        ("pool_numa_nodes", Json::num(pool.numa_nodes as f64)),
        ("pool_pinned_workers", Json::num(pool.pinned_workers as f64)),
        ("pool_steals_cross_node", Json::num(pool.cross_node_steals() as f64)),
        (
            "pool_caller_assist_cross_node",
            Json::num(pool.caller_assist_cross_node as f64),
        ),
        // request lifecycle (exit is a first-class scheduler event)
        ("requests_cancelled", Json::num(m.requests_cancelled as f64)),
        ("requests_deadline_expired", Json::num(m.requests_deadline_expired as f64)),
        ("requests_disconnected", Json::num(m.requests_disconnected as f64)),
        ("requests_shed", Json::num(m.requests_shed as f64)),
        ("requests_rejected_capacity", Json::num(m.requests_rejected_capacity as f64)),
        // GPU KV block accounting: free-count restoration on retirement +
        // the admission currency (0 = unbounded accounting-only pool)
        ("kv_blocks_in_use", Json::num(engine.kv_pool.in_use() as f64)),
        ("kv_blocks_reclaimed", Json::num(engine.kv_pool.reclaimed_blocks() as f64)),
        ("kv_blocks_capacity", Json::num(engine.kv_pool.capacity().unwrap_or(0) as f64)),
        // tiered CPU KV store (--kv-tier): per-head tier census gauges and
        // the bytes the int8 tiers currently save vs f32 storage
        ("kv_tier_f32", Json::num(m.kv_tier_f32 as f64)),
        ("kv_tier_int8", Json::num(m.kv_tier_int8 as f64)),
        ("kv_tier_window", Json::num(m.kv_tier_window as f64)),
        ("kv_quant_heads", Json::num(m.kv_quant_heads as f64)),
        ("kv_quant_bytes_saved", Json::num(m.kv_quant_bytes_saved as f64)),
        // frozen SIMD kernel dispatch level (scalar=0, sse4=1, avx2=2,
        // neon=3 — see tensor/simd); a gauge so dashboards can tell
        // heterogeneous fleets apart when comparing latency
        ("simd_level", Json::num(crate::tensor::simd::active_level().code() as f64)),
    ];
    // cross-request prefix KV reuse (radix cache); counters stay present —
    // as zeros — when the cache is disabled, so scrapers never lose fields
    let ps = engine.prefix_stats();
    fields.push(("prefix_hits", Json::num(ps.hits as f64)));
    fields.push(("prefix_misses", Json::num(ps.misses as f64)));
    fields.push(("prefix_insertions", Json::num(ps.insertions as f64)));
    fields.push(("prefix_evictions", Json::num(ps.evictions as f64)));
    fields.push(("prefix_tokens_reused", Json::num(ps.tokens_reused as f64)));
    fields.push(("prefix_entries", Json::num(ps.entries as f64)));
    fields.push(("prefix_cached_blocks", Json::num(ps.cached_blocks as f64)));
    if let Some(b) = batcher {
        let s = b.stats();
        fields.push(("batch_rows", Json::num(b.batch as f64)));
        fields.push(("batch_ticks", Json::num(s.ticks as f64)));
        fields.push(("batch_submitted", Json::num(s.submitted as f64)));
        fields.push(("batch_completed", Json::num(s.completed as f64)));
        fields.push(("batch_queued", Json::num(s.queued as f64)));
        fields.push(("batch_active", Json::num(s.active as f64)));
        fields.push(("batch_prefilling", Json::num(s.prefilling as f64)));
        fields.push(("batch_mean_occupancy", Json::num(s.mean_occupancy)));
        fields.push(("batch_max_queue_ticks", Json::num(s.max_queue_ticks as f64)));
        fields.push(("batch_retired", Json::num(s.retired as f64)));
        fields.push(("batch_prefill_chunks", Json::num(s.prefill_chunks as f64)));
        fields.push(("batch_decode_steps", Json::num(s.decode_steps as f64)));
        fields.push(("admissions_deferred", Json::num(s.admissions_deferred as f64)));
        fields.push(("deadline_preempted", Json::num(s.deadline_preempted as f64)));
        fields.push((
            "deadline_preempted_prefill",
            Json::num(s.deadline_preempted_prefill as f64),
        ));
        fields.push((
            "prefill_decode_interleave",
            Json::num(s.prefill_chunks as f64 / s.decode_steps.max(1) as f64),
        ));
    }
    // per-node counters carry their node id in the key, so the field set
    // is dynamic — build the object map directly
    let mut obj: std::collections::BTreeMap<String, Json> =
        fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
    for (i, t) in pool.node_tasks.iter().enumerate() {
        obj.insert(format!("pool_node{i}_tasks"), Json::num(*t as f64));
    }
    for (i, s) in pool.node_steals.iter().enumerate() {
        obj.insert(format!("pool_node{i}_steals"), Json::num(*s as f64));
    }
    for node in 0..engine.kv_pool.nodes() {
        if let Some(free) = engine.kv_pool.free_blocks_on(node) {
            obj.insert(format!("kv_blocks_free_node{node}"), Json::num(free as f64));
        }
    }
    HttpResponse::json(200, Json::Obj(obj).to_string())
}

/// Where a completion's response goes.
enum Waiter {
    /// a /v1/generate request: respond when its sequence retires; when
    /// `stream` is set, each generated token is flushed as it is produced
    Single {
        reply: Sender<ServerReply>,
        prompt: Vec<u8>,
        stream: bool,
        /// tokens already streamed (the NDJSON `index` counter)
        streamed: usize,
        /// the request's cancellation token — tripped here when a stream
        /// flush fails (the connection pump is gone)
        token: CancelToken,
    },
    /// one member of a /v1/batch group: respond when the whole group is done
    Group { key: u64 },
}

struct Group {
    reply: Sender<ServerReply>,
    remaining: usize,
    items: Vec<(u64, Vec<u8>, FinishReason)>,
}

/// The engine service loop: single thread owns the model runtime and serves
/// requests from the HTTP acceptor through the continuous batcher. New
/// requests are admitted into the running batch at tick granularity;
/// nothing blocks behind a long generation — long prompts prefill in
/// chunks between decode ticks, and streamed requests flush each token the
/// tick it is generated.
pub fn engine_loop(engine: &mut Engine<'_>, rx: Receiver<Incoming>, batch: usize) -> Result<()> {
    engine_loop_with(engine, rx, Batcher::new(batch), ServingConfig::default())
}

/// [`engine_loop`] over a caller-configured [`Batcher`] (e.g. with a custom
/// per-tick prefill token budget) and [`ServingConfig`] (default deadline,
/// load-shed watermark, max queue wait).
pub fn engine_loop_with(
    engine: &mut Engine<'_>,
    rx: Receiver<Incoming>,
    mut batcher: Batcher,
    serving: ServingConfig,
) -> Result<()> {
    // size the GPU KV pool before the first admission: explicit
    // --kv-blocks, or model shape × batch × --kv-headroom (default 1.0 —
    // exactly one full batch, so gating coincides with row availability),
    // split into one budget per NUMA node of the engine's topology (a
    // single-node topology yields the pre-NUMA single-capacity pool)
    let budgets = serving.effective_node_budgets(
        engine.blocks_per_sequence(),
        batcher.batch,
        engine.topology.nodes(),
    );
    engine.set_kv_node_budgets(budgets);
    // after the budgets: enabling first would only have the cache rebound
    // (and emptied) when the pool is replaced above
    if serving.prefix_cache {
        engine.enable_prefix_cache(serving.prefix_cache_entries);
    }
    let mut next_id = 0u64;
    let mut waiters: HashMap<u64, Waiter> = HashMap::new();
    let mut groups: HashMap<u64, Group> = HashMap::new();
    let mut next_group = 0u64;
    let mut open = true;

    while open || batcher.pending() > 0 {
        // block only when idle; otherwise drain whatever has arrived and
        // keep ticking the batch
        if batcher.pending() == 0 && open {
            match rx.recv() {
                Ok(inc) => admit(
                    engine, &mut batcher, &mut waiters, &mut groups, &mut next_id,
                    &mut next_group, &serving, inc,
                ),
                Err(_) => {
                    open = false;
                    continue;
                }
            }
        }
        loop {
            match rx.try_recv() {
                Ok(inc) => admit(
                    engine, &mut batcher, &mut waiters, &mut groups, &mut next_id,
                    &mut next_group, &serving, inc,
                ),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        if batcher.pending() > 0 {
            match batcher.tick(engine) {
                Ok(finished) => {
                    // flush this tick's tokens to streaming clients first,
                    // then resolve completions (final line after last token)
                    for ev in batcher.take_events() {
                        if let Some(Waiter::Single {
                            reply,
                            stream: true,
                            streamed,
                            token,
                            ..
                        }) = waiters.get_mut(&ev.id)
                        {
                            let line = token_line(ev.id, *streamed, ev.token);
                            if reply.send(ServerReply::Chunk(line)).is_err() {
                                // the connection pump is gone (client hung
                                // up): stop fusing decode work into a dead
                                // channel — the next tick's sweep retires
                                // this row and reclaims its KV blocks
                                token.trip(CancelReason::Disconnected);
                            } else {
                                *streamed += 1;
                                engine.metrics.stream_flushes += 1;
                            }
                        }
                    }
                    let kv = KvSizing {
                        needed: engine.blocks_per_sequence(),
                        // the binding bound is the largest node budget —
                        // leases never span nodes
                        capacity: engine.kv_pool.max_node_capacity().unwrap_or(0),
                    };
                    for c in finished {
                        resolve(&mut waiters, &mut groups, &mut engine.metrics, kv, c);
                    }
                }
                Err(e) => {
                    // an engine failure poisons every in-flight request:
                    // fail them all explicitly, then surface the error
                    let msg = error_json(500, &e);
                    let mut err_line = msg.body.clone();
                    err_line.push('\n');
                    for (_, w) in waiters.drain() {
                        if let Waiter::Single { reply, stream, streamed, .. } = w {
                            if stream && streamed > 0 {
                                // headers already sent — close the stream
                                let _ = reply.send(ServerReply::Chunk(err_line.clone()));
                                let _ = reply.send(ServerReply::End);
                            } else {
                                let _ = reply.send(ServerReply::Full(msg.clone()));
                            }
                        }
                    }
                    for (_, g) in groups.drain() {
                        let _ = g.reply.send(ServerReply::Full(msg.clone()));
                    }
                    return Err(e);
                }
            }
        }
    }
    Ok(())
}

/// The 429 load-shedding response: emitted instead of admission when
/// batch occupancy + queue depth would exceed the watermark. An idle
/// server (zero pending) always admits — the watermark sheds *load*, so a
/// single batch larger than the watermark must not be rejected forever
/// (retry-with-backoff has to be able to succeed once the queue drains).
fn shed_check(batcher: &Batcher, serving: &ServingConfig, incoming: usize) -> Option<HttpResponse> {
    let w = serving.shed_watermark?;
    let depth = batcher.pending();
    serving.should_shed(depth, incoming).then(|| {
        HttpResponse::json(
            429,
            Json::obj(vec![
                ("error", Json::str("overloaded: admission watermark exceeded")),
                ("shed", Json::Bool(true)),
                ("pending", Json::num(depth as f64)),
                ("watermark", Json::num(w as f64)),
            ])
            .to_string(),
        )
    })
}

/// The lifecycle handle for a `/v1/generate` admission: the connection's
/// cancel token, the request's own deadline (or the serving default), and
/// the configured max queue wait.
fn request_handle(
    cancel: &CancelToken,
    deadline_ms: Option<u64>,
    serving: &ServingConfig,
) -> RequestHandle {
    RequestHandle {
        token: cancel.clone(),
        link: None,
        deadline: deadline_ms
            .or(serving.deadline_default_ms)
            .map(|ms| Instant::now() + Duration::from_millis(ms)),
        max_queue_ticks: serving.max_queue_ticks,
    }
}

/// The lifecycle handle for one `/v1/batch` member: its own token (so
/// `/v1/cancel` targets a single member) *linked* to the connection token
/// (so a dropped batch client still cancels every member row).
fn member_handle(
    conn: &CancelToken,
    deadline_ms: Option<u64>,
    serving: &ServingConfig,
) -> RequestHandle {
    RequestHandle {
        token: CancelToken::new(),
        link: Some(conn.clone()),
        ..request_handle(conn, deadline_ms, serving)
    }
}

#[allow(clippy::too_many_arguments)]
fn admit(
    engine: &mut Engine<'_>,
    batcher: &mut Batcher,
    waiters: &mut HashMap<u64, Waiter>,
    groups: &mut HashMap<u64, Group>,
    next_id: &mut u64,
    next_group: &mut u64,
    serving: &ServingConfig,
    inc: Incoming,
) {
    match (inc.req.method.as_str(), inc.req.path.as_str()) {
        ("GET", "/health") => {
            let _ = inc
                .reply
                .send(ServerReply::Full(HttpResponse::json(200, r#"{"ok":true}"#.into())));
        }
        ("GET", "/v1/metrics") => {
            let _ = inc
                .reply
                .send(ServerReply::Full(handle_metrics(engine, Some(batcher))));
        }
        ("POST", "/v1/generate") => match parse_generate(&inc.req.body) {
            Ok((prompt, max_new, stream, deadline_ms)) => {
                if let Some(resp) = capacity_check(engine) {
                    engine.metrics.requests_rejected_capacity += 1;
                    let _ = inc.reply.send(ServerReply::Full(resp));
                    return;
                }
                if let Some(resp) = shed_check(batcher, serving, 1) {
                    engine.metrics.requests_shed += 1;
                    let _ = inc.reply.send(ServerReply::Full(resp));
                    return;
                }
                *next_id += 1;
                let handle = request_handle(&inc.cancel, deadline_ms, serving);
                batcher.submit_with(
                    Request {
                        id: *next_id,
                        prompt: prompt.clone(),
                        max_new_tokens: max_new,
                    },
                    handle,
                );
                waiters.insert(
                    *next_id,
                    Waiter::Single {
                        reply: inc.reply,
                        prompt,
                        stream,
                        streamed: 0,
                        token: inc.cancel,
                    },
                );
            }
            Err(resp) => {
                let _ = inc.reply.send(ServerReply::Full(*resp));
            }
        },
        ("POST", "/v1/batch") => {
            // batch probe: {"prompts": [...], "max_new_tokens": n}
            match parse_batch(&inc.req.body) {
                Ok((prompts, max_new, deadline_ms)) => {
                    // per member: each sequence leases blocks_per_sequence
                    // (members need not fit simultaneously — the queue
                    // defers them — but one that can never fit is rejected)
                    if let Some(resp) = capacity_check(engine) {
                        engine.metrics.requests_rejected_capacity += prompts.len() as u64;
                        let _ = inc.reply.send(ServerReply::Full(resp));
                        return;
                    }
                    if let Some(resp) = shed_check(batcher, serving, prompts.len()) {
                        engine.metrics.requests_shed += prompts.len() as u64;
                        let _ = inc.reply.send(ServerReply::Full(resp));
                        return;
                    }
                    *next_group += 1;
                    let key = *next_group;
                    groups.insert(
                        key,
                        Group {
                            reply: inc.reply,
                            remaining: prompts.len(),
                            items: Vec::with_capacity(prompts.len()),
                        },
                    );
                    for p in prompts {
                        *next_id += 1;
                        let handle = member_handle(&inc.cancel, deadline_ms, serving);
                        batcher.submit_with(
                            Request {
                                id: *next_id,
                                prompt: p,
                                max_new_tokens: max_new,
                            },
                            handle,
                        );
                        waiters.insert(*next_id, Waiter::Group { key });
                    }
                }
                Err(resp) => {
                    let _ = inc.reply.send(ServerReply::Full(*resp));
                }
            }
        }
        ("POST", "/v1/cancel") => {
            // {"id": N} — trip the request's token; the next tick retires it
            let id = Json::parse(&inc.req.body)
                .ok()
                .and_then(|j| j.get("id").and_then(|v| v.as_f64()))
                .map(|id| id as u64);
            let resp = match id {
                Some(id) => {
                    let found = batcher.cancel(id);
                    HttpResponse::json(
                        if found { 200 } else { 404 },
                        Json::obj(vec![
                            ("id", Json::num(id as f64)),
                            ("cancelled", Json::Bool(found)),
                        ])
                        .to_string(),
                    )
                }
                None => HttpResponse::json(400, r#"{"error":"missing id"}"#.into()),
            };
            let _ = inc.reply.send(ServerReply::Full(resp));
        }
        _ => {
            let _ = inc.reply.send(ServerReply::Full(HttpResponse::json(
                404,
                r#"{"error":"not found"}"#.into(),
            )));
        }
    }
}

type BatchParams = (Vec<Vec<u8>>, usize, Option<u64>);

fn parse_batch(body: &str) -> Result<BatchParams, Box<HttpResponse>> {
    let parsed = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return Err(Box::new(error_json(400, format!("bad json: {e}")))),
    };
    let Some(prompts) = parsed.get("prompts").and_then(|p| p.as_arr()) else {
        return Err(Box::new(HttpResponse::json(
            400,
            r#"{"error":"missing prompts"}"#.into(),
        )));
    };
    let max_new = parsed
        .get("max_new_tokens")
        .and_then(|v| v.as_usize())
        .unwrap_or(16);
    let mut out = Vec::with_capacity(prompts.len());
    for p in prompts {
        let Some(text) = p.as_str() else {
            return Err(Box::new(HttpResponse::json(
                400,
                r#"{"error":"prompt not a string"}"#.into(),
            )));
        };
        out.push(text.as_bytes().to_vec());
    }
    if out.is_empty() {
        return Err(Box::new(HttpResponse::json(
            400,
            r#"{"error":"empty prompts"}"#.into(),
        )));
    }
    let deadline_ms = parse_deadline_ms(&parsed)?;
    Ok((out, max_new, deadline_ms))
}

/// Advance the lifecycle exit counters for one completion.
fn count_exit(metrics: &mut Metrics, reason: FinishReason) {
    match reason {
        FinishReason::Length => {}
        FinishReason::Cancelled => metrics.requests_cancelled += 1,
        FinishReason::Deadline => metrics.requests_deadline_expired += 1,
        FinishReason::Disconnected => metrics.requests_disconnected += 1,
        FinishReason::QueueTimeout => metrics.requests_shed += 1,
        FinishReason::NoCapacity => metrics.requests_rejected_capacity += 1,
    }
}

/// KV sizing snapshot threaded into [`resolve`] so a batcher-side
/// never-fits completion can report the real block numbers.
#[derive(Clone, Copy)]
struct KvSizing {
    needed: usize,
    capacity: usize,
}

fn resolve(
    waiters: &mut HashMap<u64, Waiter>,
    groups: &mut HashMap<u64, Group>,
    metrics: &mut Metrics,
    kv: KvSizing,
    c: Completion,
) {
    count_exit(metrics, c.finish_reason);
    match waiters.remove(&c.id) {
        Some(Waiter::Single {
            reply,
            prompt,
            stream,
            ..
        }) => {
            if c.finish_reason == FinishReason::QueueTimeout {
                // shed from the queue before admission: nothing streamed
                // yet, so a plain error response is always well-formed
                let _ = reply.send(ServerReply::Full(queue_timeout_json(c.id)));
            } else if c.finish_reason == FinishReason::NoCapacity {
                // rejected by the batcher's never-fits sweep: never
                // admitted, nothing streamed, plain error is well-formed
                let _ = reply.send(ServerReply::Full(capacity_reject_json(kv.needed, kv.capacity)));
            } else if stream {
                let _ = reply.send(ServerReply::Chunk(final_line(&c, &prompt)));
                let _ = reply.send(ServerReply::End);
                if c.finish_reason != FinishReason::Disconnected {
                    metrics.stream_flushes += 1;
                }
            } else {
                let _ = reply.send(ServerReply::Full(completion_json(
                    c.id,
                    &prompt,
                    &c.text,
                    c.finish_reason,
                )));
            }
        }
        Some(Waiter::Group { key }) => {
            let done = {
                let g = groups.get_mut(&key).expect("group for member");
                g.items.push((c.id, c.text, c.finish_reason));
                g.remaining -= 1;
                g.remaining == 0
            };
            if done {
                let mut g = groups.remove(&key).expect("group complete");
                g.items.sort_by_key(|(id, _, _)| *id);
                let items: Vec<Json> = g
                    .items
                    .iter()
                    .map(|(id, text, reason)| {
                        Json::obj(vec![
                            ("id", Json::num(*id as f64)),
                            ("text", Json::str(String::from_utf8_lossy(text).to_string())),
                            ("finish_reason", Json::str(reason.as_str())),
                        ])
                    })
                    .collect();
                let _ = g.reply.send(ServerReply::Full(HttpResponse::json(
                    200,
                    Json::obj(vec![("completions", Json::arr(items))]).to_string(),
                )));
            }
        }
        None => {
            // waiter dropped (client hung up mid-flight) — nothing to do
        }
    }
}
