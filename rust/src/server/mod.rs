//! Serving front-end: std-net HTTP server + JSON API + engine service loop.

pub mod api;
pub mod http;

pub use http::{serve, HttpRequest, HttpResponse, Incoming};
