//! Serving front-end: std-net HTTP server + JSON API + engine service loop.
//!
//! [`http`] owns the sockets (accept loop, request parsing, full and
//! chunked-transfer responses); [`api`] owns the semantics (endpoint
//! routing, the continuous-batching engine loop, response/stream routing
//! back to waiting connections). See docs/API.md for the wire contract.

pub mod api;
pub mod http;

pub use http::{serve, HttpRequest, HttpResponse, Incoming, ServerReply};
