//! NUMA execution domains (ROADMAP: NUMA pinning + pool sharding).
//!
//! HGCA's CPU-side sparse attention streams KV slabs from RAM; on
//! multi-socket hosts that bandwidth halves the moment a worker reads a
//! slab homed on the other socket. This module gives every layer of the
//! stack a shared notion of *where* memory and workers live:
//!
//! * [`Topology`] — the node count plus (when known) each node's CPU set,
//!   detected from `/sys/devices/system/node` on Linux. A deterministic
//!   **synthetic** topology (`--numa-nodes N` / `HGCA_NUMA_NODES`) exists
//!   for tests and single-socket development: it has the same sharding
//!   behaviour with no affinity information.
//! * [`NodeId`] — a dense 0-based node index. Every placement decision in
//!   the stack (worker queues, head shard maps, GPU block budgets, EDF
//!   admission) speaks this index.
//! * [`Topology::pin_current_thread`] — best-effort affinity pinning via
//!   `sched_setaffinity`, behind a no-op fallback (synthetic topologies,
//!   non-Linux hosts, or a denied syscall simply leave the thread
//!   unpinned) so sandboxes and CI stay green.
//!
//! Placement never changes numerics: sharding decides *which queue runs a
//! task* and *which budget a lease draws from*, while task packing and
//! per-job arithmetic stay bitwise-identical across topologies. The
//! conformance suite (`tests/integration_numa.rs`) pins this.

use std::fmt;
use std::fs;
use std::path::Path;

/// Index of a NUMA node within a [`Topology`] (dense, 0-based).
pub type NodeId = usize;

/// Where a topology's node count came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologySource {
    /// Default single flat memory domain (no detection ran or found one
    /// node).
    Single,
    /// Detected from `/sys/devices/system/node` (CPU sets known —
    /// pinning is possible).
    Sysfs,
    /// Forced via `--numa-nodes` / `HGCA_NUMA_NODES` (no CPU sets —
    /// pinning is a no-op).
    Synthetic,
}

/// The machine's (or a synthetic) NUMA layout: how many memory domains
/// exist and, when detected from sysfs, which CPUs belong to each.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    /// Per node: the CPU ids it owns (empty when unknown — synthetic or
    /// fallback topologies).
    cpus: Vec<Vec<usize>>,
    source: TopologySource,
}

impl Default for Topology {
    fn default() -> Self {
        Topology::single()
    }
}

impl fmt::Display for Topology {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} node(s) [{:?}]", self.nodes(), self.source)
    }
}

impl Topology {
    /// The flat single-domain topology — every pre-NUMA behaviour of the
    /// stack is exactly "this topology everywhere".
    pub fn single() -> Topology {
        Topology {
            cpus: vec![Vec::new()],
            source: TopologySource::Single,
        }
    }

    /// A synthetic `n`-node topology (deterministic, no affinity
    /// information). Panics when `n == 0`.
    pub fn synthetic(n: usize) -> Topology {
        assert!(n >= 1, "a topology needs at least one node");
        if n == 1 {
            return Topology::single();
        }
        Topology {
            cpus: vec![Vec::new(); n],
            source: TopologySource::Synthetic,
        }
    }

    /// Detect the topology: `HGCA_NUMA_NODES` (synthetic override) wins,
    /// then `/sys/devices/system/node`, else a single flat domain.
    pub fn detect() -> Topology {
        if let Some(t) = std::env::var("HGCA_NUMA_NODES")
            .ok()
            .and_then(|v| Self::synthetic_from_env(&v))
        {
            return t;
        }
        Self::from_sysfs(Path::new("/sys/devices/system/node")).unwrap_or_else(Topology::single)
    }

    /// Parse an `HGCA_NUMA_NODES` value; `None` when unparsable or zero
    /// (detection then falls through to sysfs).
    pub fn synthetic_from_env(v: &str) -> Option<Topology> {
        v.trim()
            .parse::<usize>()
            .ok()
            .filter(|n| *n >= 1)
            .map(Topology::synthetic)
    }

    /// Scan a sysfs node directory (`nodeN` subdirs + their `cpulist`).
    /// `None` when the directory is missing/empty or holds a single node.
    /// Best-effort throughout: an unreadable or non-UTF-8 entry is
    /// skipped, never allowed to degrade a multi-socket host to a flat
    /// topology (matching `parse_cpulist`'s skip-malformed contract).
    fn from_sysfs(base: &Path) -> Option<Topology> {
        let mut nodes: Vec<(usize, Vec<usize>)> = Vec::new();
        for entry in fs::read_dir(base).ok()? {
            let Ok(entry) = entry else { continue };
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(id) = name.strip_prefix("node").and_then(|r| r.parse::<usize>().ok())
            else {
                continue;
            };
            let cpulist = fs::read_to_string(entry.path().join("cpulist")).unwrap_or_default();
            nodes.push((id, parse_cpulist(&cpulist)));
        }
        if nodes.len() < 2 {
            return None; // zero or one node: the flat default is exact
        }
        // dense 0-based indices in sysfs id order (ids are positionally
        // remapped if sparse, keeping the layout deterministic)
        nodes.sort_by_key(|(id, _)| *id);
        Some(Topology {
            cpus: nodes.into_iter().map(|(_, c)| c).collect(),
            source: TopologySource::Sysfs,
        })
    }

    /// Number of memory domains (≥ 1).
    pub fn nodes(&self) -> usize {
        self.cpus.len()
    }

    /// True for the flat single-domain topology.
    pub fn is_single(&self) -> bool {
        self.nodes() == 1
    }

    pub fn source(&self) -> TopologySource {
        self.source
    }

    /// The CPUs owned by `node` (empty when unknown).
    pub fn cpus_of(&self, node: NodeId) -> &[usize] {
        &self.cpus[node % self.nodes()]
    }

    /// Deterministic round-robin placement of a dense index space (worker
    /// ids, head ids) onto nodes.
    pub fn node_of(&self, index: usize) -> NodeId {
        index % self.nodes()
    }

    /// The per-head shard map for one sequence homed on `base`: head `h`
    /// lives on `(base + h) % nodes`. Single-node topologies map every
    /// head to node 0 (today's flat layout, bit for bit); multi-node
    /// topologies spread slabs round-robin starting at the home node, so
    /// placement is a pure function of `(base, h, nodes)` and never of
    /// runtime state.
    pub fn shard_heads(&self, heads: usize, base: NodeId) -> Vec<NodeId> {
        let n = self.nodes();
        (0..heads).map(|h| (base + h) % n).collect()
    }

    /// Best-effort: pin the calling thread to `node`'s CPU set. Returns
    /// `false` — and changes nothing — when the node's CPUs are unknown
    /// (synthetic topology), the platform has no affinity syscall, or the
    /// kernel refuses (sandbox seccomp). Callers must treat pinning as an
    /// optimization only.
    pub fn pin_current_thread(&self, node: NodeId) -> bool {
        let cpus = self.cpus_of(node);
        if cpus.is_empty() {
            return false;
        }
        set_current_thread_affinity(cpus)
    }
}

/// Parse a sysfs cpulist ("0-3,8,10-11") into CPU ids. Malformed pieces
/// are skipped (best-effort — an empty result just disables pinning).
fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for piece in s.trim().split(',') {
        let piece = piece.trim();
        if piece.is_empty() {
            continue;
        }
        if let Some((a, b)) = piece.split_once('-') {
            if let (Ok(a), Ok(b)) = (a.trim().parse::<usize>(), b.trim().parse::<usize>()) {
                if a <= b && b - a < 4096 {
                    out.extend(a..=b);
                }
            }
        } else if let Ok(c) = piece.parse::<usize>() {
            out.push(c);
        }
    }
    out
}

/// 1024-bit cpu_set_t as 16 u64 words (glibc's default CPU_SETSIZE).
#[cfg(target_os = "linux")]
fn set_current_thread_affinity(cpus: &[usize]) -> bool {
    const WORDS: usize = 16;
    let mut mask = [0u64; WORDS];
    let mut any = false;
    for &c in cpus {
        if c < WORDS * 64 {
            mask[c / 64] |= 1u64 << (c % 64);
            any = true;
        }
    }
    if !any {
        return false;
    }
    extern "C" {
        // pid 0 = the calling thread; linking libc is implicit on linux
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    // SAFETY: mask points at WORDS u64s and cpusetsize matches its byte
    // length; the syscall reads, never writes.
    unsafe { sched_setaffinity(0, WORDS * 8, mask.as_ptr()) == 0 }
}

#[cfg(not(target_os = "linux"))]
fn set_current_thread_affinity(_cpus: &[usize]) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_is_one_flat_node() {
        let t = Topology::single();
        assert_eq!(t.nodes(), 1);
        assert!(t.is_single());
        assert_eq!(t.source(), TopologySource::Single);
        assert_eq!(t.node_of(7), 0);
        assert_eq!(t.shard_heads(4, 0), vec![0, 0, 0, 0]);
        assert!(!t.pin_current_thread(0), "no CPU info: pinning is a no-op");
    }

    #[test]
    fn synthetic_round_robins_deterministically() {
        let t = Topology::synthetic(4);
        assert_eq!(t.nodes(), 4);
        assert_eq!(t.source(), TopologySource::Synthetic);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(5), 1);
        // shard map offset by the home node, wrapping
        assert_eq!(t.shard_heads(6, 0), vec![0, 1, 2, 3, 0, 1]);
        assert_eq!(t.shard_heads(6, 2), vec![2, 3, 0, 1, 2, 3]);
        // repeated construction is identical (placement is a pure function)
        assert_eq!(t, Topology::synthetic(4));
    }

    #[test]
    fn synthetic_one_collapses_to_single() {
        assert!(Topology::synthetic(1).is_single());
        assert_eq!(Topology::synthetic(1), Topology::single());
    }

    #[test]
    #[should_panic]
    fn zero_nodes_panics() {
        Topology::synthetic(0);
    }

    #[test]
    fn env_value_parsing() {
        assert_eq!(Topology::synthetic_from_env("2").map(|t| t.nodes()), Some(2));
        assert_eq!(Topology::synthetic_from_env(" 4 ").map(|t| t.nodes()), Some(4));
        assert!(Topology::synthetic_from_env("0").is_none());
        assert!(Topology::synthetic_from_env("banana").is_none());
        assert!(Topology::synthetic_from_env("").is_none());
    }

    #[test]
    fn cpulist_parsing() {
        assert_eq!(parse_cpulist("0-3,8,10-11\n"), vec![0, 1, 2, 3, 8, 10, 11]);
        assert_eq!(parse_cpulist("5"), vec![5]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        assert_eq!(parse_cpulist("3-1"), Vec::<usize>::new(), "inverted range skipped");
        assert_eq!(parse_cpulist("junk,2"), vec![2], "malformed pieces skipped");
    }

    #[test]
    fn detect_yields_at_least_one_node() {
        // whatever the machine (or env) looks like, detection never
        // produces an unusable topology
        let t = Topology::detect();
        assert!(t.nodes() >= 1);
    }

    #[test]
    fn pinning_is_best_effort_on_detected_topology() {
        // must never panic or corrupt anything, whatever it returns
        let t = Topology::detect();
        for node in 0..t.nodes() {
            let _ = t.pin_current_thread(node);
        }
    }

    #[test]
    fn cpus_of_wraps_out_of_range_nodes() {
        let t = Topology::synthetic(2);
        assert_eq!(t.cpus_of(5), t.cpus_of(1));
    }
}
