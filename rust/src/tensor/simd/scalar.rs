//! Portable scalar kernels — the pre-dispatch implementations moved here
//! verbatim from `tensor/ops.rs` and `kv/quant.rs`. These are the oracle
//! every SIMD level is gated against: `dot_i8` and `max_abs` bitwise, the
//! f32 kernels to ≤ 1e-5 per element (`tests/integration_simd.rs`).

pub(super) fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled: the single hottest loop in CPU sparse attention
    let mut s0 = 0.0f32;
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    let mut s3 = 0.0f32;
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

pub(super) fn axpy(scale: f32, v: &[f32], out: &mut [f32]) {
    debug_assert_eq!(v.len(), out.len());
    for (o, &x) in out.iter_mut().zip(v.iter()) {
        *o += scale * x;
    }
}

pub(super) fn softmax_lse(x: &mut [f32]) -> f32 {
    let m = x.iter().copied().fold(f32::NEG_INFINITY, f32::max).max(-1e30);
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    let sum = sum.max(1e-30);
    for v in x.iter_mut() {
        *v /= sum;
    }
    m + sum.ln()
}

pub(super) fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0i32;
    for (&x, &y) in a.iter().zip(b.iter()) {
        acc += x as i32 * y as i32;
    }
    acc
}

pub(super) fn max_abs(v: &[f32]) -> f32 {
    v.iter().fold(0.0f32, |m, x| m.max(x.abs()))
}
