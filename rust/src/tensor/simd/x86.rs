//! x86_64 kernels: AVX2+FMA (256-bit) and SSE4.1 (128-bit) tables.
//!
//! Safety model: every `#[target_feature]` function here is reached only
//! through the [`AVX2`] / [`SSE4`] tables, and those are only handed out
//! by `Kernels::for_level` after `SimdLevel::supported()` confirmed the
//! features via `is_x86_feature_detected!` — so the required ISA is
//! guaranteed present at every unsafe call site below.
//!
//! Determinism: each kernel uses a fixed accumulator shape (two vector
//! accumulators for `dot`, one for the reductions) and a fixed reduce
//! order — lanes are stored to an array and summed left-to-right, never
//! tree-reduced with `hadd` — so a level is a pure function of its
//! inputs. `dot_i8` and `max_abs` are exact (integer adds / IEEE max);
//! the f32 kernels reassociate and carry the documented 1e-5 bound.

#![allow(clippy::missing_safety_doc)] // private module; safety is the table contract above

use core::arch::x86_64::*;

use super::{Kernels, SimdLevel};

pub(super) static AVX2: Kernels = Kernels {
    level: SimdLevel::Avx2,
    dot: dot_avx2,
    axpy: axpy_avx2,
    softmax_lse: softmax_lse_avx2,
    dot_i8: dot_i8_avx2,
    max_abs: max_abs_avx2,
};

pub(super) static SSE4: Kernels = Kernels {
    level: SimdLevel::Sse4,
    dot: dot_sse4,
    axpy: axpy_sse4,
    softmax_lse: softmax_lse_sse4,
    dot_i8: dot_i8_sse4,
    max_abs: max_abs_sse4,
};

// ---------------------------------------------------------------- reduces

/// Lane-ordered horizontal sum: store then add lanes 0..8 left-to-right.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum256(v: __m256) -> f32 {
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), v);
    let mut s = 0.0f32;
    for &l in &lanes {
        s += l;
    }
    s
}

/// Lane-ordered horizontal sum over 4 lanes.
#[inline]
#[target_feature(enable = "sse4.1")]
unsafe fn hsum128(v: __m128) -> f32 {
    let mut lanes = [0.0f32; 4];
    _mm_storeu_ps(lanes.as_mut_ptr(), v);
    let mut s = 0.0f32;
    for &l in &lanes {
        s += l;
    }
    s
}

// ------------------------------------------------------------------- dot

fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: table handed out only after AVX2+FMA detection (module doc).
    unsafe { dot_avx2_impl(a, b) }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_avx2_impl(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
        acc1 = _mm256_fmadd_ps(
            _mm256_loadu_ps(pa.add(i + 8)),
            _mm256_loadu_ps(pb.add(i + 8)),
            acc1,
        );
        i += 16;
    }
    if i + 8 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
        i += 8;
    }
    let mut s = hsum256(_mm256_add_ps(acc0, acc1));
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

fn dot_sse4(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: table handed out only after SSE4.1 detection (module doc).
    unsafe { dot_sse4_impl(a, b) }
}

#[target_feature(enable = "sse4.1")]
unsafe fn dot_sse4_impl(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = _mm_setzero_ps();
    let mut acc1 = _mm_setzero_ps();
    let mut i = 0usize;
    while i + 8 <= n {
        acc0 = _mm_add_ps(acc0, _mm_mul_ps(_mm_loadu_ps(pa.add(i)), _mm_loadu_ps(pb.add(i))));
        acc1 = _mm_add_ps(
            acc1,
            _mm_mul_ps(_mm_loadu_ps(pa.add(i + 4)), _mm_loadu_ps(pb.add(i + 4))),
        );
        i += 8;
    }
    if i + 4 <= n {
        acc0 = _mm_add_ps(acc0, _mm_mul_ps(_mm_loadu_ps(pa.add(i)), _mm_loadu_ps(pb.add(i))));
        i += 4;
    }
    let mut s = hsum128(_mm_add_ps(acc0, acc1));
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

// ------------------------------------------------------------------ axpy

fn axpy_avx2(scale: f32, v: &[f32], out: &mut [f32]) {
    debug_assert_eq!(v.len(), out.len());
    // SAFETY: table handed out only after AVX2+FMA detection (module doc).
    unsafe { axpy_avx2_impl(scale, v, out) }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn axpy_avx2_impl(scale: f32, v: &[f32], out: &mut [f32]) {
    let n = v.len();
    let vs = _mm256_set1_ps(scale);
    let pv = v.as_ptr();
    let po = out.as_mut_ptr();
    let mut i = 0usize;
    while i + 8 <= n {
        let acc = _mm256_fmadd_ps(vs, _mm256_loadu_ps(pv.add(i)), _mm256_loadu_ps(po.add(i)));
        _mm256_storeu_ps(po.add(i), acc);
        i += 8;
    }
    while i < n {
        out[i] += scale * v[i];
        i += 1;
    }
}

fn axpy_sse4(scale: f32, v: &[f32], out: &mut [f32]) {
    debug_assert_eq!(v.len(), out.len());
    // SAFETY: table handed out only after SSE4.1 detection (module doc).
    unsafe { axpy_sse4_impl(scale, v, out) }
}

#[target_feature(enable = "sse4.1")]
unsafe fn axpy_sse4_impl(scale: f32, v: &[f32], out: &mut [f32]) {
    let n = v.len();
    let vs = _mm_set1_ps(scale);
    let pv = v.as_ptr();
    let po = out.as_mut_ptr();
    let mut i = 0usize;
    while i + 4 <= n {
        let acc = _mm_add_ps(_mm_loadu_ps(po.add(i)), _mm_mul_ps(vs, _mm_loadu_ps(pv.add(i))));
        _mm_storeu_ps(po.add(i), acc);
        i += 4;
    }
    while i < n {
        out[i] += scale * v[i];
        i += 1;
    }
}

// ----------------------------------------------------------- softmax_lse

// The exp itself stays scalar libm in every level — it is the expensive,
// implementation-defined part, and keeping it per-element identical to
// the scalar kernel pins the cross-level tolerance to the (tiny) sum and
// divide reassociation. Max is IEEE-exact; the exp-sum uses the fixed
// lane-ordered reduce.

fn softmax_lse_avx2(x: &mut [f32]) -> f32 {
    // SAFETY: table handed out only after AVX2+FMA detection (module doc).
    unsafe { softmax_lse_avx2_impl(x) }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn softmax_lse_avx2_impl(x: &mut [f32]) -> f32 {
    let n = x.len();
    let p = x.as_mut_ptr();
    // vector max pass (exact)
    let mut m = f32::NEG_INFINITY;
    let mut i = 0usize;
    if n >= 8 {
        let mut vm = _mm256_loadu_ps(p);
        i = 8;
        while i + 8 <= n {
            vm = _mm256_max_ps(vm, _mm256_loadu_ps(p.add(i)));
            i += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), vm);
        for &l in &lanes {
            m = m.max(l);
        }
    }
    while i < n {
        m = m.max(x[i]);
        i += 1;
    }
    let m = m.max(-1e30);
    // scalar exp pass (per-element identical to the scalar kernel)
    for v in x.iter_mut() {
        *v = (*v - m).exp();
    }
    // lane-ordered vector sum of the exps
    let mut acc = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 8 <= n {
        acc = _mm256_add_ps(acc, _mm256_loadu_ps(p.add(i)));
        i += 8;
    }
    let mut sum = hsum256(acc);
    while i < n {
        sum += x[i];
        i += 1;
    }
    let sum = sum.max(1e-30);
    // vector normalize (IEEE divide, per-element exact given `sum`)
    let vs = _mm256_set1_ps(sum);
    let mut i = 0usize;
    while i + 8 <= n {
        _mm256_storeu_ps(p.add(i), _mm256_div_ps(_mm256_loadu_ps(p.add(i)), vs));
        i += 8;
    }
    while i < n {
        x[i] /= sum;
        i += 1;
    }
    m + sum.ln()
}

fn softmax_lse_sse4(x: &mut [f32]) -> f32 {
    // SAFETY: table handed out only after SSE4.1 detection (module doc).
    unsafe { softmax_lse_sse4_impl(x) }
}

#[target_feature(enable = "sse4.1")]
unsafe fn softmax_lse_sse4_impl(x: &mut [f32]) -> f32 {
    let n = x.len();
    let p = x.as_mut_ptr();
    let mut m = f32::NEG_INFINITY;
    let mut i = 0usize;
    if n >= 4 {
        let mut vm = _mm_loadu_ps(p);
        i = 4;
        while i + 4 <= n {
            vm = _mm_max_ps(vm, _mm_loadu_ps(p.add(i)));
            i += 4;
        }
        let mut lanes = [0.0f32; 4];
        _mm_storeu_ps(lanes.as_mut_ptr(), vm);
        for &l in &lanes {
            m = m.max(l);
        }
    }
    while i < n {
        m = m.max(x[i]);
        i += 1;
    }
    let m = m.max(-1e30);
    for v in x.iter_mut() {
        *v = (*v - m).exp();
    }
    let mut acc = _mm_setzero_ps();
    let mut i = 0usize;
    while i + 4 <= n {
        acc = _mm_add_ps(acc, _mm_loadu_ps(p.add(i)));
        i += 4;
    }
    let mut sum = hsum128(acc);
    while i < n {
        sum += x[i];
        i += 1;
    }
    let sum = sum.max(1e-30);
    let vs = _mm_set1_ps(sum);
    let mut i = 0usize;
    while i + 4 <= n {
        _mm_storeu_ps(p.add(i), _mm_div_ps(_mm_loadu_ps(p.add(i)), vs));
        i += 4;
    }
    while i < n {
        x[i] /= sum;
        i += 1;
    }
    m + sum.ln()
}

// ----------------------------------------------------------------- dot_i8

fn dot_i8_avx2(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: table handed out only after AVX2+FMA detection (module doc).
    unsafe { dot_i8_avx2_impl(a, b) }
}

/// 16 bytes/step: sign-extend i8→i16, `vpmaddwd` pairwise i16×i16→i32,
/// accumulate in 8 i32 lanes. i32 adds are associative, so the result is
/// bitwise-identical to the scalar loop for any lane order.
#[target_feature(enable = "avx2")]
unsafe fn dot_i8_avx2_impl(a: &[i8], b: &[i8]) -> i32 {
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 16 <= n {
        let va = _mm256_cvtepi8_epi16(_mm_loadu_si128(pa.add(i) as *const __m128i));
        let vb = _mm256_cvtepi8_epi16(_mm_loadu_si128(pb.add(i) as *const __m128i));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(va, vb));
        i += 16;
    }
    let mut lanes = [0i32; 8];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
    let mut s = 0i32;
    for &l in &lanes {
        s += l;
    }
    while i < n {
        s += a[i] as i32 * b[i] as i32;
        i += 1;
    }
    s
}

fn dot_i8_sse4(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: table handed out only after SSE4.1 detection (module doc).
    unsafe { dot_i8_sse4_impl(a, b) }
}

/// 8 bytes/step: `pmovsxbw` + `pmaddwd`, 4 i32 lanes.
#[target_feature(enable = "sse4.1")]
unsafe fn dot_i8_sse4_impl(a: &[i8], b: &[i8]) -> i32 {
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc = _mm_setzero_si128();
    let mut i = 0usize;
    while i + 8 <= n {
        let va = _mm_cvtepi8_epi16(_mm_loadl_epi64(pa.add(i) as *const __m128i));
        let vb = _mm_cvtepi8_epi16(_mm_loadl_epi64(pb.add(i) as *const __m128i));
        acc = _mm_add_epi32(acc, _mm_madd_epi16(va, vb));
        i += 8;
    }
    let mut lanes = [0i32; 4];
    _mm_storeu_si128(lanes.as_mut_ptr() as *mut __m128i, acc);
    let mut s = 0i32;
    for &l in &lanes {
        s += l;
    }
    while i < n {
        s += a[i] as i32 * b[i] as i32;
        i += 1;
    }
    s
}

// ---------------------------------------------------------------- max_abs

fn max_abs_avx2(v: &[f32]) -> f32 {
    // SAFETY: table handed out only after AVX2+FMA detection (module doc).
    unsafe { max_abs_avx2_impl(v) }
}

/// |x| via sign-bit andnot, IEEE max — exact at every level.
#[target_feature(enable = "avx2")]
unsafe fn max_abs_avx2_impl(v: &[f32]) -> f32 {
    let n = v.len();
    let p = v.as_ptr();
    let sign = _mm256_set1_ps(-0.0);
    let mut vm = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 8 <= n {
        vm = _mm256_max_ps(vm, _mm256_andnot_ps(sign, _mm256_loadu_ps(p.add(i))));
        i += 8;
    }
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), vm);
    let mut m = 0.0f32;
    for &l in &lanes {
        m = m.max(l);
    }
    while i < n {
        m = m.max(v[i].abs());
        i += 1;
    }
    m
}

fn max_abs_sse4(v: &[f32]) -> f32 {
    // SAFETY: table handed out only after SSE4.1 detection (module doc).
    unsafe { max_abs_sse4_impl(v) }
}

#[target_feature(enable = "sse4.1")]
unsafe fn max_abs_sse4_impl(v: &[f32]) -> f32 {
    let n = v.len();
    let p = v.as_ptr();
    let sign = _mm_set1_ps(-0.0);
    let mut vm = _mm_setzero_ps();
    let mut i = 0usize;
    while i + 4 <= n {
        vm = _mm_max_ps(vm, _mm_andnot_ps(sign, _mm_loadu_ps(p.add(i))));
        i += 4;
    }
    let mut lanes = [0.0f32; 4];
    _mm_storeu_ps(lanes.as_mut_ptr(), vm);
    let mut m = 0.0f32;
    for &l in &lanes {
        m = m.max(l);
    }
    while i < n {
        m = m.max(v[i].abs());
        i += 1;
    }
    m
}
