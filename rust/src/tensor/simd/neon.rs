//! aarch64 NEON kernels (128-bit lanes). NEON is part of the aarch64
//! baseline (`std` requires it), so this table is always supported on
//! aarch64 targets and the `unsafe` blocks below are sound wherever this
//! module compiles.
//!
//! Same determinism shape as the x86 tables: fixed accumulator layout,
//! lanes stored and summed left-to-right; `dot_i8` (`smull` + `sadalp`
//! pairwise accumulate) and `max_abs` are exact, the f32 kernels carry
//! the 1e-5-vs-scalar bound.

use core::arch::aarch64::*;

use super::{Kernels, SimdLevel};

pub(super) static NEON: Kernels = Kernels {
    level: SimdLevel::Neon,
    dot: dot_neon,
    axpy: axpy_neon,
    softmax_lse: softmax_lse_neon,
    dot_i8: dot_i8_neon,
    max_abs: max_abs_neon,
};

/// Lane-ordered horizontal sum over 4 lanes.
#[inline]
unsafe fn hsum128(v: float32x4_t) -> f32 {
    let mut lanes = [0.0f32; 4];
    vst1q_f32(lanes.as_mut_ptr(), v);
    let mut s = 0.0f32;
    for &l in &lanes {
        s += l;
    }
    s
}

fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    // SAFETY: NEON is baseline on aarch64 (module doc).
    unsafe {
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 8 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
            acc1 = vfmaq_f32(acc1, vld1q_f32(pa.add(i + 4)), vld1q_f32(pb.add(i + 4)));
            i += 8;
        }
        if i + 4 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
            i += 4;
        }
        let mut s = hsum128(vaddq_f32(acc0, acc1));
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }
}

fn axpy_neon(scale: f32, v: &[f32], out: &mut [f32]) {
    debug_assert_eq!(v.len(), out.len());
    let n = v.len();
    // SAFETY: NEON is baseline on aarch64 (module doc).
    unsafe {
        let vs = vdupq_n_f32(scale);
        let pv = v.as_ptr();
        let po = out.as_mut_ptr();
        let mut i = 0usize;
        while i + 4 <= n {
            let acc = vfmaq_f32(vld1q_f32(po.add(i)), vs, vld1q_f32(pv.add(i)));
            vst1q_f32(po.add(i), acc);
            i += 4;
        }
        while i < n {
            out[i] += scale * v[i];
            i += 1;
        }
    }
}

fn softmax_lse_neon(x: &mut [f32]) -> f32 {
    let n = x.len();
    // SAFETY: NEON is baseline on aarch64 (module doc).
    unsafe {
        let p = x.as_mut_ptr();
        // vector max pass (exact)
        let mut m = f32::NEG_INFINITY;
        let mut i = 0usize;
        if n >= 4 {
            let mut vm = vld1q_f32(p);
            i = 4;
            while i + 4 <= n {
                vm = vmaxq_f32(vm, vld1q_f32(p.add(i)));
                i += 4;
            }
            let mut lanes = [0.0f32; 4];
            vst1q_f32(lanes.as_mut_ptr(), vm);
            for &l in &lanes {
                m = m.max(l);
            }
        }
        while i < n {
            m = m.max(x[i]);
            i += 1;
        }
        let m = m.max(-1e30);
        // scalar exp pass (per-element identical to the scalar kernel)
        for v in x.iter_mut() {
            *v = (*v - m).exp();
        }
        // lane-ordered vector sum of the exps
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 4 <= n {
            acc = vaddq_f32(acc, vld1q_f32(p.add(i)));
            i += 4;
        }
        let mut sum = hsum128(acc);
        while i < n {
            sum += x[i];
            i += 1;
        }
        let sum = sum.max(1e-30);
        // vector normalize
        let vs = vdupq_n_f32(sum);
        let mut i = 0usize;
        while i + 4 <= n {
            vst1q_f32(p.add(i), vdivq_f32(vld1q_f32(p.add(i)), vs));
            i += 4;
        }
        while i < n {
            x[i] /= sum;
            i += 1;
        }
        m + sum.ln()
    }
}

/// 8 bytes/step: `smull` i8×i8→i16, `sadalp` pairwise-widen accumulate
/// into 4 i32 lanes. Integer adds are associative → bitwise == scalar.
fn dot_i8_neon(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    // SAFETY: NEON is baseline on aarch64 (module doc).
    unsafe {
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc = vdupq_n_s32(0);
        let mut i = 0usize;
        while i + 8 <= n {
            let prod = vmull_s8(vld1_s8(pa.add(i)), vld1_s8(pb.add(i)));
            acc = vpadalq_s16(acc, prod);
            i += 8;
        }
        let mut s = vaddvq_s32(acc);
        while i < n {
            s += a[i] as i32 * b[i] as i32;
            i += 1;
        }
        s
    }
}

fn max_abs_neon(v: &[f32]) -> f32 {
    let n = v.len();
    // SAFETY: NEON is baseline on aarch64 (module doc).
    unsafe {
        let p = v.as_ptr();
        let mut vm = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 4 <= n {
            vm = vmaxq_f32(vm, vabsq_f32(vld1q_f32(p.add(i))));
            i += 4;
        }
        let mut lanes = [0.0f32; 4];
        vst1q_f32(lanes.as_mut_ptr(), vm);
        let mut m = 0.0f32;
        for &l in &lanes {
            m = m.max(l);
        }
        while i < n {
            m = m.max(v[i].abs());
            i += 1;
        }
        m
    }
}
