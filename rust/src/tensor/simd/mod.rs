//! Runtime-dispatched SIMD kernel layer for the CPU sparse-attention hot
//! loops (the PR 10 tentpole; closes the ROADMAP "SIMD i8 dot kernels"
//! follow-on).
//!
//! Five kernels sit on the per-entry critical path of
//! `attention/cpu_attention.rs::{run_job_range, run_job_range_tiered}` and
//! `kv/quant.rs`: f32 [`dot`] / [`axpy`] / [`softmax_lse`], int8
//! [`dot_i8`], and the quantizer's [`max_abs`] scan. Each has explicit
//! `std::arch` implementations — x86_64 AVX2+FMA, x86_64 SSE4.1, aarch64
//! NEON — plus the original scalar code ([`scalar`]) as the portable
//! baseline. One [`Kernels`] fn-pointer table per level; the process picks
//! a table exactly once ([`kernels`], `OnceLock`-cached) from, in
//! precedence order, [`configure`] (the `--simd` flag), the `HGCA_SIMD`
//! env var, then CPUID/target detection ([`detect`]).
//!
//! **Determinism contract.** Dispatch is process-global and frozen at
//! first use, so every worker thread, task split, and NUMA placement runs
//! the *same* table — tokens stay bitwise-identical across worker counts
//! and synthetic node topologies *within* a dispatch level, exactly as the
//! scalar kernels were. Across levels:
//! - [`dot_i8`] is integer math (i32 adds are associative), so every SIMD
//!   implementation is **bitwise-identical to scalar** — the int8 tier's
//!   scores do not move at all under dispatch.
//! - [`max_abs`] and the `softmax_lse` max pass use IEEE max, also exact.
//! - f32 [`dot`] / [`axpy`] / [`softmax_lse`] reassociate additions (wider
//!   lane accumulators, FMA contraction), so they carry a tolerance bound
//!   instead: ≤ 1e-5 vs scalar per element, pinned by
//!   `tests/integration_simd.rs` alongside the end-to-end replay
//!   determinism check per level.
//!
//! Inside a SIMD kernel the accumulator shape and reduce order are fixed
//! (lane 0..N summed left-to-right after a store — never a tree of
//! `hadd`s that would depend on how the compiler schedules them), so a
//! given level is a pure function of its inputs.

use std::fmt;
use std::sync::OnceLock;

mod scalar;

#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

/// One dispatch level = one complete kernel table.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SimdLevel {
    /// Portable baseline (the pre-dispatch scalar kernels, verbatim).
    Scalar,
    /// x86_64 SSE4.1 (128-bit lanes; `pmaddwd` int8 dot, no FMA).
    Sse4,
    /// x86_64 AVX2 + FMA (256-bit lanes; `vpmaddwd` int8 dot).
    Avx2,
    /// aarch64 NEON (128-bit lanes; `smull`/`sadalp` int8 dot).
    Neon,
}

impl SimdLevel {
    /// Stable lowercase name (flag/env spelling and metrics label).
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse4 => "sse4",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    /// Numeric code for the `simd_level` metrics gauge (flat-JSON metrics
    /// carry numbers): scalar=0, sse4=1, avx2=2, neon=3.
    pub fn code(self) -> u32 {
        match self {
            SimdLevel::Scalar => 0,
            SimdLevel::Sse4 => 1,
            SimdLevel::Avx2 => 2,
            SimdLevel::Neon => 3,
        }
    }

    /// Parse a `--simd` / `HGCA_SIMD` value; `auto` (or empty) means "let
    /// detection pick" and returns `None`.
    pub fn parse(s: &str) -> anyhow::Result<Option<SimdLevel>> {
        match s.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => Ok(None),
            "scalar" => Ok(Some(SimdLevel::Scalar)),
            "sse4" | "sse4.1" | "sse41" => Ok(Some(SimdLevel::Sse4)),
            "avx2" => Ok(Some(SimdLevel::Avx2)),
            "neon" => Ok(Some(SimdLevel::Neon)),
            other => anyhow::bail!(
                "unknown SIMD level '{other}' (expected auto, avx2, sse4, neon, or scalar)"
            ),
        }
    }

    /// Whether this host can run the level's kernels.
    pub fn supported(self) -> bool {
        match self {
            SimdLevel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Sse4 => std::arch::is_x86_feature_detected!("sse4.1"),
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx2 => {
                std::arch::is_x86_feature_detected!("avx2")
                    && std::arch::is_x86_feature_detected!("fma")
            }
            #[cfg(target_arch = "aarch64")]
            SimdLevel::Neon => true, // aarch64 baseline includes NEON
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }
}

impl fmt::Display for SimdLevel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Best level this host supports (the `auto` choice).
pub fn detect() -> SimdLevel {
    for level in [SimdLevel::Avx2, SimdLevel::Neon, SimdLevel::Sse4] {
        if level.supported() {
            return level;
        }
    }
    SimdLevel::Scalar
}

/// Every level this host can run, best-first (conformance tests and the
/// bench harness sweep this).
pub fn supported_levels() -> Vec<SimdLevel> {
    [SimdLevel::Avx2, SimdLevel::Neon, SimdLevel::Sse4, SimdLevel::Scalar]
        .into_iter()
        .filter(|l| l.supported())
        .collect()
}

/// A complete kernel table for one dispatch level. Consumers hoist
/// `kernels()` once per job range and call through the fn pointers — one
/// indirect call per kernel invocation, no per-call feature test.
pub struct Kernels {
    /// The level these pointers implement.
    pub level: SimdLevel,
    /// f32 dot product (scores).
    pub dot: fn(&[f32], &[f32]) -> f32,
    /// `out += scale * v` (weighted V accumulate).
    pub axpy: fn(f32, &[f32], &mut [f32]),
    /// In-place softmax returning the log-sum-exp.
    pub softmax_lse: fn(&mut [f32]) -> f32,
    /// Int8 dot with one i32 accumulation (bitwise-identical across levels).
    pub dot_i8: fn(&[i8], &[i8]) -> i32,
    /// Max |x| over a slice (the quantizer's scale scan; exact).
    pub max_abs: fn(&[f32]) -> f32,
}

static SCALAR: Kernels = Kernels {
    level: SimdLevel::Scalar,
    dot: scalar::dot,
    axpy: scalar::axpy,
    softmax_lse: scalar::softmax_lse,
    dot_i8: scalar::dot_i8,
    max_abs: scalar::max_abs,
};

impl Kernels {
    /// The table for an explicit level. Panics if this host cannot run it
    /// (callers gate on [`SimdLevel::supported`] / [`supported_levels`]);
    /// does not touch the process-global dispatch, so conformance tests
    /// and benches can compare levels side by side in one process.
    pub fn for_level(level: SimdLevel) -> &'static Kernels {
        assert!(level.supported(), "SIMD level {level} is not supported on this host");
        match level {
            SimdLevel::Scalar => &SCALAR,
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Sse4 => &x86::SSE4,
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx2 => &x86::AVX2,
            #[cfg(target_arch = "aarch64")]
            SimdLevel::Neon => &neon::NEON,
            #[allow(unreachable_patterns)]
            _ => unreachable!("unsupported level passed the support gate"),
        }
    }
}

static ACTIVE: OnceLock<&'static Kernels> = OnceLock::new();

/// The process-wide kernel table, frozen on first call: `HGCA_SIMD` if
/// set (panics on an unknown or unsupported value — a forced level must
/// never silently fall back, the conformance tests rely on that), else
/// [`detect`].
pub fn kernels() -> &'static Kernels {
    ACTIVE.get_or_init(|| {
        let level = match std::env::var("HGCA_SIMD") {
            Ok(raw) => match SimdLevel::parse(&raw) {
                Ok(Some(l)) => {
                    assert!(
                        l.supported(),
                        "HGCA_SIMD={raw}: level {l} is not supported on this host \
                         (supported: {})",
                        supported_names()
                    );
                    l
                }
                Ok(None) => detect(),
                Err(e) => panic!("HGCA_SIMD: {e}"),
            },
            Err(_) => detect(),
        };
        Kernels::for_level(level)
    })
}

/// The frozen dispatch level (freezes it if not yet frozen) — the
/// `simd_level` metrics gauge and startup logging read this.
pub fn active_level() -> SimdLevel {
    kernels().level
}

fn supported_names() -> String {
    supported_levels()
        .iter()
        .map(|l| l.name())
        .collect::<Vec<_>>()
        .join(", ")
}

/// Apply a `--simd` override (`None` = auto). Must run before the first
/// kernel use: the dispatch table freezes exactly once, so a request that
/// disagrees with an already-frozen level is an error rather than a
/// silent partial switch. Returns the level now in effect.
pub fn configure(request: Option<SimdLevel>) -> anyhow::Result<SimdLevel> {
    match request {
        None => Ok(active_level()),
        Some(want) => {
            anyhow::ensure!(
                want.supported(),
                "--simd {want}: level not supported on this host (supported: {})",
                supported_names()
            );
            let got = ACTIVE.get_or_init(|| Kernels::for_level(want)).level;
            anyhow::ensure!(
                got == want,
                "--simd {want}: dispatch already frozen at '{got}' \
                 (the override must be applied before the first kernel call)"
            );
            Ok(got)
        }
    }
}

/// f32 dot product through the process-wide dispatch.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    (kernels().dot)(a, b)
}

/// `out += scale * v` through the process-wide dispatch.
#[inline]
pub fn axpy(scale: f32, v: &[f32], out: &mut [f32]) {
    (kernels().axpy)(scale, v, out)
}

/// In-place softmax (returns log-sum-exp) through the process-wide
/// dispatch.
#[inline]
pub fn softmax_lse(x: &mut [f32]) -> f32 {
    (kernels().softmax_lse)(x)
}

/// Int8 dot product through the process-wide dispatch.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    (kernels().dot_i8)(a, b)
}

/// Max |x| through the process-wide dispatch.
#[inline]
pub fn max_abs(v: &[f32]) -> f32 {
    (kernels().max_abs)(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_all_levels_and_auto() {
        assert_eq!(SimdLevel::parse("auto").unwrap(), None);
        assert_eq!(SimdLevel::parse("").unwrap(), None);
        assert_eq!(SimdLevel::parse("AVX2").unwrap(), Some(SimdLevel::Avx2));
        assert_eq!(SimdLevel::parse("sse4.1").unwrap(), Some(SimdLevel::Sse4));
        assert_eq!(SimdLevel::parse("neon").unwrap(), Some(SimdLevel::Neon));
        assert_eq!(SimdLevel::parse("scalar").unwrap(), Some(SimdLevel::Scalar));
        assert!(SimdLevel::parse("avx512").is_err());
    }

    #[test]
    fn codes_are_stable() {
        // the metrics gauge meaning must never shift between releases
        assert_eq!(SimdLevel::Scalar.code(), 0);
        assert_eq!(SimdLevel::Sse4.code(), 1);
        assert_eq!(SimdLevel::Avx2.code(), 2);
        assert_eq!(SimdLevel::Neon.code(), 3);
    }

    #[test]
    fn detect_is_supported_and_listed() {
        let d = detect();
        assert!(d.supported());
        let all = supported_levels();
        assert!(all.contains(&d));
        assert!(all.contains(&SimdLevel::Scalar), "scalar is always last-resort");
        assert_eq!(all.first().copied(), Some(d), "detect picks the best level");
    }

    #[test]
    fn for_level_tables_report_their_level() {
        for l in supported_levels() {
            assert_eq!(Kernels::for_level(l).level, l);
        }
    }

    #[test]
    fn global_dispatch_is_frozen_and_consistent() {
        let a = kernels().level;
        let b = active_level();
        assert_eq!(a, b);
        // configure(None) never conflicts with a frozen table
        assert_eq!(configure(None).unwrap(), a);
        // re-configuring to the same level is idempotent
        assert_eq!(configure(Some(a)).unwrap(), a);
    }
}
