//! f32 tensor substrate: storage, dense kernels, `.hgw` weight I/O.

pub mod ops;
pub mod simd;
pub mod tensor;
pub mod weights;

pub use tensor::Tensor;
pub use weights::Weights;
