//! Dense kernels for the CPU attention path and the rust reference model.
//! Numerics mirror python/compile/model.py exactly (same gelu constants,
//! same layernorm epsilon) so the PJRT path and the rust oracle agree to
//! f32 tolerance.

use super::tensor::Tensor;

/// C[m,n] = A[m,k] @ B[k,n]. ikj loop order for cache-friendly access.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut c.data[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += av * bv;
            }
        }
    }
    c
}

/// y[n] = x[k] @ W[k,n] + b[n]; the hot projection primitive.
pub fn affine(x: &[f32], w: &Tensor, b: &[f32], out: &mut [f32]) {
    let (k, n) = (w.shape[0], w.shape[1]);
    assert_eq!(x.len(), k);
    assert_eq!(out.len(), n);
    assert_eq!(b.len(), n);
    out.copy_from_slice(b);
    for (p, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let wrow = &w.data[p * n..(p + 1) * n];
        for (o, &wv) in out.iter_mut().zip(wrow.iter()) {
            *o += xv * wv;
        }
    }
}

/// Dot product of two f32 slices — the single hottest loop in CPU sparse
/// attention. Routed through the runtime-dispatched kernel layer
/// ([`super::simd`]); the portable baseline (the original 4-way-unrolled
/// scalar loop) lives in `tensor/simd/scalar.rs`.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    super::simd::dot(a, b)
}

/// out += scale * v (runtime-dispatched; see [`super::simd`]).
pub fn axpy(scale: f32, v: &[f32], out: &mut [f32]) {
    super::simd::axpy(scale, v, out)
}

/// In-place softmax over a slice; returns the log-sum-exp
/// (runtime-dispatched; see [`super::simd`]).
pub fn softmax_lse(x: &mut [f32]) -> f32 {
    super::simd::softmax_lse(x)
}

/// LayerNorm matching jax: (x - mean) / sqrt(var + 1e-5) * g + b.
pub fn layernorm(x: &[f32], g: &[f32], b: &[f32], out: &mut [f32]) {
    let n = x.len() as f32;
    let mean = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for i in 0..x.len() {
        out[i] = (x[i] - mean) * inv * g[i] + b[i];
    }
}

/// GELU (tanh approximation) — constants pinned to python/compile/model.py.
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (0.7978845608028654 * (x + 0.044715 * x * x * x)).tanh())
}

pub fn gelu_slice(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = gelu(*v);
    }
}

pub fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in x.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// log-softmax value of index `target` (for perplexity evaluation).
pub fn log_softmax_at(x: &[f32], target: usize) -> f32 {
    let m = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let lse = m + x.iter().map(|v| (v - m).exp()).sum::<f32>().ln();
    x[target] - lse
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let i = Tensor::from_vec(&[2, 2], vec![1., 0., 0., 1.]);
        assert_eq!(matmul(&a, &i).data, a.data);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::from_vec(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn affine_matches_matmul() {
        let w = Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let x = [1.0f32, 0.5, -1.0];
        let b = [0.1f32, -0.2];
        let mut out = [0.0f32; 2];
        affine(&x, &w, &b, &mut out);
        let expect = [
            1.0 * 1. + 0.5 * 3. + -1.0 * 5. + 0.1,
            1.0 * 2. + 0.5 * 4. + -1.0 * 6. - 0.2,
        ];
        assert!((out[0] - expect[0]).abs() < 1e-6);
        assert!((out[1] - expect[1]).abs() < 1e-6);
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        for n in [0, 1, 3, 4, 7, 16, 33] {
            let a: Vec<f32> = (0..n).map(|i| i as f32 * 0.3 - 1.0).collect();
            let b: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-4, "n={n}");
        }
    }

    #[test]
    fn softmax_sums_to_one_and_lse_correct() {
        let mut x = vec![1.0f32, 2.0, 3.0];
        let lse = softmax_lse(&mut x);
        let sum: f32 = x.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        let expect_lse = (1f64.exp() + 2f64.exp() + 3f64.exp()).ln() as f32;
        assert!((lse - expect_lse).abs() < 1e-5);
    }

    #[test]
    fn softmax_stable_at_large_scores() {
        let mut x = vec![1000.0f32, 999.0];
        let lse = softmax_lse(&mut x);
        assert!(x.iter().all(|v| v.is_finite()));
        assert!(lse.is_finite());
        assert!((x[0] + x[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let g = [1.0f32; 4];
        let b = [0.0f32; 4];
        let mut out = [0.0f32; 4];
        layernorm(&x, &g, &b, &mut out);
        let mean: f32 = out.iter().sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        let var: f32 = out.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!((var - 1.0).abs() < 1e-2);
    }

    #[test]
    fn gelu_reference_points() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-5);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-5);
        assert!(gelu(10.0) > 9.99);
    }

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[1.0, 5.0, 5.0, 2.0]), 1);
    }

    #[test]
    fn log_softmax_at_matches_softmax() {
        let x = [0.5f32, -1.0, 2.0];
        let mut sm = x.to_vec();
        softmax_lse(&mut sm);
        assert!((log_softmax_at(&x, 2) - sm[2].ln()).abs() < 1e-5);
    }
}
