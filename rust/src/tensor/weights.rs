//! `.hgw` weight loader — the rust half of python/compile/hgw.py.
//!
//! Layout (little-endian): magic "HGW1", u32 n_tensors, then per tensor
//! u16 name_len + name, u8 ndim, u32 dims…, f32 row-major data.

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::tensor::Tensor;

pub const MAGIC: &[u8; 4] = b"HGW1";

pub type Weights = BTreeMap<String, Tensor>;

pub fn load(path: &Path) -> Result<Weights> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    parse(&bytes).with_context(|| format!("parsing {}", path.display()))
}

pub fn parse(bytes: &[u8]) -> Result<Weights> {
    let mut r = Cursor { b: bytes, pos: 0 };
    let magic = r.take(4)?;
    if magic != MAGIC {
        bail!("bad magic {:?} (want HGW1)", &magic[..4.min(magic.len())]);
    }
    let n = r.u32()? as usize;
    let mut out = Weights::new();
    for _ in 0..n {
        let name_len = r.u16()? as usize;
        let name = String::from_utf8(r.take(name_len)?.to_vec()).context("tensor name utf8")?;
        let ndim = r.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.u32()? as usize);
        }
        let count: usize = shape.iter().product();
        let raw = r.take(count * 4)?;
        let mut data = vec![0f32; count];
        for (i, chunk) in raw.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        out.insert(name, Tensor::from_vec(&shape, data));
    }
    if r.pos != bytes.len() {
        bail!("{} trailing bytes after last tensor", bytes.len() - r.pos);
    }
    Ok(out)
}

/// Serialize (used by tests for round-trips and by tools that snapshot
/// synthetic weights).
pub fn save(weights: &Weights) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(weights.len() as u32).to_le_bytes());
    for (name, t) in weights {
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.push(t.ndim() as u8);
        for &d in &t.shape {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        for &v in &t.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

pub fn write(path: &Path, weights: &Weights) -> Result<()> {
    std::fs::write(path, save(weights)).with_context(|| format!("writing {}", path.display()))
}

struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("truncated file at byte {} (want {n} more)", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }
    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
}

#[allow(unused)]
fn read_all(mut r: impl Read) -> Result<Vec<u8>> {
    let mut buf = Vec::new();
    r.read_to_end(&mut buf)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Weights {
        let mut w = Weights::new();
        w.insert("a".into(), Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]));
        w.insert(
            "layer0.wq".into(),
            Tensor::from_vec(&[3], vec![-1.5, 0.0, 2.25]),
        );
        w
    }

    #[test]
    fn roundtrip() {
        let w = sample();
        let bytes = save(&w);
        let w2 = parse(&bytes).unwrap();
        assert_eq!(w, w2);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = save(&sample());
        bytes[0] = b'X';
        assert!(parse(&bytes).is_err());
    }

    #[test]
    fn truncation_rejected() {
        let bytes = save(&sample());
        assert!(parse(&bytes[..bytes.len() - 3]).is_err());
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut bytes = save(&sample());
        bytes.push(0);
        assert!(parse(&bytes).is_err());
    }

    #[test]
    fn empty_weights_ok() {
        let w = Weights::new();
        assert_eq!(parse(&save(&w)).unwrap().len(), 0);
    }
}
