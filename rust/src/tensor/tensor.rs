//! Row-major f32 tensor. Small by design: the rust side only needs dense
//! linear algebra for the CPU attention path and the reference model; the
//! heavy GPU-side math lives in the AOT-compiled XLA artifacts.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} incompatible with {} elements",
            shape,
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; n],
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Strides in elements for the current shape.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let o = self.offset(idx);
        self.data[o] = v;
    }

    fn offset(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.shape.len(), "index rank mismatch");
        let strides = self.strides();
        let mut o = 0;
        for (i, (&x, &d)) in idx.iter().zip(self.shape.iter()).enumerate() {
            assert!(x < d, "index {x} out of bounds for dim {i} (size {d})");
            o += x * strides[i];
        }
        o
    }

    /// Reshape (must preserve element count).
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {:?}",
            self.shape,
            shape
        );
        self.shape = shape.to_vec();
        self
    }

    /// Contiguous row slice for a leading index of a 2-D tensor.
    pub fn row(&self, r: usize) -> &[f32] {
        assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        &self.data[r * cols..(r + 1) * cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        &mut self.data[r * cols..(r + 1) * cols]
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[", self.shape)?;
        for (i, v) in self.data.iter().take(8).enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{v:.4}")?;
        }
        if self.data.len() > 8 {
            write!(f, ", …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.numel(), 24);
        assert_eq!(t.strides(), vec![12, 4, 1]);
        assert_eq!(t.size_bytes(), 96);
    }

    #[test]
    fn index_roundtrip() {
        let mut t = Tensor::zeros(&[2, 3]);
        t.set(&[1, 2], 5.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
        assert_eq!(t.data[5], 5.0);
    }

    #[test]
    fn rows() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    #[should_panic]
    fn oob_panics() {
        Tensor::zeros(&[2, 2]).at(&[2, 0]);
    }

    #[test]
    #[should_panic]
    fn bad_reshape_panics() {
        Tensor::zeros(&[4]).reshape(&[3]);
    }

    #[test]
    fn max_abs_diff() {
        let a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3], vec![1.0, 2.5, 3.0]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }
}
