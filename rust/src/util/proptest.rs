//! Mini property-testing harness (`proptest` is not in the vendored
//! registry). Seeded generators + bounded iteration + first-failure
//! reporting with the reproducing seed. Used across kv/sparse/attention
//! invariant tests.

use super::rng::Rng;

/// Run `cases` random trials of `prop`, which receives a fresh seeded Rng.
/// On failure, panics with the failing case's seed so it can be replayed.
pub fn check<F: Fn(&mut Rng) -> Result<(), String>>(name: &str, cases: usize, prop: F) {
    let base = std::env::var("HGCA_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (replay with HGCA_PROP_SEED={base}, \
                 case seed {seed}): {msg}"
            );
        }
    }
}

/// Assertion helpers returning Result — composable inside properties.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_close(a: f32, b: f32, tol: f32, ctx: &str) -> Result<(), String> {
    let diff = (a - b).abs();
    let denom = a.abs().max(b.abs()).max(1.0);
    if diff / denom <= tol || diff <= tol {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} != {b} (diff {diff}, tol {tol})"))
    }
}

pub fn ensure_all_close(a: &[f32], b: &[f32], tol: f32, ctx: &str) -> Result<(), String> {
    ensure(a.len() == b.len(), format!("{ctx}: length {} != {}", a.len(), b.len()))?;
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        ensure_close(*x, *y, tol, &format!("{ctx}[{i}]"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        check("trivial", 10, |_rng| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 5, |rng| {
            ensure(rng.f32() < 0.0, "always false")
        });
    }

    #[test]
    fn close_helpers() {
        assert!(ensure_close(1.0, 1.0 + 1e-7, 1e-5, "x").is_ok());
        assert!(ensure_close(1.0, 2.0, 1e-5, "x").is_err());
        assert!(ensure_all_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6, "v").is_ok());
        assert!(ensure_all_close(&[1.0], &[1.0, 2.0], 1e-6, "v").is_err());
    }
}
