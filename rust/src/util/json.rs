//! Minimal JSON parser + writer (serde is not in the vendored registry).
//!
//! Supports the full JSON grammar we produce/consume: objects, arrays,
//! strings (with escapes), f64 numbers, bools, null. Used for the artifact
//! manifest, model configs, the HTTP API and bench result dumps.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with the byte offset it occurred at. (Hand-rolled
/// `Display`/`Error` impls — `thiserror` is not in the vendored registry.)
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -------- typed accessors --------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers that produce readable errors.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field '{key}'"))
    }
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a string"))
    }
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_f64()
            .map(|n| n as usize)
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a number"))
    }
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a number"))
    }
    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not an array"))
    }

    // -------- builders --------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut cp = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            cp = cp * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte utf-8: copy raw bytes
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    if self.pos > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.pos]).map_err(|_| self.err("bad utf-8"))?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"arr":[1,2.5,false,null],"obj":{"k":"v \"q\""},"s":"line\nbreak"}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape_and_utf8() {
        let v = Json::parse("\"\\u0041µ\"").unwrap();
        assert_eq!(v.as_str(), Some("Aµ"));
    }

    #[test]
    fn errors_have_positions() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.pos > 0);
        assert!(Json::parse("[1,2").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn req_helpers() {
        let v = Json::parse(r#"{"n": 3, "s": "x"}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 3);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!(v.req_str("missing").is_err());
        assert!(v.req_usize("s").is_err());
    }
}
