//! Tiny CLI flag parser (clap is not in the vendored registry).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and positional
//! arguments; unknown flags are an error so typos fail fast.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// `bool_flags` lists flags that take no value.
    pub fn parse(argv: &[String], bool_flags: &[&str]) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&rest) {
                    out.bools.push(rest.to_string());
                } else {
                    i += 1;
                    let v = argv
                        .get(i)
                        .ok_or_else(|| anyhow::anyhow!("--{rest} needs a value"))?;
                    out.flags.insert(rest.to_string(), v.clone());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn from_env(bool_flags: &[&str]) -> anyhow::Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv, bool_flags)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: expected integer, got '{v}'")),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: expected number, got '{v}'")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key)
    }

    /// Comma-separated list helper: `--betas 0.25,0.5`.
    pub fn f64_list(&self, key: &str, default: &[f64]) -> anyhow::Result<Vec<f64>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--{key}: bad number '{s}'"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_bools() {
        let a = Args::parse(&sv(&["--n", "3", "--fast", "--x=7", "pos"]), &["fast"]).unwrap();
        assert_eq!(a.usize("n", 0).unwrap(), 3);
        assert!(a.flag("fast"));
        assert_eq!(a.get("x"), Some("7"));
        assert_eq!(a.positional, vec!["pos"]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&[]), &[]).unwrap();
        assert_eq!(a.usize("n", 9).unwrap(), 9);
        assert_eq!(a.f64("b", 1.5).unwrap(), 1.5);
        assert!(!a.flag("fast"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["--n"]), &[]).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(&sv(&["--n", "xyz"]), &[]).unwrap();
        assert!(a.usize("n", 0).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(&sv(&["--betas", "0.25, 0.5,1.0"]), &[]).unwrap();
        assert_eq!(a.f64_list("betas", &[]).unwrap(), vec![0.25, 0.5, 1.0]);
        assert_eq!(a.f64_list("other", &[2.0]).unwrap(), vec![2.0]);
    }
}
