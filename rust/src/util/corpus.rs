//! Deterministic synthetic corpus generator — rust port of
//! python/compile/corpus.py (WikiText stand-in; no network in the build
//! environment). Same LCG, same tables, same control flow, so the bytes
//! match the python export exactly and either side can (re)generate
//! `data/corpus.txt` for the evaluation paths.

use std::path::Path;

use anyhow::{Context, Result};

/// Tiny deterministic PRNG (mirrors corpus.py::_Lcg).
struct Lcg {
    state: u64,
}

impl Lcg {
    fn new(seed: u64) -> Lcg {
        Lcg { state: seed }
    }

    fn next(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.state >> 33
    }

    fn choice<'a>(&mut self, seq: &[&'a str]) -> &'a str {
        seq[(self.next() as usize) % seq.len()]
    }

    fn randint(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }
}

const ENTITIES: [&str; 18] = [
    "Arlington",
    "the Brazos River",
    "Fort Concho",
    "Palo Duro Canyon",
    "Governor Coke",
    "the Texas and Pacific Railway",
    "Colonel Mackenzie",
    "the Red River",
    "Judge Roy Bean",
    "the Chisholm Trail",
    "Galveston",
    "the Comanche nation",
    "Captain Goodnight",
    "the Llano Estacado",
    "the Rio Grande",
    "General Sheridan",
    "the Pecos valley",
    "Austin",
];

const SUBJECTS: [&str; 10] = [
    "The settlement",
    "The expedition",
    "The railway company",
    "The garrison",
    "A survey party",
    "The territorial legislature",
    "The cattle drive",
    "The river crossing",
    "The trading post",
    "The county court",
];

const VERBS: [&str; 10] = [
    "was established near",
    "expanded along",
    "negotiated with",
    "was abandoned after the flood at",
    "mapped the region around",
    "granted land adjacent to",
    "defended the route through",
    "recorded the first census of",
    "shipped grain from",
    "surveyed",
];

const CLAUSES: [&str; 10] = [
    "during the spring of that year",
    "despite repeated delays",
    "under the terms of the treaty",
    "before the winter storms arrived",
    "with support from the federal government",
    "after the drought ended",
    "at considerable expense",
    "according to contemporary accounts",
    "as noted in the annual report",
    "following the election",
];

const CONNECTORS: [&str; 8] = [
    "Meanwhile,",
    "In the following decade,",
    "By contrast,",
    "Soon after,",
    "Historical records show that",
    "According to later historians,",
    "In the same period,",
    "Two years later,",
];

/// Default corpus length — matches corpus.py::generate.
pub const DEFAULT_BYTES: usize = 262_144;

/// Default seed — ASCII "HGCA", matching the python generator.
pub const DEFAULT_SEED: u64 = 0x48474341;

/// python str.title(): uppercase each word's first letter, lowercase the
/// rest (the entity strings are alphabetic words + spaces only).
fn title_case(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut start_of_word = true;
    for c in s.chars() {
        if c.is_ascii_alphabetic() {
            if start_of_word {
                out.push(c.to_ascii_uppercase());
            } else {
                out.push(c.to_ascii_lowercase());
            }
            start_of_word = false;
        } else {
            out.push(c);
            start_of_word = true;
        }
    }
    out
}

/// Generate `n_bytes` of the deterministic corpus (mirrors
/// corpus.py::generate — same RNG consumption order).
pub fn generate(n_bytes: usize, seed: u64) -> Vec<u8> {
    let mut rng = Lcg::new(seed);
    let mut out = String::new();
    let mut para_len: usize = 0;
    let mut focal: Vec<&str> = (0..3).map(|_| rng.choice(&ENTITIES)).collect();
    while out.len() < n_bytes {
        if para_len as u64 > rng.randint(400, 900) {
            out.push_str("\n\n");
            para_len = 0;
            if rng.randint(0, 3) == 0 {
                focal = (0..3).map(|_| rng.choice(&ENTITIES)).collect();
                let hdr = format!("= {} =\n\n", title_case(rng.choice(&ENTITIES)));
                out.push_str(&hdr);
            }
        }
        let ent = if rng.randint(0, 9) < 7 {
            focal[(rng.next() as usize) % 3]
        } else {
            rng.choice(&ENTITIES)
        };
        let mut parts: Vec<String> = Vec::with_capacity(5);
        if rng.randint(0, 2) == 0 {
            parts.push(rng.choice(&CONNECTORS).to_string());
        }
        let subj = rng.choice(&SUBJECTS);
        parts.push(if parts.is_empty() {
            subj.to_string()
        } else {
            subj.to_ascii_lowercase()
        });
        parts.push(rng.choice(&VERBS).to_string());
        parts.push(ent.to_string());
        if rng.randint(0, 1) == 0 {
            parts.push(rng.choice(&CLAUSES).to_string());
        }
        if rng.randint(0, 4) == 0 {
            parts.push(format!("in 18{}", rng.randint(40, 99)));
        }
        let sent = format!("{}. ", parts.join(" "));
        para_len += sent.len();
        out.push_str(&sent);
    }
    out.truncate(n_bytes);
    out.into_bytes()
}

/// Read `path`, generating it first when missing (the rust-side equivalent
/// of `make data/corpus.txt`). Returns the corpus bytes.
///
/// Concurrent callers are safe: the file is written to a temp name and
/// renamed into place (atomic within the directory), and every generator
/// produces identical bytes, so readers only ever observe a complete
/// corpus.
pub fn ensure_corpus(path: &Path) -> Result<Vec<u8>> {
    if path.is_file() {
        return std::fs::read(path).with_context(|| format!("reading {}", path.display()));
    }
    let text = generate(DEFAULT_BYTES, DEFAULT_SEED);
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("creating {}", parent.display()))?;
    }
    static UNIQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let tmp = path.with_extension(format!(
        "tmp.{}.{}",
        std::process::id(),
        UNIQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    std::fs::write(&tmp, &text).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming into {}", path.display()))?;
    Ok(text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_ascii() {
        let a = generate(4096, DEFAULT_SEED);
        let b = generate(4096, DEFAULT_SEED);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4096);
        assert!(a.iter().all(|&c| c.is_ascii()));
    }

    #[test]
    fn prefix_stability() {
        // a longer generation starts with the shorter one (pure streaming)
        let short = generate(1000, DEFAULT_SEED);
        let long = generate(2000, DEFAULT_SEED);
        assert_eq!(&long[..1000], &short[..]);
    }

    #[test]
    fn entities_recur() {
        let text = String::from_utf8(generate(32_768, DEFAULT_SEED)).unwrap();
        // focal-entity reuse → at least one entity appears many times
        let max_count = ENTITIES
            .iter()
            .map(|e| text.matches(e).count())
            .max()
            .unwrap();
        assert!(max_count >= 10, "max entity recurrence {max_count}");
    }

    #[test]
    fn different_seed_differs() {
        assert_ne!(generate(512, 1), generate(512, 2));
    }

    #[test]
    fn title_case_matches_python() {
        assert_eq!(title_case("the Brazos River"), "The Brazos River");
        assert_eq!(title_case("Austin"), "Austin");
    }

    #[test]
    fn ensure_corpus_roundtrip() {
        let dir = std::env::temp_dir().join("hgca_corpus_test");
        let path = dir.join("corpus.txt");
        let _ = std::fs::remove_file(&path);
        let a = ensure_corpus(&path).unwrap();
        assert_eq!(a.len(), DEFAULT_BYTES);
        let b = ensure_corpus(&path).unwrap(); // second call reads the file
        assert_eq!(a, b);
    }
}
