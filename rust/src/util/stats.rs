//! Descriptive statistics + latency recorder used by metrics and benches.

/// Summary of a sample set (times in whatever unit the caller uses).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

pub fn summarize(samples: &[f64]) -> Summary {
    assert!(!samples.is_empty(), "summarize: empty sample set");
    let n = samples.len();
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = sorted.iter().sum::<f64>() / n as f64;
    let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        p50: percentile_sorted(&sorted, 0.50),
        p90: percentile_sorted(&sorted, 0.90),
        p99: percentile_sorted(&sorted, 0.99),
        max: sorted[n - 1],
    }
}

/// Linear-interpolated percentile over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Streaming mean/max accumulator (no sample retention).
#[derive(Debug, Clone, Default)]
pub struct Acc {
    pub n: u64,
    pub sum: f64,
    pub max: f64,
    pub min: f64,
}

impl Acc {
    pub fn new() -> Self {
        Acc {
            n: 0,
            sum: 0.0,
            max: f64::NEG_INFINITY,
            min: f64::INFINITY,
        }
    }
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.max = self.max.max(x);
        self.min = self.min.min(x);
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

/// Geometric mean of positive values (used for speedup aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile_sorted(&v, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 1.0), 10.0);
    }

    #[test]
    fn percentile_single() {
        assert_eq!(percentile_sorted(&[7.0], 0.99), 7.0);
    }

    #[test]
    fn acc_tracks_extremes() {
        let mut a = Acc::new();
        for x in [3.0, -1.0, 10.0] {
            a.add(x);
        }
        assert_eq!(a.n, 3);
        assert_eq!(a.max, 10.0);
        assert_eq!(a.min, -1.0);
        assert!((a.mean() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_equal_is_identity() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn empty_summary_panics() {
        summarize(&[]);
    }
}
