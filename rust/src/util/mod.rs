//! Shared substrates: JSON, PRNG, statistics, CLI parsing, property testing.

pub mod argparse;
pub mod corpus;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;

/// Human-friendly byte formatting for memory reports.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Human-friendly duration formatting for bench tables (input: seconds).
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500 µs");
        assert_eq!(fmt_secs(5e-9), "5.0 ns");
    }
}
