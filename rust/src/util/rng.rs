//! Deterministic PRNG (xoshiro256**). The registry has no `rand`; every
//! stochastic component (workload generators, property tests, samplers)
//! takes an explicit seed so runs reproduce bit-for-bit.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + (self.next_u64() as usize) % (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    pub fn fill_normal(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * scale;
        }
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range(0, items.len())]
    }

    /// Exponentially distributed (for Poisson arrivals), mean = 1/rate.
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-12).ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.range(3, 17);
            assert!((3..17).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n {
            let v = r.normal() as f64;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
