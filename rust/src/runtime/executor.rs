//! Typed entry points over the compiled artifacts — the exact call
//! sequence of Algorithm 2's GPU half, one call per (layer, step).

use anyhow::Result;

use super::pjrt::{Arg, ModelRuntime};

/// Outputs of one attn_step call (shapes: B=batch, H=heads, N=queries,
/// dh=d_head, S=window+N).
#[derive(Debug, Clone)]
pub struct AttnOut {
    pub q: Vec<f32>,     // [B,H,N,dh] (pre-scaled)
    pub k_new: Vec<f32>, // [B,H,N,dh]
    pub v_new: Vec<f32>,
    pub o_gpu: Vec<f32>, // [B,H,N,dh]
    pub lse: Vec<f32>,   // [B,H,N]
    pub a_sum: Vec<f32>, // [B,H,S]
}

pub struct Executor<'m> {
    pub mr: &'m ModelRuntime,
}

impl<'m> Executor<'m> {
    pub fn new(mr: &'m ModelRuntime) -> Self {
        Executor { mr }
    }

    /// tokens/positions: [B,N] i32 → hidden [B,N,D].
    pub fn embed(&self, batch: usize, n: usize, tokens: &[i32], positions: &[i32]) -> Result<Vec<f32>> {
        let meta = self.mr.find_artifact("embed", batch, None, n)?.clone();
        let out = self.mr.call(
            &meta,
            &[
                Arg::I32(tokens, vec![batch, n]),
                Arg::I32(positions, vec![batch, n]),
                Arg::Weight("tok_emb"),
                Arg::Weight("pos_emb"),
            ],
        )?;
        Ok(out.into_iter().next().unwrap())
    }

    /// GPU half of one hybrid attention layer.
    #[allow(clippy::too_many_arguments)]
    pub fn attn_step(
        &self,
        layer: usize,
        batch: usize,
        window: usize,
        n: usize,
        hidden: &[f32],
        k_win: &[f32],
        v_win: &[f32],
        win_len: &[i32],
        n_valid: &[i32],
    ) -> Result<AttnOut> {
        let cfg = &self.mr.cfg;
        let (h, dh, d) = (cfg.n_heads, cfg.d_head(), cfg.d_model);
        let meta = self.mr.find_artifact("attn_step", batch, Some(window), n)?.clone();
        let l = |f: &str| format!("layer{layer}.{f}");
        let out = self.mr.call(
            &meta,
            &[
                Arg::F32(hidden, vec![batch, n, d]),
                Arg::Weight(&l("ln1_g")),
                Arg::Weight(&l("ln1_b")),
                Arg::Weight(&l("wq")),
                Arg::Weight(&l("bq")),
                Arg::Weight(&l("wk")),
                Arg::Weight(&l("bk")),
                Arg::Weight(&l("wv")),
                Arg::Weight(&l("bv")),
                Arg::F32(k_win, vec![batch, h, window, dh]),
                Arg::F32(v_win, vec![batch, h, window, dh]),
                Arg::I32(win_len, vec![batch]),
                Arg::I32(n_valid, vec![batch]),
            ],
        )?;
        let mut it = out.into_iter();
        Ok(AttnOut {
            q: it.next().unwrap(),
            k_new: it.next().unwrap(),
            v_new: it.next().unwrap(),
            o_gpu: it.next().unwrap(),
            lse: it.next().unwrap(),
            a_sum: it.next().unwrap(),
        })
    }

    /// Output projection + residual + FFN after the merge.
    pub fn post_attn(
        &self,
        layer: usize,
        batch: usize,
        n: usize,
        hidden: &[f32],
        o_merged: &[f32],
    ) -> Result<Vec<f32>> {
        let d = self.mr.cfg.d_model;
        let meta = self.mr.find_artifact("post_attn", batch, None, n)?.clone();
        let l = |f: &str| format!("layer{layer}.{f}");
        let out = self.mr.call(
            &meta,
            &[
                Arg::F32(hidden, vec![batch, n, d]),
                Arg::F32(o_merged, vec![batch, n, d]),
                Arg::Weight(&l("wo")),
                Arg::Weight(&l("bo")),
                Arg::Weight(&l("ln2_g")),
                Arg::Weight(&l("ln2_b")),
                Arg::Weight(&l("w1")),
                Arg::Weight(&l("b1")),
                Arg::Weight(&l("w2")),
                Arg::Weight(&l("b2")),
            ],
        )?;
        Ok(out.into_iter().next().unwrap())
    }

    /// hidden [B,1,D] → logits [B,1,V].
    pub fn lm_head(&self, batch: usize, hidden: &[f32]) -> Result<Vec<f32>> {
        let d = self.mr.cfg.d_model;
        let meta = self.mr.find_artifact("lm_head", batch, None, 1)?.clone();
        let out = self.mr.call(
            &meta,
            &[
                Arg::F32(hidden, vec![batch, 1, d]),
                Arg::Weight("lnf_g"),
                Arg::Weight("lnf_b"),
                Arg::Weight("tok_emb"),
            ],
        )?;
        Ok(out.into_iter().next().unwrap())
    }
}
