//! PJRT runtime: manifest-driven artifact loading + typed execution.
//! The compiled XLA executables are the system's "GPU device"
//! (DESIGN.md §1 hardware substitution).

pub mod artifacts;
pub mod executor;
pub mod pjrt;

pub use artifacts::{ArtifactMeta, Manifest};
pub use executor::{AttnOut, Executor};
pub use pjrt::{Arg, ModelRuntime, PjrtRuntime};
