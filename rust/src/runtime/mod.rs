//! Runtime: manifest-driven artifact loading + typed execution.
//! The artifact executor is the system's "GPU device" (DESIGN.md §1
//! hardware substitution) — compiled XLA when an export exists, the
//! in-process native backend ([`native`]) otherwise.

pub mod artifacts;
pub mod executor;
pub mod native;
pub mod pjrt;

pub use artifacts::{ArtifactMeta, Manifest};
pub use executor::{AttnOut, Executor};
pub use pjrt::{Arg, ModelRuntime, PjrtRuntime, RuntimeStats};
