//! PJRT execution: load HLO-text artifacts, compile once on the CPU PJRT
//! client (our stand-in "GPU" device, DESIGN.md §1), keep model weights
//! resident as device buffers, and execute typed entry points.
//!
//! Adapted from /opt/xla-example/load_hlo: HLO *text* is the interchange
//! format (xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::ModelConfig;
use crate::tensor::Weights;

use super::artifacts::{ArtifactMeta, Manifest};

/// Shared PJRT client + manifest.
pub struct PjrtRuntime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
}

impl PjrtRuntime {
    pub fn new(artifact_dir: &Path) -> Result<PjrtRuntime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt client: {e:?}"))?;
        Ok(PjrtRuntime { client, manifest })
    }

    /// Load a trained model: host weights (for the CPU attention path and
    /// the oracle) + device-resident weight buffers + compiled executables
    /// for every artifact of this model.
    pub fn load_model(self: &Rc<Self>, name: &str) -> Result<ModelRuntime> {
        let cfg = self
            .manifest
            .models
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("model '{name}' not in manifest"))?;
        let weights = crate::tensor::weights::load(&self.manifest.dir.join(format!("{name}.hgw")))?;
        ModelRuntime::new(Rc::clone(self), cfg, weights)
    }
}

/// Cumulative PJRT-path timing (perf diagnostics, EXPERIMENTS.md §Perf).
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub calls: u64,
    pub exec_secs: f64,
    pub upload_secs: f64,
    pub download_secs: f64,
    pub compile_secs: f64,
}

pub struct ModelRuntime {
    pub rt: Rc<PjrtRuntime>,
    pub cfg: ModelConfig,
    pub weights: Weights,
    /// device-resident weight buffers, uploaded once (execute_b path)
    wbufs: BTreeMap<String, xla::PjRtBuffer>,
    /// compiled executables keyed by artifact name
    exes: RefCell<BTreeMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    pub stats: RefCell<RuntimeStats>,
}

/// An argument to an artifact call.
pub enum Arg<'a> {
    F32(&'a [f32], Vec<usize>),
    I32(&'a [i32], Vec<usize>),
    /// named model weight (device-resident)
    Weight(&'a str),
}

impl ModelRuntime {
    fn new(rt: Rc<PjrtRuntime>, cfg: ModelConfig, weights: Weights) -> Result<ModelRuntime> {
        let mut wbufs = BTreeMap::new();
        for (name, t) in &weights {
            let buf = rt
                .client
                .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
                .map_err(|e| anyhow!("uploading weight {name}: {e:?}"))?;
            wbufs.insert(name.clone(), buf);
        }
        Ok(ModelRuntime {
            rt,
            cfg,
            weights,
            wbufs,
            exes: RefCell::new(BTreeMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    /// Construct from in-memory weights (tests with random weights).
    pub fn from_weights(rt: Rc<PjrtRuntime>, cfg: ModelConfig, weights: Weights) -> Result<ModelRuntime> {
        Self::new(rt, cfg, weights)
    }

    pub fn find_artifact(
        &self,
        kind: &str,
        batch: usize,
        window: Option<usize>,
        n: usize,
    ) -> Result<&ArtifactMeta> {
        self.rt
            .manifest
            .artifacts
            .iter()
            .find(|a| {
                a.model == self.cfg.name
                    && a.kind == kind
                    && a.batch == batch
                    && window.is_none_or(|w| a.window == w)
                    && a.inputs
                        .first()
                        .map(|i| i.shape.get(1).copied().unwrap_or(1) == n)
                        .unwrap_or(false)
            })
            .ok_or_else(|| {
                anyhow!(
                    "no artifact: model={} kind={kind} batch={batch} window={window:?} n={n}",
                    self.cfg.name
                )
            })
    }

    fn executable(&self, meta: &ArtifactMeta) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(&meta.name) {
            return Ok(Rc::clone(e));
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&meta.file)
            .map_err(|e| anyhow!("parsing {}: {e:?}", meta.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .rt
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", meta.name))?;
        self.stats.borrow_mut().compile_secs += t0.elapsed().as_secs_f64();
        let exe = Rc::new(exe);
        self.exes
            .borrow_mut()
            .insert(meta.name.clone(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Eagerly compile every artifact of this model (avoids first-call
    /// latency spikes on the serving path).
    pub fn warmup(&self) -> Result<usize> {
        let metas: Vec<ArtifactMeta> = self
            .rt
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.model == self.cfg.name)
            .cloned()
            .collect();
        for m in &metas {
            self.executable(m)?;
        }
        Ok(metas.len())
    }

    /// Execute an artifact. Inputs must match the manifest order; weights
    /// come from the resident buffers, dynamic tensors are uploaded here.
    /// Returns the tuple elements as f32 vectors.
    pub fn call(&self, meta: &ArtifactMeta, args: &[Arg<'_>]) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            args.len() == meta.inputs.len(),
            "{}: {} args for {} declared inputs",
            meta.name,
            args.len(),
            meta.inputs.len()
        );
        let exe = self.executable(meta)?;
        let client = &self.rt.client;

        let t_up = Instant::now();
        // uploaded dynamic buffers live here; arg_refs borrows both these
        // and the resident weight buffers
        let mut uploaded: Vec<xla::PjRtBuffer> = Vec::new();
        let mut arg_refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len());
        // two passes: upload first (so the vec doesn't reallocate while borrowed)
        for a in args {
            match a {
                Arg::F32(data, dims) => {
                    let b = client
                        .buffer_from_host_buffer::<f32>(data, dims, None)
                        .map_err(|e| anyhow!("upload f32: {e:?}"))?;
                    uploaded.push(b);
                }
                Arg::I32(data, dims) => {
                    let b = client
                        .buffer_from_host_buffer::<i32>(data, dims, None)
                        .map_err(|e| anyhow!("upload i32: {e:?}"))?;
                    uploaded.push(b);
                }
                Arg::Weight(_) => {}
            }
        }
        let mut up_iter = uploaded.iter();
        for a in args {
            match a {
                Arg::F32(..) | Arg::I32(..) => arg_refs.push(up_iter.next().unwrap()),
                Arg::Weight(name) => arg_refs.push(
                    self.wbufs
                        .get(*name)
                        .ok_or_else(|| anyhow!("no weight buffer '{name}'"))?,
                ),
            }
        }
        let upload = t_up.elapsed().as_secs_f64();

        let t_ex = Instant::now();
        let out = exe
            .execute_b(&arg_refs)
            .map_err(|e| anyhow!("execute {}: {e:?}", meta.name))?;
        let exec = t_ex.elapsed().as_secs_f64();

        let t_dl = Instant::now();
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("download: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
        let mut res = Vec::with_capacity(parts.len());
        for p in parts {
            res.push(p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?);
        }
        let download = t_dl.elapsed().as_secs_f64();

        let mut st = self.stats.borrow_mut();
        st.calls += 1;
        st.exec_secs += exec;
        st.upload_secs += upload;
        st.download_secs += download;

        anyhow::ensure!(
            res.len() == meta.outputs.len(),
            "{}: got {} outputs, manifest declares {}",
            meta.name,
            res.len(),
            meta.outputs.len()
        );
        Ok(res)
    }
}
