//! Runtime loading + typed execution over the artifact manifest.
//!
//! Historically this file drove compiled HLO-text artifacts through the PJRT
//! C API (the `xla` bindings crate). That crate is not in the offline
//! registry, so execution now goes through the native in-process executor
//! ([`super::native`]) which implements the identical artifact contract —
//! same manifest, same input order, same output tuple, same numerics as the
//! python-lowered graphs. The public types (`PjrtRuntime`, `ModelRuntime`,
//! [`Arg`], [`RuntimeStats`]) are unchanged, so every caller of the old PJRT
//! path compiles and behaves the same.
//!
//! Model resolution order:
//! 1. `artifact_dir/manifest.json` + `<name>.hgw` (a real `make artifacts`
//!    export: trained weights, authoritative shapes);
//! 2. otherwise a [`Manifest::synthetic`] shape grid with deterministic
//!    random weights — full functional stack, no trained quality claims
//!    (`ModelRuntime::trained` is false).

use std::cell::RefCell;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::ModelConfig;
use crate::tensor::Weights;

use super::artifacts::{ArtifactMeta, Manifest};
use super::native::{self, Val};

/// Stand-in for the PJRT client handle (kept so `rt.client.platform_name()`
/// callers remain source-compatible).
pub struct NativeClient;

impl NativeClient {
    pub fn platform_name(&self) -> &'static str {
        "native-cpu"
    }
}

/// Shared runtime: artifact manifest + execution backend.
pub struct PjrtRuntime {
    pub client: NativeClient,
    pub manifest: Manifest,
}

impl PjrtRuntime {
    /// Load the manifest from `artifact_dir`, falling back to the built-in
    /// synthetic shape grid when no export exists there.
    pub fn new(artifact_dir: &Path) -> Result<PjrtRuntime> {
        let manifest = if artifact_dir.join("manifest.json").is_file() {
            Manifest::load(artifact_dir)?
        } else {
            Manifest::synthetic(artifact_dir)
        };
        Ok(PjrtRuntime {
            client: NativeClient,
            manifest,
        })
    }

    /// Load a model: exported `.hgw` weights when present, deterministic
    /// synthetic weights otherwise (seeded by the model name, so every
    /// process sees identical parameters).
    pub fn load_model(self: &Rc<Self>, name: &str) -> Result<ModelRuntime> {
        let cfg = self
            .manifest
            .models
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("model '{name}' not in manifest"))?;
        let path = self.manifest.dir.join(format!("{name}.hgw"));
        let (weights, trained) = if path.is_file() {
            (crate::tensor::weights::load(&path)?, true)
        } else {
            (
                crate::model::random_weights(&cfg, name_seed(name)),
                false,
            )
        };
        ModelRuntime::new(Rc::clone(self), cfg, weights, trained)
    }
}

/// Stable 64-bit seed from a model name (FNV-1a).
fn name_seed(name: &str) -> u64 {
    name.bytes()
        .fold(0xcbf29ce484222325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100000001b3)
        })
}

/// Cumulative execution-path timing (perf diagnostics, EXPERIMENTS.md §Perf).
/// upload/download/compile are zero on the native backend and kept for
/// source compatibility with the PJRT path's consumers.
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub calls: u64,
    pub exec_secs: f64,
    pub upload_secs: f64,
    pub download_secs: f64,
    pub compile_secs: f64,
}

pub struct ModelRuntime {
    pub rt: Rc<PjrtRuntime>,
    pub cfg: ModelConfig,
    pub weights: Weights,
    /// true iff weights came from a `make artifacts` export (quality
    /// assertions — trained-model perplexity etc. — must gate on this).
    pub trained: bool,
    pub stats: RefCell<RuntimeStats>,
}

/// An argument to an artifact call.
pub enum Arg<'a> {
    F32(&'a [f32], Vec<usize>),
    I32(&'a [i32], Vec<usize>),
    /// named model weight (resident)
    Weight(&'a str),
}

impl ModelRuntime {
    fn new(
        rt: Rc<PjrtRuntime>,
        cfg: ModelConfig,
        weights: Weights,
        trained: bool,
    ) -> Result<ModelRuntime> {
        Ok(ModelRuntime {
            rt,
            cfg,
            weights,
            trained,
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    /// Construct from in-memory weights (tests with random weights).
    pub fn from_weights(
        rt: Rc<PjrtRuntime>,
        cfg: ModelConfig,
        weights: Weights,
    ) -> Result<ModelRuntime> {
        Self::new(rt, cfg, weights, false)
    }

    /// Print a stderr banner when this model runs on synthetic weights, so
    /// bench/example output is never mistaken for trained-model numbers.
    pub fn warn_if_synthetic(&self) {
        if !self.trained {
            eprintln!(
                "[hgca] model '{}' is using SYNTHETIC random weights ({}.hgw not found in {}); \
                 quality numbers below are not paper results — run `make artifacts` to train",
                self.cfg.name,
                self.cfg.name,
                self.rt.manifest.dir.display()
            );
        }
    }

    pub fn find_artifact(
        &self,
        kind: &str,
        batch: usize,
        window: Option<usize>,
        n: usize,
    ) -> Result<&ArtifactMeta> {
        self.rt
            .manifest
            .artifacts
            .iter()
            .find(|a| {
                a.model == self.cfg.name
                    && a.kind == kind
                    && a.batch == batch
                    && window.is_none_or(|w| a.window == w)
                    && a.inputs
                        .first()
                        .map(|i| i.shape.get(1).copied().unwrap_or(1) == n)
                        .unwrap_or(false)
            })
            .ok_or_else(|| {
                anyhow!(
                    "no artifact: model={} kind={kind} batch={batch} window={window:?} n={n}",
                    self.cfg.name
                )
            })
    }

    /// Validate every artifact of this model resolves (no compile step on
    /// the native backend; kept for serving-path symmetry).
    pub fn warmup(&self) -> Result<usize> {
        let count = self
            .rt
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.model == self.cfg.name)
            .count();
        anyhow::ensure!(count > 0, "no artifacts for model {}", self.cfg.name);
        Ok(count)
    }

    /// Execute an artifact. Inputs must match the manifest order; weights
    /// come from the resident map, dynamic tensors are validated against
    /// the declared shapes. Returns the tuple elements as f32 vectors.
    pub fn call(&self, meta: &ArtifactMeta, args: &[Arg<'_>]) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            args.len() == meta.inputs.len(),
            "{}: {} args for {} declared inputs",
            meta.name,
            args.len(),
            meta.inputs.len()
        );
        let mut vals: Vec<Val<'_>> = Vec::with_capacity(args.len());
        for (i, a) in args.iter().enumerate() {
            let spec = &meta.inputs[i];
            match a {
                Arg::F32(data, dims) => {
                    check_shape(&meta.name, &spec.name, dims, &spec.shape, data.len())?;
                    vals.push(Val::F32(*data));
                }
                Arg::I32(data, dims) => {
                    check_shape(&meta.name, &spec.name, dims, &spec.shape, data.len())?;
                    vals.push(Val::I32(*data));
                }
                Arg::Weight(name) => {
                    let t = self
                        .weights
                        .get(*name)
                        .ok_or_else(|| anyhow!("no weight '{name}'"))?;
                    check_shape(&meta.name, &spec.name, &t.shape, &spec.shape, t.data.len())?;
                    vals.push(Val::F32(&t.data));
                }
            }
        }

        let t0 = Instant::now();
        let res = native::execute(&self.cfg, meta, &vals)?;
        let exec = t0.elapsed().as_secs_f64();

        let mut st = self.stats.borrow_mut();
        st.calls += 1;
        st.exec_secs += exec;

        anyhow::ensure!(
            res.len() == meta.outputs.len(),
            "{}: got {} outputs, manifest declares {}",
            meta.name,
            res.len(),
            meta.outputs.len()
        );
        for (o, spec) in res.iter().zip(meta.outputs.iter()) {
            let want: usize = spec.shape.iter().product();
            anyhow::ensure!(
                o.len() == want,
                "{}: output '{}' has {} elements, shape {:?} wants {want}",
                meta.name,
                spec.name,
                o.len(),
                spec.shape
            );
        }
        Ok(res)
    }
}

fn check_shape(
    artifact: &str,
    input: &str,
    got: &[usize],
    want: &[usize],
    len: usize,
) -> Result<()> {
    anyhow::ensure!(
        got == want,
        "{artifact}: input '{input}' shape {got:?}, manifest declares {want:?}"
    );
    let product: usize = want.iter().product();
    anyhow::ensure!(
        len == product,
        "{artifact}: input '{input}' has {len} elements for shape {want:?}"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt() -> Rc<PjrtRuntime> {
        // point at a directory with no manifest → synthetic fallback
        Rc::new(PjrtRuntime::new(Path::new("/nonexistent/hgca-artifacts")).unwrap())
    }

    #[test]
    fn synthetic_fallback_loads_models() {
        let rt = rt();
        assert!(rt.manifest.synthetic);
        let mr = rt.load_model("tiny-small").unwrap();
        assert!(!mr.trained);
        assert_eq!(mr.cfg.n_layers, 2);
        assert!(mr.warmup().unwrap() > 0);
        assert!(rt.load_model("nope").is_err());
    }

    #[test]
    fn name_seed_is_stable_and_distinct() {
        assert_eq!(name_seed("tiny"), name_seed("tiny"));
        assert_ne!(name_seed("tiny"), name_seed("tiny-small"));
    }

    #[test]
    fn call_validates_shapes_and_records_stats() {
        let rt = rt();
        let mr = rt.load_model("tiny-small").unwrap();
        let meta = mr.find_artifact("embed", 1, None, 1).unwrap().clone();
        let tokens = [5i32];
        let positions = [0i32];
        // wrong dims rejected
        let bad = mr.call(
            &meta,
            &[
                Arg::I32(&tokens, vec![1, 2]),
                Arg::I32(&positions, vec![1, 1]),
                Arg::Weight("tok_emb"),
                Arg::Weight("pos_emb"),
            ],
        );
        assert!(bad.is_err());
        // correct dims execute and count a call
        let out = mr
            .call(
                &meta,
                &[
                    Arg::I32(&tokens, vec![1, 1]),
                    Arg::I32(&positions, vec![1, 1]),
                    Arg::Weight("tok_emb"),
                    Arg::Weight("pos_emb"),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), mr.cfg.d_model);
        assert_eq!(mr.stats.borrow().calls, 1);
    }

    #[test]
    fn deterministic_synthetic_weights_across_runtimes() {
        let a = rt().load_model("tiny-small").unwrap();
        let b = rt().load_model("tiny-small").unwrap();
        assert_eq!(a.weights["tok_emb"].data, b.weights["tok_emb"].data);
    }
}
