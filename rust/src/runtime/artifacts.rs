//! Artifact manifest — the contract between python/compile/aot.py and the
//! rust runtime. Parses artifacts/manifest.json and answers "which compiled
//! executable serves (model, kind, batch, window)?".

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::config::ModelConfig;
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub model: String,
    pub kind: String,
    pub name: String,
    pub file: PathBuf,
    pub batch: usize,
    pub window: usize,
    pub chunk: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelConfig>,
    pub artifacts: Vec<ArtifactMeta>,
    /// true when this manifest was generated in-process (no `make artifacts`
    /// export on disk) — weights are then synthetic and tests must not
    /// assert trained-model quality.
    pub synthetic: bool,
}

fn parse_io(j: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: j.req_str("name")?.to_string(),
        shape: j
            .req_arr("shape")?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad shape dim")))
            .collect::<Result<_>>()?,
        dtype: j
            .get("dtype")
            .and_then(|d| d.as_str())
            .unwrap_or("float32")
            .to_string(),
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;

        let mut models = BTreeMap::new();
        if let Some(obj) = j.req("models")?.as_obj() {
            for (name, mj) in obj {
                models.insert(name.clone(), ModelConfig::from_json(mj)?);
            }
        }
        let mut artifacts = Vec::new();
        for a in j.req_arr("artifacts")? {
            artifacts.push(ArtifactMeta {
                model: a.req_str("model")?.to_string(),
                kind: a.req_str("kind")?.to_string(),
                name: a.req_str("name")?.to_string(),
                file: dir.join(a.req_str("file")?),
                batch: a.req_usize("batch")?,
                window: a.req_usize("window")?,
                chunk: a.req_usize("chunk")?,
                inputs: a
                    .req_arr("inputs")?
                    .iter()
                    .map(parse_io)
                    .collect::<Result<_>>()?,
                outputs: a
                    .req_arr("outputs")?
                    .iter()
                    .map(parse_io)
                    .collect::<Result<_>>()?,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            models,
            artifacts,
            synthetic: false,
        })
    }

    /// Build the manifest in-process, mirroring the shape grid of
    /// python/compile/aot.py (`DEFAULT_SHAPES`): the `tiny` model gets the
    /// full (batch, window) ∈ {1,4} × {256,1024} set, the other trained
    /// models the (1, 256) smoke subset. Used when `make artifacts` has not
    /// run — the native executor (runtime/native.rs) serves these entries
    /// without any compiled HLO on disk.
    pub fn synthetic(dir: &Path) -> Manifest {
        let mut models = BTreeMap::new();
        for name in ["tiny", "tiny-small", "tiny-large"] {
            models.insert(
                name.to_string(),
                crate::config::model::trained(name).expect("builtin trained config"),
            );
        }
        let mut artifacts = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        for (name, cfg) in &models {
            let shapes: &[(usize, usize)] = if name == "tiny" {
                &[(1, 256), (4, 256), (1, 1024), (4, 1024)]
            } else {
                &[(1, 256)]
            };
            for &(batch, window) in shapes {
                synth_entries(cfg, batch, window, 64, dir, &mut artifacts, &mut seen);
            }
        }
        Manifest {
            dir: dir.to_path_buf(),
            models,
            artifacts,
            synthetic: true,
        }
    }

    /// Find the artifact for (model, kind) with exact batch and, for
    /// attention kinds, exact window.
    pub fn find(
        &self,
        model: &str,
        kind: &str,
        batch: usize,
        window: Option<usize>,
    ) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| {
                a.model == model
                    && a.kind == kind
                    && a.batch == batch
                    && window.is_none_or(|w| a.window == w)
            })
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for model={model} kind={kind} batch={batch} window={window:?}; \
                     available: {:?}",
                    self.artifacts
                        .iter()
                        .filter(|a| a.model == model)
                        .map(|a| (&a.kind, a.batch, a.window))
                        .collect::<Vec<_>>()
                )
            })
    }

    /// Window sizes compiled for a model (ascending).
    pub fn windows_for(&self, model: &str) -> Vec<usize> {
        let mut w: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.model == model && a.kind == "attn_step")
            .map(|a| a.window)
            .collect();
        w.sort_unstable();
        w.dedup();
        w
    }

    pub fn batches_for(&self, model: &str) -> Vec<usize> {
        let mut b: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.model == model && a.kind == "attn_step")
            .map(|a| a.batch)
            .collect();
        b.sort_unstable();
        b.dedup();
        b
    }
}

/// Emit the artifact metas of one (model, batch, window) shape — the exact
/// IO contract python/compile/aot.py::build_entries lowers, kept in lockstep
/// so a real exported manifest and the synthetic one are interchangeable.
fn synth_entries(
    cfg: &ModelConfig,
    batch: usize,
    window: usize,
    chunk: usize,
    dir: &Path,
    out: &mut Vec<ArtifactMeta>,
    seen: &mut std::collections::BTreeSet<String>,
) {
    let (d, h, dh, f, v) = (
        cfg.d_model,
        cfg.n_heads,
        cfg.d_head(),
        cfg.d_ffn,
        cfg.vocab,
    );
    let io = |name: &str, shape: Vec<usize>, dtype: &str| IoSpec {
        name: name.to_string(),
        shape,
        dtype: dtype.to_string(),
    };
    let f32s = |name: &str, shape: Vec<usize>| io(name, shape, "float32");
    let i32s = |name: &str, shape: Vec<usize>| io(name, shape, "int32");
    let mut push = |kind: &str, name: String, inputs: Vec<IoSpec>, outputs: Vec<IoSpec>| {
        let full = format!("{}__{}", cfg.name, name);
        if !seen.insert(full.clone()) {
            return;
        }
        out.push(ArtifactMeta {
            model: cfg.name.clone(),
            kind: kind.to_string(),
            file: dir.join(format!("{full}.hlo.txt")),
            name: full,
            batch,
            window,
            chunk,
            inputs,
            outputs,
        });
    };
    for (n, tag) in [(1usize, "d"), (chunk, "p")] {
        push(
            "embed",
            format!("embed_{tag}_b{batch}"),
            vec![
                i32s("tokens", vec![batch, n]),
                i32s("positions", vec![batch, n]),
                f32s("tok_emb", vec![v, d]),
                f32s("pos_emb", vec![cfg.max_pos, d]),
            ],
            vec![f32s("hidden", vec![batch, n, d])],
        );
        push(
            "attn_step",
            format!("attn_{tag}_b{batch}_w{window}"),
            vec![
                f32s("hidden", vec![batch, n, d]),
                f32s("ln1_g", vec![d]),
                f32s("ln1_b", vec![d]),
                f32s("wq", vec![d, d]),
                f32s("bq", vec![d]),
                f32s("wk", vec![d, d]),
                f32s("bk", vec![d]),
                f32s("wv", vec![d, d]),
                f32s("bv", vec![d]),
                f32s("k_win", vec![batch, h, window, dh]),
                f32s("v_win", vec![batch, h, window, dh]),
                i32s("win_len", vec![batch]),
                i32s("n_valid", vec![batch]),
            ],
            vec![
                f32s("q", vec![batch, h, n, dh]),
                f32s("k_new", vec![batch, h, n, dh]),
                f32s("v_new", vec![batch, h, n, dh]),
                f32s("o_gpu", vec![batch, h, n, dh]),
                f32s("lse", vec![batch, h, n]),
                f32s("a_sum", vec![batch, h, window + n]),
            ],
        );
        push(
            "post_attn",
            format!("post_{tag}_b{batch}"),
            vec![
                f32s("hidden", vec![batch, n, d]),
                f32s("o_merged", vec![batch, n, d]),
                f32s("wo", vec![d, d]),
                f32s("bo", vec![d]),
                f32s("ln2_g", vec![d]),
                f32s("ln2_b", vec![d]),
                f32s("w1", vec![d, f]),
                f32s("b1", vec![f]),
                f32s("w2", vec![f, d]),
                f32s("b2", vec![d]),
            ],
            vec![f32s("hidden_out", vec![batch, n, d])],
        );
    }
    push(
        "lm_head",
        format!("lm_head_b{batch}"),
        vec![
            f32s("hidden", vec![batch, 1, d]),
            f32s("lnf_g", vec![d]),
            f32s("lnf_b", vec![d]),
            f32s("tok_emb", vec![v, d]),
        ],
        vec![f32s("logits", vec![batch, 1, v])],
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "models": {"tiny": {"name":"tiny","vocab":256,"n_layers":4,"d_model":128,
                 "n_heads":4,"d_ffn":512,"max_pos":20480,"d_head":32}},
      "artifacts": [
        {"model":"tiny","kind":"attn_step","name":"tiny__attn_d_b1_w256",
         "file":"tiny__attn_d_b1_w256.hlo.txt","batch":1,"window":256,"chunk":64,
         "inputs":[{"name":"hidden","shape":[1,1,128],"dtype":"float32"}],
         "outputs":[{"name":"q","shape":[1,4,1,32]}]},
        {"model":"tiny","kind":"attn_step","name":"tiny__attn_d_b4_w1024",
         "file":"f2.hlo.txt","batch":4,"window":1024,"chunk":64,
         "inputs":[],"outputs":[]}
      ]
    }"#;

    fn manifest() -> Manifest {
        let dir = std::env::temp_dir().join("hgca_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn parses_models_and_artifacts() {
        let m = manifest();
        assert_eq!(m.models["tiny"].n_layers, 4);
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.artifacts[0].inputs[0].shape, vec![1, 1, 128]);
    }

    #[test]
    fn find_exact_match() {
        let m = manifest();
        let a = m.find("tiny", "attn_step", 1, Some(256)).unwrap();
        assert_eq!(a.name, "tiny__attn_d_b1_w256");
        assert!(m.find("tiny", "attn_step", 2, Some(256)).is_err());
        assert!(m.find("tiny", "attn_step", 1, Some(512)).is_err());
    }

    #[test]
    fn windows_and_batches() {
        let m = manifest();
        assert_eq!(m.windows_for("tiny"), vec![256, 1024]);
        assert_eq!(m.batches_for("tiny"), vec![1, 4]);
        assert!(m.windows_for("nope").is_empty());
    }

    #[test]
    fn synthetic_manifest_matches_python_shape_grid() {
        let m = Manifest::synthetic(Path::new("nowhere"));
        assert!(m.synthetic);
        assert_eq!(m.windows_for("tiny"), vec![256, 1024]);
        assert_eq!(m.batches_for("tiny"), vec![1, 4]);
        assert_eq!(m.windows_for("tiny-small"), vec![256]);
        assert_eq!(m.windows_for("tiny-large"), vec![256]);
        // one embed per (batch, n) — deduped across the window loop
        let embeds: Vec<_> = m
            .artifacts
            .iter()
            .filter(|a| a.model == "tiny" && a.kind == "embed")
            .collect();
        assert_eq!(embeds.len(), 4); // {b1,b4} × {n=1, n=chunk}
        // the IO contract find_artifact matches on: first input dim 1 == n
        let a = m
            .artifacts
            .iter()
            .find(|a| a.name == "tiny__attn_p_b4_w1024")
            .unwrap();
        assert_eq!(a.inputs[0].shape, vec![4, 64, 128]);
        assert_eq!(a.inputs[9].shape, vec![4, 4, 1024, 32]); // k_win
        assert_eq!(a.outputs[5].shape, vec![4, 4, 1024 + 64]); // a_sum
    }

    #[test]
    fn loaded_manifest_is_not_synthetic() {
        assert!(!manifest().synthetic);
    }
}
