//! Artifact manifest — the contract between python/compile/aot.py and the
//! rust runtime. Parses artifacts/manifest.json and answers "which compiled
//! executable serves (model, kind, batch, window)?".

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::config::ModelConfig;
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub model: String,
    pub kind: String,
    pub name: String,
    pub file: PathBuf,
    pub batch: usize,
    pub window: usize,
    pub chunk: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelConfig>,
    pub artifacts: Vec<ArtifactMeta>,
}

fn parse_io(j: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: j.req_str("name")?.to_string(),
        shape: j
            .req_arr("shape")?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad shape dim")))
            .collect::<Result<_>>()?,
        dtype: j
            .get("dtype")
            .and_then(|d| d.as_str())
            .unwrap_or("float32")
            .to_string(),
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;

        let mut models = BTreeMap::new();
        if let Some(obj) = j.req("models")?.as_obj() {
            for (name, mj) in obj {
                models.insert(name.clone(), ModelConfig::from_json(mj)?);
            }
        }
        let mut artifacts = Vec::new();
        for a in j.req_arr("artifacts")? {
            artifacts.push(ArtifactMeta {
                model: a.req_str("model")?.to_string(),
                kind: a.req_str("kind")?.to_string(),
                name: a.req_str("name")?.to_string(),
                file: dir.join(a.req_str("file")?),
                batch: a.req_usize("batch")?,
                window: a.req_usize("window")?,
                chunk: a.req_usize("chunk")?,
                inputs: a
                    .req_arr("inputs")?
                    .iter()
                    .map(parse_io)
                    .collect::<Result<_>>()?,
                outputs: a
                    .req_arr("outputs")?
                    .iter()
                    .map(parse_io)
                    .collect::<Result<_>>()?,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            models,
            artifacts,
        })
    }

    /// Find the artifact for (model, kind) with exact batch and, for
    /// attention kinds, exact window.
    pub fn find(
        &self,
        model: &str,
        kind: &str,
        batch: usize,
        window: Option<usize>,
    ) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| {
                a.model == model
                    && a.kind == kind
                    && a.batch == batch
                    && window.is_none_or(|w| a.window == w)
            })
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for model={model} kind={kind} batch={batch} window={window:?}; \
                     available: {:?}",
                    self.artifacts
                        .iter()
                        .filter(|a| a.model == model)
                        .map(|a| (&a.kind, a.batch, a.window))
                        .collect::<Vec<_>>()
                )
            })
    }

    /// Window sizes compiled for a model (ascending).
    pub fn windows_for(&self, model: &str) -> Vec<usize> {
        let mut w: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.model == model && a.kind == "attn_step")
            .map(|a| a.window)
            .collect();
        w.sort_unstable();
        w.dedup();
        w
    }

    pub fn batches_for(&self, model: &str) -> Vec<usize> {
        let mut b: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.model == model && a.kind == "attn_step")
            .map(|a| a.batch)
            .collect();
        b.sort_unstable();
        b.dedup();
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "models": {"tiny": {"name":"tiny","vocab":256,"n_layers":4,"d_model":128,
                 "n_heads":4,"d_ffn":512,"max_pos":20480,"d_head":32}},
      "artifacts": [
        {"model":"tiny","kind":"attn_step","name":"tiny__attn_d_b1_w256",
         "file":"tiny__attn_d_b1_w256.hlo.txt","batch":1,"window":256,"chunk":64,
         "inputs":[{"name":"hidden","shape":[1,1,128],"dtype":"float32"}],
         "outputs":[{"name":"q","shape":[1,4,1,32]}]},
        {"model":"tiny","kind":"attn_step","name":"tiny__attn_d_b4_w1024",
         "file":"f2.hlo.txt","batch":4,"window":1024,"chunk":64,
         "inputs":[],"outputs":[]}
      ]
    }"#;

    fn manifest() -> Manifest {
        let dir = std::env::temp_dir().join("hgca_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn parses_models_and_artifacts() {
        let m = manifest();
        assert_eq!(m.models["tiny"].n_layers, 4);
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.artifacts[0].inputs[0].shape, vec![1, 1, 128]);
    }

    #[test]
    fn find_exact_match() {
        let m = manifest();
        let a = m.find("tiny", "attn_step", 1, Some(256)).unwrap();
        assert_eq!(a.name, "tiny__attn_d_b1_w256");
        assert!(m.find("tiny", "attn_step", 2, Some(256)).is_err());
        assert!(m.find("tiny", "attn_step", 1, Some(512)).is_err());
    }

    #[test]
    fn windows_and_batches() {
        let m = manifest();
        assert_eq!(m.windows_for("tiny"), vec![256, 1024]);
        assert_eq!(m.batches_for("tiny"), vec![1, 4]);
        assert!(m.windows_for("nope").is_empty());
    }
}
