//! Native artifact executor — the in-process "GPU device".
//!
//! The real deployment compiles the four entry points of
//! `python/compile/model.py` to XLA artifacts executed over the PJRT C API.
//! That path needs the `xla` bindings crate plus `make artifacts`, neither of
//! which exists in the offline build environment, so this module implements
//! the *same contract* (kinds, input order, shapes, numerics) in pure Rust:
//!
//! * `embed`     — token + learned position embedding lookup
//! * `attn_step` — LN → QKV projection → dense windowed attention over the
//!   GPU-resident KV window with LSE + per-slot attention mass (the GPU half
//!   of Algorithm 2 / MAW tracking of Algorithm 1)
//! * `post_attn` — output projection + residual + FFN
//! * `lm_head`   — final LN + tied-embedding logits
//!
//! Numerics mirror `python/compile/kernels/ref.py`: scores over *valid*
//! slots only (window slot `j < win_len[b]`; chunk slot `i` visible to query
//! `n` iff `i <= n && i < n_valid[b]`), softmax via the shared
//! [`softmax_lse`] primitive, fully-masked rows yield `lse ≈ EMPTY_LSE` and
//! zero output so the LSE merge treats them as empty.
//!
//! Every (batch row, head, query) is computed independently — no cross-row
//! reductions — so results are bitwise identical whether a row runs alone
//! (batch=1) or padded into a larger batch. The continuous-batching
//! conformance tests (tests/integration_pool.rs) rely on this.

use anyhow::{bail, Result};

use crate::config::ModelConfig;
use crate::tensor::ops::{axpy, dot, gelu_slice, layernorm, softmax_lse};

use super::artifacts::ArtifactMeta;

/// A resolved runtime argument (weights already looked up by the caller).
pub enum Val<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl<'a> Val<'a> {
    fn f32(&self, what: &str) -> Result<&'a [f32]> {
        match *self {
            Val::F32(v) => Ok(v),
            Val::I32(_) => bail!("{what}: expected f32 buffer, got i32"),
        }
    }

    fn i32(&self, what: &str) -> Result<&'a [i32]> {
        match *self {
            Val::I32(v) => Ok(v),
            Val::F32(_) => bail!("{what}: expected i32 buffer, got f32"),
        }
    }
}

/// Execute one artifact call natively. `vals` follows the manifest input
/// order exactly (the contract python/compile/aot.py::build_entries pins).
pub fn execute(cfg: &ModelConfig, meta: &ArtifactMeta, vals: &[Val<'_>]) -> Result<Vec<Vec<f32>>> {
    anyhow::ensure!(
        vals.len() == meta.inputs.len(),
        "{}: {} args for {} declared inputs",
        meta.name,
        vals.len(),
        meta.inputs.len()
    );
    let b = meta.batch;
    // N is dim 1 of the first input for every kind (tokens [B,N] for embed,
    // hidden [B,N,D] otherwise) — same rule find_artifact matches on.
    let n = meta
        .inputs
        .first()
        .and_then(|i| i.shape.get(1).copied())
        .unwrap_or(1);
    match meta.kind.as_str() {
        "embed" => embed(cfg, b, n, vals),
        "attn_step" => attn_step(cfg, b, n, meta.window, vals),
        "post_attn" => post_attn(cfg, b, n, vals),
        "lm_head" => lm_head(cfg, b, vals),
        other => bail!("{}: unknown artifact kind '{other}'", meta.name),
    }
}

/// y[n] = x[k] @ W[k,n] + bias[n] over flat row-major W — same accumulation
/// order as tensor::ops::affine so the native path and the rust oracle agree
/// bit-for-bit.
fn affine_flat(x: &[f32], w: &[f32], k: usize, n: usize, bias: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(bias.len(), n);
    debug_assert_eq!(out.len(), n);
    out.copy_from_slice(bias);
    for (p, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let wrow = &w[p * n..(p + 1) * n];
        for (o, &wv) in out.iter_mut().zip(wrow.iter()) {
            *o += xv * wv;
        }
    }
}

fn check_len(what: &str, got: usize, want: usize) -> Result<()> {
    anyhow::ensure!(got == want, "{what}: buffer len {got}, expected {want}");
    Ok(())
}

/// tokens/positions i32[B,N] → hidden f32[B,N,D].
fn embed(cfg: &ModelConfig, b: usize, n: usize, vals: &[Val<'_>]) -> Result<Vec<Vec<f32>>> {
    let d = cfg.d_model;
    let tokens = vals[0].i32("tokens")?;
    let positions = vals[1].i32("positions")?;
    let tok_emb = vals[2].f32("tok_emb")?;
    let pos_emb = vals[3].f32("pos_emb")?;
    check_len("tokens", tokens.len(), b * n)?;
    check_len("positions", positions.len(), b * n)?;
    check_len("tok_emb", tok_emb.len(), cfg.vocab * d)?;
    check_len("pos_emb", pos_emb.len(), cfg.max_pos * d)?;

    let mut hidden = vec![0.0f32; b * n * d];
    for (i, out) in hidden.chunks_exact_mut(d).enumerate() {
        let tok = tokens[i];
        let pos = positions[i];
        anyhow::ensure!(
            (0..cfg.vocab as i32).contains(&tok),
            "token {tok} out of vocab range"
        );
        anyhow::ensure!(
            (0..cfg.max_pos as i32).contains(&pos),
            "position {pos} exceeds max_pos {}",
            cfg.max_pos
        );
        let e = &tok_emb[tok as usize * d..(tok as usize + 1) * d];
        let p = &pos_emb[pos as usize * d..(pos as usize + 1) * d];
        for j in 0..d {
            out[j] = e[j] + p[j];
        }
    }
    Ok(vec![hidden])
}

/// GPU half of one hybrid attention layer. Input order:
/// [hidden, ln1_g, ln1_b, wq, bq, wk, bk, wv, bv, k_win, v_win, win_len, n_valid]
/// Outputs: [q, k_new, v_new, o_gpu, lse, a_sum].
fn attn_step(
    cfg: &ModelConfig,
    b_n: usize,
    n: usize,
    w: usize,
    vals: &[Val<'_>],
) -> Result<Vec<Vec<f32>>> {
    let (d, h_n, dh) = (cfg.d_model, cfg.n_heads, cfg.d_head());
    let scale = 1.0 / (dh as f32).sqrt();
    let s_total = w + n;

    let hidden = vals[0].f32("hidden")?;
    let ln1_g = vals[1].f32("ln1_g")?;
    let ln1_b = vals[2].f32("ln1_b")?;
    let wq = vals[3].f32("wq")?;
    let bq = vals[4].f32("bq")?;
    let wk = vals[5].f32("wk")?;
    let bk = vals[6].f32("bk")?;
    let wv = vals[7].f32("wv")?;
    let bv = vals[8].f32("bv")?;
    let k_win = vals[9].f32("k_win")?;
    let v_win = vals[10].f32("v_win")?;
    let win_len = vals[11].i32("win_len")?;
    let n_valid = vals[12].i32("n_valid")?;
    check_len("hidden", hidden.len(), b_n * n * d)?;
    check_len("k_win", k_win.len(), b_n * h_n * w * dh)?;
    check_len("v_win", v_win.len(), b_n * h_n * w * dh)?;
    check_len("win_len", win_len.len(), b_n)?;
    check_len("n_valid", n_valid.len(), b_n)?;

    let mut q = vec![0.0f32; b_n * h_n * n * dh];
    let mut k_new = vec![0.0f32; b_n * h_n * n * dh];
    let mut v_new = vec![0.0f32; b_n * h_n * n * dh];
    let mut o_gpu = vec![0.0f32; b_n * h_n * n * dh];
    let mut lse = vec![0.0f32; b_n * h_n * n];
    let mut a_sum = vec![0.0f32; b_n * h_n * s_total];

    let mut x = vec![0.0f32; d];
    let mut row = vec![0.0f32; d];
    let mut scores: Vec<f32> = Vec::with_capacity(s_total);
    let mut slot_of: Vec<usize> = Vec::with_capacity(s_total);
    for b in 0..b_n {
        let wl = (win_len[b].max(0) as usize).min(w);
        let nv = (n_valid[b].max(0) as usize).min(n);
        // ---- LN + QKV projections, split to [H, N, dh] ----
        for t in 0..n {
            layernorm(&hidden[(b * n + t) * d..(b * n + t + 1) * d], ln1_g, ln1_b, &mut x);
            for (wmat, bias, dst, sc) in [
                (wq, bq, &mut q, scale),
                (wk, bk, &mut k_new, 1.0),
                (wv, bv, &mut v_new, 1.0),
            ] {
                affine_flat(&x, wmat, d, d, bias, &mut row);
                for h in 0..h_n {
                    let out = &mut dst[((b * h_n + h) * n + t) * dh..((b * h_n + h) * n + t + 1) * dh];
                    for j in 0..dh {
                        out[j] = row[h * dh + j] * sc;
                    }
                }
            }
        }
        // ---- dense windowed attention with LSE + attention-mass output ----
        for h in 0..h_n {
            let bh = b * h_n + h;
            let kw = &k_win[bh * w * dh..(bh + 1) * w * dh];
            let vw = &v_win[bh * w * dh..(bh + 1) * w * dh];
            let kn = &k_new[bh * n * dh..(bh + 1) * n * dh];
            let vn = &v_new[bh * n * dh..(bh + 1) * n * dh];
            for t in 0..n {
                let qv = &q[(bh * n + t) * dh..(bh * n + t + 1) * dh];
                scores.clear();
                slot_of.clear();
                for s in 0..wl {
                    scores.push(dot(qv, &kw[s * dh..(s + 1) * dh]));
                    slot_of.push(s);
                }
                // chunk slot i visible iff i <= t (causal) and i < n_valid[b]
                for i in 0..nv.min(t + 1) {
                    scores.push(dot(qv, &kn[i * dh..(i + 1) * dh]));
                    slot_of.push(w + i);
                }
                let l = softmax_lse(&mut scores);
                lse[bh * n + t] = l;
                let orow = &mut o_gpu[(bh * n + t) * dh..(bh * n + t + 1) * dh];
                for (si, &p) in scores.iter().enumerate() {
                    if p == 0.0 {
                        continue;
                    }
                    let slot = slot_of[si];
                    let vrow = if slot < w {
                        &vw[slot * dh..(slot + 1) * dh]
                    } else {
                        &vn[(slot - w) * dh..(slot - w + 1) * dh]
                    };
                    axpy(p, vrow, orow);
                }
                if t < nv {
                    // padded query rows never contribute attention mass
                    let arow = &mut a_sum[bh * s_total..(bh + 1) * s_total];
                    for (si, &p) in scores.iter().enumerate() {
                        arow[slot_of[si]] += p;
                    }
                }
            }
        }
    }
    Ok(vec![q, k_new, v_new, o_gpu, lse, a_sum])
}

/// Output projection + residual + FFN. Input order:
/// [hidden, o_merged, wo, bo, ln2_g, ln2_b, w1, b1, w2, b2].
fn post_attn(cfg: &ModelConfig, b_n: usize, n: usize, vals: &[Val<'_>]) -> Result<Vec<Vec<f32>>> {
    let (d, f) = (cfg.d_model, cfg.d_ffn);
    let hidden = vals[0].f32("hidden")?;
    let o_merged = vals[1].f32("o_merged")?;
    let wo = vals[2].f32("wo")?;
    let bo = vals[3].f32("bo")?;
    let ln2_g = vals[4].f32("ln2_g")?;
    let ln2_b = vals[5].f32("ln2_b")?;
    let w1 = vals[6].f32("w1")?;
    let b1 = vals[7].f32("b1")?;
    let w2 = vals[8].f32("w2")?;
    let b2 = vals[9].f32("b2")?;
    check_len("hidden", hidden.len(), b_n * n * d)?;
    check_len("o_merged", o_merged.len(), b_n * n * d)?;
    check_len("w1", w1.len(), d * f)?;
    check_len("w2", w2.len(), f * d)?;

    let mut out = vec![0.0f32; b_n * n * d];
    let mut x = vec![0.0f32; d];
    let mut y = vec![0.0f32; d];
    let mut f1 = vec![0.0f32; f];
    let mut f2 = vec![0.0f32; d];
    for (i, hrow) in out.chunks_exact_mut(d).enumerate() {
        affine_flat(&o_merged[i * d..(i + 1) * d], wo, d, d, bo, &mut y);
        for j in 0..d {
            hrow[j] = hidden[i * d + j] + y[j];
        }
        layernorm(hrow, ln2_g, ln2_b, &mut x);
        affine_flat(&x, w1, d, f, b1, &mut f1);
        gelu_slice(&mut f1);
        affine_flat(&f1, w2, f, d, b2, &mut f2);
        for j in 0..d {
            hrow[j] += f2[j];
        }
    }
    Ok(vec![out])
}

/// Final LN + tied-embedding logits. Input order:
/// [hidden(B,1,D), lnf_g, lnf_b, tok_emb].
fn lm_head(cfg: &ModelConfig, b_n: usize, vals: &[Val<'_>]) -> Result<Vec<Vec<f32>>> {
    let (d, v) = (cfg.d_model, cfg.vocab);
    let hidden = vals[0].f32("hidden")?;
    let lnf_g = vals[1].f32("lnf_g")?;
    let lnf_b = vals[2].f32("lnf_b")?;
    let tok_emb = vals[3].f32("tok_emb")?;
    check_len("hidden", hidden.len(), b_n * d)?;
    check_len("tok_emb", tok_emb.len(), v * d)?;

    let mut logits = vec![0.0f32; b_n * v];
    let mut x = vec![0.0f32; d];
    for b in 0..b_n {
        layernorm(&hidden[b * d..(b + 1) * d], lnf_g, lnf_b, &mut x);
        let lrow = &mut logits[b * v..(b + 1) * v];
        for (tok, l) in lrow.iter_mut().enumerate() {
            *l = dot(&x, &tok_emb[tok * d..(tok + 1) * d]);
        }
    }
    Ok(vec![logits])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{random_weights, RefModel};
    use crate::runtime::artifacts::Manifest;

    fn tiny_small() -> ModelConfig {
        crate::config::model::trained("tiny-small").unwrap()
    }

    fn meta_for<'m>(m: &'m Manifest, model: &str, kind: &str, batch: usize, n: usize) -> &'m ArtifactMeta {
        m.artifacts
            .iter()
            .find(|a| {
                a.model == model
                    && a.kind == kind
                    && a.batch == batch
                    && a.inputs
                        .first()
                        .map(|i| i.shape.get(1).copied().unwrap_or(1) == n)
                        .unwrap_or(false)
            })
            .unwrap()
    }

    #[test]
    fn embed_matches_weight_rows() {
        let cfg = tiny_small();
        let w = random_weights(&cfg, 7);
        let man = Manifest::synthetic(std::path::Path::new("unused"));
        let meta = meta_for(&man, "tiny-small", "embed", 1, 1);
        let tokens = [42i32];
        let positions = [3i32];
        let out = execute(
            &cfg,
            meta,
            &[
                Val::I32(&tokens),
                Val::I32(&positions),
                Val::F32(&w["tok_emb"].data),
                Val::F32(&w["pos_emb"].data),
            ],
        )
        .unwrap();
        let d = cfg.d_model;
        for j in 0..d {
            let want = w["tok_emb"].data[42 * d + j] + w["pos_emb"].data[3 * d + j];
            assert!((out[0][j] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn attn_step_empty_window_matches_causal_self_attention() {
        // With win_len = 0 and a full chunk, attn_step must equal the
        // oracle's causal attention over the chunk tokens alone.
        let cfg = tiny_small();
        let weights = random_weights(&cfg, 11);
        let oracle = RefModel::new(cfg.clone(), weights.clone()).unwrap();
        let man = Manifest::synthetic(std::path::Path::new("unused"));
        let w256 = meta_for(&man, "tiny-small", "attn_step", 1, 64);
        assert_eq!(w256.window, 256);

        let text: Vec<u8> = (0..64u8).map(|i| b'a' + (i % 24)).collect();
        let d = cfg.d_model;
        // build hidden = embeddings (layer 0 input)
        let mut hidden = vec![0.0f32; 64 * d];
        for (t, &tok) in text.iter().enumerate() {
            for j in 0..d {
                hidden[t * d + j] = weights["tok_emb"].data[tok as usize * d + j]
                    + weights["pos_emb"].data[t * d + j];
            }
        }
        let lw = oracle.layer(0);
        let k_win = vec![0.0f32; cfg.n_heads * 256 * cfg.d_head()];
        let v_win = k_win.clone();
        let win_len = [0i32];
        let n_valid = [64i32];
        let out = execute(
            &cfg,
            w256,
            &[
                Val::F32(&hidden),
                Val::F32(&lw.ln1_g.data),
                Val::F32(&lw.ln1_b.data),
                Val::F32(&lw.wq.data),
                Val::F32(&lw.bq.data),
                Val::F32(&lw.wk.data),
                Val::F32(&lw.bk.data),
                Val::F32(&lw.wv.data),
                Val::F32(&lw.bv.data),
                Val::F32(&k_win),
                Val::F32(&v_win),
                Val::I32(&win_len),
                Val::I32(&n_valid),
            ],
        )
        .unwrap();
        let o_gpu = &out[3];
        // oracle attention output for layer 0 (capture=true gives probs; we
        // recompute o from q/k/v the slow way instead: forward() already
        // applies attention inside — compare via the captured probs path)
        let (_, probs) = oracle.forward(&text, true);
        let (h_n, dh) = (cfg.n_heads, cfg.d_head());
        // reconstruct expected o for a few positions from probs and v
        // (v = ln(hidden) @ wv + bv, same as k_new path); reuse out[2] = v_new
        let v_new = &out[2];
        for &t in &[0usize, 5, 63] {
            for h in 0..h_n {
                let p = &probs[0][h][t]; // [t+1]
                let mut want = vec![0.0f32; dh];
                for (s, &pw) in p.iter().enumerate() {
                    for j in 0..dh {
                        want[j] += pw * v_new[(h * 64 + s) * dh + j];
                    }
                }
                let got = &o_gpu[(h * 64 + t) * dh..(h * 64 + t + 1) * dh];
                for j in 0..dh {
                    assert!(
                        (got[j] - want[j]).abs() < 1e-4,
                        "t={t} h={h} j={j}: {} vs {}",
                        got[j],
                        want[j]
                    );
                }
            }
        }
    }

    #[test]
    fn fully_masked_row_yields_empty_lse_and_zero_output() {
        let cfg = tiny_small();
        let w = random_weights(&cfg, 3);
        let oracle = RefModel::new(cfg.clone(), w).unwrap();
        let man = Manifest::synthetic(std::path::Path::new("unused"));
        let meta = meta_for(&man, "tiny-small", "attn_step", 1, 1);
        let d = cfg.d_model;
        let hidden = vec![0.1f32; d];
        let lw = oracle.layer(0);
        let k_win = vec![0.0f32; cfg.n_heads * meta.window * cfg.d_head()];
        let v_win = k_win.clone();
        let out = execute(
            &cfg,
            meta,
            &[
                Val::F32(&hidden),
                Val::F32(&lw.ln1_g.data),
                Val::F32(&lw.ln1_b.data),
                Val::F32(&lw.wq.data),
                Val::F32(&lw.bq.data),
                Val::F32(&lw.wk.data),
                Val::F32(&lw.bk.data),
                Val::F32(&lw.wv.data),
                Val::F32(&lw.bv.data),
                Val::F32(&k_win),
                Val::F32(&v_win),
                Val::I32(&[0]),
                Val::I32(&[0]), // n_valid = 0 → no visible slots at all
            ],
        )
        .unwrap();
        assert!(out[3].iter().all(|&x| x == 0.0), "o_gpu must be zero");
        assert!(out[4].iter().all(|&l| l <= crate::attention::EMPTY_LSE));
        assert!(out[5].iter().all(|&a| a == 0.0), "a_sum must be zero");
    }
}
