//! Mini benchmark harness (criterion is not in the vendored registry):
//! warmup + timed iterations + summary, and a row-printer for the
//! paper-figure tables every bench target emits.

use std::time::Instant;

use crate::util::stats::{summarize, Summary};

/// Time `f` for `iters` iterations after `warmup` runs; returns per-call
/// seconds summary.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    summarize(&samples)
}

/// Fixed-width table printer for bench output.
pub struct Table {
    pub widths: Vec<usize>,
}

impl Table {
    pub fn new(headers: &[&str], widths: &[usize]) -> Table {
        let t = Table { widths: widths.to_vec() };
        t.row(headers);
        let total: usize = widths.iter().sum::<usize>() + widths.len() * 2;
        println!("{}", "-".repeat(total));
        t
    }

    pub fn row(&self, cells: &[&str]) {
        let mut line = String::new();
        for (c, w) in cells.iter().zip(self.widths.iter()) {
            line.push_str(&format!("{:>w$}  ", c, w = w));
        }
        println!("{}", line.trim_end());
    }
}

/// Quick-mode switch: benches print full sweeps only with HGCA_BENCH_FULL=1
/// (CI and `cargo bench` default to the fast subset).
pub fn full_mode() -> bool {
    std::env::var("HGCA_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let s = bench(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn full_mode_reads_env() {
        // just exercise the call; value depends on environment
        let _ = full_mode();
    }
}
